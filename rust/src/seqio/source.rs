//! Data sources (seqio.DataSource): where raw examples come from.
//!
//! * [`TextLineSource`] — newline-delimited text files (the TextLineDataSource).
//! * [`RecordSource`] — our sharded record files (the TFRecord substitute).
//! * [`SyntheticTextSource`] — a seeded Markov-chain corpus generator, the
//!   documented stand-in for C4/mC4 (DESIGN.md substitution table): it
//!   produces multi-sentence "documents" so the global-shuffle experiment
//!   (E8) has real within-document correlation to destroy.
//! * [`FunctionSource`] — arbitrary generator (seqio.FunctionDataSource).

use std::path::PathBuf;
use std::sync::Arc;

use super::dataset::{check_tag, field_usize, Dataset, DatasetFactory, PipelineOp};
use super::records::RecordReader;
use super::{deserialize_example, text_example, Example, Feature};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// A source of raw examples; `num_input_examples` is advisory (None if
/// unknown). Sources are factories so epochs/retries re-instantiate.
pub trait DataSource: Send + Sync {
    fn dataset(&self, shard_id: usize, num_shards: usize) -> Dataset;

    fn num_input_examples(&self) -> Option<usize> {
        None
    }

    /// Convenience: unsharded stream.
    fn all(&self) -> Dataset {
        self.dataset(0, 1)
    }
}

// ---------------------------------------------------------------------------

/// Newline-delimited text files; each line becomes `{"text": line}`.
pub struct TextLineSource {
    pub paths: Vec<PathBuf>,
}

impl TextLineSource {
    pub fn new(paths: Vec<PathBuf>) -> Self {
        Self { paths }
    }
}

impl DataSource for TextLineSource {
    fn dataset(&self, shard_id: usize, num_shards: usize) -> Dataset {
        // Global line enumeration, round-robin sharded by line index.
        // Native op: its checkpoint state is three cursors (file, line,
        // global), so restore seeks within one file instead of replaying
        // the whole stream.
        Dataset::from_op(TextLineOp {
            paths: self.paths.clone(),
            shard_id,
            num_shards: num_shards.max(1),
            file_idx: 0,
            line_idx: 0,
            global_idx: 0,
            lines: None,
        })
    }
}

/// Native op over newline-delimited text files. `lines` is a lazy cache
/// of the current file; it is never part of the state.
struct TextLineOp {
    paths: Vec<PathBuf>,
    shard_id: usize,
    num_shards: usize,
    /// Index of the file the cursor is in.
    file_idx: usize,
    /// Next line within that file.
    line_idx: usize,
    /// Global line counter across files (for round-robin sharding).
    global_idx: usize,
    lines: Option<Vec<String>>,
}

impl PipelineOp for TextLineOp {
    fn next(&mut self) -> Option<Example> {
        loop {
            if self.file_idx >= self.paths.len() {
                return None;
            }
            if self.lines.is_none() {
                let text =
                    std::fs::read_to_string(&self.paths[self.file_idx]).unwrap_or_default();
                self.lines = Some(text.lines().map(|l| l.to_string()).collect());
            }
            let lines = self.lines.as_ref().unwrap();
            if self.line_idx >= lines.len() {
                self.file_idx += 1;
                self.line_idx = 0;
                self.lines = None;
                continue;
            }
            let line = lines[self.line_idx].clone();
            let g = self.global_idx;
            self.line_idx += 1;
            self.global_idx += 1;
            if g % self.num_shards == self.shard_id {
                return Some(text_example(&[("text", &line)]));
            }
        }
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![
            ("op", Json::str("text_lines")),
            ("file", Json::num(self.file_idx as f64)),
            ("line", Json::num(self.line_idx as f64)),
            ("global", Json::num(self.global_idx as f64)),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "text_lines")?;
        self.file_idx = field_usize(s, "file")?;
        self.line_idx = field_usize(s, "line")?;
        self.global_idx = field_usize(s, "global")?;
        self.lines = None; // reloaded lazily at the restored cursor
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// Reads serialized [`Example`]s from sharded record files. Shards map to
/// whole files (a shard gets files f with f % num_shards == shard_id).
pub struct RecordSource {
    pub paths: Vec<PathBuf>,
}

impl RecordSource {
    pub fn new(mut paths: Vec<PathBuf>) -> Self {
        paths.sort();
        Self { paths }
    }

    pub fn from_dir(dir: impl Into<PathBuf>) -> anyhow::Result<Self> {
        let dir = dir.into();
        let mut paths = Vec::new();
        for entry in std::fs::read_dir(&dir)? {
            let p = entry?.path();
            if p.extension().map(|e| e == "rec").unwrap_or(false) {
                paths.push(p);
            }
        }
        anyhow::ensure!(!paths.is_empty(), "no .rec files in {}", dir.display());
        Ok(Self::new(paths))
    }
}

impl DataSource for RecordSource {
    fn dataset(&self, shard_id: usize, num_shards: usize) -> Dataset {
        let mine: Vec<PathBuf> = self
            .paths
            .iter()
            .enumerate()
            .filter(|(i, _)| i % num_shards == shard_id)
            .map(|(_, p)| p.clone())
            .collect();
        // Native op: state is a (file, entry) cursor and restore seeks via
        // the sidecar record index — O(1), no replay or buffered examples.
        Dataset::from_op(RecordSourceOp { paths: mine, file_idx: 0, entry_idx: 0, reader: None })
    }

    fn num_input_examples(&self) -> Option<usize> {
        let mut total = 0;
        for p in &self.paths {
            total += RecordReader::open(p).ok()?.len();
        }
        Some(total)
    }
}

/// Native op over this shard's record files. Unreadable files and
/// undecodable payloads are skipped, and a read error abandons the rest of
/// the file (the behaviour of the previous opaque-iterator reader).
struct RecordSourceOp {
    paths: Vec<PathBuf>,
    file_idx: usize,
    /// Next entry within the current file.
    entry_idx: usize,
    /// Open reader for `paths[file_idx]`, positioned at `entry_idx`.
    /// Lazily (re)opened; never part of the state.
    reader: Option<RecordReader>,
}

impl RecordSourceOp {
    fn advance_file(&mut self) {
        self.file_idx += 1;
        self.entry_idx = 0;
        self.reader = None;
    }
}

impl PipelineOp for RecordSourceOp {
    fn next(&mut self) -> Option<Example> {
        loop {
            if self.file_idx >= self.paths.len() {
                return None;
            }
            if self.reader.is_none() {
                match RecordReader::open(&self.paths[self.file_idx]) {
                    Ok(mut r) => {
                        if r.seek_to(self.entry_idx).is_err() {
                            self.advance_file();
                            continue;
                        }
                        self.reader = Some(r);
                    }
                    Err(_) => {
                        self.advance_file();
                        continue;
                    }
                }
            }
            match self.reader.as_mut().unwrap().read_next() {
                Some(Ok(payload)) => {
                    self.entry_idx += 1;
                    match deserialize_example(&payload) {
                        Ok(ex) => return Some(ex),
                        Err(_) => continue, // skip undecodable payloads
                    }
                }
                Some(Err(_)) | None => self.advance_file(),
            }
        }
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![
            ("op", Json::str("record_source")),
            ("file", Json::num(self.file_idx as f64)),
            ("entry", Json::num(self.entry_idx as f64)),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "record_source")?;
        self.file_idx = field_usize(s, "file")?;
        self.entry_idx = field_usize(s, "entry")?;
        self.reader = None; // reopened lazily, seeking via the sidecar index
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// Seeded Markov-chain document generator — the C4 substitute.
///
/// A small vocabulary of synthetic words is arranged in a sparse first-order
/// Markov chain; documents are `sentences_per_doc` sentences of
/// `words_per_sentence` words. Every document carries a `doc_id` feature so
/// experiments can measure within-document correlation before/after
/// shuffling (E8).
///
/// Streams are native [`PipelineOp`]s: each document is generated
/// independently from `(seed, doc_idx)`, so the op's checkpoint state is a
/// single cursor and restore seeks in O(1) (no replay).
#[derive(Clone)]
pub struct SyntheticTextSource {
    pub seed: u64,
    pub num_docs: usize,
    pub sentences_per_doc: usize,
    pub words_per_sentence: usize,
    words: Arc<Vec<String>>,
    transitions: Arc<Vec<Vec<usize>>>,
}

impl SyntheticTextSource {
    pub fn new(seed: u64, num_docs: usize) -> Self {
        Self::with_shape(seed, num_docs, 5, 12)
    }

    pub fn with_shape(
        seed: u64,
        num_docs: usize,
        sentences_per_doc: usize,
        words_per_sentence: usize,
    ) -> Self {
        // Build a pronounceable synthetic word list: syllable pairs/triples.
        let onsets = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z"];
        let nuclei = ["a", "e", "i", "o", "u"];
        let mut words = Vec::new();
        for o1 in &onsets {
            for n1 in &nuclei {
                for o2 in &onsets {
                    words.push(format!("{o1}{n1}{o2}a"));
                    if words.len() >= 512 {
                        break;
                    }
                }
                if words.len() >= 512 {
                    break;
                }
            }
            if words.len() >= 512 {
                break;
            }
        }
        // Sparse Markov transitions: each word links to 8 successors.
        let mut rng = Pcg64::new(seed ^ 0xC0FFEE);
        let transitions: Vec<Vec<usize>> = (0..words.len())
            .map(|_| {
                (0..8)
                    .map(|_| rng.next_below(words.len() as u64) as usize)
                    .collect()
            })
            .collect();
        Self {
            seed,
            num_docs,
            sentences_per_doc,
            words_per_sentence,
            words: Arc::new(words),
            transitions: Arc::new(transitions),
        }
    }

    fn gen_doc(&self, doc_idx: usize) -> Example {
        let mut rng = Pcg64::new(self.seed).fold_in(doc_idx as u64);
        let mut text = String::new();
        let mut state = rng.next_below(self.words.len() as u64) as usize;
        for s in 0..self.sentences_per_doc {
            if s > 0 {
                text.push(' ');
            }
            for w in 0..self.words_per_sentence {
                if w > 0 {
                    text.push(' ');
                }
                text.push_str(&self.words[state]);
                let succ = &self.transitions[state];
                state = succ[rng.next_below(succ.len() as u64) as usize];
            }
            text.push('.');
        }
        let mut ex = Example::new();
        ex.insert("text".into(), Feature::Text(text));
        ex.insert("doc_id".into(), Feature::Ints(vec![doc_idx as i32]));
        ex
    }

    /// A factory yielding the full document stream (for Task plumbing).
    pub fn factory(self: Arc<Self>) -> DatasetFactory {
        let me = self.clone();
        DatasetFactory::new(move || me.clone().all())
    }
}

impl DataSource for SyntheticTextSource {
    fn dataset(&self, shard_id: usize, num_shards: usize) -> Dataset {
        assert!(num_shards >= 1 && shard_id < num_shards, "bad shard spec");
        Dataset::from_op(SyntheticTextOp {
            src: self.clone(),
            shard_id,
            num_shards,
            cursor: 0,
        })
    }

    fn num_input_examples(&self) -> Option<usize> {
        Some(self.num_docs)
    }
}

/// Native op over the synthetic corpus. Emits documents
/// `shard_id, shard_id + num_shards, ...` (the index-modulo sharding the
/// opaque-iterator version used); state is the emitted-document count, so
/// restore is a pure cursor assignment — O(1), no stream replay.
struct SyntheticTextOp {
    src: SyntheticTextSource,
    shard_id: usize,
    num_shards: usize,
    /// Documents already emitted for this shard.
    cursor: usize,
}

impl PipelineOp for SyntheticTextOp {
    fn next(&mut self) -> Option<Example> {
        let idx = self.shard_id + self.cursor * self.num_shards;
        if idx >= self.src.num_docs {
            return None;
        }
        self.cursor += 1;
        Some(self.src.gen_doc(idx))
    }

    fn state(&mut self) -> Json {
        Json::obj(vec![
            ("op", Json::str("synthetic_text")),
            ("cursor", Json::num(self.cursor as f64)),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "synthetic_text")?;
        self.cursor = field_usize(s, "cursor")?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// Wraps an arbitrary generator function.
pub struct FunctionSource {
    pub make: Arc<dyn Fn(usize, usize) -> Dataset + Send + Sync>,
    pub count: Option<usize>,
}

impl FunctionSource {
    pub fn new(make: impl Fn(usize, usize) -> Dataset + Send + Sync + 'static) -> Self {
        Self { make: Arc::new(make), count: None }
    }
}

impl DataSource for FunctionSource {
    fn dataset(&self, shard_id: usize, num_shards: usize) -> Dataset {
        (self.make)(shard_id, num_shards)
    }

    fn num_input_examples(&self) -> Option<usize> {
        self.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_deterministic_and_sharded() {
        let s1 = SyntheticTextSource::new(42, 100);
        let s2 = SyntheticTextSource::new(42, 100);
        let a: Vec<Example> = s1.all().collect_vec();
        let b: Vec<Example> = s2.all().collect_vec();
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        // different seed => different text
        let s3 = SyntheticTextSource::new(43, 100);
        assert_ne!(a, s3.all().collect_vec());
        // shards partition the docs
        let sh0 = s1.dataset(0, 4).collect_vec();
        let sh1 = s1.dataset(1, 4).collect_vec();
        assert_eq!(sh0.len(), 25);
        assert_eq!(sh1.len(), 25);
        assert_ne!(sh0[0], sh1[0]);
    }

    #[test]
    fn synthetic_text_nonempty_and_wordy() {
        let s = SyntheticTextSource::new(7, 3);
        for ex in s.all() {
            let text = ex["text"].as_text().unwrap();
            assert!(text.split_whitespace().count() >= 10);
            assert!(text.contains('.'));
        }
    }

    #[test]
    fn synthetic_state_seeks_in_o1() {
        let s = SyntheticTextSource::new(11, 40);
        let all = s.dataset(1, 3).collect_vec();

        let mut first = s.dataset(1, 3);
        let head: Vec<Example> = (&mut first).take(5).collect();
        let snap = first.state();
        // Positional cursor only — no buffered examples in the state.
        assert!(
            snap.to_json_string().len() < 64,
            "state should be a bare cursor: {}",
            snap.to_json_string()
        );

        let mut resumed = s.dataset(1, 3);
        resumed.restore(&snap).unwrap();
        let tail: Vec<Example> = resumed.collect();
        let mut joined = head;
        joined.extend(tail);
        assert_eq!(joined, all);

        // mismatched pipeline shape still fails loudly
        let mut other = Dataset::from_vec(vec![]);
        assert!(other.restore(&snap).is_err());
    }

    #[test]
    fn text_line_state_is_cursor_and_resumes() {
        let dir = std::env::temp_dir().join(format!("tls_state_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = dir.join("a.txt");
        let p2 = dir.join("b.txt");
        std::fs::write(&p1, "a0\na1\na2\n").unwrap();
        std::fs::write(&p2, "b0\nb1\nb2\nb3\n").unwrap();
        let src = TextLineSource::new(vec![p1, p2]);
        let all = src.dataset(1, 2).collect_vec();

        let mut first = src.dataset(1, 2);
        let head: Vec<Example> = (&mut first).take(2).collect();
        let snap = first.state();
        // cursors only, no buffered lines
        assert!(snap.to_json_string().len() < 96, "{}", snap.to_json_string());
        let mut resumed = src.dataset(1, 2);
        resumed.restore(&snap).unwrap();
        let mut joined = head;
        joined.extend(resumed.collect_vec());
        assert_eq!(joined, all);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn record_source_state_seeks_without_replay() {
        use crate::seqio::records::RecordWriter;
        use crate::seqio::serialize_example;
        let dir = std::env::temp_dir().join(format!("recsrc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for f in 0..3 {
            let mut w = RecordWriter::create(dir.join(format!("f{f}.rec"))).unwrap();
            for i in 0..5 {
                let ex = crate::seqio::ints_example(&[("targets", vec![f * 10 + i])]);
                w.write(&serialize_example(&ex)).unwrap();
            }
            w.finish().unwrap();
        }
        let src = RecordSource::from_dir(&dir).unwrap();
        assert_eq!(src.num_input_examples(), Some(15));
        let all = src.dataset(0, 1).collect_vec();
        assert_eq!(all.len(), 15);

        for cut in [0usize, 3, 7, 14] {
            let mut first = src.dataset(0, 1);
            let head: Vec<Example> = (&mut first).take(cut).collect();
            let snap = first.state();
            // a bare (file, entry) cursor — no buffered examples
            assert!(snap.to_json_string().len() < 96, "{}", snap.to_json_string());
            let mut resumed = src.dataset(0, 1);
            resumed.restore(&snap).unwrap();
            let mut joined = head;
            joined.extend(resumed.collect_vec());
            assert_eq!(joined, all, "cut={cut}");
        }

        // sharded readers stay disjoint + exhaustive
        let s0 = src.dataset(0, 2).collect_vec();
        let s1 = src.dataset(1, 2).collect_vec();
        assert_eq!(s0.len() + s1.len(), 15);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn text_line_source_shards_lines() {
        let dir = std::env::temp_dir().join(format!("tls_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("corpus.txt");
        std::fs::write(&p, "l0\nl1\nl2\nl3\nl4\n").unwrap();
        let src = TextLineSource::new(vec![p.clone()]);
        let all = src.all().collect_vec();
        assert_eq!(all.len(), 5);
        assert_eq!(all[2]["text"].as_text().unwrap(), "l2");
        let even = src.dataset(0, 2).collect_vec();
        assert_eq!(even.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
