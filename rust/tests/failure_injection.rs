//! Failure injection: the system must fail loudly and precisely on
//! corrupted or missing artifacts — not train on garbage.

use t5x::checkpoint::CheckpointManager;
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::seqio::cache::{cache_task, CacheConfig, CacheMeta};
use t5x::seqio::deterministic::DeterministicPipeline;
use t5x::seqio::records::{index_path, RecordReader};
use t5x::trainer::recipes;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("failinj_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn corrupted_cache_shard_detected() {
    let dir = tmpdir("cache");
    let task = recipes::lm_task("failinj_lm", 40, 32, 1);
    cache_task(&task, &dir, &CacheConfig { num_shards: 2, seed: 0, workers: 1 }).unwrap();
    // flip a payload byte in shard 0
    let shard = CacheMeta::shard_file(&dir, 0);
    let mut bytes = std::fs::read(&shard).unwrap();
    let n = bytes.len();
    bytes[n - 2] ^= 0xFF;
    std::fs::write(&shard, &bytes).unwrap();

    let mut r = RecordReader::open(&shard).unwrap();
    let last = r.len() - 1;
    assert!(r.read_at(last).is_err(), "CRC corruption must be detected");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_index_rebuilt_corrupt_meta_rejected() {
    let dir = tmpdir("meta");
    let task = recipes::lm_task("failinj_meta", 20, 32, 1);
    cache_task(&task, &dir, &CacheConfig { num_shards: 2, seed: 0, workers: 1 }).unwrap();
    // deleting the sidecar index is recoverable (rebuild by scan)
    std::fs::remove_file(index_path(&CacheMeta::shard_file(&dir, 0))).unwrap();
    let p = DeterministicPipeline::open(&dir).unwrap();
    assert!(p.global_stream().collect_vec().len() >= 20);
    // corrupting cache_meta.json is a hard error
    std::fs::write(dir.join("cache_meta.json"), "{not json").unwrap();
    assert!(DeterministicPipeline::open(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_chunk_corruption_fails_restore() {
    let dir = tmpdir("ckpt");
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let mgr = CheckpointManager::new(&dir);
    mgr.save(1, &t5x::model::init_params(m, 0), &Vec::new()).unwrap();
    // find one chunk file and corrupt it
    let mut chunk = None;
    for entry in walk(&dir) {
        if entry.file_name().unwrap().to_string_lossy().starts_with("chunk-") {
            chunk = Some(entry);
            break;
        }
    }
    let chunk = chunk.expect("no chunk file found");
    let mut bytes = std::fs::read(&chunk).unwrap();
    let n = bytes.len();
    bytes[n - 1] ^= 0x01;
    std::fs::write(&chunk, bytes).unwrap();
    assert!(mgr.restore(1).is_err(), "corrupt chunk must fail the restore");
    std::fs::remove_dir_all(&dir).ok();
}

fn walk(dir: &std::path::Path) -> Vec<std::path::PathBuf> {
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let p = e.path();
            if p.is_dir() {
                out.extend(walk(&p));
            } else {
                out.push(p);
            }
        }
    }
    out
}

#[test]
fn truncated_hlo_fails_compile_cleanly() {
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let dir = tmpdir("hlo");
    std::fs::create_dir_all(&dir).unwrap();
    let src = &m.entrypoint("train_step").unwrap().hlo;
    let text = std::fs::read_to_string(src).unwrap();
    let truncated = dir.join("broken.hlo.txt");
    std::fs::write(&truncated, &text[..text.len() / 2]).unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let err = device.compile(&truncated);
    assert!(err.is_err(), "truncated HLO must not compile");
    // the device thread survives the failure and can compile valid HLO
    let ok = device.compile(src);
    assert!(ok.is_ok(), "device thread must survive a failed compile");
    device.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wrong_input_count_is_an_error_not_ub() {
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let (exe, _) = device.compile(&m.entrypoint("eval_step").unwrap().hlo).unwrap();
    let result = exe.run(vec![t5x::runtime::HostTensor::scalar_f32(1.0)]);
    assert!(result.is_err());
    device.shutdown();
}

#[test]
fn unknown_model_is_a_clean_error() {
    let arts = Artifacts::load_default().unwrap();
    let err = arts.model("t5-enormous-dec").unwrap_err();
    assert!(err.to_string().contains("not in manifest"));
}
