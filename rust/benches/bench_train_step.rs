//! E16: end-to-end train-step throughput — tokens/sec across model sizes
//! and host counts, 1D vs 2D, gather vs block execution, on the full
//! Rust-coordinated path (infeed-synthetic -> PJRT fwd/bwd -> ring
//! collectives -> optimizer).

use t5x::bench::Bench;
use t5x::optim::{OptimizerKind, Schedule};
use t5x::partitioning::{ExecMode, Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};

fn main() {
    let arts = Artifacts::load_default().expect("make artifacts first");
    let device = DeviceHandle::spawn().unwrap();
    let mut bench = Bench::new("train step (E16)");
    let models: &[&str] = if bench.is_quick() {
        &["t5-nano-dec"]
    } else {
        &["t5-nano-dec", "t5-micro-dec", "t5-small-dec"]
    };
    let steps: u64 = if bench.is_quick() { 2 } else { 4 };

    for model in models {
        let m = arts.model(model).unwrap();
        for (mesh, strategy, exec_mode) in [
            (Mesh::new(1, 1), ParamStrategy::OneD, ExecMode::Gather),
            (Mesh::new(2, 1), ParamStrategy::OneD, ExecMode::Gather),
            (Mesh::new(2, 1), ParamStrategy::TwoD, ExecMode::Gather),
            (Mesh::new(2, 2), ParamStrategy::TwoD, ExecMode::Gather),
            // gather-vs-block head-to-head on model-parallel meshes
            (Mesh::new(1, 2), ParamStrategy::OneD, ExecMode::Gather),
            (Mesh::new(1, 2), ParamStrategy::OneD, ExecMode::Block),
            (Mesh::new(2, 2), ParamStrategy::TwoD, ExecMode::Block),
        ] {
            if exec_mode == ExecMode::Block && !m.supports_block_exec(mesh.model) {
                continue; // artifacts carry no block contract for this model
            }
            let cfg = TrainerConfig {
                model: model.to_string(),
                mesh,
                strategy,
                optimizer: OptimizerKind::adam(),
                schedule: Schedule::Constant(1e-4),
                steps,
                seed: 0,
                log_every: 1000,
                checkpoint_every: None,
                checkpoint_dir: None,
                grad_clip_norm: None,
                weight_decay: None,
                exec_mode,
            };
            let trainer = Trainer::new(&arts, &device, cfg).unwrap();
            let tokens = (m.tokens_per_step() * mesh.data * steps as usize) as f64;
            bench.measure_with_throughput(
                &format!("{model} mesh={mesh} {strategy:?} {exec_mode} ({steps} steps)"),
                Some((tokens, "tok")),
                || {
                    let s = trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
                    assert!(s.final_loss().is_finite());
                },
            );
            // §Perf: phase breakdown + per-host peak param memory
            let rows = trainer.timing.rows();
            let total: f64 = rows.iter().map(|(_, s)| s).sum();
            let pct: Vec<String> = rows
                .iter()
                .map(|(n, s)| format!("{n} {:.0}%", 100.0 * s / total.max(1e-9)))
                .collect();
            println!("      breakdown: {}", pct.join(", "));
            println!(
                "      peak param/grad tensor: {} floats ({} mode)",
                trainer.peak_param_floats(),
                trainer.exec_mode
            );
        }
    }

    // the 100M config: a few steps to prove the path + measure step time
    if !bench.is_quick() {
        let model = "t5-100m-dec";
        let m = arts.model(model).unwrap();
        let cfg = TrainerConfig {
            model: model.into(),
            mesh: Mesh::new(1, 1),
            strategy: ParamStrategy::OneD,
            optimizer: OptimizerKind::adam(),
            schedule: Schedule::Constant(1e-4),
            steps: 1,
            seed: 0,
            log_every: 1000,
            checkpoint_every: None,
            checkpoint_dir: None,
            grad_clip_norm: None,
            weight_decay: None,
            exec_mode: ExecMode::Gather,
        };
        let trainer = Trainer::new(&arts, &device, cfg).unwrap();
        let tokens = m.tokens_per_step() as f64;
        bench.measure_with_throughput(
            &format!("{model} mesh=1x1 OneD (1 step)"),
            Some((tokens, "tok")),
            || {
                let s = trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
                assert!(s.final_loss().is_finite());
            },
        );
    }
    bench.write_jsonl("bench_results.jsonl").unwrap();
    device.shutdown();
}
