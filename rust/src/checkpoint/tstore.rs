//! `tstore`: the TensorStore substitute (S4) — a chunked on-disk array
//! format supporting *sliced* reads and writes, so multiple hosts can
//! write disjoint parameter shards concurrently and restore with a
//! different topology (read-with-resharding), exactly the capability the
//! paper's checkpointing library gets from TensorStore.
//!
//! Layout per array:
//! ```text
//! <root>/<name>/meta.json       {"shape": [...], "chunk_rows": R, "dtype": "f32"}
//! <root>/<name>/chunk-<k>       rows [k*R, (k+1)*R): u32 crc | f32 LE data
//! ```
//! Chunking is along axis 0; sliced IO is row-aligned to chunks.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::runtime::HostTensor;
use crate::util::json::Json;

#[derive(Debug, thiserror::Error)]
pub enum TStoreError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("array {0} not found")]
    NotFound(String),
    #[error("corrupt chunk {0}")]
    Corrupt(PathBuf),
    #[error("unaligned slice: start row {0} not a multiple of chunk rows {1}")]
    Unaligned(usize, usize),
    #[error("{0}")]
    Other(String),
}

/// Array metadata.
#[derive(Debug, Clone)]
pub struct ArrayMeta {
    pub shape: Vec<usize>,
    pub chunk_rows: usize,
}

impl ArrayMeta {
    pub fn rows(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[0]
        }
    }

    pub fn row_elems(&self) -> usize {
        self.shape.iter().skip(1).product()
    }

    pub fn num_chunks(&self) -> usize {
        self.rows().div_ceil(self.chunk_rows)
    }
}

fn meta_path(dir: &Path) -> PathBuf {
    dir.join("meta.json")
}

fn chunk_path(dir: &Path, k: usize) -> PathBuf {
    dir.join(format!("chunk-{k:05}"))
}

/// Create an array (writes metadata only; chunks may be written by any
/// number of hosts afterwards).
pub fn create_array(
    root: &Path,
    name: &str,
    shape: &[usize],
    chunk_rows: usize,
) -> Result<ArrayMeta, TStoreError> {
    let dir = root.join(name);
    std::fs::create_dir_all(&dir)?;
    let meta = ArrayMeta { shape: shape.to_vec(), chunk_rows: chunk_rows.max(1) };
    let j = Json::obj(vec![
        ("shape", Json::arr_usize(shape)),
        ("chunk_rows", Json::num(meta.chunk_rows as f64)),
        ("dtype", Json::str("f32")),
    ]);
    std::fs::write(meta_path(&dir), j.to_string())?;
    Ok(meta)
}

pub fn open_array(root: &Path, name: &str) -> Result<ArrayMeta, TStoreError> {
    let dir = root.join(name);
    let j = Json::parse_file(meta_path(&dir))
        .map_err(|_| TStoreError::NotFound(name.to_string()))?;
    Ok(ArrayMeta {
        shape: j
            .get("shape")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default(),
        chunk_rows: j.get("chunk_rows").and_then(|v| v.as_usize()).unwrap_or(1),
    })
}

/// Write rows [start_row, start_row + data_rows) — start must be
/// chunk-aligned; the last chunk may be partial. Safe to call from
/// different hosts for disjoint chunk-aligned ranges concurrently.
pub fn write_slice(
    root: &Path,
    name: &str,
    meta: &ArrayMeta,
    start_row: usize,
    data: &[f32],
) -> Result<(), TStoreError> {
    if start_row % meta.chunk_rows != 0 {
        return Err(TStoreError::Unaligned(start_row, meta.chunk_rows));
    }
    let dir = root.join(name);
    let row_elems = meta.row_elems().max(1);
    let data_rows = data.len() / row_elems;
    let mut row = 0usize;
    while row < data_rows {
        let k = (start_row + row) / meta.chunk_rows;
        let rows_here = meta.chunk_rows.min(data_rows - row);
        let slice = &data[row * row_elems..(row + rows_here) * row_elems];
        let bytes: Vec<u8> = slice.iter().flat_map(|f| f.to_le_bytes()).collect();
        let crc = crc32fast::hash(&bytes);
        let mut f = std::fs::File::create(chunk_path(&dir, k))?;
        f.write_all(&crc.to_le_bytes())?;
        f.write_all(&bytes)?;
        row += rows_here;
    }
    Ok(())
}

/// Convenience: write a full tensor with the given chunking.
pub fn write_full(
    root: &Path,
    name: &str,
    tensor: &HostTensor,
    chunk_rows: usize,
) -> Result<ArrayMeta, TStoreError> {
    let meta = create_array(root, name, &tensor.shape, chunk_rows)?;
    write_slice(root, name, &meta, 0, tensor.as_f32())?;
    Ok(meta)
}

fn read_chunk(dir: &Path, k: usize) -> Result<Vec<f32>, TStoreError> {
    let path = chunk_path(dir, k);
    let mut f = std::fs::File::open(&path)
        .map_err(|_| TStoreError::Corrupt(path.clone()))?;
    let mut crc_buf = [0u8; 4];
    f.read_exact(&mut crc_buf)?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    if crc32fast::hash(&bytes) != u32::from_le_bytes(crc_buf) {
        return Err(TStoreError::Corrupt(path));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Read rows [start_row, start_row + count) — arbitrary alignment.
pub fn read_slice(
    root: &Path,
    name: &str,
    meta: &ArrayMeta,
    start_row: usize,
    count: usize,
) -> Result<Vec<f32>, TStoreError> {
    let dir = root.join(name);
    let row_elems = meta.row_elems().max(1);
    let mut out = Vec::with_capacity(count * row_elems);
    let mut row = start_row;
    let end = start_row + count;
    while row < end {
        let k = row / meta.chunk_rows;
        let chunk = read_chunk(&dir, k)?;
        let chunk_start = k * meta.chunk_rows;
        let lo = (row - chunk_start) * row_elems;
        let rows_here = (meta.chunk_rows - (row - chunk_start)).min(end - row);
        let hi = lo + rows_here * row_elems;
        out.extend_from_slice(&chunk[lo..hi]);
        row += rows_here;
    }
    Ok(out)
}

/// Read the whole array (chunks in parallel).
pub fn read_full(root: &Path, name: &str) -> Result<HostTensor, TStoreError> {
    let meta = open_array(root, name)?;
    let dir = root.join(name);
    let chunks = crate::util::threads::parallel_map(meta.num_chunks(), 8, |k| {
        read_chunk(&dir, k)
    });
    let mut data = Vec::with_capacity(meta.rows() * meta.row_elems().max(1));
    for c in chunks {
        data.extend_from_slice(&c?);
    }
    Ok(HostTensor::f32(meta.shape.clone(), data))
}

// ---------------------------------------------------------------------------
// Byte arrays (dtype "u8") — small opaque payloads such as the serialized
// data-pipeline state saved with each checkpoint. Same chunk+CRC layout as
// f32 arrays, with bytes instead of rows.
// ---------------------------------------------------------------------------

/// Write an opaque byte payload as a chunked, CRC-protected array.
pub fn write_bytes(
    root: &Path,
    name: &str,
    bytes: &[u8],
    chunk_bytes: usize,
) -> Result<(), TStoreError> {
    let dir = root.join(name);
    std::fs::create_dir_all(&dir)?;
    let chunk = chunk_bytes.max(1);
    let j = Json::obj(vec![
        ("shape", Json::arr_usize(&[bytes.len()])),
        ("chunk_rows", Json::num(chunk as f64)),
        ("dtype", Json::str("u8")),
    ]);
    std::fs::write(meta_path(&dir), j.to_string())?;
    for (k, slice) in bytes.chunks(chunk).enumerate() {
        let crc = crc32fast::hash(slice);
        let mut f = std::fs::File::create(chunk_path(&dir, k))?;
        f.write_all(&crc.to_le_bytes())?;
        f.write_all(slice)?;
    }
    Ok(())
}

/// Read back a byte payload written by [`write_bytes`]. A missing array
/// is `NotFound`; an unreadable/corrupt meta file is `Corrupt` (callers
/// treat `NotFound` as "never written" and must not confuse the two).
pub fn read_bytes(root: &Path, name: &str) -> Result<Vec<u8>, TStoreError> {
    let dir = root.join(name);
    let mpath = meta_path(&dir);
    if !mpath.exists() {
        return Err(TStoreError::NotFound(name.to_string()));
    }
    let j = Json::parse_file(&mpath).map_err(|_| TStoreError::Corrupt(mpath.clone()))?;
    let dtype = j.get("dtype").and_then(|v| v.as_str()).unwrap_or("f32");
    if dtype != "u8" {
        return Err(TStoreError::Other(format!(
            "array {name} has dtype {dtype}, expected u8"
        )));
    }
    let len = j
        .get("shape")
        .and_then(|v| v.as_arr())
        .and_then(|a| a.first())
        .and_then(|v| v.as_usize())
        .ok_or_else(|| TStoreError::Other(format!("array {name} has no shape")))?;
    let mut out = Vec::with_capacity(len);
    let mut k = 0usize;
    while out.len() < len {
        let path = chunk_path(&dir, k);
        let mut f = std::fs::File::open(&path)
            .map_err(|_| TStoreError::Corrupt(path.clone()))?;
        let mut crc_buf = [0u8; 4];
        f.read_exact(&mut crc_buf)?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        if crc32fast::hash(&bytes) != u32::from_le_bytes(crc_buf) {
            return Err(TStoreError::Corrupt(path));
        }
        out.extend_from_slice(&bytes);
        k += 1;
    }
    if out.len() != len {
        return Err(TStoreError::Other(format!(
            "array {name}: expected {len} bytes, found {}",
            out.len()
        )));
    }
    Ok(out)
}

/// List array names under a root.
pub fn list_arrays(root: &Path) -> Result<Vec<String>, TStoreError> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(root)? {
        let p = entry?.path();
        if p.is_dir() && meta_path(&p).exists() {
            out.push(p.file_name().unwrap().to_string_lossy().into_owned());
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tstore_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn full_roundtrip() {
        let root = tmp("full");
        let t = HostTensor::f32(vec![10, 4], (0..40).map(|i| i as f32).collect());
        write_full(&root, "param/a", &t, 3).unwrap();
        let back = read_full(&root, "param/a").unwrap();
        assert_eq!(back, t);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn sliced_multi_writer_roundtrip() {
        // two "hosts" write disjoint chunk-aligned row ranges
        let root = tmp("sliced");
        let meta = create_array(&root, "w", &[8, 3], 2).unwrap();
        let full: Vec<f32> = (0..24).map(|i| i as f32).collect();
        write_slice(&root, "w", &meta, 0, &full[0..12]).unwrap(); // rows 0..4
        write_slice(&root, "w", &meta, 4, &full[12..24]).unwrap(); // rows 4..8
        let back = read_full(&root, "w").unwrap();
        assert_eq!(back.as_f32(), full.as_slice());
        // arbitrary slice read (resharding)
        let rows_3_6 = read_slice(&root, "w", &meta, 3, 3).unwrap();
        assert_eq!(rows_3_6, full[9..18].to_vec());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn unaligned_write_rejected() {
        let root = tmp("unaligned");
        let meta = create_array(&root, "w", &[8, 1], 4).unwrap();
        assert!(matches!(
            write_slice(&root, "w", &meta, 2, &[0.0; 2]),
            Err(TStoreError::Unaligned(2, 4))
        ));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn corruption_detected() {
        let root = tmp("corrupt");
        let t = HostTensor::f32(vec![4], vec![1.0, 2.0, 3.0, 4.0]);
        write_full(&root, "x", &t, 4).unwrap();
        let cp = root.join("x").join("chunk-00000");
        let mut bytes = std::fs::read(&cp).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0x55;
        std::fs::write(&cp, bytes).unwrap();
        assert!(matches!(read_full(&root, "x"), Err(TStoreError::Corrupt(_))));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn bytes_roundtrip_and_corruption() {
        let root = tmp("bytes");
        let payload: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        write_bytes(&root, "pipeline/state", &payload, 128).unwrap();
        assert_eq!(read_bytes(&root, "pipeline/state").unwrap(), payload);
        // empty payload round-trips too
        write_bytes(&root, "empty", &[], 64).unwrap();
        assert_eq!(read_bytes(&root, "empty").unwrap(), Vec::<u8>::new());
        // dtype guard: an f32 array is not readable as bytes
        let t = HostTensor::f32(vec![4], vec![1., 2., 3., 4.]);
        write_full(&root, "floats", &t, 4).unwrap();
        assert!(read_bytes(&root, "floats").is_err());
        // flipped byte detected
        let cp = root.join("pipeline/state").join("chunk-00001");
        let mut bytes = std::fs::read(&cp).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0x01;
        std::fs::write(&cp, bytes).unwrap();
        assert!(matches!(
            read_bytes(&root, "pipeline/state"),
            Err(TStoreError::Corrupt(_))
        ));
        // corrupt meta is Corrupt, never NotFound (NotFound = never written)
        std::fs::write(root.join("empty").join("meta.json"), "{not json").unwrap();
        assert!(matches!(read_bytes(&root, "empty"), Err(TStoreError::Corrupt(_))));
        assert!(matches!(read_bytes(&root, "nope"), Err(TStoreError::NotFound(_))));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn scalar_and_vector_arrays() {
        let root = tmp("scalar");
        let t = HostTensor::f32(vec![5], vec![1., 2., 3., 4., 5.]);
        write_full(&root, "v", &t, 2).unwrap();
        assert_eq!(read_full(&root, "v").unwrap(), t);
        assert_eq!(list_arrays(&root).unwrap(), vec!["v"]);
        std::fs::remove_dir_all(&root).ok();
    }
}
