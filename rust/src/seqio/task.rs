//! Tasks (seqio.Task, Figure 2): a named binding of per-split data
//! sources, preprocessing steps, output features, and evaluation metrics.
//!
//! A Task is one kind of [`crate::seqio::provider::DatasetProvider`];
//! registration goes through the unified
//! [`crate::seqio::provider::ProviderRegistry`] namespace (shared with
//! mixtures), for which [`TaskRegistry`] is the task-typed facade.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::dataset::{Dataset, PipelineState};
use super::evaluation::Metric;
use super::preprocessors::{PipelineCtx, Preprocessor};
use super::source::DataSource;
use super::vocab::Vocabulary;

/// Declared output feature of a task (seqio.Feature).
#[derive(Clone)]
pub struct OutputFeature {
    pub name: String,
    pub vocab: Arc<dyn Vocabulary>,
    pub add_eos: bool,
    pub required: bool,
}

/// A seqio Task.
pub struct Task {
    pub name: String,
    /// The "train" split's source.
    pub source: Arc<dyn DataSource>,
    /// Additional named splits ("validation", "test", ...). All splits
    /// share the task's preprocessor stack.
    pub split_sources: BTreeMap<String, Arc<dyn DataSource>>,
    pub preprocessors: Vec<Arc<dyn Preprocessor>>,
    pub output_features: Vec<OutputFeature>,
    pub metrics: Vec<Metric>,
}

impl Task {
    pub fn builder(name: &str) -> TaskBuilder {
        TaskBuilder {
            name: name.to_string(),
            source: None,
            split_sources: BTreeMap::new(),
            preprocessors: Vec::new(),
            output_features: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// The data source behind `split` ("train" = the main source).
    pub fn source_for(&self, split: &str) -> anyhow::Result<&Arc<dyn DataSource>> {
        if split == "train" {
            return Ok(&self.source);
        }
        self.split_sources.get(split).ok_or_else(|| {
            let mut avail = vec!["train".to_string()];
            avail.extend(self.split_sources.keys().cloned());
            anyhow::anyhow!(
                "task '{}' has no split '{split}' (available: [{}])",
                self.name,
                avail.join(", ")
            )
        })
    }

    /// Instantiate the preprocessed "train" stream for one data shard.
    /// The returned stream is stateful: `Dataset::state()` captures the
    /// whole op graph (source position, preprocessor buffers) and
    /// [`Task::dataset_resumed`] rebuilds + repositions it.
    pub fn dataset(&self, seed: u64, shard_id: usize, num_shards: usize) -> Dataset {
        self.dataset_split("train", seed, shard_id, num_shards)
            .expect("the train split always exists")
    }

    /// Instantiate the preprocessed stream of any split.
    pub fn dataset_split(
        &self,
        split: &str,
        seed: u64,
        shard_id: usize,
        num_shards: usize,
    ) -> anyhow::Result<Dataset> {
        let src = self.source_for(split)?;
        let ctx = PipelineCtx { seed };
        let mut ds = src.dataset(shard_id, num_shards);
        for p in &self.preprocessors {
            ds = p.apply(ds, &ctx);
        }
        Ok(ds)
    }

    /// Rebuild the task stream (same seed/sharding) and reposition it to a
    /// previously captured [`PipelineState`].
    pub fn dataset_resumed(
        &self,
        seed: u64,
        shard_id: usize,
        num_shards: usize,
        state: &PipelineState,
    ) -> anyhow::Result<Dataset> {
        let mut ds = self.dataset(seed, shard_id, num_shards);
        ds.restore(state)?;
        Ok(ds)
    }

    pub fn output_feature(&self, name: &str) -> Option<&OutputFeature> {
        self.output_features.iter().find(|f| f.name == name)
    }

    /// Validate that a produced example carries all required features.
    pub fn validate_example(&self, ex: &super::Example) -> anyhow::Result<()> {
        for f in &self.output_features {
            if f.required && !ex.contains_key(&f.name) {
                anyhow::bail!(
                    "task '{}': example missing required feature '{}'",
                    self.name,
                    f.name
                );
            }
        }
        Ok(())
    }
}

pub struct TaskBuilder {
    name: String,
    source: Option<Arc<dyn DataSource>>,
    split_sources: BTreeMap<String, Arc<dyn DataSource>>,
    preprocessors: Vec<Arc<dyn Preprocessor>>,
    output_features: Vec<OutputFeature>,
    metrics: Vec<Metric>,
}

impl TaskBuilder {
    pub fn source(mut self, s: Arc<dyn DataSource>) -> Self {
        self.source = Some(s);
        self
    }

    /// Attach an additional named split ("validation", "test", ...).
    /// Naming it "train" replaces the main source.
    pub fn split_source(mut self, split: &str, s: Arc<dyn DataSource>) -> Self {
        if split == "train" {
            self.source = Some(s);
        } else {
            self.split_sources.insert(split.to_string(), s);
        }
        self
    }

    pub fn preprocessor(mut self, p: Arc<dyn Preprocessor>) -> Self {
        self.preprocessors.push(p);
        self
    }

    pub fn output_feature(
        mut self,
        name: &str,
        vocab: Arc<dyn Vocabulary>,
        add_eos: bool,
    ) -> Self {
        self.output_features.push(OutputFeature {
            name: name.to_string(),
            vocab,
            add_eos,
            required: true,
        });
        self
    }

    pub fn metric(mut self, m: Metric) -> Self {
        self.metrics.push(m);
        self
    }

    pub fn build(self) -> Arc<Task> {
        Arc::new(Task {
            name: self.name,
            source: self.source.expect("task needs a source"),
            split_sources: self.split_sources,
            preprocessors: self.preprocessors,
            output_features: self.output_features,
            metrics: self.metrics,
        })
    }

    /// Build and register into the unified provider namespace. Errors on
    /// a duplicate name (seqio's ValueError).
    pub fn register(self) -> anyhow::Result<Arc<Task>> {
        let t = self.build();
        TaskRegistry::add(t.clone())?;
        Ok(t)
    }
}

/// Task-typed facade over the unified
/// [`crate::seqio::provider::ProviderRegistry`] (seqio.TaskRegistry):
/// tasks and mixtures share one namespace, so a name always means one
/// thing regardless of provider kind.
pub struct TaskRegistry;

impl TaskRegistry {
    /// Register a task; duplicate names (task OR mixture) are an error.
    pub fn add(task: Arc<Task>) -> anyhow::Result<()> {
        use crate::seqio::provider::{ProviderRegistry, RegistryEntry};
        ProviderRegistry::add(RegistryEntry::Task(task))
    }

    /// Fetch a registered *task* by name (None for mixtures/other kinds).
    pub fn get(name: &str) -> Option<Arc<Task>> {
        crate::seqio::provider::ProviderRegistry::get(name).and_then(|e| e.as_task())
    }

    /// Names of registered tasks (mixtures excluded).
    pub fn names() -> Vec<String> {
        crate::seqio::provider::ProviderRegistry::entries()
            .into_iter()
            .filter(|(_, e)| e.as_task().is_some())
            .map(|(n, _)| n)
            .collect()
    }

    pub fn remove(name: &str) {
        crate::seqio::provider::ProviderRegistry::remove(name);
    }

    /// Clears the whole unified namespace (tasks AND mixtures).
    pub fn reset() {
        crate::seqio::provider::ProviderRegistry::reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seqio::preprocessors::Tokenize;
    use crate::seqio::source::SyntheticTextSource;
    use crate::seqio::vocab::ByteVocabulary;

    #[test]
    fn build_and_run_task() {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let task = Task::builder("test_task_build")
            .source(Arc::new(SyntheticTextSource::new(1, 10)))
            .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
            .output_feature("targets", vocab, true)
            .build();
        let out = task.dataset(0, 0, 1).collect_vec();
        assert_eq!(out.len(), 10);
        assert!(out[0].contains_key("targets"));
        task.validate_example(&out[0]).unwrap();
        let mut missing = out[0].clone();
        missing.remove("targets");
        assert!(task.validate_example(&missing).is_err());
    }

    #[test]
    fn registry_add_get() {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(4));
        let t = Task::builder("test_task_registry")
            .source(Arc::new(SyntheticTextSource::new(2, 3)))
            .output_feature("targets", vocab, true)
            .register()
            .unwrap();
        assert!(TaskRegistry::get("test_task_registry").is_some());
        assert!(TaskRegistry::names().contains(&"test_task_registry".to_string()));
        // duplicate registration is an error, not a silent overwrite
        assert!(TaskRegistry::add(t).is_err());
        TaskRegistry::remove("test_task_registry");
        assert!(TaskRegistry::get("test_task_registry").is_none());
    }

    #[test]
    fn split_sources_are_isolated() {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let task = Task::builder("test_task_splits")
            .source(Arc::new(SyntheticTextSource::new(1, 6)))
            .split_source("validation", Arc::new(SyntheticTextSource::new(2, 3)))
            .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
            .output_feature("targets", vocab, true)
            .build();
        let train = task.dataset_split("train", 0, 0, 1).unwrap().collect_vec();
        let val = task.dataset_split("validation", 0, 0, 1).unwrap().collect_vec();
        assert_eq!(train.len(), 6);
        assert_eq!(val.len(), 3);
        assert!(task.dataset_split("test", 0, 0, 1).is_err());
        assert!(task.source_for("validation").is_ok());
    }

    #[test]
    fn task_stream_resumes_mid_epoch() {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let task = Task::builder("test_task_resume")
            .source(Arc::new(SyntheticTextSource::new(5, 20)))
            .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
            .preprocessor(Arc::new(
                crate::seqio::preprocessors::SpanCorruption::new(vocab.clone()),
            ))
            .output_feature("targets", vocab, true)
            .build();
        let all = task.dataset(11, 0, 1).collect_vec();
        let mut first = task.dataset(11, 0, 1);
        let head: Vec<_> = (&mut first).take(8).collect();
        let snap = first.state();
        let resumed = task.dataset_resumed(11, 0, 1, &snap).unwrap();
        let mut joined = head;
        joined.extend(resumed.collect_vec());
        assert_eq!(joined, all);
    }

    #[test]
    fn task_dataset_seeded() {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let task = Task::builder("test_task_seeded")
            .source(Arc::new(SyntheticTextSource::new(5, 8)))
            .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
            .preprocessor(Arc::new(
                crate::seqio::preprocessors::SpanCorruption::new(vocab.clone()),
            ))
            .output_feature("inputs", vocab.clone(), true)
            .output_feature("targets", vocab, true)
            .build();
        let a = task.dataset(11, 0, 1).collect_vec();
        let b = task.dataset(11, 0, 1).collect_vec();
        let c = task.dataset(12, 0, 1).collect_vec();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
