//! CLU-style metrics library: counters, gauges, and periodic writers.
//!
//! The trainer emits [`MetricPoint`]s (step-stamped scalar values) through a
//! [`MetricsLogger`]; writers render them to the terminal and/or a JSONL
//! file (`train_log.jsonl`) which EXPERIMENTS.md plots are generated from.

use std::io::Write;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// One scalar observation at a training step.
#[derive(Debug, Clone)]
pub struct MetricPoint {
    pub step: u64,
    pub name: String,
    pub value: f64,
}

/// Destination for metric points.
pub trait MetricWriter: Send {
    fn write(&mut self, points: &[MetricPoint]);
    fn flush(&mut self) {}
}

/// Writes `step metric=value ...` lines to stdout.
pub struct TerminalWriter {
    start: Instant,
}

impl TerminalWriter {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }
}

impl Default for TerminalWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricWriter for TerminalWriter {
    fn write(&mut self, points: &[MetricPoint]) {
        if points.is_empty() {
            return;
        }
        let step = points[0].step;
        let body: Vec<String> = points
            .iter()
            .map(|p| format!("{}={:.6}", p.name, p.value))
            .collect();
        println!(
            "[{:>8.1}s] step {:>6}  {}",
            self.start.elapsed().as_secs_f64(),
            step,
            body.join("  ")
        );
    }
}

/// Appends one JSON object per step to a file.
pub struct JsonlWriter {
    path: PathBuf,
    buf: String,
}

impl JsonlWriter {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into(), buf: String::new() }
    }
}

impl MetricWriter for JsonlWriter {
    fn write(&mut self, points: &[MetricPoint]) {
        if points.is_empty() {
            return;
        }
        let mut pairs = vec![("step", Json::num(points[0].step as f64))];
        for p in points {
            pairs.push((p.name.as_str(), Json::num(p.value)));
        }
        self.buf.push_str(&Json::obj(pairs).to_string());
        self.buf.push('\n');
        if self.buf.len() > 16 * 1024 {
            self.flush();
        }
    }

    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        if let Some(dir) = self.path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        if let Ok(mut f) =
            std::fs::OpenOptions::new().create(true).append(true).open(&self.path)
        {
            let _ = f.write_all(self.buf.as_bytes());
        }
        self.buf.clear();
    }
}

impl Drop for JsonlWriter {
    fn drop(&mut self) {
        self.flush();
    }
}

/// Fan-out logger; thread-safe, shared by trainer + hooks.
pub struct MetricsLogger {
    writers: Mutex<Vec<Box<dyn MetricWriter>>>,
}

impl MetricsLogger {
    pub fn new() -> Self {
        Self { writers: Mutex::new(Vec::new()) }
    }

    pub fn with_terminal(self) -> Self {
        self.add(Box::new(TerminalWriter::new()))
    }

    pub fn with_jsonl(self, path: impl Into<PathBuf>) -> Self {
        self.add(Box::new(JsonlWriter::new(path)))
    }

    pub fn add(self, w: Box<dyn MetricWriter>) -> Self {
        self.writers.lock().unwrap().push(w);
        self
    }

    pub fn log(&self, step: u64, values: &[(&str, f64)]) {
        let points: Vec<MetricPoint> = values
            .iter()
            .map(|(n, v)| MetricPoint { step, name: n.to_string(), value: *v })
            .collect();
        for w in self.writers.lock().unwrap().iter_mut() {
            w.write(&points);
        }
    }

    pub fn flush(&self) {
        for w in self.writers.lock().unwrap().iter_mut() {
            w.flush();
        }
    }
}

impl Default for MetricsLogger {
    fn default() -> Self {
        Self::new()
    }
}

/// Named monotonic counters (the CLU `metrics.Counter` analog), shared by
/// the serving engine and its callers. Cheap to clone (Arc-backed); values
/// are flushed to a [`MetricsLogger`] via [`CounterSet::log_to`].
#[derive(Clone, Default)]
pub struct CounterSet {
    inner: std::sync::Arc<Mutex<std::collections::BTreeMap<String, u64>>>,
}

impl CounterSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, name: &str, n: u64) {
        *self.inner.lock().unwrap().entry(name.to_string()).or_insert(0) += n;
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Raise `name` to `n` if larger (high-water-mark counters, e.g.
    /// `train/peak_param_floats`).
    pub fn set_max(&self, name: &str, n: u64) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert(0);
        *e = (*e).max(n);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// All counters, name-sorted.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.inner.lock().unwrap().iter().map(|(k, &v)| (k.clone(), v)).collect()
    }

    /// Emit every counter as a metric point at `step`.
    pub fn log_to(&self, logger: &MetricsLogger, step: u64) {
        let snap = self.snapshot();
        let values: Vec<(&str, f64)> =
            snap.iter().map(|(k, v)| (k.as_str(), *v as f64)).collect();
        logger.log(step, &values);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_set_accumulates_and_logs() {
        let c = CounterSet::new();
        c.inc("infer/steps");
        c.add("infer/tokens", 41);
        c.inc("infer/tokens");
        assert_eq!(c.get("infer/steps"), 1);
        assert_eq!(c.get("infer/tokens"), 42);
        assert_eq!(c.get("missing"), 0);
        let c2 = c.clone();
        c2.inc("infer/steps");
        assert_eq!(c.get("infer/steps"), 2, "clones share storage");
        let path = std::env::temp_dir().join(format!("counters_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let logger = MetricsLogger::new().with_jsonl(&path);
            c.log_to(&logger, 3);
            logger.flush();
        }
        let v = Json::parse(std::fs::read_to_string(&path).unwrap().lines().next().unwrap())
            .unwrap();
        assert_eq!(v.get("infer/tokens").unwrap().as_f64().unwrap(), 42.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn jsonl_writer_appends_parseable_lines() {
        let path = std::env::temp_dir().join(format!("metrics_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let logger = MetricsLogger::new().with_jsonl(&path);
            logger.log(1, &[("loss", 3.5), ("lr", 0.001)]);
            logger.log(2, &[("loss", 3.2)]);
            logger.flush();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v = Json::parse(lines[0]).unwrap();
        assert_eq!(v.get("step").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("loss").unwrap().as_f64().unwrap(), 3.5);
        std::fs::remove_file(&path).ok();
    }
}
