//! Benchmark harness (criterion substitute — criterion is unavailable in
//! the offline registry).
//!
//! Provides warmup, timed iterations, robust statistics (median/p95), and
//! throughput units, printing both human tables and machine-readable JSONL
//! so EXPERIMENTS.md can be regenerated. Used by every `rust/benches/*`
//! target (`cargo bench`, harness = false).

use std::time::{Duration, Instant};

use crate::util::stats::Samples;

/// Configuration for one measurement.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 200,
            target_time: Duration::from_secs(2),
        }
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    /// Optional throughput: (units-per-iteration, unit name).
    pub throughput: Option<(f64, String)>,
}

impl Measurement {
    pub fn throughput_per_sec(&self) -> Option<f64> {
        self.throughput.as_ref().map(|(n, _)| n / self.median_s)
    }
}

/// A group of related measurements printed as one table.
pub struct Bench {
    pub group: String,
    pub config: BenchConfig,
    results: Vec<Measurement>,
    quick: bool,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        // T5X_BENCH_QUICK=1 shrinks iteration counts (used by `cargo test`
        // smoke-running the bench binaries).
        let quick = std::env::var("T5X_BENCH_QUICK").is_ok();
        let config = if quick {
            BenchConfig {
                warmup_iters: 1,
                min_iters: 2,
                max_iters: 5,
                target_time: Duration::from_millis(100),
            }
        } else {
            BenchConfig::default()
        };
        println!("\n== bench group: {group} ==");
        Bench { group: group.to_string(), config, results: Vec::new(), quick }
    }

    pub fn is_quick(&self) -> bool {
        self.quick
    }

    /// Time `f`, which performs ONE iteration of the workload.
    pub fn measure<F: FnMut()>(&mut self, name: &str, f: F) -> &Measurement {
        self.measure_with_throughput(name, None, f)
    }

    /// Time `f` and report `units` of work per iteration as throughput.
    pub fn measure_with_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        throughput: Option<(f64, &str)>,
        mut f: F,
    ) -> &Measurement {
        for _ in 0..self.config.warmup_iters {
            f();
        }
        let mut samples = Samples::default();
        let start = Instant::now();
        let mut iters = 0;
        while iters < self.config.min_iters
            || (start.elapsed() < self.config.target_time
                && iters < self.config.max_iters)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_s: samples.mean(),
            median_s: samples.median(),
            p95_s: samples.percentile(0.95),
            min_s: samples.min(),
            throughput: throughput.map(|(n, u)| (n, u.to_string())),
        };
        self.print_row(&m);
        self.results.push(m);
        self.results.last().unwrap()
    }

    fn print_row(&self, m: &Measurement) {
        let tput = match m.throughput_per_sec() {
            Some(t) => format!(
                "  {:>12}/s",
                human_count(t, &m.throughput.as_ref().unwrap().1)
            ),
            None => String::new(),
        };
        println!(
            "  {:<44} {:>12} med {:>12} p95 ({} iters){}",
            m.name,
            human_time(m.median_s),
            human_time(m.p95_s),
            m.iters,
            tput
        );
    }

    /// Emit JSONL (one line per measurement) for EXPERIMENTS.md tooling.
    pub fn write_jsonl(&self, path: &str) -> anyhow::Result<()> {
        use crate::util::json::Json;
        let mut out = String::new();
        for m in &self.results {
            let mut obj = vec![
                ("group", Json::str(self.group.clone())),
                ("name", Json::str(m.name.clone())),
                ("iters", Json::num(m.iters as f64)),
                ("mean_s", Json::num(m.mean_s)),
                ("median_s", Json::num(m.median_s)),
                ("p95_s", Json::num(m.p95_s)),
                ("min_s", Json::num(m.min_s)),
            ];
            if let Some(t) = m.throughput_per_sec() {
                obj.push(("throughput_per_s", Json::num(t)));
                obj.push((
                    "throughput_unit",
                    Json::str(m.throughput.as_ref().unwrap().1.clone()),
                ));
            }
            out.push_str(&Json::obj(obj).to_string());
            out.push('\n');
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        // Append so successive bench targets accumulate one log.
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(out.as_bytes())?;
        Ok(())
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

pub fn human_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

pub fn human_count(n: f64, unit: &str) -> String {
    if n >= 1e9 {
        format!("{:.2} G{unit}", n / 1e9)
    } else if n >= 1e6 {
        format!("{:.2} M{unit}", n / 1e6)
    } else if n >= 1e3 {
        format!("{:.2} k{unit}", n / 1e3)
    } else {
        format!("{n:.1} {unit}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("T5X_BENCH_QUICK", "1");
        let mut b = Bench::new("selftest");
        let m = b.measure("noop-ish", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m.median_s >= 0.0);
        assert!(m.iters >= 2);
    }

    #[test]
    fn human_units() {
        assert!(human_time(2e-9).contains("ns"));
        assert!(human_time(2e-5).contains("µs"));
        assert!(human_time(2e-2).contains("ms"));
        assert!(human_time(2.0).contains(" s"));
        assert!(human_count(5e6, "tok").contains("Mtok"));
    }
}
