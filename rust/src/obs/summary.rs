//! Bottleneck attribution over exported Chrome traces: per-span
//! self-time aggregation plus an infeed-bound / compute-bound /
//! comm-bound verdict. Backs the `t5x trace-summary` subcommand.
//!
//! Self-time is wall duration minus the duration of directly nested
//! child spans on the same track, so a `train/grad_sync` wrapper that
//! spends 95% of its time inside `coll/all_reduce` children contributes
//! only its host-side overhead — the collective time is attributed to
//! the collective spans themselves.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

/// Aggregated statistics for one span name.
#[derive(Debug, Clone)]
pub struct SpanAgg {
    pub name: String,
    pub count: u64,
    pub total_ms: f64,
    pub self_ms: f64,
}

/// Result of analysing one trace file.
#[derive(Debug)]
pub struct TraceSummary {
    /// Per-name aggregates, sorted by self-time descending.
    pub spans: Vec<SpanAgg>,
    /// Final sample per counter name (`C` events).
    pub counters: BTreeMap<String, f64>,
    pub total_events: usize,
    /// Self-time totals per bottleneck category, in ms.
    pub infeed_ms: f64,
    pub compute_ms: f64,
    pub comm_ms: f64,
    pub other_ms: f64,
    /// `infeed-bound`, `comm-bound`, or `compute-bound`.
    pub verdict: &'static str,
}

/// Map a span name onto a bottleneck category. The taxonomy matches the
/// instrumentation in trainer/infeed/collectives/engine (see the
/// "Observability" section in lib.rs).
fn category(name: &str) -> &'static str {
    if name.contains("infeed") {
        "infeed"
    } else if name.starts_with("coll/")
        || name.starts_with("train/grad_sync")
        || name.starts_with("train/broadcast")
    {
        "comm"
    } else if name.starts_with("seg/")
        || name.starts_with("train/execute")
        || name.starts_with("train/optimizer")
        || name.starts_with("serve/prefill")
        || name.starts_with("serve/decode")
        || name.starts_with("serve/rescore")
        || name.starts_with("eval/")
    {
        "compute"
    } else {
        "other"
    }
}

/// One complete span, normalized from either `X` events or matched
/// `B`/`E` pairs.
struct FlatSpan {
    name: String,
    ts: f64,
    dur: f64,
}

/// Analyse a Chrome trace-event JSON file.
pub fn summarize_file(path: impl AsRef<Path>) -> anyhow::Result<TraceSummary> {
    let v = Json::parse_file(path.as_ref())?;
    summarize(&v)
}

/// Analyse a parsed Chrome trace-event JSON value. Accepts either the
/// `{"traceEvents": [...]}` envelope or a bare event array.
pub fn summarize(trace: &Json) -> anyhow::Result<TraceSummary> {
    let events = match trace.get("traceEvents") {
        Some(e) => e.as_arr(),
        None => trace.as_arr(),
    }
    .ok_or_else(|| anyhow::anyhow!("not a Chrome trace: no traceEvents array"))?;

    // Bucket complete spans per (pid, tid) track; match B/E pairs with a
    // per-track stack for traces from other producers.
    let mut tracks: BTreeMap<(i64, i64), Vec<FlatSpan>> = BTreeMap::new();
    let mut open: BTreeMap<(i64, i64), Vec<FlatSpan>> = BTreeMap::new();
    let mut counters: BTreeMap<String, (f64, f64)> = BTreeMap::new(); // name -> (ts, value)
    let mut total_events = 0usize;
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        let name = ev.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
        let ts = ev.get("ts").and_then(|t| t.as_f64()).unwrap_or(0.0);
        let key = (
            ev.get("pid").and_then(|p| p.as_i64()).unwrap_or(0),
            ev.get("tid").and_then(|t| t.as_i64()).unwrap_or(0),
        );
        match ph {
            "X" => {
                let dur = ev.get("dur").and_then(|d| d.as_f64()).unwrap_or(0.0);
                tracks.entry(key).or_default().push(FlatSpan { name, ts, dur });
                total_events += 1;
            }
            "B" => {
                open.entry(key).or_default().push(FlatSpan { name, ts, dur: 0.0 });
                total_events += 1;
            }
            "E" => {
                if let Some(mut s) = open.get_mut(&key).and_then(|st| st.pop()) {
                    s.dur = (ts - s.ts).max(0.0);
                    tracks.entry(key).or_default().push(s);
                }
            }
            "C" => {
                let value = ev
                    .get("args")
                    .and_then(|a| a.get("value"))
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0);
                let e = counters.entry(name).or_insert((f64::MIN, 0.0));
                if ts >= e.0 {
                    *e = (ts, value);
                }
                total_events += 1;
            }
            _ => {}
        }
    }

    // Self-time: per track, sweep spans in (ts asc, dur desc) order with
    // an enclosing-span stack; each span's duration is subtracted from
    // its direct parent's self-time.
    let mut agg: BTreeMap<String, SpanAgg> = BTreeMap::new();
    for spans in tracks.values_mut() {
        spans.sort_by(|a, b| {
            a.ts.partial_cmp(&b.ts)
                .unwrap()
                .then(b.dur.partial_cmp(&a.dur).unwrap())
        });
        let mut self_us: Vec<f64> = spans.iter().map(|s| s.dur).collect();
        let mut stack: Vec<(f64, usize)> = Vec::new(); // (end_ts, index)
        for (i, s) in spans.iter().enumerate() {
            while let Some(&(end, _)) = stack.last() {
                if end <= s.ts {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&(_, parent)) = stack.last() {
                self_us[parent] -= s.dur;
            }
            stack.push((s.ts + s.dur, i));
        }
        for (s, self_dur) in spans.iter().zip(&self_us) {
            let e = agg.entry(s.name.clone()).or_insert_with(|| SpanAgg {
                name: s.name.clone(),
                count: 0,
                total_ms: 0.0,
                self_ms: 0.0,
            });
            e.count += 1;
            e.total_ms += s.dur / 1e3;
            e.self_ms += self_dur.max(0.0) / 1e3;
        }
    }

    let mut spans: Vec<SpanAgg> = agg.into_values().collect();
    spans.sort_by(|a, b| b.self_ms.partial_cmp(&a.self_ms).unwrap());

    let (mut infeed_ms, mut compute_ms, mut comm_ms, mut other_ms) = (0.0, 0.0, 0.0, 0.0);
    for s in &spans {
        match category(&s.name) {
            "infeed" => infeed_ms += s.self_ms,
            "compute" => compute_ms += s.self_ms,
            "comm" => comm_ms += s.self_ms,
            _ => other_ms += s.self_ms,
        }
    }
    let counters: BTreeMap<String, f64> =
        counters.into_iter().map(|(k, (_, v))| (k, v)).collect();
    let starved = counters.get("train/infeed_starved_steps").copied().unwrap_or(0.0);
    let verdict = if starved > 0.0 || infeed_ms > compute_ms.max(comm_ms) {
        "infeed-bound"
    } else if comm_ms > compute_ms {
        "comm-bound"
    } else {
        "compute-bound"
    };
    Ok(TraceSummary {
        spans,
        counters,
        total_events,
        infeed_ms,
        compute_ms,
        comm_ms,
        other_ms,
        verdict,
    })
}

impl TraceSummary {
    /// Print the top-k spans by self-time plus the category totals and
    /// the bottleneck verdict.
    pub fn print(&self, top_k: usize) {
        println!("{} events, {} distinct span names", self.total_events, self.spans.len());
        println!(
            "{:<36} {:>8} {:>12} {:>12}",
            "span (top by self-time)", "count", "total ms", "self ms"
        );
        for s in self.spans.iter().take(top_k) {
            println!(
                "{:<36} {:>8} {:>12.3} {:>12.3}",
                s.name, s.count, s.total_ms, s.self_ms
            );
        }
        println!(
            "category self-time: infeed={:.3}ms compute={:.3}ms comm={:.3}ms other={:.3}ms",
            self.infeed_ms, self.compute_ms, self.comm_ms, self.other_ms
        );
        if let Some(starved) =
            self.counters.get("train/infeed_starved_steps").filter(|v| **v > 0.0)
        {
            println!("infeed starved steps: {starved}");
        }
        println!("verdict: {}", self.verdict);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn x(name: &str, ts: f64, dur: f64, tid: f64) -> Json {
        Json::obj(vec![
            ("name", Json::str(name)),
            ("ph", Json::str("X")),
            ("ts", Json::num(ts)),
            ("dur", Json::num(dur)),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(tid)),
        ])
    }

    fn counter(name: &str, ts: f64, value: f64) -> Json {
        Json::obj(vec![
            ("name", Json::str(name)),
            ("ph", Json::str("C")),
            ("ts", Json::num(ts)),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("value", Json::num(value))])),
        ])
    }

    fn envelope(evs: Vec<Json>) -> Json {
        Json::obj(vec![("traceEvents", Json::Arr(evs))])
    }

    #[test]
    fn self_time_subtracts_nested_children() {
        // step [0, 1000) containing execute [100, 700) containing
        // coll/all_reduce [200, 600).
        let t = envelope(vec![
            x("train/step", 0.0, 1000.0, 1.0),
            x("train/execute", 100.0, 600.0, 1.0),
            x("coll/all_reduce", 200.0, 400.0, 1.0),
        ]);
        let s = summarize(&t).unwrap();
        let by: BTreeMap<&str, &SpanAgg> =
            s.spans.iter().map(|a| (a.name.as_str(), a)).collect();
        assert!((by["train/step"].self_ms - 0.4).abs() < 1e-9); // 1000-600 us
        assert!((by["train/execute"].self_ms - 0.2).abs() < 1e-9); // 600-400 us
        assert!((by["coll/all_reduce"].self_ms - 0.4).abs() < 1e-9);
        assert!((by["coll/all_reduce"].total_ms - 0.4).abs() < 1e-9);
        assert_eq!(s.verdict, "comm-bound"); // comm 0.4 > compute 0.2
    }

    #[test]
    fn verdict_compute_vs_infeed() {
        let normal = envelope(vec![
            x("train/infeed", 0.0, 10.0, 1.0),
            x("train/execute", 10.0, 900.0, 1.0),
            x("coll/all_reduce", 910.0, 50.0, 1.0),
        ]);
        assert_eq!(summarize(&normal).unwrap().verdict, "compute-bound");

        // Starvation counter forces the infeed verdict even if span time
        // is dominated elsewhere (blocked recv time hides in train/infeed).
        let starved = envelope(vec![
            x("train/execute", 10.0, 900.0, 1.0),
            counter("train/infeed_starved_steps", 950.0, 3.0),
        ]);
        assert_eq!(summarize(&starved).unwrap().verdict, "infeed-bound");
    }

    #[test]
    fn counters_take_last_sample_and_be_pairs_match() {
        let t = envelope(vec![
            counter("serve/queue_depth", 10.0, 5.0),
            counter("serve/queue_depth", 20.0, 2.0),
            Json::obj(vec![
                ("name", Json::str("legacy")),
                ("ph", Json::str("B")),
                ("ts", Json::num(0.0)),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(7.0)),
            ]),
            Json::obj(vec![
                ("name", Json::str("legacy")),
                ("ph", Json::str("E")),
                ("ts", Json::num(500.0)),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(7.0)),
            ]),
        ]);
        let s = summarize(&t).unwrap();
        assert_eq!(s.counters.get("serve/queue_depth"), Some(&2.0));
        let legacy = s.spans.iter().find(|a| a.name == "legacy").unwrap();
        assert!((legacy.total_ms - 0.5).abs() < 1e-9);
    }

    #[test]
    fn rejects_non_trace_json() {
        assert!(summarize(&Json::num(3.0)).is_err());
    }
}
