//! Mixtures (paper §3.1): combine multiple Tasks with user-provided mixing
//! rates for multi-task training. Sampling is deterministic given a seed;
//! the empirical rate converges to the requested rate (tested, E10).
//!
//! Members bind either eagerly ([`Mixture::new`] / [`Mixture::from_names`])
//! or lazily by *name* ([`Mixture::lazy`]): a lazy mixture records member
//! names at construction and resolves them from the unified registry at
//! first use — so a gin file can define a mixture before the tasks it
//! names are registered, exactly like seqio's `MixtureRegistry.add`.

use std::sync::Arc;
use std::sync::OnceLock;

use super::dataset::{
    check_tag, field, field_arr, rng_from_json, rng_to_json, Dataset, PipelineOp,
    PipelineState,
};
use super::task::Task;
use super::vocab::Vocabulary;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// A weighted collection of tasks.
pub struct Mixture {
    pub name: String,
    /// Lazily-bound member names + rates; `None` once constructed eagerly.
    pending: Option<Vec<(String, f64)>>,
    /// Resolved member tasks (set at construction for eager mixtures, at
    /// first [`Mixture::members`] call for lazy ones).
    resolved: OnceLock<Vec<(Arc<Task>, f64)>>,
}

impl Mixture {
    /// Validate a member set: non-empty, positive finite rates, and a
    /// shared output-feature schema. Schema fingerprint: feature name +
    /// vocab size + required flag — mixing tasks that tokenize into
    /// different id spaces corrupts training data silently, so it must
    /// fail before any example is drawn.
    fn validate(name: &str, tasks: &[(Arc<Task>, f64)]) -> anyhow::Result<()> {
        anyhow::ensure!(!tasks.is_empty(), "mixture '{name}' needs at least one task");
        fn feature_names(t: &Task) -> Vec<String> {
            let mut v: Vec<String> = t
                .output_features
                .iter()
                .map(|f| format!("{}/v{}/req={}", f.name, f.vocab.vocab_size(), f.required))
                .collect();
            v.sort_unstable();
            v
        }
        let schema = feature_names(&tasks[0].0);
        for (task, rate) in tasks {
            anyhow::ensure!(
                rate.is_finite() && *rate > 0.0,
                "mixture '{name}': task '{}' has non-positive rate {rate}",
                task.name
            );
            // seqio requires member tasks to share an output-feature
            // schema; enforce it here so feature converters never meet a
            // member example missing a feature mid-stream.
            let theirs = feature_names(task);
            anyhow::ensure!(
                theirs == schema,
                "mixture '{name}': task '{}' declares features [{}], but '{}' \
                 declares [{}] — member tasks must share an output-feature schema",
                task.name,
                theirs.join(", "),
                tasks[0].0.name,
                schema.join(", ")
            );
        }
        Ok(())
    }

    /// Construct a mixture. Errors (instead of panicking) on an empty
    /// task list or non-positive rates — construction problems surface as
    /// `anyhow::Result` like every other registry operation.
    pub fn new(name: &str, tasks: Vec<(Arc<Task>, f64)>) -> anyhow::Result<Mixture> {
        Self::validate(name, &tasks)?;
        let resolved = OnceLock::new();
        let _ = resolved.set(tasks);
        Ok(Self { name: name.to_string(), pending: None, resolved })
    }

    /// Construct a mixture from *registered task names*, resolved eagerly
    /// (every member must already be in the registry).
    pub fn from_names(name: &str, members: &[(&str, f64)]) -> anyhow::Result<Mixture> {
        let mut tasks = Vec::with_capacity(members.len());
        for (task_name, rate) in members {
            let t = super::task::TaskRegistry::get(task_name).ok_or_else(|| {
                anyhow::anyhow!("mixture '{name}': no task named '{task_name}' in the registry")
            })?;
            tasks.push((t, *rate));
        }
        Mixture::new(name, tasks)
    }

    /// Construct a mixture whose member *names* bind lazily: resolution
    /// against the unified registry happens at the first
    /// [`Mixture::members`] / `dataset()` call, so the mixture can be
    /// defined (and registered) before its member tasks are — the gin
    /// path, where binding order is the config file's business, not the
    /// registration code's (seqio `MixtureRegistry.add` semantics).
    pub fn lazy(name: &str, members: &[(&str, f64)]) -> Mixture {
        Self {
            name: name.to_string(),
            pending: Some(members.iter().map(|(n, r)| (n.to_string(), *r)).collect()),
            resolved: OnceLock::new(),
        }
    }

    /// The member tasks + rates, resolving lazily-bound names on first
    /// call (and validating the member set exactly like eager
    /// construction). Errors if a named member is still unregistered.
    pub fn members(&self) -> anyhow::Result<&[(Arc<Task>, f64)]> {
        if let Some(t) = self.resolved.get() {
            return Ok(t);
        }
        let names =
            self.pending.as_ref().expect("eagerly-constructed mixtures are always resolved");
        let mut tasks = Vec::with_capacity(names.len());
        for (task_name, rate) in names {
            let t = super::task::TaskRegistry::get(task_name).ok_or_else(|| {
                anyhow::anyhow!(
                    "mixture '{}': lazy member '{task_name}' is not a registered task \
                     (lazy members resolve at first use — register the task first)",
                    self.name
                )
            })?;
            tasks.push((t, *rate));
        }
        Self::validate(&self.name, &tasks)?;
        // a concurrent resolver may have won the race; both computed the
        // same member set from the same registry
        Ok(self.resolved.get_or_init(|| tasks))
    }

    /// Register into the unified provider namespace (shared with tasks);
    /// duplicate names error like seqio's ValueError.
    pub fn register(self) -> anyhow::Result<Arc<Mixture>> {
        let m = Arc::new(self);
        super::provider::ProviderRegistry::add(super::provider::RegistryEntry::Mixture(
            m.clone(),
        ))?;
        Ok(m)
    }

    pub fn rates(&self) -> Vec<f64> {
        let tasks = self.members().expect("mixture members must resolve before rates()");
        let total: f64 = tasks.iter().map(|(_, r)| r).sum();
        tasks.iter().map(|(_, r)| r / total).collect()
    }

    /// Sample-based interleave of the member task "train" streams; see
    /// [`Mixture::dataset_split`].
    pub fn dataset(&self, seed: u64, shard_id: usize, num_shards: usize) -> Dataset {
        self.dataset_split("train", seed, shard_id, num_shards)
            .expect("the train split always exists (lazy members must be registered)")
    }

    /// Sample-based interleave of the member task datasets for one split.
    /// Each example is stamped with a `_task` feature naming its origin
    /// (for rate tests and eval routing). Tasks that run out are dropped
    /// from the draw (seqio's behaviour with non-repeating datasets).
    ///
    /// The stream is a stateful [`PipelineOp`]: its state captures the
    /// sampling RNG, the set of still-active tasks, and every member
    /// stream's own state, so a mixture resumes mid-draw exactly.
    pub fn dataset_split(
        &self,
        split: &str,
        seed: u64,
        shard_id: usize,
        num_shards: usize,
    ) -> anyhow::Result<Dataset> {
        let mut streams: Vec<(String, Box<dyn PipelineOp>)> = Vec::new();
        let mut weights = Vec::new();
        for (task, rate) in self.members()? {
            let ds = task.dataset_split(split, seed, shard_id, num_shards)?;
            streams.push((task.name.clone(), ds.into_op()));
            weights.push(*rate);
        }
        Ok(Dataset::from_op(Sampler {
            streams,
            weights,
            rng: Pcg64::new(seed ^ 0x4D49_5854), // "MIXT"
        }))
    }

    /// Rebuild the mixture stream and reposition it to a captured state.
    pub fn dataset_resumed(
        &self,
        seed: u64,
        shard_id: usize,
        num_shards: usize,
        state: &PipelineState,
    ) -> anyhow::Result<Dataset> {
        let mut ds = self.dataset(seed, shard_id, num_shards);
        ds.restore(state)?;
        Ok(ds)
    }
}

struct Sampler {
    streams: Vec<(String, Box<dyn PipelineOp>)>,
    weights: Vec<f64>,
    rng: Pcg64,
}

impl PipelineOp for Sampler {
    fn next(&mut self) -> Option<super::Example> {
        while !self.streams.is_empty() {
            let i = self.rng.sample_weighted(&self.weights);
            match self.streams[i].1.next() {
                Some(mut ex) => {
                    ex.insert(
                        "_task".into(),
                        super::Feature::Text(self.streams[i].0.clone()),
                    );
                    return Some(ex);
                }
                None => {
                    drop(self.streams.remove(i));
                    self.weights.remove(i);
                }
            }
        }
        None
    }

    fn state(&mut self) -> Json {
        let active: Vec<Json> =
            self.streams.iter().map(|(n, _)| Json::str(n.clone())).collect();
        let states: Vec<Json> =
            self.streams.iter_mut().map(|(_, op)| op.state()).collect();
        Json::obj(vec![
            ("op", Json::str("mixture")),
            ("rng", rng_to_json(&self.rng)),
            ("active", Json::Arr(active)),
            ("streams", Json::Arr(states)),
        ])
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        check_tag(s, "mixture")?;
        let active = field_arr(s, "active")?;
        let states = field_arr(s, "streams")?;
        anyhow::ensure!(
            active.len() == states.len(),
            "mixture state arrays disagree: {} names vs {} states",
            active.len(),
            states.len()
        );
        // The saved active list is an order-preserving subset of the full
        // task list; exhausted tasks were dropped before the snapshot.
        let mut old: std::collections::VecDeque<((String, Box<dyn PipelineOp>), f64)> =
            self.streams.drain(..).zip(self.weights.drain(..)).collect();
        let mut new_streams = Vec::with_capacity(active.len());
        let mut new_weights = Vec::with_capacity(active.len());
        for (name_j, st) in active.iter().zip(states) {
            let name = name_j
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("mixture task name is not a string"))?;
            loop {
                let Some(((n, mut op), w)) = old.pop_front() else {
                    anyhow::bail!("mixture state names task '{name}' not in this mixture");
                };
                if n == name {
                    op.restore(st)?;
                    new_streams.push((n, op));
                    new_weights.push(w);
                    break;
                }
                // task exhausted before the snapshot: drop it here too
            }
        }
        self.streams = new_streams;
        self.weights = new_weights;
        self.rng = rng_from_json(field(s, "rng")?)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seqio::source::FunctionSource;
    use crate::seqio::vocab::{ByteVocabulary, Vocabulary};
    use crate::seqio::{ints_example, Feature};

    fn const_task(name: &'static str, value: i32, count: usize) -> Arc<Task> {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(4));
        Task::builder(name)
            .source(Arc::new(FunctionSource::new(move |shard, num| {
                Dataset::new(
                    (0..count)
                        .filter(move |i| i % num == shard)
                        .map(move |_| ints_example(&[("targets", vec![value])])),
                )
            })))
            .output_feature("targets", vocab, false)
            .build()
    }

    #[test]
    fn rates_normalized() {
        let m = Mixture::new(
            "m1",
            vec![(const_task("a_rates", 1, 10), 1.0), (const_task("b_rates", 2, 10), 3.0)],
        )
        .unwrap();
        let r = m.rates();
        assert!((r[0] - 0.25).abs() < 1e-12);
        assert!((r[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn empirical_rate_converges() {
        let m = Mixture::new(
            "m2",
            vec![
                (const_task("a_conv", 1, 100_000), 0.7),
                (const_task("b_conv", 2, 100_000), 0.3),
            ],
        )
        .unwrap();
        // NB: Dataset's inherent `map` (Example -> Example) shadows
        // Iterator::map, so collect first in tests.
        let sample: Vec<i32> = m
            .dataset(5, 0, 1)
            .take(20_000)
            .collect_vec()
            .iter()
            .map(|e| e["targets"].as_ints().unwrap()[0])
            .collect();
        let frac_a =
            sample.iter().filter(|&&v| v == 1).count() as f64 / sample.len() as f64;
        assert!((frac_a - 0.7).abs() < 0.02, "frac_a={frac_a}");
    }

    #[test]
    fn exhausted_task_dropped() {
        let m = Mixture::new(
            "m3",
            vec![(const_task("tiny_drop", 1, 3), 0.9), (const_task("big_drop", 2, 50), 0.1)],
        )
        .unwrap();
        let all: Vec<i32> = m
            .dataset(1, 0, 1)
            .collect_vec()
            .iter()
            .map(|e| e["targets"].as_ints().unwrap()[0])
            .collect();
        // all examples eventually emitted
        assert_eq!(all.iter().filter(|&&v| v == 1).count(), 3);
        assert_eq!(all.iter().filter(|&&v| v == 2).count(), 50);
    }

    #[test]
    fn deterministic_given_seed() {
        let make = || {
            Mixture::new(
                "m4",
                vec![(const_task("a_det", 1, 100), 0.5), (const_task("b_det", 2, 100), 0.5)],
            )
            .unwrap()
        };
        let a: Vec<_> = make().dataset(9, 0, 1).take(50).collect();
        let b: Vec<_> = make().dataset(9, 0, 1).take(50).collect();
        assert_eq!(a, b);
        let c: Vec<_> = make().dataset(10, 0, 1).take(50).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn mixture_state_resumes_exact_stream() {
        let make = || {
            Mixture::new(
                "m6",
                vec![
                    (const_task("a_res", 1, 30), 0.6),
                    (const_task("b_res", 2, 120), 0.4),
                ],
            )
            .unwrap()
        };
        let all = make().dataset(3, 0, 1).collect_vec();
        // cut=80 lands after the small task exhausts, exercising the
        // dropped-task bookkeeping in the saved state.
        for cut in [0usize, 7, 80] {
            let mut first = make().dataset(3, 0, 1);
            let head: Vec<_> = (&mut first).take(cut).collect();
            let snap = first.state();
            let resumed = make().dataset_resumed(3, 0, 1, &snap).unwrap();
            let mut joined = head;
            joined.extend(resumed.collect_vec());
            assert_eq!(joined, all, "cut={cut}");
        }
    }

    #[test]
    fn construction_errors_are_results() {
        assert!(Mixture::new("m_empty", vec![]).is_err());
        assert!(Mixture::new("m_zero_rate", vec![(const_task("zr", 1, 3), 0.0)]).is_err());
        assert!(Mixture::new("m_nan_rate", vec![(const_task("nr", 1, 3), f64::NAN)]).is_err());
        assert!(Mixture::from_names("m_unknown", &[("definitely_not_registered", 1.0)]).is_err());
        // member tasks must share an output-feature schema
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(4));
        let other = Task::builder("schema_other")
            .source(Arc::new(FunctionSource::new(|_, _| Dataset::from_vec(vec![]))))
            .output_feature("inputs", vocab, true)
            .build();
        let err = Mixture::new(
            "m_schema",
            vec![(const_task("schema_a", 1, 3), 1.0), (other, 1.0)],
        );
        assert!(err.is_err());
    }

    #[test]
    fn lazy_mixture_resolves_members_at_first_use() {
        use crate::seqio::task::TaskRegistry;
        // the mixture is defined before its member tasks exist anywhere
        let m = Mixture::lazy("m_lazy", &[("lazy_a_mem", 1.0), ("lazy_b_mem", 3.0)]);
        let err = m.members().unwrap_err().to_string();
        assert!(err.contains("lazy_a_mem"), "{err}");
        // a failed resolution must not poison the mixture: register the
        // members, then the same instance resolves and serves data
        TaskRegistry::add(const_task("lazy_a_mem", 1, 40)).unwrap();
        TaskRegistry::add(const_task("lazy_b_mem", 2, 40)).unwrap();
        assert_eq!(m.members().unwrap().len(), 2);
        let r = m.rates();
        assert!((r[0] - 0.25).abs() < 1e-12);
        let vals: Vec<i32> = m
            .dataset(4, 0, 1)
            .take(20)
            .collect_vec()
            .iter()
            .map(|e| e["targets"].as_ints().unwrap()[0])
            .collect();
        assert_eq!(vals.len(), 20);
        assert!(vals.contains(&1) && vals.contains(&2));
        TaskRegistry::remove("lazy_a_mem");
        TaskRegistry::remove("lazy_b_mem");
    }

    #[test]
    fn lazy_mixture_validates_schema_at_resolution() {
        use crate::seqio::task::TaskRegistry;
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(4));
        let other = Task::builder("lazy_schema_other")
            .source(Arc::new(FunctionSource::new(|_, _| Dataset::from_vec(vec![]))))
            .output_feature("inputs", vocab, true)
            .build();
        TaskRegistry::add(const_task("lazy_schema_a", 1, 3)).unwrap();
        TaskRegistry::add(other).unwrap();
        let m = Mixture::lazy(
            "m_lazy_schema",
            &[("lazy_schema_a", 1.0), ("lazy_schema_other", 1.0)],
        );
        let err = m.members().unwrap_err().to_string();
        assert!(err.contains("output-feature schema"), "{err}");
        TaskRegistry::remove("lazy_schema_a");
        TaskRegistry::remove("lazy_schema_other");
    }

    #[test]
    fn task_stamp_present() {
        let m = Mixture::new("m5", vec![(const_task("only_stamp", 7, 5), 1.0)]).unwrap();
        for ex in m.dataset(0, 0, 1) {
            match &ex["_task"] {
                Feature::Text(t) => assert_eq!(t, "only_stamp"),
                _ => panic!("missing _task stamp"),
            }
        }
    }
}
