//! Property-based tests (via the in-crate `testing` harness) over the
//! library's core invariants: collectives == mathematical reductions,
//! deterministic-pipeline resume/shard laws, packing conservation,
//! partitioner roundtrips, record/JSON codecs.

use std::sync::Arc;

use t5x::collectives::{chunk_bounds, run_ranks, CollectiveGroup};
use t5x::partitioning::{Mesh, ParamStrategy, Partitioner};
use t5x::runtime::artifacts::ParamSpec;
use t5x::runtime::HostTensor;
use t5x::seqio::cache::{cache_task, CacheConfig};
use t5x::seqio::deterministic::DeterministicPipeline;
use t5x::seqio::feature_converters::pack_lm;
use t5x::seqio::preprocessors::Tokenize;
use t5x::seqio::source::SyntheticTextSource;
use t5x::seqio::task::Task;
use t5x::seqio::vocab::{ByteVocabulary, Vocabulary, PAD_ID};
use t5x::seqio::{deserialize_example, ints_example, serialize_example, Feature};
use t5x::testing::{assert_allclose, Runner};
use t5x::util::json::Json;

#[test]
fn prop_all_reduce_equals_sum() {
    Runner::new("all_reduce_sum", 30).run(|g| {
        let n = g.usize_in(1, 8);
        let len = g.usize_in(1, 300);
        let inputs: Vec<Vec<f32>> =
            (0..n).map(|_| g.vec_f32(len, -10.0, 10.0)).collect();
        let expect: Vec<f32> = (0..len)
            .map(|i| inputs.iter().map(|v| v[i]).sum())
            .collect();
        let group = CollectiveGroup::new(n);
        let outs = run_ranks(n, |r| group.all_reduce(r, inputs[r].clone()));
        for out in outs {
            assert_allclose(&out, &expect, 1e-3, 1e-4);
        }
    });
}

#[test]
fn prop_reduce_scatter_all_gather_compose() {
    Runner::new("rs_ag_compose", 20).run(|g| {
        let n = g.usize_in(1, 6);
        let len = g.usize_in(n, 200);
        let inputs: Vec<Vec<f32>> =
            (0..n).map(|_| g.vec_f32(len, -5.0, 5.0)).collect();
        let expect: Vec<f32> = (0..len)
            .map(|i| inputs.iter().map(|v| v[i]).sum())
            .collect();
        let group = CollectiveGroup::new(n);
        let outs = run_ranks(n, |r| {
            let chunk = group.reduce_scatter(r, inputs[r].clone());
            group.all_gather(r, chunk, len)
        });
        for out in outs {
            assert_allclose(&out, &expect, 1e-3, 1e-4);
        }
    });
}

#[test]
fn prop_chunk_bounds_partition() {
    Runner::new("chunk_bounds", 200).run(|g| {
        let len = g.usize_in(0, 10_000);
        let n = g.usize_in(1, 64);
        let b = chunk_bounds(len, n);
        assert_eq!(b.len(), n);
        assert_eq!(b[0].0, 0);
        assert_eq!(b[n - 1].1, len);
        for w in b.windows(2) {
            assert_eq!(w[0].1, w[1].0); // contiguous
        }
        // balanced within 1
        let sizes: Vec<usize> = b.iter().map(|(lo, hi)| hi - lo).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    });
}

#[test]
fn prop_partitioner_shard_unshard_roundtrip() {
    Runner::new("partitioner_roundtrip", 40).run(|g| {
        let data = 1 << g.usize_in(0, 2);
        let model = 1 << g.usize_in(0, 2);
        let rows = *g.pick(&[4, 8, 12, 16]);
        let cols = *g.pick(&[4, 8, 16]);
        let strategy = if g.bool() { ParamStrategy::OneD } else { ParamStrategy::TwoD };
        let p = Partitioner::new(Mesh::new(data, model), strategy);
        let spec = ParamSpec {
            name: "w".into(),
            shape: vec![rows, cols],
            logical_axes: vec!["embed".into(), "mlp".into()],
            init: "const:0".into(),
        };
        let full = HostTensor::f32(
            vec![rows, cols],
            g.vec_f32(rows * cols, -1.0, 1.0),
        );
        let pspec = p.spec_for(&spec);
        let shards: Vec<HostTensor> = (0..p.mesh.num_hosts())
            .map(|h| p.shard(&full, &pspec, h))
            .collect();
        let back = p.unshard(&shards, &pspec);
        assert_eq!(back, full);
    });
}

#[test]
fn prop_packing_conserves_tokens() {
    Runner::new("packing_conserves", 60).run(|g| {
        let row_len = g.usize_in(4, 32);
        let n = g.usize_in(1, 20);
        let examples: Vec<_> = (0..n)
            .map(|i| {
                let len = g.usize_in(1, row_len);
                ints_example(&[(
                    "targets",
                    (0..len).map(|j| (i * 100 + j + 1) as i32).collect(),
                )])
            })
            .collect();
        let rows = pack_lm(&examples, row_len);
        // token conservation
        let mut packed: Vec<i32> = rows
            .iter()
            .flat_map(|r| {
                r["decoder_target_tokens"]
                    .as_ints()
                    .unwrap()
                    .iter()
                    .copied()
                    .filter(|&t| t != PAD_ID)
            })
            .collect();
        let mut original: Vec<i32> = examples
            .iter()
            .flat_map(|e| e["targets"].as_ints().unwrap().iter().copied())
            .collect();
        packed.sort();
        original.sort();
        assert_eq!(packed, original);
        // segment monotonicity within each row
        for r in &rows {
            let seg = r["decoder_segment_ids"].as_ints().unwrap();
            let mut last = 0;
            for &s in seg {
                if s != 0 {
                    assert!(s == last || s == last + 1);
                    last = s.max(last);
                }
            }
        }
    });
}

#[test]
fn prop_example_serialization_roundtrip() {
    Runner::new("example_codec", 100).run(|g| {
        let mut ex = t5x::seqio::Example::new();
        let n_fields = g.usize_in(0, 6);
        for i in 0..n_fields {
            let name = format!("f{i}_{}", g.string(6).replace(' ', "_"));
            let feat = match g.usize_in(0, 2) {
                0 => Feature::Text(g.string(40)),
                1 => Feature::Ints(
                    (0..g.usize_in(0, 50)).map(|_| g.i64_in(-1000, 1000) as i32).collect(),
                ),
                _ => {
                    let len = g.usize_in(0, 50);
                    Feature::Floats(g.vec_f32(len, -100.0, 100.0))
                }
            };
            ex.insert(name, feat);
        }
        let buf = serialize_example(&ex);
        let back = deserialize_example(&buf).unwrap();
        assert_eq!(ex, back);
    });
}

#[test]
fn prop_json_roundtrip() {
    Runner::new("json_roundtrip", 100).run(|g| {
        fn gen_value(g: &mut t5x::testing::Gen, depth: usize) -> Json {
            match if depth > 2 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num(g.i64_in(-1_000_000, 1_000_000) as f64),
                3 => Json::Str(g.string(24)),
                4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| gen_value(g, depth + 1)).collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..g.usize_in(0, 4) {
                        m.insert(format!("k{i}"), gen_value(g, depth + 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = gen_value(g, 0);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    });
}

#[test]
fn prop_deterministic_pipeline_resume_and_shard_laws() {
    // Heavier property: random (docs, shards, hosts, start) — resume ==
    // continuous suffix, shards partition the index space.
    let dir_base = std::env::temp_dir().join(format!("prop_det_{}", std::process::id()));
    let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
    let task = Task::builder("prop_det_task")
        .source(Arc::new(SyntheticTextSource::new(3, 60)))
        .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
        .output_feature("targets", vocab, true)
        .build();

    Runner::new("det_pipeline_laws", 8).run(|g| {
        let hosts = *g.pick(&[1usize, 2, 4]);
        let shards = hosts * g.usize_in(1, 3);
        let dir = dir_base.join(format!("{}_{}", hosts, shards));
        cache_task(
            &task,
            &dir,
            &CacheConfig { num_shards: shards, seed: g.u64(), workers: 2 },
        )
        .unwrap();
        let p = DeterministicPipeline::open(&dir).unwrap();
        let mut seen = Vec::new();
        for h in 0..hosts {
            let full: Vec<i32> = p
                .host_stream(h, hosts, 0, false)
                .collect_vec()
                .iter()
                .map(|e| e["_index"].as_ints().unwrap()[0])
                .collect();
            let k = g.usize_in(0, full.len());
            let resumed: Vec<i32> = p
                .host_stream(h, hosts, k, false)
                .collect_vec()
                .iter()
                .map(|e| e["_index"].as_ints().unwrap()[0])
                .collect();
            assert_eq!(resumed.as_slice(), &full[k..]);
            seen.extend(full);
        }
        seen.sort();
        assert_eq!(seen, (0..p.meta.num_examples as i32).collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).ok();
    });
    std::fs::remove_dir_all(&dir_base).ok();
}

#[test]
fn prop_span_corruption_conserves_tokens() {
    let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
    Runner::new("span_corruption_tokens", 80).run(|g| {
        let len = g.usize_in(2, 200);
        let tokens: Vec<i32> =
            (0..len).map(|_| g.i64_in(3, 250) as i32).collect();
        let sc = t5x::seqio::preprocessors::SpanCorruption::new(vocab.clone());
        let mut rng = t5x::util::rng::Pcg64::new(g.u64());
        let (inputs, targets) = sc.corrupt(&tokens, &mut rng);
        let mut recovered: Vec<i32> = inputs
            .iter()
            .chain(targets.iter())
            .copied()
            .filter(|&t| !vocab.is_sentinel(t))
            .collect();
        recovered.sort();
        let mut orig = tokens.clone();
        orig.sort();
        assert_eq!(recovered, orig);
        assert!(vocab.is_sentinel(*targets.last().unwrap()));
    });
}
