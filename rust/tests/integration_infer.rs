//! Integration: the inference serving subsystem — continuous-batching
//! engine vs single-request decoding (byte-identity), KV-cached vs
//! full-rescore decode modes (byte-identity under mid-flight refills,
//! seeded sampling, and the beam fallback), stale-artifact fallback,
//! mid-flight slot refill, the JSONL serve loop, and the predict-based
//! Evaluator path.

use t5x::infer::{DecodeMethod, DecodeMode, InferEngine, InferRequest, InferResult};
use t5x::model::Params;
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::seqio::evaluation::Metric;
use t5x::seqio::vocab::{ByteVocabulary, Vocabulary};
use t5x::trainer::eval::{predict_and_evaluate, EvalRunner};

const MODEL: &str = "t5-nano-dec";

fn setup() -> (Artifacts, DeviceHandle, Params) {
    let arts = Artifacts::load_default().unwrap();
    let dev = DeviceHandle::spawn().unwrap();
    let params = t5x::model::init_params(arts.model(MODEL).unwrap(), 3);
    (arts, dev, params)
}

/// Reference: decode `prompt` alone through the historical greedy path
/// (batch filled with the same prompt; row 0).
fn single_request_greedy(
    runner: &EvalRunner,
    params: &Params,
    prompt: &[i32],
    decode_len: usize,
    eos: i32,
) -> Vec<i32> {
    let b = runner.manifest.batch();
    let prompts = vec![prompt.to_vec(); b];
    runner.greedy_decode(params, None, &prompts, decode_len, eos).unwrap()[0].clone()
}

#[test]
fn engine_greedy_is_byte_identical_to_single_request_path() {
    let (arts, dev, params) = setup();
    let runner = EvalRunner::new(&arts, &dev, MODEL).unwrap();
    let b = runner.manifest.batch();
    // eos -1 never fires, and budgets are staggered per request: slots
    // free at different steps, so queued requests are deterministically
    // admitted while other rows are mid-decode.
    let eos = -1;
    // N > B forces queueing + refills: the engine must still reproduce
    // every request's solo decode exactly.
    let n = b + 3;
    let prompts: Vec<Vec<i32>> = (0..n).map(|i| vec![5 + i as i32, 9, 11]).collect();
    let budget = |i: usize| 3 + (i % 4);
    let expected: Vec<Vec<i32>> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| single_request_greedy(&runner, &params, p, budget(i), eos))
        .collect();

    let mut engine = InferEngine::new(&arts, &dev, MODEL, &params, eos).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        engine
            .submit(InferRequest {
                id: i as u64,
                prompt: p.clone(),
                max_tokens: budget(i),
                method: DecodeMethod::Greedy,
            })
            .unwrap();
    }
    let mut results = engine.run_until_idle().unwrap();
    assert_eq!(results.len(), n, "every queued request must complete");
    results.sort_by_key(|r| r.id);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(
            r.tokens, expected[i],
            "request {i}: batched engine output diverged from solo greedy"
        );
    }
    assert!(
        engine.counters().get("infer/refills") > 0,
        "with N > B queued requests, freed slots must be refilled"
    );
    dev.shutdown();
}

#[test]
fn freed_slots_refill_before_slowest_row_finishes() {
    let (arts, dev, params) = setup();
    let mut engine = InferEngine::new(&arts, &dev, MODEL, &params, -1).unwrap();
    let b = engine.manifest.batch();
    // b long-running requests fill every slot; request 0 exits after 2
    // tokens; one extra queued request must take over its slot while the
    // long rows are still decoding.
    let long = 6usize;
    for i in 0..b {
        engine
            .submit(InferRequest {
                id: i as u64,
                prompt: vec![7 + i as i32, 3],
                max_tokens: if i == 0 { 2 } else { long },
                method: DecodeMethod::Greedy,
            })
            .unwrap();
    }
    let extra_id = b as u64;
    engine
        .submit(InferRequest {
            id: extra_id,
            prompt: vec![2, 4],
            max_tokens: long,
            method: DecodeMethod::Greedy,
        })
        .unwrap();
    let results = engine.run_until_idle().unwrap();
    assert_eq!(results.len(), b + 1);
    let extra = results.iter().find(|r| r.id == extra_id).unwrap();
    let slowest_finish = results
        .iter()
        .filter(|r| r.id != extra_id)
        .map(|r| r.finished_step)
        .max()
        .unwrap();
    assert_eq!(extra.started_step, 2, "slot must be handed over the step it frees");
    assert!(
        extra.started_step < slowest_finish,
        "refill at step {} must precede the slowest row's finish at step {}",
        extra.started_step,
        slowest_finish
    );
    assert!(extra.queue_seconds >= 0.0 && extra.latency_seconds >= extra.queue_seconds);
    assert_eq!(engine.counters().get("infer/refills"), 1);
    // with one early-exit + one refill, utilization stays below 100% but
    // well above the single-request floor
    let util = engine.slot_utilization();
    assert!(util > 0.5 && util <= 1.0, "utilization {util}");
    dev.shutdown();
}

#[test]
fn engine_sampling_is_seed_deterministic_under_packing() {
    let (arts, dev, params) = setup();
    let eos = 1;
    let sample = DecodeMethod::Sample { temperature: 0.8, top_k: 16, top_p: 0.95, seed: 42 };
    let prompt = vec![5, 9, 11];
    // run 1: the sampled request decodes alone
    let mut solo = InferEngine::new(&arts, &dev, MODEL, &params, eos).unwrap();
    solo.submit(InferRequest {
        id: 0,
        prompt: prompt.clone(),
        max_tokens: 6,
        method: sample.clone(),
    })
    .unwrap();
    let solo_tokens = solo.run_until_idle().unwrap()[0].tokens.clone();

    // run 2: same request packed among unrelated greedy neighbors
    let mut packed = InferEngine::new(&arts, &dev, MODEL, &params, eos).unwrap();
    let b = packed.manifest.batch();
    for i in 0..b + 1 {
        packed
            .submit(InferRequest {
                id: i as u64,
                prompt: vec![20 + i as i32],
                max_tokens: 5,
                method: DecodeMethod::Greedy,
            })
            .unwrap();
    }
    packed
        .submit(InferRequest {
            id: 99,
            prompt: prompt.clone(),
            max_tokens: 6,
            method: sample.clone(),
        })
        .unwrap();
    let results = packed.run_until_idle().unwrap();
    let packed_tokens = &results.iter().find(|r| r.id == 99).unwrap().tokens;
    assert_eq!(
        &solo_tokens, packed_tokens,
        "same (prompt, seed) must sample identically regardless of packing"
    );

    // different seeds diverge: over a handful of seeds at least one
    // continuation must differ (per-step token distributions are near
    // uniform under random params, so this is astronomically safe)
    let mut other = InferEngine::new(&arts, &dev, MODEL, &params, eos).unwrap();
    let mut any_diverged = false;
    for seed in 100u64..110 {
        other
            .submit(InferRequest {
                id: seed,
                prompt: prompt.clone(),
                max_tokens: 6,
                method: DecodeMethod::Sample {
                    temperature: 0.8,
                    top_k: 16,
                    top_p: 0.95,
                    seed,
                },
            })
            .unwrap();
        let tokens = other.run_until_idle().unwrap()[0].tokens.clone();
        if tokens != solo_tokens {
            any_diverged = true;
            break;
        }
    }
    assert!(any_diverged, "different seeds should diverge");
    dev.shutdown();
}

#[test]
fn beam_width_one_matches_greedy() {
    let (arts, dev, params) = setup();
    let runner = EvalRunner::new(&arts, &dev, MODEL).unwrap();
    let eos = -1; // never fires: fixed-length comparison
    let decode_len = 5;
    let prompt = vec![6, 2, 9];
    let greedy = single_request_greedy(&runner, &params, &prompt, decode_len, eos);
    let mut engine = InferEngine::new(&arts, &dev, MODEL, &params, eos).unwrap();
    let hyps = engine.beam_decode(&prompt, 1, 0.0, decode_len).unwrap();
    assert_eq!(hyps[0].tokens, greedy, "beam=1, alpha=0 must equal greedy");
    // wider beam returns hypotheses sorted best-first and is reproducible
    let b = engine.manifest.batch();
    if b >= 2 {
        let wide = engine.beam_decode(&prompt, 2, 0.0, decode_len).unwrap();
        assert!(!wide.is_empty() && wide.len() <= 2);
        for w in wide.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        let again = engine.beam_decode(&prompt, 2, 0.0, decode_len).unwrap();
        assert_eq!(wide, again, "beam_decode must be deterministic");
    }
    dev.shutdown();
}

#[test]
fn serve_loop_round_trips_jsonl() {
    use t5x::util::json::Json;
    let (arts, dev, params) = setup();
    let runner = EvalRunner::new(&arts, &dev, MODEL).unwrap();
    let expected = single_request_greedy(&runner, &params, &[5, 9, 11], 4, 1);
    let engine = InferEngine::new(&arts, &dev, MODEL, &params, 1).unwrap();
    let gateway =
        t5x::serve::Gateway::launch(vec![engine], t5x::serve::GatewayConfig::default());
    let input = std::io::Cursor::new(
        [
            r#"{"id": 1, "prompt": [5, 9, 11], "max_tokens": 4}"#,
            "this is not json",
            r#"{"id": 2, "prompt": [8], "max_tokens": 3, "method": "sample", "seed": 5}"#,
        ]
        .join("\n"),
    );
    let mut out: Vec<u8> = Vec::new();
    let summary =
        t5x::infer::server::serve(&gateway, input, &mut out, 16, None).unwrap();
    assert_eq!(summary.requests, 2);
    assert_eq!(summary.errors, 1);
    assert_eq!(summary.completed, 2);
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(lines.len(), 3, "2 responses + 1 error, got: {text}");
    let errors = lines.iter().filter(|v| v.get("error").is_some()).count();
    assert_eq!(errors, 1);
    let r1 = lines
        .iter()
        .find(|v| v.get("id").and_then(|x| x.as_i64()) == Some(1))
        .expect("response for id 1");
    let tokens: Vec<i32> = r1
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as i32)
        .collect();
    assert_eq!(tokens, expected, "served greedy output must match solo decode");
    assert!(lines.iter().any(|v| v.get("id").and_then(|x| x.as_i64()) == Some(2)));
    let report = gateway.shutdown();
    assert_eq!(report.completed, 2);
    dev.shutdown();
}

#[test]
fn predict_and_evaluate_streams_engine_outputs() {
    let (arts, dev, params) = setup();
    let vocab = ByteVocabulary::new(16);
    let mut engine = InferEngine::new(&arts, &dev, MODEL, &params, 1).unwrap();
    let examples: Vec<(Vec<i32>, String)> = (0..3i32)
        .map(|i| {
            let prompt: Vec<i32> = vocab.encode("ab").iter().map(|t| t + i).collect();
            (prompt, "ab".to_string())
        })
        .collect();
    let report = predict_and_evaluate(
        &mut engine,
        &vocab,
        "infer_eval_smoke",
        &examples,
        5,
        &[Metric::ExactMatch, Metric::EditSimilarity],
    )
    .unwrap();
    assert_eq!(report.result.num_examples, 3);
    assert_eq!(report.predictions.len(), 3);
    let em = report.result.get("exact_match").unwrap();
    assert!((0.0..=1.0).contains(&em));
    assert!(report.result.get("edit_similarity").is_some());
    // engine must have decoded all three requests
    assert_eq!(engine.counters().get("infer/requests_completed"), 3);
    dev.shutdown();
}

/// Submit `prompts[i]` with budget `budget(i)` and method `method(i)`,
/// drain the engine, and return the results sorted by request id.
fn run_requests(
    engine: &mut InferEngine,
    prompts: &[Vec<i32>],
    budget: impl Fn(usize) -> usize,
    method: impl Fn(usize) -> DecodeMethod,
) -> Vec<InferResult> {
    for (i, p) in prompts.iter().enumerate() {
        engine
            .submit(InferRequest {
                id: i as u64,
                prompt: p.clone(),
                max_tokens: budget(i),
                method: method(i),
            })
            .unwrap();
    }
    let mut results = engine.run_until_idle().unwrap();
    results.sort_by_key(|r| r.id);
    results
}

#[test]
fn kv_and_rescore_modes_are_byte_identical_under_refills() {
    // The tentpole acceptance test (L=32 model): see
    // kv_vs_rescore_byte_identity for the shared body.
    let (arts, dev, params) = setup();
    kv_vs_rescore_byte_identity(&arts, &dev, MODEL, &params, 2);
    dev.shutdown();
}

#[test]
fn kv_and_rescore_modes_are_byte_identical_at_l128() {
    // Same contract on the long-sequence config: deep prompts make the
    // single-query relpos-bias path cross the far (log-bucket) distance
    // buckets that L=32 barely touches.
    let arts = Artifacts::load_default().unwrap();
    if !arts.models.contains_key("t5-nano-dec-l128") {
        eprintln!("SKIP: t5-nano-dec-l128 not in this artifact dir (re-export)");
        return;
    }
    let dev = DeviceHandle::spawn().unwrap();
    let params = t5x::model::init_params(arts.model("t5-nano-dec-l128").unwrap(), 3);
    kv_vs_rescore_byte_identity(&arts, &dev, "t5-nano-dec-l128", &params, 40);
    dev.shutdown();
}

/// Shared body: the O(L) kv path (prefill on admit + [B, 1] decode_step)
/// must reproduce the O(L^2) rescore path byte-for-byte — tokens AND
/// schedule — across N > B requests with staggered budgets (mid-flight
/// refills), for greedy and seeded sampling.
fn kv_vs_rescore_byte_identity(
    arts: &Artifacts,
    dev: &DeviceHandle,
    model: &str,
    params: &Params,
    base_budget: usize,
) {
    let m = arts.model(model).unwrap();
    let (b, l) = (m.batch(), m.seq_len());
    let eos = -1; // budgets drive retirement -> deterministic refills
    let n = b + 5;
    // Prompts reach half the sequence so kv steps attend across long
    // distances; budgets stagger so slots free at different steps.
    let plen = l / 2;
    let prompts: Vec<Vec<i32>> = (0..n)
        .map(|i| {
            (0..plen as i32 + (i % 3) as i32).map(|j| 5 + i as i32 + 3 * j % 400).collect()
        })
        .collect();
    let budget = move |i: usize| base_budget + (i % 5);
    let methods: Vec<(&str, Box<dyn Fn(usize) -> DecodeMethod>)> = vec![
        ("greedy", Box::new(|_| DecodeMethod::Greedy)),
        (
            "sample",
            Box::new(|i| DecodeMethod::Sample {
                temperature: 0.8,
                top_k: 16,
                top_p: 0.95,
                seed: 1000 + i as u64,
            }),
        ),
    ];
    for (label, method) in &methods {
        let mut kv =
            InferEngine::with_mode(arts, dev, model, params, eos, Some(DecodeMode::Kv))
                .unwrap();
        let mut rescore =
            InferEngine::with_mode(arts, dev, model, params, eos, Some(DecodeMode::Rescore))
                .unwrap();
        assert_eq!(kv.mode(), DecodeMode::Kv);
        assert_eq!(rescore.mode(), DecodeMode::Rescore);
        let r_kv = run_requests(&mut kv, &prompts, budget, method);
        let r_rs = run_requests(&mut rescore, &prompts, budget, method);
        assert_eq!(r_kv.len(), n);
        assert_eq!(r_rs.len(), n);
        for (a, b_) in r_kv.iter().zip(&r_rs) {
            assert_eq!(
                a.tokens, b_.tokens,
                "{label} request {}: kv-mode tokens diverged from rescore",
                a.id
            );
            assert_eq!(a.started_step, b_.started_step, "{label} request {}", a.id);
            assert_eq!(a.finished_step, b_.finished_step, "{label} request {}", a.id);
        }
        // both schedules actually exercised continuous batching...
        assert!(kv.counters().get("infer/refills") > 0, "{label}: no refills");
        assert_eq!(
            kv.counters().get("infer/refills"),
            rescore.counters().get("infer/refills")
        );
        // ...and the kv engine really ran the incremental hot path:
        // refill admissions prefill again, continuing rows ride
        // decode_step, and rescore mode never prefills.
        assert!(kv.counters().get("infer/prefills") >= 2, "{label}");
        assert!(kv.counters().get("infer/kv_steps") > 0, "{label}");
        assert_eq!(rescore.counters().get("infer/prefills"), 0);
    }
}

#[test]
fn beam_fallback_is_mode_independent() {
    // Beam search always drives decode_logits; a kv-mode engine must
    // produce exactly the rescore engine's hypotheses.
    let (arts, dev, params) = setup();
    let prompt = vec![6, 2, 9];
    let mut kv =
        InferEngine::with_mode(&arts, &dev, MODEL, &params, -1, Some(DecodeMode::Kv))
            .unwrap();
    let mut rescore =
        InferEngine::with_mode(&arts, &dev, MODEL, &params, -1, Some(DecodeMode::Rescore))
            .unwrap();
    let beams = kv.manifest.batch().min(2);
    let h_kv = kv.beam_decode(&prompt, beams, 0.6, 5).unwrap();
    let h_rs = rescore.beam_decode(&prompt, beams, 0.6, 5).unwrap();
    assert_eq!(h_kv, h_rs, "beam fallback must not depend on the decode mode");
    dev.shutdown();
}

#[test]
fn stale_artifact_dirs_serve_via_rescore_fallback() {
    // An artifact dir exported before the kv entrypoints: auto mode must
    // resolve to rescore and keep serving; forcing kv is a clear error.
    let (arts, dev, params) = setup();
    let mut stale = arts.clone();
    {
        let m = stale.models.get_mut(MODEL).unwrap();
        m.entrypoints.remove("prefill");
        m.entrypoints.remove("decode_step");
        m.kv_cache = None;
        assert!(!m.supports_kv_decode());
    }
    let req = || InferRequest {
        id: 0,
        prompt: vec![5, 9, 11],
        max_tokens: 4,
        method: DecodeMethod::Greedy,
    };
    let mut engine = InferEngine::new(&stale, &dev, MODEL, &params, -1).unwrap();
    assert_eq!(engine.mode(), DecodeMode::Rescore, "auto must fall back");
    engine.submit(req()).unwrap();
    let out = engine.run_until_idle().unwrap();
    assert_eq!(out[0].tokens.len(), 4);
    // the fresh manifest auto-selects kv and agrees on the output
    let mut kv = InferEngine::new(&arts, &dev, MODEL, &params, -1).unwrap();
    assert_eq!(kv.mode(), DecodeMode::Kv, "re-export artifacts (make artifacts)");
    kv.submit(req()).unwrap();
    assert_eq!(kv.run_until_idle().unwrap()[0].tokens, out[0].tokens);
    // explicit --decode-mode kv against the stale dir errors loudly
    let err =
        InferEngine::with_mode(&stale, &dev, MODEL, &params, -1, Some(DecodeMode::Kv));
    assert!(err.is_err());
    assert!(
        format!("{:#}", err.err().unwrap()).contains("decode-mode rescore"),
        "the error must point at the fallback flag"
    );
    dev.shutdown();
}

#[test]
fn serve_rejects_impossible_prompts_per_request_and_continues() {
    // An over-long prompt (>= seq_len) or an out-of-vocab token id must
    // produce a per-request {"id", "error"} response — never crash the
    // serve loop — and later requests must still decode.
    use t5x::util::json::Json;
    let (arts, dev, params) = setup();
    let l = arts.model(MODEL).unwrap().seq_len();
    let engine = InferEngine::new(&arts, &dev, MODEL, &params, 1).unwrap();
    let gateway =
        t5x::serve::Gateway::launch(vec![engine], t5x::serve::GatewayConfig::default());
    let long: Vec<String> = (0..l).map(|_| "3".to_string()).collect();
    let input = std::io::Cursor::new(format!(
        "{{\"id\": 7, \"prompt\": [{}], \"max_tokens\": 4}}\n\
         {{\"id\": 9, \"prompt\": [500000], \"max_tokens\": 3}}\n\
         {{\"id\": 8, \"prompt\": [5, 9], \"max_tokens\": 3}}\n",
        long.join(", ")
    ));
    let mut out: Vec<u8> = Vec::new();
    let summary =
        t5x::infer::server::serve(&gateway, input, &mut out, 8, None).unwrap();
    assert_eq!(summary.requests, 1);
    assert_eq!(summary.errors, 2);
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    let by_id = |id: i64| {
        lines
            .iter()
            .find(|v| v.get("id").and_then(|x| x.as_i64()) == Some(id))
            .unwrap_or_else(|| panic!("no response for id {id}: {text}"))
    };
    assert!(by_id(7).get("error").is_some(), "over-long prompt must error");
    assert!(by_id(9).get("error").is_some(), "out-of-vocab id must error");
    let tokens = by_id(8).get("tokens").expect("valid request must decode");
    assert_eq!(tokens.as_arr().unwrap().len(), 3);
    assert_eq!(
        gateway.counters().get("serve/rejected_invalid"),
        2,
        "both impossible requests must be rejected at admission"
    );
    gateway.shutdown();
    dev.shutdown();
}

#[test]
fn submit_rejects_impossible_requests() {
    let (arts, dev, params) = setup();
    let mut engine = InferEngine::new(&arts, &dev, MODEL, &params, 1).unwrap();
    let l = engine.manifest.seq_len();
    assert!(engine
        .submit(InferRequest {
            id: 0,
            prompt: vec![3; l], // no room for BOS + one decode position
            max_tokens: 4,
            method: DecodeMethod::Greedy,
        })
        .is_err());
    assert!(engine
        .submit(InferRequest {
            id: 1,
            prompt: vec![3],
            max_tokens: 0,
            method: DecodeMethod::Greedy,
        })
        .is_err());
    assert!(engine
        .submit(InferRequest {
            id: 2,
            prompt: vec![3],
            max_tokens: 4,
            method: DecodeMethod::Beam { beams: 2, length_penalty: 0.6 },
        })
        .is_err());
    assert!(!engine.has_work(), "rejected requests must not enqueue");
    dev.shutdown();
}
