//! The deterministic cache job (paper §3.2): "a distributed caching job
//! loads the raw data, preprocesses and shuffles the examples, assigns
//! ordered indices, and writes the data to sharded files. Importantly, the
//! examples are sharded by the modulo of their index to the number of
//! files."
//!
//! This is the Apache-Beam substitute: multi-threaded over shard writers,
//! one pass, deterministic given the seed. The resulting layout is read by
//! [`super::deterministic`].

use std::path::{Path, PathBuf};

use super::records::RecordWriter;
use super::serialize_example;
use super::task::Task;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::threads::parallel_map;

#[derive(Clone, Debug)]
pub struct CacheConfig {
    /// Number of output record files. Choose a multiple of every host count
    /// you intend to train with (paper: enables exclusive file sets).
    pub num_shards: usize,
    /// Shuffle / preprocessing seed.
    pub seed: u64,
    /// Writer threads.
    pub workers: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self { num_shards: 8, seed: 0, workers: 4 }
    }
}

#[derive(Debug, Clone)]
pub struct CacheMeta {
    pub task: String,
    pub num_examples: usize,
    pub num_shards: usize,
    pub seed: u64,
}

impl CacheMeta {
    pub fn shard_file(dir: &Path, shard: usize) -> PathBuf {
        dir.join(format!("shard-{shard:05}.rec"))
    }

    pub fn load(dir: &Path) -> anyhow::Result<CacheMeta> {
        let j = Json::parse_file(dir.join("cache_meta.json"))?;
        Ok(CacheMeta {
            task: j.get("task").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            num_examples: j
                .get("num_examples")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("cache_meta missing num_examples"))?,
            num_shards: j
                .get("num_shards")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("cache_meta missing num_shards"))?,
            seed: j.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        })
    }

    fn save(&self, dir: &Path) -> anyhow::Result<()> {
        let j = Json::obj(vec![
            ("task", Json::str(self.task.clone())),
            ("num_examples", Json::num(self.num_examples as f64)),
            ("num_shards", Json::num(self.num_shards as f64)),
            ("seed", Json::num(self.seed as f64)),
        ]);
        std::fs::write(dir.join("cache_meta.json"), j.to_string())?;
        Ok(())
    }
}

/// Run the cache job: preprocess -> global shuffle -> index -> shard by
/// `index % num_shards`. Returns the metadata. Atomic: writes into a
/// `.tmp` directory then renames.
pub fn cache_task(
    task: &Task,
    out_dir: impl AsRef<Path>,
    cfg: &CacheConfig,
) -> anyhow::Result<CacheMeta> {
    let out_dir = out_dir.as_ref();
    let tmp_dir = out_dir.with_extension("tmp");
    if tmp_dir.exists() {
        std::fs::remove_dir_all(&tmp_dir)?;
    }
    std::fs::create_dir_all(&tmp_dir)?;

    // 1. materialize the preprocessed dataset (the "Beam" load+preprocess).
    let mut examples = task.dataset(cfg.seed, 0, 1).collect_vec();
    anyhow::ensure!(!examples.is_empty(), "task '{}' produced no examples", task.name);
    for ex in examples.iter().take(8) {
        task.validate_example(ex)?;
    }

    // 2. global shuffle (the well-shuffled guarantee of §3.2).
    let mut rng = Pcg64::new(cfg.seed ^ 0x5348_5546); // "SHUF"
    rng.shuffle(&mut examples);

    // 3+4. assign ordered indices implicitly (position after shuffle) and
    // write example i to file i % num_shards, preserving order within file.
    let n = examples.len();
    let shards = cfg.num_shards.max(1);
    let examples = std::sync::Arc::new(examples);
    let counts = parallel_map(shards, cfg.workers.max(1), |s| {
        let mut w = RecordWriter::create(CacheMeta::shard_file(&tmp_dir, s))
            .expect("create shard");
        let mut i = s;
        while i < n {
            w.write(&serialize_example(&examples[i])).expect("write record");
            i += shards;
        }
        w.finish().expect("finish shard")
    });
    debug_assert_eq!(counts.iter().sum::<usize>(), n);

    let meta = CacheMeta {
        task: task.name.clone(),
        num_examples: n,
        num_shards: shards,
        seed: cfg.seed,
    };
    meta.save(&tmp_dir)?;

    // Atomic commit.
    if out_dir.exists() {
        std::fs::remove_dir_all(out_dir)?;
    }
    std::fs::rename(&tmp_dir, out_dir)?;
    Ok(meta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seqio::preprocessors::Tokenize;
    use crate::seqio::records::RecordReader;
    use crate::seqio::source::SyntheticTextSource;
    use crate::seqio::task::Task;
    use crate::seqio::vocab::{ByteVocabulary, Vocabulary};
    use crate::seqio::deserialize_example;
    use std::sync::Arc;

    fn test_task(n: usize) -> Arc<Task> {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        Task::builder("cache_test_task")
            .source(Arc::new(SyntheticTextSource::new(3, n)))
            .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
            .output_feature("targets", vocab, true)
            .build()
    }

    #[test]
    fn cache_roundtrip_and_layout() {
        let dir = std::env::temp_dir().join(format!("cache_{}", std::process::id()));
        let task = test_task(37);
        let cfg = CacheConfig { num_shards: 4, seed: 9, workers: 2 };
        let meta = cache_task(&task, &dir, &cfg).unwrap();
        assert_eq!(meta.num_examples, 37);
        assert_eq!(meta.num_shards, 4);
        let loaded = CacheMeta::load(&dir).unwrap();
        assert_eq!(loaded.num_examples, 37);

        // layout: shard s holds ceil((37 - s)/4) examples
        let mut total = 0;
        for s in 0..4 {
            let r = RecordReader::open(CacheMeta::shard_file(&dir, s)).unwrap();
            let expect = (37 + 4 - 1 - s) / 4;
            assert_eq!(r.len(), expect, "shard {s}");
            total += r.len();
        }
        assert_eq!(total, 37);

        // entries decode back into examples with expected features
        let mut r = RecordReader::open(CacheMeta::shard_file(&dir, 1)).unwrap();
        let ex = deserialize_example(&r.read_at(0).unwrap()).unwrap();
        assert!(ex.contains_key("targets"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cache_deterministic_given_seed() {
        let d1 = std::env::temp_dir().join(format!("cache_d1_{}", std::process::id()));
        let d2 = std::env::temp_dir().join(format!("cache_d2_{}", std::process::id()));
        let task = test_task(20);
        let cfg = CacheConfig { num_shards: 2, seed: 5, workers: 2 };
        cache_task(&task, &d1, &cfg).unwrap();
        cache_task(&task, &d2, &cfg).unwrap();
        for s in 0..2 {
            let a = std::fs::read(CacheMeta::shard_file(&d1, s)).unwrap();
            let b = std::fs::read(CacheMeta::shard_file(&d2, s)).unwrap();
            assert_eq!(a, b, "shard {s} differs");
        }
        // different seed -> different order
        let d3 = std::env::temp_dir().join(format!("cache_d3_{}", std::process::id()));
        let cfg3 = CacheConfig { seed: 6, ..cfg };
        cache_task(&task, &d3, &cfg3).unwrap();
        let a = std::fs::read(CacheMeta::shard_file(&d1, 0)).unwrap();
        let c = std::fs::read(CacheMeta::shard_file(&d3, 0)).unwrap();
        assert_ne!(a, c);
        for d in [&d1, &d2, &d3] {
            std::fs::remove_dir_all(d).ok();
        }
    }
}
