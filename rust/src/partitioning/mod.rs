//! Partitioning (paper §2.2, S2): the t5x high-level API over GSPMD-style
//! sharding, reimplemented explicitly for the simulated host mesh.
//!
//! * [`Mesh`] — the 2-D device decomposition N = data × model.
//! * [`LogicalAxisRules`] — map *logical* axis names (the
//!   `param_with_axes` annotations carried in the artifact manifest) to
//!   mesh axes, exactly like `t5x.partitioning.standard_logical_axis_rules`.
//! * [`Partitioner`] — computes a [`PartitionSpec`] per parameter, slices /
//!   reassembles host shards of [`HostTensor`]s, and implements the
//!   paper's strategy matrix (1D vs 2D parameter partitioning).
//! * [`ShardPlan`] — the manifest-wide materialization of those specs that
//!   the trainer *executes*: which block of each parameter a host keeps
//!   resident, which hosts own (vs replicate) a block, and the per-host
//!   memory accounting behind the §2.2 claims.
//! * [`cost`] — the analytic GSPMD memory/communication model that
//!   regenerates the §2.2 trade-off discussion as a table (E3), now with
//!   per-mesh-axis communication terms validated against the measured
//!   per-axis byte counters of [`crate::collectives::MeshCollectives`].
//!
//! ## Shard-resident storage, block-sharded execution (§2.2)
//!
//! Parameter state is *shard-resident end-to-end*: a host materializes
//! only the `PartitionSpec` block of each parameter (and the matching
//! optimizer-state block), so per-host resident memory is
//! ~`total/(data·model)` plus the small replicated residue. Execution
//! comes in two [`ExecMode`]s:
//!
//! * **Block** (the Megatron f/g decomposition, auto-selected when the
//!   artifact manifest carries a `block_exec` contract for the mesh's
//!   model degree): the step feeds each host's resident model-axis block
//!   straight into per-segment HLOs — column-parallel matmuls run locally,
//!   and at every row-parallel boundary (attention `wo`, MLP `wo`, the
//!   vocab-sharded softmax) the trainer replays the manifest's ordered
//!   collective schedule over the model subgroup (all-reduce sum/max/min).
//!   No full parameter tensor is ever materialized: per-host peak step
//!   memory is O(block + activations) and model-axis traffic is
//!   *activation*-sized reductions, not parameter-sized gathers. Grads
//!   come out block-shaped, so the slice-then-sync path collapses to the
//!   data-axis sync alone.
//! * **Gather** (the fallback for pre-block artifact dirs and the
//!   reference for agreement tests): at step start each host reconstructs
//!   full parameters with data-axis then model-axis all-gathers over
//!   [`crate::collectives::MeshCollectives`] subgroups and runs the
//!   monolithic `train_step` HLO; after the backward pass it keeps its
//!   model-axis gradient slice and syncs it over the data axis
//!   (reduce-scatter for data-sharded blocks, all-reduce for
//!   data-replicated ones).
//!
//! Selection rule: `ExecMode::Auto` resolves to `Block` iff
//! `mesh.model > 1` and `manifest.supports_block_exec(mesh.model)`;
//! forcing `Block` on an unsupported mesh/manifest is a hard error naming
//! `--exec-mode gather`. In both modes **checkpoints** are written by
//! block owners directly as disjoint tstore slices (no host-0 gather),
//! and restore reads each host's block range regardless of the saving
//! topology (read-with-resharding) — a gather-mode checkpoint resumes in
//! block mode and vice versa.

pub mod cost;


use crate::runtime::artifacts::ParamSpec;
use crate::runtime::HostTensor;

/// Hardware mesh axes (t5x: "data" and "model").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeshAxis {
    Data,
    Model,
}

/// How a train step executes against sharded parameters (module docs above).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// `Block` iff the manifest supports the mesh's model degree, else
    /// `Gather`.
    #[default]
    Auto,
    /// Gather full parameters at step start, run the monolithic HLO.
    Gather,
    /// Run the block-segment schedule on resident model-axis blocks; hard
    /// error if the manifest has no contract for the mesh's model degree.
    Block,
}

impl ExecMode {
    /// Parse a `--exec-mode` / gin value.
    pub fn parse(s: &str) -> anyhow::Result<ExecMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(ExecMode::Auto),
            "gather" => Ok(ExecMode::Gather),
            "block" => Ok(ExecMode::Block),
            other => anyhow::bail!("bad exec mode '{other}' (expected auto|gather|block)"),
        }
    }
}

impl std::fmt::Display for ExecMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ExecMode::Auto => "auto",
            ExecMode::Gather => "gather",
            ExecMode::Block => "block",
        })
    }
}

/// The device mesh: `data * model` simulated hosts. Host h has coordinates
/// (h / model, h % model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mesh {
    pub data: usize,
    pub model: usize,
}

impl Mesh {
    pub fn new(data: usize, model: usize) -> Mesh {
        assert!(data >= 1 && model >= 1);
        Mesh { data, model }
    }

    pub fn num_hosts(&self) -> usize {
        self.data * self.model
    }

    pub fn coords(&self, host: usize) -> (usize, usize) {
        (host / self.model, host % self.model)
    }

    pub fn axis_size(&self, axis: MeshAxis) -> usize {
        match axis {
            MeshAxis::Data => self.data,
            MeshAxis::Model => self.model,
        }
    }

    /// Host coordinate along `axis`.
    pub fn coord(&self, host: usize, axis: MeshAxis) -> usize {
        let (d, m) = self.coords(host);
        match axis {
            MeshAxis::Data => d,
            MeshAxis::Model => m,
        }
    }

    /// Parse `"DxM"` (e.g. "4x2") or a bare host count `"N"` (= Nx1).
    pub fn parse(s: &str) -> anyhow::Result<Mesh> {
        let s = s.trim();
        let (d, m) = match s.split_once(['x', 'X']) {
            Some((d, m)) => (
                d.trim().parse::<usize>().map_err(|_| bad_mesh(s))?,
                m.trim().parse::<usize>().map_err(|_| bad_mesh(s))?,
            ),
            None => (s.parse::<usize>().map_err(|_| bad_mesh(s))?, 1),
        };
        anyhow::ensure!(d >= 1 && m >= 1, "mesh axes must be >= 1, got {s}");
        Ok(Mesh { data: d, model: m })
    }
}

fn bad_mesh(s: &str) -> anyhow::Error {
    anyhow::anyhow!("bad mesh spec '{s}' (expected 'DATAxMODEL', e.g. '4x2', or a host count)")
}

impl std::fmt::Display for Mesh {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}", self.data, self.model)
    }
}

/// Parameter-partitioning strategy (paper §2.2):
/// * `OneD` — parameters sharded over the *model* axis only; replicated
///   over the data axis ("1D parameter partitioning", Megatron-style).
/// * `TwoD` — additionally sharded over the *data* axis (ZeRO-3 / fully
///   sharded data parallelism: "2D parameter partitioning").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamStrategy {
    OneD,
    TwoD,
}

/// Activation-partitioning strategy (cost model only — activations live
/// inside XLA on this testbed): 1D = replicate activations with an
/// embed/model axis over the model axis; 2D = shard them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActivationStrategy {
    OneD,
    TwoD,
}

/// Per-dimension sharding of one tensor: `Some((axis, shards))` or None
/// (replicated dim).
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionSpec {
    pub dims: Vec<Option<(MeshAxis, usize)>>,
}

impl PartitionSpec {
    pub fn replicated(rank: usize) -> Self {
        Self { dims: vec![None; rank] }
    }

    /// Number of distinct shards this spec produces.
    pub fn num_shards(&self) -> usize {
        self.dims.iter().flatten().map(|(_, s)| s).product()
    }

    /// Shape of one shard of a tensor with `shape`.
    pub fn shard_shape(&self, shape: &[usize]) -> Vec<usize> {
        shape
            .iter()
            .zip(&self.dims)
            .map(|(&d, s)| match s {
                Some((_, n)) => d / n,
                None => d,
            })
            .collect()
    }

    /// The tensor dimension sharded over `axis` (at most one per axis by
    /// construction), as `(dim_index, num_shards)`.
    pub fn dim_for(&self, axis: MeshAxis) -> Option<(usize, usize)> {
        self.dims
            .iter()
            .enumerate()
            .find_map(|(i, d)| match d {
                Some((a, n)) if *a == axis => Some((i, *n)),
                _ => None,
            })
    }

    pub fn is_sharded(&self) -> bool {
        self.dims.iter().any(|d| d.is_some())
    }

    /// Host `host`'s `(start, len)` range per tensor dimension under this
    /// spec on `mesh` — the block of the full tensor the host keeps
    /// resident (full dim for replicated dimensions).
    pub fn host_ranges(&self, mesh: &Mesh, host: usize, shape: &[usize]) -> Vec<(usize, usize)> {
        shape
            .iter()
            .zip(&self.dims)
            .map(|(&full, d)| match d {
                Some((axis, n)) => {
                    let size = full / n;
                    (mesh.coord(host, *axis) * size, size)
                }
                None => (0, full),
            })
            .collect()
    }

    /// True if `host` is the designated *owner* of its block: its
    /// coordinate is 0 along every mesh axis this spec does NOT shard
    /// over. Exactly one host owns each distinct block — the host that
    /// writes it to checkpoints and counts it in global accounting.
    pub fn owns(&self, mesh: &Mesh, host: usize) -> bool {
        [MeshAxis::Data, MeshAxis::Model]
            .into_iter()
            .all(|axis| self.dim_for(axis).is_some() || mesh.coord(host, axis) == 0)
    }
}

/// Logical-axis-name -> mesh-axis rules, in priority order. A rule applies
/// to a dimension if the axis name matches and the mesh axis size divides
/// the dimension (t5x semantics).
#[derive(Debug, Clone)]
pub struct LogicalAxisRules {
    pub rules: Vec<(String, MeshAxis)>,
}

impl LogicalAxisRules {
    /// The t5x standard rules: vocab/heads/mlp/joined_kv shard over the
    /// model axis; batch over data; embed & norms replicated.
    pub fn standard() -> Self {
        Self {
            rules: vec![
                ("vocab".into(), MeshAxis::Model),
                ("heads".into(), MeshAxis::Model),
                ("mlp".into(), MeshAxis::Model),
                ("joined_kv".into(), MeshAxis::Model),
                ("batch".into(), MeshAxis::Data),
            ],
        }
    }

    pub fn mesh_axis_for(&self, logical: &str) -> Option<MeshAxis> {
        self.rules
            .iter()
            .find(|(name, _)| name == logical)
            .map(|(_, a)| *a)
    }
}

/// The t5x partitioner: logical axes + mesh + strategy -> concrete specs
/// and shard/unshard operations.
pub struct Partitioner {
    pub mesh: Mesh,
    pub rules: LogicalAxisRules,
    pub strategy: ParamStrategy,
}

impl Partitioner {
    pub fn new(mesh: Mesh, strategy: ParamStrategy) -> Self {
        Self { mesh, rules: LogicalAxisRules::standard(), strategy }
    }

    /// Compute the axis-wise partition spec for a parameter.
    ///
    /// 1D: the first dimension whose logical axis maps to Model (and is
    /// divisible) is sharded `model`-ways.
    /// 2D: additionally, the first *other* dimension divisible by `data`
    /// is sharded `data`-ways (ZeRO-3's second array axis, following
    /// Xu et al.'s 2D scheme).
    pub fn spec_for(&self, param: &ParamSpec) -> PartitionSpec {
        let mut dims: Vec<Option<(MeshAxis, usize)>> = vec![None; param.shape.len()];
        // model-axis sharding
        if self.mesh.model > 1 {
            for (i, axis_name) in param.logical_axes.iter().enumerate() {
                if self.rules.mesh_axis_for(axis_name) == Some(MeshAxis::Model)
                    && param.shape[i] % self.mesh.model == 0
                {
                    dims[i] = Some((MeshAxis::Model, self.mesh.model));
                    break;
                }
            }
        }
        // data-axis sharding (2D only)
        if self.strategy == ParamStrategy::TwoD && self.mesh.data > 1 {
            for i in 0..param.shape.len() {
                if dims[i].is_none() && param.shape[i] % self.mesh.data == 0 {
                    dims[i] = Some((MeshAxis::Data, self.mesh.data));
                    break;
                }
            }
        }
        PartitionSpec { dims }
    }

    /// Extract host `h`'s shard of a full tensor under `spec`.
    pub fn shard(&self, full: &HostTensor, spec: &PartitionSpec, host: usize) -> HostTensor {
        let (d, m) = self.mesh.coords(host);
        let mut out = full.clone();
        // Slice axis-by-axis (order doesn't matter for disjoint axes).
        for (axis_idx, dim_spec) in spec.dims.iter().enumerate() {
            if let Some((mesh_axis, shards)) = dim_spec {
                let coord = match mesh_axis {
                    MeshAxis::Data => d,
                    MeshAxis::Model => m,
                };
                let size = out.shape[axis_idx] / shards;
                out = out.slice_axis(axis_idx, coord * size, size);
            }
        }
        out
    }

    /// Reassemble the full tensor from all hosts' shards (inverse of
    /// [`Partitioner::shard`]). `shards[h]` is host h's piece. Replicated
    /// tensors return host 0's copy.
    pub fn unshard(&self, shards: &[HostTensor], spec: &PartitionSpec) -> HostTensor {
        assert_eq!(shards.len(), self.mesh.num_hosts());
        let mut current: Vec<HostTensor> = shards.to_vec();
        let mut group = self.mesh.num_hosts();
        // Fold mesh axes back in reverse declaration order: model is the
        // fastest-varying host coordinate, so merge model first.
        for (mesh_axis, axis_size) in [(MeshAxis::Model, self.mesh.model), (MeshAxis::Data, self.mesh.data)] {
            if axis_size == 1 {
                continue;
            }
            let dim_idx = spec
                .dims
                .iter()
                .position(|d| matches!(d, Some((a, _)) if *a == mesh_axis));
            group /= axis_size;
            let mut next: Vec<HostTensor> = Vec::with_capacity(group);
            for g in 0..group {
                let members: Vec<HostTensor> = (0..axis_size)
                    .map(|k| current[g * axis_size + k].clone())
                    .collect();
                next.push(match dim_idx {
                    Some(di) => HostTensor::concat_axis(&members, di),
                    None => members[0].clone(), // replicated over this axis
                });
            }
            current = next;
        }
        assert_eq!(current.len(), 1);
        current.remove(0)
    }
}

// ---------------------------------------------------------------------------
// ShardPlan: the manifest-wide sharding the trainer executes
// ---------------------------------------------------------------------------

/// One parameter's entry in a [`ShardPlan`].
#[derive(Debug, Clone)]
pub struct ShardEntry {
    pub name: String,
    /// Full tensor shape.
    pub shape: Vec<usize>,
    pub spec: PartitionSpec,
    /// Shape of the per-host resident block.
    pub shard_shape: Vec<usize>,
}

impl ShardEntry {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn shard_elems(&self) -> usize {
        self.shard_shape.iter().product()
    }
}

/// The concrete sharding of a whole parameter set over a mesh — what
/// [`crate::trainer::Trainer`] keeps resident, gathers, syncs, and
/// checkpoints. Built once per run from the manifest's [`ParamSpec`]s.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub mesh: Mesh,
    pub strategy: ParamStrategy,
    pub entries: Vec<ShardEntry>,
}

impl ShardPlan {
    pub fn new(partitioner: &Partitioner, params: &[ParamSpec]) -> ShardPlan {
        let entries = params
            .iter()
            .map(|p| {
                let spec = partitioner.spec_for(p);
                ShardEntry {
                    name: p.name.clone(),
                    shard_shape: spec.shard_shape(&p.shape),
                    shape: p.shape.clone(),
                    spec,
                }
            })
            .collect();
        ShardPlan { mesh: partitioner.mesh, strategy: partitioner.strategy, entries }
    }

    /// Total parameter elements across the full (unsharded) set.
    pub fn total_elems(&self) -> usize {
        self.entries.iter().map(|e| e.elems()).sum()
    }

    /// Parameter elements resident per host (identical for all hosts:
    /// every host holds exactly one block per parameter).
    pub fn resident_elems_per_host(&self) -> usize {
        self.entries.iter().map(|e| e.shard_elems()).sum()
    }

    /// Elements of the largest single parameter — the transient gather
    /// allowance in the §2.2 per-host memory claim.
    pub fn largest_param_elems(&self) -> usize {
        self.entries.iter().map(|e| e.elems()).max().unwrap_or(0)
    }

    pub fn entry(&self, name: &str) -> Option<&ShardEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pspec(name: &str, shape: Vec<usize>, axes: Vec<&str>) -> ParamSpec {
        ParamSpec {
            name: name.into(),
            shape,
            logical_axes: axes.into_iter().map(|s| s.to_string()).collect(),
            init: "const:0".into(),
        }
    }

    #[test]
    fn mesh_coords() {
        let mesh = Mesh::new(2, 4);
        assert_eq!(mesh.num_hosts(), 8);
        assert_eq!(mesh.coords(0), (0, 0));
        assert_eq!(mesh.coords(5), (1, 1));
        assert_eq!(mesh.coords(7), (1, 3));
    }

    #[test]
    fn spec_1d_shards_model_axis_only() {
        let p = Partitioner::new(Mesh::new(2, 2), ParamStrategy::OneD);
        let wq = pspec("wq", vec![64, 64], vec!["embed", "joined_kv"]);
        let spec = p.spec_for(&wq);
        assert_eq!(spec.dims[0], None);
        assert_eq!(spec.dims[1], Some((MeshAxis::Model, 2)));
        assert_eq!(spec.shard_shape(&wq.shape), vec![64, 32]);
        // norm scale: replicated
        let norm = pspec("scale", vec![64], vec!["embed"]);
        assert_eq!(p.spec_for(&norm), PartitionSpec::replicated(1));
    }

    #[test]
    fn spec_2d_adds_data_axis() {
        let p = Partitioner::new(Mesh::new(2, 2), ParamStrategy::TwoD);
        let wq = pspec("wq", vec![64, 64], vec!["embed", "joined_kv"]);
        let spec = p.spec_for(&wq);
        assert_eq!(spec.dims[1], Some((MeshAxis::Model, 2)));
        assert_eq!(spec.dims[0], Some((MeshAxis::Data, 2)));
        assert_eq!(spec.shard_shape(&wq.shape), vec![32, 32]);
        // 2D with pure data parallelism (model=1): ZeRO shards first axis
        let pdp = Partitioner::new(Mesh::new(4, 1), ParamStrategy::TwoD);
        let spec2 = pdp.spec_for(&wq);
        assert_eq!(spec2.dims[0], Some((MeshAxis::Data, 4)));
        assert_eq!(spec2.dims[1], None);
    }

    #[test]
    fn shard_unshard_roundtrip() {
        for (mesh, strategy) in [
            (Mesh::new(1, 2), ParamStrategy::OneD),
            (Mesh::new(2, 2), ParamStrategy::OneD),
            (Mesh::new(2, 2), ParamStrategy::TwoD),
            (Mesh::new(4, 1), ParamStrategy::TwoD),
        ] {
            let p = Partitioner::new(mesh, strategy);
            let param = pspec("w", vec![8, 12], vec!["embed", "mlp"]);
            let full = HostTensor::f32(
                vec![8, 12],
                (0..96).map(|i| i as f32).collect(),
            );
            let spec = p.spec_for(&param);
            let shards: Vec<HostTensor> = (0..mesh.num_hosts())
                .map(|h| p.shard(&full, &spec, h))
                .collect();
            let back = p.unshard(&shards, &spec);
            assert_eq!(back, full, "mesh={mesh:?} strategy={strategy:?}");
        }
    }

    #[test]
    fn indivisible_dims_stay_replicated() {
        let p = Partitioner::new(Mesh::new(1, 4), ParamStrategy::OneD);
        // relpos bias: heads=6 not divisible by 4 -> replicated
        let param = pspec("relpos", vec![32, 6], vec!["relpos_buckets", "heads"]);
        assert_eq!(p.spec_for(&param), PartitionSpec::replicated(2));
    }

    #[test]
    fn shard_shapes_consistent_across_hosts() {
        let p = Partitioner::new(Mesh::new(2, 2), ParamStrategy::TwoD);
        let param = pspec("w", vec![16, 8], vec!["embed", "joined_kv"]);
        let spec = p.spec_for(&param);
        let full = HostTensor::zeros(vec![16, 8]);
        for h in 0..4 {
            assert_eq!(p.shard(&full, &spec, h).shape, spec.shard_shape(&param.shape));
        }
    }

    #[test]
    fn mesh_parse_and_display() {
        assert_eq!(Mesh::parse("4x2").unwrap(), Mesh::new(4, 2));
        assert_eq!(Mesh::parse(" 2X2 ").unwrap(), Mesh::new(2, 2));
        assert_eq!(Mesh::parse("8").unwrap(), Mesh::new(8, 1));
        assert!(Mesh::parse("0x2").is_err());
        assert!(Mesh::parse("axb").is_err());
        assert_eq!(Mesh::new(4, 2).to_string(), "4x2");
    }

    #[test]
    fn host_ranges_match_shard_slices() {
        let mesh = Mesh::new(2, 2);
        let p = Partitioner::new(mesh, ParamStrategy::TwoD);
        let param = pspec("w", vec![8, 12], vec!["embed", "mlp"]);
        let spec = p.spec_for(&param);
        let full = HostTensor::f32(vec![8, 12], (0..96).map(|i| i as f32).collect());
        for h in 0..4 {
            let ranges = spec.host_ranges(&mesh, h, &param.shape);
            let mut t = full.clone();
            for (axis, &(start, len)) in ranges.iter().enumerate() {
                t = t.slice_axis(axis, start, len);
            }
            assert_eq!(t, p.shard(&full, &spec, h), "host {h}");
        }
    }

    #[test]
    fn ownership_unique_per_block() {
        let mesh = Mesh::new(2, 2);
        // replicated: only host (0,0) owns
        let rep = PartitionSpec::replicated(2);
        let owners: Vec<usize> = (0..4).filter(|&h| rep.owns(&mesh, h)).collect();
        assert_eq!(owners, vec![0]);
        // model-sharded only: one owner per model coordinate (data row 0)
        let ms = PartitionSpec {
            dims: vec![None, Some((MeshAxis::Model, 2))],
        };
        let owners: Vec<usize> = (0..4).filter(|&h| ms.owns(&mesh, h)).collect();
        assert_eq!(owners, vec![0, 1]);
        // fully sharded: every host owns its distinct block
        let fs = PartitionSpec {
            dims: vec![Some((MeshAxis::Data, 2)), Some((MeshAxis::Model, 2))],
        };
        assert!((0..4).all(|h| fs.owns(&mesh, h)));
    }

    #[test]
    fn shard_plan_accounting() {
        let mesh = Mesh::new(2, 2);
        let p = Partitioner::new(mesh, ParamStrategy::TwoD);
        let params = vec![
            pspec("w", vec![8, 8], vec!["embed", "mlp"]),
            pspec("scale", vec![8], vec!["embed"]),
        ];
        let plan = ShardPlan::new(&p, &params);
        assert_eq!(plan.total_elems(), 72);
        // w: 8x8 / 4 hosts = 16; scale: data-sharded 8/2 = 4
        assert_eq!(plan.resident_elems_per_host(), 20);
        assert_eq!(plan.largest_param_elems(), 64);
        assert_eq!(plan.entry("scale").unwrap().shard_shape, vec![4]);
    }
}
