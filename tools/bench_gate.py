#!/usr/bin/env python3
"""Bench trajectory snapshot + regression gate (stdlib only).

Reads the ``bench_results.jsonl`` that ``cargo bench`` appends (one JSON
object per measurement, see ``rust/src/bench/mod.rs::write_jsonl``),
writes a compact ``BENCH_<pr>.json`` snapshot for the committed
``benchmarks/`` trajectory, and gates two headlines:

* **PR 6** — on any model-parallel mesh (model degree >= 2), block
  execution must not be slower than gather execution of the same
  (model, mesh, strategy) case (``--tolerance``).
* **PR 7** — an armed tracer must not slow the train step: each
  ``... traced (N steps)`` row must hold tok/s within
  ``--trace-tolerance`` of its untraced twin. The nominal contract is
  3%; quick-mode CI medians are noisy, so CI passes a looser value and
  the snapshot records the exact ratios either way.
* **PR 8** — the serving gateway must not tax throughput: under the
  open-loop Poisson workload (``serve gateway (poisson)``), 2-replica
  tok/s must hold the 1-replica line within ``--gateway-tolerance``.
  (One device thread serializes HLO executions, so the gate is
  "replicas are free", not "replicas are 2x".)
* **PR 9** — overlapping communication with compute must not lose
  throughput: for every ``train overlap (serial vs overlapped)`` row on
  a data-parallel mesh (data degree >= 2), overlapped tok/s must hold
  the serial line within ``--overlap-tolerance``.
* **PR 10** — the self-healing supervisor must be free when nothing
  fails: for every ``train supervisor (fault-free)`` row, the
  supervised run (restart loop + disarmed fault hooks + armed ring
  deadline) must hold the plain trainer's tok/s line within
  ``--supervisor-tolerance``.

Beyond the single-run gates, the script cross-compares the *committed*
``benchmarks/BENCH_<n>.json`` trajectory PR-over-PR: the headline
*ratios* (block/gather, traced/untraced, gateway 2/1, overlap/serial,
supervised/plain) of each snapshot are compared against the previous snapshot that
carries the same headline, and a drop beyond ``--history-tolerance``
fails loud. Ratios — not absolute tok/s — are compared because
absolute numbers move with the CI machine; missing snapshots and
snapshots that predate a gate are tolerated (empty intersection is a
skip, not a failure).

The snapshot also distills the PR-7 observability rows: the per-phase
step-time breakdown (``train phase breakdown (obs)``) and the serve
latency percentiles (``serve latency (obs)``).

Usage (CI smoke job):

    python tools/bench_gate.py --input rust/bench_results.jsonl \
        --output benchmarks/BENCH_10.json [--tolerance 0.10] \
        [--trace-tolerance 0.10] [--gateway-tolerance 0.10] \
        [--overlap-tolerance 0.10] [--supervisor-tolerance 0.10] \
        [--history-tolerance 0.25]

Exit status is non-zero if a gate fails or if the input contains no pair
to compare (so a silently-skipped comparison cannot read as a pass).
"""

import argparse
import glob
import json
import os
import re
import sys

# "t5-nano-dec mesh=1x2 OneD block (2 steps)" — see bench_train_step.rs
TRAIN_ROW = re.compile(
    r"^(?P<model>\S+) mesh=(?P<data>\d+)x(?P<mdeg>\d+) "
    r"(?P<strategy>\w+) (?P<exec>gather|block) \(\d+ steps\)$"
)
# "t5-nano-dec mesh=1x2 OneD block traced (2 steps)"
TRACED_ROW = re.compile(
    r"^(?P<model>\S+) mesh=(?P<data>\d+)x(?P<mdeg>\d+) "
    r"(?P<strategy>\w+) (?P<exec>gather|block) traced \(\d+ steps\)$"
)
TRAIN_GROUP = "train step (E16)"
PHASE_GROUP = "train phase breakdown (obs)"
SERVE_GROUP = "serve latency (obs)"
GATEWAY_GROUP = "serve gateway (poisson)"
OVERLAP_GROUP = "train overlap (serial vs overlapped)"
SUPERVISOR_GROUP = "train supervisor (fault-free)"
# "t5-nano-dec mesh=2x1 mb=4" — see the §Overlap block in bench_train_step.rs
OVERLAP_NAME = re.compile(
    r"^(?P<model>\S+) mesh=(?P<data>\d+)x(?P<mdeg>\d+) mb=(?P<mb>\d+)$"
)
BENCH_SNAPSHOT = re.compile(r"^BENCH_(?P<pr>\d+)\.json$")


def load_rows(path):
    rows = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def gate_block(rows, tolerance):
    """Return (pairs, failures) for the block-vs-gather comparison."""
    cases = {}
    for r in rows:
        if r.get("group") != TRAIN_GROUP:
            continue
        m = TRAIN_ROW.match(r.get("name", ""))
        if not m or int(m.group("mdeg")) < 2:
            continue
        key = (m.group("model"), m.group("data"), m.group("mdeg"),
               m.group("strategy"))
        cases.setdefault(key, {})[m.group("exec")] = r.get("throughput_per_s")
    pairs, failures = [], []
    for key, by_exec in sorted(cases.items()):
        if "gather" not in by_exec or "block" not in by_exec:
            continue
        g, b = by_exec["gather"], by_exec["block"]
        pair = {
            "model": key[0],
            "mesh": f"{key[1]}x{key[2]}",
            "strategy": key[3],
            "gather_tok_per_s": g,
            "block_tok_per_s": b,
            "block_over_gather": (b / g) if g else None,
        }
        pairs.append(pair)
        if g and b < g * (1.0 - tolerance):
            failures.append(
                f"{pair['model']} mesh={pair['mesh']} {pair['strategy']}: "
                f"block {b:.1f} tok/s < gather {g:.1f} tok/s "
                f"(ratio {b / g:.3f}, tolerance {tolerance:.2f})"
            )
    return pairs, failures


def gate_tracing(rows, tolerance):
    """Return (pairs, failures) for the traced-vs-untraced comparison."""
    plain, traced = {}, {}
    for r in rows:
        if r.get("group") != TRAIN_GROUP:
            continue
        name = r.get("name", "")
        m = TRACED_ROW.match(name)
        if m:
            bucket = traced
        else:
            m = TRAIN_ROW.match(name)
            bucket = plain
        if not m:
            continue
        key = (m.group("model"), m.group("data"), m.group("mdeg"),
               m.group("strategy"), m.group("exec"))
        bucket[key] = r.get("throughput_per_s")
    pairs, failures = [], []
    for key in sorted(set(plain) & set(traced)):
        p, t = plain[key], traced[key]
        pair = {
            "model": key[0],
            "mesh": f"{key[1]}x{key[2]}",
            "strategy": key[3],
            "exec": key[4],
            "untraced_tok_per_s": p,
            "traced_tok_per_s": t,
            "traced_over_untraced": (t / p) if p else None,
        }
        pairs.append(pair)
        if p and t < p * (1.0 - tolerance):
            failures.append(
                f"{pair['model']} mesh={pair['mesh']} {pair['strategy']} "
                f"{pair['exec']}: traced {t:.1f} tok/s < untraced {p:.1f} "
                f"tok/s (ratio {t / p:.3f}, tolerance {tolerance:.2f})"
            )
    return pairs, failures


def gate_gateway(rows, tolerance):
    """Return (rows, failures) for the replica-scaling comparison."""
    by_replicas = {}
    gateway_rows = []
    for r in rows:
        if r.get("group") != GATEWAY_GROUP:
            continue
        gateway_rows.append({k: v for k, v in r.items() if k != "group"})
        n = r.get("replicas")
        if n is not None:
            by_replicas[int(n)] = r.get("tok_per_s")
    failures = []
    one, two = by_replicas.get(1), by_replicas.get(2)
    if one is None or two is None:
        return gateway_rows, None, failures
    ratio = (two / one) if one else None
    if one and two < one * (1.0 - tolerance):
        failures.append(
            f"gateway poisson: 2-replica {two:.1f} tok/s < 1-replica "
            f"{one:.1f} tok/s (ratio {ratio:.3f}, tolerance {tolerance:.2f})"
        )
    return gateway_rows, ratio, failures


def gate_overlap(rows, tolerance):
    """Return (pairs, failures) for the overlap-vs-serial comparison.

    Each ``train overlap (serial vs overlapped)`` row already carries both
    sides of the pair (bench_train_step.rs measures serial and overlapped
    back-to-back); the gate only applies where the data axis actually has
    peers to overlap against (data degree >= 2).
    """
    pairs, failures = [], []
    for r in rows:
        if r.get("group") != OVERLAP_GROUP:
            continue
        name = r.get("name", "")
        m = OVERLAP_NAME.match(name)
        s, o = r.get("serial_tok_s"), r.get("overlap_tok_s")
        pair = {
            "name": name,
            "microbatches": r.get("microbatches"),
            "serial_tok_s": s,
            "overlap_tok_s": o,
            "overlap_over_serial": (o / s) if s and o is not None else None,
            "serial_step_ms": r.get("serial_step_ms"),
            "overlap_step_ms": r.get("overlap_step_ms"),
            "serial_exposed_comm_ms": r.get("serial_exposed_comm_ms"),
            "overlap_exposed_comm_ms": r.get("overlap_exposed_comm_ms"),
            "overlapped_comm_ms": r.get("overlapped_comm_ms"),
        }
        pairs.append(pair)
        if m and int(m.group("data")) < 2:
            continue  # no data-axis peers: nothing to overlap, don't gate
        if s and o is not None and o < s * (1.0 - tolerance):
            failures.append(
                f"{name}: overlapped {o:.1f} tok/s < serial {s:.1f} tok/s "
                f"(ratio {o / s:.3f}, tolerance {tolerance:.2f})"
            )
    return pairs, failures


def gate_supervisor(rows, tolerance):
    """Return (pairs, failures) for the supervised-vs-plain comparison.

    Each ``train supervisor (fault-free)`` row carries both sides of the
    pair (bench_train_step.rs measures the plain trainer and a fault-free
    supervised run of the same config back-to-back).
    """
    pairs, failures = [], []
    for r in rows:
        if r.get("group") != SUPERVISOR_GROUP:
            continue
        name = r.get("name", "")
        p, s = r.get("plain_tok_s"), r.get("supervised_tok_s")
        pair = {
            "name": name,
            "plain_tok_s": p,
            "supervised_tok_s": s,
            "supervised_over_plain": (s / p) if p and s is not None else None,
        }
        pairs.append(pair)
        if p and s is not None and s < p * (1.0 - tolerance):
            failures.append(
                f"{name}: supervised {s:.1f} tok/s < plain {p:.1f} tok/s "
                f"(ratio {s / p:.3f}, tolerance {tolerance:.2f})"
            )
    return pairs, failures


def headline_ratios(snapshot):
    """Distil one snapshot dict into its {label: ratio} headline map.

    Labels are stable across PRs so adjacent snapshots can be joined on
    them; snapshots that predate a gate simply contribute fewer keys.
    """
    out = {}
    for p in (snapshot.get("gate") or {}).get("pairs") or []:
        r = p.get("block_over_gather")
        if r is not None:
            out[f"block/gather {p.get('model')} mesh={p.get('mesh')} "
                f"{p.get('strategy')}"] = r
    for p in (snapshot.get("trace_gate") or {}).get("pairs") or []:
        r = p.get("traced_over_untraced")
        if r is not None:
            out[f"traced/untraced {p.get('model')} mesh={p.get('mesh')} "
                f"{p.get('strategy')} {p.get('exec')}"] = r
    r = (snapshot.get("gateway") or {}).get("two_over_one")
    if r is not None:
        out["gateway 2-replica/1-replica"] = r
    for p in (snapshot.get("overlap_gate") or {}).get("pairs") or []:
        r = p.get("overlap_over_serial")
        if r is not None:
            out[f"overlap/serial {p.get('name')}"] = r
    for p in (snapshot.get("supervisor_gate") or {}).get("pairs") or []:
        r = p.get("supervised_over_plain")
        if r is not None:
            out[f"supervised/plain {p.get('name')}"] = r
    return out


def cross_compare(bench_dir, current_name, current_snapshot, tolerance):
    """PR-over-PR compare of the committed BENCH_<n>.json trajectory.

    Returns (comparisons, failures). Every adjacent pair in PR order is
    joined on shared headline labels; a ratio drop beyond ``tolerance``
    is a failure. Gaps in PR numbers and headlines absent from older
    snapshots are tolerated — an empty join is recorded as a skip.
    """
    trajectory = []
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        base = os.path.basename(path)
        m = BENCH_SNAPSHOT.match(base)
        if not m or base == current_name:
            continue
        try:
            with open(path, "r", encoding="utf-8") as f:
                snap = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"history: skipping unreadable {base}: {e}",
                  file=sys.stderr)
            continue
        trajectory.append((int(m.group("pr")), base, headline_ratios(snap)))
    m = BENCH_SNAPSHOT.match(current_name)
    cur_pr = int(m.group("pr")) if m else None
    trajectory.append(
        (cur_pr if cur_pr is not None else 1 << 30, current_name,
         headline_ratios(current_snapshot)))
    trajectory.sort(key=lambda t: t[0])

    comparisons, failures = [], []
    for (_, prev_name, prev), (_, cur_name, cur) in zip(
            trajectory, trajectory[1:]):
        shared = sorted(set(prev) & set(cur))
        deltas = []
        for label in shared:
            before, after = prev[label], cur[label]
            regressed = bool(before) and after < before * (1.0 - tolerance)
            deltas.append({
                "headline": label,
                "before": before,
                "after": after,
                "regressed": regressed,
            })
            if regressed:
                failures.append(
                    f"{prev_name} -> {cur_name}: {label} fell "
                    f"{before:.3f} -> {after:.3f} "
                    f"(tolerance {tolerance:.2f})"
                )
        comparisons.append({
            "from": prev_name,
            "to": cur_name,
            "shared_headlines": len(shared),
            "deltas": deltas,
        })
    return comparisons, failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--input", required=True, help="bench_results.jsonl path")
    ap.add_argument("--output", required=True, help="BENCH_<pr>.json path")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional block-vs-gather shortfall")
    ap.add_argument("--trace-tolerance", type=float, default=0.03,
                    help="allowed fractional traced-vs-untraced shortfall "
                         "(3%% nominal contract)")
    ap.add_argument("--gateway-tolerance", type=float, default=0.10,
                    help="allowed fractional 2-replica-vs-1-replica "
                         "gateway throughput shortfall")
    ap.add_argument("--overlap-tolerance", type=float, default=0.05,
                    help="allowed fractional overlapped-vs-serial train "
                         "throughput shortfall on data-parallel meshes")
    ap.add_argument("--supervisor-tolerance", type=float, default=0.05,
                    help="allowed fractional supervised-vs-plain train "
                         "throughput shortfall on fault-free runs")
    ap.add_argument("--history-tolerance", type=float, default=0.25,
                    help="allowed PR-over-PR drop in committed headline "
                         "ratios (block/gather, traced/untraced, "
                         "gateway, overlap/serial)")
    ap.add_argument("--history-dir", default=None,
                    help="directory of committed BENCH_<n>.json snapshots "
                         "(default: the --output directory)")
    args = ap.parse_args()

    rows = load_rows(args.input)
    block_pairs, block_failures = gate_block(rows, args.tolerance)
    trace_pairs, trace_failures = gate_tracing(rows, args.trace_tolerance)
    gateway_rows, gateway_ratio, gateway_failures = gate_gateway(
        rows, args.gateway_tolerance)
    overlap_pairs, overlap_failures = gate_overlap(
        rows, args.overlap_tolerance)
    supervisor_pairs, supervisor_failures = gate_supervisor(
        rows, args.supervisor_tolerance)

    snapshot = {
        "schema": "t5x-bench-trajectory-v1",
        "source": args.input,
        "gate": {
            "rule": "block tok/s >= gather tok/s at model degree >= 2",
            "tolerance": args.tolerance,
            "pairs": block_pairs,
            "failures": block_failures,
        },
        "trace_gate": {
            "rule": "traced tok/s >= untraced tok/s per train-step case",
            "tolerance": args.trace_tolerance,
            "pairs": trace_pairs,
            "failures": trace_failures,
        },
        "gateway": {
            "rule": "2-replica poisson tok/s >= 1-replica tok/s",
            "tolerance": args.gateway_tolerance,
            "two_over_one": gateway_ratio,
            "rows": gateway_rows,
            "failures": gateway_failures,
        },
        "overlap_gate": {
            "rule": "overlapped tok/s >= serial tok/s at data degree >= 2",
            "tolerance": args.overlap_tolerance,
            "pairs": overlap_pairs,
            "failures": overlap_failures,
        },
        "supervisor_gate": {
            "rule": "fault-free supervised tok/s >= plain trainer tok/s",
            "tolerance": args.supervisor_tolerance,
            "pairs": supervisor_pairs,
            "failures": supervisor_failures,
        },
        "phase_breakdown": [
            {k: v for k, v in r.items() if k != "group"}
            for r in rows if r.get("group") == PHASE_GROUP
        ],
        "serve_latency": [
            {k: v for k, v in r.items() if k != "group"}
            for r in rows if r.get("group") == SERVE_GROUP
        ],
        "measurements": [
            {
                "group": r.get("group"),
                "name": r.get("name"),
                "median_s": r.get("median_s"),
                "throughput_per_s": r.get("throughput_per_s"),
                "throughput_unit": r.get("throughput_unit"),
            }
            for r in rows if "median_s" in r
        ],
    }
    history_dir = args.history_dir or os.path.dirname(args.output) or "."
    comparisons, history_failures = cross_compare(
        history_dir, os.path.basename(args.output), snapshot,
        args.history_tolerance)
    snapshot["history"] = {
        "rule": "committed headline ratios must not regress PR-over-PR",
        "tolerance": args.history_tolerance,
        "comparisons": comparisons,
        "failures": history_failures,
    }

    with open(args.output, "w", encoding="utf-8") as f:
        json.dump(snapshot, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.output}: {len(rows)} rows, "
          f"{len(block_pairs)} gather-vs-block pair(s), "
          f"{len(trace_pairs)} traced-vs-untraced pair(s), "
          f"{len(gateway_rows)} gateway row(s), "
          f"{len(overlap_pairs)} overlap pair(s), "
          f"{len(supervisor_pairs)} supervisor pair(s), "
          f"{len(comparisons)} history comparison(s)")

    status = 0
    if not block_pairs:
        print("gate: FAIL — no gather-vs-block pair found in "
              f"group '{TRAIN_GROUP}' (bench_train_step did not run?)",
              file=sys.stderr)
        status = 1
    if not trace_pairs:
        print("trace gate: FAIL — no traced-vs-untraced pair found in "
              f"group '{TRAIN_GROUP}' (bench_train_step did not run?)",
              file=sys.stderr)
        status = 1
    for f_ in block_failures:
        print(f"gate: FAIL — {f_}", file=sys.stderr)
        status = 1
    for f_ in trace_failures:
        print(f"trace gate: FAIL — {f_}", file=sys.stderr)
        status = 1
    if gateway_ratio is None:
        print("gateway gate: FAIL — no 1-vs-2 replica pair found in "
              f"group '{GATEWAY_GROUP}' (bench_decode did not run?)",
              file=sys.stderr)
        status = 1
    for f_ in gateway_failures:
        print(f"gateway gate: FAIL — {f_}", file=sys.stderr)
        status = 1
    if not overlap_pairs:
        print("overlap gate: FAIL — no serial-vs-overlapped row found in "
              f"group '{OVERLAP_GROUP}' (bench_train_step did not run?)",
              file=sys.stderr)
        status = 1
    for f_ in overlap_failures:
        print(f"overlap gate: FAIL — {f_}", file=sys.stderr)
        status = 1
    if not supervisor_pairs:
        print("supervisor gate: FAIL — no plain-vs-supervised row found in "
              f"group '{SUPERVISOR_GROUP}' (bench_train_step did not run?)",
              file=sys.stderr)
        status = 1
    for f_ in supervisor_failures:
        print(f"supervisor gate: FAIL — {f_}", file=sys.stderr)
        status = 1
    for f_ in history_failures:
        print(f"history gate: FAIL — {f_}", file=sys.stderr)
        status = 1
    if status:
        return status
    for p in block_pairs:
        print(f"gate: ok — {p['model']} mesh={p['mesh']} {p['strategy']} "
              f"block/gather = {p['block_over_gather']:.3f}")
    for p in trace_pairs:
        print(f"trace gate: ok — {p['model']} mesh={p['mesh']} "
              f"{p['strategy']} {p['exec']} traced/untraced = "
              f"{p['traced_over_untraced']:.3f}")
    print(f"gateway gate: ok — 2-replica/1-replica tok/s = "
          f"{gateway_ratio:.3f}")
    for p in overlap_pairs:
        ratio = p["overlap_over_serial"]
        print(f"overlap gate: ok — {p['name']} overlap/serial = "
              + (f"{ratio:.3f}" if ratio is not None else "n/a"))
    for p in supervisor_pairs:
        ratio = p["supervised_over_plain"]
        print(f"supervisor gate: ok — {p['name']} supervised/plain = "
              + (f"{ratio:.3f}" if ratio is not None else "n/a"))
    for c in comparisons:
        print(f"history gate: ok — {c['from']} -> {c['to']}: "
              f"{c['shared_headlines']} shared headline(s), no regression")
    return 0


if __name__ == "__main__":
    sys.exit(main())
