//! # t5x-rs
//!
//! A Rust + JAX + Pallas reproduction of *"Scaling Up Models and Data with
//! t5x and seqio"* (Roberts et al., 2022).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): tiled flash
//!   attention and a fused gated-GeLU MLP, validated against pure-jnp
//!   oracles at build time.
//! * **L2** — a pure-JAX T5-style transformer (`python/compile/model.py`)
//!   lowered once by `python/compile/aot.py` to HLO text artifacts.
//! * **L3** — this crate: it loads the artifacts through PJRT ([`runtime`]),
//!   shards parameters/optimizer state over a simulated multi-host mesh
//!   ([`partitioning`], [`collectives`]), feeds data through a full seqio
//!   port ([`seqio`]), and runs the training loop ([`trainer`]) with
//!   TensorStore-style checkpointing ([`checkpoint`]) and Gin-style
//!   configuration ([`gin`]).
//!
//! Python never runs on the training path: after `make artifacts` the
//! `t5x` binary and all examples are self-contained.
//!
//! ## Sharded parameters end-to-end (§2.2)
//!
//! The `Partitioner`'s `PartitionSpec`s *drive execution*, not just the
//! cost model. On a `data × model` [`partitioning::Mesh`]
//! (`t5x train --mesh 4x2 --strategy 2d`, gin `trainer.mesh = '4x2'`):
//!
//! * each host's resident state is one spec block per parameter plus the
//!   matching optimizer block — ~`total/(data·model)` floats
//!   ([`trainer::Trainer::resident_param_floats`]); initialization is
//!   init-then-slice, so numerics match the replicated baseline
//!   (see `tests/integration_sharded.rs`);
//! * the train step itself runs in one of two
//!   [`partitioning::ExecMode`]s (`t5x train --exec-mode
//!   auto|gather|block`, gin `trainer.exec_mode`):
//!   - **Gather** materializes each full parameter on demand via a
//!     model-axis all-gather, runs the monolithic step HLO, and
//!     discards the copy — simple, but per-host peak memory is
//!     O(largest full parameter);
//!   - **Block** never materializes a full parameter. The exporter
//!     emits twelve *segment* HLOs per model-axis degree (embed /
//!     attention / MLP / vocab-parallel loss, forward and backward)
//!     plus an ordered host-side collective schedule (`block_exec` in
//!     the manifest), and the trainer feeds resident shards straight
//!     into the segments, replaying the schedule's model-axis
//!     all-reduces — the Megatron f/g points, the three loss
//!     reductions, and one fused all-reduce for replicated-parameter
//!     grads — through [`collectives::MeshCollectives`] at the exact
//!     recorded cursor positions. Gradients come out block-shaped, so
//!     per-host peak step memory drops from O(total params) to
//!     O(parameter block + activations)
//!     (`train/peak_param_floats` counts it);
//!   - **Auto** (the default) picks Block iff the manifest carries a
//!     `block_exec` contract for the mesh's model degree, so pre-block
//!     artifact dirs keep training via Gather; forcing `--exec-mode
//!     block` without the contract fails loudly.
//! * collectives run in per-axis subgroup rings
//!   ([`collectives::MeshCollectives`]): model-axis subgroups carry
//!   the schedule's activation/loss all-reduces (Block) or parameter
//!   all-gathers (Gather) plus the data row's batch broadcast, data-axis
//!   subgroups carry gradient reduce-scatter / all-reduce — with per-axis
//!   byte/op accounting surfaced in `TrainSummary`, the trainer's
//!   `CounterSet` (`train/{data,model}_axis_bytes`), its
//!   `TimingBreakdown` (`collectives/data` vs `collectives/model`), and
//!   validated against [`partitioning::cost`]'s exec-mode-aware per-axis
//!   terms by `bench_partitioning` and `tests/integration_sharded.rs`;
//! * `Trainer::params()` gathers on demand — there is no free full copy;
//! * checkpoints are *distributed*: owning hosts concurrently write
//!   disjoint `tstore` slices (chunk-aligned row writes or block grids),
//!   no host-0 gather, and restore range-reads each host's block so a
//!   `4x2` save resumes on `2x2` or `8x1` (params + elementwise optimizer
//!   state; factored Adafactor stats are topology-local) — and a
//!   gather-mode save resumes under `--exec-mode block` (both modes
//!   share the resident block layout). Eval, infer and
//!   `inspect-ckpt` reassemble full tensors through the same layout-aware
//!   readers.
//!
//! ## Overlapping communication with compute
//!
//! The train step is not a monolithic function: [`trainer::schedule`]
//! plans each step as an explicit `{Compute, Comm}` task list
//! (`plan_step(microbatches, overlap)`) and a per-host `StepRunner`
//! executes it, routing every comm-lane task onto a dedicated
//! [`collectives::CommLane`] thread (one per host, FIFO, panic ⇒
//! poisons the shared abort flag so no peer deadlocks mid-ring).
//!
//! * **Microbatched gradient accumulation** — `--microbatches k` (gin
//!   `trainer.microbatches`) splits each optimizer step into `k`
//!   forward/backward microbatches whose data-axis-reduced gradients are
//!   accumulated in strict microbatch order, so the summed gradient is
//!   *bit-identical* to the monolithic step and independent of
//!   `--overlap` (asserted by `tests/integration_sharded.rs`).
//! * **Async ring collectives** — with `--overlap` (gin
//!   `trainer.overlap`), microbatch `j`'s gradient reduce is dispatched
//!   async ([`collectives::reduce_scatter_axis_async`]) and settled
//!   under microbatch `j+1`'s forward/backward; only the time the host
//!   actually *blocks* on the lane counts as exposed. The split is
//!   surfaced as `train/exposed_comm_ms` vs `train/overlapped_comm_ms`
//!   (and `TrainSummary::{exposed,overlapped}_comm_micros`).
//! * **Double-buffered infeed** — `--infeed-depth` (gin
//!   `trainer.infeed_depth`) sizes the per-host prefetch pipe, scaled by
//!   `k` so a microbatched step never starves mid-step.
//!
//! The [`partitioning::cost`] model mirrors the schedule: `estimate_exec`
//! takes a `StepShape { microbatches, overlap }`, scales per-microbatch
//! traffic by `k`, keeps per-step terms (gather-mode parameter
//! materialization is hoisted once per step) at ×1, and moves
//! `(k-1)/k` of the gradient-sync seconds into `comm_seconds_overlapped`
//! without changing totals — validated against the measured per-axis
//! byte counters by `tests/integration_sharded.rs` and benched
//! serial-vs-overlap by `bench_train_step` (gated into
//! `benchmarks/BENCH_9.json` by `tools/bench_gate.py`, which also
//! cross-compares headline ratios across every committed snapshot).
//!
//! ## One data entry point: `seqio::get_dataset` (§3.1)
//!
//! Every data scenario resolves through
//! [`seqio::get_dataset`]`(name_or_provider, GetDatasetOptions { split,
//! task_feature_lengths, converter, shard, seed, resume, .. })`. Behind it
//! sits the [`seqio::DatasetProvider`] trait — implemented by live
//! [`seqio::task::Task`]s, weighted [`seqio::mixture::Mixture`]s, and
//! [`seqio::CachedTask`] (an offline §3.2 deterministic cache) — plus a
//! single [`seqio::ProviderRegistry`] namespace where duplicate
//! registration is an error. Caches hold *every* split of their task in
//! per-split subdirectories (`t5x cache` writes them;
//! `seqio::cache::cache_task_splits`), so `--use-cached` works for any
//! split. `get_dataset` validates the split and the task-vs-converter
//! feature declaration eagerly, audits the stream head in-stream through
//! a state-transparent passthrough op (no second pipeline); applies
//! the [`seqio::feature_converters`] registry entry for the requested
//! converter/model arch; and returns a model-ready, checkpoint-resumable
//! stream. The trainer, evaluator, and cache CLI all select data by name:
//!
//! ```text
//! t5x list-tasks                       # the registry namespace
//! t5x train --task c4_span            # or gin: train.task = 'c4_span'
//!           --split train             #         train.split = 'train'
//!           --use-cached              #         train.use_cached = True
//! t5x eval  --task reverse_words      # defaults per model arch
//! t5x cache --task c4_lm --out DIR
//! ```
//!
//! ## Checkpointable data pipelines
//!
//! Every seqio stream is a graph of stateful ops
//! ([`seqio::dataset::PipelineOp`]); `Dataset::state()` captures the whole
//! graph as a JSON [`seqio::dataset::PipelineState`] and `Dataset::restore`
//! repositions a freshly built, structurally identical pipeline. The infeed
//! snapshots each host's state at batch boundaries (pairing the state with
//! the batch so it reflects *consumed*, not prefetched, data), the trainer
//! saves all hosts' states with each checkpoint, and
//! [`checkpoint::CheckpointManager`] persists them as a CRC-protected
//! tstore byte array (`pipeline/state`: a JSON array with one entry per
//! host). A killed-and-resumed run therefore consumes the exact global
//! example sequence of an uninterrupted one — verified end-to-end by the
//! `_index` audit feature in the integration tests.
//!
//! ### Pipeline-state payload
//!
//! Each op contributes one JSON object tagged with `"op"` and nesting its
//! upstream under `"inner"`. Positional ops store counters (`pos`, `idx`,
//! `remaining`, `emitted_total`); buffering ops (`shuffle`, `flat_map`,
//! `parallel_map`, `packed_lm`) embed their buffered examples as hex of
//! the binary record encoding; RNG-bearing ops store the raw generator
//! lanes as hex strings (JSON numbers are f64 and would truncate them).
//! `prefetch` snapshots **on request only** (zero steady-state
//! serialization): `state()` asks the producer thread for the upstream
//! position and serializes the in-transit elements as `"parked"`, which
//! restore replays first — exact at every batch boundary without the old
//! per-element JSON build. Restore validates the `"op"` tag at every
//! level and fails loudly on a structurally different pipeline.
//!
//! ### `parallel_map` determinism contract
//!
//! `Dataset::parallel_map(f, n)` fans `f` out over `n` worker threads with
//! tf.data `num_parallel_calls` semantics: a single coordinator assigns
//! monotonically increasing sequence numbers to upstream elements and
//! re-sequences results, so the output order is byte-identical to serial
//! `map` regardless of worker scheduling. `f` must be pure (it may run
//! ahead of the consumer); `state()` snapshots *incrementally* — without
//! waiting for workers to drain — by serializing both mapped-but-unemitted
//! results and the still-in-flight *inputs* keyed by sequence number;
//! restore re-dispatches those inputs under their original sequence
//! numbers, so resume never recomputes, reorders, or skips an element.
//!
//! ## Inference serving ([`infer`])
//!
//! The serving stack mirrors `t5x.decoding` + `InferTask`: a pure
//! host-side decoding library (greedy / temperature / top-k / top-p
//! sampling / beam search with length penalty) and a continuous-batching
//! engine that packs independent requests into the fixed `B` batch
//! slots, retires rows at EOS, and refills freed slots from the request
//! queue mid-flight (`t5x serve` speaks JSONL over stdin/stdout, or
//! HTTP — see *Serving at scale* below).
//!
//! ### KV-cached incremental decoding (the serving hot path)
//!
//! Decoder models export two entrypoints beyond `decode_logits`:
//! `prefill(params, tokens) -> (logits, kv_cache)` scores a prompt buffer
//! once and materializes per-layer K/V tensors (`[B, H, L, head_dim]`,
//! the manifest `kv_cache` contract), and `decode_step(params, kv_cache,
//! token, pos) -> (logits, kv_cache')` extends each row's cache by one
//! position from a `[B, 1]` token input — O(L) total work per sequence
//! instead of the O(L^2) full-prefix rescore. The engine prefills a slot
//! on admission (merging only that slot's cache rows, so mid-flight
//! neighbors are untouched), rides `decode_step` thereafter, and recycles
//! a retired slot's cache rows at the next admission; the KV slot
//! lifecycle and the `--decode-mode auto|kv|rescore` selection rule
//! (auto = kv iff the manifest supports it, so pre-KV artifact dirs keep
//! serving via rescore) are documented in [`infer`]. `EvalRunner`'s
//! greedy decode rides the same entrypoints; beam search stays on the
//! rescore substrate (beams fork/reorder prefixes).
//!
//! ### Inference determinism contract
//!
//! * Greedy ties break toward the lowest token id everywhere
//!   ([`infer::decoding::argmax`] is shared by the engine and
//!   `EvalRunner::greedy_decode`), and per-row decode outputs do not
//!   depend on other rows (in either decode mode) — so a request's
//!   greedy output is byte-identical whether it ran alone or packed with
//!   arbitrary neighbors (asserted by `tests/integration_infer.rs`).
//! * Kv and Rescore modes share one scheduling contract (admissions, one
//!   token per active slot per step, retirement timing) by construction,
//!   and the incremental entrypoints are golden-checked against full
//!   rescoring at export time (the exporter fails on drift; the residual
//!   kernel-lowering gap sits far below typical argmax margins) — per-
//!   slot outputs match between modes byte-for-byte, including under
//!   mid-flight refills and seeded sampling, as asserted by
//!   `tests/integration_infer.rs`.
//! * Sampling is seeded per request and draws exactly one RNG value per
//!   emitted token, so (prompt, seed) fully determines the continuation
//!   regardless of batch packing or scheduler interleaving.
//! * Beam search orders candidates and final hypotheses with total,
//!   deterministic tie-breaks and is golden-tested against a brute-force
//!   exhaustive reference.
//!
//! ## Serving at scale ([`serve`])
//!
//! `t5x serve` is fronted by a production-style gateway: one bounded,
//! priority-ordered **admission queue** feeding N **engine replicas**
//! (`--replicas N`; [`infer::InferEngine::replica`] clones share the
//! compiled executables and Arc-backed weights, each replica owns
//! private slots/KV cache and steps on its own thread), with an
//! optional stdlib-only **HTTP/1.1 front end** (`--http-port`). Both
//! transports — HTTP and the JSONL stdin loop — submit through the same
//! [`serve::Gateway`], so scheduling, shedding, and metrics live in one
//! place.
//!
//! **Request lifecycle:** submit → validate (HTTP `400` on bad
//! requests) → admission queue (bounded `--queue-depth`; full ⇒ `429` +
//! `Retry-After`, and past `--shed-watermark` all `priority <= 0` work
//! is shed early with `429` while urgent work still gets in) → a
//! replica with free slots pulls it (least-loaded by construction: each
//! replica pulls at most its free-slot count) → continuous-batching
//! decode → outcome routed back to the submitter. A request whose
//! `deadline_ms` expires while queued is shed *before* occupying a slot
//! (`serve/shed_deadline`, HTTP `504`); once dispatched it always runs
//! to completion. Replica routing never changes tokens: per-row decode
//! is independent of batch neighbors and replicas share weights, so
//! outputs are byte-identical to a solo engine run
//! (`tests/integration_serve.rs`).
//!
//! **Graceful shutdown:** SIGINT or `POST /admin/drain` stops
//! admission, lets replicas finish queued + in-flight requests, flushes
//! trace/metrics files, and prints per-replica summaries.
//!
//! Quickstart:
//!
//! ```text
//! t5x serve --model t5-nano-dec --replicas 2 --http-port 8077 \
//!           --queue-depth 32 --shed-watermark 24
//! curl -s localhost:8077/v1/generate -d \
//!   '{"prompt": [5, 9, 11], "max_tokens": 8, "priority": 1, "deadline_ms": 500}'
//! # => {"id": ..., "tokens": [...], "text": "...", "steps": 8,
//! #     "replica": 0, "queue_ms": 0.2, "ttft_ms": 1.9, "latency_ms": 14.8}
//! curl -s localhost:8077/metrics   # counters + p50/p95/p99 + per-replica
//! curl -s localhost:8077/healthz
//! curl -s -X POST localhost:8077/admin/drain
//! ```
//!
//! ## Fault tolerance ([`faults`], [`trainer::supervisor`])
//!
//! The paper's operational pitch is that big runs are *survivable*:
//! frequent checkpoints plus a deterministic, resumable data pipeline
//! mean a preempted job restarts bit-identically (§2, §3). This crate
//! closes the loop with a recovery layer that is itself testable, via
//! deterministic fault injection ([`faults`]: a JSON `FaultPlan` armed
//! with `--fault-plan`, keyed by host/step/batch/request, every fault
//! one-shot, every hook a single relaxed atomic load when disarmed).
//!
//! **Failure taxonomy → recovery path:**
//!
//! | failure                        | detected by                       | recovery                                            |
//! |--------------------------------|-----------------------------------|-----------------------------------------------------|
//! | host panic mid-step            | `catch_unwind` in `Trainer::train`| supervisor restores latest checkpoint, relaunches   |
//! | wedged collective peer         | ring-op deadline (`collectives::set_comm_deadline_ms`) trips the shared abort flag, naming point/axis/rank | failed step → supervisor restart |
//! | corrupt checkpoint shard (CRC) | `restore_latest` CRC mismatch     | quarantine dir as `ckpt-<n>.corrupt`, walk back to the previous retained step |
//! | partial checkpoint (`*.tmp`)   | invisible to `steps()`; swept by `sweep_tmp` on restore | previous committed step restores |
//! | transient infeed source error  | producer `catch_unwind`           | bounded in-place retries (`train/infeed_retries`) before tripping `Infeed::failed` |
//! | serving replica panic          | `catch_unwind` around the replica loop | in-flight requests fail with `ServeOutcome::Failed` (HTTP 500), queued work reroutes to survivors, `/healthz` reports `degraded` |
//!
//! **Supervisor state machine** ([`trainer::supervisor::Supervisor`]):
//!
//! ```text
//!           ┌────────────────────────────────────────────────┐
//!           ▼                                                │ attempt < max_restarts:
//!   RUN (Trainer::train) ──ok──▶ DONE                        │ backoff · 2^(attempt-1)
//!           │failed (panic / abort / deadline)               │
//!           ▼                                                │
//!   RESTORE (restore_latest: sweep *.tmp, walk back past     │
//!            corrupt steps, quarantining each) ──────────────┘
//!           │no valid checkpoint, or restarts exhausted
//!           ▼
//!          FAIL (error propagates with restart history)
//! ```
//!
//! Every attempt rebuilds the `Trainer` from the artifacts (the shared
//! abort flag is poisoned by design after a failure) and re-targets the
//! *original* end step, so the supervised run consumes exactly the
//! fault-free step sequence; `tests/integration_faults.rs` proves final
//! params and the consumed `_index` sequence are bit-identical to an
//! unfaulted run. Counters: `train/restarts`, `train/quarantined_ckpts`,
//! `train/recovery_ms`. The serving side mirrors it per replica
//! ([`serve::router::Gateway`] marks dead replicas unhealthy and keeps
//! serving at N−1). Fault-free supervised throughput is gated against
//! the unsupervised line by `tools/bench_gate.py` (`supervisor` gate).
//!
//! ## Observability ([`obs`], re-exported through [`metrics`])
//!
//! The paper's operational claims ("prevent bottlenecks when infeeding
//! data", scalable distributed execution) are only checkable if the
//! system can show where the time goes. [`obs::Tracer`] records RAII
//! spans (`span!(tracer, "name", { "k" => v })`) into per-thread buffers
//! and exports Chrome trace-event JSON, loadable in Perfetto /
//! `chrome://tracing`; [`obs::Histogram`] adds fixed log-bucket latency
//! histograms (p50/p95/p99) and [`obs::GaugeSet`] last-write-wins gauges,
//! both flushing through [`metrics::MetricsLogger`].
//!
//! **Span taxonomy** (the `trace-summary` verdict keys off these
//! prefixes):
//!
//! * `train/step`, `train/infeed`, `train/broadcast_batch`,
//!   `train/grad_sync`, `train/grad_clip`, `train/optimizer`,
//!   `train/execute` (gather-mode step HLO) — per-host trainer phases;
//! * `seg/<name>` — one span per block-mode segment HLO invocation;
//! * `coll/<point>` — one span per manifest `CollectiveStep` replayed in
//!   block mode, annotated with `axis`/`op`/`bytes`; generic
//!   `coll/all_reduce|reduce_scatter|all_gather|broadcast` spans wrap
//!   every multi-rank ring op with `elems`/`bytes`;
//! * `infeed/batch` — per-batch producer-thread spans on `infeed-<host>`
//!   tracks, plus the `train/infeed_starved_steps` counter whenever the
//!   consumer blocks on an empty pipe;
//! * `checkpoint/save`, `checkpoint/restore`;
//! * `serve/prefill`, `serve/decode_step`, `serve/rescore_step` — engine
//!   batch steps; per-request `req <id> queued` / `req <id>` spans land on
//!   `serve/queue` and `serve/slot<i>` virtual tracks, and
//!   `serve/queue_depth` / `serve/active_slots` counter samples chart
//!   occupancy. Under the gateway each replica's engine tracks are
//!   namespaced `serve/replica<i>/...` and its thread track carries
//!   `serve/replica<i>/step` spans, so an N-replica trace shows every
//!   replica's timeline side by side.
//!
//! **Overhead contract:** tracing off (the default, or outside the
//! `--profile-steps N..M` window) ⇒ a span is one relaxed atomic load —
//! no allocation, no clock read, no lock on the hot path; tracing on ⇒
//! two clock reads plus a push onto an uncontended per-thread buffer
//! (bounded ≤3% step-time overhead, gated by `tools/bench_gate.py` into
//! `benchmarks/BENCH_7.json`). Surface: `--trace-out <path>` (+ gin
//! `trainer.trace_out` / `serve.trace_out`) on `t5x train`/`infer`/
//! `serve`, step-aligned `train/phase_*_ms` percentiles in the JSONL
//! metrics, and `t5x trace-summary <trace.json>` for top-k self-time
//! spans with an infeed-bound vs compute-bound vs comm-bound verdict.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper claim to a bench/example, and `EXPERIMENTS.md` for
//! measured results.

pub mod bench;
pub mod checkpoint;
pub mod collectives;
pub mod faults;
pub mod gin;
pub mod infer;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod optim;
pub mod partitioning;
pub mod runtime;
pub mod seqio;
pub mod serve;
pub mod testing;
pub mod trainer;
pub mod util;
