//! Integration: Rust PJRT runtime vs JAX goldens (the E1/E4 numerics gate).
//!
//! `aot.py` computed loss + grad norms with pattern-init params on a
//! deterministic batch; Rust rebuilds both bit-identically (splitmix64
//! pattern init) and must reproduce the numbers through the compiled HLO.

use t5x::model::golden::{golden_batch, load_golden};
use t5x::model::{params_in_order, pattern_params};
use t5x::runtime::{Artifacts, DeviceHandle, HostTensor};

fn check_model_golden(model: &str) {
    let arts = Artifacts::load_default().expect("run `make artifacts` first");
    let m = arts.model(model).unwrap();
    let golden = load_golden(&arts.dir, model).unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let (exe, _) = device.compile(&m.entrypoint("train_step").unwrap().hlo).unwrap();

    let params = pattern_params(m, 0);
    let mut inputs = params_in_order(m, &params);
    inputs.extend(golden_batch(m));
    let outs = exe.run(inputs).unwrap();

    let loss_sum = outs[0].first_f32() as f64;
    let weight_sum = outs[1].first_f32() as f64;
    let correct_sum = outs[2].first_f32() as f64;
    assert!(
        (loss_sum - golden.loss_sum).abs() / golden.loss_sum < 1e-4,
        "{model} loss_sum: rust {loss_sum} vs jax {}",
        golden.loss_sum
    );
    assert_eq!(weight_sum, golden.weight_sum, "{model} weight_sum");
    assert_eq!(correct_sum, golden.correct_sum, "{model} correct_sum");

    // per-parameter gradient norms
    for (i, (name, expect)) in golden.grad_norms.iter().enumerate() {
        let got = outs[3 + i].norm();
        assert_eq!(name, &m.params[i].name, "grad order mismatch at {i}");
        let tol = (1e-3 * expect.abs()).max(1e-3);
        assert!(
            (got - expect).abs() < tol,
            "{model} grad norm {name}: rust {got} vs jax {expect}"
        );
    }
    device.shutdown();
}

#[test]
fn golden_decoder_model_matches_jax() {
    check_model_golden("t5-nano-dec");
}

#[test]
fn golden_encdec_model_matches_jax() {
    check_model_golden("t5-nano-encdec");
}

/// Megatron-style tensor parallelism (E3): a column/row-sharded FFN across
/// k simulated model-parallel hosts, partial products all-reduced, must
/// equal the unsharded computation.
#[test]
fn megatron_ffn_sharding_matches_full() {
    use t5x::collectives::{run_ranks, CollectiveGroup};
    use t5x::util::rng::Pcg64;

    let arts = Artifacts::load_default().unwrap();
    let pd = arts.partdemo.as_ref().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let (full_exe, _) = device.compile(&pd.hlos["ffn_full"]).unwrap();

    let mut rng = Pcg64::new(123);
    let x: Vec<f32> = (0..pd.m * pd.k).map(|_| rng.next_f32() - 0.5).collect();
    let w1: Vec<f32> = (0..pd.k * pd.f).map(|_| (rng.next_f32() - 0.5) * 0.1).collect();
    let w2: Vec<f32> = (0..pd.f * pd.k).map(|_| (rng.next_f32() - 0.5) * 0.1).collect();
    let xt = HostTensor::f32(vec![pd.m, pd.k], x);
    let w1t = HostTensor::f32(vec![pd.k, pd.f], w1);
    let w2t = HostTensor::f32(vec![pd.f, pd.k], w2);

    let full_out =
        full_exe.run(vec![xt.clone(), w1t.clone(), w2t.clone()]).unwrap()[0].clone();

    for shards in [2usize, 4] {
        let (shard_exe, _) =
            device.compile(&pd.hlos[&format!("ffn_shard{shards}")]).unwrap();
        let fs = pd.f / shards;
        let group = CollectiveGroup::new(shards);
        let outs = run_ranks(shards, |r| {
            // column-parallel w1 shard, row-parallel w2 shard
            let w1_shard = w1t.slice_axis(1, r * fs, fs);
            let w2_shard = w2t.slice_axis(0, r * fs, fs);
            let partial = shard_exe
                .run(vec![xt.clone(), w1_shard, w2_shard])
                .unwrap()[0]
                .clone();
            group.all_reduce(r, partial.as_f32().to_vec())
        });
        for (r, out) in outs.iter().enumerate() {
            for (a, b) in out.iter().zip(full_out.as_f32()) {
                assert!(
                    (a - b).abs() < 1e-4,
                    "shards={shards} rank={r}: {a} vs {b}"
                );
            }
        }
    }
    device.shutdown();
}

/// The eval_step HLO agrees with train_step's loss terms (same params,
/// same batch, no grads).
#[test]
fn eval_step_consistent_with_train_step() {
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let (train_exe, _) = device.compile(&m.entrypoint("train_step").unwrap().hlo).unwrap();
    let (eval_exe, _) = device.compile(&m.entrypoint("eval_step").unwrap().hlo).unwrap();
    let params = pattern_params(m, 0);
    let mut inputs = params_in_order(m, &params);
    inputs.extend(golden_batch(m));
    let t_out = train_exe.run(inputs.clone()).unwrap();
    let e_out = eval_exe.run(inputs).unwrap();
    assert_eq!(e_out.len(), 3);
    for i in 0..3 {
        assert!((t_out[i].first_f32() - e_out[i].first_f32()).abs() < 1e-3);
    }
    device.shutdown();
}

/// All exported models compile and execute a train step (coverage of the
/// full registry, incl. the scan/unroll bench HLOs loading).
#[test]
fn all_bench_hlos_parse_and_compile() {
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    for name in ["scan_L2", "unroll_L2"] {
        let (exe, dt) = device.compile(&arts.bench[name]).unwrap();
        assert!(dt.as_secs_f64() > 0.0, "{name}");
        exe.release();
    }
    device.shutdown();
}
