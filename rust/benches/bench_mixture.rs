//! E10: mixture sampling — throughput of the weighted task interleave and
//! fidelity of the realized mixing rates (§3.1 Mixtures).

use std::sync::Arc;

use t5x::bench::Bench;
use t5x::seqio::dataset::Dataset;
use t5x::seqio::mixture::Mixture;
use t5x::seqio::source::FunctionSource;
use t5x::seqio::task::Task;
use t5x::seqio::vocab::{ByteVocabulary, Vocabulary};
use t5x::seqio::ints_example;

fn const_task(name: &str, value: i32, count: usize) -> Arc<Task> {
    let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(4));
    Task::builder(name)
        .source(Arc::new(FunctionSource::new(move |shard, num| {
            Dataset::new(
                (0..count)
                    .filter(move |i| i % num == shard)
                    .map(move |_| ints_example(&[("targets", vec![value; 32])])),
            )
        })))
        .output_feature("targets", vocab, false)
        .build()
}

fn main() {
    let mut bench = Bench::new("mixture (E10)");
    let draw = if bench.is_quick() { 5_000 } else { 100_000 };

    for num_tasks in [2usize, 8, 32] {
        let tasks: Vec<(Arc<Task>, f64)> = (0..num_tasks)
            .map(|i| {
                (
                    const_task(&format!("bench_mix_{num_tasks}_{i}"), i as i32, draw),
                    (i + 1) as f64,
                )
            })
            .collect();
        let mixture = Mixture::new("bench_mix", tasks).unwrap();
        let rates = mixture.rates();
        bench.measure_with_throughput(
            &format!("sample {num_tasks}-task mixture"),
            Some((draw as f64, "ex")),
            || {
                let got = mixture.dataset(7, 0, 1).take(draw).collect_vec();
                std::hint::black_box(&got);
            },
        );
        // rate fidelity at the measured sample size
        let sample = mixture.dataset(7, 0, 1).take(draw).collect_vec();
        let mut counts = vec![0usize; num_tasks];
        for ex in &sample {
            counts[ex["targets"].as_ints().unwrap()[0] as usize] += 1;
        }
        for (i, (&c, &r)) in counts.iter().zip(&rates).enumerate() {
            let emp = c as f64 / sample.len() as f64;
            assert!(
                (emp - r).abs() < 0.03 + r * 0.2,
                "task {i}: empirical {emp:.3} vs requested {r:.3}"
            );
        }
        println!(
            "  rate fidelity ok: max |emp-req| = {:.4}",
            counts
                .iter()
                .zip(&rates)
                .map(|(&c, &r)| (c as f64 / sample.len() as f64 - r).abs())
                .fold(0.0, f64::max)
        );
    }
    bench.write_jsonl("bench_results.jsonl").unwrap();
}
