//! Golden cross-checks: the deterministic batch + expected loss/grad norms
//! exported by `aot.py` (`artifacts/golden.json`). The Rust runtime must
//! reproduce the JAX numbers bit-for-bit-ish (f32 tolerance) — the
//! strongest end-to-end signal that HLO loading, input ordering and
//! parameter construction are all correct.

use crate::runtime::artifacts::ModelManifest;
use crate::runtime::HostTensor;
use crate::util::json::Json;

/// The deterministic golden batch (mirrors `aot.golden_batch`).
pub fn golden_batch(m: &ModelManifest) -> Vec<HostTensor> {
    let b = m.batch();
    let l = m.seq_len();
    let v = m.vocab();
    let tgt: Vec<i32> = (0..b * l)
        .map(|idx| {
            let (i, j) = (idx / l, idx % l);
            ((i * 7919 + j * 104_729 + 13) % (v - 2) + 2) as i32
        })
        .collect();
    let mut dec_in = vec![0i32; b * l];
    for i in 0..b {
        for j in 1..l {
            dec_in[i * l + j] = tgt[i * l + j - 1];
        }
    }
    let mut weights = vec![1.0f32; b * l];
    for j in (l - 4)..l {
        weights[j] = 0.0; // row 0, last 4 positions
    }
    let mut out = Vec::new();
    if m.arch == "encdec" {
        let enc: Vec<i32> = (0..b * l)
            .map(|idx| {
                let (i, j) = (idx / l, idx % l);
                ((i * 6101 + j * 3571 + 29) % (v - 2) + 2) as i32
            })
            .collect();
        out.push(HostTensor::i32(vec![b, l], enc));
    }
    out.push(HostTensor::i32(vec![b, l], dec_in));
    out.push(HostTensor::i32(vec![b, l], tgt));
    out.push(HostTensor::f32(vec![b, l], weights));
    out
}

/// Expected values parsed from golden.json.
#[derive(Debug, Clone)]
pub struct Golden {
    pub loss_sum: f64,
    pub weight_sum: f64,
    pub correct_sum: f64,
    pub grad_norms: Vec<(String, f64)>,
}

pub fn load_golden(dir: &std::path::Path, model: &str) -> anyhow::Result<Golden> {
    let j = Json::parse_file(dir.join("golden.json"))?;
    let g = j
        .get(model)
        .ok_or_else(|| anyhow::anyhow!("no golden entry for {model}"))?;
    let grad_norms = g
        .get("grad_norms")
        .and_then(|v| v.as_obj())
        .map(|m| {
            m.iter()
                .map(|(k, v)| (k.clone(), v.as_f64().unwrap_or(0.0)))
                .collect()
        })
        .unwrap_or_default();
    Ok(Golden {
        loss_sum: g.get("loss_sum").and_then(|v| v.as_f64()).unwrap_or(0.0),
        weight_sum: g.get("weight_sum").and_then(|v| v.as_f64()).unwrap_or(0.0),
        correct_sum: g.get("correct_sum").and_then(|v| v.as_f64()).unwrap_or(0.0),
        grad_norms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;

    #[test]
    fn golden_batch_shape_and_mask() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let batch = golden_batch(m);
        assert_eq!(batch.len(), 3);
        let w = batch[2].as_f32();
        let l = m.seq_len();
        assert_eq!(w.iter().filter(|&&x| x == 0.0).count(), 4);
        assert_eq!(w[l - 1], 0.0);
        assert_eq!(w[l], 1.0); // row 1 all ones
        // shift property: dec_in[i, j] == tgt[i, j-1]
        let dec_in = batch[0].as_i32();
        let tgt = batch[1].as_i32();
        assert_eq!(dec_in[1], tgt[0]);
        assert_eq!(dec_in[0], 0);
    }

    #[test]
    fn golden_json_parses() {
        let arts = Artifacts::load_default().unwrap();
        let g = load_golden(&arts.dir, "t5-nano-dec").unwrap();
        assert!(g.loss_sum > 100.0);
        assert_eq!(g.weight_sum, 252.0);
        assert!(!g.grad_norms.is_empty());
    }
}
