//! Artifact manifest: the contract between `python/compile/aot.py` (L2/L1)
//! and the Rust coordinator. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One model parameter: name, shape, logical axes (t5x `param_with_axes`),
/// and an init spec ("normal:<stddev>" or "const:<value>").
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub logical_axes: Vec<String>,
    pub init: String,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One batch feature expected by the entrypoints.
#[derive(Debug, Clone)]
pub struct FeatureSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub is_int: bool,
}

/// One exported HLO computation.
#[derive(Debug, Clone)]
pub struct Entrypoint {
    pub hlo: PathBuf,
    pub outputs: Vec<String>,
}

/// The KV-cache contract exported next to `prefill`/`decode_step`
/// (decoder-only models): per-layer k/v tensors of `shape`
/// (`[batch, heads, seq, head_dim]`, f32, batch-major so one request's
/// cache rows are contiguous — the engine recycles them on slot refill).
#[derive(Debug, Clone)]
pub struct KvCacheSpec {
    /// Axis names, e.g. ["batch", "heads", "seq", "head_dim"].
    pub layout: Vec<String>,
    pub shape: Vec<usize>,
    pub num_layers: usize,
    /// Tensors per layer in entrypoint order, e.g. ["k", "v"].
    pub per_layer: Vec<String>,
}

impl KvCacheSpec {
    /// Number of cache tensors flowing through the entrypoints.
    pub fn num_tensors(&self) -> usize {
        self.num_layers * self.per_layer.len()
    }

    /// Elements per cache tensor.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Elements of one batch row of one cache tensor (batch-major layout).
    pub fn row_elements(&self) -> usize {
        self.shape[1..].iter().product()
    }
}

/// One parameter's model-axis block in the block-execution contract:
/// shape of the `[.., dim/n, ..]` block a shard holds and feeds straight
/// into the block train step.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockParamSpec {
    pub name: String,
    pub block_shape: Vec<usize>,
    /// The model-sharded dimension; `None` for model-replicated params
    /// (the norm scales), whose grads ride the fused trailing all-reduce.
    pub model_dim: Option<usize>,
}

impl BlockParamSpec {
    pub fn elements(&self) -> usize {
        self.block_shape.iter().product()
    }
}

/// One host-inserted model-axis collective in the ordered block schedule
/// (a Megatron f/g point surfaced as a host callback between segments).
#[derive(Debug, Clone, PartialEq)]
pub struct CollectiveStep {
    /// Schedule point label, e.g. "layer_0.attn_out", "logits_max".
    pub point: String,
    /// "all_reduce_sum" | "all_reduce_max" | "all_reduce_min".
    pub op: String,
    /// f32 payload elements (bytes = elems * 4).
    pub elems: usize,
}

/// The per-degree block-execution contract (§2.2): segment HLOs, per-param
/// block shapes, and the ordered collective schedule the trainer replays
/// between segment executions.
#[derive(Debug, Clone)]
pub struct BlockExecDegree {
    pub degree: usize,
    pub params: Vec<BlockParamSpec>,
    /// Segment name -> HLO path (the 12 block-step segments; per-layer
    /// segments share one HLO since layer weights are inputs).
    pub segments: BTreeMap<String, PathBuf>,
    pub collectives: Vec<CollectiveStep>,
    /// Model-replicated param names (manifest order) summed in the fused
    /// `replicated_grads` all-reduce at schedule end.
    pub replicated_grads: Vec<String>,
}

impl BlockExecDegree {
    pub fn param(&self, name: &str) -> Option<&BlockParamSpec> {
        self.params.iter().find(|p| p.name == name)
    }

    /// Model-axis payload bytes of one full step's collective schedule
    /// (per participating host pair-wise ring; see cost model).
    pub fn schedule_elems(&self) -> usize {
        self.collectives.iter().map(|c| c.elems).sum()
    }
}

/// Everything the coordinator knows about one exported model.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub arch: String,
    pub config: BTreeMap<String, f64>,
    pub params: Vec<ParamSpec>,
    pub batch_features: Vec<FeatureSpec>,
    pub entrypoints: BTreeMap<String, Entrypoint>,
    /// KV-cache contract, present when `prefill`/`decode_step` exist.
    /// Older artifact dirs (exported before the incremental-decode
    /// entrypoints) simply lack it and serve via full rescoring.
    pub kv_cache: Option<KvCacheSpec>,
    /// Block-execution contracts by model-axis degree. Empty for pre-block
    /// artifact dirs (which keep training via `ExecMode::Gather`).
    pub block_exec: BTreeMap<usize, BlockExecDegree>,
}

impl ModelManifest {
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.elements()).sum()
    }

    pub fn param(&self, name: &str) -> Option<&ParamSpec> {
        self.params.iter().find(|p| p.name == name)
    }

    pub fn entrypoint(&self, name: &str) -> anyhow::Result<&Entrypoint> {
        self.entrypoints
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {} has no entrypoint {name}", self.name))
    }

    pub fn cfg_usize(&self, key: &str) -> usize {
        *self.config.get(key).unwrap_or(&0.0) as usize
    }

    /// Per-host batch size baked into the HLO.
    pub fn batch(&self) -> usize {
        self.cfg_usize("batch")
    }

    pub fn seq_len(&self) -> usize {
        self.cfg_usize("seq_len")
    }

    pub fn vocab(&self) -> usize {
        self.cfg_usize("vocab")
    }

    /// Tokens contributing to a train step on one host.
    pub fn tokens_per_step(&self) -> usize {
        self.batch() * self.seq_len()
    }

    /// True when this artifact dir carries the O(L) incremental-decode
    /// capability: `prefill` + `decode_step` entrypoints plus the
    /// `kv_cache` contract. Drives the serving stack's auto mode
    /// selection; stale dirs fall back to `decode_logits` rescoring.
    pub fn supports_kv_decode(&self) -> bool {
        self.kv_cache.is_some()
            && self.entrypoints.contains_key("prefill")
            && self.entrypoints.contains_key("decode_step")
    }

    /// True when this artifact dir carries a block-execution contract for
    /// the given model-axis degree. Drives `ExecMode::Auto`: supported →
    /// block execution, stale/absent → gather fallback.
    pub fn supports_block_exec(&self, degree: usize) -> bool {
        self.block_exec
            .get(&degree)
            .is_some_and(|b| !b.segments.is_empty())
    }

    pub fn block_exec(&self, degree: usize) -> Option<&BlockExecDegree> {
        self.block_exec.get(&degree)
    }
}

/// The parsed manifest + artifact directory.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
    /// Compile-bench HLOs (scan vs unroll), name -> path.
    pub bench: BTreeMap<String, PathBuf>,
    /// Partitioning-demo HLOs + dims.
    pub partdemo: Option<PartDemo>,
}

#[derive(Debug, Clone)]
pub struct PartDemo {
    pub m: usize,
    pub k: usize,
    pub f: usize,
    pub hlos: BTreeMap<String, PathBuf>,
}

impl Artifacts {
    /// Default location: `$T5X_ARTIFACTS` or `artifacts/` under the cwd /
    /// the cargo manifest dir (so tests work from any directory).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("T5X_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let cwd = PathBuf::from("artifacts");
        if cwd.join("manifest.json").exists() {
            return cwd;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn load_default() -> anyhow::Result<Artifacts> {
        Self::load(Self::default_dir())
    }

    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Json::parse_file(dir.join("manifest.json"))?;
        let mut models = BTreeMap::new();
        if let Some(Json::Obj(m)) = manifest.get("models") {
            for (name, jm) in m {
                models.insert(name.clone(), parse_model(name, jm, &dir)?);
            }
        }
        let mut bench = BTreeMap::new();
        if let Some(Json::Obj(b)) = manifest.get("bench") {
            for (name, path) in b {
                if let Some(p) = path.as_str() {
                    bench.insert(name.clone(), dir.join(p));
                }
            }
        }
        let partdemo = manifest.get("partdemo").map(|pd| {
            let mut hlos = BTreeMap::new();
            if let Some(Json::Obj(h)) = pd.get("hlos") {
                for (name, path) in h {
                    if let Some(p) = path.as_str() {
                        hlos.insert(name.clone(), dir.join(p));
                    }
                }
            }
            PartDemo {
                m: pd.get("m").and_then(|v| v.as_usize()).unwrap_or(0),
                k: pd.get("k").and_then(|v| v.as_usize()).unwrap_or(0),
                f: pd.get("f").and_then(|v| v.as_usize()).unwrap_or(0),
                hlos,
            }
        });
        Ok(Artifacts { dir, models, bench, partdemo })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))
    }
}

fn parse_model(name: &str, j: &Json, dir: &Path) -> anyhow::Result<ModelManifest> {
    let arch = j.get("arch").and_then(|v| v.as_str()).unwrap_or("decoder").to_string();
    let mut config = BTreeMap::new();
    if let Some(Json::Obj(c)) = j.get("config") {
        for (k, v) in c {
            if let Some(n) = v.as_f64() {
                config.insert(k.clone(), n);
            }
        }
    }
    let mut params = Vec::new();
    for p in j.get("params").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        params.push(ParamSpec {
            name: p.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            shape: p
                .get("shape")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            logical_axes: p
                .get("logical_axes")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(|s| s.to_string()))
                        .collect()
                })
                .unwrap_or_default(),
            init: p.get("init").and_then(|v| v.as_str()).unwrap_or("const:0").to_string(),
        });
    }
    let mut batch_features = Vec::new();
    for f in j.get("batch_features").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        batch_features.push(FeatureSpec {
            name: f.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            shape: f
                .get("shape")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            is_int: f.get("dtype").and_then(|v| v.as_str()) == Some("i32"),
        });
    }
    let mut entrypoints = BTreeMap::new();
    if let Some(Json::Obj(eps)) = j.get("entrypoints") {
        for (ep_name, ep) in eps {
            entrypoints.insert(
                ep_name.clone(),
                Entrypoint {
                    hlo: dir.join(ep.get("hlo").and_then(|v| v.as_str()).unwrap_or("")),
                    outputs: ep
                        .get("outputs")
                        .and_then(|v| v.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|x| x.as_str().map(|s| s.to_string()))
                                .collect()
                        })
                        .unwrap_or_default(),
                },
            );
        }
    }
    let kv_cache = j.get("kv_cache").map(|kv| {
        let strings = |key: &str| -> Vec<String> {
            kv.get(key)
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(|s| s.to_string()))
                        .collect()
                })
                .unwrap_or_default()
        };
        KvCacheSpec {
            layout: strings("layout"),
            shape: kv
                .get("shape")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            num_layers: kv.get("num_layers").and_then(|v| v.as_usize()).unwrap_or(0),
            per_layer: strings("per_layer"),
        }
    });
    let mut block_exec = BTreeMap::new();
    if let Some(Json::Obj(degrees)) = j.get("block_exec").and_then(|b| b.get("degrees")) {
        for (deg_str, jd) in degrees {
            let degree: usize = match deg_str.parse() {
                Ok(d) => d,
                Err(_) => continue,
            };
            let mut bparams = Vec::new();
            for p in jd.get("params").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                bparams.push(BlockParamSpec {
                    name: p.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                    block_shape: p
                        .get("block_shape")
                        .and_then(|v| v.as_arr())
                        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default(),
                    model_dim: p.get("model_dim").and_then(|v| v.as_usize()),
                });
            }
            let mut segments = BTreeMap::new();
            if let Some(Json::Obj(segs)) = jd.get("segments") {
                for (seg_name, seg) in segs {
                    if let Some(p) = seg.get("hlo").and_then(|v| v.as_str()) {
                        segments.insert(seg_name.clone(), dir.join(p));
                    }
                }
            }
            let mut collectives = Vec::new();
            for c in jd.get("collectives").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                collectives.push(CollectiveStep {
                    point: c.get("point").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                    op: c.get("op").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                    elems: c.get("elems").and_then(|v| v.as_usize()).unwrap_or(0),
                });
            }
            let replicated_grads = jd
                .get("replicated_grads")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(|s| s.to_string()))
                        .collect()
                })
                .unwrap_or_default();
            block_exec.insert(
                degree,
                BlockExecDegree {
                    degree,
                    params: bparams,
                    segments,
                    collectives,
                    replicated_grads,
                },
            );
        }
    }
    Ok(ModelManifest {
        name: name.to_string(),
        arch,
        config,
        params,
        batch_features,
        entrypoints,
        kv_cache,
        block_exec,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest() {
        let a = Artifacts::load_default().expect("run `make artifacts` first");
        let m = a.model("t5-nano-dec").unwrap();
        assert_eq!(m.arch, "decoder");
        assert!(m.total_params() > 100_000);
        assert!(m.entrypoint("train_step").is_ok());
        assert!(m.entrypoint("eval_step").is_ok());
        assert!(m.entrypoint("decode_logits").is_ok());
        // params sorted by name, embed present with vocab axis
        let emb = m.param("token_embed").unwrap();
        assert_eq!(emb.logical_axes, vec!["vocab", "embed"]);
        assert_eq!(emb.shape, vec![m.vocab(), 64]);
        // train outputs: 3 scalars + one grad per param
        let ep = m.entrypoint("train_step").unwrap();
        assert_eq!(ep.outputs.len(), 3 + m.params.len());
        assert!(ep.hlo.exists());
        // bench + partdemo artifacts present
        assert!(a.bench.contains_key("scan_L4"));
        assert!(a.partdemo.as_ref().unwrap().hlos.contains_key("ffn_full"));
    }

    #[test]
    fn decoder_manifests_carry_kv_decode_contract() {
        let a = Artifacts::load_default().unwrap();
        let m = a.model("t5-nano-dec").unwrap();
        assert!(m.supports_kv_decode(), "re-export artifacts (make artifacts)");
        let kv = m.kv_cache.as_ref().unwrap();
        assert_eq!(
            kv.shape,
            vec![
                m.batch(),
                m.cfg_usize("num_heads"),
                m.seq_len(),
                m.cfg_usize("head_dim")
            ]
        );
        assert_eq!(kv.num_layers, m.cfg_usize("num_layers"));
        assert_eq!(kv.per_layer, vec!["k", "v"]);
        assert_eq!(kv.row_elements() * m.batch(), kv.elements());
        // one output per cache tensor plus the logits
        let pf = m.entrypoint("prefill").unwrap();
        assert_eq!(pf.outputs.len(), 1 + kv.num_tensors());
        assert!(pf.hlo.exists());
        let ds = m.entrypoint("decode_step").unwrap();
        assert_eq!(ds.outputs.len(), 1 + kv.num_tensors());
        assert!(ds.hlo.exists());
        // encdec models serve via rescoring only
        let ed = a.model("t5-nano-encdec").unwrap();
        assert!(!ed.supports_kv_decode());
        assert!(ed.kv_cache.is_none());
    }

    #[test]
    fn block_exec_contract_parsed() {
        let a = Artifacts::load_default().unwrap();
        let m = a.model("t5-nano-dec").unwrap();
        assert!(m.supports_block_exec(2), "re-export artifacts (make artifacts)");
        assert!(m.supports_block_exec(4));
        assert!(!m.supports_block_exec(3)); // heads=4 not divisible
        assert!(!m.supports_block_exec(1)); // degenerate degree never exported
        let b = m.block_exec(2).unwrap();
        assert_eq!(b.degree, 2);
        // block shapes divide the model-sharded dim only
        let emb = b.param("token_embed").unwrap();
        assert_eq!(emb.model_dim, Some(0));
        assert_eq!(emb.block_shape, vec![m.vocab() / 2, 64]);
        let norm = b.param("decoder.final_norm.scale").unwrap();
        assert_eq!(norm.model_dim, None);
        assert_eq!(norm.block_shape, vec![64]);
        assert!(b.replicated_grads.contains(&"decoder.final_norm.scale".to_string()));
        // the 12 segments exist on disk
        assert_eq!(b.segments.len(), 12);
        for (seg, path) in &b.segments {
            assert!(path.exists(), "missing block segment HLO {seg}");
        }
        // ordered schedule: starts at the embed g-point, ends at the fused
        // replicated-grad AR, length 4*layers + 7
        let l = m.cfg_usize("num_layers");
        assert_eq!(b.collectives.len(), 4 * l + 7);
        assert_eq!(b.collectives[0].point, "embed_out");
        assert_eq!(b.collectives.last().unwrap().point, "replicated_grads");
        assert!(b.collectives.iter().any(|c| c.op == "all_reduce_max"));
        assert!(b.collectives.iter().any(|c| c.op == "all_reduce_min"));
        assert!(b.schedule_elems() > 0);
        // encdec models carry no block contract
        let ed = a.model("t5-nano-encdec").unwrap();
        assert!(ed.block_exec.is_empty());
    }

    #[test]
    fn encdec_manifest_features() {
        let a = Artifacts::load_default().unwrap();
        let m = a.model("t5-nano-encdec").unwrap();
        let names: Vec<&str> = m.batch_features.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "encoder_input_tokens",
                "decoder_input_tokens",
                "decoder_target_tokens",
                "decoder_loss_weights"
            ]
        );
        assert!(m.batch_features[0].is_int);
        assert!(!m.batch_features[3].is_int);
    }
}
