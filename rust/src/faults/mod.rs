//! Deterministic fault injection (S10).
//!
//! Large runs die in boring, reproducible ways: a host preempted
//! mid-step, a checkpoint shard flipped on disk, a data source that
//! hiccups once, a collective peer that wedges, a serving replica that
//! panics. The recovery machinery in [`crate::trainer::supervisor`] and
//! [`crate::serve::router`] is only trustworthy if those failures can be
//! *reproduced on demand* — so this module injects them deterministically,
//! keyed by the same coordinates that make the rest of the system
//! deterministic (host rank, step number, batch index, request id).
//!
//! ## Plan format
//!
//! A [`FaultPlan`] is a JSON document (CLI `--fault-plan plan.json`, gin
//! `faults.plan = 'plan.json'`):
//!
//! ```json
//! {"faults": [
//!   {"kind": "host_panic",          "host": 0, "step": 3},
//!   {"kind": "slow_host",           "host": 1, "step": 2, "ms": 50},
//!   {"kind": "corrupt_checkpoint",  "step": 4, "array": "wte"},
//!   {"kind": "infeed_source_error", "host": 0, "batch": 2},
//!   {"kind": "comm_stall",          "host": 1, "step": 3, "ms": 200},
//!   {"kind": "replica_panic",       "replica": 1, "request": 2}
//! ]}
//! ```
//!
//! Every fault fires **exactly once**: after the supervisor restarts a
//! run and re-reaches step `N`, a `host_panic{step: N}` does not fire
//! again — that is what makes "inject a panic, prove bit-identical
//! recovery" a terminating test rather than a crash loop.
//!
//! ## Hook points
//!
//! Injection sites are named like trace spans and consulted explicitly:
//!
//! | point               | faults consulted                    |
//! |---------------------|-------------------------------------|
//! | `trainer/step`      | `host_panic`, `slow_host`           |
//! | `trainer/grad_sync` | `comm_stall` (host sleeps *before*  |
//! |                     | entering the collective, so peers'  |
//! |                     | recv deadline is what trips)        |
//! | infeed producer     | `infeed_source_error` (keyed by the |
//! |                     | per-host batch index)               |
//! | checkpoint commit   | `corrupt_checkpoint` (flips a byte  |
//! |                     | in a committed tstore chunk)        |
//! | gateway replica     | `replica_panic` (keyed by client id)|
//!
//! ## Overhead contract
//!
//! Same deal as the [`crate::obs`] tracer: with no plan armed, every
//! hook is a single relaxed atomic load and an immediate return — the
//! slow path (plan lookup under a mutex) is only ever reached while a
//! plan is armed, i.e. in chaos tests and chaos CI, never in production
//! training or serving. `tests/integration_faults.rs` pins this with a
//! timing test.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// One deterministic injection point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Panic host `host`'s train loop when it reaches `step`.
    HostPanic { host: usize, step: u64 },
    /// Delay host `host` by `ms` at the top of `step` (straggler).
    SlowHost { host: usize, step: u64, ms: u64 },
    /// After the checkpoint for `step` commits, flip a byte in one of its
    /// tstore chunks (under `array`'s subtree; any array when empty).
    CorruptCheckpoint { step: u64, array: String },
    /// Panic host `host`'s infeed producer while pulling `batch`.
    InfeedSourceError { host: usize, batch: u64 },
    /// Stall host `host` for `ms` before it enters the step's gradient
    /// sync, so its ring peers hit the collective deadline.
    CommStall { host: usize, step: u64, ms: u64 },
    /// Panic serving replica `replica` when it dispatches the request
    /// whose client id is `request`.
    ReplicaPanic { replica: usize, request: u64 },
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Fault::HostPanic { host, step } => {
                write!(f, "host_panic(host={host}, step={step})")
            }
            Fault::SlowHost { host, step, ms } => {
                write!(f, "slow_host(host={host}, step={step}, ms={ms})")
            }
            Fault::CorruptCheckpoint { step, array } => {
                write!(f, "corrupt_checkpoint(step={step}, array={array:?})")
            }
            Fault::InfeedSourceError { host, batch } => {
                write!(f, "infeed_source_error(host={host}, batch={batch})")
            }
            Fault::CommStall { host, step, ms } => {
                write!(f, "comm_stall(host={host}, step={step}, ms={ms})")
            }
            Fault::ReplicaPanic { replica, request } => {
                write!(f, "replica_panic(replica={replica}, request={request})")
            }
        }
    }
}

struct ArmedFault {
    fault: Fault,
    fired: AtomicBool,
}

/// A parsed set of one-shot faults. Arm it globally with [`arm`].
pub struct FaultPlan {
    faults: Vec<ArmedFault>,
}

impl FaultPlan {
    pub fn new(faults: Vec<Fault>) -> FaultPlan {
        FaultPlan {
            faults: faults
                .into_iter()
                .map(|fault| ArmedFault { fault, fired: AtomicBool::new(false) })
                .collect(),
        }
    }

    /// Parse the `{"faults": [...]}` document.
    pub fn parse(text: &str) -> anyhow::Result<FaultPlan> {
        let json = Json::parse(text).map_err(|e| anyhow::anyhow!("fault plan: {e:?}"))?;
        Self::from_json(&json)
    }

    pub fn from_file(path: impl AsRef<Path>) -> anyhow::Result<FaultPlan> {
        let json = Json::parse_file(&path)?;
        Self::from_json(&json)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))
    }

    pub fn from_json(json: &Json) -> anyhow::Result<FaultPlan> {
        let arr = json
            .get("faults")
            .and_then(|f| f.as_arr())
            .ok_or_else(|| anyhow::anyhow!("fault plan: missing \"faults\" array"))?;
        let mut faults = Vec::with_capacity(arr.len());
        for (i, entry) in arr.iter().enumerate() {
            faults.push(parse_fault(entry).map_err(|e| anyhow::anyhow!("fault #{i}: {e}"))?);
        }
        Ok(FaultPlan::new(faults))
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// How many faults have fired so far.
    pub fn fired(&self) -> usize {
        self.faults.iter().filter(|f| f.fired.load(Ordering::Relaxed)).count()
    }

    pub fn faults(&self) -> Vec<Fault> {
        self.faults.iter().map(|f| f.fault.clone()).collect()
    }

    /// Claim the first unfired fault matching `pred` (one-shot).
    fn claim(&self, pred: impl Fn(&Fault) -> bool) -> Option<Fault> {
        for f in &self.faults {
            if pred(&f.fault)
                && f.fired
                    .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
            {
                return Some(f.fault.clone());
            }
        }
        None
    }
}

fn field_u64(entry: &Json, key: &str) -> anyhow::Result<u64> {
    entry
        .get(key)
        .and_then(|v| v.as_i64())
        .filter(|&v| v >= 0)
        .map(|v| v as u64)
        .ok_or_else(|| anyhow::anyhow!("missing or invalid \"{key}\""))
}

fn field_usize(entry: &Json, key: &str) -> anyhow::Result<usize> {
    entry
        .get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow::anyhow!("missing or invalid \"{key}\""))
}

fn parse_fault(entry: &Json) -> anyhow::Result<ArmedFault> {
    let kind = entry
        .get("kind")
        .and_then(|k| k.as_str())
        .ok_or_else(|| anyhow::anyhow!("missing \"kind\""))?;
    let fault = match kind {
        "host_panic" => Fault::HostPanic {
            host: field_usize(entry, "host")?,
            step: field_u64(entry, "step")?,
        },
        "slow_host" => Fault::SlowHost {
            host: field_usize(entry, "host")?,
            step: field_u64(entry, "step")?,
            ms: field_u64(entry, "ms")?,
        },
        "corrupt_checkpoint" => Fault::CorruptCheckpoint {
            step: field_u64(entry, "step")?,
            array: entry
                .get("array")
                .and_then(|a| a.as_str())
                .unwrap_or("")
                .to_string(),
        },
        "infeed_source_error" => Fault::InfeedSourceError {
            host: field_usize(entry, "host")?,
            batch: field_u64(entry, "batch")?,
        },
        "comm_stall" => Fault::CommStall {
            host: field_usize(entry, "host")?,
            step: field_u64(entry, "step")?,
            ms: field_u64(entry, "ms")?,
        },
        "replica_panic" => Fault::ReplicaPanic {
            replica: field_usize(entry, "replica")?,
            request: field_u64(entry, "request")?,
        },
        other => anyhow::bail!("unknown fault kind {other:?}"),
    };
    Ok(ArmedFault { fault, fired: AtomicBool::new(false) })
}

// ---------------------------------------------------------------------------
// Global arming. ARMED is the only thing the hot path ever touches.
// ---------------------------------------------------------------------------

static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);

/// Arm a plan process-wide. Returns a handle so callers (tests, the CLI
/// summary line) can inspect fire counts after the run.
pub fn arm(plan: FaultPlan) -> Arc<FaultPlan> {
    let plan = Arc::new(plan);
    *PLAN.lock().unwrap() = Some(plan.clone());
    ARMED.store(true, Ordering::SeqCst);
    plan
}

/// Disarm: hooks return to the single-relaxed-load fast path.
pub fn disarm() {
    ARMED.store(false, Ordering::SeqCst);
    *PLAN.lock().unwrap() = None;
}

#[inline]
pub fn is_armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

fn plan() -> Option<Arc<FaultPlan>> {
    PLAN.lock().unwrap().clone()
}

// ---------------------------------------------------------------------------
// Hook points.
// ---------------------------------------------------------------------------

/// Trainer hook: consulted at named points in the host loop. With no plan
/// armed this is one relaxed load. Panics (on purpose) for `host_panic`;
/// sleeps for `slow_host` / `comm_stall`.
#[inline]
pub fn maybe_inject(point: &'static str, host: usize, step: u64) {
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    inject_slow(point, host, step);
}

#[cold]
fn inject_slow(point: &'static str, host: usize, step: u64) {
    let Some(plan) = plan() else { return };
    match point {
        "trainer/step" => {
            if let Some(f) = plan.claim(|f| {
                matches!(f, Fault::SlowHost { host: h, step: s, .. } if *h == host && *s == step)
            }) {
                if let Fault::SlowHost { ms, .. } = f {
                    eprintln!("[faults] injecting {f} at {point}");
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                }
            }
            if let Some(f) = plan.claim(|f| {
                matches!(f, Fault::HostPanic { host: h, step: s } if *h == host && *s == step)
            }) {
                eprintln!("[faults] injecting {f} at {point}");
                panic!("fault injected: {f} at {point}");
            }
        }
        "trainer/grad_sync" => {
            if let Some(f) = plan.claim(|f| {
                matches!(f, Fault::CommStall { host: h, step: s, .. } if *h == host && *s == step)
            }) {
                if let Fault::CommStall { ms, .. } = f {
                    eprintln!("[faults] injecting {f} at {point}");
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                }
            }
        }
        _ => {}
    }
}

/// Infeed hook: `true` means the producer should fail this pull (the
/// caller panics so the retry/`Infeed::failed` path is exercised).
#[inline]
pub fn infeed_error(host: usize, batch: u64) -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    let Some(plan) = plan() else { return false };
    plan.claim(|f| {
        matches!(f, Fault::InfeedSourceError { host: h, batch: b } if *h == host && *b == batch)
    })
    .inspect(|f| eprintln!("[faults] injecting {f}"))
    .is_some()
}

/// Checkpoint hook: when a `corrupt_checkpoint` fault targets `step`,
/// returns the array prefix to corrupt (empty = any array).
#[inline]
pub fn checkpoint_corrupt_target(step: u64) -> Option<String> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let plan = plan()?;
    let f = plan
        .claim(|f| matches!(f, Fault::CorruptCheckpoint { step: s, .. } if *s == step))?;
    eprintln!("[faults] injecting {f}");
    match f {
        Fault::CorruptCheckpoint { array, .. } => Some(array),
        _ => None,
    }
}

/// Serving hook: `true` means replica `replica` should panic while
/// dispatching the request with client id `request`.
#[inline]
pub fn replica_panic(replica: usize, request: u64) -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    let Some(plan) = plan() else { return false };
    plan.claim(|f| {
        matches!(f, Fault::ReplicaPanic { replica: r, request: q } if *r == replica && *q == request)
    })
    .inspect(|f| eprintln!("[faults] injecting {f}"))
    .is_some()
}

/// Flip the last byte of one CRC-protected tstore chunk under
/// `ckpt_dir` (restricted to `array`'s subtree when non-empty). Used by
/// the `corrupt_checkpoint` injection and directly by tests; returns the
/// corrupted file.
pub fn corrupt_checkpoint_chunk(ckpt_dir: &Path, array: &str) -> anyhow::Result<PathBuf> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
        if let Ok(rd) = std::fs::read_dir(dir) {
            for e in rd.flatten() {
                let p = e.path();
                if p.is_dir() {
                    walk(&p, out);
                } else {
                    out.push(p);
                }
            }
        }
    }
    let root = if array.is_empty() {
        ckpt_dir.join("params")
    } else {
        ckpt_dir.join("params").join(array)
    };
    let search = if root.exists() { root } else { ckpt_dir.to_path_buf() };
    let mut files = Vec::new();
    walk(&search, &mut files);
    files.sort();
    let chunk = files
        .into_iter()
        .find(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("chunk-"))
        })
        .ok_or_else(|| {
            anyhow::anyhow!("no tstore chunk under {} (array {array:?})", ckpt_dir.display())
        })?;
    let mut bytes = std::fs::read(&chunk)?;
    let last = bytes.len() - 1;
    bytes[last] ^= 0xff;
    std::fs::write(&chunk, &bytes)?;
    Ok(chunk)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Lib unit tests share one process with every other module's tests;
    // plans here use coordinates (host 7, step 999999, replica 42) that
    // no real test mesh ever reaches, and this lock serializes the tests
    // that arm/disarm the global plan.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn plan_parses_every_kind() {
        let plan = FaultPlan::parse(
            r#"{"faults": [
                {"kind": "host_panic", "host": 7, "step": 999999},
                {"kind": "slow_host", "host": 7, "step": 999999, "ms": 5},
                {"kind": "corrupt_checkpoint", "step": 999999, "array": "wte"},
                {"kind": "infeed_source_error", "host": 7, "batch": 999999},
                {"kind": "comm_stall", "host": 7, "step": 999999, "ms": 5},
                {"kind": "replica_panic", "replica": 42, "request": 999999}
            ]}"#,
        )
        .unwrap();
        assert_eq!(plan.len(), 6);
        assert_eq!(plan.fired(), 0);
        assert_eq!(
            plan.faults()[0],
            Fault::HostPanic { host: 7, step: 999999 }
        );
        assert_eq!(
            plan.faults()[2],
            Fault::CorruptCheckpoint { step: 999999, array: "wte".into() }
        );
    }

    #[test]
    fn plan_rejects_unknown_kind_and_missing_fields() {
        let e = FaultPlan::parse(r#"{"faults": [{"kind": "meteor_strike"}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("meteor_strike"), "{e}");
        let e = FaultPlan::parse(r#"{"faults": [{"kind": "host_panic", "host": 7}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("step"), "{e}");
        assert!(FaultPlan::parse(r#"{"nope": 1}"#).is_err());
    }

    #[test]
    fn faults_fire_exactly_once() {
        let _g = TEST_LOCK.lock().unwrap();
        let plan = arm(FaultPlan::new(vec![
            Fault::InfeedSourceError { host: 7, batch: 999999 },
            Fault::ReplicaPanic { replica: 42, request: 999999 },
        ]));
        assert!(infeed_error(7, 999999));
        assert!(!infeed_error(7, 999999), "one-shot: second query must not fire");
        assert!(!infeed_error(7, 999998), "wrong batch never fires");
        assert!(replica_panic(42, 999999));
        assert!(!replica_panic(42, 999999));
        assert_eq!(plan.fired(), 2);
        disarm();
    }

    #[test]
    fn disarmed_hooks_are_inert() {
        let _g = TEST_LOCK.lock().unwrap();
        disarm();
        // No plan armed: every hook is a relaxed load + return.
        maybe_inject("trainer/step", 7, 999999);
        maybe_inject("trainer/grad_sync", 7, 999999);
        assert!(!infeed_error(7, 999999));
        assert!(checkpoint_corrupt_target(999999).is_none());
        assert!(!replica_panic(42, 999999));
        assert!(!is_armed());
    }

    #[test]
    fn corrupt_target_returns_array_prefix() {
        let _g = TEST_LOCK.lock().unwrap();
        arm(FaultPlan::new(vec![Fault::CorruptCheckpoint {
            step: 999999,
            array: "wte".into(),
        }]));
        assert_eq!(checkpoint_corrupt_target(999998), None);
        assert_eq!(checkpoint_corrupt_target(999999).as_deref(), Some("wte"));
        assert_eq!(checkpoint_corrupt_target(999999), None, "one-shot");
        disarm();
    }
}
