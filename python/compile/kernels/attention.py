"""L1 Pallas kernel: flash-style tiled multi-head attention.

TPU-oriented design (see DESIGN.md §Hardware-Adaptation):
  * grid = (batch, heads, Lq / block_q): each program instance owns one
    query tile; K/V are streamed through VMEM in ``block_k`` chunks with
    online-softmax accumulation (the TPU translation of the GPU
    shared-memory flash-attention trick — no [Lq, Lk] score matrix is ever
    materialized in HBM).
  * tile shapes default to MXU-friendly multiples (>= 8x128 lanes when the
    problem is big enough) and are clamped for the small test shapes.
  * executed with ``interpret=True`` — the CPU PJRT plugin cannot run
    Mosaic custom-calls; on real TPU the same kernel lowers natively.

The backward pass is provided via ``jax.custom_vjp``. dq/dk/dv/dbias are
computed by a pair of Pallas kernels that recompute the probability tiles
(flash-attention backward); a pure-jnp fallback (``_bwd_reference``) is kept
for cross-checking in tests.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e10


def _pick_block(n, preferred):
    """Largest divisor of n that is <= preferred (TPU tiles must divide)."""
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, causal, block_k, scale):
    """One (batch, head, q-tile) program: online softmax over k tiles."""
    q = q_ref[0, 0].astype(jnp.float32) * scale  # [bq, d]
    bq, d = q.shape
    lk = k_ref.shape[2]
    q_off = pl.program_id(2) * bq
    n_kb = lk // block_k

    def body(j, carry):
        acc, m, l = carry
        k_blk = pl.load(
            k_ref, (0, 0, pl.dslice(j * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        v_blk = pl.load(
            v_ref, (0, 0, pl.dslice(j * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        b_blk = pl.load(
            bias_ref, (0, slice(None), pl.dslice(j * block_k, block_k))
        ).astype(jnp.float32)
        s = q @ k_blk.T + b_blk  # [bq, bk]
        if causal:
            rows = q_off + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            cols = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 1
            )
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        acc = acc * alpha[:, None] + p @ v_blk
        l = l * alpha + p.sum(axis=-1)
        return acc, m_new, l

    acc = jnp.zeros((bq, d), jnp.float32)
    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_kb, body, (acc, m, l))
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


def _fwd_pallas(q, k, v, bias, causal, block_q, block_k):
    b, h, lq, d = q.shape
    lk = k.shape[2]
    bq = _pick_block(lq, block_q)
    bk = _pick_block(lk, block_k)
    scale = 1.0 / (d**0.5)
    kernel = functools.partial(
        _fwd_kernel, causal=causal, block_k=bk, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, lq // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, bq, lk), lambda b_, h_, i: (h_, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, lq, d), q.dtype),
        interpret=True,
    )(q, k, v, bias)


# ---------------------------------------------------------------------------
# Backward kernels (flash-attention backward: recompute p per tile)
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(
    q_ref, k_ref, v_ref, bias_ref, do_ref, delta_ref, lse_ref, dq_ref, db_ref,
    *, causal, block_k, scale
):
    """dq (and dbias) for one q tile: stream over k tiles."""
    q = q_ref[0, 0].astype(jnp.float32) * scale
    do = do_ref[0, 0].astype(jnp.float32)
    delta = delta_ref[0, 0].astype(jnp.float32)  # [bq]
    lse = lse_ref[0, 0].astype(jnp.float32)  # [bq]
    bq, d = q.shape
    lk = k_ref.shape[2]
    q_off = pl.program_id(2) * bq

    def body(j, dq):
        k_blk = pl.load(
            k_ref, (0, 0, pl.dslice(j * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        v_blk = pl.load(
            v_ref, (0, 0, pl.dslice(j * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        b_blk = pl.load(
            bias_ref, (0, slice(None), pl.dslice(j * block_k, block_k))
        ).astype(jnp.float32)
        s = q @ k_blk.T + b_blk
        if causal:
            rows = q_off + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            cols = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 1
            )
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # [bq, bk]
        dp = do @ v_blk.T
        ds = p * (dp - delta[:, None])
        pl.store(
            db_ref,
            (0, 0, slice(None), pl.dslice(j * block_k, block_k)),
            ds.astype(db_ref.dtype),
        )
        return dq + ds @ k_blk

    dq = jax.lax.fori_loop(0, lk // block_k, body, jnp.zeros((bq, d), jnp.float32))
    dq_ref[0, 0] = (dq * scale).astype(dq_ref.dtype)


def _bwd_dkv_kernel(
    q_ref, k_ref, v_ref, bias_ref, do_ref, delta_ref, lse_ref, dk_ref, dv_ref,
    *, causal, block_q, scale
):
    """dk/dv for one k tile: stream over q tiles."""
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)
    bk, d = k.shape
    lq = q_ref.shape[2]
    k_off = pl.program_id(2) * bk

    def body(i, carry):
        dk, dv = carry
        q_blk = (
            pl.load(
                q_ref, (0, 0, pl.dslice(i * block_q, block_q), slice(None))
            ).astype(jnp.float32)
            * scale
        )
        do_blk = pl.load(
            do_ref, (0, 0, pl.dslice(i * block_q, block_q), slice(None))
        ).astype(jnp.float32)
        b_blk = pl.load(
            bias_ref, (0, pl.dslice(i * block_q, block_q), slice(None))
        ).astype(jnp.float32)
        delta = pl.load(delta_ref, (0, 0, pl.dslice(i * block_q, block_q))).astype(
            jnp.float32
        )
        lse = pl.load(lse_ref, (0, 0, pl.dslice(i * block_q, block_q))).astype(
            jnp.float32
        )
        s = q_blk @ k.T + b_blk  # [bq, bk]
        if causal:
            rows = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, bk), 0
            )
            cols = k_off + jax.lax.broadcasted_iota(jnp.int32, (block_q, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv = dv + p.T @ do_blk
        dp = do_blk @ v.T
        ds = p * (dp - delta[:, None])
        dk = dk + ds.T @ q_blk
        return dk, dv

    dk = jnp.zeros((bk, d), jnp.float32)
    dv = jnp.zeros((bk, d), jnp.float32)
    # q_blk was pre-scaled inside body, so dk = ds^T @ (q * scale) is already
    # the gradient w.r.t. the raw k — no extra scale factor here.
    dk, dv = jax.lax.fori_loop(0, lq // block_q, body, (dk, dv))
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)


def _fwd_stats_kernel(q_ref, k_ref, bias_ref, lse_ref, *, causal, block_k, scale):
    """Recompute the log-sum-exp rows needed by the backward kernels."""
    q = q_ref[0, 0].astype(jnp.float32) * scale
    bq, _ = q.shape
    lk = k_ref.shape[2]
    q_off = pl.program_id(2) * bq

    def body(j, carry):
        m, l = carry
        k_blk = pl.load(
            k_ref, (0, 0, pl.dslice(j * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        b_blk = pl.load(
            bias_ref, (0, slice(None), pl.dslice(j * block_k, block_k))
        ).astype(jnp.float32)
        s = q @ k_blk.T + b_blk
        if causal:
            rows = q_off + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            cols = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 1
            )
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.exp(s - m_new[:, None]).sum(axis=-1)
        return m_new, l

    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    m, l = jax.lax.fori_loop(0, lk // block_k, body, (m, l))
    lse_ref[0, 0] = (m + jnp.log(l)).astype(lse_ref.dtype)


# ---------------------------------------------------------------------------
# Public API with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def flash_attention(q, k, v, bias, causal=False, block_q=64, block_k=64):
    """Tiled multi-head attention: softmax(q k^T / sqrt(d) + bias) v.

    Args:
      q: [B, H, Lq, D]; k, v: [B, H, Lk, D]; bias: [H, Lq, Lk] additive
        logit bias (pass zeros for unbiased attention).
      causal: apply causal masking (requires Lq == Lk).
      block_q / block_k: tile sizes (clamped to divisors of Lq / Lk).

    Returns [B, H, Lq, D] in q's dtype.
    """
    return _fwd_pallas(q, k, v, bias, causal, block_q, block_k)


def _flash_fwd(q, k, v, bias, causal, block_q, block_k):
    o = _fwd_pallas(q, k, v, bias, causal, block_q, block_k)
    return o, (q, k, v, bias, o)


def _flash_bwd(causal, block_q, block_k, res, do):
    q, k, v, bias, o = res
    b, h, lq, d = q.shape
    lk = k.shape[2]
    bq = _pick_block(lq, block_q)
    bk = _pick_block(lk, block_k)
    scale = 1.0 / (d**0.5)

    # delta_i = rowsum(do * o): the softmax-jacobian correction term.
    delta = jnp.einsum("bhqd,bhqd->bhq", do.astype(jnp.float32), o.astype(jnp.float32))

    lse = pl.pallas_call(
        functools.partial(_fwd_stats_kernel, causal=causal, block_k=bk, scale=scale),
        grid=(b, h, lq // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, bq, lk), lambda b_, h_, i: (h_, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq), lambda b_, h_, i: (b_, h_, i)),
        out_shape=jax.ShapeDtypeStruct((b, h, lq), jnp.float32),
        interpret=True,
    )(q, k, bias)

    dq, db_per_b = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, causal=causal, block_k=bk, scale=scale),
        grid=(b, h, lq // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda b_, h_, i: (b_, h_, 0, 0)),
            pl.BlockSpec((1, bq, lk), lambda b_, h_, i: (h_, i, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, i: (b_, h_, i)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, i: (b_, h_, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bq, lk), lambda b_, h_, i: (b_, h_, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, lq, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, lq, lk), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, bias, do, delta, lse)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, causal=causal, block_q=bq, scale=scale),
        grid=(b, h, lk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, lq, d), lambda b_, h_, j: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, lq, bk), lambda b_, h_, j: (h_, 0, j)),
            pl.BlockSpec((1, 1, lq, d), lambda b_, h_, j: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, lq), lambda b_, h_, j: (b_, h_, 0)),
            pl.BlockSpec((1, 1, lq), lambda b_, h_, j: (b_, h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, j: (b_, h_, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, lk, d), k.dtype),
            jax.ShapeDtypeStruct((b, h, lk, d), v.dtype),
        ],
        interpret=True,
    )(q, k, v, bias, do, delta, lse)

    dbias = db_per_b.sum(axis=0).astype(bias.dtype)  # [H, Lq, Lk]
    return dq, dk, dv, dbias


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def bwd_reference(q, k, v, bias, do, causal=False):
    """jnp backward oracle used by tests to validate the Pallas backward."""
    from . import ref

    def f(q_, k_, v_, b_):
        return ref.attention_ref(q_, k_, v_, b_, causal=causal)

    _, vjp = jax.vjp(f, q, k, v, bias)
    return vjp(do)
