//! Quickstart (E1): the whole Figure-1 stack in ~60 lines of user code,
//! with every dataset resolved *by registry name* through the unified
//! `seqio::get_dataset` provider API (paper §3.1).
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! The same scenario from the CLI / gin (flags win over bindings):
//!
//! ```bash
//! t5x train --model t5-nano-dec --steps 30 --task c4_lm --use-cached
//! #   equivalently, in a .gin file:
//! #   train.task = 'c4_lm'
//! #   train.split = 'train'
//! #   train.use_cached = True
//! t5x eval  --model t5-nano-dec --task c4_lm   # reads its validation split
//! t5x list-tasks                               # the registry namespace
//! ```
//!
//! Profiling a run (works on `train`, `infer`, and `serve`): add
//! `--trace-out trace.json` (gin: `trainer.trace_out`), optionally
//! narrowed with `--profile-steps N..M`, then either open
//! <https://ui.perfetto.dev> and drag the JSON in — one track per host
//! thread, spans for step phases, block segments, collectives, infeed
//! and serving — or stay in the terminal:
//!
//! ```bash
//! t5x train --task c4_lm --steps 20 --model t5-nano-dec --trace-out trace.json
//! t5x trace-summary trace.json   # top spans by self-time + bottleneck verdict
//! ```

use std::sync::Arc;

use t5x::optim::{OptimizerKind, Schedule};
use t5x::partitioning::{Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::seqio::provider::CachedTask;
use t5x::seqio::task::TaskRegistry;
use t5x::trainer::recipes;
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};

fn main() -> anyhow::Result<()> {
    let arts = Artifacts::load_default()?;
    let device = DeviceHandle::spawn()?;
    let model = "t5-nano-dec";
    let m = arts.model(model)?;
    println!(
        "model {model}: {} params, batch {} x seq {}",
        m.total_params(),
        m.batch(),
        m.seq_len()
    );

    // 1. seqio: the pretraining corpus is one registry name away. A Task,
    //    a Mixture, or a cached pipeline behind the same get_dataset call.
    recipes::register_defaults();
    let task = TaskRegistry::get("c4_lm").expect("default registry task");
    let cache_dir = std::env::temp_dir().join("t5x_quickstart_cache");
    let meta = recipes::ensure_cached(&task, &cache_dir, 8, 0)?;
    println!("cached {} examples in {} shards", meta.num_examples, meta.num_shards);
    let cached = Arc::new(CachedTask::open(&cache_dir, Some(&task))?);

    // 2. t5x: a 2x2 data x model mesh, ZeRO-3 sharded optimizer —
    //    every host keeps only its block of each parameter resident
    let cfg = TrainerConfig {
        model: model.into(),
        mesh: Mesh::new(2, 2),
        strategy: ParamStrategy::TwoD,
        optimizer: OptimizerKind::adam(),
        schedule: Schedule::RsqrtWithWarmup { peak: 3e-3, warmup: 10 },
        steps: 30,
        seed: 0,
        log_every: 5,
        checkpoint_every: None,
        checkpoint_dir: None,
        grad_clip_norm: None,
        weight_decay: None,
        // Auto picks block-sharded execution when the artifacts carry a
        // block contract for the model axis (no full-param gathers)
        exec_mode: t5x::partitioning::ExecMode::Auto,
        // Set to Some(path) to dump a Chrome/Perfetto trace of the run:
        // open ui.perfetto.dev and drag the JSON in (or use
        // `t5x trace-summary <path>` for a terminal breakdown).
        trace_out: None,
        profile_steps: None,
    };
    let trainer = Trainer::new(&arts, &device, cfg)?
        .with_logger(t5x::metrics::MetricsLogger::new().with_terminal());
    // provider -> model-ready infeed: get_dataset picks the feature
    // converter for the model arch and shards the split per host.
    let infeed = recipes::provider_infeed(m, cached, "train", 2, 0, 0, None)?;
    let summary = trainer.train(&BatchSource::Infeed(infeed))?;
    println!(
        "\nloss {:.3} -> {:.3} over {} steps ({:.1}s, {} comm bytes)",
        summary.first_loss(),
        summary.final_loss(),
        summary.history.len(),
        summary.wall_seconds,
        summary.comm_bytes,
    );

    // 3. eval on the task's held-out "validation" split — same provider,
    //    same entry point, different split.
    let runner = t5x::trainer::eval::EvalRunner::new(&arts, &device, model)?;
    let split = recipes::eval_split(task.as_ref());
    let metrics = runner.evaluate(
        &trainer.params(),
        recipes::eval_batches(m, task, &split, 7, 4)?.into_iter(),
    )?;
    println!(
        "eval [validation]: loss {:.3}, token accuracy {:.1}% over {} batches",
        metrics.loss,
        metrics.accuracy * 100.0,
        metrics.num_batches
    );

    assert!(summary.final_loss() < summary.first_loss());
    println!("quickstart OK");
    device.shutdown();
    Ok(())
}
