//! PJRT runtime (the t5x execution substrate, S1 in DESIGN.md).
//!
//! * [`artifacts`] — parse `artifacts/manifest.json`, the L2→L3 contract.
//! * [`tensor`] — [`tensor::HostTensor`], the host-side ndarray currency.
//! * [`service`] — the device-service thread wrapping `xla::PjRtClient`
//!   (HLO text → compile → execute), with cloneable, thread-safe handles.

pub mod artifacts;
pub mod service;
pub mod tensor;

pub use artifacts::{
    Artifacts, BlockExecDegree, BlockParamSpec, CollectiveStep, ModelManifest, ParamSpec,
};
pub use service::{DeviceHandle, Executable};
pub use tensor::{HostTensor, TensorData};
