//! Substrate utilities implemented from scratch (no serde/clap/rand/tokio
//! in the offline registry — see DESIGN.md substitution table).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threads;
