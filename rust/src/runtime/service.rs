//! Device service: a dedicated thread that owns the PJRT client and all
//! compiled executables, serving execute requests over channels.
//!
//! Rationale: the `xla` crate's `PjRtClient` is `Rc`-based (neither `Send`
//! nor `Sync`), so all PJRT calls must stay on one OS thread. Simulated
//! hosts (trainer worker threads, collectives) talk to the device through
//! cloneable [`DeviceHandle`]s. Executions therefore serialize on the
//! device thread — which mirrors reality on this testbed: all simulated
//! hosts share one physical CPU, and XLA already multi-threads each
//! execution internally. Coordination (sharding, collectives, optimizer
//! updates) runs fully parallel on the host threads.

use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::tensor::HostTensor;

enum Request {
    /// Compile HLO text from a file; reply with (exe_id, compile_time).
    Compile(PathBuf, Sender<anyhow::Result<(usize, Duration)>>),
    /// Execute exe_id on inputs; reply with outputs (tuple flattened).
    Execute(usize, Vec<HostTensor>, Sender<anyhow::Result<Vec<HostTensor>>>),
    /// Drop a compiled executable (frees memory for compile benches).
    Release(usize),
    Shutdown,
}

/// Cloneable, thread-safe handle to the device thread.
#[derive(Clone)]
pub struct DeviceHandle {
    tx: Arc<Mutex<Sender<Request>>>,
}

impl DeviceHandle {
    /// Spawn the device-service thread (one per process is typical).
    pub fn spawn() -> anyhow::Result<DeviceHandle> {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || {
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => {
                        let _ = ready_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow::anyhow!("PJRT init: {e}")));
                        return;
                    }
                };
                let mut executables: Vec<Option<xla::PjRtLoadedExecutable>> = Vec::new();
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Compile(path, reply) => {
                            let t0 = Instant::now();
                            let result = compile(&client, &path).map(|exe| {
                                executables.push(Some(exe));
                                (executables.len() - 1, t0.elapsed())
                            });
                            let _ = reply.send(result);
                        }
                        Request::Execute(id, inputs, reply) => {
                            let result = match executables.get(id).and_then(|e| e.as_ref()) {
                                Some(exe) => execute(exe, &inputs),
                                None => Err(anyhow::anyhow!("bad executable id {id}")),
                            };
                            let _ = reply.send(result);
                        }
                        Request::Release(id) => {
                            if let Some(slot) = executables.get_mut(id) {
                                *slot = None;
                            }
                        }
                        Request::Shutdown => break,
                    }
                }
            })?;
        ready_rx.recv().map_err(|_| anyhow::anyhow!("device thread died"))??;
        Ok(DeviceHandle { tx: Arc::new(Mutex::new(tx)) })
    }

    fn send(&self, req: Request) {
        self.tx.lock().unwrap().send(req).expect("device thread alive");
    }

    /// Compile HLO text from `path`; returns a runnable handle + the
    /// PJRT compile time (used by bench_compile / E12).
    pub fn compile(&self, path: impl AsRef<Path>) -> anyhow::Result<(Executable, Duration)> {
        let (reply_tx, reply_rx) = channel();
        self.send(Request::Compile(path.as_ref().to_path_buf(), reply_tx));
        let (id, dt) = reply_rx.recv().map_err(|_| anyhow::anyhow!("device thread died"))??;
        Ok((Executable { device: self.clone(), id }, dt))
    }

    pub fn shutdown(&self) {
        self.send(Request::Shutdown);
    }
}

/// A compiled computation living on the device thread.
#[derive(Clone)]
pub struct Executable {
    device: DeviceHandle,
    id: usize,
}

impl Executable {
    /// Execute synchronously. Inputs are positional (manifest order).
    pub fn run(&self, inputs: Vec<HostTensor>) -> anyhow::Result<Vec<HostTensor>> {
        let (reply_tx, reply_rx) = channel();
        self.device.send(Request::Execute(self.id, inputs, reply_tx));
        reply_rx.recv().map_err(|_| anyhow::anyhow!("device thread died"))?
    }

    /// Free the underlying PJRT executable.
    pub fn release(self) {
        self.device.send(Request::Release(self.id));
    }
}

fn compile(
    client: &xla::PjRtClient,
    path: &Path,
) -> anyhow::Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))
}

fn execute(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[HostTensor],
) -> anyhow::Result<Vec<HostTensor>> {
    // NOTE: we deliberately use `execute_b` with Rust-owned PjRtBuffers.
    // The crate's `execute(literals)` path leaks every input buffer (the
    // C++ shim `release()`s them and never frees after the run) — with
    // per-step full-parameter inputs that is ~params-bytes leaked per
    // step. Rust-side `PjRtBuffer` has a correct Drop. (Found via the
    // §Perf leak hunt; see EXPERIMENTS.md.)
    let client = exe.client();
    let mut buffers: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
    for t in inputs {
        let buf = match &t.data {
            crate::runtime::tensor::TensorData::F32(v) => {
                client.buffer_from_host_buffer(v.as_slice(), &t.shape, None)
            }
            crate::runtime::tensor::TensorData::I32(v) => {
                client.buffer_from_host_buffer(v.as_slice(), &t.shape, None)
            }
        }
        .map_err(|e| anyhow::anyhow!("host->device transfer: {e}"))?;
        buffers.push(buf);
    }
    let result = exe
        .execute_b::<xla::PjRtBuffer>(&buffers)
        .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
    drop(buffers);
    let out_lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetch output: {e}"))?;
    // aot.py lowers with return_tuple=True: flatten the tuple.
    let parts = out_lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
    parts.iter().map(HostTensor::from_literal).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Artifacts;

    #[test]
    fn device_runs_partdemo_ffn() {
        let arts = Artifacts::load_default().unwrap();
        let pd = arts.partdemo.as_ref().unwrap();
        let device = DeviceHandle::spawn().unwrap();
        let (exe, dt) = device.compile(&pd.hlos["ffn_full"]).unwrap();
        assert!(dt.as_secs_f64() > 0.0);
        let x = HostTensor::f32(vec![pd.m, pd.k], vec![0.01; pd.m * pd.k]);
        let w1 = HostTensor::f32(vec![pd.k, pd.f], vec![0.02; pd.k * pd.f]);
        let w2 = HostTensor::f32(vec![pd.f, pd.k], vec![0.03; pd.f * pd.k]);
        let out = exe.run(vec![x, w1, w2]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![pd.m, pd.k]);
        // y = gelu(x@w1)@w2; with x@w1 = 0.01*0.02*256 = 0.0512 per elem,
        // gelu(0.0512) ~ 0.0266, y ~ 0.0266*0.03*1024 ~ 0.817
        let v = out[0].as_f32()[0];
        assert!((v - 0.817).abs() < 0.05, "v={v}");
        // handle usable from other threads
        let exe2 = exe.clone();
        let h = std::thread::spawn(move || {
            let x = HostTensor::f32(vec![64, 256], vec![0.0; 64 * 256]);
            let w1 = HostTensor::f32(vec![256, 1024], vec![0.0; 256 * 1024]);
            let w2 = HostTensor::f32(vec![1024, 256], vec![0.0; 1024 * 256]);
            exe2.run(vec![x, w1, w2]).unwrap()[0].as_f32()[0]
        });
        assert_eq!(h.join().unwrap(), 0.0);
        device.shutdown();
    }
}
