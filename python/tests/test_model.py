"""L2 model tests: shapes, loss math, masking, pallas/ref agreement."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import golden_batch

NANO_DEC = M.CONFIGS["t5-nano-dec"]
NANO_ED = M.CONFIGS["t5-nano-encdec"]


def _params_and_batch(cfg, seed=0):
    params = M.random_params(cfg, jax.random.PRNGKey(seed))
    batch = {k: jnp.asarray(v) for k, v in golden_batch(cfg).items()}
    return params, batch


@pytest.mark.parametrize("cfg", [NANO_DEC, NANO_ED], ids=lambda c: c.name)
def test_logits_shape(cfg):
    params, batch = _params_and_batch(cfg)
    logits = M.logits_fn(
        params, cfg, batch["decoder_input_tokens"], batch.get("encoder_input_tokens")
    )
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)


@pytest.mark.parametrize("cfg", [NANO_DEC, NANO_ED], ids=lambda c: c.name)
def test_initial_loss_near_uniform(cfg):
    """Random init => per-token loss near ln(vocab) (above it, since random
    logits have nonzero variance; far below would indicate leakage)."""
    params, batch = _params_and_batch(cfg)
    ls, ws, _ = M.loss_terms(params, cfg, batch)
    per_token = float(ls) / float(ws)
    assert np.log(cfg.vocab) - 0.1 < per_token < np.log(cfg.vocab) + 2.0


def test_loss_weights_mask_positions():
    """Zero-weight positions must not contribute to loss_sum."""
    params, batch = _params_and_batch(NANO_DEC)
    ls0, ws0, _ = M.loss_terms(params, NANO_DEC, batch)
    # Corrupt the targets at the masked positions (weights[0, -4:] == 0).
    tgt = batch["decoder_target_tokens"].at[0, -4:].set(3)
    batch2 = dict(batch, decoder_target_tokens=tgt)
    ls1, ws1, _ = M.loss_terms(params, NANO_DEC, batch2)
    # decoder *inputs* unchanged, so the only diff path is via the loss mask.
    assert float(ws0) == float(ws1)
    np.testing.assert_allclose(float(ls0), float(ls1), rtol=1e-6)


def test_causal_masking_in_model():
    """Changing future input tokens must not change earlier logits."""
    params, batch = _params_and_batch(NANO_DEC)
    logits1 = M.logits_fn(params, NANO_DEC, batch["decoder_input_tokens"])
    toks2 = batch["decoder_input_tokens"].at[:, -8:].set(5)
    logits2 = M.logits_fn(params, NANO_DEC, toks2)
    np.testing.assert_allclose(
        np.asarray(logits1[:, :-8]), np.asarray(logits2[:, :-8]), atol=1e-5
    )


def test_encoder_is_bidirectional():
    """Changing ANY encoder token changes decoder logits (no enc masking)."""
    params, batch = _params_and_batch(NANO_ED)
    l1 = M.logits_fn(
        params, NANO_ED, batch["decoder_input_tokens"], batch["encoder_input_tokens"]
    )
    enc2 = batch["encoder_input_tokens"].at[:, 0].set(7)
    l2 = M.logits_fn(params, NANO_ED, batch["decoder_input_tokens"], enc2)
    assert float(jnp.abs(l1 - l2).max()) > 1e-6


@pytest.mark.parametrize("cfg", [NANO_DEC, NANO_ED], ids=lambda c: c.name)
def test_pallas_and_ref_lowering_agree(cfg):
    """The L1 kernels and jnp oracles must produce the same train step."""
    params, batch = _params_and_batch(cfg)
    fn_p, names = M.train_step_fn(cfg)
    fn_r, _ = M.train_step_fn(dataclasses.replace(cfg, use_pallas=False))
    args = [params[n] for n in names] + [
        batch[f] for f in M.batch_feature_names(cfg)
    ]
    out_p = jax.jit(fn_p)(*args)
    out_r = jax.jit(fn_r)(*args)
    np.testing.assert_allclose(float(out_p[0]), float(out_r[0]), rtol=1e-5)
    for n, a, b in zip(names, out_p[3:], out_r[3:]):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-3, err_msg=n
        )


def test_grads_cover_all_params():
    """Every parameter must receive a nonzero gradient on the golden batch."""
    cfg = NANO_DEC
    params, batch = _params_and_batch(cfg)
    fn, names = M.train_step_fn(cfg)
    args = [params[n] for n in names] + [batch[f] for f in M.batch_feature_names(cfg)]
    outs = jax.jit(fn)(*args)
    for n, g in zip(names, outs[3:]):
        assert float(jnp.abs(g).max()) > 0, f"zero gradient for {n}"


def test_scan_and_unroll_agree():
    """Scalable-T5 scan lowering must match the unrolled model numerically."""
    depth = 2
    cfg = dataclasses.replace(M.CONFIGS["t5-micro-dec"], num_layers=depth)
    key = jax.random.PRNGKey(0)
    d, jkv, ff = cfg.d_model, cfg.joined_kv, cfg.d_ff

    def r(k_, shape, scale=0.02):
        return jax.random.normal(k_, shape, jnp.float32) * scale

    ks = jax.random.split(key, 12)
    batch = golden_batch(cfg)
    args = [
        r(ks[0], (cfg.vocab, d), 1.0),
        r(ks[1], (cfg.relpos_buckets, cfg.num_heads)),
        jnp.ones((depth, d)),
        r(ks[2], (depth, d, jkv)),
        r(ks[3], (depth, d, jkv)),
        r(ks[4], (depth, d, jkv)),
        r(ks[5], (depth, jkv, d)),
        jnp.ones((depth, d)),
        r(ks[6], (depth, d, ff)),
        r(ks[7], (depth, d, ff)),
        r(ks[8], (depth, ff, d)),
        jnp.ones((d,)),
        jnp.asarray(batch["decoder_input_tokens"]),
        jnp.asarray(batch["decoder_target_tokens"]),
        jnp.asarray(batch["decoder_loss_weights"]),
    ]
    scan_loss = M.scan_decoder_loss_fn(cfg)(*args)
    unroll_loss = M.unrolled_decoder_loss_fn(cfg)(*args)
    np.testing.assert_allclose(float(scan_loss), float(unroll_loss), rtol=1e-5)


def test_param_specs_sorted_and_unique():
    for cfg in (NANO_DEC, NANO_ED):
        names = [s[0] for s in M.param_specs(cfg)]
        assert names == sorted(names)
        assert len(names) == len(set(names))


def test_pattern_init_is_deterministic_and_bounded():
    a = M.pattern_init("decoder.layers_0.self_attn.wq", (64, 64), 0.05)
    b = M.pattern_init("decoder.layers_0.self_attn.wq", (64, 64), 0.05)
    np.testing.assert_array_equal(a, b)
    assert np.abs(a).max() <= 0.05
    c = M.pattern_init("decoder.layers_0.self_attn.wk", (64, 64), 0.05)
    assert np.abs(a - c).max() > 0  # name-salted


# KV tests run on the ref kernels: the incremental-decode contract is
# kernel-independent, and pallas/ref agreement has its own test above.
NANO_REF = dataclasses.replace(NANO_DEC, use_pallas=False)


def test_prefill_logits_match_full_rescoring():
    """`prefill` is the decode_logits computation plus cache outputs — its
    logits must equal `logits_fn` on the same buffer (same kernels/order)."""
    cfg = NANO_REF
    params, batch = _params_and_batch(cfg)
    toks = batch["decoder_input_tokens"]
    full = M.logits_fn(params, cfg, toks)
    pre, caches = M.decoder_prefill(params, cfg, toks)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full), atol=1e-5)
    assert len(caches) == cfg.num_layers
    for k, v in caches:
        assert k.shape == (cfg.batch, cfg.num_heads, cfg.seq_len, cfg.head_dim)
        assert v.shape == k.shape


L128_REF = dataclasses.replace(M.CONFIGS["t5-nano-dec-l128"], use_pallas=False)


@pytest.mark.parametrize("ragged", [False, True], ids=["aligned", "ragged"])
@pytest.mark.parametrize("cfg", [NANO_REF, L128_REF], ids=lambda c: c.name)
def test_prefill_plus_decode_steps_match_rescoring(cfg, ragged):
    """The tentpole numerical contract: prefill + N x decode_step next-token
    logits == full logits_fn rescoring at every step, including rows packed
    at different lengths (continuous batching) and — in the L=128 config —
    queries attending across long-distance relpos buckets."""
    params, _ = _params_and_batch(cfg)
    b, l, v = cfg.batch, cfg.seq_len, cfg.vocab
    rng = np.random.RandomState(7)
    dec = np.zeros((b, l), np.int32)
    lens = np.zeros((b,), np.int32)
    for i in range(b):
        plen = l // 2 + (i % 5 if ragged else 3)
        dec[i, 1 : 1 + plen] = rng.randint(2, v, plen)
        lens[i] = plen + 1
    full_logits, cache_pairs = M.decoder_prefill(params, cfg, jnp.asarray(dec))
    caches = [t for kv in cache_pairs for t in kv]
    rows = np.asarray(full_logits)[np.arange(b), lens - 1]
    for _ in range(5):
        nxt = rows.argmax(-1).astype(np.int32)
        dec[np.arange(b), lens] = nxt
        lens = lens + 1
        outs = M.decoder_decode_step(
            params,
            cfg,
            caches,
            jnp.asarray(dec[np.arange(b), lens - 1][:, None]),
            jnp.asarray(lens - 1),
        )
        rows, caches = np.asarray(outs[0]), list(outs[1:])
        assert rows.shape == (b, v)
        ref_logits = np.asarray(M.logits_fn(params, cfg, jnp.asarray(dec)))
        np.testing.assert_allclose(
            rows, ref_logits[np.arange(b), lens - 1], atol=2e-3, rtol=1e-3
        )


def test_decode_step_rows_are_independent():
    """A row's decode_step logits must not depend on other rows' caches or
    tokens — the engine's packing-independence contract."""
    cfg = NANO_REF
    params, _ = _params_and_batch(cfg)
    b, l, v = cfg.batch, cfg.seq_len, cfg.vocab
    dec = np.zeros((b, l), np.int32)
    dec[:, 1:4] = np.arange(2, 2 + 3)[None, :]
    full_logits, cache_pairs = M.decoder_prefill(params, cfg, jnp.asarray(dec))
    caches = [t for kv in cache_pairs for t in kv]
    token = np.full((b, 1), 9, np.int32)
    pos = np.full((b,), 4, np.int32)
    base = np.asarray(
        M.decoder_decode_step(params, cfg, caches, jnp.asarray(token), jnp.asarray(pos))[0]
    )
    # Corrupt every row but 0 (tokens, positions, and cache contents).
    token2 = token.copy()
    token2[1:] = 55
    pos2 = pos.copy()
    pos2[1:] = 9
    caches2 = [np.asarray(c).copy() for c in caches]
    for c in caches2:
        c[1:] += 0.37
    out = np.asarray(
        M.decoder_decode_step(
            params,
            cfg,
            [jnp.asarray(c) for c in caches2],
            jnp.asarray(token2),
            jnp.asarray(pos2),
        )[0]
    )
    np.testing.assert_array_equal(base[0], out[0])


def test_z_loss_increases_loss():
    cfg = NANO_DEC
    params, batch = _params_and_batch(cfg)
    ls_z, _, _ = M.loss_terms(params, cfg, batch)
    cfg0 = dataclasses.replace(cfg, z_loss=0.0)
    ls_0, _, _ = M.loss_terms(params, cfg0, batch)
    assert float(ls_z) > float(ls_0)
