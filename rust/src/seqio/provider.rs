//! The unified data-provider API (paper §3.1, Figure 2): one task-based
//! entry point — [`get_dataset`] — behind which live [`Task`]s,
//! [`Mixture`]s and cached deterministic pipelines ([`CachedTask`], §3.2)
//! are interchangeable.
//!
//! Everything a training, eval or cache job needs is expressed as a
//! *registry name* plus [`GetDatasetOptions`]; the provider kind (live vs
//! mixture vs offline cache) is an implementation detail of the name.
//! This is the paper's configurability claim: every scenario (pretrain,
//! finetune, mixture, cached, resumed) is reachable from gin/CLI without
//! touching library code.
//!
//! ```text
//!   get_dataset("c4_span", opts)
//!        |
//!        v
//!   ProviderRegistry ── Task ─────┐
//!     (one namespace)  Mixture ───┼─ DatasetProvider::dataset(split, shard, seed)
//!                      CachedTask ┘        |
//!                                          v
//!                         [repeat] -> [strip _index] -> FeatureConverter
//!                                          |
//!                                          v
//!                      model-ready, checkpoint-resumable Dataset
//! ```

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use once_cell::sync::Lazy;

use super::dataset::{Dataset, DatasetFactory, PipelineOp, PipelineState};
use super::deterministic::{strip_index, DeterministicPipeline};
use super::evaluation::Metric;
use super::feature_converters::{resolve_converter, FeatureConverter, FeatureLengths};
use super::mixture::Mixture;
use super::task::{OutputFeature, Task};
use super::Example;
use crate::util::json::Json;

/// Which data shard of a split this reader owns (seqio.ShardInfo).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardInfo {
    pub index: usize,
    pub num_shards: usize,
}

impl ShardInfo {
    pub fn new(index: usize, num_shards: usize) -> ShardInfo {
        assert!(num_shards >= 1 && index < num_shards, "bad shard spec {index}/{num_shards}");
        ShardInfo { index, num_shards }
    }

    /// The whole (unsharded) split.
    pub fn whole() -> ShardInfo {
        ShardInfo { index: 0, num_shards: 1 }
    }
}

impl Default for ShardInfo {
    fn default() -> ShardInfo {
        ShardInfo::whole()
    }
}

/// The common surface of every data provider (seqio.DatasetProviderBase):
/// a named source of one or more splits of feature-dict examples, with
/// declared output features and checkpoint-exact resume.
pub trait DatasetProvider: Send + Sync {
    fn name(&self) -> &str;

    /// Split names this provider can serve. Every provider has "train".
    fn splits(&self) -> Vec<String> {
        vec!["train".to_string()]
    }

    /// The declared task-feature schema ("inputs"/"targets"/...). May be
    /// empty for raw providers (e.g. a cache opened without its live
    /// task); [`get_dataset`] then validates against the stream head.
    fn output_features(&self) -> Vec<OutputFeature>;

    /// Eval metrics associated with this provider's task(s).
    fn metrics(&self) -> Vec<Metric> {
        Vec::new()
    }

    /// One pass over `split` for this shard, seeded.
    fn dataset(&self, split: &str, shard: ShardInfo, seed: u64) -> anyhow::Result<Dataset>;

    /// Fast path for providers with native seek/repeat (the deterministic
    /// cache reader): build the split stream already positioned `start`
    /// examples in, optionally repeating over epochs. `Ok(None)` means
    /// "no native support" and [`get_dataset`] applies the generic
    /// fallback (factory-based repeat + replay-to-start).
    fn dataset_native(
        &self,
        _split: &str,
        _shard: ShardInfo,
        _seed: u64,
        _start: usize,
        _repeat: bool,
    ) -> anyhow::Result<Option<Dataset>> {
        Ok(None)
    }

    /// Rebuild the raw split stream and reposition it to a previously
    /// captured [`PipelineState`] (state-aware resume).
    fn dataset_resumed(
        &self,
        split: &str,
        shard: ShardInfo,
        seed: u64,
        state: &PipelineState,
    ) -> anyhow::Result<Dataset> {
        let mut ds = self.dataset(split, shard, seed)?;
        ds.restore(state)?;
        Ok(ds)
    }

    /// Advisory example count for `split` (None if unknown).
    fn num_input_examples(&self, _split: &str) -> Option<usize> {
        None
    }
}

// ---------------------------------------------------------------------------
// Provider impls: Task, Mixture
// ---------------------------------------------------------------------------

impl DatasetProvider for Task {
    fn name(&self) -> &str {
        &self.name
    }

    fn splits(&self) -> Vec<String> {
        let mut out = vec!["train".to_string()];
        out.extend(self.split_sources.keys().filter(|k| k.as_str() != "train").cloned());
        out
    }

    fn output_features(&self) -> Vec<OutputFeature> {
        self.output_features.clone()
    }

    fn metrics(&self) -> Vec<Metric> {
        self.metrics.clone()
    }

    fn dataset(&self, split: &str, shard: ShardInfo, seed: u64) -> anyhow::Result<Dataset> {
        self.dataset_split(split, seed, shard.index, shard.num_shards)
    }

    fn num_input_examples(&self, split: &str) -> Option<usize> {
        self.source_for(split).ok()?.num_input_examples()
    }
}

impl DatasetProvider for Mixture {
    fn name(&self) -> &str {
        &self.name
    }

    /// Splits every member task can serve (order of the first task).
    /// Lazily-bound members resolve here; an unresolvable member is a
    /// configuration error surfaced before any data is drawn.
    fn splits(&self) -> Vec<String> {
        let tasks = self.members().expect("mixture members must be registered before use");
        let mut out = DatasetProvider::splits(tasks[0].0.as_ref());
        out.retain(|s| tasks.iter().all(|(t, _)| t.source_for(s).is_ok()));
        out
    }

    /// seqio requires member tasks to share an output-feature schema; the
    /// first task's declaration speaks for the mixture.
    fn output_features(&self) -> Vec<OutputFeature> {
        let tasks = self.members().expect("mixture members must be registered before use");
        tasks[0].0.output_features.clone()
    }

    fn metrics(&self) -> Vec<Metric> {
        let tasks = self.members().expect("mixture members must be registered before use");
        tasks[0].0.metrics.clone()
    }

    fn dataset(&self, split: &str, shard: ShardInfo, seed: u64) -> anyhow::Result<Dataset> {
        self.dataset_split(split, seed, shard.index, shard.num_shards)
    }
}

// ---------------------------------------------------------------------------
// CachedTask: an offline deterministic cache as a provider (§3.2)
// ---------------------------------------------------------------------------

/// A deterministic cache directory wrapped as a provider, so
/// offline-preprocessed data is interchangeable with its live task behind
/// [`get_dataset`]. Examples arrive in global index order and carry the
/// `_index` audit feature (stripped before feature conversion).
///
/// Both cache layouts are served: a legacy single-split root (train at
/// the directory root) and the multi-split layout of
/// [`crate::seqio::cache::cache_task_splits`], where every split of the
/// task lives in its own `splits/<name>/` subdirectory and is addressable
/// through `get_dataset(.., split, ..)` like any live split.
pub struct CachedTask {
    name: String,
    dir: std::path::PathBuf,
    build_seed: u64,
    /// Split name -> its deterministic reader (BTreeMap: "train" sorts
    /// before "validation", keeping split listings stable).
    pipelines: BTreeMap<String, DeterministicPipeline>,
    output_features: Vec<OutputFeature>,
    metrics: Vec<Metric>,
}

impl CachedTask {
    /// Open a cache directory (either layout). `live` supplies the
    /// feature/metric declarations (a cache stores only examples); pass
    /// `None` for raw access — [`get_dataset`] then validates features
    /// against the stream head instead of the declaration.
    pub fn open(dir: impl AsRef<Path>, live: Option<&Task>) -> anyhow::Result<CachedTask> {
        let dir = dir.as_ref();
        let root = crate::seqio::cache::CacheMeta::load(dir)?;
        let mut pipelines = BTreeMap::new();
        match &root.splits {
            Some(names) => {
                for split in names {
                    let sub = crate::seqio::cache::CacheMeta::split_dir(dir, split);
                    pipelines.insert(split.clone(), DeterministicPipeline::open(&sub)?);
                }
                anyhow::ensure!(
                    pipelines.contains_key("train"),
                    "multi-split cache at {} has no 'train' split",
                    dir.display()
                );
            }
            None => {
                pipelines.insert("train".to_string(), DeterministicPipeline::open(dir)?);
            }
        }
        let name = if let Some(t) = live {
            anyhow::ensure!(
                root.task.is_empty() || root.task == t.name,
                "cache at {} was built from task '{}', not '{}'",
                dir.display(),
                root.task,
                t.name
            );
            t.name.clone()
        } else if !root.task.is_empty() {
            root.task.clone()
        } else {
            dir.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default()
        };
        Ok(CachedTask {
            name,
            dir: dir.to_path_buf(),
            build_seed: root.seed,
            pipelines,
            output_features: live.map(|t| t.output_features.clone()).unwrap_or_default(),
            metrics: live.map(|t| t.metrics.clone()).unwrap_or_default(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Examples in the train split (see [`CachedTask::num_input_examples`]
    /// for other splits).
    pub fn num_examples(&self) -> usize {
        self.pipelines["train"].meta.num_examples
    }

    /// The preprocessing/shuffle seed the cache was built with — the seed
    /// that pins this provider's data (runtime seeds are ignored).
    pub fn build_seed(&self) -> u64 {
        self.build_seed
    }

    fn pipeline(&self, split: &str) -> anyhow::Result<&DeterministicPipeline> {
        self.pipelines.get(split).ok_or_else(|| {
            anyhow::anyhow!(
                "cached task '{}' has no split '{split}' (cached: [{}]); re-cache with \
                 `t5x cache` to pick up new splits",
                self.name,
                DatasetProvider::splits(self).join(", ")
            )
        })
    }
}

impl DatasetProvider for CachedTask {
    fn name(&self) -> &str {
        &self.name
    }

    /// Every cached split ("train" first; BTreeMap order).
    fn splits(&self) -> Vec<String> {
        self.pipelines.keys().cloned().collect()
    }

    fn output_features(&self) -> Vec<OutputFeature> {
        self.output_features.clone()
    }

    fn metrics(&self) -> Vec<Metric> {
        self.metrics.clone()
    }

    fn dataset(&self, split: &str, shard: ShardInfo, seed: u64) -> anyhow::Result<Dataset> {
        Ok(self
            .dataset_native(split, shard, seed, 0, false)?
            .expect("CachedTask always reads natively"))
    }

    /// Native O(1) seek through the sidecar record indices — the §3.2
    /// Recoverability property, preserved through the provider API.
    ///
    /// The runtime seed is ignored by contract: a cache pins its
    /// preprocessing/shuffle seed at build time (`cache_meta.json`), so
    /// live/cached byte-identity holds when the caller's seed matches the
    /// cache's build seed.
    fn dataset_native(
        &self,
        split: &str,
        shard: ShardInfo,
        _seed: u64,
        start: usize,
        repeat: bool,
    ) -> anyhow::Result<Option<Dataset>> {
        let pipeline = self.pipeline(split)?;
        anyhow::ensure!(
            pipeline.meta.num_shards % shard.num_shards == 0,
            "cache '{}' split '{split}' has {} files, not divisible by {} shards \
             (re-cache with a shard count that is a multiple of every host count)",
            self.name,
            pipeline.meta.num_shards,
            shard.num_shards
        );
        Ok(Some(pipeline.try_host_stream(shard.index, shard.num_shards, start, repeat)?))
    }

    fn num_input_examples(&self, split: &str) -> Option<usize> {
        Some(self.pipelines.get(split)?.meta.num_examples)
    }
}

// ---------------------------------------------------------------------------
// Unified registry (tasks + mixtures + cached providers, one namespace)
// ---------------------------------------------------------------------------

/// One entry of the unified registry namespace.
#[derive(Clone)]
pub enum RegistryEntry {
    Task(Arc<Task>),
    Mixture(Arc<Mixture>),
    Cached(Arc<CachedTask>),
    /// Any other provider implementation.
    Provider(Arc<dyn DatasetProvider>),
}

impl RegistryEntry {
    pub fn provider(&self) -> Arc<dyn DatasetProvider> {
        match self {
            RegistryEntry::Task(t) => t.clone(),
            RegistryEntry::Mixture(m) => m.clone(),
            RegistryEntry::Cached(c) => c.clone(),
            RegistryEntry::Provider(p) => p.clone(),
        }
    }

    pub fn as_task(&self) -> Option<Arc<Task>> {
        match self {
            RegistryEntry::Task(t) => Some(t.clone()),
            _ => None,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            RegistryEntry::Task(_) => "task",
            RegistryEntry::Mixture(_) => "mixture",
            RegistryEntry::Cached(_) => "cached",
            RegistryEntry::Provider(_) => "provider",
        }
    }

    pub fn name(&self) -> String {
        self.provider().name().to_string()
    }
}

static REGISTRY: Lazy<Mutex<BTreeMap<String, RegistryEntry>>> =
    Lazy::new(|| Mutex::new(BTreeMap::new()));

/// The global provider registry: tasks and mixtures share one namespace,
/// and duplicate registration is an error (seqio's ValueError), so a name
/// can never silently change meaning.
pub struct ProviderRegistry;

impl ProviderRegistry {
    pub fn add(entry: RegistryEntry) -> anyhow::Result<()> {
        let name = entry.name();
        anyhow::ensure!(!name.is_empty(), "cannot register a provider with an empty name");
        let mut reg = REGISTRY.lock().unwrap();
        anyhow::ensure!(
            !reg.contains_key(&name),
            "a task or mixture named '{name}' is already registered \
             (duplicate registration is an error; ProviderRegistry::remove it first)"
        );
        reg.insert(name, entry);
        Ok(())
    }

    pub fn get(name: &str) -> Option<RegistryEntry> {
        REGISTRY.lock().unwrap().get(name).cloned()
    }

    /// Resolve a name to its provider, with a did-you-mean error.
    pub fn provider(name: &str) -> anyhow::Result<Arc<dyn DatasetProvider>> {
        Self::get(name).map(|e| e.provider()).ok_or_else(|| {
            anyhow::anyhow!(
                "no task or mixture named '{name}' in the registry (registered: [{}])",
                Self::names().join(", ")
            )
        })
    }

    pub fn names() -> Vec<String> {
        REGISTRY.lock().unwrap().keys().cloned().collect()
    }

    pub fn entries() -> Vec<(String, RegistryEntry)> {
        REGISTRY.lock().unwrap().iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    }

    pub fn remove(name: &str) {
        REGISTRY.lock().unwrap().remove(name);
    }

    pub fn reset() {
        REGISTRY.lock().unwrap().clear();
    }
}

// ---------------------------------------------------------------------------
// get_dataset
// ---------------------------------------------------------------------------

/// Either a registry name or a provider instance — both sides of
/// `get_dataset(mixture_or_task_name, ...)`.
pub enum ProviderRef {
    Name(String),
    Provider(Arc<dyn DatasetProvider>),
}

impl ProviderRef {
    pub fn resolve(self) -> anyhow::Result<Arc<dyn DatasetProvider>> {
        match self {
            ProviderRef::Name(n) => ProviderRegistry::provider(&n),
            ProviderRef::Provider(p) => Ok(p),
        }
    }
}

impl From<&str> for ProviderRef {
    fn from(s: &str) -> ProviderRef {
        ProviderRef::Name(s.to_string())
    }
}

impl From<String> for ProviderRef {
    fn from(s: String) -> ProviderRef {
        ProviderRef::Name(s)
    }
}

impl From<Arc<dyn DatasetProvider>> for ProviderRef {
    fn from(p: Arc<dyn DatasetProvider>) -> ProviderRef {
        ProviderRef::Provider(p)
    }
}

impl From<Arc<Task>> for ProviderRef {
    fn from(t: Arc<Task>) -> ProviderRef {
        ProviderRef::Provider(t)
    }
}

impl From<Arc<Mixture>> for ProviderRef {
    fn from(m: Arc<Mixture>) -> ProviderRef {
        ProviderRef::Provider(m)
    }
}

impl From<Arc<CachedTask>> for ProviderRef {
    fn from(c: Arc<CachedTask>) -> ProviderRef {
        ProviderRef::Provider(c)
    }
}

/// Options of one [`get_dataset`] call (seqio's get_dataset signature).
#[derive(Clone)]
pub struct GetDatasetOptions {
    /// Split to read ("train", "validation", ...).
    pub split: String,
    /// Requested length per *task* feature, e.g. {"inputs": 64,
    /// "targets": 64}. Required for every feature the converter consumes.
    pub task_feature_lengths: FeatureLengths,
    /// Feature-converter registry name ("enc_dec", "lm", "prefix_lm") or
    /// a model-arch alias ("encdec", "decoder"). None = raw task features.
    pub converter: Option<String>,
    /// Which shard of the split this reader owns.
    pub shard: ShardInfo,
    /// Pipeline seed (preprocessing randomness + mixture sampling).
    pub seed: u64,
    /// Coarse positional start: skip this many (per-shard) examples.
    /// Providers with native seek (caches) honor it in O(1); others replay.
    /// Ignored when `resume` is set — the exact state wins.
    pub start: usize,
    /// Repeat over epochs (training streams).
    pub repeat: bool,
    /// Exact resume: a [`PipelineState`] captured from the stream of a
    /// previous, identically-configured get_dataset call.
    pub resume: Option<PipelineState>,
    /// Validate the stream head against the declared output features (and
    /// the converter's required task features) before returning.
    pub validate: bool,
}

impl Default for GetDatasetOptions {
    fn default() -> GetDatasetOptions {
        GetDatasetOptions {
            split: "train".to_string(),
            task_feature_lengths: FeatureLengths::new(),
            converter: None,
            shard: ShardInfo::whole(),
            seed: 0,
            start: 0,
            repeat: false,
            resume: None,
            validate: true,
        }
    }
}

/// THE entry point (paper §3.1): resolve a task/mixture/cache by name (or
/// take a provider directly), read the requested split shard, apply the
/// right feature converter, and return a model-ready, checkpoint-resumable
/// stream. Tasks, mixtures and §3.2 caches are interchangeable here.
pub fn get_dataset(
    provider: impl Into<ProviderRef>,
    opts: &GetDatasetOptions,
) -> anyhow::Result<Dataset> {
    let provider = provider.into().resolve()?;

    // -- split + converter validation ------------------------------------
    let splits = provider.splits();
    anyhow::ensure!(
        splits.iter().any(|s| s == &opts.split),
        "provider '{}' has no split '{}' (available: [{}])",
        provider.name(),
        opts.split,
        splits.join(", ")
    );
    let conv: Option<Arc<dyn FeatureConverter>> = match &opts.converter {
        Some(name) => Some(resolve_converter(name)?),
        None => None,
    };
    let features = provider.output_features();
    if let Some(c) = &conv {
        for feat in c.task_features() {
            if !features.is_empty() {
                anyhow::ensure!(
                    features.iter().any(|f| f.name == *feat),
                    "task '{}' does not declare feature '{feat}' required by \
                     converter '{}' (declared: [{}])",
                    provider.name(),
                    c.name(),
                    features.iter().map(|f| f.name.as_str()).collect::<Vec<_>>().join(", ")
                );
            }
            anyhow::ensure!(
                opts.task_feature_lengths.contains_key(*feat),
                "no task_feature_length given for '{feat}' (converter '{}' converts [{}])",
                c.name(),
                c.task_features().join(", ")
            );
        }
    }

    // -- build the positioned raw stream ----------------------------------
    let start = if opts.resume.is_some() { 0 } else { opts.start };
    let native =
        provider.dataset_native(&opts.split, opts.shard, opts.seed, start, opts.repeat)?;
    let raw = match native {
        Some(ds) => ds,
        None => {
            let mut ds = if opts.repeat {
                // Surface construction errors eagerly — the factory
                // closure below can only panic (construction only: no
                // element is pulled, no preprocessing runs).
                drop(provider.dataset(&opts.split, opts.shard, opts.seed)?);
                let (p, split, shard, seed) =
                    (provider.clone(), opts.split.clone(), opts.shard, opts.seed);
                Arc::new(DatasetFactory::new(move || {
                    p.dataset(&split, shard, seed).expect("re-instantiate epoch stream")
                }))
                .repeat()
            } else {
                provider.dataset(&opts.split, opts.shard, opts.seed)?
            };
            for _ in 0..start {
                if ds.next().is_none() {
                    break;
                }
            }
            ds
        }
    };

    // -- stream-head validation, in-stream --------------------------------
    // A state-transparent passthrough op audits the first element actually
    // produced (no second pipeline is built or consumed, unlike the old
    // probe). Schema-level errors (missing split, undeclared features,
    // missing lengths) still fail eagerly above; a head that contradicts
    // the declaration is a data bug and panics with the full context.
    let raw = if opts.validate {
        let required: Vec<String> = features
            .iter()
            .filter(|f| f.required)
            .map(|f| f.name.clone())
            .collect();
        let conv_feats: Vec<String> = conv
            .as_ref()
            .map(|c| c.task_features().iter().map(|f| f.to_string()).collect())
            .unwrap_or_default();
        let conv_name = conv.as_ref().map(|c| c.name().to_string());
        let ctx = format!("task '{}', split '{}'", provider.name(), opts.split);
        Dataset::from_op(ValidateHeadOp {
            inner: raw.into_op(),
            check: Some(Box::new(move |head: &Example| {
                for f in &required {
                    anyhow::ensure!(
                        head.contains_key(f),
                        "{ctx}: stream head is missing required feature '{f}'"
                    );
                }
                for feat in &conv_feats {
                    anyhow::ensure!(
                        head.contains_key(feat),
                        "{ctx}: stream head is missing task feature '{feat}' required \
                         by converter '{}'",
                        conv_name.as_deref().unwrap_or("?")
                    );
                }
                Ok(())
            })),
        })
    } else {
        raw
    };

    // -- feature conversion ------------------------------------------------
    let mut ds = match conv {
        Some(c) => {
            let lens = opts.task_feature_lengths.clone();
            // Bookkeeping features (the cache reader's `_index`) are not
            // model features; strip before converting.
            raw.map(strip_index).map(move |ex| c.convert_example(&ex, &lens))
        }
        None => raw,
    };

    // -- exact resume -------------------------------------------------------
    if let Some(state) = &opts.resume {
        ds.restore(state).map_err(|e| {
            anyhow::anyhow!(
                "restoring '{}' split '{}' from checkpointed pipeline state: {e}",
                provider.name(),
                opts.split
            )
        })?;
    }
    Ok(ds)
}

/// Validating passthrough: audits the first element flowing through the
/// stream, then becomes a no-op forwarder. State-transparent — `state()`
/// and `restore()` delegate to the inner op, so the pipeline-state payload
/// is byte-identical to an unvalidated stream (checkpoints from validated
/// and unvalidated builds interchange). A failed check panics: by the time
/// an element exists, schema-level errors have already been rejected
/// eagerly, so a bad head means the data itself contradicts the task
/// declaration.
struct ValidateHeadOp {
    inner: Box<dyn PipelineOp>,
    check: Option<Box<dyn FnOnce(&Example) -> anyhow::Result<()> + Send>>,
}

impl PipelineOp for ValidateHeadOp {
    fn next(&mut self) -> Option<Example> {
        let e = self.inner.next();
        if let Some(ex) = &e {
            if let Some(check) = self.check.take() {
                if let Err(err) = check(ex) {
                    panic!("get_dataset stream validation failed: {err:#}");
                }
            }
        }
        e
    }

    fn state(&mut self) -> Json {
        self.inner.state()
    }

    fn restore(&mut self, s: &Json) -> anyhow::Result<()> {
        self.inner.restore(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seqio::source::SyntheticTextSource;
    use crate::seqio::vocab::{ByteVocabulary, Vocabulary};

    fn toy_task(name: &str) -> Arc<Task> {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        Task::builder(name)
            .source(Arc::new(SyntheticTextSource::new(3, 12)))
            .split_source("validation", Arc::new(SyntheticTextSource::new(103, 6)))
            .preprocessor(Arc::new(crate::seqio::preprocessors::Tokenize::new(
                vocab.clone(),
                &[("text", "targets")],
            )))
            .output_feature("targets", vocab, true)
            .build()
    }

    #[test]
    fn provider_trait_exposes_splits_and_features() {
        let task = toy_task("prov_unit_splits");
        let p: Arc<dyn DatasetProvider> = task;
        assert_eq!(p.splits(), vec!["train".to_string(), "validation".to_string()]);
        assert_eq!(p.output_features().len(), 1);
        assert_eq!(p.num_input_examples("train"), Some(12));
        assert_eq!(p.num_input_examples("validation"), Some(6));
        let train = p.dataset("train", ShardInfo::whole(), 0).unwrap().collect_vec();
        let val = p.dataset("validation", ShardInfo::whole(), 0).unwrap().collect_vec();
        assert_eq!(train.len(), 12);
        assert_eq!(val.len(), 6);
        assert!(p.dataset("test", ShardInfo::whole(), 0).is_err());
    }

    #[test]
    fn get_dataset_validates_split_and_lengths() {
        let task = toy_task("prov_unit_validate");
        let missing_split = GetDatasetOptions { split: "test".into(), ..Default::default() };
        assert!(get_dataset(task.clone(), &missing_split).is_err());
        // converter without lengths for its features errors up front
        let no_lengths = GetDatasetOptions {
            converter: Some("lm".into()),
            ..Default::default()
        };
        let err =
            get_dataset(task.clone(), &no_lengths).err().expect("must error").to_string();
        assert!(err.contains("task_feature_length"), "{err}");
        // unknown converter name errors with the registry listing
        let bad_conv = GetDatasetOptions {
            converter: Some("nope".into()),
            ..Default::default()
        };
        assert!(get_dataset(task, &bad_conv).is_err());
    }

    #[test]
    fn get_dataset_repeat_and_start() {
        let task = toy_task("prov_unit_repeat");
        let one_pass =
            get_dataset(task.clone(), &GetDatasetOptions::default()).unwrap().collect_vec();
        assert_eq!(one_pass.len(), 12);
        // repeat wraps epochs deterministically
        let repeated: Vec<_> = (&mut get_dataset(
            task.clone(),
            &GetDatasetOptions { repeat: true, ..Default::default() },
        )
        .unwrap())
            .take(30)
            .collect();
        assert_eq!(&repeated[..12], one_pass.as_slice());
        assert_eq!(&repeated[12..24], one_pass.as_slice());
        // coarse positional start replays exactly
        let from_5 = get_dataset(
            task,
            &GetDatasetOptions { start: 5, ..Default::default() },
        )
        .unwrap()
        .collect_vec();
        assert_eq!(from_5.as_slice(), &one_pass[5..]);
    }

    #[test]
    fn cached_task_serves_every_split() {
        use crate::seqio::cache::{cache_task_splits, CacheConfig};
        let dir = std::env::temp_dir()
            .join(format!("prov_ms_cache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let task = toy_task("prov_unit_ms_cache");
        cache_task_splits(&task, &dir, &CacheConfig { num_shards: 2, seed: 0, workers: 2 })
            .unwrap();
        let cached = Arc::new(CachedTask::open(&dir, Some(&task)).unwrap());
        assert_eq!(
            DatasetProvider::splits(cached.as_ref()),
            vec!["train".to_string(), "validation".to_string()]
        );
        assert_eq!(cached.num_input_examples("train"), Some(12));
        assert_eq!(cached.num_input_examples("validation"), Some(6));
        let val = get_dataset(
            cached.clone(),
            &GetDatasetOptions { split: "validation".into(), ..Default::default() },
        )
        .unwrap()
        .collect_vec();
        assert_eq!(val.len(), 6);
        // unknown split still errors eagerly with the cached split list
        let err = get_dataset(
            cached,
            &GetDatasetOptions { split: "test".into(), ..Default::default() },
        )
        .err()
        .expect("must error")
        .to_string();
        assert!(err.contains("test"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn head_validation_is_in_stream_and_state_transparent() {
        let task = toy_task("prov_unit_head_validate");
        // validated and unvalidated streams produce byte-identical
        // pipeline states (the op is transparent)
        let mut v = get_dataset(task.clone(), &GetDatasetOptions::default()).unwrap();
        let mut u = get_dataset(
            task.clone(),
            &GetDatasetOptions { validate: false, ..Default::default() },
        )
        .unwrap();
        v.next();
        u.next();
        assert_eq!(v.state(), u.state());
        // a head contradicting the declaration panics on the first pull,
        // not at build time
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let lying = Task::builder("prov_unit_lying")
            .source(Arc::new(SyntheticTextSource::new(1, 4)))
            // no Tokenize: "targets" is declared but never produced
            .output_feature("targets", vocab, true)
            .build();
        let mut ds = get_dataset(lying, &GetDatasetOptions::default())
            .expect("schema checks pass; the data lies");
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| ds.next()));
        assert!(r.is_err(), "bad head must panic in-stream");
    }

    #[test]
    fn registry_name_resolution_and_errors() {
        let task = toy_task("prov_unit_registry");
        ProviderRegistry::add(RegistryEntry::Task(task)).unwrap();
        let got =
            get_dataset("prov_unit_registry", &GetDatasetOptions::default()).unwrap().collect_vec();
        assert_eq!(got.len(), 12);
        let err = get_dataset("prov_unit_missing", &GetDatasetOptions::default())
            .err()
            .expect("must error")
            .to_string();
        assert!(err.contains("prov_unit_missing"), "{err}");
        ProviderRegistry::remove("prov_unit_registry");
    }
}
