//! Gin-style configuration / dependency-injection system (§2.1 of the
//! paper: "we use Gin for this dependency injection").
//!
//! Supported syntax (a faithful subset of gin-config):
//!
//! ```text
//! # comment
//! include 'configs/base.gin'
//! BATCH = 32                      # macro definition
//! trainer.steps = 1000
//! trainer.model = 't5-micro-dec'
//! trainer.batch = %BATCH          # macro reference
//! trainer.schedule = @rsqrt       # configurable reference
//! rsqrt.warmup_steps = 100
//! eval/trainer.steps = 5          # scoped binding overrides
//! mixture.rates = [0.7, 0.3]
//! task.opts = {'key': 1, 'other': true}
//! ```
//!
//! Bindings are `function.argument = value`; the trainer, seqio pipeline
//! and checkpointing code query their arguments through [`Config::get`],
//! so users can retarget nearly everything without touching library code —
//! the paper's configurability claim. CLI `--gin.x.y=v` overrides map to
//! [`Config::apply_override`]. [`Config::operative`] dumps the
//! operative config exactly like t5x logs it.

mod parser;

pub use parser::{parse_value, ParseError};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A gin value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    List(Vec<Value>),
    Dict(Vec<(String, Value)>),
    /// `@configurable` or `@scope/configurable` reference.
    Reference(String),
    /// `%MACRO` (unresolved only transiently during parsing).
    Macro(String),
    None,
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            Value::Reference(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Value]> {
        match self {
            Value::List(v) => Some(v),
            _ => None,
        }
    }

    fn render(&self) -> String {
        match self {
            Value::Int(i) => i.to_string(),
            Value::Float(f) => format!("{f:?}"),
            Value::Bool(b) => (if *b { "True" } else { "False" }).into(),
            Value::Str(s) => format!("'{s}'"),
            Value::List(v) => format!(
                "[{}]",
                v.iter().map(|x| x.render()).collect::<Vec<_>>().join(", ")
            ),
            Value::Dict(kv) => format!(
                "{{{}}}",
                kv.iter()
                    .map(|(k, v)| format!("'{k}': {}", v.render()))
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
            Value::Reference(r) => format!("@{r}"),
            Value::Macro(m) => format!("%{m}"),
            Value::None => "None".into(),
        }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum GinError {
    #[error("gin: {0}")]
    Parse(String),
    #[error("gin: unknown macro %{0}")]
    UnknownMacro(String),
    #[error("gin: missing required binding {0}.{1}")]
    Missing(String, String),
    #[error("gin: binding {0}.{1} has wrong type (expected {2})")]
    WrongType(String, String, &'static str),
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Binding key: optional scope, configurable (function) name, argument name.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Key {
    scope: String, // empty = unscoped
    func: String,
    arg: String,
}

/// The parsed configuration: a set of (possibly scoped) bindings + macros.
#[derive(Debug, Clone, Default)]
pub struct Config {
    bindings: BTreeMap<Key, Value>,
    macros: BTreeMap<String, Value>,
    /// Keys that were actually queried — the "operative" subset.
    #[allow(clippy::type_complexity)]
    queried: std::sync::Arc<std::sync::Mutex<std::collections::BTreeSet<(String, String, String)>>>,
}

impl Config {
    pub fn new() -> Config {
        Config::default()
    }

    /// Parse a config string (no includes).
    pub fn parse(text: &str) -> Result<Config, GinError> {
        let mut cfg = Config::new();
        cfg.ingest(text, None)?;
        cfg.resolve_macros()?;
        Ok(cfg)
    }

    /// Parse a file, resolving `include 'path'` relative to its directory.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Config, GinError> {
        let mut cfg = Config::new();
        cfg.ingest_file(path.as_ref())?;
        cfg.resolve_macros()?;
        Ok(cfg)
    }

    fn ingest_file(&mut self, path: &Path) -> Result<(), GinError> {
        let text = std::fs::read_to_string(path)?;
        self.ingest(&text, path.parent())
    }

    fn ingest(&mut self, text: &str, dir: Option<&Path>) -> Result<(), GinError> {
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("include") {
                let inc = rest.trim().trim_matches(|c| c == '\'' || c == '"');
                let p: PathBuf = match dir {
                    Some(d) => d.join(inc),
                    None => PathBuf::from(inc),
                };
                self.ingest_file(&p)?;
                continue;
            }
            let (lhs, rhs) = line.split_once('=').ok_or_else(|| {
                GinError::Parse(format!("line {}: expected '='", lineno + 1))
            })?;
            let value = parse_value(rhs.trim())
                .map_err(|e| GinError::Parse(format!("line {}: {e}", lineno + 1)))?;
            self.bind(lhs.trim(), value)?;
        }
        Ok(())
    }

    /// Bind `scope/func.arg` (or `func.arg`, or `MACRO`) to a value.
    pub fn bind(&mut self, lhs: &str, value: Value) -> Result<(), GinError> {
        if !lhs.contains('.') {
            // Macro definition: NAME = value
            self.macros.insert(lhs.to_string(), value);
            return Ok(());
        }
        let (scope, rest) = match lhs.rsplit_once('/') {
            Some((s, r)) => (s.to_string(), r),
            None => (String::new(), lhs),
        };
        let (func, arg) = rest
            .rsplit_once('.')
            .ok_or_else(|| GinError::Parse(format!("bad binding '{lhs}'")))?;
        self.bindings.insert(
            Key { scope, func: func.to_string(), arg: arg.to_string() },
            value,
        );
        Ok(())
    }

    /// Apply a CLI override of the form `func.arg=value`.
    pub fn apply_override(&mut self, binding: &str) -> Result<(), GinError> {
        let (lhs, rhs) = binding
            .split_once('=')
            .ok_or_else(|| GinError::Parse(format!("bad override '{binding}'")))?;
        let value =
            parse_value(rhs.trim()).map_err(|e| GinError::Parse(e.to_string()))?;
        self.bind(lhs.trim(), value)?;
        self.resolve_macros()
    }

    fn resolve_macros(&mut self) -> Result<(), GinError> {
        let macros = self.macros.clone();
        for v in self.bindings.values_mut() {
            resolve(v, &macros)?;
        }
        Ok(())
    }

    // ---- queries ---------------------------------------------------------

    /// Scoped lookup: `scope/func.arg` falls back to `func.arg`.
    pub fn get_scoped(&self, scope: &str, func: &str, arg: &str) -> Option<&Value> {
        let hit = self
            .bindings
            .get(&Key { scope: scope.into(), func: func.into(), arg: arg.into() })
            .or_else(|| {
                self.bindings.get(&Key {
                    scope: String::new(),
                    func: func.into(),
                    arg: arg.into(),
                })
            });
        if hit.is_some() {
            self.queried.lock().unwrap().insert((
                scope.to_string(),
                func.to_string(),
                arg.to_string(),
            ));
        }
        hit
    }

    pub fn get(&self, func: &str, arg: &str) -> Option<&Value> {
        self.get_scoped("", func, arg)
    }

    pub fn usize_or(&self, func: &str, arg: &str, default: usize) -> usize {
        self.get(func, arg).and_then(|v| v.as_i64()).map(|i| i as usize).unwrap_or(default)
    }

    pub fn f64_or(&self, func: &str, arg: &str, default: f64) -> f64 {
        self.get(func, arg).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, func: &str, arg: &str, default: bool) -> bool {
        self.get(func, arg).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn str_or(&self, func: &str, arg: &str, default: &str) -> String {
        self.get(func, arg)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn require_str(&self, func: &str, arg: &str) -> Result<String, GinError> {
        self.get(func, arg)
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .ok_or_else(|| GinError::Missing(func.into(), arg.into()))
    }

    /// Full dump of all bindings in gin syntax (sorted, deterministic).
    pub fn full_config(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.macros {
            out.push_str(&format!("{name} = {}\n", v.render()));
        }
        for (k, v) in &self.bindings {
            let scope = if k.scope.is_empty() {
                String::new()
            } else {
                format!("{}/", k.scope)
            };
            out.push_str(&format!("{scope}{}.{} = {}\n", k.func, k.arg, v.render()));
        }
        out
    }

    /// The operative config: only bindings that were actually consumed —
    /// t5x logs this at startup for reproducibility.
    pub fn operative(&self) -> String {
        let queried = self.queried.lock().unwrap();
        let mut out = String::new();
        for (scope, func, arg) in queried.iter() {
            if let Some(v) = self
                .bindings
                .get(&Key { scope: scope.clone(), func: func.clone(), arg: arg.clone() })
                .or_else(|| {
                    self.bindings.get(&Key {
                        scope: String::new(),
                        func: func.clone(),
                        arg: arg.clone(),
                    })
                })
            {
                let sc = if scope.is_empty() { String::new() } else { format!("{scope}/") };
                out.push_str(&format!("{sc}{func}.{arg} = {}\n", v.render()));
            }
        }
        out
    }
}

fn resolve(v: &mut Value, macros: &BTreeMap<String, Value>) -> Result<(), GinError> {
    match v {
        Value::Macro(name) => {
            let m = macros
                .get(name)
                .ok_or_else(|| GinError::UnknownMacro(name.clone()))?;
            *v = m.clone();
            Ok(())
        }
        Value::List(items) => {
            for i in items {
                resolve(i, macros)?;
            }
            Ok(())
        }
        Value::Dict(kv) => {
            for (_, i) in kv {
                resolve(i, macros)?;
            }
            Ok(())
        }
        _ => Ok(()),
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str: Option<char> = None;
    for (i, c) in line.char_indices() {
        match (c, in_str) {
            ('#', None) => return &line[..i],
            ('\'', None) | ('"', None) => in_str = Some(c),
            (c2, Some(q)) if c2 == q => in_str = None,
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_query() {
        let cfg = Config::parse(
            "
# top comment
BATCH = 32
trainer.steps = 1000   # trailing comment
trainer.lr = 1e-3
trainer.batch = %BATCH
trainer.model = 't5-micro-dec'
trainer.sched = @rsqrt
trainer.use_pallas = True
mixture.rates = [0.7, 0.3]
eval/trainer.steps = 5
",
        )
        .unwrap();
        assert_eq!(cfg.usize_or("trainer", "steps", 0), 1000);
        assert_eq!(cfg.usize_or("trainer", "batch", 0), 32);
        assert!((cfg.f64_or("trainer", "lr", 0.0) - 1e-3).abs() < 1e-12);
        assert_eq!(cfg.str_or("trainer", "model", ""), "t5-micro-dec");
        assert_eq!(cfg.str_or("trainer", "sched", ""), "rsqrt");
        assert!(cfg.bool_or("trainer", "use_pallas", false));
        let rates = cfg.get("mixture", "rates").unwrap().as_list().unwrap();
        assert_eq!(rates.len(), 2);
        // Scoped lookup overrides; fallback to unscoped.
        assert_eq!(
            cfg.get_scoped("eval", "trainer", "steps").unwrap().as_i64(),
            Some(5)
        );
        assert_eq!(
            cfg.get_scoped("eval", "trainer", "lr").unwrap().as_f64(),
            Some(1e-3)
        );
    }

    #[test]
    fn overrides_win() {
        let mut cfg = Config::parse("trainer.steps = 10").unwrap();
        cfg.apply_override("trainer.steps=99").unwrap();
        assert_eq!(cfg.usize_or("trainer", "steps", 0), 99);
    }

    #[test]
    fn unknown_macro_errors() {
        assert!(matches!(
            Config::parse("a.b = %NOPE"),
            Err(GinError::UnknownMacro(_))
        ));
    }

    #[test]
    fn operative_only_contains_queried() {
        let cfg = Config::parse("a.x = 1\na.y = 2").unwrap();
        let _ = cfg.get("a", "x");
        let op = cfg.operative();
        assert!(op.contains("a.x = 1"));
        assert!(!op.contains("a.y"));
        let full = cfg.full_config();
        assert!(full.contains("a.y = 2"));
    }

    #[test]
    fn includes_resolve_relative() {
        let dir = std::env::temp_dir().join(format!("gin_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("base.gin"), "t.a = 1\nt.b = 2\n").unwrap();
        std::fs::write(dir.join("main.gin"), "include 'base.gin'\nt.b = 3\n").unwrap();
        let cfg = Config::from_file(dir.join("main.gin")).unwrap();
        assert_eq!(cfg.usize_or("t", "a", 0), 1);
        assert_eq!(cfg.usize_or("t", "b", 0), 3); // later binding wins
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dicts_and_none() {
        let cfg = Config::parse("t.d = {'k': 1, 'b': False}\nt.n = None").unwrap();
        match cfg.get("t", "d").unwrap() {
            Value::Dict(kv) => assert_eq!(kv.len(), 2),
            other => panic!("{other:?}"),
        }
        assert_eq!(cfg.get("t", "n"), Some(&Value::None));
    }
}
