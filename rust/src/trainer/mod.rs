//! The t5x training loop (S7): data-parallel simulated hosts, explicit
//! gradient synchronization, ZeRO-style sharded optimizer updates, metric
//! logging, checkpointing hooks, and exact resume.
//!
//! Strategy semantics (paper §2.2) at runtime:
//!
//! * [`ParamStrategy::OneD`] — every host holds full parameters and full
//!   optimizer state; per-step: grads are *ring all-reduced* over the data
//!   axis and every host applies the same update ("1D parameter
//!   partitioning": params replicated over the data axis).
//! * [`ParamStrategy::TwoD`] — ZeRO-3/FSDP: per-step grads are
//!   *reduce-scattered*, each host updates only its 1/D contiguous shard
//!   of the flat parameter vector (and owns only that shard's optimizer
//!   state), then the updated shards are *all-gathered*. Numerics are
//!   identical to OneD for elementwise optimizers (verified by E4).
//!
//! Model parallelism at runtime is exercised by the Megatron FFN demo
//! (examples/partitioning_demo.rs); the exported whole-model HLOs are
//! data-parallel per host (mesh.model == 1 in the trainer).

pub mod eval;
pub mod infeed;
pub mod recipes;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::checkpoint::{CheckpointManager, ExtraState};
use crate::collectives::{chunk_bounds, run_ranks, CollectiveGroup};
use crate::seqio::dataset::PipelineState;
use crate::metrics::MetricsLogger;
use crate::model::Params;
use crate::optim::{Optimizer, OptimizerKind, Schedule};
use crate::partitioning::ParamStrategy;
use crate::runtime::artifacts::ModelManifest;
use crate::runtime::{Artifacts, DeviceHandle, Executable, HostTensor};

/// Flat parameter layout: manifest order, contiguous f32.
#[derive(Debug, Clone)]
pub struct FlatLayout {
    /// (name, offset, len, shape) per parameter.
    pub entries: Vec<(String, usize, usize, Vec<usize>)>,
    pub total: usize,
}

impl FlatLayout {
    pub fn from_manifest(m: &ModelManifest) -> FlatLayout {
        let mut entries = Vec::with_capacity(m.params.len());
        let mut off = 0usize;
        for p in &m.params {
            let len = p.elements();
            entries.push((p.name.clone(), off, len, p.shape.clone()));
            off += len;
        }
        FlatLayout { entries, total: off }
    }

    pub fn flatten(&self, params: &Params) -> Vec<f32> {
        let mut out = vec![0.0f32; self.total];
        for (name, off, len, _) in &self.entries {
            out[*off..off + len].copy_from_slice(params[name].as_f32());
        }
        out
    }

    pub fn unflatten(&self, flat: &[f32]) -> Params {
        let mut out = Params::new();
        for (name, off, len, shape) in &self.entries {
            out.insert(
                name.clone(),
                HostTensor::f32(shape.clone(), flat[*off..off + len].to_vec()),
            );
        }
        out
    }

    /// Build executor inputs (manifest order) from the flat vector.
    pub fn tensors(&self, flat: &[f32]) -> Vec<HostTensor> {
        self.entries
            .iter()
            .map(|(_, off, len, shape)| {
                HostTensor::f32(shape.clone(), flat[*off..off + len].to_vec())
            })
            .collect()
    }
}

/// Where batches come from.
pub enum BatchSource {
    /// Deterministic random tokens (tests/benches).
    Synthetic { seed: u64 },
    /// A spawned seqio infeed (one prefetching stream per host).
    Infeed(infeed::Infeed),
}

impl BatchSource {
    fn next(&self, m: &ModelManifest, host: usize, step: u64) -> Option<Vec<HostTensor>> {
        match self {
            BatchSource::Synthetic { seed } => {
                Some(infeed::synthetic_batch(m, *seed, host, step))
            }
            BatchSource::Infeed(inf) => inf.next(host),
        }
    }

    /// Per-host pipeline states as of the last consumed batch (None for
    /// stateless synthetic sources). Persisted with each checkpoint so the
    /// data stream resumes exactly where the params/optimizer do.
    fn pipeline_states(&self, num_hosts: usize) -> Option<Vec<PipelineState>> {
        match self {
            BatchSource::Synthetic { .. } => None,
            BatchSource::Infeed(inf) => {
                Some((0..num_hosts).map(|h| inf.pipeline_state(h)).collect())
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub model: String,
    /// Data-parallel host count (runtime model axis is 1; see module docs).
    pub num_hosts: usize,
    pub strategy: ParamStrategy,
    pub optimizer: OptimizerKind,
    pub schedule: Schedule,
    pub steps: u64,
    pub seed: u64,
    pub log_every: u64,
    pub checkpoint_every: Option<u64>,
    pub checkpoint_dir: Option<PathBuf>,
    /// Clip gradients to this global L2 norm (None = off). Computed on the
    /// *global* (post-all-reduce) gradient so all strategies agree.
    pub grad_clip_norm: Option<f64>,
    /// Decoupled (AdamW-style) weight decay per step (None = off).
    pub weight_decay: Option<f64>,
}

impl TrainerConfig {
    pub fn quick(model: &str, steps: u64) -> TrainerConfig {
        TrainerConfig {
            model: model.to_string(),
            num_hosts: 1,
            strategy: ParamStrategy::OneD,
            optimizer: OptimizerKind::adam(),
            schedule: Schedule::RsqrtWithWarmup { peak: 3e-3, warmup: 20 },
            steps,
            seed: 0,
            log_every: 10,
            checkpoint_every: None,
            checkpoint_dir: None,
            grad_clip_norm: None,
            weight_decay: None,
        }
    }
}

/// Per-step metric record returned by [`Trainer::train`].
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: u64,
    pub loss: f64,
    pub accuracy: f64,
    pub lr: f64,
    pub step_seconds: f64,
}

pub struct TrainSummary {
    pub history: Vec<StepMetrics>,
    pub final_step: u64,
    pub comm_bytes: u64,
    pub wall_seconds: f64,
}

impl TrainSummary {
    pub fn final_loss(&self) -> f64 {
        self.history.last().map(|h| h.loss).unwrap_or(f64::NAN)
    }

    pub fn first_loss(&self) -> f64 {
        self.history.first().map(|h| h.loss).unwrap_or(f64::NAN)
    }
}

/// Per-host training state.
struct HostState {
    flat_params: Vec<f32>,
    optimizer: Optimizer,
}

/// Accumulated wall time of one pipeline phase (all hosts summed),
/// microseconds. Drives the §Perf breakdown in `bench_train_step`.
#[derive(Default)]
pub struct PhaseTimer(AtomicU64);

impl PhaseTimer {
    fn add_since(&self, t0: Instant) {
        self.0.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
    }

    pub fn seconds(&self) -> f64 {
        self.0.load(Ordering::Relaxed) as f64 / 1e6
    }

    fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Per-phase timing across the training loop.
#[derive(Default)]
pub struct TimingBreakdown {
    pub infeed: PhaseTimer,
    pub tensorize: PhaseTimer,
    pub execute: PhaseTimer,
    pub collectives: PhaseTimer,
    pub optimizer: PhaseTimer,
}

impl TimingBreakdown {
    pub fn reset(&self) {
        self.infeed.reset();
        self.tensorize.reset();
        self.execute.reset();
        self.collectives.reset();
        self.optimizer.reset();
    }

    /// (phase, seconds) rows, largest first.
    pub fn rows(&self) -> Vec<(&'static str, f64)> {
        let mut rows = vec![
            ("infeed", self.infeed.seconds()),
            ("tensorize", self.tensorize.seconds()),
            ("execute", self.execute.seconds()),
            ("collectives", self.collectives.seconds()),
            ("optimizer", self.optimizer.seconds()),
        ];
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }
}

/// Gradient scale factor implementing global-norm clipping: 1 when the
/// norm is within `clip`, else clip/norm.
fn clip_scale(clip: Option<f64>, grads: impl Iterator<Item = f64>) -> f32 {
    match clip {
        None => 1.0,
        Some(c) => {
            let norm = grads.map(|g| g * g).sum::<f64>().sqrt();
            clip_scale_from_norm(Some(c), norm)
        }
    }
}

fn clip_scale_from_norm(clip: Option<f64>, norm: f64) -> f32 {
    match clip {
        Some(c) if norm > c && norm > 0.0 => (c / norm) as f32,
        _ => 1.0,
    }
}

pub struct Trainer {
    pub manifest: ModelManifest,
    pub layout: FlatLayout,
    pub config: TrainerConfig,
    exe: Executable,
    group: Arc<CollectiveGroup>,
    hosts: Vec<Mutex<HostState>>,
    pub start_step: u64,
    /// Per-host data pipeline states recovered by [`Trainer::restore_latest`]
    /// (None when the checkpoint predates pipeline checkpointing or the run
    /// used a synthetic source). Pass to
    /// [`infeed::Infeed::spawn_resumable`] to resume the exact stream.
    pub restored_pipeline: Option<Vec<PipelineState>>,
    pub logger: Arc<MetricsLogger>,
    /// Per-phase wall-time accounting (summed over hosts); reset per train().
    pub timing: TimingBreakdown,
}

impl Trainer {
    pub fn new(
        arts: &Artifacts,
        device: &DeviceHandle,
        config: TrainerConfig,
    ) -> anyhow::Result<Trainer> {
        let manifest = arts.model(&config.model)?.clone();
        let layout = FlatLayout::from_manifest(&manifest);
        let (exe, _) = device.compile(&manifest.entrypoint("train_step")?.hlo)?;
        let group = CollectiveGroup::new(config.num_hosts);

        // init params once, replicate to hosts (t5x broadcasts from host 0)
        let init = crate::model::init_params(&manifest, config.seed);
        let flat0 = layout.flatten(&init);
        let hosts = (0..config.num_hosts)
            .map(|h| {
                Mutex::new(HostState {
                    flat_params: flat0.clone(),
                    optimizer: Self::build_optimizer(&config, &layout, h),
                })
            })
            .collect();
        Ok(Trainer {
            manifest,
            layout,
            config,
            exe,
            group,
            hosts,
            start_step: 0,
            restored_pipeline: None,
            logger: Arc::new(MetricsLogger::new()),
            timing: TimingBreakdown::default(),
        })
    }

    pub fn with_logger(mut self, logger: MetricsLogger) -> Self {
        self.logger = Arc::new(logger);
        self
    }

    fn build_optimizer(config: &TrainerConfig, layout: &FlatLayout, host: usize) -> Optimizer {
        let mut opt = Optimizer::new(config.optimizer, config.schedule);
        match config.strategy {
            ParamStrategy::OneD => {
                // full per-param states; factoring allowed
                for (name, _, len, shape) in &layout.entries {
                    let mat = if shape.len() >= 2 {
                        Some((shape[0], shape[1..].iter().product()))
                    } else {
                        None
                    };
                    opt.register(name, *len, mat);
                }
            }
            ParamStrategy::TwoD => {
                // ZeRO: one flat contiguous shard per host
                let bounds = chunk_bounds(layout.total, config.num_hosts);
                let (lo, hi) = bounds[host];
                opt.register("zero_shard", hi - lo, None);
            }
        }
        opt
    }

    /// Total optimizer-state floats currently held per host (memory claim).
    pub fn optimizer_state_floats(&self, host: usize) -> usize {
        self.hosts[host].lock().unwrap().optimizer.state_floats()
    }

    /// Current parameters (host 0's copy).
    pub fn params(&self) -> Params {
        self.layout.unflatten(&self.hosts[0].lock().unwrap().flat_params)
    }

    /// Run the training loop over `source`, returning per-step metrics.
    pub fn train(&self, source: &BatchSource) -> anyhow::Result<TrainSummary> {
        let n = self.config.num_hosts;
        let history = Mutex::new(Vec::<StepMetrics>::new());
        let stop_step = AtomicU64::new(u64::MAX);
        let t0 = Instant::now();
        self.group.reset_stats();
        self.timing.reset();

        let errors: Vec<Option<String>> = run_ranks(n, |rank| {
            match self.host_loop(rank, source, &history, &stop_step) {
                Ok(()) => None,
                Err(e) => Some(format!("host {rank}: {e}")),
            }
        });
        for e in errors.into_iter().flatten() {
            anyhow::bail!("{e}");
        }
        let mut history = history.into_inner().unwrap();
        history.sort_by_key(|h| h.step);
        let final_step = history.last().map(|h| h.step + 1).unwrap_or(self.start_step);
        self.logger.flush();
        Ok(TrainSummary {
            history,
            final_step,
            comm_bytes: self.group.bytes_sent(),
            wall_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    fn host_loop(
        &self,
        rank: usize,
        source: &BatchSource,
        history: &Mutex<Vec<StepMetrics>>,
        stop_step: &AtomicU64,
    ) -> anyhow::Result<()> {
        let m = &self.manifest;
        let n = self.config.num_hosts;
        let bounds = chunk_bounds(self.layout.total, n);
        let end = self.start_step + self.config.steps;
        for step in self.start_step..end {
            if step >= stop_step.load(Ordering::Acquire) {
                break;
            }
            let t_step = Instant::now();
            // ---- infeed ----
            let Some(batch) = source.next(m, rank, step) else {
                // data exhausted: all hosts exhaust simultaneously because
                // shards are balanced; signal and stop.
                stop_step.fetch_min(step, Ordering::AcqRel);
                // unblock peers mid-collective is unnecessary: all ranks
                // exhaust at the same step by construction.
                break;
            };
            self.timing.infeed.add_since(t_step);
            // ---- forward/backward on the device ----
            let t_tensorize = Instant::now();
            let mut inputs = {
                let host = self.hosts[rank].lock().unwrap();
                self.layout.tensors(&host.flat_params)
            };
            inputs.extend(batch);
            self.timing.tensorize.add_since(t_tensorize);
            let t_exec = Instant::now();
            let outs = self.exe.run(inputs)?;
            self.timing.execute.add_since(t_exec);
            let loss_sum = outs[0].first_f32();
            let weight_sum = outs[1].first_f32();
            let correct_sum = outs[2].first_f32();
            anyhow::ensure!(loss_sum.is_finite(), "non-finite loss at step {step}");

            // flatten grads (manifest order == layout order)
            let mut flat_grad = vec![0.0f32; self.layout.total];
            for (i, (_, off, len, _)) in self.layout.entries.iter().enumerate() {
                flat_grad[*off..off + len].copy_from_slice(outs[3 + i].as_f32());
            }

            // ---- gradient sync + update ----
            let t_comm = Instant::now();
            let scalars =
                self.group
                    .all_reduce(rank, vec![loss_sum, weight_sum, correct_sum]);
            let w_total = scalars[1].max(1e-9);
            let clip = self.config.grad_clip_norm;
            let decay = self.config.weight_decay.map(|d| d as f32);
            let lr_now = self.config.schedule.lr(step) as f32;
            match self.config.strategy {
                ParamStrategy::OneD => {
                    let summed = self.group.all_reduce(rank, flat_grad);
                    self.timing.collectives.add_since(t_comm);
                    let t_opt = Instant::now();
                    // global-norm clip scale on the normalized gradient
                    let scale = clip_scale(
                        clip,
                        summed.iter().map(|&x| (x / w_total) as f64),
                    ) / w_total;
                    let mut host = self.hosts[rank].lock().unwrap();
                    let HostState { flat_params, optimizer } = &mut *host;
                    for (name, off, len, _) in &self.layout.entries {
                        let g: Vec<f32> = summed[*off..off + len]
                            .iter()
                            .map(|&x| x * scale)
                            .collect();
                        if let Some(d) = decay {
                            for p in flat_params[*off..off + len].iter_mut() {
                                *p -= lr_now * d * *p;
                            }
                        }
                        optimizer.update(
                            name,
                            step,
                            &mut flat_params[*off..off + len],
                            &g,
                        );
                    }
                    self.timing.optimizer.add_since(t_opt);
                }
                ParamStrategy::TwoD => {
                    let chunk = self.group.reduce_scatter(rank, flat_grad);
                    // global-norm clip needs the norm over ALL shards:
                    // all-reduce the local squared sum (tiny payload).
                    let local_sq: f64 = chunk
                        .iter()
                        .map(|&x| {
                            let g = (x / w_total) as f64;
                            g * g
                        })
                        .sum();
                    let scale = if clip.is_some() {
                        let total_sq =
                            self.group.all_reduce(rank, vec![local_sq as f32])[0] as f64;
                        clip_scale_from_norm(clip, total_sq.sqrt()) / w_total
                    } else {
                        1.0 / w_total
                    };
                    self.timing.collectives.add_since(t_comm);
                    let t_opt = Instant::now();
                    let (lo, hi) = bounds[rank];
                    let g: Vec<f32> = chunk.iter().map(|&x| x * scale).collect();
                    let updated_chunk = {
                        let mut host = self.hosts[rank].lock().unwrap();
                        let HostState { flat_params, optimizer } = &mut *host;
                        if let Some(d) = decay {
                            for p in flat_params[lo..hi].iter_mut() {
                                *p -= lr_now * d * *p;
                            }
                        }
                        optimizer.update(
                            "zero_shard",
                            step,
                            &mut flat_params[lo..hi],
                            &g,
                        );
                        flat_params[lo..hi].to_vec()
                    };
                    self.timing.optimizer.add_since(t_opt);
                    let t_ag = Instant::now();
                    let full =
                        self.group.all_gather(rank, updated_chunk, self.layout.total);
                    self.hosts[rank].lock().unwrap().flat_params = full;
                    self.timing.collectives.add_since(t_ag);
                }
            }

            // ---- metrics (host 0) ----
            if rank == 0 {
                let loss = (scalars[0] / scalars[1]) as f64;
                let acc = (scalars[2] / scalars[1]) as f64;
                let lr = self.config.schedule.lr(step);
                let rec = StepMetrics {
                    step,
                    loss,
                    accuracy: acc,
                    lr,
                    step_seconds: t_step.elapsed().as_secs_f64(),
                };
                if step % self.config.log_every == 0 || step + 1 == end {
                    let tokens =
                        (m.tokens_per_step() * n) as f64 / rec.step_seconds;
                    self.logger.log(
                        step,
                        &[
                            ("loss", loss),
                            ("accuracy", acc),
                            ("lr", lr),
                            ("tokens_per_sec", tokens),
                        ],
                    );
                }
                history.lock().unwrap().push(rec);
            }

            // ---- checkpoint hook ----
            if let (Some(every), Some(dir)) =
                (self.config.checkpoint_every, self.config.checkpoint_dir.as_ref())
            {
                if (step + 1) % every == 0 || step + 1 == end {
                    self.checkpoint_barrier(rank, step + 1, dir, source)?;
                }
            }
        }
        Ok(())
    }

    /// Synchronized checkpoint: all hosts contribute optimizer shards
    /// (2D) / host 0 saves (1D has replicated state). Host 0 additionally
    /// persists every host's data-pipeline state (all ranks are at the
    /// same step boundary here, so the snapshot is globally consistent).
    fn checkpoint_barrier(
        &self,
        rank: usize,
        step: u64,
        dir: &PathBuf,
        source: &BatchSource,
    ) -> anyhow::Result<()> {
        let extra: ExtraState = match self.config.strategy {
            ParamStrategy::OneD => {
                if rank == 0 {
                    let host = self.hosts[0].lock().unwrap();
                    let mut extra = Vec::new();
                    for (name, _, _, _) in &self.layout.entries {
                        for (slot, vec) in host.optimizer.state_vectors(name) {
                            extra.push((format!("{name}/{slot}"), vec));
                        }
                    }
                    extra
                } else {
                    Vec::new()
                }
            }
            ParamStrategy::TwoD => {
                // gather each slot's flat shards to every host (cheap at
                // these sizes); host 0 persists.
                let my = {
                    let host = self.hosts[rank].lock().unwrap();
                    host.optimizer.state_vectors("zero_shard")
                };
                let mut extra = Vec::new();
                for (slot, vec) in my {
                    let full = self.group.all_gather(rank, vec, self.layout.total);
                    if rank == 0 {
                        extra.push((format!("flat/{slot}"), full));
                    }
                }
                extra
            }
        };
        self.group.barrier(rank);
        if rank == 0 {
            let mgr = CheckpointManager::new(dir.clone());
            let params = self.layout.unflatten(&self.hosts[0].lock().unwrap().flat_params);
            let mut meta_extra = extra;
            meta_extra.push(("trainstate/step".into(), vec![step as f32]));
            let pipeline = source.pipeline_states(self.config.num_hosts);
            mgr.save_with_pipeline(step, &params, &meta_extra, pipeline.as_deref())?;
        }
        self.group.barrier(rank);
        Ok(())
    }

    /// Restore params + optimizer state + step + data-pipeline position
    /// from the latest checkpoint.
    pub fn restore_latest(&mut self, dir: &PathBuf) -> anyhow::Result<u64> {
        let mgr = CheckpointManager::new(dir.clone());
        let step = mgr
            .latest()
            .ok_or_else(|| anyhow::anyhow!("no checkpoint in {}", dir.display()))?;
        let (params, extra) = mgr.restore(step)?;
        self.restored_pipeline = mgr.restore_pipeline(step)?;
        let flat = self.layout.flatten(&params);
        let n = self.config.num_hosts;
        let bounds = chunk_bounds(self.layout.total, n);
        for (h, hs) in self.hosts.iter().enumerate() {
            let mut host = hs.lock().unwrap();
            host.flat_params = flat.clone();
            for (key, vec) in &extra {
                if key == "trainstate/step" {
                    continue;
                }
                match self.config.strategy {
                    ParamStrategy::OneD => {
                        if let Some((name, slot)) = key.rsplit_once('/') {
                            host.optimizer.restore_state_vector(name, slot, vec.clone());
                        }
                    }
                    ParamStrategy::TwoD => {
                        if let Some(slot) = key.strip_prefix("flat/") {
                            let (lo, hi) = bounds[h];
                            host.optimizer.restore_state_vector(
                                "zero_shard",
                                slot,
                                vec[lo..hi].to_vec(),
                            );
                        }
                    }
                }
            }
        }
        self.start_step = step;
        Ok(step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn device() -> DeviceHandle {
        DeviceHandle::spawn().unwrap()
    }

    #[test]
    fn loss_decreases_on_fixed_batch_distribution() {
        let arts = Artifacts::load_default().unwrap();
        let dev = device();
        let mut cfg = TrainerConfig::quick("t5-nano-dec", 12);
        cfg.schedule = Schedule::Constant(2e-3);
        let trainer = Trainer::new(&arts, &dev, cfg).unwrap();
        let summary = trainer.train(&BatchSource::Synthetic { seed: 7 }).unwrap();
        assert_eq!(summary.history.len(), 12);
        assert!(
            summary.final_loss() < summary.first_loss(),
            "loss did not decrease: {} -> {}",
            summary.first_loss(),
            summary.final_loss()
        );
        dev.shutdown();
    }

    #[test]
    fn multi_host_1d_matches_single_host_global_batch() {
        // 2 hosts with the same per-host batch == global batch 2x; loss at
        // step 0 should equal the average of both hosts' losses and grads
        // must sync (smoke: just ensure it runs and improves).
        let arts = Artifacts::load_default().unwrap();
        let dev = device();
        let mut cfg = TrainerConfig::quick("t5-nano-dec", 6);
        cfg.num_hosts = 2;
        let trainer = Trainer::new(&arts, &dev, cfg).unwrap();
        let summary = trainer.train(&BatchSource::Synthetic { seed: 3 }).unwrap();
        assert!(summary.final_loss() < summary.first_loss());
        assert!(summary.comm_bytes > 0);
        dev.shutdown();
    }

    #[test]
    fn zero3_matches_1d_losses_exactly() {
        // E4: 2D (ZeRO-3) must reproduce the 1D loss trajectory with an
        // elementwise optimizer.
        let arts = Artifacts::load_default().unwrap();
        let dev = device();
        let mk = |strategy| {
            let mut cfg = TrainerConfig::quick("t5-nano-dec", 5);
            cfg.num_hosts = 2;
            cfg.strategy = strategy;
            cfg.seed = 11;
            Trainer::new(&arts, &dev, cfg).unwrap()
        };
        let s1 = mk(ParamStrategy::OneD)
            .train(&BatchSource::Synthetic { seed: 5 })
            .unwrap();
        let s2 = mk(ParamStrategy::TwoD)
            .train(&BatchSource::Synthetic { seed: 5 })
            .unwrap();
        for (a, b) in s1.history.iter().zip(&s2.history) {
            assert!(
                (a.loss - b.loss).abs() < 1e-4,
                "step {}: 1D {} vs 2D {}",
                a.step,
                a.loss,
                b.loss
            );
        }
        // and ZeRO holds ~1/2 the optimizer state per host
        let t1 = mk(ParamStrategy::OneD);
        let t2 = mk(ParamStrategy::TwoD);
        assert!(
            t2.optimizer_state_floats(0) * 2 <= t1.optimizer_state_floats(0) + 16
        );
        dev.shutdown();
    }

    #[test]
    fn checkpoint_and_resume_continue_exactly() {
        let arts = Artifacts::load_default().unwrap();
        let dev = device();
        let dir = std::env::temp_dir().join(format!("trainer_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        // run 6 steps straight
        let mut cfg = TrainerConfig::quick("t5-nano-dec", 6);
        cfg.seed = 2;
        cfg.schedule = Schedule::Constant(1e-3);
        let t_full = Trainer::new(&arts, &dev, cfg.clone()).unwrap();
        let full = t_full.train(&BatchSource::Synthetic { seed: 9 }).unwrap();

        // run 3 + checkpoint + restore + 3
        let mut cfg_a = cfg.clone();
        cfg_a.steps = 3;
        cfg_a.checkpoint_every = Some(3);
        cfg_a.checkpoint_dir = Some(dir.clone());
        let t_a = Trainer::new(&arts, &dev, cfg_a).unwrap();
        t_a.train(&BatchSource::Synthetic { seed: 9 }).unwrap();

        let mut cfg_b = cfg;
        cfg_b.steps = 3;
        let mut t_b = Trainer::new(&arts, &dev, cfg_b).unwrap();
        let resumed_step = t_b.restore_latest(&dir).unwrap();
        assert_eq!(resumed_step, 3);
        let resumed = t_b.train(&BatchSource::Synthetic { seed: 9 }).unwrap();

        // steps 3..6 must match the uninterrupted run exactly
        for (a, b) in full.history[3..].iter().zip(&resumed.history) {
            assert_eq!(a.step, b.step);
            assert!(
                (a.loss - b.loss).abs() < 1e-5,
                "step {}: {} vs {}",
                a.step,
                a.loss,
                b.loss
            );
        }
        std::fs::remove_dir_all(&dir).ok();
        dev.shutdown();
    }
}

#[cfg(test)]
mod feature_tests {
    use super::*;

    #[test]
    fn grad_clip_keeps_training_stable_and_changes_trajectory() {
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let mut base = TrainerConfig::quick("t5-nano-dec", 5);
        base.schedule = Schedule::Constant(1e-3);
        let unclipped = Trainer::new(&arts, &dev, base.clone())
            .unwrap()
            .train(&BatchSource::Synthetic { seed: 2 })
            .unwrap();
        let mut clipped_cfg = base.clone();
        clipped_cfg.grad_clip_norm = Some(0.05); // tight: always active
        let clipped = Trainer::new(&arts, &dev, clipped_cfg)
            .unwrap()
            .train(&BatchSource::Synthetic { seed: 2 })
            .unwrap();
        // both runs train; trajectories differ because the clip is active
        assert!(clipped.final_loss().is_finite());
        assert!(
            (clipped.final_loss() - unclipped.final_loss()).abs() > 1e-6,
            "clip had no effect"
        );
        dev.shutdown();
    }

    #[test]
    fn grad_clip_identical_across_strategies() {
        // clipping is computed on the GLOBAL gradient, so 1D and 2D still
        // agree step-for-step with clipping enabled.
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let mk = |strategy| {
            let mut cfg = TrainerConfig::quick("t5-nano-dec", 4);
            cfg.num_hosts = 2;
            cfg.strategy = strategy;
            cfg.grad_clip_norm = Some(0.1);
            cfg.schedule = Schedule::Constant(1e-3);
            Trainer::new(&arts, &dev, cfg).unwrap()
        };
        let a = mk(ParamStrategy::OneD)
            .train(&BatchSource::Synthetic { seed: 4 })
            .unwrap();
        let b = mk(ParamStrategy::TwoD)
            .train(&BatchSource::Synthetic { seed: 4 })
            .unwrap();
        for (x, y) in a.history.iter().zip(&b.history) {
            assert!((x.loss - y.loss).abs() < 1e-4, "step {}: {} vs {}", x.step, x.loss, y.loss);
        }
        dev.shutdown();
    }

    #[test]
    fn weight_decay_shrinks_param_norm() {
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let mut cfg = TrainerConfig::quick("t5-nano-dec", 6);
        cfg.schedule = Schedule::Constant(1e-4); // tiny lr: decay dominates
        cfg.weight_decay = Some(5.0);
        let trainer = Trainer::new(&arts, &dev, cfg.clone()).unwrap();
        let norm_before: f64 = trainer
            .params()
            .values()
            .map(|t| t.norm().powi(2))
            .sum::<f64>()
            .sqrt();
        trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
        let norm_after: f64 = trainer
            .params()
            .values()
            .map(|t| t.norm().powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(
            norm_after < norm_before * 0.999,
            "decay did not shrink params: {norm_before} -> {norm_after}"
        );
        dev.shutdown();
    }

    #[test]
    fn timing_breakdown_accounts_for_step_time() {
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let cfg = TrainerConfig::quick("t5-nano-dec", 3);
        let trainer = Trainer::new(&arts, &dev, cfg).unwrap();
        let summary = trainer.train(&BatchSource::Synthetic { seed: 0 }).unwrap();
        let rows = trainer.timing.rows();
        let phase_total: f64 = rows.iter().map(|(_, s)| s).sum();
        assert!(phase_total > 0.0);
        // phases cover the bulk of wall time (single host, no overlap)
        assert!(
            phase_total > 0.5 * summary.wall_seconds,
            "phases {phase_total} vs wall {}",
            summary.wall_seconds
        );
        // execute dominates on this workload
        assert_eq!(rows[0].0, "execute");
        dev.shutdown();
    }
}
