//! Integration: the serving gateway (ISSUE 8) — multi-replica HTTP
//! serving byte-identical to solo-engine decode, health/metrics under
//! load, explicit 429 backpressure on a full admission queue, graceful
//! drain, and deterministic deadline shedding.

use std::io::{Read, Write};
use std::sync::atomic::AtomicBool;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use t5x::infer::{DecodeMethod, InferEngine, InferRequest};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::serve::{
    Gateway, GatewayConfig, HttpConfig, HttpServer, ServeOutcome, ShedReason,
    SubmitOpts,
};
use t5x::util::json::Json;

const MODEL: &str = "t5-nano-dec";

/// One blocking HTTP/1.1 round-trip with `Connection: close`; returns
/// (status, raw headers, body).
fn http_call(port: u16, method: &str, path: &str, body: &str) -> (u16, String, String) {
    let mut s = std::net::TcpStream::connect(("127.0.0.1", port)).expect("connect");
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    s.flush().unwrap();
    let mut resp = Vec::new();
    s.read_to_end(&mut resp).unwrap();
    let text = String::from_utf8_lossy(&resp).to_string();
    let (head, payload) =
        text.split_once("\r\n\r\n").unwrap_or_else(|| panic!("no header split: {text}"));
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line: {head}"));
    (status, head.to_string(), payload.to_string())
}

/// The ISSUE-8 acceptance test: N concurrent HTTP clients against a
/// 2-replica gateway get byte-identical tokens to solo-engine decoding
/// of the same requests, while /healthz and /metrics answer mid-load and
/// /admin/drain shuts the whole stack down cleanly.
#[test]
fn two_replica_http_serving_is_byte_identical_to_solo_engine() {
    let arts = Artifacts::load_default().unwrap();
    let dev = DeviceHandle::spawn().unwrap();
    let params = t5x::model::init_params(arts.model(MODEL).unwrap(), 3);
    let b = arts.model(MODEL).unwrap().batch();
    let eos = -1; // budgets drive retirement: deterministic lengths
    let n = b + 4;
    let prompts: Vec<Vec<i32>> = (0..n).map(|i| vec![5 + i as i32, 9, 11]).collect();
    let budget = |i: usize| 3 + (i % 4);

    // Reference: every request decoded solo, one engine, one at a time.
    let mut solo = InferEngine::new(&arts, &dev, MODEL, &params, eos).unwrap();
    let expected: Vec<Vec<i32>> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            solo.submit(InferRequest {
                id: 0,
                prompt: p.clone(),
                max_tokens: budget(i),
                method: DecodeMethod::Greedy,
            })
            .unwrap();
            solo.run_until_idle().unwrap()[0].tokens.clone()
        })
        .collect();

    let engine0 = InferEngine::new(&arts, &dev, MODEL, &params, eos).unwrap();
    let engine1 = engine0.replica();
    let gw = Gateway::launch(
        vec![engine0, engine1],
        GatewayConfig { queue_depth: 64, shed_watermark: None },
    );
    let stop = Arc::new(AtomicBool::new(false));
    let server =
        HttpServer::start(gw.clone(), HttpConfig::default(), stop.clone()).unwrap();
    let port = server.port();

    let clients: Vec<_> = (0..n)
        .map(|i| {
            let body = format!(
                "{{\"id\": {}, \"prompt\": [{}], \"max_tokens\": {}}}",
                i + 1,
                prompts[i].iter().map(|t| t.to_string()).collect::<Vec<_>>().join(", "),
                budget(i)
            );
            std::thread::spawn(move || http_call(port, "POST", "/v1/generate", &body))
        })
        .collect();

    // Health and metrics must answer while the generate load is in
    // flight (workers busy, replicas stepping).
    let (hs, _, hb) = http_call(port, "GET", "/healthz", "");
    assert_eq!(hs, 200, "healthz under load: {hb}");
    assert_eq!(Json::parse(&hb).unwrap().get("status").unwrap().as_str(), Some("ok"));
    let (ms, _, mb) = http_call(port, "GET", "/metrics", "");
    assert_eq!(ms, 200, "metrics under load: {mb}");
    let metrics = Json::parse(&mb).unwrap();
    assert_eq!(metrics.get("replicas").unwrap().as_arr().unwrap().len(), 2);
    assert!(metrics.get("counters").is_some() && metrics.get("queue").is_some());

    for (i, c) in clients.into_iter().enumerate() {
        let (status, head, body) = c.join().unwrap();
        assert_eq!(status, 200, "request {i}: {body}");
        assert!(
            head.to_ascii_lowercase().contains("content-type: application/json"),
            "request {i}: {head}"
        );
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.get("id").unwrap().as_i64(), Some((i + 1) as i64));
        let tokens: Vec<i32> = v
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect();
        assert_eq!(
            tokens, expected[i],
            "request {i}: routed decode diverged from solo engine"
        );
        let replica = v.get("replica").unwrap().as_i64().unwrap();
        assert!((0..2).contains(&replica), "replica {replica}");
        assert!(v.get("queue_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert!(v.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("text").unwrap().as_str().is_some(), "decoded text missing");
    }

    let (ds, _, db) = http_call(port, "POST", "/admin/drain", "");
    assert_eq!(ds, 200);
    assert_eq!(Json::parse(&db).unwrap().get("status").unwrap().as_str(), Some("draining"));
    server.join();
    let report = gw.shutdown();
    assert_eq!(report.completed, n as u64);
    assert_eq!(report.replicas.len(), 2);
    assert_eq!(
        report.replicas.iter().map(|r| r.completed).sum::<u64>(),
        n as u64,
        "per-replica completions must add up"
    );
    assert!(report.latency_ms_p99 > 0.0);
    dev.shutdown();
}

/// Admission semantics over HTTP, made deterministic with a zero-replica
/// gateway: queue depth 1 means the first request parks in the queue,
/// the second gets an explicit 429 + Retry-After (never a hang), and the
/// drain flushes the parked request as a 503.
#[test]
fn http_backpressure_is_explicit_and_drain_flushes_queued_work() {
    let gw = Gateway::launch(
        Vec::new(),
        GatewayConfig { queue_depth: 1, shed_watermark: None },
    );
    let stop = Arc::new(AtomicBool::new(false));
    let server =
        HttpServer::start(gw.clone(), HttpConfig::default(), stop.clone()).unwrap();
    let port = server.port();

    // Client 1 occupies the whole queue and blocks awaiting an outcome.
    let parked = std::thread::spawn(move || {
        http_call(port, "POST", "/v1/generate", r#"{"id": 1, "prompt": [5, 9]}"#)
    });
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while gw.queue_depth() < 1 {
        assert!(std::time::Instant::now() < deadline, "request 1 never queued");
        std::thread::sleep(Duration::from_millis(5));
    }

    // Client 2: the queue is full — explicit backpressure, with the
    // retry hint, and a JSON error body.
    let (status, head, body) =
        http_call(port, "POST", "/v1/generate", r#"{"id": 2, "prompt": [7]}"#);
    assert_eq!(status, 429, "expected backpressure: {body}");
    assert!(head.to_ascii_lowercase().contains("retry-after:"), "no Retry-After: {head}");
    assert!(Json::parse(&body).unwrap().get("error").is_some());

    // Malformed body: 400, not a hang or a 500.
    let (status, _, body) = http_call(port, "POST", "/v1/generate", r#"{"max_tokens": 3}"#);
    assert_eq!(status, 400, "{body}");

    // Health stays responsive with a wedged queue; unknown paths 404.
    let (status, _, _) = http_call(port, "GET", "/healthz", "");
    assert_eq!(status, 200);
    let (status, _, _) = http_call(port, "GET", "/nope", "");
    assert_eq!(status, 404);

    let (status, _, body) = http_call(port, "POST", "/admin/drain", "");
    assert_eq!(status, 200, "{body}");
    // Shutdown flushes the parked request as a draining shed -> 503.
    let report = gw.shutdown();
    let (status, _, body) = parked.join().unwrap();
    assert_eq!(status, 503, "parked request must be flushed on drain: {body}");
    assert!(body.contains("draining"), "{body}");
    server.join();
    assert_eq!(report.completed, 0);
    assert_eq!(gw.counters().get("serve/rejected_full"), 1);
    assert_eq!(gw.counters().get("serve/shed_draining"), 1);
    // Submits after the drain are rejected outright (503 path).
    let (tx, _rx) = mpsc::channel();
    assert!(gw
        .submit(
            InferRequest {
                id: 3,
                prompt: vec![4],
                max_tokens: 2,
                method: DecodeMethod::Greedy
            },
            SubmitOpts::default(),
            tx
        )
        .is_err());
}

/// A request whose deadline has already expired when a replica would
/// dispatch it is shed before ever occupying a slot — deterministically
/// forced with a zero deadline — while later work still decodes.
#[test]
fn deadline_expired_requests_are_shed_before_decoding() {
    let arts = Artifacts::load_default().unwrap();
    let dev = DeviceHandle::spawn().unwrap();
    let params = t5x::model::init_params(arts.model(MODEL).unwrap(), 3);
    let engine = InferEngine::new(&arts, &dev, MODEL, &params, -1).unwrap();
    let gw = Gateway::launch(vec![engine], GatewayConfig::default());

    let (tx, rx) = mpsc::channel();
    gw.submit(
        InferRequest { id: 1, prompt: vec![5, 9], max_tokens: 4, method: DecodeMethod::Greedy },
        SubmitOpts { priority: 0, deadline: Some(Duration::ZERO) },
        tx.clone(),
    )
    .unwrap();
    match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
        ServeOutcome::Shed { client_id: 1, reason: ShedReason::DeadlineExpired, waited_ms } => {
            assert!(waited_ms >= 0.0);
        }
        other => panic!("expected deadline shed, got {other:?}"),
    }
    assert_eq!(gw.counters().get("serve/shed_deadline"), 1);

    // The gateway keeps serving: an undeadlined request completes.
    gw.submit(
        InferRequest { id: 2, prompt: vec![5, 9], max_tokens: 4, method: DecodeMethod::Greedy },
        SubmitOpts::default(),
        tx,
    )
    .unwrap();
    match rx.recv_timeout(Duration::from_secs(60)).unwrap() {
        ServeOutcome::Done { client_id: 2, result, .. } => {
            assert_eq!(result.tokens.len(), 4);
        }
        other => panic!("expected completion, got {other:?}"),
    }
    let report = gw.shutdown();
    assert_eq!(report.completed, 1);
    let shed = report
        .counters
        .iter()
        .find(|(k, _)| k.as_str() == "serve/shed_deadline")
        .expect("shed counter in report");
    assert_eq!(shed.1, 1);
    dev.shutdown();
}
