//! Integration: deterministic fault injection + self-healing recovery
//! (ISSUE 10) — a mid-run host panic recovers bit-identically under the
//! supervisor, corrupt checkpoints are quarantined and walked back, a
//! wedged collective trips the deadline with a named stall point, and a
//! panicked serving replica leaves N-1 survivors serving with a degraded
//! /healthz.
//!
//! The fault-plan registry and the collective deadline are process-global,
//! so every test that arms either serializes on [`FAULT_LOCK`] and resets
//! through [`FaultGuard`].

use std::sync::{mpsc, Mutex};
use std::time::Duration;

use t5x::faults::{self, Fault, FaultPlan};
use t5x::infer::{DecodeMethod, InferEngine, InferRequest};
use t5x::optim::Schedule;
use t5x::partitioning::{Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::serve::{Gateway, GatewayConfig, ServeOutcome, SubmitOpts};
use t5x::trainer::supervisor::{Supervisor, SupervisorConfig};
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};

static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Serializes fault-armed tests and guarantees the process-global fault
/// plan and collective deadline are reset even when an assertion panics.
struct FaultGuard<'a> {
    _lock: std::sync::MutexGuard<'a, ()>,
}

impl FaultGuard<'_> {
    fn acquire() -> Self {
        let lock = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        faults::disarm();
        t5x::collectives::set_comm_deadline_ms(0);
        FaultGuard { _lock: lock }
    }
}

impl Drop for FaultGuard<'_> {
    fn drop(&mut self) {
        faults::disarm();
        t5x::collectives::set_comm_deadline_ms(0);
    }
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("faults_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn assert_params_identical(a: &t5x::model::Params, b: &t5x::model::Params) {
    assert_eq!(a.len(), b.len(), "param sets differ in size");
    for (name, ta) in a {
        let tb = b.get(name).unwrap_or_else(|| panic!("missing param {name}"));
        assert_eq!(ta.shape, tb.shape, "{name}: shape mismatch");
        assert_eq!(
            ta.as_f32(),
            tb.as_f32(),
            "{name}: recovered parameters are not bit-identical"
        );
    }
}

/// The headline acceptance test: a host panic injected mid-run on a 2x2
/// mesh is healed by the supervisor — restore from the last checkpoint,
/// relaunch, and finish with final parameters bit-identical to a
/// fault-free run of the same config.
#[test]
fn host_panic_recovery_is_bit_identical_on_2x2_mesh() {
    let _guard = FaultGuard::acquire();
    let arts = Artifacts::load_default().unwrap();
    let dev = DeviceHandle::spawn().unwrap();
    let ckpt = temp_dir("panic2x2");

    let mut cfg = TrainerConfig::quick("t5-nano-dec", 6);
    cfg.mesh = Mesh::new(2, 2);
    cfg.strategy = ParamStrategy::TwoD;
    cfg.seed = 3;
    cfg.schedule = Schedule::Constant(1e-3);
    cfg.checkpoint_every = Some(2);

    // Fault-free reference (no checkpoint dir: nothing to restore from).
    let t_ref = Trainer::new(&arts, &dev, cfg.clone()).unwrap();
    let full = t_ref.train(&BatchSource::Synthetic { seed: 9 }).unwrap();
    let full_params = t_ref.params();

    // Supervised run with host 1 panicking at the top of step 4. The
    // checkpoint hook saved step 4 at the end of step 3, so the restart
    // restores step 4 and replays exactly steps 4..6.
    let mut cfg_f = cfg;
    cfg_f.checkpoint_dir = Some(ckpt.clone());
    faults::arm(FaultPlan::new(vec![Fault::HostPanic { host: 1, step: 4 }]));
    let sup = Supervisor::new(
        &arts,
        &dev,
        cfg_f,
        SupervisorConfig { max_restarts: 2, backoff_ms: 1, comm_deadline_ms: None, resume: false },
    );
    let run = sup
        .run(|_| Ok(BatchSource::Synthetic { seed: 9 }), |t, _| t)
        .unwrap();

    assert_eq!(run.restarts, 1, "exactly one restart expected");
    assert_eq!(run.quarantined_ckpts, 0);
    assert_eq!(run.summary.final_step, full.final_step);
    // The relaunched attempt covers steps 4..6; its losses must match the
    // uninterrupted run's exactly.
    for h in &run.summary.history {
        let r = full
            .history
            .iter()
            .find(|f| f.step == h.step)
            .unwrap_or_else(|| panic!("reference missing step {}", h.step));
        assert!(
            (h.loss - r.loss).abs() < 1e-7,
            "step {}: recovered {} vs fault-free {}",
            h.step,
            h.loss,
            r.loss
        );
    }
    assert_params_identical(&full_params, &run.trainer.params());
    assert_eq!(run.trainer.counters.get("train/restarts"), 1);

    std::fs::remove_dir_all(&ckpt).ok();
    dev.shutdown();
}

/// A checkpoint corrupted on disk (single bit flipped in a tstore chunk,
/// via the `corrupt_checkpoint` fault at save time) fails its CRC on
/// restore, gets quarantined as `ckpt-<n>.corrupt`, and `restore_latest`
/// falls back to the previous retained step instead of dying.
#[test]
fn corrupt_checkpoint_is_quarantined_and_walked_back() {
    let _guard = FaultGuard::acquire();
    let arts = Artifacts::load_default().unwrap();
    let dev = DeviceHandle::spawn().unwrap();
    let ckpt = temp_dir("corrupt");

    let mut cfg = TrainerConfig::quick("t5-nano-dec", 4);
    cfg.checkpoint_every = Some(2);
    cfg.checkpoint_dir = Some(ckpt.clone());

    // Corrupt the step-4 save as it is committed; step 2 stays valid.
    faults::arm(FaultPlan::new(vec![Fault::CorruptCheckpoint {
        step: 4,
        array: String::new(),
    }]));
    let t = Trainer::new(&arts, &dev, cfg.clone()).unwrap();
    t.train(&BatchSource::Synthetic { seed: 5 }).unwrap();
    assert!(ckpt.join("ckpt-00000004").exists(), "latest checkpoint missing");

    let mut t2 = Trainer::new(&arts, &dev, cfg).unwrap();
    let restored = t2.restore_latest(&ckpt).unwrap();
    assert_eq!(restored, 2, "must fall back past the corrupt step-4 save");
    assert!(
        ckpt.join("ckpt-00000004.corrupt").exists(),
        "corrupt checkpoint must be quarantined, not deleted"
    );
    assert!(!ckpt.join("ckpt-00000004").exists());
    assert_eq!(t2.counters.get("train/quarantined_ckpts"), 1);

    std::fs::remove_dir_all(&ckpt).ok();
    dev.shutdown();
}

/// A host wedged inside a ring collective (the `comm_stall` fault delays
/// it past the armed deadline) must not hang the run: its peers trip the
/// deadline, poison the abort flag, and the error names the stalled
/// collective point so the operator knows *where* the mesh wedged.
#[test]
fn comm_stall_trips_deadline_and_names_the_stalled_point() {
    let _guard = FaultGuard::acquire();
    let arts = Artifacts::load_default().unwrap();
    let dev = DeviceHandle::spawn().unwrap();

    let mut cfg = TrainerConfig::quick("t5-nano-dec", 3);
    cfg.mesh = Mesh::new(2, 1);
    // Host 0 sleeps 2 s just before the step-1 gradient sync; the 150 ms
    // deadline fires on host 1 long before the sleeper shows up.
    faults::arm(FaultPlan::new(vec![Fault::CommStall { host: 0, step: 1, ms: 2_000 }]));
    t5x::collectives::set_comm_deadline_ms(150);

    let t = Trainer::new(&arts, &dev, cfg).unwrap();
    let err = t
        .train(&BatchSource::Synthetic { seed: 1 })
        .expect_err("stalled collective must fail, not hang");
    let msg = format!("{err:#}");
    assert!(msg.contains("collective deadline"), "no deadline report in: {msg}");
    assert!(msg.contains("coll/"), "stalled point not named in: {msg}");
    assert!(msg.contains("stalled"), "{msg}");

    dev.shutdown();
}

/// Replica death under load: whichever of the two replicas pulls the
/// poisoned request panics; that request fails with an explicit
/// [`ServeOutcome::Failed`], /healthz drops to `degraded` with the dead
/// replica named, and the survivor keeps completing new work.
#[test]
fn replica_death_leaves_survivors_serving_and_healthz_degraded() {
    let _guard = FaultGuard::acquire();
    let arts = Artifacts::load_default().unwrap();
    let dev = DeviceHandle::spawn().unwrap();
    let params = t5x::model::init_params(arts.model("t5-nano-dec").unwrap(), 3);
    let engine0 = InferEngine::new(&arts, &dev, "t5-nano-dec", &params, -1).unwrap();
    let engine1 = engine0.replica();

    // Poison request 42 on *both* replicas: whichever pulls it dies.
    faults::arm(FaultPlan::new(vec![
        Fault::ReplicaPanic { replica: 0, request: 42 },
        Fault::ReplicaPanic { replica: 1, request: 42 },
    ]));
    let gw = Gateway::launch(vec![engine0, engine1], GatewayConfig::default());
    assert_eq!(gw.alive_replicas(), 2);

    let (tx, rx) = mpsc::channel();
    gw.submit(
        InferRequest { id: 42, prompt: vec![5, 9], max_tokens: 4, method: DecodeMethod::Greedy },
        SubmitOpts::default(),
        tx.clone(),
    )
    .unwrap();
    match rx.recv_timeout(Duration::from_secs(60)).unwrap() {
        ServeOutcome::Failed { client_id: 42, error } => {
            assert!(error.contains("replica"), "{error}");
        }
        other => panic!("poisoned request must fail explicitly, got {other:?}"),
    }

    // The dead replica is reflected in health the moment the flush runs.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while gw.alive_replicas() != 1 {
        assert!(std::time::Instant::now() < deadline, "replica never marked dead");
        std::thread::sleep(Duration::from_millis(5));
    }
    let h = gw.healthz_json();
    assert_eq!(h.get("status").unwrap().as_str(), Some("degraded"));
    assert_eq!(h.get("replicas_alive").unwrap().as_f64(), Some(1.0));
    let states: Vec<&str> = h
        .get("per_replica")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.get("state").unwrap().as_str().unwrap())
        .collect();
    assert!(states.contains(&"down") && states.contains(&"up"), "{states:?}");
    assert_eq!(gw.counters().get("serve/replica_failures"), 1);

    // N-1 serving: the survivor still completes fresh work.
    for id in 1..=3u64 {
        gw.submit(
            InferRequest { id, prompt: vec![5, 9], max_tokens: 3, method: DecodeMethod::Greedy },
            SubmitOpts::default(),
            tx.clone(),
        )
        .unwrap();
        match rx.recv_timeout(Duration::from_secs(60)).unwrap() {
            ServeOutcome::Done { client_id, result, .. } => {
                assert_eq!(client_id, id);
                assert_eq!(result.tokens.len(), 3);
            }
            other => panic!("survivor must serve request {id}, got {other:?}"),
        }
    }
    let report = gw.shutdown();
    assert_eq!(report.completed, 3);
    dev.shutdown();
}

/// FaultPlan round-trip through the JSON the CLI consumes (`--fault-plan`).
#[test]
fn fault_plan_parses_cli_json() {
    let plan = FaultPlan::parse(
        r#"{"faults": [
            {"kind": "host_panic", "host": 1, "step": 4},
            {"kind": "slow_host", "host": 0, "step": 2, "ms": 50},
            {"kind": "corrupt_checkpoint", "step": 4},
            {"kind": "infeed_source_error", "host": 0, "batch": 3},
            {"kind": "comm_stall", "host": 1, "step": 5, "ms": 100},
            {"kind": "replica_panic", "replica": 0, "request": 42}
        ]}"#,
    )
    .unwrap();
    assert_eq!(plan.len(), 6);
    assert_eq!(plan.fired(), 0);
    assert!(FaultPlan::parse(r#"{"faults": [{"kind": "meteor_strike"}]}"#).is_err());
}

/// The overhead contract: with no plan armed, a hook is one relaxed
/// atomic load. 10M disarmed calls must complete in well under a second —
/// generous enough to never flake, tight enough to catch an accidental
/// mutex or map lookup on the fast path.
#[test]
fn disarmed_hooks_cost_one_atomic_load() {
    let _guard = FaultGuard::acquire();
    let start = std::time::Instant::now();
    for i in 0..10_000_000u64 {
        faults::maybe_inject("trainer/step", 0, i);
    }
    let elapsed = start.elapsed();
    assert!(
        elapsed < Duration::from_millis(900),
        "10M disarmed hook calls took {elapsed:?} — off path is not zero-cost"
    );
}
