//! Infeed (S7): assembles model-feature batches from seqio streams into
//! the positional [`HostTensor`] layout the HLO entrypoints expect, with a
//! per-host background prefetch thread and bounded backpressure — the
//! paper's "prevent bottlenecks when infeeding data" machinery (E9).
//!
//! Each producer thread snapshots its pipeline's [`PipelineState`] at
//! every batch boundary and ships it alongside the batch; the state the
//! trainer observes via [`Infeed::pipeline_state`] therefore corresponds
//! to the batches actually *consumed*, never to batches merely sitting in
//! the prefetch buffer — the property that makes kill-and-resume runs
//! consume the exact same global example sequence.
//!
//! Cost note: a snapshot serializes each buffering op's buffer
//! (`parallel_map` snapshots incrementally — its in-flight *inputs* are
//! serialized without draining the workers), so its per-batch price
//! scales with `shuffle_window`/packer buffer sizes. The trainer-facing
//! streams (deterministic cache reader + converters) are pure positional
//! ops where a snapshot is a handful of counters; pipelines with very
//! large in-memory buffers should keep them upstream of the offline
//! cache job.

use std::sync::Mutex;

use crate::runtime::artifacts::ModelManifest;

/// Transient-source resilience (S10): a panicking stream pull is retried
/// in place this many times (exponential backoff from
/// [`PULL_RETRY_BACKOFF_MS`]) before the failure propagates and trips
/// [`Infeed::failed`] — one flaky read no longer kills a run. Retries are
/// counted into the `train/infeed_retries` counter via
/// [`Infeed::retries`].
const MAX_PULL_RETRIES: u32 = 3;
const PULL_RETRY_BACKOFF_MS: u64 = 10;
use crate::runtime::HostTensor;
use crate::seqio::dataset::{Dataset, PipelineState};
use crate::seqio::{Example, Feature};
use crate::util::json::Json;
use crate::util::threads::{Pipe, PipeReceiver};

/// Assemble one batch: `examples.len()` rows of the manifest's batch
/// features, in manifest order. Panics if a feature is missing or has the
/// wrong length (converters guarantee fixed lengths).
pub fn assemble_batch(m: &ModelManifest, examples: &[Example]) -> Vec<HostTensor> {
    let b = m.batch();
    assert_eq!(examples.len(), b, "expected per-host batch {b}, got {}", examples.len());
    let mut out = Vec::with_capacity(m.batch_features.len());
    for spec in &m.batch_features {
        let l = spec.shape[1];
        if spec.is_int {
            let mut data = Vec::with_capacity(b * l);
            for ex in examples {
                let v = ex
                    .get(&spec.name)
                    .and_then(|f| f.as_ints())
                    .unwrap_or_else(|| panic!("batch missing int feature {}", spec.name));
                assert_eq!(v.len(), l, "feature {} length", spec.name);
                data.extend_from_slice(v);
            }
            out.push(HostTensor::i32(vec![b, l], data));
        } else {
            let mut data = Vec::with_capacity(b * l);
            for ex in examples {
                match ex.get(&spec.name) {
                    Some(Feature::Floats(v)) => {
                        assert_eq!(v.len(), l, "feature {} length", spec.name);
                        data.extend_from_slice(v);
                    }
                    // weights may be emitted as ints by custom tasks
                    Some(Feature::Ints(v)) => {
                        assert_eq!(v.len(), l);
                        data.extend(v.iter().map(|&x| x as f32));
                    }
                    _ => panic!("batch missing float feature {}", spec.name),
                }
            }
            out.push(HostTensor::f32(vec![b, l], data));
        }
    }
    out
}

/// Multi-host prefetching infeed. One background thread per host converts
/// its stream into ready batches through a bounded pipe, pairing each
/// batch with the pipeline state that follows it.
///
/// On a 2-D `data × model` mesh, spawn one stream per *data row*
/// (`num_hosts = mesh.data`): hosts in the same row consume the same
/// batch — the row leader (`model` coordinate 0) pulls from its stream
/// and broadcasts to its model-axis peers
/// ([`crate::collectives::broadcast_batch`]), so pipeline state stays
/// per-row and checkpoints reshard across model-axis changes for free.
pub struct Infeed {
    receivers: Vec<Mutex<PipeReceiver<(Vec<HostTensor>, Json)>>>,
    /// Per host: pipeline state after the last batch *delivered* by
    /// [`Infeed::next`] (initially the stream's starting state).
    states: Vec<Mutex<Json>>,
    /// Set when a producer thread panicked (e.g. the in-stream head
    /// validation of `get_dataset`): [`Infeed::next`] then re-raises
    /// instead of reporting a clean end-of-stream, so a data bug fails the
    /// run loudly rather than producing a silent zero-step "success".
    failed: std::sync::Arc<std::sync::atomic::AtomicBool>,
    /// Tracer slot shared with the (already running) producer threads;
    /// [`Infeed::attach_tracer`] arms per-batch `infeed/batch` spans.
    tracer: std::sync::Arc<std::sync::OnceLock<std::sync::Arc<crate::obs::Tracer>>>,
    /// Per host: batches currently sitting in the prefetch pipe
    /// (producer increments after send, consumer decrements on recv) —
    /// the `train/infeed_queue_depth` gauge.
    depths: Vec<std::sync::Arc<std::sync::atomic::AtomicI64>>,
    /// Total transient-pull retries across all producers (the
    /// `train/infeed_retries` counter).
    retries: std::sync::Arc<std::sync::atomic::AtomicU64>,
}

impl Infeed {
    /// `make_stream(host)` must yield *converted* model-feature examples
    /// for that host (already fixed-length).
    pub fn spawn(
        m: &ModelManifest,
        num_hosts: usize,
        prefetch: usize,
        make_stream: impl Fn(usize) -> Dataset + Send + Sync,
    ) -> Infeed {
        Self::spawn_resumable(m, num_hosts, prefetch, move |h| Ok(make_stream(h)), None)
            .expect("infeed spawn without resume state cannot fail")
    }

    /// Like [`Infeed::spawn`], but the stream builder is fallible (the
    /// [`crate::seqio::get_dataset`] path: registry resolution, split and
    /// feature validation can all error), and every host's freshly built
    /// stream is optionally repositioned to a checkpointed per-host
    /// [`PipelineState`] before production starts (the trainer's
    /// exact-resume path).
    pub fn spawn_resumable(
        m: &ModelManifest,
        num_hosts: usize,
        prefetch: usize,
        make_stream: impl Fn(usize) -> anyhow::Result<Dataset> + Send + Sync,
        resume: Option<&[PipelineState]>,
    ) -> anyhow::Result<Infeed> {
        if let Some(states) = resume {
            anyhow::ensure!(
                states.len() == num_hosts,
                "resume has {} host states, trainer has {num_hosts} hosts",
                states.len()
            );
        }
        let mut receivers = Vec::with_capacity(num_hosts);
        let mut states_out = Vec::with_capacity(num_hosts);
        let batch = m.batch();
        let failed = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let tracer: std::sync::Arc<std::sync::OnceLock<std::sync::Arc<crate::obs::Tracer>>> =
            std::sync::Arc::new(std::sync::OnceLock::new());
        let retries = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        let mut depths = Vec::with_capacity(num_hosts);
        for host in 0..num_hosts {
            let (tx, rx) = Pipe::bounded(prefetch.max(1));
            let mut stream = make_stream(host)
                .map_err(|e| anyhow::anyhow!("building host {host} stream: {e}"))?;
            if let Some(states) = resume {
                stream
                    .restore(&states[host])
                    .map_err(|e| anyhow::anyhow!("restoring host {host} stream: {e}"))?;
            }
            let start_state = stream.state().0;
            states_out.push(Mutex::new(start_state));
            let manifest = m.clone();
            let failed_flag = failed.clone();
            let tracer_slot = tracer.clone();
            let retry_ctr = retries.clone();
            let depth = std::sync::Arc::new(std::sync::atomic::AtomicI64::new(0));
            depths.push(depth.clone());
            std::thread::Builder::new()
                .name(format!("infeed-{host}"))
                .spawn(move || {
                    // `tx` stays owned by this outer scope: the failure
                    // flag is set BEFORE the sender drops, so a consumer
                    // observing the disconnect always sees the flag.
                    let tx_ref = &tx;
                    let produce = std::panic::AssertUnwindSafe(move || {
                        let track = format!("infeed-{host}");
                        let mut buf = Vec::with_capacity(batch);
                        let mut batches_done: u64 = 0;
                        // Per-batch span window: stream pulls + assembly +
                        // state snapshot (send-side backpressure excluded,
                        // so span time is real producer work).
                        let mut batch_t0 = std::time::Instant::now();
                        while let Some(ex) =
                            pull_with_retry(&mut stream, host, batches_done, &retry_ctr)
                        {
                            buf.push(ex);
                            if buf.len() == batch {
                                let assembled = assemble_batch(&manifest, &buf);
                                buf.clear();
                                // Snapshot at the batch boundary: the state
                                // a consumer resumes from after this batch.
                                let state = stream.state().0;
                                if let Some(t) = tracer_slot.get() {
                                    t.complete(
                                        &track,
                                        "infeed/batch",
                                        batch_t0,
                                        std::time::Instant::now(),
                                        vec![("host", crate::obs::ArgValue::Num(host as f64))],
                                    );
                                }
                                if !tx_ref.send((assembled, state)) {
                                    return; // trainer hung up
                                }
                                depth.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                                batches_done += 1;
                                batch_t0 = std::time::Instant::now();
                            }
                        }
                        // drop partial tail batch (seqio drop_remainder=True)
                    });
                    if std::panic::catch_unwind(produce).is_err() {
                        failed_flag.store(true, std::sync::atomic::Ordering::SeqCst);
                    }
                    drop(tx);
                })
                .expect("spawn infeed thread");
            receivers.push(Mutex::new(rx));
        }
        Ok(Infeed { receivers, states: states_out, failed, tracer, depths, retries })
    }

    /// Arm per-batch producer spans. Callable after the producer threads
    /// are already running (the trainer attaches its tracer at train
    /// start); first writer wins.
    pub fn attach_tracer(&self, t: std::sync::Arc<crate::obs::Tracer>) {
        let _ = self.tracer.set(t);
    }

    /// Batches currently buffered in host `h`'s prefetch pipe (the
    /// `train/infeed_queue_depth` gauge; approximate during handoff).
    pub fn queue_depth(&self, host: usize) -> i64 {
        self.depths[host].load(std::sync::atomic::Ordering::Relaxed).max(0)
    }

    /// Blocking fetch of host `h`'s next batch; None when the stream ends
    /// — including when the producer died abnormally, so that every mesh
    /// rank winds down through the ordinary exhaustion path (panicking
    /// here would strand peers mid-collective). Callers must check
    /// [`Infeed::failed`] after the loop; the trainer turns it into an
    /// error instead of a silent zero-step "success".
    pub fn next(&self, host: usize) -> Option<Vec<HostTensor>> {
        self.next_inner(host, None)
    }

    /// [`Infeed::next`], counting consumer stalls: whenever the prefetch
    /// pipe is empty and this call has to block for a producer (the
    /// "infeed-bound" signature), `train/infeed_starved_steps` is
    /// incremented on `counters`. End-of-stream blocking is not counted.
    pub fn next_counted(
        &self,
        host: usize,
        counters: &crate::metrics::CounterSet,
    ) -> Option<Vec<HostTensor>> {
        self.next_inner(host, Some(counters))
    }

    fn next_inner(
        &self,
        host: usize,
        counters: Option<&crate::metrics::CounterSet>,
    ) -> Option<Vec<HostTensor>> {
        let rx = self.receivers[host].lock().unwrap();
        let item = match rx.try_recv() {
            Some(it) => Some(it),
            None => {
                // Pipe empty: block on the producer. Only count it as a
                // starved step if a batch eventually arrives (a clean
                // end-of-stream wait is not starvation).
                let it = rx.recv();
                if it.is_some() {
                    if let Some(c) = counters {
                        c.inc("train/infeed_starved_steps");
                    }
                }
                it
            }
        };
        drop(rx);
        match item {
            Some((batch, state)) => {
                self.depths[host].fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
                *self.states[host].lock().unwrap() = state;
                Some(batch)
            }
            None => None,
        }
    }

    /// True if any producer thread panicked (e.g. the in-stream head
    /// validation of `get_dataset`) rather than ending cleanly.
    pub fn failed(&self) -> bool {
        self.failed.load(std::sync::atomic::Ordering::SeqCst)
    }

    /// Total transient stream-pull retries across all producer threads
    /// (exported by the trainer as `train/infeed_retries`).
    pub fn retries(&self) -> u64 {
        self.retries.load(std::sync::atomic::Ordering::SeqCst)
    }

    /// Pipeline state of host `h` as of its last consumed batch. Saved in
    /// checkpoints so a restarted run resumes the exact example sequence.
    pub fn pipeline_state(&self, host: usize) -> PipelineState {
        PipelineState(self.states[host].lock().unwrap().clone())
    }
}

/// One stream pull with bounded in-place retries: a panic inside the
/// source (or an injected `infeed_source_error` keyed by this host's
/// produced-batch index) is caught and the pull retried up to
/// [`MAX_PULL_RETRIES`] times with exponential backoff before the final
/// panic is allowed to propagate (tripping `Infeed::failed` as before).
/// Retry is best-effort for real sources — the stream must tolerate a
/// re-issued `next` after an internal panic, which positional
/// cache/synthetic readers do.
fn pull_with_retry(
    stream: &mut Dataset,
    host: usize,
    batch_index: u64,
    retry_ctr: &std::sync::atomic::AtomicU64,
) -> Option<Example> {
    let mut attempt: u32 = 0;
    loop {
        let pull = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if crate::faults::infeed_error(host, batch_index) {
                panic!("fault injected: infeed_source_error(host={host}, batch={batch_index})");
            }
            stream.next()
        }));
        match pull {
            Ok(ex) => return ex,
            Err(p) => {
                attempt += 1;
                if attempt > MAX_PULL_RETRIES {
                    std::panic::resume_unwind(p);
                }
                retry_ctr.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                eprintln!(
                    "warning: infeed host {host} batch {batch_index}: source pull \
                     failed (attempt {attempt}/{MAX_PULL_RETRIES}), retrying"
                );
                std::thread::sleep(std::time::Duration::from_millis(
                    PULL_RETRY_BACKOFF_MS << (attempt - 1),
                ));
            }
        }
    }
}

/// A synthetic random-token batch source (tests/benches that don't need a
/// real pipeline). Deterministic per (seed, host, step).
pub fn synthetic_batch(m: &ModelManifest, seed: u64, host: usize, step: u64) -> Vec<HostTensor> {
    use crate::util::rng::Pcg64;
    let b = m.batch();
    let l = m.seq_len();
    let v = m.vocab() as u64;
    let mut rng = Pcg64::new(seed).fold_in(host as u64).fold_in(step);
    let tgt: Vec<i32> = (0..b * l).map(|_| (2 + rng.next_below(v - 2)) as i32).collect();
    let mut dec_in = vec![0i32; b * l];
    for i in 0..b {
        for j in 1..l {
            dec_in[i * l + j] = tgt[i * l + j - 1];
        }
    }
    let weights = vec![1.0f32; b * l];
    let mut out = Vec::new();
    if m.arch == "encdec" {
        let enc: Vec<i32> =
            (0..b * l).map(|_| (2 + rng.next_below(v - 2)) as i32).collect();
        out.push(HostTensor::i32(vec![b, l], enc));
    }
    out.push(HostTensor::i32(vec![b, l], dec_in));
    out.push(HostTensor::i32(vec![b, l], tgt));
    out.push(HostTensor::f32(vec![b, l], weights));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;
    use crate::seqio::ints_example;

    fn converted_example(m: &ModelManifest, val: i32) -> Example {
        let l = m.seq_len();
        let mut ex = ints_example(&[
            ("decoder_input_tokens", vec![val; l]),
            ("decoder_target_tokens", vec![val; l]),
        ]);
        ex.insert(
            "decoder_loss_weights".into(),
            Feature::Floats(vec![1.0; l]),
        );
        ex
    }

    #[test]
    fn assemble_shapes_and_order() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let exs: Vec<Example> = (0..m.batch() as i32).map(|i| converted_example(m, i)).collect();
        let batch = assemble_batch(m, &exs);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].shape, vec![m.batch(), m.seq_len()]);
        // row i filled with i
        assert_eq!(batch[1].as_i32()[m.seq_len()], 1);
    }

    #[test]
    fn infeed_prefetches_per_host() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let b = m.batch();
        let infeed = Infeed::spawn(m, 2, 2, |host| {
            let m2 = m.clone();
            Dataset::new(
                (0..(b * 3) as i32).map(move |i| converted_example(&m2, i + 100 * host as i32)),
            )
        });
        // 3 batches per host then end-of-stream
        for host in 0..2 {
            for _ in 0..3 {
                let batch = infeed.next(host).unwrap();
                let first = batch[0].as_i32()[0];
                assert_eq!(first >= 100 * host as i32, true);
            }
            assert!(infeed.next(host).is_none());
        }
    }

    #[test]
    fn starvation_counter_counts_blocking_pulls() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let b = m.batch();
        // Deliberately slow source: every example costs 5ms, so the
        // consumer always drains the pipe and blocks.
        let infeed = Infeed::spawn(m, 1, 1, |_| {
            let m2 = m.clone();
            Dataset::new((0..(b * 2) as i32).map(move |i| {
                std::thread::sleep(std::time::Duration::from_millis(5));
                converted_example(&m2, i)
            }))
        });
        let c = crate::metrics::CounterSet::new();
        assert!(infeed.next_counted(0, &c).is_some());
        assert!(infeed.next_counted(0, &c).is_some());
        assert!(infeed.next_counted(0, &c).is_none(), "stream ends after 2 batches");
        assert!(
            c.get("train/infeed_starved_steps") >= 1,
            "slow producer must register starvation, got {}",
            c.get("train/infeed_starved_steps")
        );
        assert_eq!(infeed.queue_depth(0), 0);
    }

    #[test]
    fn transient_pull_panic_retries_and_recovers() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let b = m.batch();
        let tripped = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        // One panic on the first pull; the panicking element is consumed
        // by the underlying iterator, so provision one spare example.
        let infeed = Infeed::spawn(m, 1, 1, |_| {
            let m2 = m.clone();
            let tripped = tripped.clone();
            Dataset::new((0..(b * 2 + 1) as i32).map(move |i| {
                if i == 0 && !tripped.swap(true, std::sync::atomic::Ordering::SeqCst) {
                    panic!("transient source hiccup");
                }
                converted_example(&m2, i)
            }))
        });
        assert!(infeed.next(0).is_some());
        assert!(infeed.next(0).is_some());
        assert!(infeed.next(0).is_none());
        assert!(!infeed.failed(), "a retried transient error must not fail the infeed");
        assert!(infeed.retries() >= 1, "retry counter must record the recovery");
    }

    #[test]
    fn persistent_pull_panic_exhausts_retries_and_fails() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let b = m.batch();
        let infeed = Infeed::spawn(m, 1, 1, |_| {
            Dataset::new((0..(b * 2) as i32).map(move |i| -> Example {
                panic!("permanent source failure at {i}");
            }))
        });
        assert!(infeed.next(0).is_none(), "a dead producer ends the stream");
        assert!(infeed.failed(), "exhausted retries must trip the failure flag");
        assert_eq!(infeed.retries(), MAX_PULL_RETRIES as u64);
    }

    #[test]
    fn synthetic_batches_deterministic() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let a = synthetic_batch(m, 1, 0, 5);
        let b = synthetic_batch(m, 1, 0, 5);
        let c = synthetic_batch(m, 1, 1, 5);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // shift invariant
        let dec_in = a[0].as_i32();
        let tgt = a[1].as_i32();
        assert_eq!(dec_in[1], tgt[0]);
    }
}
