//! Integration: the full seqio pipeline (Figure 2 + §3.2 properties, E2 /
//! E5-E8) — task -> preprocess -> cache -> deterministic read -> feature
//! convert, across hosts and restarts.

use std::sync::Arc;

use t5x::seqio::cache::{cache_task, CacheConfig};
use t5x::seqio::deterministic::DeterministicPipeline;
use t5x::seqio::feature_converters::{lengths, EncDecConverter, FeatureConverter, LmConverter};
use t5x::seqio::mixture::Mixture;
use t5x::seqio::preprocessors::{AppendEos, ChunkTokens, SpanCorruption, Tokenize};
use t5x::seqio::source::SyntheticTextSource;
use t5x::seqio::task::Task;
use t5x::seqio::vocab::{BpeVocabulary, ByteVocabulary, Vocabulary, EOS_ID};
use t5x::util::stats::lag1_autocorrelation;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("seqio_int_{}_{tag}", std::process::id()))
}

/// Build the canonical pretraining task: synthetic corpus -> tokenize ->
/// chunk -> span corruption (T5 objective).
fn span_corruption_task(name: &str, docs: usize) -> Arc<Task> {
    let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
    Task::builder(name)
        .source(Arc::new(SyntheticTextSource::new(11, docs)))
        .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
        .preprocessor(Arc::new(ChunkTokens::new("targets", 96)))
        .preprocessor(Arc::new(SpanCorruption::new(vocab.clone())))
        .preprocessor(Arc::new(AppendEos::new(&["targets"])))
        .output_feature("inputs", vocab.clone(), false)
        .output_feature("targets", vocab, true)
        .build()
}

#[test]
fn figure2_task_pipeline_end_to_end() {
    // One task serves BOTH architectures via different converters — the
    // §3.1 claim that feature converters decouple tasks from models.
    let task = span_corruption_task("fig2_task", 30);
    let examples = task.dataset(5, 0, 1).collect_vec();
    assert!(examples.len() >= 30);
    for ex in examples.iter().take(10) {
        task.validate_example(ex).unwrap();
        let tgt = ex["targets"].as_ints().unwrap();
        assert_eq!(*tgt.last().unwrap(), EOS_ID);
    }
    let tl = lengths(&[("inputs", 96), ("targets", 48)]);
    let encdec = EncDecConverter.convert_example(&examples[0], &tl);
    assert_eq!(encdec["encoder_input_tokens"].as_ints().unwrap().len(), 96);
    assert_eq!(encdec["decoder_target_tokens"].as_ints().unwrap().len(), 48);
    let lm = LmConverter.convert_example(&examples[0], &tl);
    assert!(lm.contains_key("decoder_target_tokens"));
    assert!(!lm.contains_key("encoder_input_tokens"));
}

#[test]
fn deterministic_cache_properties_reproducible_and_recoverable() {
    let task = span_corruption_task("det_props_task", 64);
    let dir = tmpdir("props");
    let meta = cache_task(
        &task,
        &dir,
        &CacheConfig { num_shards: 8, seed: 3, workers: 4 },
    )
    .unwrap();
    assert!(meta.num_examples >= 64);
    let p = DeterministicPipeline::open(&dir).unwrap();

    // E5 Reproducibility: two readers agree exactly.
    let a = p.host_stream(0, 1, 0, false).collect_vec();
    let b = p.host_stream(0, 1, 0, false).collect_vec();
    assert_eq!(a, b);

    // E7 Sharding: disjoint, exhaustive, order-preserving.
    let h: Vec<Vec<_>> = (0..4)
        .map(|host| p.host_stream(host, 4, 0, false).collect_vec())
        .collect();
    let total: usize = h.iter().map(|v| v.len()).sum();
    assert_eq!(total, meta.num_examples);
    let mut all_indices: Vec<i32> = h
        .iter()
        .flatten()
        .map(|e| e["_index"].as_ints().unwrap()[0])
        .collect();
    all_indices.sort();
    assert_eq!(all_indices, (0..meta.num_examples as i32).collect::<Vec<_>>());

    // E6 Recoverability: resume at k == continuous[k..], for every host.
    for host in 0..4 {
        let full = p.host_stream(host, 4, 0, false).collect_vec();
        let resumed = p.host_stream(host, 4, 5, false).collect_vec();
        assert_eq!(resumed.as_slice(), &full[5..]);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn global_shuffle_decorrelates_documents() {
    // E8: before shuffling, chunks of the same document are adjacent
    // (high lag-1 autocorrelation of doc_id); the cache job's global
    // shuffle destroys that correlation.
    let task = span_corruption_task("shuffle_task", 40);
    let unshuffled: Vec<f64> = task
        .dataset(1, 0, 1)
        .collect_vec()
        .iter()
        .map(|e| e["doc_id"].as_ints().unwrap()[0] as f64)
        .collect();
    let rho_before = lag1_autocorrelation(&unshuffled);

    let dir = tmpdir("shuffle");
    cache_task(&task, &dir, &CacheConfig { num_shards: 4, seed: 1, workers: 2 }).unwrap();
    let p = DeterministicPipeline::open(&dir).unwrap();
    let shuffled: Vec<f64> = p
        .global_stream()
        .collect_vec()
        .iter()
        .map(|e| e["doc_id"].as_ints().unwrap()[0] as f64)
        .collect();
    let rho_after = lag1_autocorrelation(&shuffled);
    assert!(rho_before > 0.5, "expected correlated raw stream, rho={rho_before}");
    assert!(rho_after.abs() < 0.2, "shuffle left correlation rho={rho_after}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bpe_vocabulary_through_task() {
    // Train BPE on the synthetic corpus, then run the task with it:
    // ids must roundtrip through decode.
    let source = SyntheticTextSource::new(21, 50);
    use t5x::seqio::source::DataSource;
    let corpus: Vec<String> = source
        .all()
        .collect_vec()
        .iter()
        .map(|e| e["text"].as_text().unwrap().to_string())
        .collect();
    let bpe = Arc::new(BpeVocabulary::train(corpus.iter().cloned(), 400, 16));
    let text = &corpus[0];
    let ids = bpe.encode(text);
    assert!(ids.len() < text.len() / 2, "BPE should compress the corpus");
    assert_eq!(bpe.decode(&ids), *text);
}

#[test]
fn pipeline_state_resumes_cached_stream_mid_epoch() {
    // §3.2 Recoverability via op state: snapshot a repeating host stream
    // at arbitrary cut points (including across an epoch boundary) and the
    // restored stream's `_index` audit sequence must continue exactly
    // where the uninterrupted stream's does.
    let task = span_corruption_task("state_resume_task", 48);
    let dir = tmpdir("state_resume");
    cache_task(&task, &dir, &CacheConfig { num_shards: 4, seed: 2, workers: 2 }).unwrap();
    let p = DeterministicPipeline::open(&dir).unwrap();
    let per_host = p.host_examples(1, 2);
    let total = per_host * 2 + 3; // crosses two epoch boundaries

    let idx_of = |e: &t5x::seqio::Example| e["_index"].as_ints().unwrap()[0];
    let mut full = p.host_stream(1, 2, 0, true);
    let all: Vec<i32> = (&mut full).take(total).map(|e| idx_of(&e)).collect();

    for cut in [0usize, 1, per_host - 1, per_host + 5, 2 * per_host + 1] {
        let mut first = p.host_stream(1, 2, 0, true);
        let head: Vec<i32> = (&mut first).take(cut).map(|e| idx_of(&e)).collect();
        let snap = first.state();

        let mut resumed = p.host_stream(1, 2, 0, true);
        resumed.restore(&snap).unwrap();
        let tail: Vec<i32> =
            (&mut resumed).take(total - cut).map(|e| idx_of(&e)).collect();

        let mut joined = head;
        joined.extend(tail);
        assert_eq!(joined, all, "cut={cut}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn parallel_map_preprocessing_is_order_identical_to_serial() {
    // Acceptance: parallel_map(4) yields byte-identical example order to
    // serial map on a tokenize-heavy preprocessor, regardless of worker
    // scheduling.
    use t5x::seqio::source::DataSource;
    use t5x::seqio::{Example, Feature};

    let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
    let heavy = move |mut ex: Example| {
        if let Some(Feature::Text(t)) = ex.get("text") {
            // tokenize several times to make the map genuinely hot
            let mut ids = vocab.encode(t);
            for _ in 0..8 {
                let txt = vocab.decode(&ids);
                ids = vocab.encode(&txt);
            }
            ex.insert("targets".into(), Feature::Ints(ids));
        }
        ex
    };

    let source = SyntheticTextSource::new(9, 120);
    let serial = source.all().map(heavy.clone()).collect_vec();
    for workers in [1usize, 2, 4] {
        let par = source.all().parallel_map(heavy.clone(), workers).collect_vec();
        assert_eq!(par, serial, "workers={workers}");
    }
}

#[test]
fn mixture_over_cached_tasks() {
    // E10: a mixture of two tasks keeps rates and examples flowing.
    let t1 = span_corruption_task("mix_a", 40);
    let t2 = span_corruption_task("mix_b", 40);
    let m = Mixture::new("mix", vec![(t1, 0.8), (t2, 0.2)]).unwrap();
    let sample = m.dataset(7, 0, 1).take(100).collect_vec();
    let a_count = sample
        .iter()
        .filter(|e| e["_task"].as_text() == Some("mix_a"))
        .count();
    assert!(a_count > 55 && a_count < 98, "a_count={a_count}");
}
