//! Integration: sharded parameters end-to-end — bit-identity of 2-D
//! sharded training against the replicated baseline, the per-host memory
//! claim of §2.2, distributed (no-gather) checkpoint layout, and the
//! save-on-4x2 / restore-on-2x2 resharding round-trip with params,
//! optimizer state, and pipeline state.

use std::sync::Arc;

use t5x::checkpoint::{open_layout, ArrayLayout, CheckpointManager};
use t5x::optim::Schedule;
use t5x::partitioning::{cost, ExecMode, Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle, HostTensor};
use t5x::seqio::cache::{cache_task, CacheConfig};
use t5x::trainer::recipes;
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};

fn cfg_mesh(mesh: Mesh, strategy: ParamStrategy, steps: u64) -> TrainerConfig {
    let mut cfg = TrainerConfig::quick("t5-nano-dec", steps);
    cfg.mesh = mesh;
    cfg.strategy = strategy;
    cfg.seed = 17;
    cfg.schedule = Schedule::Constant(1e-3);
    cfg
}

#[test]
fn sharded_2d_training_bit_identical_to_replicated_baseline() {
    // A 2x2 TwoD mesh consumes the same two data-row batches as the 2x1
    // fully replicated baseline. Init is init-then-slice, 2-rank ring sums
    // are commutative (hence exact), parameter gathers are pure data
    // movement, and Adam is elementwise — so 5 steps must agree
    // BIT-FOR-BIT, in both the loss trajectory and the final parameters.
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();

    let base = Trainer::new(
        &arts,
        &device,
        cfg_mesh(Mesh::new(2, 1), ParamStrategy::OneD, 5),
    )
    .unwrap();
    let sharded = Trainer::new(
        &arts,
        &device,
        cfg_mesh(Mesh::new(2, 2), ParamStrategy::TwoD, 5),
    )
    .unwrap();

    let s_base = base.train(&BatchSource::Synthetic { seed: 21 }).unwrap();
    let s_shard = sharded.train(&BatchSource::Synthetic { seed: 21 }).unwrap();
    assert_eq!(s_base.history.len(), 5);
    for (a, b) in s_base.history.iter().zip(&s_shard.history) {
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "step {}: baseline {} vs sharded {}",
            a.step,
            a.loss,
            b.loss
        );
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    }
    // gathered parameters are byte-identical
    let p_base = base.params();
    let p_shard = sharded.params();
    for (name, t) in &p_base {
        assert_eq!(t, &p_shard[name], "param {name} diverged");
    }
    // and the sharded run moved bytes on BOTH mesh axes
    assert!(s_shard.data_axis_bytes > 0);
    assert!(s_shard.model_axis_bytes > 0);
    assert_eq!(s_base.model_axis_bytes, 0);
    device.shutdown();
}

#[test]
fn per_host_memory_bounded_by_mesh_division() {
    // Acceptance: with TwoD on a d x m mesh, per-host resident parameter
    // and optimizer floats are <= total/(d*m) + the largest single
    // gathered parameter (the slack absorbs blocks that only one axis can
    // shard plus the replicated residue).
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    for mesh in [Mesh::new(2, 2), Mesh::new(4, 2)] {
        let t = Trainer::new(
            &arts,
            &device,
            cfg_mesh(mesh, ParamStrategy::TwoD, 1),
        )
        .unwrap();
        let total = t.plan.total_elems();
        let bound = total / mesh.num_hosts() + t.plan.largest_param_elems();
        for host in 0..mesh.num_hosts() {
            let params = t.resident_param_floats(host);
            let opt = t.optimizer_state_floats(host);
            assert!(
                params <= bound,
                "mesh {mesh} host {host}: {params} resident param floats > bound {bound}"
            );
            // Adam: 2 optimizer floats per resident parameter float
            assert!(
                opt <= 2 * bound,
                "mesh {mesh} host {host}: {opt} optimizer floats > bound {}",
                2 * bound
            );
        }
    }
    device.shutdown();
}

#[test]
fn resharding_round_trip_4x2_to_2x2() {
    // Save on a 4x2 mesh from a real cached data pipeline, restore on
    // 2x2 (and sanity-check 8x1): parameters and elementwise optimizer
    // state reshard exactly; pipeline state restores exactly when the
    // data-row count matches and falls back to coarse positioning when it
    // does not.
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let pid = std::process::id();
    let cache = std::env::temp_dir().join(format!("reshard_cache_{pid}"));
    let ckpt = std::env::temp_dir().join(format!("reshard_ckpt_{pid}"));
    let _ = std::fs::remove_dir_all(&ckpt);
    let task = recipes::lm_task("reshard_lm", 400, m.seq_len(), 42);
    cache_task(&task, &cache, &CacheConfig { num_shards: 8, seed: 5, workers: 2 }).unwrap();

    let infeed = |rows: usize,
                  start_step: u64,
                  resume: Option<&[t5x::seqio::dataset::PipelineState]>| {
        let cached: Arc<dyn t5x::seqio::provider::DatasetProvider> =
            Arc::new(t5x::seqio::provider::CachedTask::open(&cache, Some(&task)).unwrap());
        recipes::provider_infeed(m, cached, "train", rows, 4, start_step, 5, resume).unwrap()
    };

    // 2 steps on 4x2, checkpoint at step 2
    let mut cfg = cfg_mesh(Mesh::new(4, 2), ParamStrategy::TwoD, 2);
    cfg.checkpoint_every = Some(2);
    cfg.checkpoint_dir = Some(ckpt.clone());
    let t_save = Trainer::new(&arts, &device, cfg).unwrap();
    t_save
        .train(&BatchSource::Infeed(infeed(4, 0, None)))
        .unwrap();
    let saved_params = t_save.params();

    let mgr = CheckpointManager::new(&ckpt);
    assert_eq!(mgr.latest(), Some(2));
    assert_eq!(mgr.saved_mesh(2).unwrap(), Some(Mesh::new(4, 2)));
    // the checkpoint is genuinely sharded on disk: at least one parameter
    // uses the block-grid layout (written by its owners, never gathered)
    let proot = ckpt.join("ckpt-00000002").join("params");
    let any_blocks = m.params.iter().any(|p| {
        matches!(open_layout(&proot, &p.name), Ok(ArrayLayout::Blocks { .. }))
    });
    assert!(any_blocks, "expected at least one block-layout parameter");
    // eval/infer load through the same path: a plain full restore
    // reassembles every layout
    let (full, _) = mgr.restore(2).unwrap();
    assert_eq!(full, saved_params);

    // ---- restore on 2x2: params + optimizer reshard exactly ----
    let mut t_2x2 =
        Trainer::new(&arts, &device, cfg_mesh(Mesh::new(2, 2), ParamStrategy::TwoD, 2)).unwrap();
    assert_eq!(t_2x2.restore_latest(&ckpt).unwrap(), 2);
    assert_eq!(t_2x2.params(), saved_params);
    // 4 saved row states vs 2 rows -> coarse fallback
    assert!(t_2x2.restored_pipeline.is_none());
    // optimizer moments reshard: reassemble Adam's m for every param on
    // both topologies and compare
    for e in &t_save.plan.entries {
        let gather = |t: &Trainer| -> HostTensor {
            let entry = t.plan.entry(&e.name).unwrap();
            let shards: Vec<HostTensor> = (0..t.config.mesh.num_hosts())
                .map(|h| {
                    HostTensor::f32(
                        entry.shard_shape.clone(),
                        t.optimizer_slot(h, &e.name, "m").unwrap(),
                    )
                })
                .collect();
            t.partitioner.unshard(&shards, &entry.spec)
        };
        assert_eq!(gather(&t_save), gather(&t_2x2), "adam m for {}", e.name);
    }
    // the restored trainer continues training from the coarse position
    let resumed = t_2x2
        .train(&BatchSource::Infeed(infeed(2, t_2x2.start_step, None)))
        .unwrap();
    assert_eq!(resumed.history.first().unwrap().step, 2);
    assert!(resumed.final_loss().is_finite());

    // ---- restore on 8x1 too (pure data-parallel) ----
    let mut t_8x1 =
        Trainer::new(&arts, &device, cfg_mesh(Mesh::new(8, 1), ParamStrategy::TwoD, 1)).unwrap();
    assert_eq!(t_8x1.restore_latest(&ckpt).unwrap(), 2);
    assert_eq!(t_8x1.params(), saved_params);

    // ---- same-mesh restore keeps the exact pipeline state ----
    let mut t_same =
        Trainer::new(&arts, &device, cfg_mesh(Mesh::new(4, 2), ParamStrategy::TwoD, 1)).unwrap();
    assert_eq!(t_same.restore_latest(&ckpt).unwrap(), 2);
    let states = t_same.restored_pipeline.clone().expect("same row count: exact states");
    assert_eq!(states.len(), 4);
    assert_eq!(t_same.params(), saved_params);
    let cont = t_same
        .train(&BatchSource::Infeed(infeed(4, 0, Some(&states))))
        .unwrap();
    assert_eq!(cont.history.first().unwrap().step, 2);

    std::fs::remove_dir_all(&cache).ok();
    std::fs::remove_dir_all(&ckpt).ok();
    device.shutdown();
}

/// Relative L2 distance between two same-shaped tensors.
fn rel_l2(a: &HostTensor, b: &HostTensor) -> f64 {
    assert_eq!(a.shape, b.shape);
    let (av, bv) = (a.as_f32(), b.as_f32());
    let mut num = 0f64;
    let mut den = 0f64;
    for (x, y) in av.iter().zip(bv.iter()) {
        let d = (*x - *y) as f64;
        num += d * d;
        den += (*x as f64) * (*x as f64);
    }
    (num / den.max(1e-12)).sqrt()
}

#[test]
fn block_matches_gather_on_2x2_and_1x4() {
    // The block program decomposes the train step into 12 segments with
    // model-axis all-reduces at the Megatron f/g points, while gather mode
    // runs the monolithic HLO on transiently reconstructed full params.
    // Both compute the same math up to floating-point association at the
    // cross-shard reduction points (the segment HLOs are validated
    // bitwise against the monolithic step at export time at degree 2, and
    // to ~1e-6 relative on gradients at degree 4), so 5 training steps
    // must agree tightly in both the loss trajectory and final params.
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    for (mesh, strategy) in [
        (Mesh::new(2, 2), ParamStrategy::TwoD),
        (Mesh::new(1, 4), ParamStrategy::OneD),
    ] {
        assert!(
            m.supports_block_exec(mesh.model),
            "re-export artifacts (make artifacts): no block contract at degree {}",
            mesh.model
        );
        let gather = Trainer::new(&arts, &device, cfg_mesh(mesh, strategy, 5)).unwrap();
        assert_eq!(gather.exec_mode, ExecMode::Gather, "quick() defaults to gather");
        let mut cfg = cfg_mesh(mesh, strategy, 5);
        cfg.exec_mode = ExecMode::Auto; // auto-select must pick Block here
        let block = Trainer::new(&arts, &device, cfg).unwrap();
        assert_eq!(block.exec_mode, ExecMode::Block, "mesh {mesh}");

        let s_g = gather.train(&BatchSource::Synthetic { seed: 21 }).unwrap();
        let s_b = block.train(&BatchSource::Synthetic { seed: 21 }).unwrap();
        assert_eq!(s_g.history.len(), 5);
        assert_eq!(s_b.history.len(), 5);
        for (a, b) in s_g.history.iter().zip(&s_b.history) {
            let rel = (a.loss - b.loss).abs() / a.loss.abs().max(1.0);
            assert!(
                rel < 1e-4,
                "mesh {mesh} step {}: gather loss {} vs block loss {}",
                a.step,
                a.loss,
                b.loss
            );
        }
        let p_g = gather.params();
        let p_b = block.params();
        for (name, t) in &p_g {
            let rel = rel_l2(t, &p_b[name]);
            assert!(rel < 1e-3, "mesh {mesh} param {name}: rel L2 {rel:.3e}");
        }
        // both modes moved bytes on the model axis; only block's peak
        // param/grad tensor stays at block size (never a full parameter)
        assert!(s_g.model_axis_bytes > 0 && s_b.model_axis_bytes > 0);
        let largest = gather.plan.largest_param_elems();
        assert_eq!(
            gather.peak_param_floats(),
            largest,
            "gather mode materializes the largest full parameter"
        );
        assert!(
            block.peak_param_floats() <= largest / 2,
            "mesh {mesh}: block peak {} floats vs largest full param {largest}",
            block.peak_param_floats()
        );
    }
    device.shutdown();
}

#[test]
fn block_model_axis_traffic_matches_cost_model() {
    // Acceptance: the measured model-axis bytes/step in block mode match
    // the cost model's schedule-derived term. A synthetic source keeps
    // the model axis free of batch-broadcast traffic, so the counters see
    // exactly the manifest's collective schedule (ring all-reduces).
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let mesh = Mesh::new(1, 4);
    let steps = 2u64;
    let mut cfg = cfg_mesh(mesh, ParamStrategy::OneD, steps);
    cfg.exec_mode = ExecMode::Block;
    let t = Trainer::new(&arts, &device, cfg).unwrap();
    let s = t.train(&BatchSource::Synthetic { seed: 3 }).unwrap();
    let per_host = cost::block_schedule_bytes_per_host(m, mesh)
        .expect("block contract present at degree 4");
    let expect = (mesh.num_hosts() as u64 * per_host * steps) as f64;
    let got = s.model_axis_bytes as f64;
    assert!(
        (got - expect).abs() / expect < 0.05,
        "measured model-axis bytes {got} vs cost model {expect}"
    );
    device.shutdown();
}

#[test]
fn microbatched_step_is_bit_identical_to_monolithic_accumulation() {
    // On a 1x1 mesh the data-axis reduce is the identity, so microbatched
    // gradient accumulation must reproduce the monolithic left-fold over
    // the same k batches bit-for-bit. Reference: run the train_step HLO
    // directly on the initial parameters for each microbatch's synthetic
    // batch (batch index = step*k + j) and fold the scalar outputs in
    // microbatch order, exactly like the step runner, then compare the
    // trainer's step-0 loss.
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let (exe, _) =
        device.compile(&m.entrypoint("train_step").unwrap().hlo).unwrap();
    let seed = 77u64;
    for k in [1usize, 2, 4] {
        let mut cfg = cfg_mesh(Mesh::new(1, 1), ParamStrategy::OneD, 1);
        cfg.microbatches = k;
        let t = Trainer::new(&arts, &device, cfg).unwrap();
        let init = t.params();
        let full: Vec<HostTensor> =
            t.plan.entries.iter().map(|e| init[&e.name].clone()).collect();
        let (mut l_acc, mut w_acc) = (0f32, 0f32);
        for j in 0..k as u64 {
            let mut inputs = full.clone();
            inputs.extend(t5x::trainer::infeed::synthetic_batch(m, seed, 0, j));
            let outs = exe.run(inputs).unwrap();
            l_acc += outs[0].first_f32();
            w_acc += outs[1].first_f32();
        }
        let expect = (l_acc / w_acc) as f64;
        let s = t.train(&BatchSource::Synthetic { seed }).unwrap();
        assert_eq!(
            s.history[0].loss.to_bits(),
            expect.to_bits(),
            "k={k}: trainer loss {} vs monolithic accumulation {}",
            s.history[0].loss,
            expect
        );
    }
    device.shutdown();
}

#[test]
fn overlap_on_and_off_are_bit_identical() {
    // The serial and overlapped plans issue the same collective op
    // sequence and accumulate gradients in the same microbatch order —
    // only wall-clock placement of the waits differs — so 5 steps on a
    // 2x2 (TwoD) and a 1x4 (OneD) mesh must agree bit-for-bit in both the
    // loss trajectory and the final parameters, for every k.
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    for (mesh, strategy) in [
        (Mesh::new(2, 2), ParamStrategy::TwoD),
        (Mesh::new(1, 4), ParamStrategy::OneD),
    ] {
        for k in [1usize, 2, 4] {
            let run = |overlap: bool| {
                let mut cfg = cfg_mesh(mesh, strategy, 5);
                cfg.microbatches = k;
                cfg.overlap = overlap;
                let t = Trainer::new(&arts, &device, cfg).unwrap();
                let s = t.train(&BatchSource::Synthetic { seed: 21 }).unwrap();
                (s, t.params())
            };
            let (s_off, p_off) = run(false);
            let (s_on, p_on) = run(true);
            assert_eq!(s_off.history.len(), 5);
            for (a, b) in s_off.history.iter().zip(&s_on.history) {
                assert_eq!(
                    a.loss.to_bits(),
                    b.loss.to_bits(),
                    "mesh {mesh} k={k} step {}: serial {} vs overlapped {}",
                    a.step,
                    a.loss,
                    b.loss
                );
                assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
            }
            for (name, t) in &p_off {
                assert_eq!(t, &p_on[name], "mesh {mesh} k={k} param {name}");
            }
            // same bytes on the wire either way
            assert_eq!(s_off.data_axis_bytes, s_on.data_axis_bytes);
            assert_eq!(s_off.model_axis_bytes, s_on.model_axis_bytes);
            // with real data-axis rings and k > 1, the overlapped run
            // actually hides reduce time under the next microbatch
            if mesh.data > 1 && k > 1 {
                assert!(
                    s_on.overlapped_comm_micros > 0,
                    "mesh {mesh} k={k}: no comm was overlapped"
                );
            }
            assert!(s_on.exposed_comm_micros > 0, "mesh {mesh} k={k}");
        }
    }
    device.shutdown();
}

#[test]
fn microbatched_traffic_matches_overlap_aware_cost_model() {
    // Acceptance: the cost model's microbatch-aware data-axis term matches
    // the measured byte counters — gradient reduces scale with k while the
    // hoisted parameter gathers are paid once per step. A 2x1 mesh keeps
    // the model axis silent so the data-axis counter is exactly the
    // gather + k-fold reduce traffic.
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let mesh = Mesh::new(2, 1);
    let steps = 2u64;
    let link = cost::LinkModel::default();
    let measure = |k: usize| {
        let mut cfg = cfg_mesh(mesh, ParamStrategy::TwoD, steps);
        cfg.microbatches = k;
        cfg.overlap = true;
        let t = Trainer::new(&arts, &device, cfg).unwrap();
        t.train(&BatchSource::Synthetic { seed: 9 }).unwrap()
    };
    for k in [1usize, 2, 4] {
        let est = cost::estimate_exec(
            m,
            mesh,
            ParamStrategy::TwoD,
            t5x::partitioning::ActivationStrategy::OneD,
            link,
            ExecMode::Gather,
            cost::StepShape { microbatches: k, overlap: true },
        );
        let s = measure(k);
        let expect =
            (mesh.num_hosts() as u64 * est.comm_bytes_data_axis * steps) as f64;
        let got = s.data_axis_bytes as f64;
        assert!(
            (got - expect).abs() / expect < 0.05,
            "k={k}: measured data-axis bytes {got} vs cost model {expect}"
        );
        assert_eq!(s.model_axis_bytes, 0);
    }
    device.shutdown();
}

#[test]
fn stale_manifest_auto_falls_back_to_gather_and_forced_block_errors() {
    // A pre-block artifact dir (simulated by clearing the parsed
    // contract) must keep training: Auto resolves to Gather; forcing
    // Block fails loudly, naming the flag that unblocks the run.
    let mut arts = Artifacts::load_default().unwrap();
    arts.models.get_mut("t5-nano-dec").unwrap().block_exec.clear();
    let device = DeviceHandle::spawn().unwrap();
    let mesh = Mesh::new(1, 2);
    let mut cfg = cfg_mesh(mesh, ParamStrategy::OneD, 1);
    cfg.exec_mode = ExecMode::Auto;
    let t = Trainer::new(&arts, &device, cfg).unwrap();
    assert_eq!(t.exec_mode, ExecMode::Gather);
    let s = t.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
    assert!(s.final_loss().is_finite());

    let mut cfg = cfg_mesh(mesh, ParamStrategy::OneD, 1);
    cfg.exec_mode = ExecMode::Block;
    let err = Trainer::new(&arts, &device, cfg).unwrap_err().to_string();
    assert!(err.contains("--exec-mode gather"), "unhelpful error: {err}");
    device.shutdown();
}
