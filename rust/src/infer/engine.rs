//! Continuous-batching inference engine (the serving half of t5x's
//! `InferTask` path, grown into a real scheduler).
//!
//! The model's `decode_logits` HLO has a fixed batch `B` baked in; naive
//! serving runs one request per full-batch call (1/B slot utilization) or
//! waits for the slowest row of a batch to finish (head-of-line blocking).
//! This engine instead treats the `B` rows as *slots*:
//!
//! * a FIFO queue holds submitted [`InferRequest`]s;
//! * before every decode step, free slots are refilled from the queue —
//!   a request admitted at step `s` starts decoding at step `s` while
//!   longer-running rows continue uninterrupted (continuous batching);
//! * a row that emits EOS or reaches its token budget exits immediately,
//!   freeing its slot for the next queued request at the *next* step, not
//!   at the end of the batch.
//!
//! ## Determinism contract
//!
//! Per-row logits from `decode_logits` are independent of the other rows'
//! contents, greedy tokens come from [`decoding::argmax`] (shared with
//! `EvalRunner::greedy_decode`), and sampling draws exactly one RNG value
//! per token from a per-request [`Pcg64`] — so a request's output is
//! byte-identical whether it ran alone or packed with arbitrary neighbors
//! (asserted by `tests/integration_infer.rs`).
//!
//! Metrics flow through [`crate::metrics::CounterSet`]: `infer/steps`,
//! `infer/tokens`, `infer/requests_completed`, `infer/slot_steps_busy`
//! (utilization = busy / (steps * B)), and `infer/refills` (admissions
//! that happened while other requests were mid-flight).

use std::collections::VecDeque;
use std::time::Instant;

use super::decoding::{self, DecodeMethod, Hypothesis};
use crate::metrics::CounterSet;
use crate::model::Params;
use crate::runtime::artifacts::ModelManifest;
use crate::runtime::{Artifacts, DeviceHandle, Executable, HostTensor};
use crate::util::rng::Pcg64;

/// One inference request. `id` is caller-assigned and echoed on the result.
#[derive(Debug, Clone)]
pub struct InferRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_tokens: usize,
    pub method: DecodeMethod,
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct InferResult {
    pub id: u64,
    pub prompt_len: usize,
    /// Generated ids (EOS included when it terminated generation).
    pub tokens: Vec<i32>,
    /// Engine step at which the request entered a batch slot.
    pub started_step: u64,
    /// Engine step after which the request left its slot.
    pub finished_step: u64,
    /// Seconds spent queued before a slot freed up.
    pub queue_seconds: f64,
    /// Submit-to-completion wall time in seconds.
    pub latency_seconds: f64,
}

struct ActiveSlot {
    id: u64,
    prompt_len: usize,
    /// Next decoder position to fill (BOS at 0, prompt at 1..=prompt_len).
    len: usize,
    produced: Vec<i32>,
    max_tokens: usize,
    method: DecodeMethod,
    rng: Option<Pcg64>,
    submitted: Instant,
    admitted: Instant,
    started_step: u64,
}

/// Aggregate serving statistics derived from the engine counters.
#[derive(Debug, Clone)]
pub struct EngineSummary {
    pub steps: u64,
    pub tokens: u64,
    pub completed: u64,
    pub refills: u64,
    /// Mean fraction of batch slots occupied per decode step.
    pub slot_utilization: f64,
    /// Wall time spent inside decode steps.
    pub decode_seconds: f64,
    pub tokens_per_sec: f64,
}

pub struct InferEngine {
    pub manifest: ModelManifest,
    exe: Executable,
    /// Parameter tensors in manifest order. Arc-backed `HostTensor` makes
    /// the per-step `ordered.clone()` O(num_params) pointer bumps, not a
    /// deep copy of the parameter bytes.
    ordered: Vec<HostTensor>,
    eos_id: i32,
    queue: VecDeque<(InferRequest, Instant)>,
    slots: Vec<Option<ActiveSlot>>,
    /// The shared `[B, L]` decoder token buffer, row per slot.
    dec: Vec<i32>,
    steps: u64,
    decode_seconds: f64,
    finished: Vec<InferResult>,
    counters: CounterSet,
}

impl InferEngine {
    pub fn new(
        arts: &Artifacts,
        device: &DeviceHandle,
        model: &str,
        params: &Params,
        eos_id: i32,
    ) -> anyhow::Result<InferEngine> {
        let manifest = arts.model(model)?.clone();
        anyhow::ensure!(
            manifest.arch == "decoder",
            "InferEngine serves decoder-only models; {} is {}",
            model,
            manifest.arch
        );
        let (exe, _) = device.compile(&manifest.entrypoint("decode_logits")?.hlo)?;
        let ordered = crate::model::params_in_order(&manifest, params);
        let b = manifest.batch();
        let l = manifest.seq_len();
        Ok(InferEngine {
            manifest,
            exe,
            ordered,
            eos_id,
            queue: VecDeque::new(),
            slots: (0..b).map(|_| None).collect(),
            dec: vec![0i32; b * l],
            steps: 0,
            decode_seconds: 0.0,
            finished: Vec::new(),
            counters: CounterSet::new(),
        })
    }

    pub fn eos_id(&self) -> i32 {
        self.eos_id
    }

    /// Enqueue a request. `max_tokens` is clamped to the sequence budget
    /// (`seq_len - 1 - prompt_len`); over-long prompts are rejected.
    pub fn submit(&mut self, req: InferRequest) -> anyhow::Result<()> {
        let l = self.manifest.seq_len();
        anyhow::ensure!(
            req.prompt.len() + 2 <= l,
            "prompt of {} tokens leaves no room to decode (seq_len {})",
            req.prompt.len(),
            l
        );
        anyhow::ensure!(req.max_tokens >= 1, "max_tokens must be >= 1");
        anyhow::ensure!(
            matches!(req.method, DecodeMethod::Greedy | DecodeMethod::Sample { .. }),
            "the continuous-batching engine decodes greedy/sample requests; \
             use beam_decode() for beam search"
        );
        self.counters.inc("infer/requests_submitted");
        self.queue.push_back((req, Instant::now()));
        Ok(())
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(|s| s.is_some())
    }

    /// Pull queued requests into free slots (continuous-batching refill).
    fn admit(&mut self) {
        let l = self.manifest.seq_len();
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                continue;
            }
            let Some((req, submitted)) = self.queue.pop_front() else {
                break;
            };
            // A *refill* is an admission while other requests are already
            // mid-decode (have produced tokens) — i.e. this request joins
            // a running batch rather than a fresh one.
            let mid_flight =
                self.slots.iter().flatten().any(|s| !s.produced.is_empty());
            if mid_flight {
                self.counters.inc("infer/refills");
            }
            let plen = req.prompt.len();
            let max_tokens = req.max_tokens.min(l - 1 - plen);
            let row = &mut self.dec[i * l..(i + 1) * l];
            row.fill(0);
            row[1..=plen].copy_from_slice(&req.prompt);
            let rng = match &req.method {
                DecodeMethod::Sample { seed, .. } => Some(Pcg64::new(*seed)),
                _ => None,
            };
            self.slots[i] = Some(ActiveSlot {
                id: req.id,
                prompt_len: plen,
                len: plen + 1,
                produced: Vec::new(),
                max_tokens,
                method: req.method,
                rng,
                submitted,
                admitted: Instant::now(),
                started_step: self.steps,
            });
        }
    }

    /// Run one decode step over all occupied slots: admit from the queue,
    /// execute `decode_logits` once, extend every active row by one token,
    /// and retire rows that hit EOS / their budget / the sequence end.
    /// Returns the number of rows that decoded (0 = engine idle).
    pub fn step(&mut self) -> anyhow::Result<usize> {
        self.admit();
        let active = self.active();
        if active == 0 {
            return Ok(0);
        }
        let b = self.manifest.batch();
        let l = self.manifest.seq_len();
        let v = self.manifest.vocab();
        let t0 = Instant::now();
        let mut inputs = self.ordered.clone();
        inputs.push(HostTensor::i32(vec![b, l], self.dec.clone()));
        let outs = self.exe.run(inputs)?;
        self.decode_seconds += t0.elapsed().as_secs_f64();
        let lf = outs[0].as_f32(); // [B, L, V]
        self.steps += 1;
        self.counters.inc("infer/steps");
        self.counters.add("infer/slot_steps_busy", active as u64);
        for i in 0..b {
            let Some(slot) = self.slots[i].as_mut() else {
                continue;
            };
            // logits at the last filled position predict the next token
            let pos = slot.len - 1;
            let row = &lf[(i * l + pos) * v..(i * l + pos + 1) * v];
            let tok = decoding::next_token(&slot.method, row, slot.rng.as_mut()) as i32;
            slot.produced.push(tok);
            self.counters.inc("infer/tokens");
            let done =
                tok == self.eos_id || slot.len + 1 >= l || slot.produced.len() >= slot.max_tokens;
            if done {
                let slot = self.slots[i].take().unwrap();
                self.dec[i * l..(i + 1) * l].fill(0);
                let now = Instant::now();
                self.counters.inc("infer/requests_completed");
                self.finished.push(InferResult {
                    id: slot.id,
                    prompt_len: slot.prompt_len,
                    tokens: slot.produced,
                    started_step: slot.started_step,
                    finished_step: self.steps,
                    queue_seconds: (slot.admitted - slot.submitted).as_secs_f64(),
                    latency_seconds: (now - slot.submitted).as_secs_f64(),
                });
            } else {
                self.dec[i * l + slot.len] = tok;
                slot.len += 1;
            }
        }
        Ok(active)
    }

    /// Step until queue and slots are empty; returns everything completed
    /// since the last drain, in completion order.
    pub fn run_until_idle(&mut self) -> anyhow::Result<Vec<InferResult>> {
        while self.has_work() {
            self.step()?;
        }
        Ok(self.drain_finished())
    }

    /// Take completed results accumulated so far (completion order).
    pub fn drain_finished(&mut self) -> Vec<InferResult> {
        std::mem::take(&mut self.finished)
    }

    /// Beam search for a single request, using the batch rows as beam
    /// slots. Requires an idle engine (beams borrow the whole batch) and
    /// `beams <= B`.
    pub fn beam_decode(
        &mut self,
        prompt: &[i32],
        beams: usize,
        alpha: f32,
        max_tokens: usize,
    ) -> anyhow::Result<Vec<Hypothesis>> {
        anyhow::ensure!(
            !self.has_work(),
            "beam_decode needs an idle engine (beams occupy every slot)"
        );
        let b = self.manifest.batch();
        let l = self.manifest.seq_len();
        let v = self.manifest.vocab();
        anyhow::ensure!(beams >= 1 && beams <= b, "need 1 <= beams <= batch ({b})");
        anyhow::ensure!(prompt.len() + 2 <= l, "prompt leaves no room to decode");
        let plen = prompt.len();
        let max_tokens = max_tokens.min(l - 1 - plen).max(1);
        let exe = self.exe.clone();
        let ordered = self.ordered.clone();
        let counters = self.counters.clone();
        let step = move |prefixes: &[Vec<i32>]| -> anyhow::Result<Vec<Vec<f32>>> {
            anyhow::ensure!(prefixes.len() <= b, "live beams exceed batch");
            let mut dec = vec![0i32; b * l];
            for (r, pre) in prefixes.iter().enumerate() {
                dec[r * l + 1..r * l + 1 + plen].copy_from_slice(prompt);
                for (j, &t) in pre.iter().enumerate() {
                    dec[r * l + 1 + plen + j] = t;
                }
            }
            let mut inputs = ordered.clone();
            inputs.push(HostTensor::i32(vec![b, l], dec));
            let outs = exe.run(inputs)?;
            let lf = outs[0].as_f32();
            counters.inc("infer/beam_steps");
            // all live prefixes share one length by beam_search's contract
            let pos = plen + prefixes[0].len();
            Ok(prefixes
                .iter()
                .enumerate()
                .map(|(r, _)| lf[(r * l + pos) * v..(r * l + pos + 1) * v].to_vec())
                .collect())
        };
        decoding::beam_search(step, beams, max_tokens, self.eos_id, alpha)
    }

    pub fn counters(&self) -> &CounterSet {
        &self.counters
    }

    /// Mean slot occupancy over all decode steps so far.
    pub fn slot_utilization(&self) -> f64 {
        let steps = self.counters.get("infer/steps");
        if steps == 0 {
            return 0.0;
        }
        self.counters.get("infer/slot_steps_busy") as f64
            / (steps * self.manifest.batch() as u64) as f64
    }

    pub fn summary(&self) -> EngineSummary {
        let tokens = self.counters.get("infer/tokens");
        EngineSummary {
            steps: self.counters.get("infer/steps"),
            tokens,
            completed: self.counters.get("infer/requests_completed"),
            refills: self.counters.get("infer/refills"),
            slot_utilization: self.slot_utilization(),
            decode_seconds: self.decode_seconds,
            tokens_per_sec: if self.decode_seconds > 0.0 {
                tokens as f64 / self.decode_seconds
            } else {
                0.0
            },
        }
    }
}
