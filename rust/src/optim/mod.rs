//! Optimizers + LR schedules (S5): host-side parameter updates operating on
//! flat f32 slices, so the same code applies to full parameters (1D
//! strategy, replicated update) or ZeRO shards (2D strategy, each host
//! updates its slice only — the memory saving the paper calls
//! "2D parameter partitioning").
//!
//! Implemented: SGD(+momentum), Adam, and Adafactor (factored second
//! moments, the t5x default). Adafactor factoring needs the parameter's
//! matrix shape, so it stores per-parameter row/col statistics; for flat
//! shards (ZeRO) it falls back to the unfactored diagonal — exactly the
//! trade-off t5x documents for sharded optimizer states.

use std::collections::BTreeMap;

/// Learning-rate schedules (t5x defaults).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    Constant(f64),
    /// T5 default: lr = peak / sqrt(max(step, warmup)); linear warmup.
    RsqrtWithWarmup { peak: f64, warmup: u64 },
    /// Linear decay from peak to floor over total steps, linear warmup.
    LinearDecay { peak: f64, warmup: u64, total: u64, floor: f64 },
}

impl Schedule {
    pub fn lr(&self, step: u64) -> f64 {
        match *self {
            Schedule::Constant(v) => v,
            Schedule::RsqrtWithWarmup { peak, warmup } => {
                if step < warmup {
                    peak * (step + 1) as f64 / warmup as f64
                } else {
                    peak * (warmup as f64).sqrt() / (step as f64 + 1.0).sqrt()
                }
            }
            Schedule::LinearDecay { peak, warmup, total, floor } => {
                if step < warmup {
                    peak * (step + 1) as f64 / warmup as f64
                } else if step >= total {
                    floor
                } else {
                    let frac = (step - warmup) as f64 / (total - warmup).max(1) as f64;
                    floor + (peak - floor) * (1.0 - frac)
                }
            }
        }
    }
}

/// Optimizer configuration.
#[derive(Debug, Clone, Copy)]
pub enum OptimizerKind {
    Sgd { momentum: f32 },
    Adam { beta1: f32, beta2: f32, eps: f32 },
    Adafactor { decay: f32, eps: f32 },
}

impl OptimizerKind {
    pub fn adam() -> Self {
        OptimizerKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }

    pub fn adafactor() -> Self {
        OptimizerKind::Adafactor { decay: 0.8, eps: 1e-30 }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "sgd" => Ok(OptimizerKind::Sgd { momentum: 0.9 }),
            "adam" => Ok(OptimizerKind::adam()),
            "adafactor" => Ok(OptimizerKind::adafactor()),
            other => anyhow::bail!("unknown optimizer '{other}'"),
        }
    }

    /// Bytes of optimizer state per parameter element (for the cost model).
    pub fn state_floats_per_param(&self) -> usize {
        match self {
            OptimizerKind::Sgd { .. } => 1,
            OptimizerKind::Adam { .. } => 2,
            OptimizerKind::Adafactor { .. } => 1, // amortized (factored)
        }
    }
}

/// Per-parameter optimizer state.
#[derive(Debug, Clone)]
pub enum ParamState {
    Sgd { velocity: Vec<f32> },
    Adam { m: Vec<f32>, v: Vec<f32> },
    /// Factored: row/col second-moment stats for rank-2+ params.
    AdafactorFactored { row: Vec<f32>, col: Vec<f32> },
    /// Unfactored diagonal (rank-1 params or flat ZeRO shards).
    AdafactorDiag { v: Vec<f32> },
}

impl ParamState {
    pub fn num_floats(&self) -> usize {
        match self {
            ParamState::Sgd { velocity } => velocity.len(),
            ParamState::Adam { m, v } => m.len() + v.len(),
            ParamState::AdafactorFactored { row, col } => row.len() + col.len(),
            ParamState::AdafactorDiag { v } => v.len(),
        }
    }
}

/// The optimizer: holds state per named parameter (or shard).
pub struct Optimizer {
    pub kind: OptimizerKind,
    pub schedule: Schedule,
    states: BTreeMap<String, ParamState>,
    /// Matrix shape per param when factoring applies: (rows, cols).
    shapes: BTreeMap<String, (usize, usize)>,
}

impl Optimizer {
    pub fn new(kind: OptimizerKind, schedule: Schedule) -> Self {
        Self { kind, schedule, states: BTreeMap::new(), shapes: BTreeMap::new() }
    }

    /// Register a parameter (or shard). `matrix_shape` enables Adafactor
    /// factoring; pass None for flat shards.
    pub fn register(&mut self, name: &str, len: usize, matrix_shape: Option<(usize, usize)>) {
        let state = match self.kind {
            OptimizerKind::Sgd { .. } => ParamState::Sgd { velocity: vec![0.0; len] },
            OptimizerKind::Adam { .. } => {
                ParamState::Adam { m: vec![0.0; len], v: vec![0.0; len] }
            }
            OptimizerKind::Adafactor { .. } => match matrix_shape {
                Some((r, c)) if r > 1 && c > 1 && r * c == len => {
                    self.shapes.insert(name.to_string(), (r, c));
                    ParamState::AdafactorFactored { row: vec![0.0; r], col: vec![0.0; c] }
                }
                _ => ParamState::AdafactorDiag { v: vec![0.0; len] },
            },
        };
        self.states.insert(name.to_string(), state);
    }

    pub fn state_floats(&self) -> usize {
        self.states.values().map(|s| s.num_floats()).sum()
    }

    /// Apply one update in place: `param -= lr * precondition(grad)`.
    pub fn update(&mut self, name: &str, step: u64, param: &mut [f32], grad: &[f32]) {
        assert_eq!(param.len(), grad.len(), "param/grad length mismatch for {name}");
        let lr = self.schedule.lr(step) as f32;
        let state = self
            .states
            .get_mut(name)
            .unwrap_or_else(|| panic!("optimizer: unregistered param {name}"));
        match (self.kind, state) {
            (OptimizerKind::Sgd { momentum }, ParamState::Sgd { velocity }) => {
                for i in 0..param.len() {
                    velocity[i] = momentum * velocity[i] + grad[i];
                    param[i] -= lr * velocity[i];
                }
            }
            (OptimizerKind::Adam { beta1, beta2, eps }, ParamState::Adam { m, v }) => {
                let t = (step + 1) as i32;
                let bc1 = 1.0 - beta1.powi(t);
                let bc2 = 1.0 - beta2.powi(t);
                for i in 0..param.len() {
                    m[i] = beta1 * m[i] + (1.0 - beta1) * grad[i];
                    v[i] = beta2 * v[i] + (1.0 - beta2) * grad[i] * grad[i];
                    let mhat = m[i] / bc1;
                    let vhat = v[i] / bc2;
                    param[i] -= lr * mhat / (vhat.sqrt() + eps);
                }
            }
            (
                OptimizerKind::Adafactor { decay, eps },
                ParamState::AdafactorFactored { row, col },
            ) => {
                let (r, c) = self.shapes[name];
                let t = (step + 1) as f32;
                // beta2_t per Adafactor: 1 - t^-decay
                let beta2t = 1.0 - t.powf(-decay);
                // update row/col stats
                for i in 0..r {
                    let mut sum = 0.0f32;
                    for j in 0..c {
                        let g = grad[i * c + j];
                        sum += g * g;
                    }
                    row[i] = beta2t * row[i] + (1.0 - beta2t) * (sum / c as f32 + eps);
                }
                for j in 0..c {
                    let mut sum = 0.0f32;
                    for i in 0..r {
                        let g = grad[i * c + j];
                        sum += g * g;
                    }
                    col[j] = beta2t * col[j] + (1.0 - beta2t) * (sum / r as f32 + eps);
                }
                let row_mean: f32 =
                    row.iter().sum::<f32>() / r as f32 + 1e-30;
                for i in 0..r {
                    for j in 0..c {
                        let vhat = row[i] * col[j] / row_mean;
                        let update = grad[i * c + j] / vhat.sqrt().max(1e-30);
                        param[i * c + j] -= lr * update;
                    }
                }
            }
            (OptimizerKind::Adafactor { decay, eps }, ParamState::AdafactorDiag { v }) => {
                let t = (step + 1) as f32;
                let beta2t = 1.0 - t.powf(-decay);
                for i in 0..param.len() {
                    v[i] = beta2t * v[i] + (1.0 - beta2t) * (grad[i] * grad[i] + eps);
                    param[i] -= lr * grad[i] / v[i].sqrt().max(1e-30);
                }
            }
            _ => unreachable!("state kind mismatch"),
        }
    }

    /// Export/import state for checkpointing.
    pub fn state(&self, name: &str) -> Option<&ParamState> {
        self.states.get(name)
    }

    /// Borrowed `(slot, data)` views of a parameter's state — the
    /// checkpoint write path, which must not clone the whole optimizer
    /// state just to stream it to disk.
    pub fn state_slices(&self, name: &str) -> Vec<(&'static str, &[f32])> {
        match self.states.get(name) {
            Some(ParamState::Sgd { velocity }) => vec![("velocity", velocity.as_slice())],
            Some(ParamState::Adam { m, v }) => vec![("m", m), ("v", v)],
            Some(ParamState::AdafactorFactored { row, col }) => {
                vec![("vr", row), ("vc", col)]
            }
            Some(ParamState::AdafactorDiag { v }) => vec![("v", v)],
            None => vec![],
        }
    }

    /// `(slot, len)` pairs without touching the data — layout decisions
    /// (elementwise vs factored) and restore-time slot enumeration.
    pub fn state_slot_lens(&self, name: &str) -> Vec<(&'static str, usize)> {
        self.state_slices(name)
            .into_iter()
            .map(|(slot, data)| (slot, data.len()))
            .collect()
    }

    pub fn state_vectors(&self, name: &str) -> Vec<(String, Vec<f32>)> {
        match self.states.get(name) {
            Some(ParamState::Sgd { velocity }) => vec![("velocity".into(), velocity.clone())],
            Some(ParamState::Adam { m, v }) => {
                vec![("m".into(), m.clone()), ("v".into(), v.clone())]
            }
            Some(ParamState::AdafactorFactored { row, col }) => {
                vec![("vr".into(), row.clone()), ("vc".into(), col.clone())]
            }
            Some(ParamState::AdafactorDiag { v }) => vec![("v".into(), v.clone())],
            None => vec![],
        }
    }

    pub fn restore_state_vector(&mut self, name: &str, slot: &str, data: Vec<f32>) {
        if let Some(state) = self.states.get_mut(name) {
            match (state, slot) {
                (ParamState::Sgd { velocity }, "velocity") => *velocity = data,
                (ParamState::Adam { m, .. }, "m") => *m = data,
                (ParamState::Adam { v, .. }, "v") => *v = data,
                (ParamState::AdafactorFactored { row, .. }, "vr") => *row = data,
                (ParamState::AdafactorFactored { col, .. }, "vc") => *col = data,
                (ParamState::AdafactorDiag { v }, "v") => *v = data,
                _ => panic!("unknown optimizer slot {slot} for {name}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_loss_grad(p: &[f32]) -> Vec<f32> {
        // loss = sum (p - 3)^2 -> grad = 2(p - 3)
        p.iter().map(|&x| 2.0 * (x - 3.0)).collect()
    }

    #[test]
    fn schedules_shapes() {
        let s = Schedule::RsqrtWithWarmup { peak: 0.01, warmup: 100 };
        assert!(s.lr(0) < s.lr(50));
        assert!(s.lr(99) <= 0.01 + 1e-12);
        assert!(s.lr(100) > s.lr(10_000));
        let l = Schedule::LinearDecay { peak: 1.0, warmup: 10, total: 110, floor: 0.1 };
        assert!((l.lr(10) - 1.0).abs() < 0.01);
        assert!((l.lr(110) - 0.1).abs() < 1e-9);
        assert!((l.lr(1000) - 0.1).abs() < 1e-9);
    }

    fn converges(kind: OptimizerKind, lr: f64, steps: u64) -> f32 {
        let mut opt = Optimizer::new(kind, Schedule::Constant(lr));
        opt.register("p", 4, Some((2, 2)));
        let mut p = vec![0.0f32; 4];
        for step in 0..steps {
            let g = quad_loss_grad(&p);
            opt.update("p", step, &mut p, &g);
        }
        p.iter().map(|&x| (x - 3.0).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        assert!(converges(OptimizerKind::Sgd { momentum: 0.9 }, 0.05, 200) < 0.01);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        assert!(converges(OptimizerKind::adam(), 0.1, 500) < 0.05);
    }

    #[test]
    fn adafactor_converges_on_quadratic() {
        assert!(converges(OptimizerKind::adafactor(), 0.1, 500) < 0.05);
    }

    #[test]
    fn adafactor_factored_uses_less_state() {
        let mut f = Optimizer::new(OptimizerKind::adafactor(), Schedule::Constant(0.1));
        f.register("w", 64 * 128, Some((64, 128)));
        assert_eq!(f.state_floats(), 64 + 128); // vs 8192 diagonal
        let mut d = Optimizer::new(OptimizerKind::adam(), Schedule::Constant(0.1));
        d.register("w", 64 * 128, Some((64, 128)));
        assert_eq!(d.state_floats(), 2 * 64 * 128);
    }

    #[test]
    fn sharded_update_equals_full_update_sgd() {
        // ZeRO-style: updating two half-shards == updating the full vector.
        let kind = OptimizerKind::Sgd { momentum: 0.9 };
        let mut full = Optimizer::new(kind, Schedule::Constant(0.05));
        full.register("p", 8, None);
        let mut pf = vec![1.0f32; 8];

        let mut sh0 = Optimizer::new(kind, Schedule::Constant(0.05));
        let mut sh1 = Optimizer::new(kind, Schedule::Constant(0.05));
        sh0.register("p", 4, None);
        sh1.register("p", 4, None);
        let mut p0 = vec![1.0f32; 4];
        let mut p1 = vec![1.0f32; 4];

        for step in 0..20 {
            let g = quad_loss_grad(&pf);
            full.update("p", step, &mut pf, &g);
            let g0 = quad_loss_grad(&p0);
            let g1 = quad_loss_grad(&p1);
            sh0.update("p", step, &mut p0, &g0);
            sh1.update("p", step, &mut p1, &g1);
        }
        let merged: Vec<f32> = p0.into_iter().chain(p1).collect();
        for (a, b) in pf.iter().zip(&merged) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn state_roundtrip() {
        let mut opt = Optimizer::new(OptimizerKind::adam(), Schedule::Constant(0.1));
        opt.register("p", 4, None);
        let mut p = vec![0.0f32; 4];
        for step in 0..5 {
            let g = quad_loss_grad(&p);
            opt.update("p", step, &mut p, &g);
        }
        let vecs = opt.state_vectors("p");
        assert_eq!(vecs.len(), 2);
        let mut opt2 = Optimizer::new(OptimizerKind::adam(), Schedule::Constant(0.1));
        opt2.register("p", 4, None);
        for (slot, data) in vecs {
            opt2.restore_state_vector("p", &slot, data);
        }
        // continuing from restored state matches continuing original
        let mut pa = p.clone();
        let mut pb = p.clone();
        let g = quad_loss_grad(&pa);
        opt.update("p", 5, &mut pa, &g);
        opt2.update("p", 5, &mut pb, &g);
        assert_eq!(pa, pb);
    }
}
