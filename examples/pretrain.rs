//! Pretraining driver (E14, the headline end-to-end run).
//!
//! ```bash
//! # loss-curve run (~4M-param model, few hundred steps):
//! cargo run --release --example pretrain -- --model t5-micro-dec --steps 300 \
//!     --hosts 2 --strategy 2d --log train_log.jsonl
//! # 100M-param smoke (memory + step time through the full path):
//! cargo run --release --example pretrain -- --model t5-100m-dec --steps 3 --docs 64
//! ```
//! Full pipeline: synthetic corpus -> seqio deterministic cache -> sharded
//! infeed -> data-parallel trainer (1D or ZeRO-3) -> checkpoints -> eval.
//! Results are recorded in EXPERIMENTS.md §E14.

use t5x::optim::{OptimizerKind, Schedule};
use t5x::partitioning::{Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::trainer::recipes;
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};
use t5x::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "t5-micro-dec");
    let steps = args.get_usize("steps", 300)? as u64;
    let mesh = Mesh::parse(&args.get_or("mesh", "2x1"))?;
    let hosts = mesh.data; // data rows: one infeed stream per row
    let docs = args.get_usize("docs", 2000)?;
    let strategy = match args.get_or("strategy", "2d").as_str() {
        "1d" => ParamStrategy::OneD,
        _ => ParamStrategy::TwoD,
    };
    let log_path = args.get_or("log", "train_log.jsonl");

    let arts = Artifacts::load_default()?;
    let device = DeviceHandle::spawn()?;
    let m = arts.model(&model)?;
    println!(
        "== pretrain {model}: {:.1}M params, {} mesh, {:?}, {} steps ==",
        m.total_params() as f64 / 1e6,
        mesh,
        strategy,
        steps
    );

    // seqio deterministic cache (shards must be divisible by hosts)
    let cache_dir = std::env::temp_dir().join(format!("t5x_pretrain_{model}_{docs}"));
    let task = recipes::lm_task("pretrain_lm", docs, m.seq_len(), 42);
    let t_cache = std::time::Instant::now();
    let meta = recipes::ensure_cached(&task, &cache_dir, 8 * hosts.max(1), 0)?;
    println!(
        "cache: {} examples, {} shards ({:.1}s)",
        meta.num_examples,
        meta.num_shards,
        t_cache.elapsed().as_secs_f64()
    );

    let ckpt_dir = std::env::temp_dir().join(format!("t5x_pretrain_ckpt_{model}"));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let cfg = TrainerConfig {
        model: model.clone(),
        mesh,
        strategy,
        optimizer: OptimizerKind::adam(),
        schedule: Schedule::RsqrtWithWarmup { peak: 2e-3, warmup: 40 },
        steps,
        seed: 0,
        log_every: 10,
        checkpoint_every: Some(steps.max(2) / 2),
        checkpoint_dir: Some(ckpt_dir.clone()),
        grad_clip_norm: None,
        weight_decay: None,
        exec_mode: t5x::partitioning::ExecMode::Auto,
        trace_out: None,
        profile_steps: None,
    };
    let trainer = Trainer::new(&arts, &device, cfg)?.with_logger(
        t5x::metrics::MetricsLogger::new()
            .with_terminal()
            .with_jsonl(&log_path),
    );
    let infeed = recipes::cached_infeed(m, &cache_dir, hosts, 0, None)?;
    let summary = trainer.train(&BatchSource::Infeed(infeed))?;

    let tokens_per_step = m.tokens_per_step() * hosts;
    let tps = tokens_per_step as f64 * summary.history.len() as f64 / summary.wall_seconds;
    println!("\n== summary ==");
    println!("loss: {:.4} -> {:.4}", summary.first_loss(), summary.final_loss());
    println!(
        "wall: {:.1}s  ({:.0} tokens/s global, {:.3}s/step median-ish)",
        summary.wall_seconds,
        tps,
        summary.wall_seconds / summary.history.len().max(1) as f64
    );
    println!("comm: {:.1} MiB total", summary.comm_bytes as f64 / (1 << 20) as f64);
    println!("checkpoints at {:?}: {:?}", ckpt_dir,
        t5x::checkpoint::CheckpointManager::new(&ckpt_dir).steps());

    // held-out eval: same task, its "validation" split (via get_dataset)
    let runner = t5x::trainer::eval::EvalRunner::new(&arts, &device, &model)?;
    let split = recipes::eval_split(task.as_ref());
    let metrics = runner.evaluate(
        &trainer.params(),
        recipes::eval_batches(m, task.clone(), &split, 3, 4)?.into_iter(),
    )?;
    println!(
        "heldout eval: loss {:.4}, token accuracy {:.2}%",
        metrics.loss,
        metrics.accuracy * 100.0
    );
    println!("train log: {log_path}");
    device.shutdown();
    Ok(())
}
