//! Minimal JSON parser/serializer (serde substitute).
//!
//! Supports the full JSON grammar; numbers are kept as `f64` plus a raw
//! i64 fast path. Used for the artifact manifest, checkpoint metadata,
//! metric logs and golden files.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access, `/`-separated.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('/') {
            cur = match cur {
                Json::Obj(m) => m.get(seg)?,
                Json::Arr(a) => a.get(seg.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // ---- parsing ---------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: impl AsRef<std::path::Path>) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            anyhow::anyhow!("reading {}: {e}", path.as_ref().display())
        })?;
        Ok(Json::parse(&text)?)
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character (may be multi-byte).
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.path("a/1").unwrap().as_f64().unwrap(), 2.5);
        assert_eq!(v.path("b/c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café \t ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café \t ok");
        let s = Json::Str("a\"b\\c\n".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str().unwrap(), "a\"b\\c\n");
    }

    #[test]
    fn big_ints_stable() {
        let v = Json::parse("123456789012").unwrap();
        assert_eq!(v.to_string(), "123456789012");
    }
}
