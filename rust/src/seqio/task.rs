//! Tasks (seqio.Task, Figure 2): a named binding of a data source,
//! preprocessing steps, output features, and evaluation metrics, plus the
//! global [`TaskRegistry`].

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use once_cell::sync::Lazy;

use super::dataset::{Dataset, PipelineState};
use super::evaluation::Metric;
use super::preprocessors::{PipelineCtx, Preprocessor};
use super::source::DataSource;
use super::vocab::Vocabulary;

/// Declared output feature of a task (seqio.Feature).
#[derive(Clone)]
pub struct OutputFeature {
    pub name: String,
    pub vocab: Arc<dyn Vocabulary>,
    pub add_eos: bool,
    pub required: bool,
}

/// A seqio Task.
pub struct Task {
    pub name: String,
    pub source: Arc<dyn DataSource>,
    pub preprocessors: Vec<Arc<dyn Preprocessor>>,
    pub output_features: Vec<OutputFeature>,
    pub metrics: Vec<Metric>,
}

impl Task {
    pub fn builder(name: &str) -> TaskBuilder {
        TaskBuilder {
            name: name.to_string(),
            source: None,
            preprocessors: Vec::new(),
            output_features: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Instantiate the preprocessed dataset for one data shard. The
    /// returned stream is stateful: `Dataset::state()` captures the whole
    /// op graph (source position, preprocessor buffers) and
    /// [`Task::dataset_resumed`] rebuilds + repositions it.
    pub fn dataset(&self, seed: u64, shard_id: usize, num_shards: usize) -> Dataset {
        let ctx = PipelineCtx { seed };
        let mut ds = self.source.dataset(shard_id, num_shards);
        for p in &self.preprocessors {
            ds = p.apply(ds, &ctx);
        }
        ds
    }

    /// Rebuild the task stream (same seed/sharding) and reposition it to a
    /// previously captured [`PipelineState`].
    pub fn dataset_resumed(
        &self,
        seed: u64,
        shard_id: usize,
        num_shards: usize,
        state: &PipelineState,
    ) -> anyhow::Result<Dataset> {
        let mut ds = self.dataset(seed, shard_id, num_shards);
        ds.restore(state)?;
        Ok(ds)
    }

    pub fn output_feature(&self, name: &str) -> Option<&OutputFeature> {
        self.output_features.iter().find(|f| f.name == name)
    }

    /// Validate that a produced example carries all required features.
    pub fn validate_example(&self, ex: &super::Example) -> anyhow::Result<()> {
        for f in &self.output_features {
            if f.required && !ex.contains_key(&f.name) {
                anyhow::bail!(
                    "task '{}': example missing required feature '{}'",
                    self.name,
                    f.name
                );
            }
        }
        Ok(())
    }
}

pub struct TaskBuilder {
    name: String,
    source: Option<Arc<dyn DataSource>>,
    preprocessors: Vec<Arc<dyn Preprocessor>>,
    output_features: Vec<OutputFeature>,
    metrics: Vec<Metric>,
}

impl TaskBuilder {
    pub fn source(mut self, s: Arc<dyn DataSource>) -> Self {
        self.source = Some(s);
        self
    }

    pub fn preprocessor(mut self, p: Arc<dyn Preprocessor>) -> Self {
        self.preprocessors.push(p);
        self
    }

    pub fn output_feature(
        mut self,
        name: &str,
        vocab: Arc<dyn Vocabulary>,
        add_eos: bool,
    ) -> Self {
        self.output_features.push(OutputFeature {
            name: name.to_string(),
            vocab,
            add_eos,
            required: true,
        });
        self
    }

    pub fn metric(mut self, m: Metric) -> Self {
        self.metrics.push(m);
        self
    }

    pub fn build(self) -> Arc<Task> {
        Arc::new(Task {
            name: self.name,
            source: self.source.expect("task needs a source"),
            preprocessors: self.preprocessors,
            output_features: self.output_features,
            metrics: self.metrics,
        })
    }

    /// Build and register globally.
    pub fn register(self) -> Arc<Task> {
        let t = self.build();
        TaskRegistry::add(t.clone());
        t
    }
}

/// Global task registry (seqio.TaskRegistry).
pub struct TaskRegistry;

static REGISTRY: Lazy<Mutex<BTreeMap<String, Arc<Task>>>> =
    Lazy::new(|| Mutex::new(BTreeMap::new()));

impl TaskRegistry {
    pub fn add(task: Arc<Task>) {
        REGISTRY.lock().unwrap().insert(task.name.clone(), task);
    }

    pub fn get(name: &str) -> Option<Arc<Task>> {
        REGISTRY.lock().unwrap().get(name).cloned()
    }

    pub fn names() -> Vec<String> {
        REGISTRY.lock().unwrap().keys().cloned().collect()
    }

    pub fn remove(name: &str) {
        REGISTRY.lock().unwrap().remove(name);
    }

    pub fn reset() {
        REGISTRY.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seqio::preprocessors::Tokenize;
    use crate::seqio::source::SyntheticTextSource;
    use crate::seqio::vocab::ByteVocabulary;

    #[test]
    fn build_and_run_task() {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let task = Task::builder("test_task_build")
            .source(Arc::new(SyntheticTextSource::new(1, 10)))
            .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
            .output_feature("targets", vocab, true)
            .build();
        let out = task.dataset(0, 0, 1).collect_vec();
        assert_eq!(out.len(), 10);
        assert!(out[0].contains_key("targets"));
        task.validate_example(&out[0]).unwrap();
        let mut missing = out[0].clone();
        missing.remove("targets");
        assert!(task.validate_example(&missing).is_err());
    }

    #[test]
    fn registry_add_get() {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(4));
        Task::builder("test_task_registry")
            .source(Arc::new(SyntheticTextSource::new(2, 3)))
            .output_feature("targets", vocab, true)
            .register();
        assert!(TaskRegistry::get("test_task_registry").is_some());
        assert!(TaskRegistry::names().contains(&"test_task_registry".to_string()));
        TaskRegistry::remove("test_task_registry");
        assert!(TaskRegistry::get("test_task_registry").is_none());
    }

    #[test]
    fn task_stream_resumes_mid_epoch() {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let task = Task::builder("test_task_resume")
            .source(Arc::new(SyntheticTextSource::new(5, 20)))
            .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
            .preprocessor(Arc::new(
                crate::seqio::preprocessors::SpanCorruption::new(vocab.clone()),
            ))
            .output_feature("targets", vocab, true)
            .build();
        let all = task.dataset(11, 0, 1).collect_vec();
        let mut first = task.dataset(11, 0, 1);
        let head: Vec<_> = (&mut first).take(8).collect();
        let snap = first.state();
        let resumed = task.dataset_resumed(11, 0, 1, &snap).unwrap();
        let mut joined = head;
        joined.extend(resumed.collect_vec());
        assert_eq!(joined, all);
    }

    #[test]
    fn task_dataset_seeded() {
        let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
        let task = Task::builder("test_task_seeded")
            .source(Arc::new(SyntheticTextSource::new(5, 8)))
            .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
            .preprocessor(Arc::new(
                crate::seqio::preprocessors::SpanCorruption::new(vocab.clone()),
            ))
            .output_feature("inputs", vocab.clone(), true)
            .output_feature("targets", vocab, true)
            .build();
        let a = task.dataset(11, 0, 1).collect_vec();
        let b = task.dataset(11, 0, 1).collect_vec();
        let c = task.dataset(12, 0, 1).collect_vec();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
