//! Sharded record files — the TFRecord/ArrayRecord substitute backing the
//! deterministic cache (§3.2).
//!
//! Format (little endian):
//! ```text
//! file:   magic "T5XREC1\n" | entries...
//! entry:  u32 payload_len | u32 crc32(payload) | payload
//! index:  sidecar <file>.idx = u64 count | u64 byte-offset per entry
//! ```
//! The sidecar index makes records *seekable*, which is what gives the
//! deterministic pipeline O(1) resume-from-arbitrary-step (§3.2
//! Recoverability).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

pub const MAGIC: &[u8; 8] = b"T5XREC1\n";

#[derive(Debug, thiserror::Error)]
pub enum RecordError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("bad magic in {0}")]
    BadMagic(PathBuf),
    #[error("crc mismatch in {0} at entry {1}")]
    CrcMismatch(PathBuf, usize),
    #[error("truncated record file {0}")]
    Truncated(PathBuf),
    #[error("index out of range: {0} >= {1}")]
    OutOfRange(usize, usize),
}

/// Streaming writer; also accumulates the sidecar index.
pub struct RecordWriter {
    path: PathBuf,
    w: BufWriter<File>,
    offsets: Vec<u64>,
    pos: u64,
}

impl RecordWriter {
    pub fn create(path: impl AsRef<Path>) -> Result<Self, RecordError> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(&path)?);
        w.write_all(MAGIC)?;
        Ok(Self { path, w, offsets: Vec::new(), pos: MAGIC.len() as u64 })
    }

    pub fn write(&mut self, payload: &[u8]) -> Result<(), RecordError> {
        self.offsets.push(self.pos);
        let crc = crc32fast::hash(payload);
        self.w.write_all(&(payload.len() as u32).to_le_bytes())?;
        self.w.write_all(&crc.to_le_bytes())?;
        self.w.write_all(payload)?;
        self.pos += 8 + payload.len() as u64;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offsets.is_empty()
    }

    /// Flush data + write the sidecar index.
    pub fn finish(mut self) -> Result<usize, RecordError> {
        self.w.flush()?;
        let idx_path = index_path(&self.path);
        let mut iw = BufWriter::new(File::create(idx_path)?);
        iw.write_all(&(self.offsets.len() as u64).to_le_bytes())?;
        for off in &self.offsets {
            iw.write_all(&off.to_le_bytes())?;
        }
        iw.flush()?;
        Ok(self.offsets.len())
    }
}

pub fn index_path(path: &Path) -> PathBuf {
    let mut p = path.as_os_str().to_owned();
    p.push(".idx");
    PathBuf::from(p)
}

/// Random-access + sequential reader over one record file.
pub struct RecordReader {
    path: PathBuf,
    r: BufReader<File>,
    offsets: Vec<u64>,
    next: usize,
}

impl RecordReader {
    pub fn open(path: impl AsRef<Path>) -> Result<Self, RecordError> {
        let path = path.as_ref().to_path_buf();
        let mut r = BufReader::new(File::open(&path)?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)
            .map_err(|_| RecordError::Truncated(path.clone()))?;
        if &magic != MAGIC {
            return Err(RecordError::BadMagic(path));
        }
        // Load the sidecar index; if missing, rebuild by scanning.
        let idx = index_path(&path);
        let offsets = if idx.exists() {
            let mut ir = BufReader::new(File::open(&idx)?);
            let mut buf8 = [0u8; 8];
            ir.read_exact(&mut buf8)?;
            let n = u64::from_le_bytes(buf8) as usize;
            let mut offsets = Vec::with_capacity(n);
            for _ in 0..n {
                ir.read_exact(&mut buf8)?;
                offsets.push(u64::from_le_bytes(buf8));
            }
            offsets
        } else {
            Self::scan_offsets(&path)?
        };
        Ok(Self { path, r, offsets, next: 0 })
    }

    fn scan_offsets(path: &Path) -> Result<Vec<u64>, RecordError> {
        let mut r = BufReader::new(File::open(path)?);
        r.seek(SeekFrom::Start(MAGIC.len() as u64))?;
        let mut offsets = Vec::new();
        let mut pos = MAGIC.len() as u64;
        let mut hdr = [0u8; 8];
        loop {
            match r.read_exact(&mut hdr) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
                Err(e) => return Err(e.into()),
            }
            let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as u64;
            offsets.push(pos);
            pos += 8 + len;
            r.seek(SeekFrom::Start(pos))?;
        }
        Ok(offsets)
    }

    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offsets.is_empty()
    }

    /// Position the cursor at entry `i` (for resume).
    pub fn seek_to(&mut self, i: usize) -> Result<(), RecordError> {
        if i > self.offsets.len() {
            return Err(RecordError::OutOfRange(i, self.offsets.len()));
        }
        self.next = i;
        Ok(())
    }

    /// Read entry `i` without moving the sequential cursor.
    pub fn read_at(&mut self, i: usize) -> Result<Vec<u8>, RecordError> {
        if i >= self.offsets.len() {
            return Err(RecordError::OutOfRange(i, self.offsets.len()));
        }
        self.r.seek(SeekFrom::Start(self.offsets[i]))?;
        let mut hdr = [0u8; 8];
        self.r
            .read_exact(&mut hdr)
            .map_err(|_| RecordError::Truncated(self.path.clone()))?;
        let len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        let mut payload = vec![0u8; len];
        self.r
            .read_exact(&mut payload)
            .map_err(|_| RecordError::Truncated(self.path.clone()))?;
        if crc32fast::hash(&payload) != crc {
            return Err(RecordError::CrcMismatch(self.path.clone(), i));
        }
        Ok(payload)
    }

    /// Sequential read of the next entry.
    pub fn read_next(&mut self) -> Option<Result<Vec<u8>, RecordError>> {
        if self.next >= self.offsets.len() {
            return None;
        }
        let i = self.next;
        self.next += 1;
        Some(self.read_at(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rec_{}_{name}", std::process::id()))
    }

    #[test]
    fn write_read_roundtrip() {
        let p = tmp("rt.rec");
        let mut w = RecordWriter::create(&p).unwrap();
        for i in 0..100u32 {
            w.write(format!("payload-{i}").as_bytes()).unwrap();
        }
        assert_eq!(w.finish().unwrap(), 100);
        let mut r = RecordReader::open(&p).unwrap();
        assert_eq!(r.len(), 100);
        assert_eq!(r.read_at(42).unwrap(), b"payload-42");
        r.seek_to(98).unwrap();
        assert_eq!(r.read_next().unwrap().unwrap(), b"payload-98");
        assert_eq!(r.read_next().unwrap().unwrap(), b"payload-99");
        assert!(r.read_next().is_none());
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(index_path(&p)).ok();
    }

    #[test]
    fn survives_missing_index() {
        let p = tmp("noidx.rec");
        let mut w = RecordWriter::create(&p).unwrap();
        for i in 0..10u32 {
            w.write(&i.to_le_bytes()).unwrap();
        }
        w.finish().unwrap();
        std::fs::remove_file(index_path(&p)).unwrap();
        let mut r = RecordReader::open(&p).unwrap();
        assert_eq!(r.len(), 10);
        assert_eq!(r.read_at(3).unwrap(), 3u32.to_le_bytes());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_corruption() {
        let p = tmp("corrupt.rec");
        let mut w = RecordWriter::create(&p).unwrap();
        w.write(b"hello world, a reasonably long payload").unwrap();
        w.finish().unwrap();
        // Flip a byte in the payload region.
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let mut r = RecordReader::open(&p).unwrap();
        assert!(matches!(r.read_at(0), Err(RecordError::CrcMismatch(_, 0))));
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(index_path(&p)).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("badmagic.rec");
        std::fs::write(&p, b"NOTMAGIC").unwrap();
        assert!(matches!(RecordReader::open(&p), Err(RecordError::BadMagic(_))));
        std::fs::remove_file(&p).ok();
    }
}
