"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness gates).

These are the ground-truth implementations: simple, obviously-correct jnp.
`pytest python/tests` asserts the Pallas kernels (attention.py, fused_ffn.py)
match these within tolerance over a hypothesis-swept space of shapes/dtypes.
The L2 model can be built against either implementation (``use_pallas`` flag),
which is itself a test: lowered HLO numerics must agree.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e10


def attention_ref(q, k, v, bias=None, causal=False):
    """Multi-head attention reference.

    Args:
      q: [B, H, Lq, D] queries.
      k: [B, H, Lk, D] keys.
      v: [B, H, Lk, D] values.
      bias: optional [H, Lq, Lk] additive logit bias (T5 relative position
        bias), broadcast over batch.
      causal: if True, apply a causal mask (position i attends to j <= i).

    Returns:
      [B, H, Lq, D] attention output.
    """
    depth = q.shape[-1]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(depth, q.dtype)
    )
    if bias is not None:
        logits = logits + bias[None, :, :, :].astype(logits.dtype)
    if causal:
        lq, lk = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((lq, lk), bool), k=lk - lq)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    weights = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", weights, v)


def gated_ffn_ref(x, wi_0, wi_1, wo):
    """T5.1.1 gated-GeLU feed-forward reference.

    y = (gelu(x @ wi_0) * (x @ wi_1)) @ wo

    Args:
      x: [M, d_model] activations (batch*seq flattened).
      wi_0: [d_model, d_ff] gate projection.
      wi_1: [d_model, d_ff] linear projection.
      wo: [d_ff, d_model] output projection.
    """
    gate = jax.nn.gelu(x @ wi_0, approximate=True)
    return (gate * (x @ wi_1)) @ wo
