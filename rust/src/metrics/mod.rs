//! CLU-style metrics library: counters, gauges, and periodic writers.
//!
//! The trainer emits [`MetricPoint`]s (step-stamped scalar values) through a
//! [`MetricsLogger`]; writers render them to the terminal and/or a JSONL
//! file (`train_log.jsonl`) which EXPERIMENTS.md plots are generated from.

use std::io::Write;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

// The observability layer (spans, histograms, gauges) lives in
// `crate::obs` and is re-exported here so metrics consumers see one
// surface.
pub use crate::obs::{GaugeSet, Histogram, Span, Tracer};

/// One scalar observation at a training step.
#[derive(Debug, Clone)]
pub struct MetricPoint {
    pub step: u64,
    pub name: String,
    pub value: f64,
}

/// Destination for metric points.
pub trait MetricWriter: Send {
    fn write(&mut self, points: &[MetricPoint]);
    fn flush(&mut self) {}
}

/// Writes `step metric=value ...` lines to stdout.
pub struct TerminalWriter {
    start: Instant,
}

impl TerminalWriter {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }
}

impl Default for TerminalWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricWriter for TerminalWriter {
    fn write(&mut self, points: &[MetricPoint]) {
        if points.is_empty() {
            return;
        }
        let step = points[0].step;
        let body: Vec<String> = points
            .iter()
            .map(|p| format!("{}={:.6}", p.name, p.value))
            .collect();
        println!(
            "[{:>8.1}s] step {:>6}  {}",
            self.start.elapsed().as_secs_f64(),
            step,
            body.join("  ")
        );
    }
}

/// Appends one JSON object per step to a file.
pub struct JsonlWriter {
    path: PathBuf,
    buf: String,
    warned_dup: bool,
    warned_io: bool,
}

impl JsonlWriter {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into(), buf: String::new(), warned_dup: false, warned_io: false }
    }
}

impl MetricWriter for JsonlWriter {
    fn write(&mut self, points: &[MetricPoint]) {
        if points.is_empty() {
            return;
        }
        let mut pairs = vec![("step", Json::num(points[0].step as f64))];
        for p in points {
            // Two points with one name at the same step would serialize as
            // duplicate JSON keys; dedup last-write-wins, warning once.
            if let Some(existing) = pairs.iter_mut().find(|(k, _)| *k == p.name) {
                if !self.warned_dup {
                    self.warned_dup = true;
                    eprintln!(
                        "warning: duplicate metric '{}' at step {}; keeping the last \
                         value (further duplicates silently deduped)",
                        p.name, p.step
                    );
                }
                existing.1 = Json::num(p.value);
            } else {
                pairs.push((p.name.as_str(), Json::num(p.value)));
            }
        }
        self.buf.push_str(&Json::obj(pairs).to_string());
        self.buf.push('\n');
        if self.buf.len() > 16 * 1024 {
            self.flush();
        }
    }

    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        let res = (|| -> std::io::Result<()> {
            if let Some(dir) = self.path.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&self.path)?;
            f.write_all(self.buf.as_bytes())
        })();
        if let Err(e) = res {
            // A broken sink must never take down the run, but it also must
            // not fail silently: warn once, then keep dropping quietly.
            if !self.warned_io {
                self.warned_io = true;
                eprintln!(
                    "warning: failed to write metrics to {}: {e}; buffered metrics \
                     are being dropped",
                    self.path.display()
                );
            }
        }
        self.buf.clear();
    }
}

impl Drop for JsonlWriter {
    fn drop(&mut self) {
        self.flush();
    }
}

/// Fan-out logger; thread-safe, shared by trainer + hooks.
pub struct MetricsLogger {
    writers: Mutex<Vec<Box<dyn MetricWriter>>>,
}

impl MetricsLogger {
    pub fn new() -> Self {
        Self { writers: Mutex::new(Vec::new()) }
    }

    pub fn with_terminal(self) -> Self {
        self.add(Box::new(TerminalWriter::new()))
    }

    pub fn with_jsonl(self, path: impl Into<PathBuf>) -> Self {
        self.add(Box::new(JsonlWriter::new(path)))
    }

    pub fn add(self, w: Box<dyn MetricWriter>) -> Self {
        self.writers.lock().unwrap().push(w);
        self
    }

    pub fn log(&self, step: u64, values: &[(&str, f64)]) {
        let points: Vec<MetricPoint> = values
            .iter()
            .map(|(n, v)| MetricPoint { step, name: n.to_string(), value: *v })
            .collect();
        for w in self.writers.lock().unwrap().iter_mut() {
            w.write(&points);
        }
    }

    pub fn flush(&self) {
        for w in self.writers.lock().unwrap().iter_mut() {
            w.flush();
        }
    }
}

impl Default for MetricsLogger {
    fn default() -> Self {
        Self::new()
    }
}

/// Named monotonic counters (the CLU `metrics.Counter` analog), shared by
/// the serving engine and its callers. Cheap to clone (Arc-backed); values
/// are flushed to a [`MetricsLogger`] via [`CounterSet::log_to`].
#[derive(Clone, Default)]
pub struct CounterSet {
    inner: std::sync::Arc<Mutex<std::collections::BTreeMap<String, u64>>>,
}

impl CounterSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, name: &str, n: u64) {
        *self.inner.lock().unwrap().entry(name.to_string()).or_insert(0) += n;
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Raise `name` to `n` if larger (high-water-mark counters, e.g.
    /// `train/peak_param_floats`).
    pub fn set_max(&self, name: &str, n: u64) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name.to_string()).or_insert(0);
        *e = (*e).max(n);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// All counters, name-sorted.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.inner.lock().unwrap().iter().map(|(k, &v)| (k.clone(), v)).collect()
    }

    /// Emit every counter as a metric point at `step`.
    pub fn log_to(&self, logger: &MetricsLogger, step: u64) {
        let snap = self.snapshot();
        let values: Vec<(&str, f64)> =
            snap.iter().map(|(k, v)| (k.as_str(), *v as f64)).collect();
        logger.log(step, &values);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_set_accumulates_and_logs() {
        let c = CounterSet::new();
        c.inc("infer/steps");
        c.add("infer/tokens", 41);
        c.inc("infer/tokens");
        assert_eq!(c.get("infer/steps"), 1);
        assert_eq!(c.get("infer/tokens"), 42);
        assert_eq!(c.get("missing"), 0);
        let c2 = c.clone();
        c2.inc("infer/steps");
        assert_eq!(c.get("infer/steps"), 2, "clones share storage");
        let path = std::env::temp_dir().join(format!("counters_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let logger = MetricsLogger::new().with_jsonl(&path);
            c.log_to(&logger, 3);
            logger.flush();
        }
        let v = Json::parse(std::fs::read_to_string(&path).unwrap().lines().next().unwrap())
            .unwrap();
        assert_eq!(v.get("infer/tokens").unwrap().as_f64().unwrap(), 42.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn jsonl_writer_appends_parseable_lines() {
        let path = std::env::temp_dir().join(format!("metrics_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let logger = MetricsLogger::new().with_jsonl(&path);
            logger.log(1, &[("loss", 3.5), ("lr", 0.001)]);
            logger.log(2, &[("loss", 3.2)]);
            logger.flush();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v = Json::parse(lines[0]).unwrap();
        assert_eq!(v.get("step").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("loss").unwrap().as_f64().unwrap(), 3.5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn jsonl_writer_dedups_duplicate_keys_last_wins() {
        let path = std::env::temp_dir().join(format!("dup_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let logger = MetricsLogger::new().with_jsonl(&path);
            logger.log(1, &[("loss", 1.0), ("lr", 0.5), ("loss", 2.0)]);
            logger.flush();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let line = text.lines().next().unwrap();
        assert_eq!(line.matches("\"loss\"").count(), 1, "duplicate key emitted: {line}");
        let v = Json::parse(line).unwrap();
        assert_eq!(v.get("loss").unwrap().as_f64().unwrap(), 2.0, "last write wins");
        assert_eq!(v.get("lr").unwrap().as_f64().unwrap(), 0.5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn jsonl_writer_survives_unwritable_path() {
        // Point the writer at a directory: open() fails, the writer warns
        // (once) and drops the buffer instead of erroring or growing.
        let mut w = JsonlWriter::new(std::env::temp_dir());
        w.write(&[MetricPoint { step: 1, name: "loss".into(), value: 1.0 }]);
        w.flush();
        assert!(w.buf.is_empty());
        assert!(w.warned_io);
        w.write(&[MetricPoint { step: 2, name: "loss".into(), value: 2.0 }]);
        w.flush(); // second failure stays quiet but still clears
        assert!(w.buf.is_empty());
    }
}
