//! E11: checkpointing — save/restore throughput vs shard (chunk) size,
//! sliced reads, and the §2.3 claim that converting legacy (single-file
//! sequential) checkpoints to the native chunked format "results in
//! faster reading".

use t5x::bench::Bench;
use t5x::checkpoint::{legacy, CheckpointManager};
use t5x::runtime::Artifacts;

fn main() {
    let arts = Artifacts::load_default().expect("make artifacts first");
    let mut bench = Bench::new("checkpoint (E11)");
    let model = if bench.is_quick() { "t5-nano-dec" } else { "t5-small-dec" };
    let m = arts.model(model).unwrap();
    let params = t5x::model::init_params(m, 0);
    let total_bytes = (m.total_params() * 4) as f64;
    let root = std::env::temp_dir().join(format!("bench_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);

    println!("model {model}: {:.1} MiB of parameters\n", total_bytes / (1 << 20) as f64);

    for chunk_rows in [256usize, 4096] {
        let dir = root.join(format!("native_{chunk_rows}"));
        let mut mgr = CheckpointManager::new(&dir);
        mgr.chunk_rows = chunk_rows;
        bench.measure_with_throughput(
            &format!("native save (chunk_rows={chunk_rows})"),
            Some((total_bytes, "B")),
            || {
                mgr.save(1, &params, &Vec::new()).unwrap();
            },
        );
        bench.measure_with_throughput(
            &format!("native restore (chunk_rows={chunk_rows})"),
            Some((total_bytes, "B")),
            || {
                let (p, _) = mgr.restore(1).unwrap();
                std::hint::black_box(&p);
            },
        );
    }

    // legacy single-file format
    let legacy_path = root.join("legacy.ckpt");
    bench.measure_with_throughput("legacy save (single file)", Some((total_bytes, "B")), || {
        legacy::save_legacy(&legacy_path, &params).unwrap();
    });
    bench.measure_with_throughput("legacy load (single file)", Some((total_bytes, "B")), || {
        let p = legacy::load_legacy(&legacy_path).unwrap();
        std::hint::black_box(&p);
    });

    // conversion + converted read (the §2.3 claim)
    let conv_dir = root.join("converted");
    let mgr = CheckpointManager::new(&conv_dir);
    legacy::convert_to_native(&legacy_path, &mgr, 0).unwrap();
    bench.measure_with_throughput(
        "converted-native restore",
        Some((total_bytes, "B")),
        || {
            let (p, _) = mgr.restore(0).unwrap();
            std::hint::black_box(&p);
        },
    );

    // sliced restore: one host pulling 1/4 of the embedding
    let emb = m.param("token_embed").unwrap();
    let rows = emb.shape[0];
    bench.measure_with_throughput(
        "sliced restore (1/4 of token_embed)",
        Some(((emb.elements()) as f64, "floats")),
        || {
            let v = mgr
                .restore_param_slice(0, "token_embed", rows / 2, rows / 4)
                .unwrap();
            std::hint::black_box(&v);
        },
    );

    bench.write_jsonl("bench_results.jsonl").unwrap();
    std::fs::remove_dir_all(&root).ok();
}
