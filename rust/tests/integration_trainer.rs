//! Integration: the full Figure-1 stack (E1) — seqio deterministic cache ->
//! infeed -> partitioned trainer -> metrics/checkpoint -> eval, plus
//! multi-host strategies on a real (non-synthetic) data pipeline.

use std::sync::Arc;

use t5x::optim::{OptimizerKind, Schedule};
use t5x::partitioning::{ExecMode, Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::seqio::cache::{cache_task, CacheConfig};
use t5x::seqio::dataset::{Dataset, PipelineState};
use t5x::seqio::deterministic::{strip_index, DeterministicPipeline};
use t5x::seqio::feature_converters::{lengths, FeatureConverter, LmConverter};
use t5x::seqio::preprocessors::{AppendEos, ChunkTokens, Tokenize};
use t5x::seqio::source::SyntheticTextSource;
use t5x::seqio::task::Task;
use t5x::seqio::vocab::{ByteVocabulary, Vocabulary};
use t5x::trainer::infeed::Infeed;
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};

fn lm_task(name: &str, docs: usize, seq_len: usize) -> Arc<Task> {
    let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(16));
    Task::builder(name)
        .source(Arc::new(SyntheticTextSource::new(5, docs)))
        .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
        .preprocessor(Arc::new(ChunkTokens::new("targets", seq_len - 1)))
        .preprocessor(Arc::new(AppendEos::new(&["targets"])))
        .output_feature("targets", vocab, true)
        .build()
}

/// Build the infeed for a cached deterministic pipeline feeding the
/// nano decoder model, resuming at `start_step` (positional) or at an
/// exact checkpointed per-host pipeline state.
fn build_infeed(
    arts: &Artifacts,
    dir: &std::path::Path,
    num_hosts: usize,
    start_step: u64,
    resume: Option<&[PipelineState]>,
) -> Infeed {
    let m = arts.model("t5-nano-dec").unwrap();
    let batch = m.batch();
    let seq = m.seq_len();
    let dir = dir.to_path_buf();
    Infeed::spawn_resumable(
        m,
        num_hosts,
        4,
        move |host| {
            let p = DeterministicPipeline::open(&dir)?;
            let conv = LmConverter;
            let tl = lengths(&[("targets", seq)]);
            let ds: Dataset = p
                .host_stream(host, num_hosts, start_step as usize * batch, true)
                .map(strip_index);
            Ok(conv.convert(ds, &tl))
        },
        resume,
    )
    .unwrap()
}

#[test]
fn figure1_full_stack_loss_decreases() {
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let dir = std::env::temp_dir().join(format!("fig1_{}", std::process::id()));
    let task = lm_task("fig1_lm", 200, m.seq_len());
    cache_task(&task, &dir, &CacheConfig { num_shards: 8, seed: 1, workers: 4 }).unwrap();

    let device = DeviceHandle::spawn().unwrap();
    let cfg = TrainerConfig {
        model: "t5-nano-dec".into(),
        mesh: Mesh::new(2, 1),
        strategy: ParamStrategy::TwoD,
        optimizer: OptimizerKind::adam(),
        schedule: Schedule::Constant(2e-3),
        steps: 15,
        seed: 0,
        log_every: 100,
        checkpoint_every: None,
        checkpoint_dir: None,
        grad_clip_norm: None,
        weight_decay: None,
        exec_mode: ExecMode::Gather,
        trace_out: None,
        profile_steps: None,
        microbatches: 1,
        overlap: false,
        infeed_depth: 2,
    };
    let trainer = Trainer::new(&arts, &device, cfg).unwrap();
    let source = BatchSource::Infeed(build_infeed(&arts, &dir, 2, 0, None));
    let summary = trainer.train(&source).unwrap();
    assert_eq!(summary.history.len(), 15);
    assert!(
        summary.final_loss() < summary.first_loss() - 0.2,
        "loss {} -> {}",
        summary.first_loss(),
        summary.final_loss()
    );
    // the trainer's data came through the deterministic sharded reader
    assert!(summary.comm_bytes > 0);
    device.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn data_pipeline_resume_feeds_identical_batches() {
    // E6 at the trainer level: a restart at step k sees exactly the
    // batches the uninterrupted run saw from step k on.
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let dir = std::env::temp_dir().join(format!("resume_feed_{}", std::process::id()));
    let task = lm_task("resume_lm", 120, m.seq_len());
    cache_task(&task, &dir, &CacheConfig { num_shards: 4, seed: 2, workers: 2 }).unwrap();

    let straight = build_infeed(&arts, &dir, 2, 0, None);
    // consume 3 steps' worth, keep the 4th
    for _ in 0..3 {
        straight.next(0).unwrap();
        straight.next(1).unwrap();
    }
    let expected_h0 = straight.next(0).unwrap();
    let expected_h1 = straight.next(1).unwrap();

    let resumed = build_infeed(&arts, &dir, 2, 3, None);
    let got_h0 = resumed.next(0).unwrap();
    let got_h1 = resumed.next(1).unwrap();
    assert_eq!(got_h0, expected_h0);
    assert_eq!(got_h1, expected_h1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn infeed_resume_from_pipeline_state_feeds_identical_batches() {
    // The exact-resume path: snapshot per-host pipeline state after k
    // consumed batches, rebuild the infeed from the snapshot, and the next
    // batches must be byte-identical to the uninterrupted stream's —
    // even though the snapshot point is not a multiple of anything the
    // positional (start_step) fallback could express.
    let arts = Artifacts::load_default().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let dir = std::env::temp_dir().join(format!("resume_state_{}", std::process::id()));
    let task = lm_task("resume_state_lm", 150, m.seq_len());
    cache_task(&task, &dir, &CacheConfig { num_shards: 4, seed: 3, workers: 2 }).unwrap();

    let straight = build_infeed(&arts, &dir, 2, 0, None);
    for _ in 0..3 {
        straight.next(0).unwrap();
        straight.next(1).unwrap();
    }
    // snapshot reflects *consumed* batches, not prefetch-produced ones
    let states: Vec<PipelineState> =
        (0..2).map(|h| straight.pipeline_state(h)).collect();
    let expected_h0 = straight.next(0).unwrap();
    let expected_h1 = straight.next(1).unwrap();

    let resumed = build_infeed(&arts, &dir, 2, 0, Some(&states));
    assert_eq!(resumed.next(0).unwrap(), expected_h0);
    assert_eq!(resumed.next(1).unwrap(), expected_h1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trainer_kill_and_resume_matches_uninterrupted_run() {
    // End-to-end acceptance: a killed-and-resumed training run over a real
    // cached data pipeline reproduces the uninterrupted run's loss
    // trajectory exactly, because the checkpoint carries the data-pipeline
    // state alongside params/optimizer.
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let dir = std::env::temp_dir().join(format!("resume_train_{}", std::process::id()));
    let ckpt = std::env::temp_dir().join(format!("resume_train_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt);
    let task = lm_task("resume_train_lm", 300, m.seq_len());
    cache_task(&task, &dir, &CacheConfig { num_shards: 4, seed: 5, workers: 2 }).unwrap();

    let mut cfg = TrainerConfig::quick("t5-nano-dec", 6);
    cfg.mesh = Mesh::new(2, 1);
    cfg.seed = 2;
    cfg.schedule = Schedule::Constant(1e-3);

    // uninterrupted 6-step run
    let t_full = Trainer::new(&arts, &device, cfg.clone()).unwrap();
    let src_full = BatchSource::Infeed(build_infeed(&arts, &dir, 2, 0, None));
    let full = t_full.train(&src_full).unwrap();

    // "killed" run: 3 steps, checkpoint (params + optimizer + pipeline)
    let mut cfg_a = cfg.clone();
    cfg_a.steps = 3;
    cfg_a.checkpoint_every = Some(3);
    cfg_a.checkpoint_dir = Some(ckpt.clone());
    let t_a = Trainer::new(&arts, &device, cfg_a).unwrap();
    let src_a = BatchSource::Infeed(build_infeed(&arts, &dir, 2, 0, None));
    t_a.train(&src_a).unwrap();

    // resumed run: fresh trainer, restore, rebuild infeed from the
    // checkpointed pipeline state, train the remaining 3 steps
    let mut cfg_b = cfg;
    cfg_b.steps = 3;
    let mut t_b = Trainer::new(&arts, &device, cfg_b).unwrap();
    let resumed_step = t_b.restore_latest(&ckpt).unwrap();
    assert_eq!(resumed_step, 3);
    let states = t_b
        .restored_pipeline
        .clone()
        .expect("checkpoint must carry pipeline state");
    assert_eq!(states.len(), 2);
    let src_b = BatchSource::Infeed(build_infeed(&arts, &dir, 2, 0, Some(&states)));
    let resumed = t_b.train(&src_b).unwrap();

    assert_eq!(resumed.history.len(), 3);
    for (a, b) in full.history[3..].iter().zip(&resumed.history) {
        assert_eq!(a.step, b.step);
        assert!(
            (a.loss - b.loss).abs() < 1e-7,
            "step {}: uninterrupted {} vs resumed {}",
            a.step,
            a.loss,
            b.loss
        );
    }
    assert!(
        (full.final_loss() - resumed.final_loss()).abs() < 1e-7,
        "final losses diverged"
    );
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&ckpt).ok();
    device.shutdown();
}

#[test]
fn encdec_model_trains() {
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let mut cfg = TrainerConfig::quick("t5-nano-encdec", 8);
    cfg.schedule = Schedule::Constant(2e-3);
    let trainer = Trainer::new(&arts, &device, cfg).unwrap();
    let summary = trainer.train(&BatchSource::Synthetic { seed: 13 }).unwrap();
    assert!(summary.final_loss() < summary.first_loss());
    device.shutdown();
}

#[test]
fn four_host_zero3_trains_with_quarter_optimizer_state() {
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let mut cfg = TrainerConfig::quick("t5-nano-dec", 4);
    cfg.mesh = Mesh::new(4, 1);
    cfg.strategy = ParamStrategy::TwoD;
    let trainer = Trainer::new(&arts, &device, cfg.clone()).unwrap();
    let total: usize = trainer.layout.total;
    // Adam: 2 state floats per param; ZeRO: / 4 hosts, plus the small
    // replicated residue of dims indivisible by 4
    let per_host = trainer.optimizer_state_floats(0);
    let slack = 2 * trainer.plan.largest_param_elems() / 4;
    assert!(
        per_host <= 2 * total / 4 + slack,
        "per_host={per_host} total={total}"
    );
    let summary = trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
    assert_eq!(summary.history.len(), 4);
    device.shutdown();
}

#[test]
fn gin_config_drives_trainer_construction() {
    // The paper's configurability claim (§2.1): build a TrainerConfig
    // entirely from gin bindings + CLI-style overrides.
    use t5x::gin::Config;
    let mut cfg = Config::parse(
        "
trainer.model = 't5-nano-dec'
trainer.mesh = '2x1'
trainer.strategy = '2d'
trainer.optimizer = 'adam'
trainer.steps = 3
trainer.lr = 1e-3
",
    )
    .unwrap();
    cfg.apply_override("trainer.steps=2").unwrap();
    let tc = TrainerConfig {
        model: cfg.require_str("trainer", "model").unwrap(),
        mesh: Mesh::parse(&cfg.str_or("trainer", "mesh", "1x1")).unwrap(),
        strategy: match cfg.str_or("trainer", "strategy", "1d").as_str() {
            "2d" => ParamStrategy::TwoD,
            _ => ParamStrategy::OneD,
        },
        optimizer: OptimizerKind::from_name(&cfg.str_or("trainer", "optimizer", "adam"))
            .unwrap(),
        schedule: Schedule::Constant(cfg.f64_or("trainer", "lr", 1e-3)),
        steps: cfg.usize_or("trainer", "steps", 1) as u64,
        seed: 0,
        log_every: 100,
        checkpoint_every: None,
        checkpoint_dir: None,
        grad_clip_norm: None,
        weight_decay: None,
        exec_mode: ExecMode::parse(&cfg.str_or("trainer", "exec_mode", "auto")).unwrap(),
        trace_out: cfg
            .get("trainer", "trace_out")
            .and_then(|v| v.as_str().map(std::path::PathBuf::from)),
        profile_steps: None,
        microbatches: cfg.usize_or("trainer", "microbatches", 1),
        overlap: cfg.bool_or("trainer", "overlap", false),
        infeed_depth: cfg.usize_or("trainer", "infeed_depth", 2),
    };
    assert_eq!(tc.steps, 2);
    assert_eq!(tc.strategy, ParamStrategy::TwoD);
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let trainer = Trainer::new(&arts, &device, tc).unwrap();
    let s = trainer.train(&BatchSource::Synthetic { seed: 0 }).unwrap();
    assert_eq!(s.history.len(), 2);
    let op = cfg.operative();
    assert!(op.contains("trainer.steps = 2"));
    device.shutdown();
}
