//! E13: collective primitives — ring all-reduce / reduce-scatter /
//! all-gather time vs host count and payload size, plus the mesh
//! axis-subgroup fabric (per-axis rings + per-axis byte accounting).
//! These are the communication terms behind every §2.2 strategy; the
//! measured byte counts are checked against the analytic ring model.

use t5x::bench::Bench;
use t5x::collectives::{
    all_gather_axis, reduce_scatter_axis, run_ranks, CollectiveGroup, MeshCollectives, ReduceOp,
};
use t5x::partitioning::cost::{ring_all_gather_bytes, ring_all_reduce_bytes, ring_reduce_scatter_bytes};
use t5x::partitioning::{Mesh, MeshAxis};
use t5x::runtime::HostTensor;

fn main() {
    let mut bench = Bench::new("collectives (E13)");
    let sizes: &[usize] = if bench.is_quick() {
        &[1 << 16]
    } else {
        &[1 << 16, 1 << 20, 1 << 23]
    };
    let host_counts: &[usize] = if bench.is_quick() { &[4] } else { &[2, 4, 8] };

    for &n in host_counts {
        for &len in sizes {
            let group = CollectiveGroup::new(n);
            let mib = (len * 4) as f64 / (1 << 20) as f64;
            bench.measure_with_throughput(
                &format!("all_reduce n={n} {mib:.0}MiB"),
                Some(((len * 4) as f64, "B")),
                || {
                    run_ranks(n, |r| {
                        std::hint::black_box(group.all_reduce(r, vec![r as f32; len]))
                    });
                },
            );
            // verify measured bytes track the ring model
            group.reset_stats();
            run_ranks(n, |r| group.all_reduce(r, vec![0.0; len]));
            let expect = n as u64 * ring_all_reduce_bytes(len as u64 * 4, n as u64);
            let got = group.bytes_sent();
            assert!(
                (got as f64 - expect as f64).abs() / (expect.max(1) as f64) < 0.05,
                "byte model mismatch: got {got}, ring model {expect}"
            );

            bench.measure_with_throughput(
                &format!("reduce_scatter n={n} {mib:.0}MiB"),
                Some(((len * 4) as f64, "B")),
                || {
                    run_ranks(n, |r| {
                        std::hint::black_box(group.reduce_scatter(r, vec![1.0; len]))
                    });
                },
            );
            let chunk = len / n;
            bench.measure_with_throughput(
                &format!("all_gather n={n} {mib:.0}MiB"),
                Some(((len * 4) as f64, "B")),
                || {
                    run_ranks(n, |r| {
                        std::hint::black_box(group.all_gather(r, vec![1.0; chunk], chunk * n))
                    });
                },
            );
            // non-sum reductions (block-execution g-points: logit max,
            // argmax-claim min) — same ring, different combiner
            for op in [ReduceOp::Max, ReduceOp::Min] {
                bench.measure_with_throughput(
                    &format!("all_reduce_{op:?} n={n} {mib:.0}MiB"),
                    Some(((len * 4) as f64, "B")),
                    || {
                        run_ranks(n, |r| {
                            std::hint::black_box(group.all_reduce_op(
                                r,
                                vec![r as f32; len],
                                op,
                            ))
                        });
                    },
                );
            }
        }
    }

    // ---- gather vs block model-axis pattern (per §2.2 block execution) ----
    // Gather mode moves parameter-sized all-gathers over the model axis;
    // block mode replaces them with activation-sized all-reduces. Measure
    // both patterns at a representative size ratio (params 16x activations).
    {
        let n = 2;
        let param_len = 1 << 20; // "full parameter" payload per gather
        let act_len = 1 << 16; // one activation-reduction payload
        let g = CollectiveGroup::new(n);
        bench.measure_with_throughput(
            "model-axis gather pattern n=2 (param all-gather)",
            Some(((param_len * 4) as f64, "B")),
            || {
                run_ranks(n, |r| {
                    std::hint::black_box(g.all_gather(
                        r,
                        vec![1.0; param_len / n],
                        param_len,
                    ))
                });
            },
        );
        bench.measure_with_throughput(
            "model-axis block pattern n=2 (activation all-reduce)",
            Some(((act_len * 4) as f64, "B")),
            || {
                run_ranks(n, |r| {
                    std::hint::black_box(g.all_reduce(r, vec![1.0; act_len]))
                });
            },
        );
    }
    // ---- mesh axis subgroups: the trainer's per-step pattern ----
    // Each host reduce-scatters a "gradient" over its data-axis ring and
    // all-gathers a "parameter" over its model-axis ring; the per-axis
    // byte counters must match the ring model per subgroup.
    let meshes: &[Mesh] = if bench.is_quick() {
        &[Mesh { data: 2, model: 2 }]
    } else {
        &[Mesh { data: 2, model: 2 }, Mesh { data: 4, model: 2 }]
    };
    let rows = 1usize << 8;
    let cols = 64usize;
    for &mesh in meshes {
        let mc = MeshCollectives::new(mesh);
        let mib = (rows * cols * 4) as f64 / (1 << 20) as f64;
        bench.measure_with_throughput(
            &format!("mesh {mesh} RS(data)+AG(model) {mib:.2}MiB"),
            Some(((rows * cols * 4) as f64, "B")),
            || {
                run_ranks(mesh.num_hosts(), |h| {
                    let (dg, dr) = mc.data_group(h);
                    let grad = HostTensor::f32(vec![rows, cols], vec![1.0; rows * cols]);
                    let mine = reduce_scatter_axis(dg, dr, &grad, 0);
                    let (mg, mr) = mc.model_group(h);
                    let shard = HostTensor::f32(
                        vec![rows, cols / mesh.model],
                        vec![1.0; rows * cols / mesh.model],
                    );
                    let full = all_gather_axis(mg, mr, &shard, 1);
                    std::hint::black_box((mine, full));
                });
            },
        );
        // byte accounting vs the ring model, per axis
        mc.reset_stats();
        run_ranks(mesh.num_hosts(), |h| {
            let (dg, dr) = mc.data_group(h);
            let grad = HostTensor::f32(vec![rows, cols], vec![1.0; rows * cols]);
            let _ = reduce_scatter_axis(dg, dr, &grad, 0);
            let (mg, mr) = mc.model_group(h);
            let shard = HostTensor::f32(
                vec![rows, cols / mesh.model],
                vec![1.0; rows * cols / mesh.model],
            );
            let _ = all_gather_axis(mg, mr, &shard, 1);
        });
        let payload = (rows * cols * 4) as u64;
        // RS over `data` ranks in `model` independent subgroups: every
        // host sends the canonical ring reduce-scatter share.
        let expect_data =
            mesh.num_hosts() as u64 * ring_reduce_scatter_bytes(payload, mesh.data as u64);
        let expect_model =
            mesh.num_hosts() as u64 * ring_all_gather_bytes(payload, mesh.model as u64);
        let got_data = mc.axis_bytes(MeshAxis::Data);
        let got_model = mc.axis_bytes(MeshAxis::Model);
        for (axis, got, expect) in
            [("data", got_data, expect_data), ("model", got_model, expect_model)]
        {
            assert!(
                (got as f64 - expect as f64).abs() / (expect.max(1) as f64) < 0.05,
                "{axis}-axis byte model mismatch on {mesh}: got {got}, ring model {expect}"
            );
        }
        println!(
            "  mesh {mesh}: data-axis {got_data} B, model-axis {got_model} B (ring model ok)"
        );
    }
    bench.write_jsonl("bench_results.jsonl").unwrap();
}
