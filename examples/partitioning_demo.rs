//! Partitioning demo (E3): the paper's §2.2 strategy matrix, both as the
//! analytic GSPMD cost model (table) and as *live* tensor parallelism —
//! a Megatron-style column/row-sharded FFN running on simulated
//! model-parallel hosts with real ring all-reduce, checked against the
//! unsharded HLO.
//!
//! ```bash
//! cargo run --release --example partitioning_demo
//! ```

use t5x::collectives::{run_ranks, CollectiveGroup};
use t5x::partitioning::cost::{strategy_table, LinkModel};
use t5x::partitioning::Mesh;
use t5x::runtime::{Artifacts, DeviceHandle, HostTensor};
use t5x::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let arts = Artifacts::load_default()?;

    // ---- analytic strategy matrix (§2.2) --------------------------------
    println!("== GSPMD cost model: t5-100m-dec over mesh strategies ==\n");
    let m = arts.model("t5-100m-dec")?;
    let meshes = [
        Mesh::new(1, 1),
        Mesh::new(4, 1),
        Mesh::new(16, 1),
        Mesh::new(4, 4),
        Mesh::new(1, 8),
    ];
    println!("{}", strategy_table(m, &meshes, LinkModel::default()));
    println!("reading: 1D replicates params over the data axis; 2D (ZeRO-3)");
    println!("shards them; model-axis sharding adds per-layer all-reduces.\n");

    // ---- live Megatron FFN across model-parallel hosts ------------------
    println!("== live tensor parallelism: column/row-sharded FFN ==");
    let pd = arts.partdemo.as_ref().unwrap();
    let device = DeviceHandle::spawn()?;
    let (full_exe, _) = device.compile(&pd.hlos["ffn_full"])?;

    let mut rng = Pcg64::new(7);
    let x = HostTensor::f32(
        vec![pd.m, pd.k],
        (0..pd.m * pd.k).map(|_| rng.next_f32() - 0.5).collect(),
    );
    let w1 = HostTensor::f32(
        vec![pd.k, pd.f],
        (0..pd.k * pd.f).map(|_| (rng.next_f32() - 0.5) * 0.1).collect(),
    );
    let w2 = HostTensor::f32(
        vec![pd.f, pd.k],
        (0..pd.f * pd.k).map(|_| (rng.next_f32() - 0.5) * 0.1).collect(),
    );
    let t0 = std::time::Instant::now();
    let full = full_exe.run(vec![x.clone(), w1.clone(), w2.clone()])?[0].clone();
    let t_full = t0.elapsed();
    println!(
        "unsharded ffn ({}x{}x{}): {:.2?}",
        pd.m, pd.k, pd.f, t_full
    );

    for shards in [2usize, 4] {
        let (shard_exe, _) = device.compile(&pd.hlos[&format!("ffn_shard{shards}")])?;
        let fs = pd.f / shards;
        let group = CollectiveGroup::new(shards);
        let t0 = std::time::Instant::now();
        let outs = run_ranks(shards, |r| {
            let w1_s = w1.slice_axis(1, r * fs, fs);
            let w2_s = w2.slice_axis(0, r * fs, fs);
            let partial = shard_exe.run(vec![x.clone(), w1_s, w2_s]).unwrap()[0].clone();
            group.all_reduce(r, partial.as_f32().to_vec())
        });
        let dt = t0.elapsed();
        let max_err = outs
            .iter()
            .flat_map(|o| o.iter().zip(full.as_f32()).map(|(a, b)| (a - b).abs()))
            .fold(0.0f32, f32::max);
        println!(
            "{shards}-way model parallel: {:.2?}, all-reduce bytes {}, max |err| vs full = {:.2e}",
            dt,
            group.bytes_sent(),
            max_err
        );
        assert!(max_err < 1e-4);
    }
    println!("\npartitioning_demo OK");
    device.shutdown();
    Ok(())
}
