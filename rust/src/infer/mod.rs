//! Inference serving subsystem (S8): the `t5x.decoding` + `InferTask`
//! counterpart, grown into a serving stack.
//!
//! * [`decoding`] — pure host-side decoding algorithms: greedy,
//!   temperature/top-k/top-p sampling (seeded, one RNG draw per token),
//!   and beam search with length penalty, plus a brute-force exhaustive
//!   reference used by golden tests.
//! * [`engine`] — the continuous-batching engine: packs independent
//!   requests into the fixed `B` batch slots of the decode HLOs, retires
//!   rows at EOS, and refills freed slots from the queue mid-flight.
//!   Reports latency/throughput/utilization through
//!   [`crate::metrics::CounterSet`].
//! * [`server`] — the JSONL request/response transport (`t5x serve`'s
//!   stdin mode) with a background reader so requests join running
//!   batches. Since PR 8 it is a thin client of the
//!   [`crate::serve::Gateway`] admission queue + replica router — the
//!   same scheduling path the HTTP front end uses; see
//!   [`crate::serve`] for the admission/shedding/replica contract.
//!
//! ## KV-cache slot lifecycle (Kv decode mode)
//!
//! Each of the `B` slots owns row `i` of every per-layer K/V cache tensor
//! (`[B, H, L, head_dim]`, the manifest `kv_cache` contract):
//!
//! 1. **admit** — the request's prompt is written into the shared token
//!    buffer and one `prefill` call scores it, materializing the slot's
//!    cache rows (merged out of the batch-wide prefill result; mid-flight
//!    neighbors keep their incrementally built rows untouched) and its
//!    first next-token logits;
//! 2. **decode** — every subsequent token costs one `decode_step` row:
//!    `[B, 1]` token input, the cache row extended at the row's own
//!    position (slots sit at different lengths under continuous batching);
//! 3. **retire** — at EOS / budget / end-of-sequence the slot frees
//!    immediately; its cache rows go stale and are *recycled* — the next
//!    request admitted to the slot overwrites them via its prefill merge,
//!    so refills need no cache zeroing and cost one prefill regardless of
//!    what ran in the slot before.
//!
//! **Decode-mode selection rule:** `--decode-mode auto` (the default)
//! uses Kv iff the manifest has `prefill` + `decode_step` + `kv_cache`
//! ([`ModelManifest::supports_kv_decode`](crate::runtime::artifacts::ModelManifest::supports_kv_decode));
//! artifact dirs exported before the KV entrypoints automatically serve
//! via `decode_logits` full rescoring. `--decode-mode kv` errors on such
//! dirs; `--decode-mode rescore` forces the O(L^2) path (debugging /
//! byte-identity diffing). Beam search always rides rescoring (beams
//! fork/reorder prefixes; no per-slot cache locality).
//!
//! The subsystem's determinism contract (engine output byte-identical to
//! single-request decoding AND across decode modes, seeded sampling
//! reproducible per request) is documented in [`decoding`] and [`engine`]
//! and enforced by `tests/integration_infer.rs`.

pub mod decoding;
pub mod engine;
pub mod server;

pub use decoding::{DecodeMethod, Hypothesis};
pub use engine::{
    validate_request, DecodeMode, EngineSummary, InferEngine, InferRequest,
    InferResult,
};
