//! Deadline-aware admission queue with bounded depth and load shedding.
//!
//! One [`AdmissionQueue`] sits between every transport (HTTP, JSONL) and
//! the replica pool. It enforces three pressure-relief valves, each with
//! its own counter so `/metrics` can tell them apart:
//!
//! 1. **Bounded depth** — a submit past `capacity` fails immediately with
//!    [`AdmitError::QueueFull`] (`serve/rejected_full`), which the HTTP
//!    layer renders as `429` + `Retry-After`. The queue never grows
//!    without bound and a slow engine surfaces as backpressure, not as
//!    unbounded memory.
//! 2. **Load-shedding watermark** — once depth reaches `watermark`,
//!    submits with `priority <= 0` are rejected
//!    ([`AdmitError::ShedLowPriority`], `serve/shed_lowpri`) while
//!    higher-priority work is still admitted until depth hits capacity.
//! 3. **Deadline shedding** — a request whose `deadline_ms` elapsed while
//!    it waited is dropped at *pop* time, before it ever occupies an
//!    engine slot (`serve/shed_deadline`); its submitter receives
//!    [`ServeOutcome::Shed`] instead of silently timing out.
//!
//! Ordering is priority-descending, FIFO within a priority level (a
//! submission sequence number breaks ties), implemented as a
//! `BinaryHeap` under one mutex with a condvar for blocking pops.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::{OutcomeSender, ServeOutcome, ShedReason, SubmitOpts};
use crate::infer::InferRequest;
use crate::metrics::CounterSet;
use crate::obs::Histogram;

/// Suggested client back-off rendered into `Retry-After` (seconds).
pub const RETRY_AFTER_SECS: u64 = 1;

/// Why a submit was rejected synchronously (never enters the queue).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmitError {
    /// Queue depth is at capacity.
    QueueFull { depth: usize, retry_after_secs: u64 },
    /// Depth crossed the shed watermark and the request's priority is not
    /// above the default (0).
    ShedLowPriority { depth: usize, watermark: usize, retry_after_secs: u64 },
    /// The gateway is draining; no new work is admitted.
    Draining,
    /// The request failed validation (bad prompt, bad method, ...).
    Invalid(String),
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull { depth, .. } => {
                write!(f, "admission queue full (depth {depth})")
            }
            AdmitError::ShedLowPriority { depth, watermark, .. } => write!(
                f,
                "load shedding: queue depth {depth} >= watermark {watermark} \
                 and request priority is not above 0"
            ),
            AdmitError::Draining => write!(f, "gateway is draining"),
            AdmitError::Invalid(msg) => write!(f, "invalid request: {msg}"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// An admitted request waiting for a replica: the validated engine
/// request plus everything needed to route its outcome back.
pub struct Pending {
    /// Engine-facing request. Its `id` is the gateway-internal id (unique
    /// across clients); the client's original id travels in `client_id`.
    pub req: InferRequest,
    pub opts: SubmitOpts,
    /// The id the submitting client used (echoed in responses).
    pub client_id: u64,
    /// When the gateway accepted the request (queue-wait clock).
    pub submitted: Instant,
    pub reply: OutcomeSender,
}

impl Pending {
    /// True once the request's deadline elapsed while queued.
    fn expired(&self) -> bool {
        match self.opts.deadline {
            Some(dl) => self.submitted.elapsed() >= dl,
            None => false,
        }
    }
}

struct Entry {
    priority: i64,
    /// Submission sequence number; later submissions sort after earlier
    /// ones at equal priority (FIFO within a level).
    seq: u64,
    pending: Pending,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Max-heap: highest priority first, then lowest seq (oldest).
        self.priority.cmp(&other.priority).then(other.seq.cmp(&self.seq))
    }
}

struct State {
    heap: BinaryHeap<Entry>,
    next_seq: u64,
    /// False once draining: submits fail, pops return what's left then
    /// [`Popped::Closed`].
    open: bool,
}

/// Result of [`AdmissionQueue::pop`].
pub enum Popped {
    /// Up to `max` requests in dispatch order (possibly empty when
    /// non-blocking or when `max == 0`).
    Batch(Vec<Pending>),
    /// The queue is closed and empty; no more work will ever arrive.
    Closed,
}

/// The bounded, priority-ordered, deadline-shedding admission queue.
/// Thread-safe; shared as a plain reference from within [`super::Gateway`].
pub struct AdmissionQueue {
    state: Mutex<State>,
    cv: Condvar,
    capacity: usize,
    watermark: usize,
    counters: CounterSet,
    /// Gateway queue wait (submit → dispatch) of dispatched requests, ms.
    queue_wait: Histogram,
    next_internal_id: AtomicU64,
}

impl AdmissionQueue {
    /// `capacity` bounds queue depth; `watermark <= capacity` arms early
    /// shedding of `priority <= 0` work (pass `capacity` to disable).
    pub fn new(capacity: usize, watermark: usize, counters: CounterSet) -> AdmissionQueue {
        AdmissionQueue {
            state: Mutex::new(State {
                heap: BinaryHeap::new(),
                next_seq: 0,
                open: true,
            }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
            watermark: watermark.max(1),
            counters,
            queue_wait: Histogram::new(),
            next_internal_id: AtomicU64::new(1),
        }
    }

    /// A fresh gateway-internal request id (clients may reuse ids freely;
    /// the engine sees only these).
    pub fn next_internal_id(&self) -> u64 {
        self.next_internal_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Admit `pending` or reject it with explicit backpressure.
    pub fn submit(&self, pending: Pending) -> Result<(), AdmitError> {
        let mut st = self.state.lock().unwrap();
        if !st.open {
            self.counters.inc("serve/rejected_draining");
            return Err(AdmitError::Draining);
        }
        let depth = st.heap.len();
        if depth >= self.capacity {
            self.counters.inc("serve/rejected_full");
            return Err(AdmitError::QueueFull {
                depth,
                retry_after_secs: RETRY_AFTER_SECS,
            });
        }
        if depth >= self.watermark && pending.opts.priority <= 0 {
            self.counters.inc("serve/shed_lowpri");
            return Err(AdmitError::ShedLowPriority {
                depth,
                watermark: self.watermark,
                retry_after_secs: RETRY_AFTER_SECS,
            });
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.heap.push(Entry { priority: pending.opts.priority, seq, pending });
        self.counters.inc("serve/admitted");
        self.counters.set_max("serve/queue_depth_peak", st.heap.len() as u64);
        drop(st);
        self.cv.notify_all();
        Ok(())
    }

    /// Current queue depth (for `/metrics` and trace counters).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().heap.len()
    }

    /// False once [`Self::close`] was called (the gateway is draining).
    pub fn is_open(&self) -> bool {
        self.state.lock().unwrap().open
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn watermark(&self) -> usize {
        self.watermark
    }

    /// Pop up to `max` requests in dispatch order, shedding any whose
    /// deadline expired while queued (their submitters are notified with
    /// [`ServeOutcome::Shed`] and they never count toward `max`). With
    /// `block`, waits until at least one request is available or the
    /// queue closes; otherwise returns an empty batch immediately.
    pub fn pop(&self, max: usize, block: bool) -> Popped {
        if max == 0 {
            return Popped::Batch(Vec::new());
        }
        let mut st = self.state.lock().unwrap();
        loop {
            let mut batch = Vec::new();
            while batch.len() < max {
                let Some(entry) = st.heap.pop() else { break };
                let p = entry.pending;
                if p.expired() {
                    self.counters.inc("serve/shed_deadline");
                    let waited_ms = p.submitted.elapsed().as_secs_f64() * 1e3;
                    let _ = p.reply.send(ServeOutcome::Shed {
                        client_id: p.client_id,
                        reason: ShedReason::DeadlineExpired,
                        waited_ms,
                    });
                    continue;
                }
                self.counters.inc("serve/dispatched");
                self.queue_wait.record_seconds(p.submitted.elapsed().as_secs_f64());
                batch.push(p);
            }
            if !batch.is_empty() {
                return Popped::Batch(batch);
            }
            if !st.open && st.heap.is_empty() {
                return Popped::Closed;
            }
            if !block {
                return Popped::Batch(batch);
            }
            // Re-check periodically: deadlines expire without a notify.
            let (guard, _) =
                self.cv.wait_timeout(st, Duration::from_millis(50)).unwrap();
            st = guard;
        }
    }

    /// Stop admitting; blocked pops drain what's left, then see
    /// [`Popped::Closed`].
    pub fn close(&self) {
        self.state.lock().unwrap().open = false;
        self.cv.notify_all();
    }

    /// Remove and return everything still queued (shutdown path when no
    /// replica will drain it).
    pub fn drain_remaining(&self) -> Vec<Pending> {
        let mut st = self.state.lock().unwrap();
        let mut out: Vec<Entry> = st.heap.drain().collect();
        // Heap drain order is arbitrary; restore dispatch order.
        out.sort_by(|a, b| b.cmp(a));
        out.into_iter().map(|e| e.pending).collect()
    }

    pub fn counters(&self) -> &CounterSet {
        &self.counters
    }

    /// Gateway queue-wait histogram (submit → dispatch) of dispatched
    /// requests.
    pub fn queue_wait(&self) -> &Histogram {
        &self.queue_wait
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::DecodeMethod;
    use std::sync::mpsc;

    fn pending(
        q: &AdmissionQueue,
        client_id: u64,
        opts: SubmitOpts,
    ) -> (Pending, mpsc::Receiver<ServeOutcome>) {
        let (tx, rx) = mpsc::channel();
        let p = Pending {
            req: InferRequest {
                id: q.next_internal_id(),
                prompt: vec![5, 9],
                max_tokens: 4,
                method: DecodeMethod::Greedy,
            },
            opts,
            client_id,
            submitted: Instant::now(),
            reply: tx,
        };
        (p, rx)
    }

    fn queue(cap: usize, watermark: usize) -> AdmissionQueue {
        AdmissionQueue::new(cap, watermark, CounterSet::new())
    }

    #[test]
    fn pops_by_priority_then_fifo() {
        let q = queue(8, 8);
        for (cid, pri) in [(1u64, 0i64), (2, 5), (3, 0), (4, 5)] {
            let (p, _rx) = pending(&q, cid, SubmitOpts { priority: pri, deadline: None });
            q.submit(p).unwrap();
        }
        let Popped::Batch(batch) = q.pop(8, false) else { panic!("closed") };
        let order: Vec<u64> = batch.iter().map(|p| p.client_id).collect();
        // priority 5 first (FIFO within level), then priority 0 FIFO.
        assert_eq!(order, vec![2, 4, 1, 3]);
    }

    #[test]
    fn rejects_past_capacity_and_watermark() {
        let q = queue(3, 2);
        let (p, _r1) = pending(&q, 1, SubmitOpts::default());
        q.submit(p).unwrap();
        let (p, _r2) = pending(&q, 2, SubmitOpts::default());
        q.submit(p).unwrap();
        // depth 2 == watermark: default priority is shed...
        let (p, _r3) = pending(&q, 3, SubmitOpts::default());
        match q.submit(p) {
            Err(AdmitError::ShedLowPriority { depth: 2, watermark: 2, .. }) => {}
            other => panic!("expected watermark shed, got {other:?}"),
        }
        assert_eq!(q.counters().get("serve/shed_lowpri"), 1);
        // ...but priority > 0 still gets in until capacity.
        let (p, _r4) = pending(&q, 4, SubmitOpts { priority: 1, deadline: None });
        q.submit(p).unwrap();
        let (p, _r5) = pending(&q, 5, SubmitOpts { priority: 9, deadline: None });
        match q.submit(p) {
            Err(AdmitError::QueueFull { depth: 3, .. }) => {}
            other => panic!("expected queue full, got {other:?}"),
        }
        assert_eq!(q.counters().get("serve/rejected_full"), 1);
        assert_eq!(q.depth(), 3);
    }

    #[test]
    fn sheds_expired_deadlines_at_pop() {
        let q = queue(8, 8);
        let (p, rx) = pending(
            &q,
            7,
            SubmitOpts { priority: 0, deadline: Some(Duration::ZERO) },
        );
        q.submit(p).unwrap();
        let (p, _rx2) = pending(&q, 8, SubmitOpts::default());
        q.submit(p).unwrap();
        let Popped::Batch(batch) = q.pop(8, false) else { panic!("closed") };
        // Only the live request dispatches; the expired one was shed.
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].client_id, 8);
        assert_eq!(q.counters().get("serve/shed_deadline"), 1);
        match rx.try_recv().unwrap() {
            ServeOutcome::Shed { client_id: 7, reason, .. } => {
                assert_eq!(reason, ShedReason::DeadlineExpired);
            }
            other => panic!("expected shed outcome, got {other:?}"),
        }
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q = queue(4, 4);
        let (p, _rx) = pending(&q, 1, SubmitOpts::default());
        q.submit(p).unwrap();
        q.close();
        let (p, _rx2) = pending(&q, 2, SubmitOpts::default());
        assert_eq!(q.submit(p), Err(AdmitError::Draining));
        let Popped::Batch(batch) = q.pop(4, true) else { panic!("closed early") };
        assert_eq!(batch.len(), 1);
        assert!(matches!(q.pop(4, true), Popped::Closed));
    }

    #[test]
    fn drain_remaining_returns_dispatch_order() {
        let q = queue(8, 8);
        for (cid, pri) in [(1u64, 0i64), (2, 3), (3, 0)] {
            let (p, _rx) = pending(&q, cid, SubmitOpts { priority: pri, deadline: None });
            q.submit(p).unwrap();
        }
        let order: Vec<u64> =
            q.drain_remaining().iter().map(|p| p.client_id).collect();
        assert_eq!(order, vec![2, 1, 3]);
        assert_eq!(q.depth(), 0);
    }
}
