//! Model registry + parameter initialization (S?): the Rust mirror of
//! `python/compile/model.py`'s CONFIGS. Parameters are initialized host-side
//! (truncated normal per the manifest init specs, like t5x's default
//! initializers), or with the cross-language deterministic "pattern" init
//! used by the golden tests.
//!
//! ## Shard-local init ([`shard_params`])
//!
//! Under the shard-resident trainer every host materializes only its
//! `PartitionSpec` block of each parameter. Initialization is
//! *init-then-slice*: the full set is generated once, exactly as in the
//! replicated baseline (same RNG stream, same element order), then each
//! host's blocks are sliced out with [`shard_params`] — so
//! sharded-vs-replicated numerics match bit-for-bit regardless of mesh
//! shape. (The full set exists only transiently, during construction.)

pub mod golden;

use std::collections::BTreeMap;

use crate::partitioning::ShardPlan;
use crate::runtime::artifacts::{ModelManifest, ParamSpec};
use crate::runtime::HostTensor;
use crate::util::rng::{pattern_init, Pcg64};

/// A full set of named host-side parameters.
pub type Params = BTreeMap<String, HostTensor>;

/// Parse an init spec string ("normal:0.05" | "const:1").
fn parse_init(spec: &str) -> (&str, f64) {
    match spec.split_once(':') {
        Some((kind, arg)) => (kind, arg.parse().unwrap_or(0.0)),
        None => (spec, 0.0),
    }
}

/// Initialize all parameters with seeded truncated normals (t5x default).
pub fn init_params(manifest: &ModelManifest, seed: u64) -> Params {
    let mut out = Params::new();
    for p in &manifest.params {
        out.insert(p.name.clone(), init_param(p, seed));
    }
    out
}

/// Initialize one parameter per its manifest init spec.
pub fn init_param(p: &ParamSpec, seed: u64) -> HostTensor {
    let n = p.elements();
    let (kind, arg) = parse_init(&p.init);
    let data: Vec<f32> = match kind {
        "const" => vec![arg as f32; n],
        "normal" => {
            let mut rng = Pcg64::new(seed).fold_in(crate::util::rng::fnv1a64(&p.name));
            (0..n).map(|_| (rng.next_trunc_normal() * arg) as f32).collect()
        }
        other => panic!("unknown init spec '{other}' for {}", p.name),
    };
    HostTensor::f32(p.shape.clone(), data)
}

/// Slice host `host`'s resident blocks out of a full parameter set, in
/// `plan` (= manifest) order — the slice half of init-then-slice (see
/// module docs). The trainer initializes the full set once with
/// [`init_params`] and carves every host's blocks from it, so sharded
/// values equal the replicated baseline's bit-for-bit.
pub fn shard_params(params: &Params, plan: &ShardPlan, host: usize) -> Vec<HostTensor> {
    plan.entries
        .iter()
        .map(|e| {
            params[&e.name].slice_ranges(&e.spec.host_ranges(&plan.mesh, host, &e.shape))
        })
        .collect()
}

/// The deterministic cross-language init (matches `model.pattern_params`).
pub fn pattern_params(manifest: &ModelManifest, seed: u64) -> Params {
    let mut out = Params::new();
    for p in &manifest.params {
        let n = p.elements();
        let (kind, arg) = parse_init(&p.init);
        let data = match kind {
            "const" => vec![arg as f32; n],
            _ => pattern_init(&p.name, n, 0.05, seed),
        };
        out.insert(p.name.clone(), HostTensor::f32(p.shape.clone(), data));
    }
    out
}

/// Total parameter count.
pub fn param_count(params: &Params) -> usize {
    params.values().map(|t| t.elements()).sum()
}

/// Flatten params into manifest order (the HLO input convention).
pub fn params_in_order(manifest: &ModelManifest, params: &Params) -> Vec<HostTensor> {
    manifest
        .params
        .iter()
        .map(|p| {
            params
                .get(&p.name)
                .unwrap_or_else(|| panic!("missing param {}", p.name))
                .clone()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;

    #[test]
    fn init_respects_specs() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let params = init_params(m, 42);
        assert_eq!(params.len(), m.params.len());
        // norm scales are const 1
        let norm = &params["decoder.final_norm.scale"];
        assert!(norm.as_f32().iter().all(|&x| x == 1.0));
        // kernels have roughly the requested stddev
        let wq = &params["decoder.layers_0.self_attn.wq"];
        let std = (wq.as_f32().iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
            / wq.elements() as f64)
            .sqrt();
        let expect = (64f64).powf(-0.5);
        assert!((std - expect).abs() / expect < 0.15, "std={std} expect={expect}");
        // deterministic per seed
        let again = init_params(m, 42);
        assert_eq!(params["token_embed"], again["token_embed"]);
        let other = init_params(m, 43);
        assert_ne!(params["token_embed"], other["token_embed"]);
    }

    #[test]
    fn shard_params_equals_partitioner_shard() {
        use crate::partitioning::{Mesh, ParamStrategy, Partitioner, ShardPlan};
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let mesh = Mesh::new(2, 2);
        let part = Partitioner::new(mesh, ParamStrategy::TwoD);
        let plan = ShardPlan::new(&part, &m.params);
        let full = init_params(m, 7);
        for host in 0..mesh.num_hosts() {
            let shards = shard_params(&full, &plan, host);
            for (e, shard) in plan.entries.iter().zip(&shards) {
                let expect = part.shard(&full[&e.name], &e.spec, host);
                assert_eq!(shard, &expect, "host {host} param {}", e.name);
            }
        }
    }

    #[test]
    fn pattern_params_bounded() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let params = pattern_params(m, 0);
        let emb = &params["token_embed"];
        assert!(emb.as_f32().iter().all(|&x| x.abs() <= 0.05));
        assert!(param_count(&params) > 100_000);
    }

    #[test]
    fn params_in_order_matches_manifest() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let params = pattern_params(m, 0);
        let ordered = params_in_order(m, &params);
        assert_eq!(ordered.len(), m.params.len());
        assert_eq!(ordered[0].shape, m.params[0].shape);
    }
}
