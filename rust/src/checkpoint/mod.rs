//! Checkpointing (paper §2.1 "Checkpointing", S4): multi-host sliced
//! parameter + optimizer-state checkpoints over the [`tstore`] chunked
//! array store, with atomic commit, retention, async save, and a legacy
//! single-file format + converter (the paper's Mesh-TF compatibility
//! claim: converted native checkpoints read faster — measured by
//! `bench_checkpoint`).
//!
//! ## Distributed sharded checkpoints
//!
//! Since the shard-resident trainer refactor, multi-host checkpoints are
//! *written by the block owners* — no host ever gathers the full
//! parameter set:
//!
//! 1. the coordinator host creates the tmp directory and every array's
//!    metadata ([`ShardedWriter::declare`]);
//! 2. after a barrier, every owning host concurrently writes its disjoint
//!    piece — a chunk-aligned [`tstore::write_slice`] row range when the
//!    parameter is sharded along axis 0 only ("rows" layout: the on-disk
//!    array is indistinguishable from a host-0 save), or a per-block
//!    sub-array under a `layout.json` grid ("blocks" layout) when the
//!    sharding involves other dimensions;
//! 3. after a second barrier, the coordinator writes `checkpoint.json`
//!    (now carrying the saving mesh), the pipeline states, and atomically
//!    renames the tmp directory.
//!
//! Reads are topology-agnostic: [`read_array_full`] reassembles any
//! layout (eval / infer / inspect load through it), and
//! [`read_array_range`] pulls an arbitrary per-dimension block range so a
//! run saved on a `4x2` mesh restores on `2x2` or `8x1`
//! (read-with-resharding). The single exception is the "local" layout
//! used for factored (Adafactor row/col) optimizer statistics, which are
//! functions of the saving block shape and only restore on the same mesh.

pub mod legacy;
pub mod tstore;

use std::path::{Path, PathBuf};

use crate::model::Params;
use crate::partitioning::{Mesh, MeshAxis, PartitionSpec};
use crate::runtime::HostTensor;
use crate::seqio::dataset::PipelineState;
use crate::util::json::Json;

/// Extra (non-parameter) f32 vectors saved alongside params — optimizer
/// slots, keyed "optstate/<param>/<slot>".
pub type ExtraState = Vec<(String, Vec<f32>)>;

pub struct CheckpointManager {
    pub dir: PathBuf,
    /// Keep the most recent N checkpoints (t5x `keep`).
    pub retain: usize,
    /// Rows per tstore chunk.
    pub chunk_rows: usize,
}

impl CheckpointManager {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into(), retain: 3, chunk_rows: 1024 }
    }

    fn step_dir(&self, step: u64) -> PathBuf {
        self.dir.join(format!("ckpt-{step:08}"))
    }

    /// All available checkpoint steps, ascending.
    pub fn steps(&self) -> Vec<u64> {
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                if let Some(name) = e.file_name().to_str() {
                    if let Some(num) = name.strip_prefix("ckpt-") {
                        if let Ok(step) = num.parse::<u64>() {
                            out.push(step);
                        }
                    }
                }
            }
        }
        out.sort();
        out
    }

    pub fn latest(&self) -> Option<u64> {
        self.steps().last().copied()
    }

    /// Quarantine a damaged checkpoint: rename `ckpt-<n>` to
    /// `ckpt-<n>.corrupt`, which [`Self::steps`] no longer parses — so
    /// [`Self::latest`] falls back to the previous retained step while the
    /// bad bytes stay on disk for a post-mortem. Returns the new path.
    pub fn quarantine(&self, step: u64) -> std::io::Result<PathBuf> {
        let dir = self.step_dir(step);
        let dst = dir.with_extension("corrupt");
        if dst.exists() {
            std::fs::remove_dir_all(&dst)?;
        }
        std::fs::rename(&dir, &dst)?;
        Ok(dst)
    }

    /// Sweep stale `ckpt-*.tmp` leftovers (a save that died between
    /// `begin_sharded` and the atomic rename). Returns how many were
    /// removed. Deliberately NOT called from the constructor: every rank
    /// builds a manager at the top of the checkpoint barrier while the
    /// coordinator's *live* tmp dir may already exist, so sweeping only
    /// happens at explicit recovery points (`Trainer::restore_latest`,
    /// the supervisor) where no save can be in flight.
    pub fn sweep_tmp(&self) -> usize {
        let mut removed = 0;
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                let name = e.file_name();
                let Some(name) = name.to_str() else { continue };
                if name.starts_with("ckpt-")
                    && name.ends_with(".tmp")
                    && std::fs::remove_dir_all(e.path()).is_ok()
                {
                    eprintln!(
                        "warning: swept partial checkpoint {} (interrupted save)",
                        e.path().display()
                    );
                    removed += 1;
                }
            }
        }
        removed
    }

    /// Save synchronously: params + extra state + metadata, atomic rename.
    pub fn save(&self, step: u64, params: &Params, extra: &ExtraState) -> anyhow::Result<()> {
        self.save_with_pipeline(step, params, extra, None)
    }

    /// [`CheckpointManager::save`] plus the per-host data-pipeline states,
    /// persisted as a CRC-protected tstore byte array (`pipeline/state`,
    /// a JSON array with one entry per host) inside the same atomic
    /// checkpoint directory.
    pub fn save_with_pipeline(
        &self,
        step: u64,
        params: &Params,
        extra: &ExtraState,
        pipeline: Option<&[PipelineState]>,
    ) -> anyhow::Result<()> {
        let final_dir = self.step_dir(step);
        let tmp = final_dir.with_extension("tmp");
        if tmp.exists() {
            std::fs::remove_dir_all(&tmp)?;
        }
        std::fs::create_dir_all(&tmp)?;
        // parallel parameter writes (multi-host writers in t5x; threads here)
        let names: Vec<&String> = params.keys().collect();
        crate::util::threads::parallel_map(names.len(), 8, |i| {
            let t = &params[names[i]];
            tstore::write_full(&tmp, &format!("params/{}", names[i]), t, self.chunk_rows)
                .expect("param write");
        });
        for (key, vec) in extra {
            let t = HostTensor::f32(vec![vec.len()], vec.clone());
            tstore::write_full(&tmp, &format!("optstate/{key}"), &t, self.chunk_rows)?;
        }
        if let Some(states) = pipeline {
            let arr = Json::Arr(states.iter().map(|s| s.0.clone()).collect());
            tstore::write_bytes(
                &tmp,
                "pipeline/state",
                arr.to_string().as_bytes(),
                64 * 1024,
            )?;
        }
        let meta = Json::obj(vec![
            ("step", Json::num(step as f64)),
            ("num_params", Json::num(params.len() as f64)),
            ("has_pipeline", Json::Bool(pipeline.is_some())),
            ("format", Json::str("t5x-native-v1")),
        ]);
        std::fs::write(tmp.join("checkpoint.json"), meta.to_string())?;
        if final_dir.exists() {
            std::fs::remove_dir_all(&final_dir)?;
        }
        std::fs::rename(&tmp, &final_dir)?;
        self.apply_retention()?;
        Ok(())
    }

    /// Async save on a snapshot (t5x saves without blocking the train
    /// loop). `pipeline` carries the per-host data-pipeline states
    /// captured with the snapshot, so async checkpoints are just as
    /// resumable as synchronous ones (pass `None` for synthetic sources).
    pub fn save_async(
        &self,
        step: u64,
        params: Params,
        extra: ExtraState,
        pipeline: Option<Vec<PipelineState>>,
    ) -> std::thread::JoinHandle<anyhow::Result<()>> {
        let mgr = CheckpointManager {
            dir: self.dir.clone(),
            retain: self.retain,
            chunk_rows: self.chunk_rows,
        };
        std::thread::spawn(move || {
            mgr.save_with_pipeline(step, &params, &extra, pipeline.as_deref())
        })
    }

    fn apply_retention(&self) -> anyhow::Result<()> {
        let steps = self.steps();
        if steps.len() > self.retain {
            for &old in &steps[..steps.len() - self.retain] {
                std::fs::remove_dir_all(self.step_dir(old))?;
            }
        }
        Ok(())
    }

    /// Restore all params (full tensors) + extra state at `step`.
    pub fn restore(&self, step: u64) -> anyhow::Result<(Params, ExtraState)> {
        let dir = self.step_dir(step);
        anyhow::ensure!(dir.exists(), "no checkpoint at step {step} in {}", self.dir.display());
        let mut params = Params::new();
        let proot = dir.join("params");
        for name in collect_array_names(&proot)? {
            let t = read_array_full(&proot, &name)
                .map_err(|e| anyhow::anyhow!("restoring {name}: {e}"))?;
            params.insert(name, t);
        }
        let mut extra = ExtraState::new();
        let oroot = dir.join("optstate");
        if oroot.exists() {
            for name in collect_array_names(&oroot)? {
                let t = read_array_full(&oroot, &name)?;
                extra.push((name, t.as_f32().to_vec()));
            }
        }
        Ok((params, extra))
    }

    /// Restore the per-host data-pipeline states saved at `step`, or None
    /// for checkpoints written without pipeline state.
    pub fn restore_pipeline(&self, step: u64) -> anyhow::Result<Option<Vec<PipelineState>>> {
        let dir = self.step_dir(step);
        let bytes = match tstore::read_bytes(&dir, "pipeline/state") {
            Ok(b) => b,
            Err(tstore::TStoreError::NotFound(_)) => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        let text = String::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("pipeline state is not utf-8: {e}"))?;
        let arr = match Json::parse(&text)? {
            Json::Arr(a) => a,
            other => anyhow::bail!("pipeline state is not a JSON array: {other}"),
        };
        Ok(Some(arr.into_iter().map(PipelineState).collect()))
    }

    /// Restore a row-slice of one parameter (read-with-resharding: a host
    /// pulls only its shard regardless of the saving topology).
    pub fn restore_param_slice(
        &self,
        step: u64,
        name: &str,
        start_row: usize,
        rows: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let proot = self.step_dir(step).join("params");
        let layout = open_layout(&proot, name)?;
        let shape = layout.shape();
        anyhow::ensure!(!shape.is_empty(), "cannot row-slice scalar array {name}");
        let mut ranges: Vec<(usize, usize)> = shape.iter().map(|&d| (0, d)).collect();
        ranges[0] = (start_row, rows);
        Ok(read_array_range(&proot, name, &ranges)?.as_f32().to_vec())
    }

    /// Restore an arbitrary per-dimension block range of one parameter —
    /// the read-with-resharding entry point the sharded trainer restores
    /// through (works against any saving topology/layout).
    pub fn restore_param_range(
        &self,
        step: u64,
        name: &str,
        ranges: &[(usize, usize)],
    ) -> anyhow::Result<HostTensor> {
        read_array_range(&self.step_dir(step).join("params"), name, ranges)
    }

    /// Whether an optimizer-state array exists at `step` (params-only
    /// checkpoints, e.g. legacy conversions, have none).
    pub fn has_optstate(&self, step: u64, name: &str) -> bool {
        let dir = self.step_dir(step).join("optstate").join(name);
        dir.join("meta.json").exists() || dir.join("layout.json").exists()
    }

    /// On-disk layout of an optimizer-state array (callers use it to
    /// route factored slots and to degrade gracefully on legacy formats).
    pub fn optstate_layout(&self, step: u64, name: &str) -> anyhow::Result<ArrayLayout> {
        open_layout(&self.step_dir(step).join("optstate"), name)
    }

    /// Same range read against an optimizer-state array.
    pub fn restore_optstate_range(
        &self,
        step: u64,
        name: &str,
        ranges: &[(usize, usize)],
    ) -> anyhow::Result<HostTensor> {
        read_array_range(&self.step_dir(step).join("optstate"), name, ranges)
    }

    /// A topology-local optimizer block (factored stats), valid only when
    /// the restoring mesh matches the saving mesh.
    pub fn restore_optstate_local(
        &self,
        step: u64,
        name: &str,
        mesh: &Mesh,
        coords: (usize, usize),
    ) -> anyhow::Result<Vec<f32>> {
        let root = self.step_dir(step).join("optstate");
        match open_layout(&root, name)? {
            ArrayLayout::Local { mesh: saved } => {
                anyhow::ensure!(
                    saved == (mesh.data, mesh.model),
                    "optimizer state '{name}' is topology-local (factored stats), saved on a \
                     {}x{} mesh; restore on the same mesh or switch to an elementwise optimizer",
                    saved.0,
                    saved.1
                );
                let t = tstore::read_full(&root, &format!("{name}/{}", block_dir(coords)))?;
                Ok(t.as_f32().to_vec())
            }
            _ => anyhow::bail!("optimizer state '{name}' is not a local-layout array"),
        }
    }

    /// The mesh a checkpoint was saved on (None for host-0 v1 saves).
    pub fn saved_mesh(&self, step: u64) -> anyhow::Result<Option<Mesh>> {
        let j = Json::parse_file(self.step_dir(step).join("checkpoint.json"))?;
        Ok(j.get("mesh").and_then(|v| v.as_arr()).and_then(|a| {
            match (a.first().and_then(|x| x.as_usize()), a.get(1).and_then(|x| x.as_usize())) {
                (Some(d), Some(m)) => Some(Mesh::new(d, m)),
                _ => None,
            }
        }))
    }

    // -- distributed sharded save (see module docs) -----------------------

    /// The deterministic writer handle for `step` (same path on every
    /// host; only the coordinator calls [`CheckpointManager::begin_sharded`]).
    pub fn sharded_writer(&self, step: u64) -> ShardedWriter {
        ShardedWriter {
            tmp: self.step_dir(step).with_extension("tmp"),
            chunk_rows: self.chunk_rows,
        }
    }

    /// Phase 1, coordinator only: (re)create the tmp directory.
    pub fn begin_sharded(&self, step: u64) -> anyhow::Result<ShardedWriter> {
        let w = self.sharded_writer(step);
        if w.tmp.exists() {
            std::fs::remove_dir_all(&w.tmp)?;
        }
        std::fs::create_dir_all(&w.tmp)?;
        Ok(w)
    }

    /// Phase 3, coordinator only: metadata + pipeline states + atomic
    /// rename + retention. All owners must have finished writing (the
    /// trainer barriers between phases).
    pub fn commit_sharded(
        &self,
        step: u64,
        num_params: usize,
        mesh: Mesh,
        pipeline: Option<&[PipelineState]>,
    ) -> anyhow::Result<()> {
        let w = self.sharded_writer(step);
        if let Some(states) = pipeline {
            let arr = Json::Arr(states.iter().map(|s| s.0.clone()).collect());
            tstore::write_bytes(&w.tmp, "pipeline/state", arr.to_string().as_bytes(), 64 * 1024)?;
        }
        let meta = Json::obj(vec![
            ("step", Json::num(step as f64)),
            ("num_params", Json::num(num_params as f64)),
            ("has_pipeline", Json::Bool(pipeline.is_some())),
            ("mesh", Json::arr_usize(&[mesh.data, mesh.model])),
            ("format", Json::str("t5x-native-v2")),
        ]);
        std::fs::write(w.tmp.join("checkpoint.json"), meta.to_string())?;
        let final_dir = self.step_dir(step);
        if final_dir.exists() {
            std::fs::remove_dir_all(&final_dir)?;
        }
        std::fs::rename(&w.tmp, &final_dir)?;
        self.apply_retention()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Sharded array layouts
// ---------------------------------------------------------------------------

fn axis_tag(a: MeshAxis) -> &'static str {
    match a {
        MeshAxis::Data => "data",
        MeshAxis::Model => "model",
    }
}

fn axis_from_tag(s: &str) -> anyhow::Result<MeshAxis> {
    match s {
        "data" => Ok(MeshAxis::Data),
        "model" => Ok(MeshAxis::Model),
        other => anyhow::bail!("unknown mesh axis tag '{other}' in layout.json"),
    }
}

fn block_dir(coords: (usize, usize)) -> String {
    format!("block-{}-{}", coords.0, coords.1)
}

/// A host's block coordinates for an array: its mesh coordinate along each
/// axis the spec shards over, 0 along replicated axes — so replicas of the
/// same block project to the same name and exactly one (the owner) writes.
pub fn block_coords(spec: &PartitionSpec, mesh: &Mesh, host: usize) -> (usize, usize) {
    let proj = |axis| {
        if spec.dim_for(axis).is_some() {
            mesh.coord(host, axis)
        } else {
            0
        }
    };
    (proj(MeshAxis::Data), proj(MeshAxis::Model))
}

/// On-disk layout of one checkpoint array.
pub enum ArrayLayout {
    /// A single tstore array (replicated saves, legacy v1 checkpoints, and
    /// "rows" saves where owners wrote disjoint chunk-aligned row slices).
    Plain(tstore::ArrayMeta),
    /// A `layout.json` grid of per-block sub-arrays (sharding touching a
    /// non-0 dimension).
    Blocks {
        shape: Vec<usize>,
        /// Per tensor dimension: `Some((axis, shards))` or None.
        dims: Vec<Option<(MeshAxis, usize)>>,
    },
    /// Topology-local per-host blocks (factored optimizer stats).
    Local { mesh: (usize, usize) },
}

impl ArrayLayout {
    pub fn shape(&self) -> Vec<usize> {
        match self {
            ArrayLayout::Plain(m) => m.shape.clone(),
            ArrayLayout::Blocks { shape, .. } => shape.clone(),
            ArrayLayout::Local { .. } => Vec::new(),
        }
    }
}

/// Open an array's layout: `layout.json` if present, else a plain tstore
/// array.
pub fn open_layout(root: &Path, name: &str) -> anyhow::Result<ArrayLayout> {
    let lpath = root.join(name).join("layout.json");
    if !lpath.exists() {
        return Ok(ArrayLayout::Plain(tstore::open_array(root, name)?));
    }
    let j = Json::parse_file(&lpath)?;
    match j.get("mode").and_then(|v| v.as_str()) {
        Some("blocks") => {
            let shape: Vec<usize> = j
                .get("shape")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default();
            let mut dims = Vec::with_capacity(shape.len());
            for d in j.get("dims").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                dims.push(match d.as_arr() {
                    Some(pair) if pair.len() == 2 => {
                        let axis = axis_from_tag(pair[0].as_str().unwrap_or(""))?;
                        let n = pair[1]
                            .as_usize()
                            .ok_or_else(|| anyhow::anyhow!("bad shard count in layout.json"))?;
                        Some((axis, n))
                    }
                    _ => None,
                });
            }
            anyhow::ensure!(dims.len() == shape.len(), "layout.json dims/shape mismatch for {name}");
            Ok(ArrayLayout::Blocks { shape, dims })
        }
        Some("local") => {
            let mesh = j
                .get("mesh")
                .and_then(|v| v.as_arr())
                .and_then(|a| {
                    Some((a.first()?.as_usize()?, a.get(1)?.as_usize()?))
                })
                .ok_or_else(|| anyhow::anyhow!("local layout.json missing mesh for {name}"))?;
            Ok(ArrayLayout::Local { mesh })
        }
        other => anyhow::bail!("unknown layout mode {other:?} for array {name}"),
    }
}

/// Read the whole array, reassembling block layouts. Local layouts concat
/// their blocks in coordinate order (diagnostic use only).
pub fn read_array_full(root: &Path, name: &str) -> anyhow::Result<HostTensor> {
    match open_layout(root, name)? {
        ArrayLayout::Plain(_) => Ok(tstore::read_full(root, name)?),
        ArrayLayout::Blocks { shape, .. } => {
            let ranges: Vec<(usize, usize)> = shape.iter().map(|&d| (0, d)).collect();
            read_array_range(root, name, &ranges)
        }
        ArrayLayout::Local { mesh } => {
            let mut data = Vec::new();
            for d in 0..mesh.0 {
                for m in 0..mesh.1 {
                    let bname = format!("{name}/{}", block_dir((d, m)));
                    if root.join(&bname).join("meta.json").exists() {
                        data.extend_from_slice(tstore::read_full(root, &bname)?.as_f32());
                    }
                }
            }
            Ok(HostTensor::f32(vec![data.len()], data))
        }
    }
}

/// Read an arbitrary per-dimension `(start, len)` block range — THE
/// read-with-resharding primitive. Plain arrays use sliced row IO plus
/// in-memory column slicing; block arrays read only the overlapping
/// blocks.
pub fn read_array_range(
    root: &Path,
    name: &str,
    ranges: &[(usize, usize)],
) -> anyhow::Result<HostTensor> {
    match open_layout(root, name)? {
        ArrayLayout::Plain(meta) => {
            anyhow::ensure!(
                ranges.len() == meta.shape.len(),
                "range rank {} vs array rank {} for {name}",
                ranges.len(),
                meta.shape.len()
            );
            if meta.shape.is_empty() {
                return Ok(tstore::read_full(root, name)?);
            }
            let (r0, rl) = ranges[0];
            let rows = tstore::read_slice(root, name, &meta, r0, rl)?;
            let mut shape = meta.shape.clone();
            shape[0] = rl;
            let t = HostTensor::f32(shape, rows);
            let mut rel = ranges.to_vec();
            rel[0] = (0, rl);
            Ok(t.slice_ranges(&rel))
        }
        ArrayLayout::Blocks { shape, dims } => {
            anyhow::ensure!(ranges.len() == shape.len(), "range rank mismatch for {name}");
            // Needed block-index range per mesh axis (0..=0 when the axis
            // does not shard this array).
            let info = |axis: MeshAxis| -> (Option<usize>, usize, usize, usize) {
                // (dim, block_size, lo_block, hi_block)
                for (dim, d) in dims.iter().enumerate() {
                    if let Some((a, n)) = d {
                        if *a == axis {
                            let bsz = shape[dim] / n;
                            let (s, l) = ranges[dim];
                            return (Some(dim), bsz, s / bsz, (s + l - 1) / bsz);
                        }
                    }
                }
                (None, 0, 0, 0)
            };
            let (d_dim, d_bsz, d_lo, d_hi) = info(MeshAxis::Data);
            let (m_dim, m_bsz, m_lo, m_hi) = info(MeshAxis::Model);
            let mut data_parts = Vec::with_capacity(d_hi - d_lo + 1);
            for di in d_lo..=d_hi {
                let mut model_parts = Vec::with_capacity(m_hi - m_lo + 1);
                for mi in m_lo..=m_hi {
                    let bname = format!("{name}/{}", block_dir((di, mi)));
                    model_parts.push(tstore::read_full(root, &bname)?);
                }
                data_parts.push(match m_dim {
                    Some(dim) => HostTensor::concat_axis(&model_parts, dim),
                    None => model_parts.remove(0),
                });
            }
            let assembled = match d_dim {
                Some(dim) => HostTensor::concat_axis(&data_parts, dim),
                None => data_parts.remove(0),
            };
            // Slice to the requested range, relative to the assembled
            // region's origin.
            let rel: Vec<(usize, usize)> = ranges
                .iter()
                .enumerate()
                .map(|(dim, &(s, l))| {
                    let off = if Some(dim) == d_dim {
                        d_lo * d_bsz
                    } else if Some(dim) == m_dim {
                        m_lo * m_bsz
                    } else {
                        0
                    };
                    (s - off, l)
                })
                .collect();
            Ok(assembled.slice_ranges(&rel))
        }
        ArrayLayout::Local { .. } => anyhow::bail!(
            "array {name} has topology-local layout (factored optimizer stats) and cannot \
             be range-read; restore on the saving mesh"
        ),
    }
}

/// Per-array writer used during a distributed sharded save.
pub struct ShardedWriter {
    pub tmp: PathBuf,
    chunk_rows: usize,
}

impl ShardedWriter {
    fn is_rows_mode(spec: &PartitionSpec) -> bool {
        spec.is_sharded()
            && spec
                .dims
                .iter()
                .enumerate()
                .all(|(i, d)| d.is_none() || i == 0)
    }

    /// Phase 1 (coordinator): create array metadata. Replicated specs get
    /// a plain array; axis-0-only sharding gets a plain array whose
    /// chunking aligns with the writers' row slices; anything else gets a
    /// block grid.
    pub fn declare(
        &self,
        name: &str,
        shape: &[usize],
        spec: &PartitionSpec,
    ) -> anyhow::Result<()> {
        if !spec.is_sharded() {
            tstore::create_array(&self.tmp, name, shape, self.chunk_rows)?;
        } else if Self::is_rows_mode(spec) {
            let shards = spec.dims[0].expect("rows mode shards dim 0").1;
            let shard_rows = shape[0] / shards;
            tstore::create_array(&self.tmp, name, shape, shard_rows.max(1))?;
        } else {
            let dir = self.tmp.join(name);
            std::fs::create_dir_all(&dir)?;
            let dims = Json::Arr(
                spec.dims
                    .iter()
                    .map(|d| match d {
                        Some((a, n)) => Json::Arr(vec![
                            Json::str(axis_tag(*a)),
                            Json::num(*n as f64),
                        ]),
                        None => Json::Null,
                    })
                    .collect(),
            );
            let j = Json::obj(vec![
                ("mode", Json::str("blocks")),
                ("shape", Json::arr_usize(shape)),
                ("dims", dims),
            ]);
            std::fs::write(dir.join("layout.json"), j.to_string())?;
        }
        Ok(())
    }

    /// Phase 2 (every owner, concurrently): write this host's block.
    /// Caller must ensure `spec.owns(mesh, host)` — replicas skip.
    pub fn write_block(
        &self,
        name: &str,
        spec: &PartitionSpec,
        mesh: &Mesh,
        host: usize,
        block: &HostTensor,
    ) -> anyhow::Result<()> {
        if !spec.is_sharded() {
            let meta = tstore::open_array(&self.tmp, name)?;
            tstore::write_slice(&self.tmp, name, &meta, 0, block.as_f32())?;
        } else if Self::is_rows_mode(spec) {
            let meta = tstore::open_array(&self.tmp, name)?;
            let start_row = spec.host_ranges(mesh, host, &meta.shape)[0].0;
            tstore::write_slice(&self.tmp, name, &meta, start_row, block.as_f32())?;
        } else {
            let bname = format!("{name}/{}", block_dir(block_coords(spec, mesh, host)));
            tstore::write_full(&self.tmp, &bname, block, self.chunk_rows)?;
        }
        Ok(())
    }

    /// Phase 1 (coordinator): declare a topology-local array (factored
    /// optimizer stats, restorable only on the same mesh).
    pub fn declare_local(&self, name: &str, mesh: &Mesh) -> anyhow::Result<()> {
        let dir = self.tmp.join(name);
        std::fs::create_dir_all(&dir)?;
        let j = Json::obj(vec![
            ("mode", Json::str("local")),
            ("mesh", Json::arr_usize(&[mesh.data, mesh.model])),
        ]);
        std::fs::write(dir.join("layout.json"), j.to_string())?;
        Ok(())
    }

    /// Phase 2 (owners): write a local block keyed by the host's projected
    /// block coordinates.
    pub fn write_local(
        &self,
        name: &str,
        spec: &PartitionSpec,
        mesh: &Mesh,
        host: usize,
        data: &[f32],
    ) -> anyhow::Result<()> {
        let bname = format!("{name}/{}", block_dir(block_coords(spec, mesh, host)));
        let t = HostTensor::f32(vec![data.len()], data.to_vec());
        tstore::write_full(&self.tmp, &bname, &t, self.chunk_rows)?;
        Ok(())
    }
}

/// Array names under a tstore root, including nested (slash-joined) names.
/// A directory holding `meta.json` (plain array) or `layout.json` (block /
/// local array) is one array — its contents are not descended into.
fn collect_array_names(root: &Path) -> anyhow::Result<Vec<String>> {
    fn walk(dir: &Path, prefix: String, out: &mut Vec<String>) -> anyhow::Result<()> {
        if dir.join("meta.json").exists() || dir.join("layout.json").exists() {
            out.push(prefix);
            return Ok(());
        }
        for e in std::fs::read_dir(dir)? {
            let p = e?.path();
            if p.is_dir() {
                let name = p.file_name().unwrap().to_string_lossy().into_owned();
                let next = if prefix.is_empty() { name } else { format!("{prefix}/{name}") };
                walk(&p, next, out)?;
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    if root.exists() {
        walk(root, String::new(), &mut out)?;
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ckptmgr_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn fake_params() -> Params {
        let mut p = Params::new();
        p.insert(
            "decoder.layers_0.wq".into(),
            HostTensor::f32(vec![8, 4], (0..32).map(|i| i as f32).collect()),
        );
        p.insert("final_norm.scale".into(), HostTensor::f32(vec![4], vec![1.0; 4]));
        p
    }

    #[test]
    fn save_restore_roundtrip_with_optstate() {
        let dir = tmp("rt");
        let mgr = CheckpointManager::new(&dir);
        let params = fake_params();
        let extra: ExtraState =
            vec![("decoder.layers_0.wq/m".into(), vec![0.5; 32])];
        mgr.save(100, &params, &extra).unwrap();
        assert_eq!(mgr.latest(), Some(100));
        let (back, ex) = mgr.restore(100).unwrap();
        assert_eq!(back, params);
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].0, "decoder.layers_0.wq/m");
        assert_eq!(ex[0].1, vec![0.5; 32]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pipeline_state_saved_and_restored() {
        let dir = tmp("pipe");
        let mgr = CheckpointManager::new(&dir);
        let mk = |k: f64| {
            PipelineState(Json::obj(vec![
                ("op", Json::str("det_reader")),
                ("emitted_total", Json::num(k)),
            ]))
        };
        let states = vec![mk(42.0), mk(17.0)];
        mgr.save_with_pipeline(5, &fake_params(), &Vec::new(), Some(&states))
            .unwrap();
        let back = mgr.restore_pipeline(5).unwrap().unwrap();
        assert_eq!(back, states);
        // plain saves carry no pipeline state
        mgr.save(6, &fake_params(), &Vec::new()).unwrap();
        assert!(mgr.restore_pipeline(6).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retention_keeps_last_n() {
        let dir = tmp("retain");
        let mut mgr = CheckpointManager::new(&dir);
        mgr.retain = 2;
        let params = fake_params();
        for step in [1u64, 2, 3, 4] {
            mgr.save(step, &params, &Vec::new()).unwrap();
        }
        assert_eq!(mgr.steps(), vec![3, 4]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sliced_restore_for_resharding() {
        let dir = tmp("reshard");
        let mut mgr = CheckpointManager::new(&dir);
        mgr.chunk_rows = 2;
        let params = fake_params();
        mgr.save(7, &params, &Vec::new()).unwrap();
        // host 1 of 2 pulls rows 4..8 of the 8-row param
        let rows = mgr
            .restore_param_slice(7, "decoder.layers_0.wq", 4, 4)
            .unwrap();
        assert_eq!(rows, (16..32).map(|i| i as f32).collect::<Vec<_>>());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_save_completes() {
        let dir = tmp("async");
        let mgr = CheckpointManager::new(&dir);
        let h = mgr.save_async(3, fake_params(), Vec::new(), None);
        h.join().unwrap().unwrap();
        assert_eq!(mgr.latest(), Some(3));
        assert!(mgr.restore_pipeline(3).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_save_carries_pipeline_state() {
        let dir = tmp("async_pipe");
        let mgr = CheckpointManager::new(&dir);
        let states = vec![PipelineState(Json::obj(vec![
            ("op", Json::str("vec")),
            ("pos", Json::num(9.0)),
        ]))];
        let h = mgr.save_async(4, fake_params(), Vec::new(), Some(states.clone()));
        h.join().unwrap().unwrap();
        assert_eq!(mgr.restore_pipeline(4).unwrap().unwrap(), states);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_missing_step_errors() {
        let dir = tmp("missing");
        let mgr = CheckpointManager::new(&dir);
        assert!(mgr.restore(99).is_err());
    }

    #[test]
    fn sharded_save_rows_and_blocks_roundtrip() {
        use crate::partitioning::{ParamStrategy, Partitioner};
        use crate::runtime::artifacts::ParamSpec;

        let dir = tmp("sharded");
        let mgr = CheckpointManager::new(&dir);
        let mesh = Mesh::new(2, 2);
        let part = Partitioner::new(mesh, ParamStrategy::TwoD);

        // w: model-shards dim 1, data-shards dim 0 -> blocks layout
        let w_spec = part.spec_for(&ParamSpec {
            name: "w".into(),
            shape: vec![8, 12],
            logical_axes: vec!["embed".into(), "mlp".into()],
            init: "const:0".into(),
        });
        // v: data-shards dim 0 only -> rows layout (sliced writes)
        let v_spec = part.spec_for(&ParamSpec {
            name: "v".into(),
            shape: vec![8],
            logical_axes: vec!["embed".into()],
            init: "const:0".into(),
        });
        // s: indivisible -> replicated, plain array from the coordinator
        let s_spec = PartitionSpec::replicated(1);

        let w_full = HostTensor::f32(vec![8, 12], (0..96).map(|i| i as f32).collect());
        let v_full = HostTensor::f32(vec![8], (0..8).map(|i| i as f32).collect());
        let s_full = HostTensor::f32(vec![3], vec![7.0, 8.0, 9.0]);

        // phase 1: coordinator declares
        let writer = mgr.begin_sharded(5).unwrap();
        writer.declare("params/w", &w_full.shape, &w_spec).unwrap();
        writer.declare("params/v", &v_full.shape, &v_spec).unwrap();
        writer.declare("params/s", &s_full.shape, &s_spec).unwrap();
        // phase 2: each owner writes its disjoint block (serial here; the
        // trainer does this from all host threads concurrently)
        for host in 0..4 {
            for (name, full, spec) in [
                ("params/w", &w_full, &w_spec),
                ("params/v", &v_full, &v_spec),
                ("params/s", &s_full, &s_spec),
            ] {
                if spec.owns(&mesh, host) {
                    let block = full.slice_ranges(&spec.host_ranges(&mesh, host, &full.shape));
                    writer.write_block(name, spec, &mesh, host, &block).unwrap();
                }
            }
        }
        // phase 3: commit
        mgr.commit_sharded(5, 3, mesh, None).unwrap();
        assert_eq!(mgr.saved_mesh(5).unwrap(), Some(mesh));

        // full restore reassembles every layout
        let (params, _) = mgr.restore(5).unwrap();
        assert_eq!(params["w"], w_full);
        assert_eq!(params["v"], v_full);
        assert_eq!(params["s"], s_full);

        // read-with-resharding: a 1x2-mesh host's block of w (full rows,
        // model-half of columns) straddles two saved blocks
        let got = mgr.restore_param_range(5, "w", &[(0, 8), (6, 6)]).unwrap();
        assert_eq!(got, w_full.slice_ranges(&[(0, 8), (6, 6)]));
        // row-sliced read of the rows-layout array
        assert_eq!(
            mgr.restore_param_slice(5, "v", 2, 4).unwrap(),
            (2..6).map(|i| i as f32).collect::<Vec<_>>()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn local_layout_guards_topology() {
        let dir = tmp("local");
        let mgr = CheckpointManager::new(&dir);
        let mesh = Mesh::new(2, 1);
        let spec = PartitionSpec { dims: vec![Some((MeshAxis::Data, 2))] };
        let writer = mgr.begin_sharded(1).unwrap();
        writer.declare_local("optstate/w/vr", &mesh).unwrap();
        for host in 0..2 {
            writer
                .write_local("optstate/w/vr", &spec, &mesh, host, &[host as f32; 4])
                .unwrap();
        }
        // params must exist for restore(); give it one
        writer
            .declare("params/p", &[2], &PartitionSpec::replicated(1))
            .unwrap();
        writer
            .write_block(
                "params/p",
                &PartitionSpec::replicated(1),
                &mesh,
                0,
                &HostTensor::f32(vec![2], vec![1.0, 2.0]),
            )
            .unwrap();
        mgr.commit_sharded(1, 1, mesh, None).unwrap();

        // same-mesh restore reads the host's own block
        let got = mgr.restore_optstate_local(1, "w/vr", &mesh, (1, 0)).unwrap();
        assert_eq!(got, vec![1.0; 4]);
        // a different mesh is rejected with a clear error
        let err = mgr
            .restore_optstate_local(1, "w/vr", &Mesh::new(4, 1), (0, 0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("topology-local"), "{err}");
        // and range reads refuse local arrays
        assert!(read_array_range(&dir.join("ckpt-00000001/optstate"), "w/vr", &[(0, 4)]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
