"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/dtypes/block sizes; assert_allclose against ref.py.
This is the gate that `make artifacts` quality rests on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import bwd_reference, flash_attention
from compile.kernels.fused_ffn import fused_ffn

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


dims = st.sampled_from([8, 16, 24, 32, 48, 64])
small = st.sampled_from([1, 2, 3])
heads = st.sampled_from([1, 2, 4])
head_dim = st.sampled_from([8, 16, 32])
blocks = st.sampled_from([8, 16, 32, 64])
dtypes = st.sampled_from([jnp.float32, jnp.bfloat16])


@settings(**SETTINGS)
@given(b=small, h=heads, lq=dims, lk=dims, d=head_dim, bq=blocks, bk=blocks,
       dtype=dtypes, seed=st.integers(0, 2**16))
def test_attention_fwd_matches_ref(b, h, lq, lk, d, bq, bk, dtype, seed):
    key = jax.random.PRNGKey(seed)
    q = _rand(jax.random.fold_in(key, 0), (b, h, lq, d), dtype)
    k = _rand(jax.random.fold_in(key, 1), (b, h, lk, d), dtype)
    v = _rand(jax.random.fold_in(key, 2), (b, h, lk, d), dtype)
    bias = _rand(jax.random.fold_in(key, 3), (h, lq, lk), dtype, 0.2)
    out = flash_attention(q, k, v, bias, False, bq, bk)
    expect = ref.attention_ref(q, k, v, bias, causal=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=tol, rtol=tol,
    )


@settings(**SETTINGS)
@given(b=small, h=heads, l=dims, d=head_dim, bq=blocks, bk=blocks,
       seed=st.integers(0, 2**16))
def test_attention_causal_fwd_matches_ref(b, h, l, d, bq, bk, seed):
    key = jax.random.PRNGKey(seed)
    q = _rand(jax.random.fold_in(key, 0), (b, h, l, d), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (b, h, l, d), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (b, h, l, d), jnp.float32)
    bias = _rand(jax.random.fold_in(key, 3), (h, l, l), jnp.float32, 0.2)
    out = flash_attention(q, k, v, bias, True, bq, bk)
    expect = ref.attention_ref(q, k, v, bias, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(b=small, h=heads, lq=dims, lk=dims, d=head_dim, causal=st.booleans(),
       seed=st.integers(0, 2**16))
def test_attention_bwd_matches_ref(b, h, lq, lk, d, causal, seed):
    if causal:
        lk = lq  # causal requires square attention
    key = jax.random.PRNGKey(seed)
    q = _rand(jax.random.fold_in(key, 0), (b, h, lq, d), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (b, h, lk, d), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (b, h, lk, d), jnp.float32)
    bias = _rand(jax.random.fold_in(key, 3), (h, lq, lk), jnp.float32, 0.2)
    do = _rand(jax.random.fold_in(key, 4), (b, h, lq, d), jnp.float32)

    def f(q_, k_, v_, b_):
        return (flash_attention(q_, k_, v_, b_, causal, 16, 16) * do).sum()

    grads = jax.grad(f, argnums=(0, 1, 2, 3))(q, k, v, bias)
    expect = bwd_reference(q, k, v, bias, do, causal=causal)
    for name, g, e in zip(("dq", "dk", "dv", "dbias"), grads, expect):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(e), atol=1e-4, rtol=1e-4, err_msg=name
        )


def test_attention_rejects_nothing_degenerate():
    """Single-token, single-head edge case."""
    q = jnp.ones((1, 1, 1, 8))
    bias = jnp.zeros((1, 1, 1))
    out = flash_attention(q, q, q, bias, True, 64, 64)
    np.testing.assert_allclose(np.asarray(out), np.ones((1, 1, 1, 8)), atol=1e-6)


def test_attention_masks_future_positions():
    """A causal query must be unaffected by future keys/values."""
    key = jax.random.PRNGKey(0)
    b, h, l, d = 1, 2, 16, 8
    q = _rand(jax.random.fold_in(key, 0), (b, h, l, d), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (b, h, l, d), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (b, h, l, d), jnp.float32)
    bias = jnp.zeros((h, l, l))
    out1 = flash_attention(q, k, v, bias, True, 8, 8)
    # Perturb the second half of k/v: first-half outputs must not change.
    k2 = k.at[:, :, l // 2:].set(123.0)
    v2 = v.at[:, :, l // 2:].set(-7.0)
    out2 = flash_attention(q, k2, v2, bias, True, 8, 8)
    np.testing.assert_allclose(
        np.asarray(out1[:, :, : l // 2]), np.asarray(out2[:, :, : l // 2]), atol=1e-6
    )


@settings(**SETTINGS)
@given(m=st.sampled_from([8, 16, 32, 64, 128]), k=st.sampled_from([16, 32, 64]),
       f=st.sampled_from([32, 64, 128, 256]), bm=blocks, bf=blocks,
       dtype=dtypes, seed=st.integers(0, 2**16))
def test_ffn_fwd_matches_ref(m, k, f, bm, bf, dtype, seed):
    key = jax.random.PRNGKey(seed)
    x = _rand(jax.random.fold_in(key, 0), (m, k), dtype)
    wi0 = _rand(jax.random.fold_in(key, 1), (k, f), dtype, k**-0.5)
    wi1 = _rand(jax.random.fold_in(key, 2), (k, f), dtype, k**-0.5)
    wo = _rand(jax.random.fold_in(key, 3), (f, k), dtype, f**-0.5)
    out = fused_ffn(x, wi0, wi1, wo, bm, bf)
    expect = ref.gated_ffn_ref(x, wi0, wi1, wo)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=tol, rtol=tol,
    )


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([8, 32, 64]), k=st.sampled_from([16, 32]),
       f=st.sampled_from([32, 128]), seed=st.integers(0, 2**16))
def test_ffn_bwd_matches_ref(m, k, f, seed):
    key = jax.random.PRNGKey(seed)
    x = _rand(jax.random.fold_in(key, 0), (m, k), jnp.float32)
    wi0 = _rand(jax.random.fold_in(key, 1), (k, f), jnp.float32, k**-0.5)
    wi1 = _rand(jax.random.fold_in(key, 2), (k, f), jnp.float32, k**-0.5)
    wo = _rand(jax.random.fold_in(key, 3), (f, k), jnp.float32, f**-0.5)
    g = jax.grad(lambda *a: fused_ffn(*a, 16, 32).sum(), argnums=(0, 1, 2, 3))(
        x, wi0, wi1, wo
    )
    ge = jax.grad(lambda *a: ref.gated_ffn_ref(*a).sum(), argnums=(0, 1, 2, 3))(
        x, wi0, wi1, wo
    )
    for name, a, b in zip(("dx", "dwi0", "dwi1", "dwo"), g, ge):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5, err_msg=name
        )


def test_ffn_odd_sizes_fall_back_to_divisor_blocks():
    """Non-power-of-two dims must still be exact (block clamping)."""
    key = jax.random.PRNGKey(7)
    x = _rand(jax.random.fold_in(key, 0), (24, 20), jnp.float32)
    wi0 = _rand(jax.random.fold_in(key, 1), (20, 36), jnp.float32)
    wi1 = _rand(jax.random.fold_in(key, 2), (20, 36), jnp.float32)
    wo = _rand(jax.random.fold_in(key, 3), (36, 20), jnp.float32)
    out = fused_ffn(x, wi0, wi1, wo, 128, 128)
    expect = ref.gated_ffn_ref(x, wi0, wi1, wo)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4, rtol=1e-4)
