//! seqio deterministic-pipeline demo (E2, E5-E8): Figure 2 + the four §3.2
//! properties, demonstrated live with the actual artifacts on disk.
//!
//! ```bash
//! cargo run --release --example data_pipeline
//! ```

use t5x::seqio::cache::{cache_task, CacheConfig};
use t5x::seqio::deterministic::DeterministicPipeline;
use t5x::seqio::feature_converters::{
    lengths, EncDecConverter, FeatureConverter, LmConverter,
};
use t5x::trainer::recipes;
use t5x::util::stats::lag1_autocorrelation;

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join("t5x_data_pipeline_demo");
    let _ = std::fs::remove_dir_all(&dir);

    // ---- Figure 2: Task = source -> preprocessors -> features ----------
    println!("== Figure 2: the Task pipeline ==");
    let task = recipes::span_corruption_task("demo_span", 300, 96, 7);
    let sample = task.dataset(1, 0, 1).take(1).collect_vec().remove(0);
    println!(
        "task features: inputs[{}] targets[{}] (span corruption, sentinels at vocab top)",
        sample["inputs"].len(),
        sample["targets"].len()
    );
    // one task, two architectures (feature converters)
    let tl = lengths(&[("inputs", 96), ("targets", 48)]);
    let ed = EncDecConverter.convert_example(&sample, &tl);
    let lm = LmConverter.convert_example(&sample, &tl);
    println!(
        "enc-dec features: {:?}",
        ed.keys().collect::<Vec<_>>()
    );
    println!("decoder-only features: {:?}", lm.keys().collect::<Vec<_>>());

    // ---- §3.2: the deterministic cache ---------------------------------
    println!("\n== §3.2 deterministic pipeline ==");
    let t0 = std::time::Instant::now();
    let meta = cache_task(&task, &dir, &CacheConfig { num_shards: 8, seed: 0, workers: 4 })?;
    println!(
        "cache job: {} examples -> {} index-modulo shards in {:.2}s",
        meta.num_examples,
        meta.num_shards,
        t0.elapsed().as_secs_f64()
    );
    let p = DeterministicPipeline::open(&dir)?;

    // E5 reproducibility
    let a: Vec<i32> = first_indices(&p, 0, 1, 0, 10);
    let b: Vec<i32> = first_indices(&p, 0, 1, 0, 10);
    println!("reproducibility: two reads of the stream head agree: {}", a == b);
    assert_eq!(a, b);

    // E6 recoverability
    let full = first_indices(&p, 0, 2, 0, 20);
    let resumed = first_indices(&p, 0, 2, 7, 13);
    println!(
        "recoverability: resume@7 == continuous[7..]: {}",
        resumed.as_slice() == &full[7..]
    );
    assert_eq!(resumed.as_slice(), &full[7..]);

    // E7 sharding
    println!("sharding: 4 hosts read exclusive file sets:");
    for h in 0..4 {
        println!("  host {h}: files {:?}", p.host_files(h, 4));
    }

    // E8 global shuffle
    let doc_ids: Vec<f64> = p
        .global_stream()
        .collect_vec()
        .iter()
        .map(|e| e["doc_id"].as_ints().unwrap()[0] as f64)
        .collect();
    let raw_ids: Vec<f64> = task
        .dataset(0, 0, 1)
        .collect_vec()
        .iter()
        .map(|e| e["doc_id"].as_ints().unwrap()[0] as f64)
        .collect();
    println!(
        "global shuffle: doc-id lag-1 autocorrelation {:.3} (raw) -> {:.3} (cached)",
        lag1_autocorrelation(&raw_ids),
        lag1_autocorrelation(&doc_ids)
    );

    // ---- §3.1: one get_dataset call over live and cached providers ------
    println!("\n== get_dataset: providers are interchangeable ==");
    use t5x::seqio::feature_converters::{converter_for_arch, default_task_lengths};
    use t5x::seqio::provider::{get_dataset, CachedTask, GetDatasetOptions};
    let conv = converter_for_arch("encdec");
    let opts = GetDatasetOptions {
        task_feature_lengths: default_task_lengths(conv.as_ref(), 64),
        converter: Some(conv.name().to_string()),
        seed: 0,
        ..Default::default()
    };
    let live = get_dataset(task.clone(), &opts)?.collect_vec();
    let cached_provider = std::sync::Arc::new(CachedTask::open(&dir, Some(&task))?);
    let cached = get_dataset(cached_provider, &opts)?.collect_vec();
    let key = t5x::seqio::serialize_example;
    let (mut a, mut b): (Vec<_>, Vec<_>) =
        (live.iter().map(key).collect(), cached.iter().map(key).collect());
    a.sort();
    b.sort();
    println!(
        "same {} model-ready examples from the live task and its cache: {}",
        live.len(),
        a == b
    );
    assert_eq!(a, b);

    println!("\ndata_pipeline demo OK");
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

fn first_indices(
    p: &DeterministicPipeline,
    host: usize,
    hosts: usize,
    start: usize,
    n: usize,
) -> Vec<i32> {
    p.host_stream(host, hosts, start, false)
        .take(n)
        .collect_vec()
        .iter()
        .map(|e| e["_index"].as_ints().unwrap()[0])
        .collect()
}
