//! Tiny CLI argument parser (clap substitute).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, positional
//! args, and pass-through of `--gin.<binding>=<value>` overrides to the
//! [`crate::gin`] configuration system (the t5x launcher convention).

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// `--gin.trainer.steps=100` style overrides, with the `gin.` stripped.
    pub gin_overrides: Vec<String>,
}

impl Args {
    /// Parse std::env::args() (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(raw: Vec<String>) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        // First non-flag token is the subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some(binding) = rest.strip_prefix("gin.") {
                    args.gin_overrides.push(binding.to_string());
                    continue;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // Convention: positionals come before options; a bare token after
        // `--key` is consumed as that option's value.
        let a = Args::parse(s(&[
            "train", "pos1", "--model", "t5-nano-dec", "--steps=10", "--verbose",
        ]));
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("t5-nano-dec"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 10);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn gin_overrides_passthrough() {
        let a = Args::parse(s(&["train", "--gin.trainer.lr=0.1", "--gin.seqio.seed=3"]));
        assert_eq!(a.gin_overrides, vec!["trainer.lr=0.1", "seqio.seed=3"]);
    }

    #[test]
    fn bad_numbers_error() {
        let a = Args::parse(s(&["--steps", "abc"]));
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(s(&["--check"]));
        assert!(a.has_flag("check"));
    }
}
