//! Decoding library — the `t5x.decoding` mirror: greedy, temperature /
//! top-k / top-p sampling, and beam search with length penalty.
//!
//! All routines are *pure host-side* functions over next-token logits
//! rows; they never touch the device. The model is abstracted as a step
//! function `&[prefix] -> next-token logits per prefix`, so the same code
//! is driven by the XLA `decode_logits` executable (via
//! [`crate::infer::engine::InferEngine`]), by the batched beam adapter,
//! and by toy closures in golden tests.
//!
//! ## Determinism contract
//!
//! * [`argmax`] breaks ties toward the lowest token id (first strict max),
//!   the same rule `EvalRunner::greedy_decode` has always used — batched
//!   engine decodes and single-request decodes pick identical tokens.
//! * [`sample_token`] draws exactly **one** `next_f64` from the caller's
//!   [`Pcg64`] per emitted token, so a request's sampled continuation
//!   depends only on (logits, seed, position) — never on how requests were
//!   packed into batch slots or interleaved by the engine scheduler.
//! * [`beam_search`] orders candidates by (score desc, parent beam asc,
//!   token asc) and final hypotheses by (score desc, tokens asc): full
//!   ties are impossible, so results are reproducible across runs.

use crate::util::rng::Pcg64;

/// How to turn logits into tokens, per request.
#[derive(Debug, Clone, PartialEq)]
pub enum DecodeMethod {
    /// Argmax at every step (temperature -> 0 limit).
    Greedy,
    /// Seeded ancestral sampling. `top_k == 0` disables top-k;
    /// `top_p >= 1.0` disables nucleus truncation. The seed is
    /// per-request: the same (prompt, seed) always yields the same tokens.
    Sample { temperature: f32, top_k: usize, top_p: f32, seed: u64 },
    /// Beam search with GNMT/t5x length penalty `((5+len)/6)^alpha`.
    Beam { beams: usize, length_penalty: f32 },
}

impl DecodeMethod {
    pub fn name(&self) -> &'static str {
        match self {
            DecodeMethod::Greedy => "greedy",
            DecodeMethod::Sample { .. } => "sample",
            DecodeMethod::Beam { .. } => "beam",
        }
    }
}

/// Index of the first strict maximum — the greedy token. Must stay
/// byte-compatible with the historical `greedy_decode` loop (ties break
/// toward the lowest id).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (k, &x) in row.iter().enumerate() {
        if x > best_v {
            best = k;
            best_v = x;
        }
    }
    best
}

/// Numerically stable log-softmax over one logits row (f64 accumulation).
pub fn log_softmax(row: &[f32]) -> Vec<f64> {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse = row.iter().map(|&x| (x as f64 - max).exp()).sum::<f64>().ln() + max;
    row.iter().map(|&x| x as f64 - lse).collect()
}

/// Sample one token id from a logits row.
///
/// Pipeline (matching `t5x.decoding.temperature_sample`): scale by
/// `1/temperature`, keep the `top_k` highest-logit candidates (0 = all),
/// then keep the smallest high-probability prefix with mass `>= top_p`
/// (nucleus), renormalize, and draw once from `rng`. `temperature <= 0`
/// degenerates to [`argmax`] without consuming randomness.
pub fn sample_token(
    row: &[f32],
    temperature: f32,
    top_k: usize,
    top_p: f32,
    rng: &mut Pcg64,
) -> usize {
    if temperature <= 0.0 || row.len() == 1 {
        return argmax(row);
    }
    // Candidates sorted by (logit desc, id asc) — deterministic under
    // ties, and total_cmp keeps the comparator a total order even if a
    // degenerate checkpoint produces NaN logits (sort_by panics on
    // intransitive comparators).
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]).then(a.cmp(&b)));
    if top_k > 0 {
        idx.truncate(top_k.min(idx.len()));
    }
    let inv_t = 1.0 / temperature as f64;
    let max = row[idx[0]] as f64 * inv_t;
    let weights: Vec<f64> =
        idx.iter().map(|&i| (row[i] as f64 * inv_t - max).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut keep = weights.len();
    if top_p < 1.0 {
        let threshold = (top_p.max(0.0) as f64) * total;
        let mut acc = 0.0;
        for (i, w) in weights.iter().enumerate() {
            acc += w;
            if acc >= threshold {
                keep = i + 1;
                break;
            }
        }
    }
    let kept_total: f64 = weights[..keep].iter().sum();
    let mut x = rng.next_f64() * kept_total;
    for (i, w) in weights[..keep].iter().enumerate() {
        x -= w;
        if x < 0.0 {
            return idx[i];
        }
    }
    idx[keep - 1]
}

/// Pick the next token for a row under `method`. Sampling methods must be
/// given the request's RNG (one draw per token, see module docs).
pub fn next_token(method: &DecodeMethod, row: &[f32], rng: Option<&mut Pcg64>) -> usize {
    match method {
        DecodeMethod::Greedy => argmax(row),
        DecodeMethod::Sample { temperature, top_k, top_p, .. } => {
            let rng = rng.expect("sampling requires the request RNG");
            sample_token(row, *temperature, *top_k, *top_p, rng)
        }
        DecodeMethod::Beam { .. } => {
            panic!("beam search decodes whole sequences; use beam_search()")
        }
    }
}

/// GNMT / t5x brevity penalty: `((5 + len) / 6)^alpha`. `alpha = 0`
/// disables it; larger alpha favors longer hypotheses.
pub fn length_penalty(alpha: f32, len: usize) -> f64 {
    ((5.0 + len as f64) / 6.0).powf(alpha as f64)
}

/// One (possibly finished) decoded sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct Hypothesis {
    /// Generated ids, including the terminating EOS when present.
    pub tokens: Vec<i32>,
    /// Sum of token log-probabilities.
    pub log_prob: f64,
    /// `log_prob / length_penalty(alpha, tokens.len())` — the sort key.
    pub score: f64,
}

/// Beam search over a step function.
///
/// `step(&prefixes)` receives the live prefixes (generated ids only — the
/// caller's closure owns the prompt) and returns one next-token logits row
/// per prefix. All live prefixes at one call have equal length, so
/// batch-packed XLA adapters can feed them as rows of one `[B, L]` batch.
///
/// Classic 2x-expansion: each round keeps the `2*beams` best candidate
/// extensions, absorbs those ending in `eos_id` into the finished pool,
/// and carries at most `beams` live hypotheses forward. Hypotheses still
/// live at `max_len` are closed out unfinished. Returns up to `beams`
/// hypotheses, best (length-penalized) first.
pub fn beam_search<F>(
    mut step: F,
    beams: usize,
    max_len: usize,
    eos_id: i32,
    alpha: f32,
) -> anyhow::Result<Vec<Hypothesis>>
where
    F: FnMut(&[Vec<i32>]) -> anyhow::Result<Vec<Vec<f32>>>,
{
    anyhow::ensure!(beams >= 1, "need at least one beam");
    anyhow::ensure!(max_len >= 1, "need max_len >= 1");
    let mut live: Vec<(Vec<i32>, f64)> = vec![(Vec::new(), 0.0)];
    let mut finished: Vec<Hypothesis> = Vec::new();
    for _ in 0..max_len {
        let prefixes: Vec<Vec<i32>> = live.iter().map(|(t, _)| t.clone()).collect();
        let logits = step(&prefixes)?;
        anyhow::ensure!(
            logits.len() == live.len(),
            "step returned {} logits rows for {} prefixes",
            logits.len(),
            live.len()
        );
        // Expand every live hypothesis by every token.
        let mut cands: Vec<(usize, i32, f64)> = Vec::new();
        for (p, ((_, lp), row)) in live.iter().zip(&logits).enumerate() {
            for (tok, l) in log_softmax(row).into_iter().enumerate() {
                cands.push((p, tok as i32, lp + l));
            }
        }
        cands.sort_by(|a, b| b.2.total_cmp(&a.2).then(a.0.cmp(&b.0)).then(a.1.cmp(&b.1)));
        let mut next_live: Vec<(Vec<i32>, f64)> = Vec::new();
        for (p, tok, lp) in cands.into_iter().take(2 * beams) {
            let mut tokens = live[p].0.clone();
            tokens.push(tok);
            if tok == eos_id {
                let score = lp / length_penalty(alpha, tokens.len());
                finished.push(Hypothesis { tokens, log_prob: lp, score });
            } else if next_live.len() < beams {
                next_live.push((tokens, lp));
            }
        }
        if next_live.is_empty() {
            break;
        }
        live = next_live;
    }
    for (tokens, lp) in live {
        let score = lp / length_penalty(alpha, tokens.len());
        finished.push(Hypothesis { tokens, log_prob: lp, score });
    }
    finished.sort_by(|a, b| b.score.total_cmp(&a.score).then(a.tokens.cmp(&b.tokens)));
    finished.truncate(beams);
    anyhow::ensure!(!finished.is_empty(), "beam search produced no hypotheses");
    Ok(finished)
}

/// Brute-force reference: enumerate *every* sequence (terminated by EOS or
/// by `max_len`) and return the best length-penalized one. Exponential in
/// `max_len` — golden tests only. Ties resolve to the lexicographically
/// smallest token sequence, matching [`beam_search`]'s final sort.
pub fn exhaustive_search<F>(
    step: &mut F,
    max_len: usize,
    eos_id: i32,
    alpha: f32,
) -> anyhow::Result<Hypothesis>
where
    F: FnMut(&[Vec<i32>]) -> anyhow::Result<Vec<Vec<f32>>>,
{
    fn recurse<F>(
        step: &mut F,
        prefix: &mut Vec<i32>,
        lp: f64,
        max_len: usize,
        eos_id: i32,
        alpha: f32,
        best: &mut Option<Hypothesis>,
    ) -> anyhow::Result<()>
    where
        F: FnMut(&[Vec<i32>]) -> anyhow::Result<Vec<Vec<f32>>>,
    {
        let rows = step(std::slice::from_ref(prefix))?;
        anyhow::ensure!(rows.len() == 1, "step must return one row per prefix");
        let ls = log_softmax(&rows[0]);
        for (tok, l) in ls.into_iter().enumerate() {
            let tok = tok as i32;
            let new_lp = lp + l;
            prefix.push(tok);
            if tok == eos_id || prefix.len() == max_len {
                let score = new_lp / length_penalty(alpha, prefix.len());
                let better = match best {
                    None => true,
                    Some(b) => {
                        score > b.score
                            || (score == b.score && prefix.as_slice() < b.tokens.as_slice())
                    }
                };
                if better {
                    *best = Some(Hypothesis {
                        tokens: prefix.clone(),
                        log_prob: new_lp,
                        score,
                    });
                }
            } else {
                recurse(step, prefix, new_lp, max_len, eos_id, alpha, best)?;
            }
            prefix.pop();
        }
        Ok(())
    }
    let mut best = None;
    let mut prefix = Vec::new();
    recurse(step, &mut prefix, 0.0, max_len, eos_id, alpha, &mut best)?;
    best.ok_or_else(|| anyhow::anyhow!("empty search space"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::splitmix64;

    /// Deterministic toy model: logits depend on the prefix hash, so the
    /// "model" has real sequential structure without any device.
    fn toy_step(
        vocab: usize,
        salt: u64,
    ) -> impl FnMut(&[Vec<i32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        move |prefixes| {
            Ok(prefixes
                .iter()
                .map(|p| {
                    let mut h = salt;
                    for &t in p {
                        h = splitmix64(h ^ (t as u64 + 1));
                    }
                    (0..vocab)
                        .map(|v| {
                            let x = splitmix64(h ^ ((v as u64 + 1) << 17));
                            (x >> 40) as f32 / (1u64 << 24) as f32 * 4.0 - 2.0
                        })
                        .collect()
                })
                .collect())
        }
    }

    fn toy_row(vocab: usize, seed: u64) -> Vec<f32> {
        toy_step(vocab, seed)(&[vec![]]).unwrap().pop().unwrap()
    }

    #[test]
    fn argmax_breaks_ties_low() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn log_softmax_normalizes() {
        let ls = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f64 = ls.iter().map(|l| l.exp()).sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert!(ls[2] > ls[1] && ls[1] > ls[0]);
    }

    #[test]
    fn sampling_same_seed_same_tokens() {
        let row = toy_row(64, 9);
        let draw = |seed: u64| -> Vec<usize> {
            let mut rng = Pcg64::new(seed);
            (0..32).map(|_| sample_token(&row, 0.9, 0, 1.0, &mut rng)).collect()
        };
        assert_eq!(draw(7), draw(7), "same seed must reproduce exactly");
        assert_ne!(draw(7), draw(8), "different seeds must diverge");
    }

    #[test]
    fn temperature_zero_is_greedy_and_draws_no_randomness() {
        let row = toy_row(32, 4);
        let mut rng = Pcg64::new(1);
        let before = rng.raw_state();
        assert_eq!(sample_token(&row, 0.0, 0, 1.0, &mut rng), argmax(&row));
        assert_eq!(rng.raw_state(), before, "greedy limit must not consume rng");
    }

    #[test]
    fn one_draw_per_token() {
        // The packing-independence contract: exactly one next_f64 per call.
        let row = toy_row(32, 5);
        let mut a = Pcg64::new(3);
        let mut b = Pcg64::new(3);
        sample_token(&row, 0.7, 8, 0.9, &mut a);
        b.next_f64();
        assert_eq!(a.raw_state(), b.raw_state());
    }

    #[test]
    fn top_k_restricts_support() {
        let row = toy_row(64, 11);
        let mut sorted: Vec<usize> = (0..row.len()).collect();
        sorted.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap().then(a.cmp(&b)));
        let allowed: std::collections::BTreeSet<usize> =
            sorted[..4].iter().copied().collect();
        let mut rng = Pcg64::new(0);
        for _ in 0..500 {
            let t = sample_token(&row, 1.5, 4, 1.0, &mut rng);
            assert!(allowed.contains(&t), "token {t} outside top-4");
        }
    }

    #[test]
    fn top_p_tiny_is_greedy() {
        // A nucleus smaller than the top token's mass keeps only argmax.
        let row = toy_row(64, 13);
        let mut rng = Pcg64::new(5);
        for _ in 0..100 {
            assert_eq!(sample_token(&row, 1.0, 0, 1e-9, &mut rng), argmax(&row));
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let row = toy_row(16, 21);
        let mut rng = Pcg64::new(2);
        let distinct: std::collections::BTreeSet<usize> =
            (0..400).map(|_| sample_token(&row, 10.0, 0, 1.0, &mut rng)).collect();
        assert!(distinct.len() > 8, "hot sampling should cover most of V=16");
    }

    #[test]
    fn beam_matches_exhaustive_when_wide() {
        // With beams >= |search space| the beam is exhaustive: the top
        // hypothesis must equal the brute-force optimum (score AND tokens).
        for (vocab, max_len, alpha) in [(4usize, 3usize, 0.0f32), (5, 3, 0.6), (3, 4, 1.0)] {
            let eos = 0;
            let wide = vocab.pow(max_len as u32);
            let best_beam =
                beam_search(toy_step(vocab, 77), wide, max_len, eos, alpha).unwrap();
            let mut step = toy_step(vocab, 77);
            let best_exh = exhaustive_search(&mut step, max_len, eos, alpha).unwrap();
            assert_eq!(
                best_beam[0].tokens, best_exh.tokens,
                "vocab={vocab} len={max_len} alpha={alpha}"
            );
            assert!((best_beam[0].score - best_exh.score).abs() < 1e-9);
        }
    }

    #[test]
    fn narrow_beam_never_beats_exhaustive() {
        let eos = 0;
        let mut step = toy_step(5, 123);
        let optimum = exhaustive_search(&mut step, 3, eos, 0.6).unwrap();
        for beams in [1usize, 2, 3] {
            let hyps = beam_search(toy_step(5, 123), beams, 3, eos, 0.6).unwrap();
            assert!(hyps.len() <= beams);
            assert!(
                hyps[0].score <= optimum.score + 1e-9,
                "beam={beams} found score {} above optimum {}",
                hyps[0].score,
                optimum.score
            );
            // hypotheses sorted best-first
            for w in hyps.windows(2) {
                assert!(w[0].score >= w[1].score);
            }
        }
    }

    #[test]
    fn beam_is_deterministic() {
        let a = beam_search(toy_step(6, 9), 4, 5, 0, 0.6).unwrap();
        let b = beam_search(toy_step(6, 9), 4, 5, 0, 0.6).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn length_penalty_shape() {
        assert!((length_penalty(0.0, 10) - 1.0).abs() < 1e-12);
        assert_eq!(length_penalty(1.0, 1), 1.0);
        assert!(length_penalty(1.0, 13) == 3.0);
        assert!(length_penalty(0.6, 20) > length_penalty(0.6, 5));
    }
}
