//! JSONL request/response serving loop over the continuous-batching
//! engine (the `t5x serve` subcommand).
//!
//! Protocol: one JSON object per input line —
//!
//! ```json
//! {"id": 1, "prompt": [5, 9, 11], "max_tokens": 8,
//!  "method": "sample", "temperature": 0.8, "top_k": 20, "top_p": 0.95,
//!  "seed": 7}
//! ```
//!
//! Only `prompt` is required: `id` defaults to an auto-incremented
//! counter, `method` to `"greedy"`, `max_tokens` to the server default.
//! Responses are emitted *as requests complete* (not in submission
//! order):
//!
//! ```json
//! {"id": 1, "tokens": [12, 4, 1], "steps": 3,
//!  "queue_ms": 0.1, "latency_ms": 5.2}
//! ```
//!
//! A background thread reads the input while the engine decodes, so new
//! requests join the running batch mid-flight — the same continuous
//! batching the engine gives programmatic callers. Malformed lines
//! produce `{"error": ...}` responses and do not stop the loop.

use std::io::{BufRead, Write};

use super::decoding::DecodeMethod;
use super::engine::{InferEngine, InferRequest, InferResult};
use crate::util::json::Json;
use crate::util::threads::Pipe;

/// Parse one request line. `auto_id` is used when the line carries no
/// `"id"`; `default_max_tokens` when it carries no `"max_tokens"`.
pub fn parse_request(
    line: &str,
    auto_id: u64,
    default_max_tokens: usize,
) -> anyhow::Result<InferRequest> {
    let v = Json::parse(line.trim())?;
    let prompt: Vec<i32> = v
        .get("prompt")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| anyhow::anyhow!("request needs a \"prompt\" array of token ids"))?
        .iter()
        .map(|x| {
            let n = x
                .as_i64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric token id in prompt"))?;
            i32::try_from(n)
                .map_err(|_| anyhow::anyhow!("token id {n} out of i32 range"))
        })
        .collect::<anyhow::Result<_>>()?;
    let id = match v.get("id") {
        None => auto_id,
        Some(x) => {
            let n = x.as_i64().unwrap_or(-1);
            anyhow::ensure!(n >= 0, "\"id\" must be a non-negative integer");
            n as u64
        }
    };
    let max_tokens =
        v.get("max_tokens").and_then(|x| x.as_usize()).unwrap_or(default_max_tokens);
    let method = match v.get("method").and_then(|m| m.as_str()).unwrap_or("greedy") {
        "greedy" => DecodeMethod::Greedy,
        "sample" => DecodeMethod::Sample {
            temperature: v
                .get("temperature")
                .and_then(|x| x.as_f64())
                .unwrap_or(1.0) as f32,
            top_k: v.get("top_k").and_then(|x| x.as_usize()).unwrap_or(0),
            top_p: v.get("top_p").and_then(|x| x.as_f64()).unwrap_or(1.0) as f32,
            seed: v.get("seed").and_then(|x| x.as_i64()).unwrap_or(0) as u64,
        },
        other => anyhow::bail!("unknown method '{other}' (greedy|sample)"),
    };
    Ok(InferRequest { id, prompt, max_tokens, method })
}

/// Render one completed request as a response line.
pub fn result_to_json(r: &InferResult) -> Json {
    let mut pairs = vec![
        ("id", Json::num(r.id as f64)),
        (
            "tokens",
            Json::Arr(r.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("steps", Json::num(r.tokens.len() as f64)),
        ("queue_ms", Json::num(r.queue_seconds * 1e3)),
        ("latency_ms", Json::num(r.latency_seconds * 1e3)),
    ];
    if let Some(t) = r.ttft_seconds {
        pairs.push(("ttft_ms", Json::num(t * 1e3)));
    }
    Json::obj(pairs)
}

/// Totals reported when the input stream closes.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    /// Requests accepted into the engine queue.
    pub requests: u64,
    /// Lines rejected at parse time or by `submit` validation.
    pub errors: u64,
}

/// Drive the engine from a line-oriented reader until EOF, writing one
/// response line per completed request to `output`. The reader runs on a
/// background thread so requests arriving mid-decode join the running
/// batch (continuous batching at the I/O boundary too).
pub fn serve<R, W>(
    engine: &mut InferEngine,
    input: R,
    mut output: W,
    default_max_tokens: usize,
) -> anyhow::Result<ServeSummary>
where
    R: BufRead + Send + 'static,
    W: Write,
{
    let (tx, rx) = Pipe::<String>::bounded(256);
    std::thread::Builder::new()
        .name("serve-reader".into())
        .spawn(move || {
            for line in input.lines() {
                let Ok(line) = line else { break };
                if line.trim().is_empty() {
                    continue;
                }
                if !tx.send(line) {
                    break; // server hung up
                }
            }
        })?;
    let mut summary = ServeSummary { requests: 0, errors: 0 };
    let mut next_auto_id = 0u64;
    let mut input_open = true;
    // Stop draining input once this many requests are queued: lines then
    // back up in the bounded pipe and the reader thread blocks, so a
    // client streaming faster than the engine decodes hits backpressure
    // instead of growing the queue without limit.
    let max_backlog = 4 * engine.manifest.batch().max(1);
    while input_open || engine.has_work() {
        // Drain lines already available without blocking (up to the
        // backlog cap), so queued requests are admitted before the next
        // decode step; block only when the engine would otherwise spin
        // idle.
        loop {
            let line: String = if engine.has_work() {
                if engine.queued() >= max_backlog {
                    break;
                }
                match rx.try_recv() {
                    Some(l) => l,
                    None => break,
                }
            } else {
                // about to block for input: any responses/errors already
                // written must reach the client first, or a request/reply
                // client deadlocks against a buffering writer
                output.flush()?;
                match rx.recv() {
                    Some(l) => l,
                    None => {
                        input_open = false;
                        break;
                    }
                }
            };
            match parse_request(&line, next_auto_id, default_max_tokens) {
                Ok(req) => {
                    next_auto_id = next_auto_id.max(req.id).saturating_add(1);
                    let id = req.id;
                    match engine.submit(req) {
                        Ok(()) => summary.requests += 1,
                        Err(e) => {
                            summary.errors += 1;
                            // echo the id so the client can correlate the
                            // rejection with its in-flight request
                            writeln!(
                                output,
                                "{}",
                                Json::obj(vec![
                                    ("id", Json::num(id as f64)),
                                    ("error", Json::str(format!("{e:#}"))),
                                ])
                            )?;
                        }
                    }
                }
                Err(e) => {
                    summary.errors += 1;
                    writeln!(
                        output,
                        "{}",
                        Json::obj(vec![("error", Json::str(format!("{e:#}")))])
                    )?;
                }
            }
        }
        engine.step()?;
        for r in engine.drain_finished() {
            writeln!(output, "{}", result_to_json(&r))?;
        }
        output.flush()?;
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_and_full_requests() {
        let r = parse_request(r#"{"prompt": [5, 9]}"#, 7, 16).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt, vec![5, 9]);
        assert_eq!(r.max_tokens, 16);
        assert_eq!(r.method, DecodeMethod::Greedy);

        let r = parse_request(
            r#"{"id": 3, "prompt": [1], "max_tokens": 4, "method": "sample",
               "temperature": 0.5, "top_k": 8, "top_p": 0.9, "seed": 11}"#,
            0,
            16,
        )
        .unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(
            r.method,
            DecodeMethod::Sample { temperature: 0.5, top_k: 8, top_p: 0.9, seed: 11 }
        );
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json", 0, 8).is_err());
        assert!(parse_request(r#"{"max_tokens": 3}"#, 0, 8).is_err(), "missing prompt");
        assert!(parse_request(r#"{"prompt": [1], "method": "magic"}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"prompt": ["x"]}"#, 0, 8).is_err());
        // out-of-range numbers must be rejected, not silently wrapped
        assert!(parse_request(r#"{"prompt": [4294967301]}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"id": -1, "prompt": [1]}"#, 0, 8).is_err());
    }

    #[test]
    fn result_lines_are_json() {
        let r = InferResult {
            id: 9,
            prompt_len: 3,
            tokens: vec![4, 5, 1],
            started_step: 0,
            finished_step: 3,
            queue_seconds: 0.001,
            latency_seconds: 0.01,
            ttft_seconds: Some(0.004),
        };
        let v = Json::parse(&result_to_json(&r).to_string()).unwrap();
        assert_eq!(v.get("id").unwrap().as_i64(), Some(9));
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("steps").unwrap().as_i64(), Some(3));
        let ttft = v.get("ttft_ms").unwrap().as_f64().unwrap();
        assert!((ttft - 4.0).abs() < 1e-9);
    }
}
