//! Partitioning (paper §2.2, S2): the t5x high-level API over GSPMD-style
//! sharding, reimplemented explicitly for the simulated host mesh.
//!
//! * [`Mesh`] — the 2-D device decomposition N = data × model.
//! * [`LogicalAxisRules`] — map *logical* axis names (the
//!   `param_with_axes` annotations carried in the artifact manifest) to
//!   mesh axes, exactly like `t5x.partitioning.standard_logical_axis_rules`.
//! * [`Partitioner`] — computes a [`PartitionSpec`] per parameter, slices /
//!   reassembles host shards of [`HostTensor`]s, and implements the
//!   paper's strategy matrix (1D vs 2D parameter partitioning).
//! * [`cost`] — the analytic GSPMD memory/communication model that
//!   regenerates the §2.2 trade-off discussion as a table (E3).

pub mod cost;


use crate::runtime::artifacts::ParamSpec;
use crate::runtime::HostTensor;

/// Hardware mesh axes (t5x: "data" and "model").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeshAxis {
    Data,
    Model,
}

/// The device mesh: `data * model` simulated hosts. Host h has coordinates
/// (h / model, h % model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mesh {
    pub data: usize,
    pub model: usize,
}

impl Mesh {
    pub fn new(data: usize, model: usize) -> Mesh {
        assert!(data >= 1 && model >= 1);
        Mesh { data, model }
    }

    pub fn num_hosts(&self) -> usize {
        self.data * self.model
    }

    pub fn coords(&self, host: usize) -> (usize, usize) {
        (host / self.model, host % self.model)
    }

    pub fn axis_size(&self, axis: MeshAxis) -> usize {
        match axis {
            MeshAxis::Data => self.data,
            MeshAxis::Model => self.model,
        }
    }
}

/// Parameter-partitioning strategy (paper §2.2):
/// * `OneD` — parameters sharded over the *model* axis only; replicated
///   over the data axis ("1D parameter partitioning", Megatron-style).
/// * `TwoD` — additionally sharded over the *data* axis (ZeRO-3 / fully
///   sharded data parallelism: "2D parameter partitioning").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamStrategy {
    OneD,
    TwoD,
}

/// Activation-partitioning strategy (cost model only — activations live
/// inside XLA on this testbed): 1D = replicate activations with an
/// embed/model axis over the model axis; 2D = shard them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActivationStrategy {
    OneD,
    TwoD,
}

/// Per-dimension sharding of one tensor: `Some((axis, shards))` or None
/// (replicated dim).
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionSpec {
    pub dims: Vec<Option<(MeshAxis, usize)>>,
}

impl PartitionSpec {
    pub fn replicated(rank: usize) -> Self {
        Self { dims: vec![None; rank] }
    }

    /// Number of distinct shards this spec produces.
    pub fn num_shards(&self) -> usize {
        self.dims.iter().flatten().map(|(_, s)| s).product()
    }

    /// Shape of one shard of a tensor with `shape`.
    pub fn shard_shape(&self, shape: &[usize]) -> Vec<usize> {
        shape
            .iter()
            .zip(&self.dims)
            .map(|(&d, s)| match s {
                Some((_, n)) => d / n,
                None => d,
            })
            .collect()
    }
}

/// Logical-axis-name -> mesh-axis rules, in priority order. A rule applies
/// to a dimension if the axis name matches and the mesh axis size divides
/// the dimension (t5x semantics).
#[derive(Debug, Clone)]
pub struct LogicalAxisRules {
    pub rules: Vec<(String, MeshAxis)>,
}

impl LogicalAxisRules {
    /// The t5x standard rules: vocab/heads/mlp/joined_kv shard over the
    /// model axis; batch over data; embed & norms replicated.
    pub fn standard() -> Self {
        Self {
            rules: vec![
                ("vocab".into(), MeshAxis::Model),
                ("heads".into(), MeshAxis::Model),
                ("mlp".into(), MeshAxis::Model),
                ("joined_kv".into(), MeshAxis::Model),
                ("batch".into(), MeshAxis::Data),
            ],
        }
    }

    pub fn mesh_axis_for(&self, logical: &str) -> Option<MeshAxis> {
        self.rules
            .iter()
            .find(|(name, _)| name == logical)
            .map(|(_, a)| *a)
    }
}

/// The t5x partitioner: logical axes + mesh + strategy -> concrete specs
/// and shard/unshard operations.
pub struct Partitioner {
    pub mesh: Mesh,
    pub rules: LogicalAxisRules,
    pub strategy: ParamStrategy,
}

impl Partitioner {
    pub fn new(mesh: Mesh, strategy: ParamStrategy) -> Self {
        Self { mesh, rules: LogicalAxisRules::standard(), strategy }
    }

    /// Compute the axis-wise partition spec for a parameter.
    ///
    /// 1D: the first dimension whose logical axis maps to Model (and is
    /// divisible) is sharded `model`-ways.
    /// 2D: additionally, the first *other* dimension divisible by `data`
    /// is sharded `data`-ways (ZeRO-3's second array axis, following
    /// Xu et al.'s 2D scheme).
    pub fn spec_for(&self, param: &ParamSpec) -> PartitionSpec {
        let mut dims: Vec<Option<(MeshAxis, usize)>> = vec![None; param.shape.len()];
        // model-axis sharding
        if self.mesh.model > 1 {
            for (i, axis_name) in param.logical_axes.iter().enumerate() {
                if self.rules.mesh_axis_for(axis_name) == Some(MeshAxis::Model)
                    && param.shape[i] % self.mesh.model == 0
                {
                    dims[i] = Some((MeshAxis::Model, self.mesh.model));
                    break;
                }
            }
        }
        // data-axis sharding (2D only)
        if self.strategy == ParamStrategy::TwoD && self.mesh.data > 1 {
            for i in 0..param.shape.len() {
                if dims[i].is_none() && param.shape[i] % self.mesh.data == 0 {
                    dims[i] = Some((MeshAxis::Data, self.mesh.data));
                    break;
                }
            }
        }
        PartitionSpec { dims }
    }

    /// Extract host `h`'s shard of a full tensor under `spec`.
    pub fn shard(&self, full: &HostTensor, spec: &PartitionSpec, host: usize) -> HostTensor {
        let (d, m) = self.mesh.coords(host);
        let mut out = full.clone();
        // Slice axis-by-axis (order doesn't matter for disjoint axes).
        for (axis_idx, dim_spec) in spec.dims.iter().enumerate() {
            if let Some((mesh_axis, shards)) = dim_spec {
                let coord = match mesh_axis {
                    MeshAxis::Data => d,
                    MeshAxis::Model => m,
                };
                let size = out.shape[axis_idx] / shards;
                out = out.slice_axis(axis_idx, coord * size, size);
            }
        }
        out
    }

    /// Reassemble the full tensor from all hosts' shards (inverse of
    /// [`Partitioner::shard`]). `shards[h]` is host h's piece. Replicated
    /// tensors return host 0's copy.
    pub fn unshard(&self, shards: &[HostTensor], spec: &PartitionSpec) -> HostTensor {
        assert_eq!(shards.len(), self.mesh.num_hosts());
        let mut current: Vec<HostTensor> = shards.to_vec();
        let mut group = self.mesh.num_hosts();
        // Fold mesh axes back in reverse declaration order: model is the
        // fastest-varying host coordinate, so merge model first.
        for (mesh_axis, axis_size) in [(MeshAxis::Model, self.mesh.model), (MeshAxis::Data, self.mesh.data)] {
            if axis_size == 1 {
                continue;
            }
            let dim_idx = spec
                .dims
                .iter()
                .position(|d| matches!(d, Some((a, _)) if *a == mesh_axis));
            group /= axis_size;
            let mut next: Vec<HostTensor> = Vec::with_capacity(group);
            for g in 0..group {
                let members: Vec<HostTensor> = (0..axis_size)
                    .map(|k| current[g * axis_size + k].clone())
                    .collect();
                next.push(match dim_idx {
                    Some(di) => HostTensor::concat_axis(&members, di),
                    None => members[0].clone(), // replicated over this axis
                });
            }
            current = next;
        }
        assert_eq!(current.len(), 1);
        current.remove(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pspec(name: &str, shape: Vec<usize>, axes: Vec<&str>) -> ParamSpec {
        ParamSpec {
            name: name.into(),
            shape,
            logical_axes: axes.into_iter().map(|s| s.to_string()).collect(),
            init: "const:0".into(),
        }
    }

    #[test]
    fn mesh_coords() {
        let mesh = Mesh::new(2, 4);
        assert_eq!(mesh.num_hosts(), 8);
        assert_eq!(mesh.coords(0), (0, 0));
        assert_eq!(mesh.coords(5), (1, 1));
        assert_eq!(mesh.coords(7), (1, 3));
    }

    #[test]
    fn spec_1d_shards_model_axis_only() {
        let p = Partitioner::new(Mesh::new(2, 2), ParamStrategy::OneD);
        let wq = pspec("wq", vec![64, 64], vec!["embed", "joined_kv"]);
        let spec = p.spec_for(&wq);
        assert_eq!(spec.dims[0], None);
        assert_eq!(spec.dims[1], Some((MeshAxis::Model, 2)));
        assert_eq!(spec.shard_shape(&wq.shape), vec![64, 32]);
        // norm scale: replicated
        let norm = pspec("scale", vec![64], vec!["embed"]);
        assert_eq!(p.spec_for(&norm), PartitionSpec::replicated(1));
    }

    #[test]
    fn spec_2d_adds_data_axis() {
        let p = Partitioner::new(Mesh::new(2, 2), ParamStrategy::TwoD);
        let wq = pspec("wq", vec![64, 64], vec!["embed", "joined_kv"]);
        let spec = p.spec_for(&wq);
        assert_eq!(spec.dims[1], Some((MeshAxis::Model, 2)));
        assert_eq!(spec.dims[0], Some((MeshAxis::Data, 2)));
        assert_eq!(spec.shard_shape(&wq.shape), vec![32, 32]);
        // 2D with pure data parallelism (model=1): ZeRO shards first axis
        let pdp = Partitioner::new(Mesh::new(4, 1), ParamStrategy::TwoD);
        let spec2 = pdp.spec_for(&wq);
        assert_eq!(spec2.dims[0], Some((MeshAxis::Data, 4)));
        assert_eq!(spec2.dims[1], None);
    }

    #[test]
    fn shard_unshard_roundtrip() {
        for (mesh, strategy) in [
            (Mesh::new(1, 2), ParamStrategy::OneD),
            (Mesh::new(2, 2), ParamStrategy::OneD),
            (Mesh::new(2, 2), ParamStrategy::TwoD),
            (Mesh::new(4, 1), ParamStrategy::TwoD),
        ] {
            let p = Partitioner::new(mesh, strategy);
            let param = pspec("w", vec![8, 12], vec!["embed", "mlp"]);
            let full = HostTensor::f32(
                vec![8, 12],
                (0..96).map(|i| i as f32).collect(),
            );
            let spec = p.spec_for(&param);
            let shards: Vec<HostTensor> = (0..mesh.num_hosts())
                .map(|h| p.shard(&full, &spec, h))
                .collect();
            let back = p.unshard(&shards, &spec);
            assert_eq!(back, full, "mesh={mesh:?} strategy={strategy:?}");
        }
    }

    #[test]
    fn indivisible_dims_stay_replicated() {
        let p = Partitioner::new(Mesh::new(1, 4), ParamStrategy::OneD);
        // relpos bias: heads=6 not divisible by 4 -> replicated
        let param = pspec("relpos", vec![32, 6], vec!["relpos_buckets", "heads"]);
        assert_eq!(p.spec_for(&param), PartitionSpec::replicated(2));
    }

    #[test]
    fn shard_shapes_consistent_across_hosts() {
        let p = Partitioner::new(Mesh::new(2, 2), ParamStrategy::TwoD);
        let param = pspec("w", vec![16, 8], vec!["embed", "joined_kv"]);
        let spec = p.spec_for(&param);
        let full = HostTensor::zeros(vec![16, 8]);
        for h in 0..4 {
            assert_eq!(p.shard(&full, &spec, h).shape, spec.shard_shape(&param.shape));
        }
    }
}
