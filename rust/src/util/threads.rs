//! Thread utilities (tokio substitute): bounded SPSC/MPSC channels via
//! std::sync::mpsc plus a tiny scoped worker-pool used by the seqio cache
//! job and prefetch pipelines.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread;

/// A bounded producer/consumer queue with explicit close semantics, used as
/// the infeed backpressure mechanism (§3.2 throughput claims, E9).
/// Namespace struct: construct ends via [`Pipe::bounded`].
pub struct Pipe<T>(std::marker::PhantomData<T>);

impl<T> Pipe<T> {
    pub fn bounded(cap: usize) -> (PipeSender<T>, PipeReceiver<T>) {
        let (tx, rx) = sync_channel(cap.max(1));
        (PipeSender { tx }, PipeReceiver { rx })
    }
}

pub struct PipeSender<T> {
    tx: SyncSender<T>,
}

impl<T> Clone for PipeSender<T> {
    fn clone(&self) -> Self {
        Self { tx: self.tx.clone() }
    }
}

impl<T> PipeSender<T> {
    /// Blocks when the pipe is full (backpressure). Returns false if the
    /// receiver hung up.
    pub fn send(&self, item: T) -> bool {
        self.tx.send(item).is_ok()
    }
}

pub struct PipeReceiver<T> {
    rx: Receiver<T>,
}

impl<T> PipeReceiver<T> {
    /// Blocks until an item arrives; None when all senders dropped.
    pub fn recv(&self) -> Option<T> {
        self.rx.recv().ok()
    }

    pub fn try_recv(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }

    pub fn into_iter(self) -> impl Iterator<Item = T> {
        self.rx.into_iter()
    }
}

/// Run `f(i)` for i in 0..n on up to `workers` threads, collecting results
/// in index order. Panics in workers are propagated.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let val = f(i);
                slots.lock().unwrap()[i] = Some(val);
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker did not fill slot")).collect()
}

/// Shared atomic counter for cross-thread byte/item accounting.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicUsize>);

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, n: usize) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipe_backpressure_and_close() {
        let (tx, rx) = Pipe::bounded(2);
        let producer = thread::spawn(move || {
            for i in 0..10 {
                assert!(tx.send(i));
            }
        });
        let got: Vec<i32> = rx.into_iter().collect();
        producer.join().unwrap();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out[7], 49);
        assert_eq!(out.len(), 100);
    }

    #[test]
    fn counter_accumulates_across_threads() {
        let c = Counter::new();
        let c2 = c.clone();
        parallel_map(50, 4, |_| c2.add(2));
        assert_eq!(c.get(), 100);
    }
}
