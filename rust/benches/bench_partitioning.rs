//! E3: the §2.2 strategy matrix, measured — per-host resident parameter +
//! optimizer memory, per-step per-axis communication bytes, and step time
//! for 1D vs 2D parameter partitioning across mesh shapes, checked
//! against the analytic GSPMD cost model's per-axis terms.

use t5x::bench::Bench;
use t5x::optim::{OptimizerKind, Schedule};
use t5x::partitioning::cost::{estimate, LinkModel};
use t5x::partitioning::{ActivationStrategy, Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};

fn main() {
    let arts = Artifacts::load_default().expect("make artifacts first");
    let device = DeviceHandle::spawn().unwrap();
    let mut bench = Bench::new("partitioning strategies (E3)");
    let model = "t5-nano-dec";
    let m = arts.model(model).unwrap();
    let steps: u64 = if bench.is_quick() { 2 } else { 5 };
    let meshes: &[Mesh] = if bench.is_quick() {
        &[Mesh { data: 2, model: 1 }, Mesh { data: 2, model: 2 }]
    } else {
        &[
            Mesh { data: 1, model: 1 },
            Mesh { data: 2, model: 1 },
            Mesh { data: 4, model: 1 },
            Mesh { data: 1, model: 2 },
            Mesh { data: 2, model: 2 },
        ]
    };

    println!(
        "model {model}: {} params | optimizer adam (2 floats/param)\n",
        m.total_params()
    );
    println!(
        "{:<10} {:<6} {:>14} {:>16} {:>14} {:>14} {:>12}",
        "strategy", "mesh", "param f/host", "opt floats/host", "dataMiB/step", "modelMiB/step", "tokens/s"
    );
    for &mesh in meshes {
        for strategy in [ParamStrategy::OneD, ParamStrategy::TwoD] {
            let cfg = TrainerConfig {
                model: model.into(),
                mesh,
                strategy,
                optimizer: OptimizerKind::adam(),
                schedule: Schedule::Constant(1e-3),
                steps,
                seed: 0,
                log_every: 1000,
                checkpoint_every: None,
                checkpoint_dir: None,
                grad_clip_norm: None,
                weight_decay: None,
                exec_mode: t5x::partitioning::ExecMode::Gather,
                trace_out: None,
                profile_steps: None,
                microbatches: 1,
                overlap: false,
                infeed_depth: 2,
            };
            let trainer = Trainer::new(&arts, &device, cfg).unwrap();
            let opt_floats = trainer.optimizer_state_floats(0);
            let param_floats = trainer.resident_param_floats(0);
            let label = format!("{strategy:?} mesh={mesh}");
            let tokens = (m.tokens_per_step() * mesh.data * steps as usize) as f64;
            let mes = bench.measure_with_throughput(&label, Some((tokens, "tok")), || {
                let s = trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
                assert!(s.final_loss().is_finite());
            });
            let med = mes.median_s;
            // one fresh run for per-axis comm accounting
            let summary = trainer.train(&BatchSource::Synthetic { seed: 1 }).unwrap();
            let per_step = |b: u64| b as f64 / steps as f64 / (1 << 20) as f64;
            println!(
                "{:<10} {:<6} {:>14} {:>16} {:>14.2} {:>14.2} {:>12.0}",
                format!("{strategy:?}"),
                mesh.to_string(),
                param_floats,
                opt_floats,
                per_step(summary.data_axis_bytes),
                per_step(summary.model_axis_bytes),
                tokens / med
            );
            // the measured per-axis split must agree with the analytic
            // model in *direction*: a size-1 axis moves zero bytes, a
            // sharded axis moves a positive amount (exact totals differ:
            // the analytic model excludes scalar syncs and counts
            // activation collectives the testbed doesn't execute).
            let e = estimate(m, mesh, strategy, ActivationStrategy::OneD, LinkModel::default());
            if mesh.data == 1 {
                assert_eq!(summary.data_axis_bytes, 0, "{label}");
                assert_eq!(e.comm_bytes_data_axis, 0, "{label}");
            } else {
                assert!(summary.data_axis_bytes > 0, "{label}");
                assert!(e.comm_bytes_data_axis > 0, "{label}");
            }
            if mesh.model == 1 {
                assert_eq!(summary.model_axis_bytes, 0, "{label}");
            } else {
                assert!(summary.model_axis_bytes > 0, "{label}");
                assert!(e.comm_bytes_model_axis > 0, "{label}");
            }
        }
    }

    // analytic table for the same model (extends to meshes we can't run)
    println!("\nanalytic GSPMD cost model (same model):");
    let table_meshes = [
        Mesh::new(1, 1),
        Mesh::new(2, 1),
        Mesh::new(4, 1),
        Mesh::new(4, 4),
        Mesh::new(16, 1),
    ];
    for mesh in table_meshes {
        for strategy in [ParamStrategy::OneD, ParamStrategy::TwoD] {
            let e = estimate(m, mesh, strategy, ActivationStrategy::OneD, LinkModel::default());
            println!(
                "  mesh {} {:?}: params {:.2} MiB/host, optim {:.2} MiB/host, \
                 comm {:.2} MiB/step (data {:.2} + model {:.2})",
                mesh,
                strategy,
                e.param_bytes_per_host as f64 / (1 << 20) as f64,
                e.optim_bytes_per_host as f64 / (1 << 20) as f64,
                e.comm_bytes_per_host as f64 / (1 << 20) as f64,
                e.comm_bytes_data_axis as f64 / (1 << 20) as f64,
                e.comm_bytes_model_axis as f64 / (1 << 20) as f64,
            );
        }
    }
    bench.write_jsonl("bench_results.jsonl").unwrap();
    device.shutdown();
}
