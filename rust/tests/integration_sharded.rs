//! Integration: sharded parameters end-to-end — bit-identity of 2-D
//! sharded training against the replicated baseline, the per-host memory
//! claim of §2.2, distributed (no-gather) checkpoint layout, and the
//! save-on-4x2 / restore-on-2x2 resharding round-trip with params,
//! optimizer state, and pipeline state.

use std::sync::Arc;

use t5x::checkpoint::{open_layout, ArrayLayout, CheckpointManager};
use t5x::optim::Schedule;
use t5x::partitioning::{Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle, HostTensor};
use t5x::seqio::cache::{cache_task, CacheConfig};
use t5x::trainer::recipes;
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};

fn cfg_mesh(mesh: Mesh, strategy: ParamStrategy, steps: u64) -> TrainerConfig {
    let mut cfg = TrainerConfig::quick("t5-nano-dec", steps);
    cfg.mesh = mesh;
    cfg.strategy = strategy;
    cfg.seed = 17;
    cfg.schedule = Schedule::Constant(1e-3);
    cfg
}

#[test]
fn sharded_2d_training_bit_identical_to_replicated_baseline() {
    // A 2x2 TwoD mesh consumes the same two data-row batches as the 2x1
    // fully replicated baseline. Init is init-then-slice, 2-rank ring sums
    // are commutative (hence exact), parameter gathers are pure data
    // movement, and Adam is elementwise — so 5 steps must agree
    // BIT-FOR-BIT, in both the loss trajectory and the final parameters.
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();

    let base = Trainer::new(
        &arts,
        &device,
        cfg_mesh(Mesh::new(2, 1), ParamStrategy::OneD, 5),
    )
    .unwrap();
    let sharded = Trainer::new(
        &arts,
        &device,
        cfg_mesh(Mesh::new(2, 2), ParamStrategy::TwoD, 5),
    )
    .unwrap();

    let s_base = base.train(&BatchSource::Synthetic { seed: 21 }).unwrap();
    let s_shard = sharded.train(&BatchSource::Synthetic { seed: 21 }).unwrap();
    assert_eq!(s_base.history.len(), 5);
    for (a, b) in s_base.history.iter().zip(&s_shard.history) {
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "step {}: baseline {} vs sharded {}",
            a.step,
            a.loss,
            b.loss
        );
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    }
    // gathered parameters are byte-identical
    let p_base = base.params();
    let p_shard = sharded.params();
    for (name, t) in &p_base {
        assert_eq!(t, &p_shard[name], "param {name} diverged");
    }
    // and the sharded run moved bytes on BOTH mesh axes
    assert!(s_shard.data_axis_bytes > 0);
    assert!(s_shard.model_axis_bytes > 0);
    assert_eq!(s_base.model_axis_bytes, 0);
    device.shutdown();
}

#[test]
fn per_host_memory_bounded_by_mesh_division() {
    // Acceptance: with TwoD on a d x m mesh, per-host resident parameter
    // and optimizer floats are <= total/(d*m) + the largest single
    // gathered parameter (the slack absorbs blocks that only one axis can
    // shard plus the replicated residue).
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    for mesh in [Mesh::new(2, 2), Mesh::new(4, 2)] {
        let t = Trainer::new(
            &arts,
            &device,
            cfg_mesh(mesh, ParamStrategy::TwoD, 1),
        )
        .unwrap();
        let total = t.plan.total_elems();
        let bound = total / mesh.num_hosts() + t.plan.largest_param_elems();
        for host in 0..mesh.num_hosts() {
            let params = t.resident_param_floats(host);
            let opt = t.optimizer_state_floats(host);
            assert!(
                params <= bound,
                "mesh {mesh} host {host}: {params} resident param floats > bound {bound}"
            );
            // Adam: 2 optimizer floats per resident parameter float
            assert!(
                opt <= 2 * bound,
                "mesh {mesh} host {host}: {opt} optimizer floats > bound {}",
                2 * bound
            );
        }
    }
    device.shutdown();
}

#[test]
fn resharding_round_trip_4x2_to_2x2() {
    // Save on a 4x2 mesh from a real cached data pipeline, restore on
    // 2x2 (and sanity-check 8x1): parameters and elementwise optimizer
    // state reshard exactly; pipeline state restores exactly when the
    // data-row count matches and falls back to coarse positioning when it
    // does not.
    let arts = Artifacts::load_default().unwrap();
    let device = DeviceHandle::spawn().unwrap();
    let m = arts.model("t5-nano-dec").unwrap();
    let pid = std::process::id();
    let cache = std::env::temp_dir().join(format!("reshard_cache_{pid}"));
    let ckpt = std::env::temp_dir().join(format!("reshard_ckpt_{pid}"));
    let _ = std::fs::remove_dir_all(&ckpt);
    let task = recipes::lm_task("reshard_lm", 400, m.seq_len(), 42);
    cache_task(&task, &cache, &CacheConfig { num_shards: 8, seed: 5, workers: 2 }).unwrap();

    let infeed = |rows: usize,
                  start_step: u64,
                  resume: Option<&[t5x::seqio::dataset::PipelineState]>| {
        let cached: Arc<dyn t5x::seqio::provider::DatasetProvider> =
            Arc::new(t5x::seqio::provider::CachedTask::open(&cache, Some(&task)).unwrap());
        recipes::provider_infeed(m, cached, "train", rows, start_step, 5, resume).unwrap()
    };

    // 2 steps on 4x2, checkpoint at step 2
    let mut cfg = cfg_mesh(Mesh::new(4, 2), ParamStrategy::TwoD, 2);
    cfg.checkpoint_every = Some(2);
    cfg.checkpoint_dir = Some(ckpt.clone());
    let t_save = Trainer::new(&arts, &device, cfg).unwrap();
    t_save
        .train(&BatchSource::Infeed(infeed(4, 0, None)))
        .unwrap();
    let saved_params = t_save.params();

    let mgr = CheckpointManager::new(&ckpt);
    assert_eq!(mgr.latest(), Some(2));
    assert_eq!(mgr.saved_mesh(2).unwrap(), Some(Mesh::new(4, 2)));
    // the checkpoint is genuinely sharded on disk: at least one parameter
    // uses the block-grid layout (written by its owners, never gathered)
    let proot = ckpt.join("ckpt-00000002").join("params");
    let any_blocks = m.params.iter().any(|p| {
        matches!(open_layout(&proot, &p.name), Ok(ArrayLayout::Blocks { .. }))
    });
    assert!(any_blocks, "expected at least one block-layout parameter");
    // eval/infer load through the same path: a plain full restore
    // reassembles every layout
    let (full, _) = mgr.restore(2).unwrap();
    assert_eq!(full, saved_params);

    // ---- restore on 2x2: params + optimizer reshard exactly ----
    let mut t_2x2 =
        Trainer::new(&arts, &device, cfg_mesh(Mesh::new(2, 2), ParamStrategy::TwoD, 2)).unwrap();
    assert_eq!(t_2x2.restore_latest(&ckpt).unwrap(), 2);
    assert_eq!(t_2x2.params(), saved_params);
    // 4 saved row states vs 2 rows -> coarse fallback
    assert!(t_2x2.restored_pipeline.is_none());
    // optimizer moments reshard: reassemble Adam's m for every param on
    // both topologies and compare
    for e in &t_save.plan.entries {
        let gather = |t: &Trainer| -> HostTensor {
            let entry = t.plan.entry(&e.name).unwrap();
            let shards: Vec<HostTensor> = (0..t.config.mesh.num_hosts())
                .map(|h| {
                    HostTensor::f32(
                        entry.shard_shape.clone(),
                        t.optimizer_slot(h, &e.name, "m").unwrap(),
                    )
                })
                .collect();
            t.partitioner.unshard(&shards, &entry.spec)
        };
        assert_eq!(gather(&t_save), gather(&t_2x2), "adam m for {}", e.name);
    }
    // the restored trainer continues training from the coarse position
    let resumed = t_2x2
        .train(&BatchSource::Infeed(infeed(2, t_2x2.start_step, None)))
        .unwrap();
    assert_eq!(resumed.history.first().unwrap().step, 2);
    assert!(resumed.final_loss().is_finite());

    // ---- restore on 8x1 too (pure data-parallel) ----
    let mut t_8x1 =
        Trainer::new(&arts, &device, cfg_mesh(Mesh::new(8, 1), ParamStrategy::TwoD, 1)).unwrap();
    assert_eq!(t_8x1.restore_latest(&ckpt).unwrap(), 2);
    assert_eq!(t_8x1.params(), saved_params);

    // ---- same-mesh restore keeps the exact pipeline state ----
    let mut t_same =
        Trainer::new(&arts, &device, cfg_mesh(Mesh::new(4, 2), ParamStrategy::TwoD, 1)).unwrap();
    assert_eq!(t_same.restore_latest(&ckpt).unwrap(), 2);
    let states = t_same.restored_pipeline.clone().expect("same row count: exact states");
    assert_eq!(states.len(), 4);
    assert_eq!(t_same.params(), saved_params);
    let cont = t_same
        .train(&BatchSource::Infeed(infeed(4, 0, Some(&states))))
        .unwrap();
    assert_eq!(cont.history.first().unwrap().step, 2);

    std::fs::remove_dir_all(&cache).ok();
    std::fs::remove_dir_all(&ckpt).ok();
    device.shutdown();
}
