//! E13: collective primitives — ring all-reduce / reduce-scatter /
//! all-gather time vs host count and payload size. These are the
//! communication terms behind every §2.2 strategy; the measured byte
//! counts are checked against the analytic ring model.

use t5x::bench::Bench;
use t5x::collectives::{run_ranks, CollectiveGroup};
use t5x::partitioning::cost::ring_all_reduce_bytes;

fn main() {
    let mut bench = Bench::new("collectives (E13)");
    let sizes: &[usize] = if bench.is_quick() {
        &[1 << 16]
    } else {
        &[1 << 16, 1 << 20, 1 << 23]
    };
    let host_counts: &[usize] = if bench.is_quick() { &[4] } else { &[2, 4, 8] };

    for &n in host_counts {
        for &len in sizes {
            let group = CollectiveGroup::new(n);
            let mib = (len * 4) as f64 / (1 << 20) as f64;
            bench.measure_with_throughput(
                &format!("all_reduce n={n} {mib:.0}MiB"),
                Some(((len * 4) as f64, "B")),
                || {
                    run_ranks(n, |r| {
                        std::hint::black_box(group.all_reduce(r, vec![r as f32; len]))
                    });
                },
            );
            // verify measured bytes track the ring model
            group.reset_stats();
            run_ranks(n, |r| group.all_reduce(r, vec![0.0; len]));
            let expect = n as u64 * ring_all_reduce_bytes(len as u64 * 4, n as u64);
            let got = group.bytes_sent();
            assert!(
                (got as f64 - expect as f64).abs() / (expect.max(1) as f64) < 0.05,
                "byte model mismatch: got {got}, ring model {expect}"
            );

            bench.measure_with_throughput(
                &format!("reduce_scatter n={n} {mib:.0}MiB"),
                Some(((len * 4) as f64, "B")),
                || {
                    run_ranks(n, |r| {
                        std::hint::black_box(group.reduce_scatter(r, vec![1.0; len]))
                    });
                },
            );
            let chunk = len / n;
            bench.measure_with_throughput(
                &format!("all_gather n={n} {mib:.0}MiB"),
                Some(((len * 4) as f64, "B")),
                || {
                    run_ranks(n, |r| {
                        std::hint::black_box(group.all_gather(r, vec![1.0; chunk], chunk * n))
                    });
                },
            );
        }
    }
    bench.write_jsonl("bench_results.jsonl").unwrap();
}
