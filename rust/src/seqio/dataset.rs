//! Dataset iterator combinators — the `tensorflow.data` substitute that
//! seqio pipelines are assembled from. Pull-based, lazily evaluated,
//! deterministic when seeded, with threaded prefetch for the infeed path.

use super::Example;
use crate::util::rng::Pcg64;
use crate::util::threads::Pipe;

pub type BoxIter = Box<dyn Iterator<Item = Example> + Send>;

/// A lazily-evaluated stream of [`Example`]s.
pub struct Dataset {
    iter: BoxIter,
}

impl Iterator for Dataset {
    type Item = Example;

    fn next(&mut self) -> Option<Example> {
        self.iter.next()
    }
}

impl Dataset {
    pub fn new(iter: impl Iterator<Item = Example> + Send + 'static) -> Dataset {
        Dataset { iter: Box::new(iter) }
    }

    pub fn from_vec(v: Vec<Example>) -> Dataset {
        Dataset::new(v.into_iter())
    }

    pub fn map<F>(self, f: F) -> Dataset
    where
        F: FnMut(Example) -> Example + Send + 'static,
    {
        Dataset::new(self.iter.map(f))
    }

    pub fn filter<F>(self, mut f: F) -> Dataset
    where
        F: FnMut(&Example) -> bool + Send + 'static,
    {
        Dataset::new(self.iter.filter(move |e| f(e)))
    }

    pub fn flat_map<F>(self, mut f: F) -> Dataset
    where
        F: FnMut(Example) -> Vec<Example> + Send + 'static,
    {
        Dataset::new(self.iter.flat_map(move |e| f(e).into_iter()))
    }

    /// Stamp each example with a per-example seed derived from `seed` and
    /// the example's position — how seqio gives stochastic preprocessors
    /// (e.g. span corruption) reproducible randomness.
    pub fn enumerate_map<F>(self, mut f: F) -> Dataset
    where
        F: FnMut(usize, Example) -> Example + Send + 'static,
    {
        Dataset::new(self.iter.enumerate().map(move |(i, e)| f(i, e)))
    }

    pub fn take(self, n: usize) -> Dataset {
        Dataset::new(self.iter.take(n))
    }

    pub fn skip(self, n: usize) -> Dataset {
        Dataset::new(self.iter.skip(n))
    }

    /// Windowed shuffle (tf.data.shuffle semantics): maintain a buffer of
    /// `window` elements, emit a uniformly random one, refill.
    pub fn shuffle_window(self, window: usize, seed: u64) -> Dataset {
        struct Shuffler {
            inner: BoxIter,
            buf: Vec<Example>,
            rng: Pcg64,
            window: usize,
        }
        impl Iterator for Shuffler {
            type Item = Example;

            fn next(&mut self) -> Option<Example> {
                while self.buf.len() < self.window {
                    match self.inner.next() {
                        Some(e) => self.buf.push(e),
                        None => break,
                    }
                }
                if self.buf.is_empty() {
                    return None;
                }
                let i = self.rng.next_below(self.buf.len() as u64) as usize;
                Some(self.buf.swap_remove(i))
            }
        }
        Dataset::new(Shuffler {
            inner: self.iter,
            buf: Vec::new(),
            rng: Pcg64::new(seed),
            window: window.max(1),
        })
    }

    /// Round-robin interleave of several datasets (used by file readers).
    pub fn interleave(parts: Vec<Dataset>) -> Dataset {
        struct Interleave {
            parts: Vec<BoxIter>,
            next: usize,
        }
        impl Iterator for Interleave {
            type Item = Example;

            fn next(&mut self) -> Option<Example> {
                let n = self.parts.len();
                for _ in 0..n {
                    let i = self.next;
                    self.next = (self.next + 1) % n;
                    if let Some(e) = self.parts[i].next() {
                        return Some(e);
                    }
                }
                None
            }
        }
        Dataset::new(Interleave {
            parts: parts.into_iter().map(|d| d.iter).collect(),
            next: 0,
        })
    }

    /// Move production to a background thread with a bounded buffer —
    /// the infeed prefetch that hides data-pipeline latency (E9).
    pub fn prefetch(self, buffer: usize) -> Dataset {
        let (tx, rx) = Pipe::bounded(buffer);
        let iter = self.iter;
        std::thread::Builder::new()
            .name("seqio-prefetch".into())
            .spawn(move || {
                for item in iter {
                    if !tx.send(item) {
                        break; // consumer hung up
                    }
                }
            })
            .expect("spawn prefetch thread");
        Dataset::new(rx.into_iter())
    }

    pub fn collect_vec(self) -> Vec<Example> {
        self.iter.collect()
    }
}

/// A re-instantiable dataset (source of truth for `repeat`): seqio Tasks
/// hand out factories so epochs can restart the stream deterministically.
pub struct DatasetFactory {
    make: Box<dyn Fn() -> Dataset + Send + Sync>,
}

impl DatasetFactory {
    pub fn new(make: impl Fn() -> Dataset + Send + Sync + 'static) -> Self {
        Self { make: Box::new(make) }
    }

    pub fn instantiate(&self) -> Dataset {
        (self.make)()
    }

    /// Infinite repetition across epochs.
    pub fn repeat(self: std::sync::Arc<Self>) -> Dataset {
        struct Repeat {
            factory: std::sync::Arc<DatasetFactory>,
            cur: BoxIter,
        }
        impl Iterator for Repeat {
            type Item = Example;

            fn next(&mut self) -> Option<Example> {
                loop {
                    if let Some(e) = self.cur.next() {
                        return Some(e);
                    }
                    let fresh = self.factory.instantiate();
                    if let Some(e2) = {
                        let mut it = fresh;
                        let first = it.next();
                        self.cur = Box::new(it);
                        first
                    } {
                        return Some(e2);
                    }
                    // empty dataset: avoid infinite loop
                    return None;
                }
            }
        }
        let cur = self.instantiate();
        Dataset::new(Repeat { factory: self, cur: Box::new(cur) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seqio::{ints_example, Feature};

    fn nums(n: usize) -> Vec<Example> {
        (0..n).map(|i| ints_example(&[("x", vec![i as i32])])).collect()
    }

    fn xs(d: Dataset) -> Vec<i32> {
        d.collect_vec()
            .iter()
            .map(|e| e["x"].as_ints().unwrap()[0])
            .collect()
    }

    #[test]
    fn map_filter_take_skip() {
        let d = Dataset::from_vec(nums(10))
            .map(|mut e| {
                if let Feature::Ints(v) = e.get_mut("x").unwrap() {
                    v[0] *= 2;
                }
                e
            })
            .filter(|e| e["x"].as_ints().unwrap()[0] % 4 == 0)
            .skip(1)
            .take(3);
        assert_eq!(xs(d), vec![4, 8, 12]);
    }

    #[test]
    fn shuffle_is_seeded_permutation() {
        let a = xs(Dataset::from_vec(nums(100)).shuffle_window(32, 7));
        let b = xs(Dataset::from_vec(nums(100)).shuffle_window(32, 7));
        let c = xs(Dataset::from_vec(nums(100)).shuffle_window(32, 8));
        assert_eq!(a, b);
        assert_ne!(a, c);
        let mut sorted = a.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(a, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn interleave_round_robin() {
        let d1 = Dataset::from_vec(nums(3));
        let d2 = Dataset::from_vec(
            (10..12).map(|i| ints_example(&[("x", vec![i])])).collect(),
        );
        let out = xs(Dataset::interleave(vec![d1, d2]));
        assert_eq!(out, vec![0, 10, 1, 11, 2]);
    }

    #[test]
    fn prefetch_preserves_order() {
        let out = xs(Dataset::from_vec(nums(50)).prefetch(4));
        assert_eq!(out, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn factory_repeat() {
        let f = std::sync::Arc::new(DatasetFactory::new(|| Dataset::from_vec(nums(3))));
        let out = xs(f.repeat().take(8));
        assert_eq!(out, vec![0, 1, 2, 0, 1, 2, 0, 1]);
    }

    #[test]
    fn enumerate_map_sees_positions() {
        let d = Dataset::from_vec(nums(5)).enumerate_map(|i, mut e| {
            if let Feature::Ints(v) = e.get_mut("x").unwrap() {
                v[0] += 100 * i as i32;
            }
            e
        });
        assert_eq!(xs(d), vec![0, 101, 202, 303, 404]);
    }
}
