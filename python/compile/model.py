"""L2: pure-JAX T5-style transformer (encoder-decoder and decoder-only).

This is the "Minimal"-style model of the paper's §4 rewritten without Flax
(flax is unavailable in this image): parameters are a flat
``dict[name, jnp.ndarray]`` and every parameter carries *logical axis names*
(the t5x `param_with_axes` mechanism) in ``param_specs`` — the Rust L3
partitioner consumes those names through the artifact manifest to decide
model/data sharding, exactly as t5x maps logical axes to mesh axes.

Architecture (T5.1.1 flavour):
  * RMSNorm (T5 LayerNorm: no mean subtraction, no bias), pre-norm residuals
  * multi-head attention without biases, flash-attention Pallas kernel (L1)
  * bucketed relative position biases, shared across layers per stack
  * gated-GeLU MLP (wi_0/wi_1/wo), fused Pallas kernel (L1)
  * shared input/output embedding (logits = h @ embed^T / sqrt(d_model))
  * cross-entropy loss with z-loss regularizer (t5x default 1e-4)

Deviations from T5 (documented in DESIGN.md): attention logits are scaled by
1/sqrt(head_dim) (T5 folds this into Adafactor init); embeddings are always
shared.

``use_pallas=False`` swaps both kernels for the jnp oracles in
``kernels/ref.py`` — tests assert the two lowerings agree numerically.
"""

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.attention import flash_attention
from .kernels.fused_ffn import fused_ffn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + export-shape configuration."""

    name: str
    arch: str  # "decoder" | "encdec"
    num_layers: int
    d_model: int
    num_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    batch: int
    seq_len: int  # decoder length; encoder length is also seq_len
    relpos_buckets: int = 32
    relpos_max_distance: int = 128
    z_loss: float = 1e-4
    use_pallas: bool = True
    # L1 tile sizes (clamped to divisors inside the kernels).
    block_q: int = 64
    block_k: int = 64
    block_m: int = 128
    block_f: int = 128

    @property
    def joined_kv(self) -> int:
        return self.num_heads * self.head_dim


# ---------------------------------------------------------------------------
# Parameter inventory: (name, shape, logical_axes, init_spec)
# ---------------------------------------------------------------------------


def _layer_specs(prefix: str, cfg: ModelConfig, cross_attention: bool):
    d, jkv, ff = cfg.d_model, cfg.joined_kv, cfg.d_ff
    att = lambda p: [
        (f"{p}.wq", (d, jkv), ("embed", "joined_kv"), f"normal:{d ** -0.5:.8g}"),
        (f"{p}.wk", (d, jkv), ("embed", "joined_kv"), f"normal:{d ** -0.5:.8g}"),
        (f"{p}.wv", (d, jkv), ("embed", "joined_kv"), f"normal:{d ** -0.5:.8g}"),
        (f"{p}.wo", (jkv, d), ("joined_kv", "embed"), f"normal:{jkv ** -0.5:.8g}"),
    ]
    specs = [
        (f"{prefix}.pre_attn_norm.scale", (d,), ("embed",), "const:1"),
        *att(f"{prefix}.self_attn"),
    ]
    if cross_attention:
        specs += [
            (f"{prefix}.pre_cross_norm.scale", (d,), ("embed",), "const:1"),
            *att(f"{prefix}.cross_attn"),
        ]
    specs += [
        (f"{prefix}.pre_mlp_norm.scale", (d,), ("embed",), "const:1"),
        (f"{prefix}.mlp.wi_0", (d, ff), ("embed", "mlp"), f"normal:{d ** -0.5:.8g}"),
        (f"{prefix}.mlp.wi_1", (d, ff), ("embed", "mlp"), f"normal:{d ** -0.5:.8g}"),
        (f"{prefix}.mlp.wo", (ff, d), ("mlp", "embed"), f"normal:{ff ** -0.5:.8g}"),
    ]
    return specs


def param_specs(cfg: ModelConfig) -> List[Tuple[str, tuple, tuple, str]]:
    """Full parameter inventory in manifest (sorted) order."""
    specs = [
        ("token_embed", (cfg.vocab, cfg.d_model), ("vocab", "embed"), "normal:1"),
    ]
    if cfg.arch == "encdec":
        specs.append(
            (
                "encoder.relpos_bias",
                (cfg.relpos_buckets, cfg.num_heads),
                ("relpos_buckets", "heads"),
                f"normal:{cfg.d_model ** -0.5:.8g}",
            )
        )
        for i in range(cfg.num_layers):
            specs += _layer_specs(f"encoder.layers_{i}", cfg, cross_attention=False)
        specs.append(("encoder.final_norm.scale", (cfg.d_model,), ("embed",), "const:1"))
    specs.append(
        (
            "decoder.relpos_bias",
            (cfg.relpos_buckets, cfg.num_heads),
            ("relpos_buckets", "heads"),
            f"normal:{cfg.d_model ** -0.5:.8g}",
        )
    )
    for i in range(cfg.num_layers):
        specs += _layer_specs(
            f"decoder.layers_{i}", cfg, cross_attention=(cfg.arch == "encdec")
        )
    specs.append(("decoder.final_norm.scale", (cfg.d_model,), ("embed",), "const:1"))
    specs.sort(key=lambda s: s[0])
    return specs


# ---------------------------------------------------------------------------
# Deterministic "pattern" init shared bit-exactly with Rust (golden tests)
# ---------------------------------------------------------------------------

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def fnv1a64(name: str) -> int:
    h = _FNV_OFFSET
    for byte in name.encode("utf-8"):
        h = ((h ^ byte) * _FNV_PRIME) & _MASK64
    return h


def splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def pattern_init(name: str, shape: tuple, scale: float, seed: int = 0) -> np.ndarray:
    """Deterministic pseudo-random init computable identically in Rust.

    value[i] = (2*u - 1) * scale with u = splitmix64(fnv1a64(name)^seed ^ (i+1))
    mapped to [0, 1) via the top 53 bits.
    """
    base = fnv1a64(name) ^ seed
    n = int(np.prod(shape)) if shape else 1
    out = np.empty(n, np.float64)
    for i in range(n):
        u = splitmix64((base ^ (i + 1)) & _MASK64) >> 11
        out[i] = u * (2.0**-53)
    return ((2.0 * out - 1.0) * scale).astype(np.float32).reshape(shape)


def pattern_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    params = {}
    for name, shape, _, init in param_specs(cfg):
        kind, _, arg = init.partition(":")
        if kind == "const":
            params[name] = jnp.full(shape, float(arg), jnp.float32)
        else:
            params[name] = jnp.asarray(pattern_init(name, shape, 0.05, seed))
    return params


def random_params(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """jax.random init following the manifest init specs (python tests only)."""
    params = {}
    for name, shape, _, init in param_specs(cfg):
        kind, _, arg = init.partition(":")
        if kind == "const":
            params[name] = jnp.full(shape, float(arg), jnp.float32)
        else:
            key, sub = jax.random.split(key)
            params[name] = jax.random.normal(sub, shape, jnp.float32) * float(arg)
    return params


# ---------------------------------------------------------------------------
# Model forward
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def relative_position_bucket(relpos, bidirectional, num_buckets, max_distance):
    """T5 relative position bucketing (Raffel et al. 2020, Appendix)."""
    ret = 0
    n = -relpos
    if bidirectional:
        num_buckets //= 2
        ret += (n < 0).astype(jnp.int32) * num_buckets
        n = jnp.abs(n)
    else:
        n = jnp.maximum(n, 0)
    max_exact = num_buckets // 2
    is_small = n < max_exact
    val_if_large = max_exact + (
        jnp.log(n.astype(jnp.float32) / max_exact + 1e-6)
        / np.log(max_distance / max_exact)
        * (num_buckets - max_exact)
    ).astype(jnp.int32)
    val_if_large = jnp.minimum(val_if_large, num_buckets - 1)
    return ret + jnp.where(is_small, n, val_if_large)


def relpos_bias(rel_embedding, lq, lk, bidirectional, cfg: ModelConfig):
    """[H, Lq, Lk] additive attention bias from the bucket embedding table."""
    ctx = jnp.arange(lq)[:, None]
    mem = jnp.arange(lk)[None, :]
    buckets = relative_position_bucket(
        mem - ctx, bidirectional, cfg.relpos_buckets, cfg.relpos_max_distance
    )  # [Lq, Lk]
    values = rel_embedding[buckets]  # [Lq, Lk, H]
    return jnp.transpose(values, (2, 0, 1))


def _attention_kv(p, prefix, x_q, x_kv, bias, causal, cfg: ModelConfig):
    """Attention block that also returns the per-head K/V projections
    ([B, H, Lk, head_dim]) — the tensors `prefill` exports as the KV cache."""
    b, lq, d = x_q.shape
    lk = x_kv.shape[1]
    h, hd = cfg.num_heads, cfg.head_dim
    q = (x_q @ p[f"{prefix}.wq"]).reshape(b, lq, h, hd).transpose(0, 2, 1, 3)
    k = (x_kv @ p[f"{prefix}.wk"]).reshape(b, lk, h, hd).transpose(0, 2, 1, 3)
    v = (x_kv @ p[f"{prefix}.wv"]).reshape(b, lk, h, hd).transpose(0, 2, 1, 3)
    if bias is None:
        bias = jnp.zeros((h, lq, lk), x_q.dtype)
    if cfg.use_pallas:
        o = flash_attention(q, k, v, bias, causal, cfg.block_q, cfg.block_k)
    else:
        o = ref.attention_ref(q, k, v, bias, causal=causal)
    o = o.transpose(0, 2, 1, 3).reshape(b, lq, h * hd)
    return o @ p[f"{prefix}.wo"], k, v


def _attention(p, prefix, x_q, x_kv, bias, causal, cfg: ModelConfig):
    return _attention_kv(p, prefix, x_q, x_kv, bias, causal, cfg)[0]


def _mlp(p, prefix, x, cfg: ModelConfig):
    b, l, d = x.shape
    flat = x.reshape(b * l, d)
    if cfg.use_pallas:
        y = fused_ffn(
            flat,
            p[f"{prefix}.wi_0"],
            p[f"{prefix}.wi_1"],
            p[f"{prefix}.wo"],
            cfg.block_m,
            cfg.block_f,
        )
    else:
        y = ref.gated_ffn_ref(
            flat, p[f"{prefix}.wi_0"], p[f"{prefix}.wi_1"], p[f"{prefix}.wo"]
        )
    return y.reshape(b, l, d)


def _stack(p, stack, x, bias, causal, cfg, cross_x=None):
    """Run one transformer stack (encoder or decoder)."""
    for i in range(cfg.num_layers):
        lp = f"{stack}.layers_{i}"
        h = rms_norm(x, p[f"{lp}.pre_attn_norm.scale"])
        x = x + _attention(p, f"{lp}.self_attn", h, h, bias, causal, cfg)
        if cross_x is not None:
            h = rms_norm(x, p[f"{lp}.pre_cross_norm.scale"])
            x = x + _attention(p, f"{lp}.cross_attn", h, cross_x, None, False, cfg)
        h = rms_norm(x, p[f"{lp}.pre_mlp_norm.scale"])
        x = x + _mlp(p, f"{lp}.mlp", h, cfg)
    return rms_norm(x, p[f"{stack}.final_norm.scale"])


def logits_fn(p, cfg: ModelConfig, dec_tokens, enc_tokens=None):
    """Token logits [B, L, V] for the decoder positions."""
    embed = p["token_embed"]
    dec_x = embed[dec_tokens]
    dec_bias = relpos_bias(
        p["decoder.relpos_bias"], dec_tokens.shape[1], dec_tokens.shape[1], False, cfg
    )
    if cfg.arch == "encdec":
        enc_x = embed[enc_tokens]
        enc_bias = relpos_bias(
            p["encoder.relpos_bias"],
            enc_tokens.shape[1],
            enc_tokens.shape[1],
            True,
            cfg,
        )
        enc_out = _stack(p, "encoder", enc_x, enc_bias, False, cfg)
        dec_out = _stack(p, "decoder", dec_x, dec_bias, True, cfg, cross_x=enc_out)
    else:
        dec_out = _stack(p, "decoder", dec_x, dec_bias, True, cfg)
    # Shared-embedding output head, scaled per T5 (1/sqrt(d)).
    return (dec_out / np.sqrt(cfg.d_model)) @ embed.T


def loss_terms(p, cfg: ModelConfig, batch):
    """(loss_sum, weight_sum, correct_sum): unnormalized so the Rust trainer
    can all-reduce across hosts and divide once — exact global-batch math."""
    logits = logits_fn(
        p, cfg, batch["decoder_input_tokens"], batch.get("encoder_input_tokens")
    ).astype(jnp.float32)
    targets = batch["decoder_target_tokens"]
    weights = batch["decoder_loss_weights"].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    target_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - target_logit
    zl = cfg.z_loss * jnp.square(logz)
    loss_sum = jnp.sum((nll + zl) * weights)
    weight_sum = jnp.sum(weights)
    correct = (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32)
    correct_sum = jnp.sum(correct * weights)
    return loss_sum, weight_sum, correct_sum


def train_step_fn(cfg: ModelConfig):
    """(params..., batch...) -> (loss_sum, weight_sum, correct_sum, grads...).

    Parameters are passed positionally in sorted-name order so the HLO input
    layout matches the manifest exactly.
    """
    names = [s[0] for s in param_specs(cfg)]

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        batch = _batch_from_args(cfg, args[len(names):])

        def loss_of(p_):
            ls, ws, cs = loss_terms(p_, cfg, batch)
            return ls, (ws, cs)

        (loss_sum, (weight_sum, correct_sum)), grads = jax.value_and_grad(
            loss_of, has_aux=True
        )(p)
        return (loss_sum, weight_sum, correct_sum) + tuple(
            grads[n] for n in names
        )

    return fn, names


def eval_step_fn(cfg: ModelConfig):
    """(params..., batch...) -> (loss_sum, weight_sum, correct_sum)."""
    names = [s[0] for s in param_specs(cfg)]

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        batch = _batch_from_args(cfg, args[len(names):])
        return loss_terms(p, cfg, batch)

    return fn, names


def decode_logits_fn(cfg: ModelConfig):
    """(params..., tokens...) -> logits [B, L, V] (greedy decode in Rust)."""
    names = [s[0] for s in param_specs(cfg)]

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        rest = args[len(names):]
        if cfg.arch == "encdec":
            enc_tokens, dec_tokens = rest
            return (logits_fn(p, cfg, dec_tokens, enc_tokens),)
        (dec_tokens,) = rest
        return (logits_fn(p, cfg, dec_tokens),)

    return fn, names


# ---------------------------------------------------------------------------
# KV-cached incremental decoding (prefill + decode_step).
#
# `decode_logits` re-scores the full [B, L] prefix every step — O(L^2) work
# per sequence. The incremental pair below is the t5x `decoding` cache
# counterpart: `prefill` scores a prompt buffer once and materializes the
# per-layer K/V projections; `decode_step` extends the cache by ONE position
# per row ([B, 1] token input) and returns [B, V] next-token logits — O(L)
# total work per sequence. Decoder-only models only (the serving engine's
# scope); cache layout is [B, num_heads, L, head_dim], k then v per layer,
# recorded in the manifest as `kv_cache`.
# ---------------------------------------------------------------------------


def decoder_prefill(p, cfg: ModelConfig, dec_tokens):
    """Full-prefix decoder pass that also returns the per-layer K/V cache.

    The logits computation is the exact `logits_fn` decoder path (same
    kernels, same order of operations) — capturing K/V adds outputs, not
    different math — so `prefill` logits match `decode_logits` on the same
    buffer. Positions holding padding produce garbage cache rows; they are
    masked (`key_pos <= pos`) and later overwritten by `decode_step`.

    Returns (logits [B, L, V], [(k, v)] per layer, each [B, H, L, Hd]).
    """
    embed = p["token_embed"]
    x = embed[dec_tokens]
    l = dec_tokens.shape[1]
    bias = relpos_bias(p["decoder.relpos_bias"], l, l, False, cfg)
    caches = []
    for i in range(cfg.num_layers):
        lp = f"decoder.layers_{i}"
        h = rms_norm(x, p[f"{lp}.pre_attn_norm.scale"])
        att, k, v = _attention_kv(p, f"{lp}.self_attn", h, h, bias, True, cfg)
        x = x + att
        h = rms_norm(x, p[f"{lp}.pre_mlp_norm.scale"])
        x = x + _mlp(p, f"{lp}.mlp", h, cfg)
        caches.append((k, v))
    x = rms_norm(x, p["decoder.final_norm.scale"])
    return (x / np.sqrt(cfg.d_model)) @ embed.T, caches


def decoder_decode_step(p, cfg: ModelConfig, caches, token, pos):
    """One incremental decode step against a KV cache.

    Args:
      caches: flat [k0, v0, k1, v1, ...], each [B, H, L, head_dim].
      token: [B, 1] int32 — the most recently *written* decoder token.
      pos: [B] int32 — its position in the length-L decoder buffer
        (per-row: continuous batching packs rows at different lengths).

    Writes `token`'s K/V into the cache at `pos`, attends the single query
    over key positions `<= pos` (future cache rows are stale), and returns
    ([B, V] logits for the *next* position, updated caches). Attention is
    the `ref.attention_ref` formula specialized to Lq=1 with a per-row
    visibility mask instead of the triangular causal mask.
    """
    b = token.shape[0]
    l = cfg.seq_len
    nh, hd = cfg.num_heads, cfg.head_dim
    embed = p["token_embed"]
    x = embed[token]  # [B, 1, d]
    mem = jnp.arange(l)[None, :]  # [1, L] key positions
    buckets = relative_position_bucket(
        mem - pos[:, None], False, cfg.relpos_buckets, cfg.relpos_max_distance
    )  # [B, L]
    # [B, L, H] -> [B, H, 1, L]: per-row bias for the one query at `pos`.
    bias = jnp.transpose(p["decoder.relpos_bias"][buckets], (0, 2, 1))[:, :, None, :]
    visible = (mem <= pos[:, None])[:, None, None, :]  # [B, 1, 1, L]
    new_caches = []
    for i in range(cfg.num_layers):
        lp = f"decoder.layers_{i}"
        kc, vc = caches[2 * i], caches[2 * i + 1]
        h = rms_norm(x, p[f"{lp}.pre_attn_norm.scale"])
        q = (h @ p[f"{lp}.self_attn.wq"]).reshape(b, 1, nh, hd).transpose(0, 2, 1, 3)
        k1 = (h @ p[f"{lp}.self_attn.wk"]).reshape(b, 1, nh, hd).transpose(0, 2, 1, 3)
        v1 = (h @ p[f"{lp}.self_attn.wv"]).reshape(b, 1, nh, hd).transpose(0, 2, 1, 3)
        upd = lambda c, u, s: jax.lax.dynamic_update_slice(c, u, (0, s, 0))
        kc = jax.vmap(upd)(kc, k1, pos)
        vc = jax.vmap(upd)(vc, v1, pos)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, kc) / jnp.sqrt(
            jnp.asarray(hd, q.dtype)
        )
        logits = logits + bias.astype(logits.dtype)
        logits = jnp.where(visible, logits, ref.NEG_INF)
        weights = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
        o = jnp.einsum("bhqk,bhkd->bhqd", weights, vc)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, nh * hd)
        x = x + o @ p[f"{lp}.self_attn.wo"]
        h = rms_norm(x, p[f"{lp}.pre_mlp_norm.scale"])
        x = x + ref.gated_ffn_ref(
            h.reshape(b, cfg.d_model),
            p[f"{lp}.mlp.wi_0"],
            p[f"{lp}.mlp.wi_1"],
            p[f"{lp}.mlp.wo"],
        ).reshape(b, 1, cfg.d_model)
        new_caches += [kc, vc]
    x = rms_norm(x, p["decoder.final_norm.scale"])
    return ((x[:, 0, :] / np.sqrt(cfg.d_model)) @ embed.T,) + tuple(new_caches)


def prefill_fn(cfg: ModelConfig):
    """(params..., dec_tokens) -> (logits [B, L, V], k0, v0, k1, v1, ...)."""
    assert cfg.arch == "decoder", "KV-cached decoding exports are decoder-only"
    names = [s[0] for s in param_specs(cfg)]

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        (dec_tokens,) = args[len(names):]
        logits, caches = decoder_prefill(p, cfg, dec_tokens)
        return (logits,) + tuple(t for kv in caches for t in kv)

    return fn, names


def decode_step_fn(cfg: ModelConfig):
    """(params..., k0, v0, ..., token [B,1], pos [B]) -> (logits [B, V],
    k0', v0', ...)."""
    assert cfg.arch == "decoder", "KV-cached decoding exports are decoder-only"
    names = [s[0] for s in param_specs(cfg)]
    n_cache = 2 * cfg.num_layers

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        rest = args[len(names):]
        caches = list(rest[:n_cache])
        token, pos = rest[n_cache], rest[n_cache + 1]
        return decoder_decode_step(p, cfg, caches, token, pos)

    return fn, names


def kv_cache_shapes(cfg: ModelConfig):
    """ShapeDtypeStructs of the per-layer cache tensors, export order
    (k then v per layer) — the `kv_cache` manifest contract."""
    shape = (cfg.batch, cfg.num_heads, cfg.seq_len, cfg.head_dim)
    return [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _ in range(cfg.num_layers)
        for _ in ("k", "v")
    ]


def batch_feature_names(cfg: ModelConfig) -> List[str]:
    feats = []
    if cfg.arch == "encdec":
        feats.append("encoder_input_tokens")
    feats += ["decoder_input_tokens", "decoder_target_tokens", "decoder_loss_weights"]
    return feats


def _batch_from_args(cfg: ModelConfig, args):
    return dict(zip(batch_feature_names(cfg), args))


def batch_shapes(cfg: ModelConfig):
    """ShapeDtypeStructs for the batch features, manifest order."""
    b, l = cfg.batch, cfg.seq_len
    shapes = {}
    if cfg.arch == "encdec":
        shapes["encoder_input_tokens"] = jax.ShapeDtypeStruct((b, l), jnp.int32)
    shapes["decoder_input_tokens"] = jax.ShapeDtypeStruct((b, l), jnp.int32)
    shapes["decoder_target_tokens"] = jax.ShapeDtypeStruct((b, l), jnp.int32)
    shapes["decoder_loss_weights"] = jax.ShapeDtypeStruct((b, l), jnp.float32)
    return shapes


# ---------------------------------------------------------------------------
# Scan variant (Scalable T5, §4): layers stacked, lax.scan over depth.
# Used by the compile-time benchmark (E12); numerics match the unrolled model.
# ---------------------------------------------------------------------------


def scan_decoder_loss_fn(cfg: ModelConfig):
    """Decoder-only loss with stacked per-layer params + lax.scan over layers.

    Inputs: embed, relpos, stacked layer params (leading axis = num_layers),
    final norm scale, then the batch. Demonstrates the compile-time win of
    jax.scan that motivates Scalable T5.
    """

    def fn(
        embed,
        relpos,
        norm1,
        wq,
        wk,
        wv,
        wo,
        norm2,
        wi0,
        wi1,
        wo2,
        final_norm,
        dec_in,
        dec_tgt,
        weights,
    ):
        cfg_ref = dataclasses.replace(cfg, use_pallas=False)
        x = embed[dec_in]
        bias = relpos_bias(relpos, cfg.seq_len, cfg.seq_len, False, cfg)

        def layer(x, lp):
            (n1, q_, k_, v_, o_, n2, i0, i1, o2) = lp
            b, l, d = x.shape
            h = rms_norm(x, n1)
            hh, hd = cfg.num_heads, cfg.head_dim
            qh = (h @ q_).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            kh = (h @ k_).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            vh = (h @ v_).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            att = ref.attention_ref(qh, kh, vh, bias, causal=True)
            att = att.transpose(0, 2, 1, 3).reshape(b, l, hh * hd)
            x = x + att @ o_
            h = rms_norm(x, n2)
            x = x + ref.gated_ffn_ref(
                h.reshape(b * l, d), i0, i1, o2
            ).reshape(b, l, d)
            return x, ()

        x, _ = jax.lax.scan(layer, x, (norm1, wq, wk, wv, wo, norm2, wi0, wi1, wo2))
        x = rms_norm(x, final_norm)
        logits = (x / np.sqrt(cfg.d_model)) @ embed.T
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, dec_tgt[..., None], axis=-1)[..., 0]
        loss = jnp.sum((logz - tl) * weights)
        return loss

    return fn


def unrolled_decoder_loss_fn(cfg: ModelConfig):
    """Same computation as scan_decoder_loss_fn with a python-loop unroll."""

    def fn(
        embed,
        relpos,
        norm1,
        wq,
        wk,
        wv,
        wo,
        norm2,
        wi0,
        wi1,
        wo2,
        final_norm,
        dec_in,
        dec_tgt,
        weights,
    ):
        x = embed[dec_in]
        bias = relpos_bias(relpos, cfg.seq_len, cfg.seq_len, False, cfg)
        for i in range(cfg.num_layers):
            b, l, d = x.shape
            h = rms_norm(x, norm1[i])
            hh, hd = cfg.num_heads, cfg.head_dim
            qh = (h @ wq[i]).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            kh = (h @ wk[i]).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            vh = (h @ wv[i]).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            att = ref.attention_ref(qh, kh, vh, bias, causal=True)
            att = att.transpose(0, 2, 1, 3).reshape(b, l, hh * hd)
            x = x + att @ wo[i]
            h = rms_norm(x, norm2[i])
            x = x + ref.gated_ffn_ref(
                h.reshape(b * l, d), wi0[i], wi1[i], wo2[i]
            ).reshape(b, l, d)
        x = rms_norm(x, final_norm)
        logits = (x / np.sqrt(cfg.d_model)) @ embed.T
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, dec_tgt[..., None], axis=-1)[..., 0]
        loss = jnp.sum((logz - tl) * weights)
        return loss

    return fn


# ---------------------------------------------------------------------------
# Registry of export configs (mirrored by the Rust model registry).
# ---------------------------------------------------------------------------

CONFIGS = {
    "t5-nano-dec": ModelConfig(
        name="t5-nano-dec", arch="decoder", num_layers=2, d_model=64, num_heads=4,
        head_dim=16, d_ff=128, vocab=512, batch=8, seq_len=32,
    ),
    "t5-nano-encdec": ModelConfig(
        name="t5-nano-encdec", arch="encdec", num_layers=2, d_model=64, num_heads=4,
        head_dim=16, d_ff=128, vocab=512, batch=8, seq_len=32,
    ),
    # Long-sequence nano variant: small weights, L=128 — the serving bench
    # case where O(L^2) rescoring visibly loses to O(L) KV-cached decode.
    "t5-nano-dec-l128": ModelConfig(
        name="t5-nano-dec-l128", arch="decoder", num_layers=2, d_model=64,
        num_heads=4, head_dim=16, d_ff=128, vocab=512, batch=4, seq_len=128,
    ),
    "t5-micro-dec": ModelConfig(
        name="t5-micro-dec", arch="decoder", num_layers=4, d_model=128, num_heads=8,
        head_dim=16, d_ff=512, vocab=4096, batch=8, seq_len=64,
    ),
    "t5-micro-encdec": ModelConfig(
        name="t5-micro-encdec", arch="encdec", num_layers=4, d_model=128, num_heads=8,
        head_dim=16, d_ff=512, vocab=4096, batch=8, seq_len=64,
    ),
    "t5-small-dec": ModelConfig(
        name="t5-small-dec", arch="decoder", num_layers=6, d_model=256, num_heads=8,
        head_dim=32, d_ff=1024, vocab=8192, batch=4, seq_len=64,
    ),
    "t5-100m-dec": ModelConfig(
        name="t5-100m-dec", arch="decoder", num_layers=12, d_model=768, num_heads=12,
        head_dim=64, d_ff=2048, vocab=16384, batch=2, seq_len=128,
    ),
}
