//! t5x launcher: the CLI entrypoint (the t5x `train.py` / `eval.py` /
//! `infer.py` scripts, unified). Fully configurable via gin files +
//! `--gin.binding=value` overrides (paper §2.1).
//!
//! Data is resolved *by registry name* through `seqio::get_dataset`
//! (`t5x list-tasks` prints the namespace — tasks and mixtures alike):
//!
//! ```bash
//! t5x list-tasks
//! t5x cache  --task c4_lm --out /tmp/cache --shards 16 [--seed 0]
//! t5x train  --model t5-micro-dec --steps 100 --mesh 4x2 --strategy 2d \
//!            [--exec-mode auto|gather|block] \
//!            [--task c4_span] [--split train] [--use-cached] [--cache DIR] \
//!            [--trace-out trace.json] [--profile-steps 2..8] \
//!            [--supervise] [--max-restarts N] [--backoff-ms MS] \
//!            [--comm-deadline-ms MS] [--fault-plan plan.json] \
//!            [--config run.gin] [--gin.trainer.lr=1e-3]
//!            # --supervise (gin supervisor.enabled) wraps training in the
//!            # self-healing supervisor: failed attempts restore the
//!            # latest valid checkpoint (quarantining corrupt ones) and
//!            # relaunch with bounded backoff; the collective ring
//!            # deadline defaults ON (60 s; --comm-deadline-ms 0 turns it
//!            # off). --fault-plan (gin faults.plan) arms a deterministic
//!            # fault-injection plan — see rust/src/faults/mod.rs.
//! t5x eval   --model t5-micro-dec [--task <registry-name>] [--ckpt DIR]
//! t5x infer  --model t5-nano-dec --prompt "5 9 11" --len 8 \
//!            [--decode greedy|sample|beam] [--temperature 0.8] [--top-k 20] \
//!            [--top-p 0.95] [--seed 7] [--beam 4] [--alpha 0.6] \
//!            [--decode-mode auto|kv|rescore]
//! t5x serve  --model t5-nano-dec [--len 16] [--decode-mode auto|kv|rescore]
//!            [--replicas N] [--queue-depth D] [--shed-watermark W]
//!            [--http-port P] [--http-addr A] [--http-threads T]
//!            [--http-max-body BYTES] [--http-read-deadline-ms MS]
//!            [--fault-plan plan.json] [--trace-out trace.json]
//!            # default: JSONL requests on stdin; --http-port (or gin
//!            # serve.http_port) switches to the HTTP front end
//!            # (POST /v1/generate, GET /healthz, GET /metrics,
//!            #  POST /admin/drain); ctrl-C drains gracefully either way
//! t5x trace-summary trace.json [--top 15]
//!            # top spans by self-time + infeed/compute/comm-bound verdict
//!
//! `--trace-out` (gin `trainer.trace_out` / `serve.trace_out`) writes a
//! Chrome trace-event JSON — load it at ui.perfetto.dev or feed it to
//! `t5x trace-summary`. `--profile-steps N..M` (or a single step `N`)
//! narrows recording to that step window; `infer` takes the same flags.
//!
//! `--decode-mode` picks the serving hot path: `kv` drives the O(L)
//! `prefill`/`decode_step` entrypoints, `rescore` the O(L^2) full
//! `decode_logits` loop; `auto` (default) uses kv iff the artifact dir
//! exports it, so stale artifact dirs keep serving.
//!
//! `--exec-mode` (gin `trainer.exec_mode`) picks the train-step path on a
//! model-parallel mesh: `block` runs the per-shard segment programs with
//! the manifest's collective schedule (no per-step full-parameter
//! all-gather), `gather` transiently reconstructs full params; `auto`
//! (default) uses block iff the artifact dir exports a contract at the
//! mesh's model degree, so pre-block artifact dirs keep training.
//! t5x inspect-ckpt --dir DIR
//! t5x cost-table --model t5-100m-dec
//! ```
//!
//! Gin bindings for data selection (CLI flags win over gin):
//!
//! ```text
//! train.task = 'c4_span'      # registry name (task or mixture)
//! train.split = 'train'
//! train.use_cached = True     # route through the deterministic cache
//! train.cache_dir = '/tmp/c'  # optional explicit cache directory
//! train.data_seed = 0
//! eval.task = 'reverse_words'
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use t5x::gin::Config;
use t5x::infer::{DecodeMethod, DecodeMode, InferEngine, InferRequest};
use t5x::optim::{OptimizerKind, Schedule};
use t5x::partitioning::{cost, ExecMode, Mesh, ParamStrategy};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::seqio::provider::{CachedTask, DatasetProvider, ProviderRegistry};
use t5x::trainer::recipes;
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};
use t5x::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> anyhow::Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::new(),
    };
    for ov in &args.gin_overrides {
        cfg.apply_override(ov)?;
    }
    Ok(cfg)
}

/// Resolve trainer settings: CLI flag > gin binding > default.
fn trainer_config(args: &Args, gin: &Config) -> anyhow::Result<TrainerConfig> {
    let model = args
        .get("model")
        .map(|s| s.to_string())
        .unwrap_or_else(|| gin.str_or("trainer", "model", "t5-nano-dec"));
    let steps = match args.get("steps") {
        Some(_) => args.get_usize("steps", 0)? as u64,
        None => gin.usize_or("trainer", "steps", 50) as u64,
    };
    // --mesh DxM > gin trainer.mesh > legacy --hosts / trainer.num_hosts
    // (which mean a data-only Nx1 mesh).
    let mesh = match args.get("mesh") {
        Some(s) => Mesh::parse(s)?,
        None => match gin.get("trainer", "mesh").and_then(|v| v.as_str()) {
            Some(s) => Mesh::parse(s)?,
            None => {
                let hosts = match args.get("hosts") {
                    Some(_) => args.get_usize("hosts", 1)?,
                    None => gin.usize_or("trainer", "num_hosts", 1),
                };
                Mesh::new(hosts, 1)
            }
        },
    };
    let strategy = match args
        .get("strategy")
        .map(|s| s.to_string())
        .unwrap_or_else(|| gin.str_or("trainer", "strategy", "1d"))
        .as_str()
    {
        "2d" | "zero3" | "fsdp" => ParamStrategy::TwoD,
        _ => ParamStrategy::OneD,
    };
    let optimizer = OptimizerKind::from_name(
        &args
            .get("optimizer")
            .map(|s| s.to_string())
            .unwrap_or_else(|| gin.str_or("trainer", "optimizer", "adam")),
    )?;
    let peak = match args.get("lr") {
        Some(_) => args.get_f64("lr", 2e-3)?,
        None => gin.f64_or("trainer", "lr", 2e-3),
    };
    let warmup = gin.usize_or("trainer", "warmup_steps", 20) as u64;
    let exec_mode = ExecMode::parse(
        &args
            .get("exec-mode")
            .map(|s| s.to_string())
            .unwrap_or_else(|| gin.str_or("trainer", "exec_mode", "auto")),
    )?;
    Ok(TrainerConfig {
        model,
        mesh,
        strategy,
        optimizer,
        schedule: Schedule::RsqrtWithWarmup { peak, warmup },
        steps,
        seed: gin.usize_or("trainer", "seed", 0) as u64,
        log_every: gin.usize_or("trainer", "log_every", 10) as u64,
        checkpoint_every: args
            .get("ckpt-every")
            .and_then(|v| v.parse().ok())
            .or_else(|| {
                gin.get("trainer", "checkpoint_every")
                    .and_then(|v| v.as_i64())
                    .map(|v| v as u64)
            }),
        checkpoint_dir: args.get("ckpt").map(PathBuf::from),
        grad_clip_norm: args
            .get("clip")
            .and_then(|v| v.parse().ok())
            .or_else(|| gin.get("trainer", "grad_clip_norm").and_then(|v| v.as_f64())),
        weight_decay: args
            .get("weight-decay")
            .and_then(|v| v.parse().ok())
            .or_else(|| gin.get("trainer", "weight_decay").and_then(|v| v.as_f64())),
        exec_mode,
        trace_out: args
            .get("trace-out")
            .map(PathBuf::from)
            .or_else(|| {
                gin.get("trainer", "trace_out")
                    .and_then(|v| v.as_str().map(PathBuf::from))
            }),
        profile_steps: match args
            .get("profile-steps")
            .map(|s| s.to_string())
            .or_else(|| {
                gin.get("trainer", "profile_steps")
                    .and_then(|v| v.as_str().map(|s| s.to_string()))
            }) {
            Some(s) => Some(t5x::obs::parse_profile_steps(&s)?),
            None => None,
        },
        microbatches: match args.get("microbatches") {
            Some(_) => args.get_usize("microbatches", 1)?,
            None => gin.usize_or("trainer", "microbatches", 1),
        },
        overlap: args.has_flag("overlap") || gin.bool_or("trainer", "overlap", false),
        infeed_depth: match args.get("infeed-depth") {
            Some(_) => args.get_usize("infeed-depth", 2)?,
            None => gin.usize_or("trainer", "infeed_depth", 2),
        },
    })
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env();
    let gin = load_config(&args)?;
    match args.subcommand.as_deref() {
        Some("cache") => cmd_cache(&args),
        Some("train") => cmd_train(&args, &gin),
        Some("eval") => cmd_eval(&args, &gin),
        Some("infer") => cmd_infer(&args),
        Some("serve") => cmd_serve(&args, &gin),
        Some("inspect-ckpt") => cmd_inspect(&args),
        Some("cost-table") => cmd_cost_table(&args),
        Some("bench-report") => cmd_bench_report(&args),
        Some("trace-summary") => cmd_trace_summary(&args),
        Some("list-models") => cmd_list_models(),
        Some("list-tasks") => cmd_list_tasks(),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand '{o}'\n");
            }
            println!(
                "usage: t5x <cache|train|eval|infer|serve|inspect-ckpt|cost-table|\
                 bench-report|trace-summary|list-models|list-tasks> [flags]"
            );
            println!("  see rust/src/main.rs docs for per-command flags");
            Ok(())
        }
    }
}

/// Print the unified provider registry: every name `--task` / gin
/// `train.task` can resolve, with its kind, splits, and features.
fn cmd_list_tasks() -> anyhow::Result<()> {
    recipes::register_defaults();
    println!("{:<20} {:<8} {:<20} features", "name", "kind", "splits");
    for (name, entry) in ProviderRegistry::entries() {
        let p = entry.provider();
        let feats: Vec<String> = p.output_features().iter().map(|f| f.name.clone()).collect();
        println!(
            "{name:<20} {:<8} {:<20} {}",
            entry.kind(),
            p.splits().join(","),
            feats.join(",")
        );
    }
    Ok(())
}

fn cmd_list_models() -> anyhow::Result<()> {
    let arts = Artifacts::load_default()?;
    println!("{:<18} {:>10} {:>8} {:>8} arch", "model", "params", "batch", "seq");
    for (name, m) in &arts.models {
        println!(
            "{name:<18} {:>10} {:>8} {:>8} {}",
            m.total_params(),
            m.batch(),
            m.seq_len(),
            m.arch
        );
    }
    Ok(())
}

fn cmd_cache(args: &Args) -> anyhow::Result<()> {
    recipes::register_defaults();
    let shards = args.get_usize("shards", 16)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let out = PathBuf::from(args.get_or("out", "/tmp/t5x_cache"));
    let name = args.get_or("task", "c4_lm");
    // legacy aliases from the pre-registry CLI
    let name: String = match name.as_str() {
        "lm" => "c4_lm".to_string(),
        "span" => "c4_span".to_string(),
        other => other.to_string(),
    };
    for legacy in ["docs", "seq"] {
        if args.get(legacy).is_some() {
            eprintln!(
                "warning: --{legacy} is ignored — registry tasks have fixed corpora; \
                 register a custom task (or edit recipes::register_defaults) instead"
            );
        }
    }
    let task = match ProviderRegistry::get(&name) {
        Some(entry) => entry.as_task().ok_or_else(|| {
            anyhow::anyhow!(
                "'{name}' is a {} — only plain tasks can be cached",
                entry.kind()
            )
        })?,
        None => anyhow::bail!(
            "no task named '{name}' in the registry; see `t5x list-tasks`"
        ),
    };
    let meta = recipes::ensure_cached(&task, &out, shards, seed)?;
    println!(
        "cached task '{}': {} examples in {} shards x {} split(s) [{}] at {}",
        meta.task,
        meta.num_examples,
        meta.num_shards,
        meta.splits.as_ref().map(|s| s.len()).unwrap_or(1),
        meta.splits.as_ref().map(|s| s.join(", ")).unwrap_or_else(|| "train".into()),
        out.display()
    );
    Ok(())
}

/// Resolve the training data source: CLI flag > gin binding > default.
/// Every named scenario — live task, mixture, cached — goes through
/// `seqio::get_dataset` via `recipes::provider_infeed`.
fn train_source(
    args: &Args,
    gin: &Config,
    m: &t5x::runtime::ModelManifest,
    cfg: &TrainerConfig,
    trainer: &Trainer,
) -> anyhow::Result<BatchSource> {
    recipes::register_defaults();
    // gin-defined mixture (mixture.name/tasks[/rates]), lazily bound so
    // the config may name tasks registered at any point above
    if let Some(name) = recipes::register_gin_mixture(gin)? {
        println!("gin mixture '{name}' registered");
    }
    let task_name = args
        .get("task")
        .map(|s| s.to_string())
        .or_else(|| gin.get("train", "task").and_then(|v| v.as_str()).map(|s| s.to_string()));
    let split = args
        .get("split")
        .map(|s| s.to_string())
        .unwrap_or_else(|| gin.str_or("train", "split", "train"));
    let use_cached = args.has_flag("use-cached") || gin.bool_or("train", "use_cached", false);
    let cache_dir = args
        .get("cache")
        .map(PathBuf::from)
        .or_else(|| gin.get("train", "cache_dir").and_then(|v| v.as_str()).map(PathBuf::from));
    let data_seed = gin.usize_or("train", "data_seed", cfg.seed as usize) as u64;
    let resume = trainer.restored_pipeline.as_deref();
    // A cache's build seed pins its data; a different requested seed is
    // ignored, so say so instead of silently training on other data.
    fn warn_seed_pinned(label: &str, build_seed: u64, data_seed: u64) {
        if build_seed != data_seed {
            eprintln!(
                "warning: cache {label} was built with seed {build_seed}, not the \
                 requested data seed {data_seed}; the cache's seed wins"
            );
        }
    }

    let source = match (task_name, cache_dir) {
        (Some(name), cache_dir) => {
            let entry = ProviderRegistry::get(&name).ok_or_else(|| {
                anyhow::anyhow!(
                    "--task '{name}' is not in the registry (registered: [{}]); \
                     see `t5x list-tasks`",
                    ProviderRegistry::names().join(", ")
                )
            })?;
            let provider: Arc<dyn DatasetProvider> = if use_cached || cache_dir.is_some() {
                if let t5x::seqio::provider::RegistryEntry::Cached(c) = &entry {
                    // already a cache-backed provider; nothing to build
                    anyhow::ensure!(
                        cache_dir.is_none(),
                        "'{name}' is already cache-backed; --cache/train.cache_dir \
                         conflicts with its registered directory"
                    );
                    warn_seed_pinned(&format!("'{name}'"), c.build_seed(), data_seed);
                    println!("training '{name}' from its registered cache");
                    c.clone()
                } else {
                    let task = entry.as_task().ok_or_else(|| {
                        anyhow::anyhow!(
                            "use_cached requires a plain task; '{name}' is a {}",
                            entry.kind()
                        )
                    })?;
                    let user_dir = cache_dir.is_some();
                    let dir = cache_dir
                        .unwrap_or_else(|| std::env::temp_dir().join(format!("t5x_cache_{name}")));
                    if user_dir && dir.join("cache_meta.json").exists() {
                        // A user-supplied cache directory is reused
                        // read-only — never deleted/rebuilt in place.
                        // CachedTask::open rejects one built from another
                        // task; an incompatible shard count errors at
                        // get_dataset; a seed mismatch only warns (the
                        // cache's build seed pins the data).
                        let meta = t5x::seqio::cache::CacheMeta::load(&dir)?;
                        warn_seed_pinned(&format!("at {}", dir.display()), meta.seed, data_seed);
                        println!("training '{name}' from existing cache at {}", dir.display());
                    } else {
                        // Tool-owned (or absent) directory: (re)build as
                        // needed; ensure_cached is idempotent and rebuilds
                        // on a task/seed/shard mismatch.
                        recipes::ensure_cached(&task, &dir, 8 * cfg.mesh.data, data_seed)?;
                        println!(
                            "training '{name}' from deterministic cache at {}",
                            dir.display()
                        );
                    }
                    Arc::new(CachedTask::open(&dir, Some(&task))?)
                }
            } else {
                println!("training '{name}' ({}) live, split '{split}'", entry.kind());
                entry.provider()
            };
            BatchSource::Infeed(recipes::provider_infeed(
                m,
                provider,
                &split,
                cfg.mesh.data,
                // a step consumes k microbatches, so scale the per-row
                // prefetch so `infeed_depth` still means "steps ahead"
                cfg.infeed_depth.max(1) * cfg.microbatches.max(1),
                trainer.start_step,
                data_seed,
                resume,
            )?)
        }
        // legacy: a bare --cache DIR without --task
        (None, Some(dir)) => BatchSource::Infeed(recipes::cached_infeed(
            m,
            &dir,
            cfg.mesh.data,
            cfg.infeed_depth.max(1) * cfg.microbatches.max(1),
            trainer.start_step,
            resume,
        )?),
        (None, None) => BatchSource::Synthetic { seed: 7 },
    };
    Ok(source)
}

/// `--fault-plan PATH` (gin `faults.plan`): arm the deterministic fault
/// injection plan process-wide. No plan → hooks stay on the one-relaxed-
/// load fast path.
fn arm_fault_plan(args: &Args, gin: &Config) -> anyhow::Result<()> {
    let path = args.get("fault-plan").map(|s| s.to_string()).or_else(|| {
        gin.get("faults", "plan").and_then(|v| v.as_str()).map(|s| s.to_string())
    });
    if let Some(path) = path {
        let plan = t5x::faults::FaultPlan::from_file(&path)?;
        eprintln!("fault plan armed: {} fault(s) from {path}", plan.len());
        t5x::faults::arm(plan);
    }
    Ok(())
}

/// Resolve the supervisor restart policy (CLI flag > gin `supervisor.*` >
/// default). The collective ring deadline defaults ON under supervision
/// (60 s); `--comm-deadline-ms 0` disables it.
fn supervisor_config(args: &Args, gin: &Config) -> anyhow::Result<t5x::trainer::supervisor::SupervisorConfig> {
    let max_restarts = match args.get("max-restarts") {
        Some(_) => args.get_usize("max-restarts", 3)? as u32,
        None => gin.usize_or("supervisor", "max_restarts", 3) as u32,
    };
    let backoff_ms = match args.get("backoff-ms") {
        Some(_) => args.get_usize("backoff-ms", 100)? as u64,
        None => gin.usize_or("supervisor", "backoff_ms", 100) as u64,
    };
    let deadline = match args.get("comm-deadline-ms") {
        Some(_) => args.get_usize("comm-deadline-ms", 60_000)? as u64,
        None => gin.usize_or("supervisor", "comm_deadline_ms", 60_000) as u64,
    };
    Ok(t5x::trainer::supervisor::SupervisorConfig {
        max_restarts,
        backoff_ms,
        comm_deadline_ms: if deadline == 0 { None } else { Some(deadline) },
        resume: args.has_flag("resume"),
    })
}

fn cmd_train(args: &Args, gin: &Config) -> anyhow::Result<()> {
    let cfg = trainer_config(args, gin)?;
    arm_fault_plan(args, gin)?;
    let arts = Artifacts::load_default()?;
    let device = DeviceHandle::spawn()?;
    let m = arts.model(&cfg.model)?;
    println!(
        "training {} ({:.2}M params) for {} steps on a {} mesh ({:?})",
        cfg.model,
        m.total_params() as f64 / 1e6,
        cfg.steps,
        cfg.mesh,
        cfg.strategy
    );
    let log_path = args.get_or("log", "train_log.jsonl");
    let supervise =
        args.has_flag("supervise") || gin.bool_or("supervisor", "enabled", false);
    let summary = if supervise {
        use t5x::trainer::supervisor::Supervisor;
        let sup_cfg = supervisor_config(args, gin)?;
        println!(
            "supervised: max {} restart(s), backoff {} ms, comm deadline {}",
            sup_cfg.max_restarts,
            sup_cfg.backoff_ms,
            match sup_cfg.comm_deadline_ms {
                Some(ms) => format!("{ms} ms"),
                None => "off".to_string(),
            }
        );
        let sup = Supervisor::new(&arts, &device, cfg.clone(), sup_cfg);
        let run = sup.run(
            |trainer| train_source(args, gin, m, &cfg, trainer),
            |t, attempt| {
                // The JSONL sink truncates on open, so only attempt 0 gets
                // it; retries log to the terminal and rely on counters.
                let logger = if attempt == 0 {
                    t5x::metrics::MetricsLogger::new()
                        .with_terminal()
                        .with_jsonl(&log_path)
                } else {
                    t5x::metrics::MetricsLogger::new().with_terminal()
                };
                t.with_logger(logger)
            },
        )?;
        if run.restarts > 0 {
            println!(
                "supervisor: recovered from {} failure(s) in {} ms \
                 ({} checkpoint(s) quarantined)",
                run.restarts, run.recovery_ms, run.quarantined_ckpts
            );
        }
        run.summary
    } else {
        let logger = t5x::metrics::MetricsLogger::new()
            .with_terminal()
            .with_jsonl(&log_path);
        let mut trainer = Trainer::new(&arts, &device, cfg.clone())?.with_logger(logger);
        if cfg.mesh.model > 1 {
            println!(
                "execution mode: {} (requested '{}')",
                trainer.exec_mode, cfg.exec_mode
            );
        }
        if args.has_flag("resume") {
            if let Some(dir) = &cfg.checkpoint_dir {
                let step = trainer.restore_latest(dir)?;
                println!("resumed from checkpoint at step {step}");
            }
        }
        let source = train_source(args, gin, m, &cfg, &trainer)?;
        trainer.train(&source)?
    };
    println!(
        "done: loss {:.4} -> {:.4}, {:.1}s, comm {:.1} MiB",
        summary.first_loss(),
        summary.final_loss(),
        summary.wall_seconds,
        summary.comm_bytes as f64 / (1 << 20) as f64
    );
    if let Some(path) = &cfg.trace_out {
        println!(
            "trace written to {} (load at ui.perfetto.dev or run \
             `t5x trace-summary {}`)",
            path.display(),
            path.display()
        );
    }
    // dump the operative gin config (the t5x reproducibility artifact)
    let op = gin.operative();
    if !op.is_empty() {
        println!("-- operative gin config --\n{op}");
    }
    Ok(())
}

fn cmd_eval(args: &Args, gin: &Config) -> anyhow::Result<()> {
    let cfg = trainer_config(args, gin)?;
    let arts = Artifacts::load_default()?;
    let device = DeviceHandle::spawn()?;
    let m = arts.model(&cfg.model)?;
    let runner = t5x::trainer::eval::EvalRunner::new(&arts, &device, &cfg.model)?;
    let params = match args.get("ckpt") {
        Some(dir) => {
            let mgr = t5x::checkpoint::CheckpointManager::new(dir);
            let step = mgr.latest().ok_or_else(|| anyhow::anyhow!("no checkpoint"))?;
            println!("evaluating checkpoint step {step}");
            mgr.restore(step)?.0
        }
        None => t5x::model::init_params(m, 0),
    };
    // Resolve the eval task from the registry — default per arch, so an
    // encdec model gets a task that actually declares encoder inputs
    // (get_dataset errors on a feature mismatch instead of silently
    // evaluating on empty encoder rows).
    recipes::register_defaults();
    recipes::register_gin_mixture(gin)?;
    let task_name = args
        .get("task")
        .map(|s| s.to_string())
        .or_else(|| gin.get("eval", "task").and_then(|v| v.as_str()).map(|s| s.to_string()))
        .unwrap_or_else(|| recipes::default_task_for_arch(&m.arch).to_string());
    let provider = ProviderRegistry::provider(&task_name)?;
    let split = recipes::eval_split(provider.as_ref());
    let seed = gin.usize_or("eval", "data_seed", 5) as u64;
    let num_batches = args.get_usize("batches", 8)?;
    let batches = recipes::eval_batches(m, provider, &split, seed, num_batches)?;
    anyhow::ensure!(
        !batches.is_empty(),
        "eval task '{task_name}' split '{split}' produced no full batches"
    );
    let metrics = runner.evaluate(&params, batches.into_iter())?;
    println!(
        "eval {} on '{task_name}' [{split}]: loss {:.4}, token accuracy {:.2}%, {} batches",
        cfg.model,
        metrics.loss,
        metrics.accuracy * 100.0,
        metrics.num_batches
    );
    Ok(())
}

/// Params from --ckpt (latest step) or seeded init.
fn load_infer_params(
    args: &Args,
    m: &t5x::runtime::ModelManifest,
) -> anyhow::Result<t5x::model::Params> {
    Ok(match args.get("ckpt") {
        Some(dir) => {
            let mgr = t5x::checkpoint::CheckpointManager::new(dir);
            let step = mgr.latest().ok_or_else(|| anyhow::anyhow!("no checkpoint"))?;
            mgr.restore(step)?.0
        }
        None => t5x::model::init_params(m, 0),
    })
}

/// `--decode-mode auto|kv|rescore` (None = auto-select by manifest).
fn decode_mode_flag(args: &Args) -> anyhow::Result<Option<DecodeMode>> {
    DecodeMode::parse(&args.get_or("decode-mode", "auto"))
}

/// `--trace-out` (or gin `serve.trace_out`): arm the engine's span tracer,
/// returning it with the export path so the caller can write the Chrome
/// trace once serving finishes. `--profile-steps N..M` narrows recording
/// to that engine-step window.
fn arm_engine_tracer(
    args: &Args,
    gin: Option<&Config>,
    engine: &mut InferEngine,
) -> anyhow::Result<Option<(Arc<t5x::obs::Tracer>, PathBuf)>> {
    let path = args.get("trace-out").map(PathBuf::from).or_else(|| {
        gin.and_then(|g| {
            g.get("serve", "trace_out").and_then(|v| v.as_str().map(PathBuf::from))
        })
    });
    let Some(path) = path else { return Ok(None) };
    let tracer = t5x::obs::Tracer::new();
    tracer.name_track("serve-engine");
    engine.set_tracer(tracer.clone());
    if let Some(s) = args.get("profile-steps") {
        engine.set_profile_steps(Some(t5x::obs::parse_profile_steps(s)?));
    }
    Ok(Some((tracer, path)))
}

fn cmd_infer(args: &Args) -> anyhow::Result<()> {
    let model = args.get_or("model", "t5-nano-dec");
    let arts = Artifacts::load_default()?;
    let device = DeviceHandle::spawn()?;
    let m = arts.model(&model)?;
    let params = load_infer_params(args, m)?;
    let mut engine =
        InferEngine::with_mode(&arts, &device, &model, &params, 1, decode_mode_flag(args)?)?;
    let trace = arm_engine_tracer(args, None, &mut engine)?;
    let prompt: Vec<i32> = args
        .get_or("prompt", "5 9 11")
        .split_whitespace()
        .filter_map(|t| t.parse().ok())
        .collect();
    let len = args.get_usize("len", 8)?;
    println!("prompt ids: {prompt:?}");
    if args.get_or("decode", "greedy") == "beam" {
        let hyps = engine.beam_decode(
            &prompt,
            args.get_usize("beam", 4)?,
            args.get_f64("alpha", 0.6)? as f32,
            len,
        )?;
        for (i, h) in hyps.iter().enumerate() {
            println!(
                "beam {i}: score {:.4} (logp {:.4}) ids {:?}",
                h.score, h.log_prob, h.tokens
            );
        }
        if let Some((tracer, path)) = &trace {
            tracer.export_or_warn(path);
        }
        return Ok(());
    }
    let method = match args.get_or("decode", "greedy").as_str() {
        "greedy" => DecodeMethod::Greedy,
        "sample" => DecodeMethod::Sample {
            temperature: args.get_f64("temperature", 1.0)? as f32,
            top_k: args.get_usize("top-k", 0)?,
            top_p: args.get_f64("top-p", 1.0)? as f32,
            seed: args.get_usize("seed", 0)? as u64,
        },
        other => anyhow::bail!("unknown --decode '{other}' (greedy|sample|beam)"),
    };
    engine.submit(InferRequest { id: 0, prompt, max_tokens: len, method })?;
    let results = engine.run_until_idle()?;
    let s = engine.summary();
    println!("generated ids: {:?}", results[0].tokens);
    println!(
        "decode mode {}, latency {:.2} ms, {:.1} tok/s ({:.2} ms/step), \
         slot utilization {:.1}%",
        s.mode,
        results[0].latency_seconds * 1e3,
        s.tokens_per_sec,
        s.seconds_per_step * 1e3,
        s.slot_utilization * 100.0
    );
    if let Some((tracer, path)) = &trace {
        tracer.export_or_warn(path);
    }
    Ok(())
}

/// Resolve a `serve.*` gateway knob: CLI flag > gin binding > None.
fn serve_opt_usize(
    args: &Args,
    gin: &Config,
    flag: &str,
    key: &str,
) -> anyhow::Result<Option<usize>> {
    match args.get(flag) {
        Some(s) => Ok(Some(s.parse::<usize>().map_err(|e| {
            anyhow::anyhow!("--{flag} '{s}': {e}")
        })?)),
        None => Ok(gin
            .get("serve", key)
            .and_then(|v| v.as_i64())
            .map(|v| v.max(0) as usize)),
    }
}

fn cmd_serve(args: &Args, gin: &Config) -> anyhow::Result<()> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use t5x::serve::{Gateway, GatewayConfig, HttpConfig, HttpServer};

    let model = args.get_or("model", "t5-nano-dec");
    arm_fault_plan(args, gin)?;
    let arts = Artifacts::load_default()?;
    let device = DeviceHandle::spawn()?;
    let m = arts.model(&model)?;
    let params = load_infer_params(args, m)?;
    let mut engine =
        InferEngine::with_mode(&arts, &device, &model, &params, 1, decode_mode_flag(args)?)?;
    let trace = arm_engine_tracer(args, Some(gin), &mut engine)?;
    let default_max = match args.get("len") {
        Some(_) => args.get_usize("len", 16)?,
        None => gin.usize_or("serve", "default_max_tokens", 16),
    };
    // Gateway knobs: CLI flag > gin serve.* > default. HTTP mode engages
    // iff a port is named on either side; otherwise JSONL-on-stdin.
    let replicas = serve_opt_usize(args, gin, "replicas", "replicas")?
        .unwrap_or(1)
        .max(1);
    let queue_depth = serve_opt_usize(args, gin, "queue-depth", "queue_depth")?.unwrap_or(64);
    let shed_watermark = serve_opt_usize(args, gin, "shed-watermark", "shed_watermark")?;
    let http_port = match serve_opt_usize(args, gin, "http-port", "http_port")? {
        Some(p) => Some(u16::try_from(p).map_err(|_| {
            anyhow::anyhow!("http port {p} out of range (0..=65535; 0 = ephemeral)")
        })?),
        None => None,
    };
    let http_addr = args
        .get("http-addr")
        .map(|s| s.to_string())
        .unwrap_or_else(|| gin.str_or("serve", "http_addr", "127.0.0.1"));
    let http_threads = serve_opt_usize(args, gin, "http-threads", "http_threads")?.unwrap_or(8);
    let http_max_body = serve_opt_usize(args, gin, "http-max-body", "http_max_body_bytes")?
        .unwrap_or(1 << 20);
    let http_read_deadline_ms =
        serve_opt_usize(args, gin, "http-read-deadline-ms", "http_read_deadline_ms")?
            .unwrap_or(10_000) as u64;

    let batch = m.batch();
    let mode_name = engine.mode().name();
    engine.set_trace_label("serve/replica0");
    let mut engines = Vec::with_capacity(replicas);
    engines.push(engine);
    for i in 1..replicas {
        let mut r = engines[0].replica();
        r.set_trace_label(format!("serve/replica{i}"));
        engines.push(r);
    }
    let gw = Gateway::launch(engines, GatewayConfig { queue_depth, shed_watermark });

    // SIGINT → drain: stop admission, let in-flight requests finish, then
    // fall through to the normal summary/trace-export path. A second
    // ctrl-C exits immediately (the handler re-arms the default).
    let stop = Arc::new(AtomicBool::new(false));
    t5x::serve::signal::install_sigint();
    {
        let stop = stop.clone();
        let gwc = gw.clone();
        std::thread::Builder::new()
            .name("sigint-watch".into())
            .spawn(move || loop {
                if t5x::serve::signal::sigint_triggered() {
                    eprintln!("SIGINT: draining (ctrl-C again to exit immediately)");
                    stop.store(true, Ordering::Relaxed);
                    gwc.drain();
                    return;
                }
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(std::time::Duration::from_millis(50));
            })?;
    }

    if let Some(port) = http_port {
        let server = HttpServer::start(
            gw.clone(),
            HttpConfig {
                addr: http_addr.clone(),
                port,
                threads: http_threads,
                default_max_tokens: default_max,
                max_body_bytes: http_max_body,
                read_deadline_ms: http_read_deadline_ms,
            },
            stop.clone(),
        )?;
        eprintln!(
            "serving {model} over HTTP at {http_addr}:{} — {replicas} replica(s) x \
             {batch} slots ({mode_name} decode), queue depth {queue_depth}{}; \
             POST /v1/generate, GET /healthz, GET /metrics, POST /admin/drain \
             (or ctrl-C) to stop",
            server.port(),
            match shed_watermark {
                Some(w) => format!(", shed watermark {w}"),
                None => String::new(),
            }
        );
        while !stop.load(Ordering::Relaxed) {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        gw.drain();
        server.join();
    } else {
        eprintln!(
            "serving {model} — {replicas} replica(s) x {batch} slots ({mode_name} \
             decode), queue depth {queue_depth}: one JSON request per stdin line, \
             e.g. {{\"prompt\": [5, 9, 11], \"max_tokens\": 8, \"priority\": 1}}; \
             EOF (or ctrl-C) to stop",
        );
        let served = t5x::infer::server::serve(
            &gw,
            std::io::BufReader::new(std::io::stdin()),
            std::io::stdout(),
            default_max,
            Some(stop.clone()),
        )?;
        eprintln!(
            "accepted {} requests ({} rejected, {} shed): queue wait p50 {:.2} ms / \
             p99 {:.2} ms",
            served.requests, served.errors, served.shed, served.queue_ms_p50,
            served.queue_ms_p99
        );
    }
    stop.store(true, Ordering::Relaxed);
    let report = gw.shutdown();
    eprintln!(
        "gateway: {} completed, {} tokens, {:.1} tok/s over {:.1}s; queue p50 \
         {:.2} ms / p99 {:.2} ms, ttft p50 {:.2} ms / p99 {:.2} ms, latency p50 \
         {:.2} ms / p99 {:.2} ms",
        report.completed,
        report.tokens,
        report.tokens_per_sec,
        report.wall_seconds,
        report.queue_ms_p50,
        report.queue_ms_p99,
        report.ttft_ms_p50,
        report.ttft_ms_p99,
        report.latency_ms_p50,
        report.latency_ms_p99
    );
    for (i, s) in report.replicas.iter().enumerate() {
        eprintln!(
            "  replica {i}: {} completed, {} steps ({} prefills, {} mode), {} \
             tokens, {:.1} tok/s, slot utilization {:.1}%, {} mid-flight refills",
            s.completed,
            s.steps,
            s.prefills,
            s.mode,
            s.tokens,
            s.tokens_per_sec,
            s.slot_utilization * 100.0,
            s.refills
        );
    }
    if let Some((tracer, path)) = &trace {
        tracer.export_or_warn(path);
    }
    Ok(())
}

/// Print the top spans by self-time and the bottleneck verdict
/// (infeed-bound / compute-bound / comm-bound) for a Chrome trace written
/// by `--trace-out`.
fn cmd_trace_summary(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .first()
        .cloned()
        .or_else(|| args.get("file").map(|s| s.to_string()))
        .ok_or_else(|| {
            anyhow::anyhow!("usage: t5x trace-summary <trace.json> [--top K]")
        })?;
    let summary = t5x::obs::summarize_file(&path)?;
    summary.print(args.get_usize("top", 15)?);
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let dir = args.get("dir").ok_or_else(|| anyhow::anyhow!("--dir required"))?;
    let mgr = t5x::checkpoint::CheckpointManager::new(dir);
    let steps = mgr.steps();
    println!("checkpoints: {steps:?}");
    if let Some(&latest) = steps.last() {
        let (params, extra) = mgr.restore(latest)?;
        match mgr.saved_mesh(latest) {
            Ok(Some(mesh)) => println!("step {latest}: saved on a {mesh} mesh"),
            _ => println!("step {latest}: host-0 (v1) save"),
        }
        println!("step {latest}: {} params", params.len());
        let mut total = 0usize;
        for (name, t) in &params {
            println!("  {:<44} {:?}  |x|={:.4}", name, t.shape, t.norm());
            total += t.elements();
        }
        println!("total params: {total}");
        println!("optimizer state vectors: {}", extra.len());
        match mgr.restore_pipeline(latest)? {
            Some(states) => {
                println!("pipeline state: {} host stream(s)", states.len());
                for (h, st) in states.iter().enumerate() {
                    let tag = st.0.get("op").and_then(|v| v.as_str()).unwrap_or("?");
                    println!(
                        "  host {h}: root op '{tag}', {} bytes",
                        st.to_json_string().len()
                    );
                }
            }
            None => println!("pipeline state: none (synthetic source or pre-pipeline checkpoint)"),
        }
    }
    Ok(())
}

/// Render bench_results.jsonl (written by `cargo bench`) as the markdown
/// tables embedded in EXPERIMENTS.md.
fn cmd_bench_report(args: &Args) -> anyhow::Result<()> {
    use t5x::util::json::Json;
    let path = args.get_or("file", "bench_results.jsonl");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e} (run `cargo bench` first)"))?;
    let mut groups: std::collections::BTreeMap<String, Vec<Json>> = Default::default();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line)?;
        let group = v.get("group").and_then(|g| g.as_str()).unwrap_or("?").to_string();
        groups.entry(group).or_default().push(v);
    }
    for (group, rows) in groups {
        println!("### {group}\n");
        println!("| case | median | p95 | throughput |");
        println!("|---|---|---|---|");
        for r in rows {
            let med = r.get("median_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let p95 = r.get("p95_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let tput = match (
                r.get("throughput_per_s").and_then(|v| v.as_f64()),
                r.get("throughput_unit").and_then(|v| v.as_str()),
            ) {
                (Some(t), Some(u)) => format!("{}/s", t5x::bench::human_count(t, u)),
                _ => "-".to_string(),
            };
            println!(
                "| {} | {} | {} | {} |",
                r.get("name").and_then(|v| v.as_str()).unwrap_or("?"),
                t5x::bench::human_time(med),
                t5x::bench::human_time(p95),
                tput
            );
        }
        println!();
    }
    Ok(())
}

fn cmd_cost_table(args: &Args) -> anyhow::Result<()> {
    let model = args.get_or("model", "t5-100m-dec");
    let arts = Artifacts::load_default()?;
    let m = arts.model(&model)?;
    let meshes = [
        Mesh::new(1, 1),
        Mesh::new(4, 1),
        Mesh::new(16, 1),
        Mesh::new(64, 1),
        Mesh::new(4, 4),
        Mesh::new(1, 8),
    ];
    println!("{}", cost::strategy_table(m, &meshes, cost::LinkModel::default()));
    Ok(())
}
