//! Simulated inter-host collectives (S3): the communication layer that XLA
//! GSPMD would emit on a TPU pod, implemented explicitly over threads so
//! the paper's partitioning strategies (§2.2) run with real data movement.
//!
//! [`CollectiveGroup::all_reduce`] / [`CollectiveGroup::reduce_scatter`] /
//! [`CollectiveGroup::all_gather`] are *ring* algorithms: n-1 steps of
//! neighbor exchange moving ~2·(n-1)/n of the payload per participant — the
//! same wire complexity as NCCL/TPU-ICI rings, so measured byte counts match
//! the analytic model in [`crate::partitioning::cost`]. All ranks must call
//! the same ops in the same order (the usual collective contract).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};

/// Per-group transport + accounting shared by all ranks.
pub struct CollectiveGroup {
    n: usize,
    /// senders[r]: rank r's channel to rank (r+1) % n.
    senders: Vec<Sender<Vec<f32>>>,
    /// receivers[r]: rank r's inbox (fed by rank (r-1+n) % n).
    receivers: Vec<Mutex<Receiver<Vec<f32>>>>,
    barrier: Barrier,
    bytes_sent: AtomicU64,
    ops: AtomicU64,
}

impl CollectiveGroup {
    pub fn new(n: usize) -> Arc<CollectiveGroup> {
        assert!(n >= 1);
        let mut senders = Vec::with_capacity(n);
        let mut receivers_raw: Vec<Option<Receiver<Vec<f32>>>> =
            (0..n).map(|_| None).collect();
        for r in 0..n {
            let (tx, rx) = channel();
            // rank r sends to r+1: the receiver belongs to (r+1) % n
            senders.push(tx);
            receivers_raw[(r + 1) % n] = Some(rx);
        }
        Arc::new(CollectiveGroup {
            n,
            senders,
            receivers: receivers_raw
                .into_iter()
                .map(|r| Mutex::new(r.unwrap()))
                .collect(),
            barrier: Barrier::new(n),
            bytes_sent: AtomicU64::new(0),
            ops: AtomicU64::new(0),
        })
    }

    pub fn num_ranks(&self) -> usize {
        self.n
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    pub fn reset_stats(&self) {
        self.bytes_sent.store(0, Ordering::Relaxed);
        self.ops.store(0, Ordering::Relaxed);
    }

    pub fn barrier(&self, _rank: usize) {
        self.barrier.wait();
    }

    fn send_next(&self, rank: usize, data: Vec<f32>) {
        self.bytes_sent
            .fetch_add(data.len() as u64 * 4, Ordering::Relaxed);
        self.senders[rank].send(data).expect("ring send");
    }

    fn recv_prev(&self, rank: usize) -> Vec<f32> {
        self.receivers[rank].lock().unwrap().recv().expect("ring recv")
    }

    /// Elementwise-sum all-reduce (ring: reduce-scatter + all-gather).
    /// Every rank receives the full reduced vector.
    pub fn all_reduce(&self, rank: usize, mut data: Vec<f32>) -> Vec<f32> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        if self.n == 1 {
            return data;
        }
        let n = self.n;
        let bounds = chunk_bounds(data.len(), n);
        // Phase 1: reduce-scatter. After n-1 steps rank r owns the fully
        // reduced chunk (r+1) % n.
        for s in 0..n - 1 {
            let send_c = (rank + n - s) % n;
            let (lo, hi) = bounds[send_c];
            self.send_next(rank, data[lo..hi].to_vec());
            let recv_c = (rank + n - s - 1) % n;
            let incoming = self.recv_prev(rank);
            let (lo, hi) = bounds[recv_c];
            for (d, x) in data[lo..hi].iter_mut().zip(incoming) {
                *d += x;
            }
        }
        // Phase 2: all-gather of owned chunks.
        for s in 0..n - 1 {
            let send_c = (rank + 1 + n - s) % n;
            let (lo, hi) = bounds[send_c];
            self.send_next(rank, data[lo..hi].to_vec());
            let recv_c = (rank + n - s) % n;
            let incoming = self.recv_prev(rank);
            let (lo, hi) = bounds[recv_c];
            data[lo..hi].copy_from_slice(&incoming);
        }
        data
    }

    /// Ring reduce-scatter: rank r returns summed chunk r (of n near-equal
    /// contiguous chunks).
    pub fn reduce_scatter(&self, rank: usize, mut data: Vec<f32>) -> Vec<f32> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let n = self.n;
        let bounds = chunk_bounds(data.len(), n);
        if n == 1 {
            return data;
        }
        // After n-1 steps of the standard schedule rank r owns chunk
        // (r+1)%n; shift by one so rank r ends owning chunk r.
        for s in 0..n - 1 {
            let send_c = (rank + n - 1 - s) % n;
            let (lo, hi) = bounds[send_c];
            self.send_next(rank, data[lo..hi].to_vec());
            let recv_c = (rank + 2 * n - 2 - s) % n;
            let incoming = self.recv_prev(rank);
            let (lo, hi) = bounds[recv_c];
            for (d, x) in data[lo..hi].iter_mut().zip(incoming) {
                *d += x;
            }
        }
        let (lo, hi) = bounds[rank];
        data[lo..hi].to_vec()
    }

    /// Ring all-gather: each rank contributes chunk `rank` of the conceptual
    /// full vector; every rank returns the concatenation.
    pub fn all_gather(&self, rank: usize, chunk: Vec<f32>, full_len: usize) -> Vec<f32> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let n = self.n;
        let bounds = chunk_bounds(full_len, n);
        let mut full = vec![0.0f32; full_len];
        let (lo, hi) = bounds[rank];
        debug_assert_eq!(hi - lo, chunk.len(), "rank {rank} chunk size");
        full[lo..hi].copy_from_slice(&chunk);
        if n == 1 {
            return full;
        }
        for s in 0..n - 1 {
            let send_c = (rank + n - s) % n;
            let (lo, hi) = bounds[send_c];
            self.send_next(rank, full[lo..hi].to_vec());
            let recv_c = (rank + n - 1 - s) % n;
            let incoming = self.recv_prev(rank);
            let (lo, hi) = bounds[recv_c];
            full[lo..hi].copy_from_slice(&incoming);
        }
        full
    }

    /// Broadcast from rank 0 (ring forward).
    pub fn broadcast(&self, rank: usize, data: Option<Vec<f32>>) -> Vec<f32> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        if self.n == 1 {
            return data.expect("root must provide data");
        }
        if rank == 0 {
            let d = data.expect("root must provide data");
            self.send_next(rank, d.clone());
            d
        } else {
            let d = self.recv_prev(rank);
            if rank != self.n - 1 {
                self.send_next(rank, d.clone());
            }
            d
        }
    }
}

/// Split `len` into `n` near-equal contiguous chunks.
pub fn chunk_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut pos = 0;
    for i in 0..n {
        let sz = base + usize::from(i < rem);
        out.push((pos, pos + sz));
        pos += sz;
    }
    out
}

/// Run `f(rank)` on n threads concurrently and collect results in rank
/// order — the harness used by the trainer and all collective tests/benches.
pub fn run_ranks<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    crate::util::threads::parallel_map(n, n, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reduce_matches_sum() {
        for n in [1, 2, 3, 4, 8] {
            let g = CollectiveGroup::new(n);
            let len = 103; // ragged
            let outs = run_ranks(n, |r| {
                let data: Vec<f32> = (0..len).map(|i| (r * len + i) as f32).collect();
                g.all_reduce(r, data)
            });
            let expect: Vec<f32> = (0..len)
                .map(|i| (0..n).map(|r| (r * len + i) as f32).sum())
                .collect();
            for (r, out) in outs.iter().enumerate() {
                assert_eq!(out, &expect, "n={n} rank={r}");
            }
        }
    }

    #[test]
    fn reduce_scatter_chunks() {
        for n in [2, 3, 4] {
            let g = CollectiveGroup::new(n);
            let len = 64;
            let outs = run_ranks(n, |r| {
                let data: Vec<f32> = (0..len).map(|i| (i + r) as f32).collect();
                g.reduce_scatter(r, data)
            });
            let bounds = chunk_bounds(len, n);
            for (r, out) in outs.iter().enumerate() {
                let (lo, hi) = bounds[r];
                let expect: Vec<f32> = (lo..hi)
                    .map(|i| (0..n).map(|rr| (i + rr) as f32).sum())
                    .collect();
                assert_eq!(out, &expect, "n={n} rank={r}");
            }
        }
    }

    #[test]
    fn all_gather_reassembles() {
        let n = 4;
        let len = 50; // ragged chunks: 13,13,12,12
        let g = CollectiveGroup::new(n);
        let bounds = chunk_bounds(len, n);
        let full_expect: Vec<f32> = (0..len).map(|i| i as f32 * 2.0).collect();
        let outs = run_ranks(n, |r| {
            let (lo, hi) = bounds[r];
            g.all_gather(r, full_expect[lo..hi].to_vec(), len)
        });
        for out in outs {
            assert_eq!(out, full_expect);
        }
    }

    #[test]
    fn reduce_scatter_then_all_gather_equals_all_reduce() {
        let n = 4;
        let len = 128;
        let g1 = CollectiveGroup::new(n);
        let g2 = CollectiveGroup::new(n);
        let make = |r: usize| -> Vec<f32> {
            (0..len).map(|i| ((i * 7 + r * 13) % 23) as f32).collect()
        };
        let ar = run_ranks(n, |r| g1.all_reduce(r, make(r)));
        let rs_ag = run_ranks(n, |r| {
            let chunk = g2.reduce_scatter(r, make(r));
            g2.all_gather(r, chunk, len)
        });
        assert_eq!(ar, rs_ag);
    }

    #[test]
    fn broadcast_from_root() {
        let n = 5;
        let g = CollectiveGroup::new(n);
        let outs = run_ranks(n, |r| {
            g.broadcast(r, if r == 0 { Some(vec![1.0, 2.0, 3.0]) } else { None })
        });
        for out in outs {
            assert_eq!(out, vec![1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn byte_accounting_positive_and_ring_sized() {
        let n = 4;
        let len = 100;
        let g = CollectiveGroup::new(n);
        run_ranks(n, |r| g.all_reduce(r, vec![1.0; len]));
        // ring all-reduce sends ~2*(n-1)/n of the payload per rank
        let expected_approx = (2 * (n - 1) * len * 4) as u64; // all ranks
        let got = g.bytes_sent();
        assert!(
            got.abs_diff(expected_approx) <= (n * n * 4) as u64,
            "got {got}, expected ~{expected_approx}"
        );
        assert_eq!(g.ops(), n as u64);
    }

    #[test]
    fn concurrent_sequences_stay_ordered() {
        // Two back-to-back collectives on the same group must not interleave.
        let n = 3;
        let g = CollectiveGroup::new(n);
        let outs = run_ranks(n, |r| {
            let a = g.all_reduce(r, vec![r as f32; 8]);
            let b = g.all_reduce(r, vec![1.0; 8]);
            (a[0], b[0])
        });
        for (a, b) in outs {
            assert_eq!(a, 3.0); // 0+1+2
            assert_eq!(b, 3.0);
        }
    }
}
