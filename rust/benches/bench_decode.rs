//! Serving throughput: naive one-request-per-batch decoding vs the
//! continuous-batching engine at 1/4/8 concurrent requests.
//!
//! The naive row reproduces the pre-engine `cmd_infer` behavior: every
//! request runs its own full-batch `decode_logits` loop (useful work =
//! one row, the other B-1 slots decode wasted duplicates). The engine
//! rows pack the same requests into one batch and refill freed slots
//! mid-flight. Throughput counts *useful* tokens (requested tokens only),
//! so the gap is exactly the slot-utilization win; utilization itself is
//! printed from the engine counters.

use t5x::bench::Bench;
use t5x::infer::{DecodeMethod, InferEngine, InferRequest};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::trainer::eval::EvalRunner;

fn main() {
    let arts = Artifacts::load_default().expect("make artifacts first");
    let device = DeviceHandle::spawn().unwrap();
    let model = "t5-nano-dec";
    let m = arts.model(model).unwrap().clone();
    let mut bench = Bench::new("decode serving (infer)");
    let decode_len = if bench.is_quick() { 4 } else { 8 };
    // eos -1 never fires: every request decodes exactly decode_len tokens,
    // so naive and engine rows do identical useful work.
    let eos = -1;
    let params = t5x::model::init_params(&m, 0);
    let runner = EvalRunner::new(&arts, &device, model).unwrap();
    let b = m.batch();

    for &n in &[1usize, 4, 8] {
        // fresh engine per concurrency level so the printed counters are
        // this configuration's, not an accumulation across rows
        let mut engine =
            InferEngine::new(&arts, &device, model, &params, eos).unwrap();
        let prompts: Vec<Vec<i32>> =
            (0..n).map(|i| vec![5 + i as i32, 9, 11]).collect();
        bench.measure_with_throughput(
            &format!("naive per-prompt full-batch loop ({n} reqs)"),
            Some(((n * decode_len) as f64, "tok")),
            || {
                for p in &prompts {
                    let batch = vec![p.clone(); b];
                    let outs = runner
                        .greedy_decode(&params, None, &batch, decode_len, eos)
                        .unwrap();
                    std::hint::black_box(&outs);
                }
            },
        );
        bench.measure_with_throughput(
            &format!("continuous-batching engine ({n} reqs)"),
            Some(((n * decode_len) as f64, "tok")),
            || {
                for (i, p) in prompts.iter().enumerate() {
                    engine
                        .submit(InferRequest {
                            id: i as u64,
                            prompt: p.clone(),
                            max_tokens: decode_len,
                            method: DecodeMethod::Greedy,
                        })
                        .unwrap();
                }
                let res = engine.run_until_idle().unwrap();
                assert_eq!(res.len(), n);
                std::hint::black_box(&res);
            },
        );
        println!(
            "  engine counters after {n}-req rows: slot utilization {:.1}%, \
             {} refills, {} steps",
            engine.slot_utilization() * 100.0,
            engine.counters().get("infer/refills"),
            engine.counters().get("infer/steps"),
        );
    }
    bench.write_jsonl("bench_results.jsonl").unwrap();
    device.shutdown();
}
