//! Evaluation + inference loops (the t5x `EvaluateTask` / `InferTask`
//! paths): loss/accuracy over held-out batches via the `eval_step` HLO,
//! greedy decoding via the `decode_logits` HLO, and the predict-based
//! [`predict_and_evaluate`] path that streams continuous-batching engine
//! outputs through seqio's [`crate::seqio::evaluation::Evaluator`].

use crate::infer::decoding;
use crate::infer::engine::{InferEngine, InferRequest};
use crate::model::Params;
use crate::runtime::artifacts::ModelManifest;
use crate::runtime::{DeviceHandle, Executable, HostTensor};
use crate::seqio::evaluation::{EvalResult, Evaluator, Metric};
use crate::seqio::vocab::Vocabulary;

/// Holds the compiled eval/decode entrypoints for one model.
pub struct EvalRunner {
    pub manifest: ModelManifest,
    eval_exe: Executable,
    decode_exe: Executable,
    /// KV-cached incremental decode pair, compiled when the artifact dir
    /// exports it (decoder models); `greedy_decode` rides it, falling
    /// back to full rescoring for encdec models and stale artifact dirs.
    prefill_exe: Option<Executable>,
    step_exe: Option<Executable>,
}

#[derive(Debug, Clone)]
pub struct EvalMetrics {
    pub loss: f64,
    pub accuracy: f64,
    pub weight_sum: f64,
    pub num_batches: usize,
}

impl EvalRunner {
    pub fn new(
        arts: &crate::runtime::Artifacts,
        device: &DeviceHandle,
        model: &str,
    ) -> anyhow::Result<EvalRunner> {
        let manifest = arts.model(model)?.clone();
        let (eval_exe, _) = device.compile(&manifest.entrypoint("eval_step")?.hlo)?;
        let (decode_exe, _) = device.compile(&manifest.entrypoint("decode_logits")?.hlo)?;
        let (prefill_exe, step_exe) = if manifest.supports_kv_decode() {
            let (pf, _) = device.compile(&manifest.entrypoint("prefill")?.hlo)?;
            let (st, _) = device.compile(&manifest.entrypoint("decode_step")?.hlo)?;
            (Some(pf), Some(st))
        } else {
            (None, None)
        };
        Ok(EvalRunner { manifest, eval_exe, decode_exe, prefill_exe, step_exe })
    }

    /// True when `greedy_decode` (decoder-only calls) uses the KV-cached
    /// incremental path rather than per-step full rescoring.
    pub fn decodes_with_kv(&self) -> bool {
        self.prefill_exe.is_some()
    }

    /// Average loss/accuracy over a set of batches.
    pub fn evaluate(
        &self,
        params: &Params,
        batches: impl Iterator<Item = Vec<HostTensor>>,
    ) -> anyhow::Result<EvalMetrics> {
        let ordered = crate::model::params_in_order(&self.manifest, params);
        let mut loss_sum = 0.0f64;
        let mut weight_sum = 0.0f64;
        let mut correct_sum = 0.0f64;
        let mut num_batches = 0usize;
        for batch in batches {
            let mut inputs = ordered.clone();
            inputs.extend(batch);
            let outs = self.eval_exe.run(inputs)?;
            loss_sum += outs[0].first_f32() as f64;
            weight_sum += outs[1].first_f32() as f64;
            correct_sum += outs[2].first_f32() as f64;
            num_batches += 1;
        }
        anyhow::ensure!(num_batches > 0, "no eval batches");
        Ok(EvalMetrics {
            loss: loss_sum / weight_sum.max(1e-9),
            accuracy: correct_sum / weight_sum.max(1e-9),
            weight_sum,
            num_batches,
        })
    }

    /// Greedy decode: `prompts` holds per-row prompt token ids
    /// (<= seq_len). For enc-dec models `encoder_tokens` must hold the
    /// full [B, L] encoder batch; for decoder-only pass None.
    ///
    /// Decoder-only calls ride the KV-cached path when the artifact dir
    /// exports it (`prefill` once, then one `decode_step` per token);
    /// otherwise each step re-feeds the prefix through `decode_logits`.
    /// Token selection is [`decoding::argmax`] either way.
    ///
    /// Returns [B][decode_len] generated ids (prompt not included).
    pub fn greedy_decode(
        &self,
        params: &Params,
        encoder_tokens: Option<&HostTensor>,
        prompts: &[Vec<i32>],
        decode_len: usize,
        eos_id: i32,
    ) -> anyhow::Result<Vec<Vec<i32>>> {
        if encoder_tokens.is_none() && self.prefill_exe.is_some() {
            return self.greedy_decode_kv(params, prompts, decode_len, eos_id);
        }
        self.greedy_decode_rescore(params, encoder_tokens, prompts, decode_len, eos_id)
    }

    /// The historical full-rescore loop (encdec models, stale artifacts).
    fn greedy_decode_rescore(
        &self,
        params: &Params,
        encoder_tokens: Option<&HostTensor>,
        prompts: &[Vec<i32>],
        decode_len: usize,
        eos_id: i32,
    ) -> anyhow::Result<Vec<Vec<i32>>> {
        let b = self.manifest.batch();
        let l = self.manifest.seq_len();
        let v = self.manifest.vocab();
        anyhow::ensure!(prompts.len() == b, "need exactly {b} prompt rows");
        let ordered = crate::model::params_in_order(&self.manifest, params);

        // decoder stream: shifted-right convention (BOS=0 at position 0)
        let mut dec = vec![0i32; b * l];
        let mut lens = Vec::with_capacity(b);
        for (i, p) in prompts.iter().enumerate() {
            anyhow::ensure!(p.len() + decode_len < l, "prompt+decode exceeds seq_len");
            // position 0 is BOS(0); prompt occupies 1..=len
            for (j, &t) in p.iter().enumerate() {
                dec[i * l + 1 + j] = t;
            }
            lens.push(p.len() + 1); // next position to fill
        }
        let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); b];
        let mut done = vec![false; b];
        for _ in 0..decode_len {
            let mut inputs = ordered.clone();
            if let Some(enc) = encoder_tokens {
                inputs.push(enc.clone());
            }
            inputs.push(HostTensor::i32(vec![b, l], dec.clone()));
            let outs = self.decode_exe.run(inputs)?;
            let logits = &outs[0]; // [B, L, V]
            let lf = logits.as_f32();
            for i in 0..b {
                if done[i] {
                    continue;
                }
                // logits at the last filled position predict the next token
                let pos = lens[i] - 1;
                let row = &lf[(i * l + pos) * v..(i * l + pos + 1) * v];
                // shared argmax => engine decodes stay byte-identical
                let tok = decoding::argmax(row) as i32;
                outputs[i].push(tok);
                if tok == eos_id || lens[i] + 1 >= l {
                    done[i] = true;
                } else {
                    dec[i * l + lens[i]] = tok;
                    lens[i] += 1;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
        }
        Ok(outputs)
    }

    /// KV-cached greedy decode: one `prefill` scores every prompt row and
    /// materializes the cache; each further token costs one `decode_step`
    /// ([B, 1] token input) — O(L) total instead of O(L^2).
    fn greedy_decode_kv(
        &self,
        params: &Params,
        prompts: &[Vec<i32>],
        decode_len: usize,
        eos_id: i32,
    ) -> anyhow::Result<Vec<Vec<i32>>> {
        let b = self.manifest.batch();
        let l = self.manifest.seq_len();
        let v = self.manifest.vocab();
        anyhow::ensure!(prompts.len() == b, "need exactly {b} prompt rows");
        let ordered = crate::model::params_in_order(&self.manifest, params);
        let mut dec = vec![0i32; b * l];
        let mut lens = Vec::with_capacity(b);
        for (i, p) in prompts.iter().enumerate() {
            anyhow::ensure!(p.len() + decode_len < l, "prompt+decode exceeds seq_len");
            for (j, &t) in p.iter().enumerate() {
                dec[i * l + 1 + j] = t;
            }
            lens.push(p.len() + 1); // next position to fill
        }
        let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); b];
        let mut done = vec![false; b];
        if decode_len == 0 {
            return Ok(outputs);
        }
        // First token: prefill the prompt buffer and build the cache.
        let mut inputs = ordered.clone();
        inputs.push(HostTensor::i32(vec![b, l], dec.clone()));
        let mut outs = self.prefill_exe.as_ref().unwrap().run(inputs)?;
        let mut cache = outs.split_off(1);
        {
            let lf = outs[0].as_f32(); // [B, L, V]
            for i in 0..b {
                let pos = lens[i] - 1;
                let tok = decoding::argmax(&lf[(i * l + pos) * v..(i * l + pos + 1) * v]) as i32;
                outputs[i].push(tok);
                if tok == eos_id || lens[i] + 1 >= l {
                    done[i] = true;
                } else {
                    dec[i * l + lens[i]] = tok;
                    lens[i] += 1;
                }
            }
        }
        // Remaining tokens: one decode_step per position. Finished rows
        // ride along re-feeding their last token (idempotent cache write,
        // output ignored) — exactly the rescore loop's skip semantics.
        for _ in 1..decode_len {
            if done.iter().all(|&d| d) {
                break;
            }
            let mut tok = vec![0i32; b];
            let mut pos = vec![0i32; b];
            for i in 0..b {
                tok[i] = dec[i * l + lens[i] - 1];
                pos[i] = (lens[i] - 1) as i32;
            }
            let mut inputs = ordered.clone();
            inputs.extend(cache.iter().cloned());
            inputs.push(HostTensor::i32(vec![b, 1], tok));
            inputs.push(HostTensor::i32(vec![b], pos));
            let mut outs = self.step_exe.as_ref().unwrap().run(inputs)?;
            cache = outs.split_off(1);
            let lf = outs[0].as_f32(); // [B, V]
            for i in 0..b {
                if done[i] {
                    continue;
                }
                let tok = decoding::argmax(&lf[i * v..(i + 1) * v]) as i32;
                outputs[i].push(tok);
                if tok == eos_id || lens[i] + 1 >= l {
                    done[i] = true;
                } else {
                    dec[i * l + lens[i]] = tok;
                    lens[i] += 1;
                }
            }
        }
        Ok(outputs)
    }
}

/// Prediction-based evaluation report: the seqio metric values plus the
/// decoded prediction strings (prediction order matches `examples`).
pub struct PredictEvalReport {
    pub result: EvalResult,
    pub predictions: Vec<String>,
}

/// The t5x predict-then-evaluate path: decode every `(prompt, target)`
/// example through the continuous-batching engine (greedy, so results are
/// reproducible), detokenize with `vocab`, and stream the (target,
/// prediction) pairs through the seqio [`Evaluator`].
pub fn predict_and_evaluate(
    engine: &mut InferEngine,
    vocab: &dyn Vocabulary,
    task_name: &str,
    examples: &[(Vec<i32>, String)],
    max_tokens: usize,
    metrics: &[Metric],
) -> anyhow::Result<PredictEvalReport> {
    anyhow::ensure!(!examples.is_empty(), "no examples to evaluate");
    for (i, (prompt, _)) in examples.iter().enumerate() {
        engine.submit(InferRequest {
            id: i as u64,
            prompt: prompt.clone(),
            max_tokens,
            method: decoding::DecodeMethod::Greedy,
        })?;
    }
    let mut results = engine.run_until_idle()?;
    anyhow::ensure!(
        results.len() == examples.len(),
        "engine completed {} of {} requests",
        results.len(),
        examples.len()
    );
    results.sort_by_key(|r| r.id);
    let predictions: Vec<String> = results
        .iter()
        .map(|r| {
            // drop the trailing EOS before detokenizing
            let ids: &[i32] = match r.tokens.split_last() {
                Some((&last, rest)) if last == engine.eos_id() => rest,
                _ => &r.tokens,
            };
            vocab.decode(ids)
        })
        .collect();
    let evaluator = Evaluator::new(metrics.to_vec());
    let result = evaluator.evaluate_stream(
        task_name,
        examples
            .iter()
            .zip(&predictions)
            .map(|((_, target), pred)| (target.clone(), pred.clone())),
    );
    Ok(PredictEvalReport { result, predictions })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;
    use crate::trainer::infeed::synthetic_batch;

    #[test]
    fn eval_runs_and_matches_chance() {
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let runner = EvalRunner::new(&arts, &dev, "t5-nano-dec").unwrap();
        let params = crate::model::init_params(&runner.manifest, 1);
        let m = runner.manifest.clone();
        let metrics = runner
            .evaluate(&params, (0..3).map(|s| synthetic_batch(&m, 4, 0, s)))
            .unwrap();
        assert_eq!(metrics.num_batches, 3);
        // random params, random tokens: loss ~ ln(512)=6.24 (+init variance)
        assert!(metrics.loss > 5.0 && metrics.loss < 9.0, "loss={}", metrics.loss);
        assert!(metrics.accuracy < 0.1);
        dev.shutdown();
    }

    #[test]
    fn greedy_decode_emits_tokens() {
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let runner = EvalRunner::new(&arts, &dev, "t5-nano-dec").unwrap();
        let params = crate::model::init_params(&runner.manifest, 2);
        let b = runner.manifest.batch();
        let prompts: Vec<Vec<i32>> = (0..b).map(|i| vec![5 + i as i32, 9, 11]).collect();
        let outs = runner.greedy_decode(&params, None, &prompts, 6, 1).unwrap();
        assert_eq!(outs.len(), b);
        for o in &outs {
            assert!(!o.is_empty() && o.len() <= 6);
            for &t in o {
                assert!((0..runner.manifest.vocab() as i32).contains(&t));
            }
        }
        // determinism
        let outs2 = runner.greedy_decode(&params, None, &prompts, 6, 1).unwrap();
        assert_eq!(outs, outs2);
        dev.shutdown();
    }

    #[test]
    fn greedy_decode_kv_matches_rescore_loop() {
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();
        let runner = EvalRunner::new(&arts, &dev, "t5-nano-dec").unwrap();
        assert!(runner.decodes_with_kv(), "re-export artifacts for kv entrypoints");
        let params = crate::model::init_params(&runner.manifest, 5);
        let b = runner.manifest.batch();
        // ragged prompts + a live EOS so rows finish at different steps
        let prompts: Vec<Vec<i32>> =
            (0..b).map(|i| (0..=(i % 3) as i32).map(|j| 7 + 3 * j + i as i32).collect()).collect();
        let kv = runner.greedy_decode(&params, None, &prompts, 8, 1).unwrap();
        let rescore =
            runner.greedy_decode_rescore(&params, None, &prompts, 8, 1).unwrap();
        assert_eq!(kv, rescore, "kv greedy decode must match the rescore loop");
        dev.shutdown();
    }
}
