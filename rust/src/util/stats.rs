//! Running statistics & histograms for metrics and the bench harness.

/// Streaming mean/variance/min/max (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a stored sample set (exact; fine for bench sizes).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// q in [0, 1]; linear interpolation between order statistics.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Pearson lag-1 serial correlation — used by the global-shuffle experiment
/// (E8) to quantify how well the cache job decorrelates adjacent examples.
pub fn lag1_autocorrelation(xs: &[f64]) -> f64 {
    if xs.len() < 3 {
        return 0.0;
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let denom: f64 = xs.iter().map(|x| (x - mean).powi(2)).sum();
    if denom == 0.0 {
        return 0.0;
    }
    let num: f64 = (1..n).map(|i| (xs[i] - mean) * (xs[i - 1] - mean)).sum();
    num / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        let mut r = Running::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 4);
        assert!((r.mean() - 2.5).abs() < 1e-12);
        assert!((r.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 4.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::default();
        for x in 1..=100 {
            s.push(x as f64);
        }
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(1.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(0.95) - 95.05).abs() < 0.1);
    }

    #[test]
    fn autocorrelation_detects_order() {
        let sorted: Vec<f64> = (0..100).map(|i| i as f64).collect();
        assert!(lag1_autocorrelation(&sorted) > 0.9);
        let alternating: Vec<f64> = (0..100).map(|i| (i % 2) as f64).collect();
        assert!(lag1_autocorrelation(&alternating) < -0.9);
    }
}
