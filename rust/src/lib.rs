//! # t5x-rs
//!
//! A Rust + JAX + Pallas reproduction of *"Scaling Up Models and Data with
//! t5x and seqio"* (Roberts et al., 2022).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): tiled flash
//!   attention and a fused gated-GeLU MLP, validated against pure-jnp
//!   oracles at build time.
//! * **L2** — a pure-JAX T5-style transformer (`python/compile/model.py`)
//!   lowered once by `python/compile/aot.py` to HLO text artifacts.
//! * **L3** — this crate: it loads the artifacts through PJRT ([`runtime`]),
//!   shards parameters/optimizer state over a simulated multi-host mesh
//!   ([`partitioning`], [`collectives`]), feeds data through a full seqio
//!   port ([`seqio`]), and runs the training loop ([`trainer`]) with
//!   TensorStore-style checkpointing ([`checkpoint`]) and Gin-style
//!   configuration ([`gin`]).
//!
//! Python never runs on the training path: after `make artifacts` the
//! `t5x` binary and all examples are self-contained.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper claim to a bench/example, and `EXPERIMENTS.md` for
//! measured results.

pub mod bench;
pub mod checkpoint;
pub mod collectives;
pub mod gin;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod partitioning;
pub mod runtime;
pub mod seqio;
pub mod testing;
pub mod trainer;
pub mod util;
