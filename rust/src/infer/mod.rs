//! Inference serving subsystem (S8): the `t5x.decoding` + `InferTask`
//! counterpart, grown into a serving stack.
//!
//! * [`decoding`] — pure host-side decoding algorithms: greedy,
//!   temperature/top-k/top-p sampling (seeded, one RNG draw per token),
//!   and beam search with length penalty, plus a brute-force exhaustive
//!   reference used by golden tests.
//! * [`engine`] — the continuous-batching engine: packs independent
//!   requests into the fixed `B` batch slots of the `decode_logits` HLO,
//!   retires rows at EOS, and refills freed slots from the queue
//!   mid-flight. Reports latency/throughput/utilization through
//!   [`crate::metrics::CounterSet`].
//! * [`server`] — a JSONL request/response loop (`t5x serve`) with a
//!   background reader so requests join the running batch.
//!
//! The subsystem's determinism contract (engine output byte-identical to
//! single-request decoding, seeded sampling reproducible per request) is
//! documented in [`decoding`] and [`engine`] and enforced by
//! `tests/integration_infer.rs`.

pub mod decoding;
pub mod engine;
pub mod server;

pub use decoding::{DecodeMethod, Hypothesis};
pub use engine::{EngineSummary, InferEngine, InferRequest, InferResult};
