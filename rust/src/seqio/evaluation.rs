//! Evaluation (seqio.Evaluator + metric functions): consistent benchmarks
//! across competing models (paper §1, §3.1).
//!
//! Metrics operate on (target, prediction) string pairs or token streams;
//! the [`Evaluator`] aggregates them over a task's eval examples.

use std::collections::HashMap;

/// Built-in metric functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Fraction of predictions exactly matching the target string.
    ExactMatch,
    /// Token-level accuracy over aligned positions (padded comparison).
    TokenAccuracy,
    /// BLEU (up to 4-gram, uniform weights, brevity penalty).
    Bleu,
    /// Character-level edit-distance similarity 1 - d/max_len.
    EditSimilarity,
    /// ROUGE-N recall of target n-grams found in the prediction.
    RougeN(u8),
    /// Bag-of-tokens F1 (the SQuAD-style answer metric).
    TokenF1,
}

impl Metric {
    pub fn name(&self) -> &'static str {
        match self {
            Metric::ExactMatch => "exact_match",
            Metric::TokenAccuracy => "token_accuracy",
            Metric::Bleu => "bleu",
            Metric::EditSimilarity => "edit_similarity",
            Metric::RougeN(1) => "rouge1",
            Metric::RougeN(2) => "rouge2",
            Metric::RougeN(_) => "rougeN",
            Metric::TokenF1 => "token_f1",
        }
    }

    /// Compute over a set of (target, prediction) pairs.
    pub fn compute(&self, pairs: &[(String, String)]) -> f64 {
        if pairs.is_empty() {
            return 0.0;
        }
        match self {
            Metric::ExactMatch => {
                pairs.iter().filter(|(t, p)| t == p).count() as f64 / pairs.len() as f64
            }
            Metric::TokenAccuracy => {
                let mut correct = 0usize;
                let mut total = 0usize;
                for (t, p) in pairs {
                    let tt: Vec<&str> = t.split_whitespace().collect();
                    let pp: Vec<&str> = p.split_whitespace().collect();
                    total += tt.len();
                    correct += tt
                        .iter()
                        .zip(pp.iter())
                        .filter(|(a, b)| a == b)
                        .count();
                }
                if total == 0 {
                    0.0
                } else {
                    correct as f64 / total as f64
                }
            }
            Metric::Bleu => corpus_bleu(pairs),
            Metric::EditSimilarity => {
                pairs
                    .iter()
                    .map(|(t, p)| {
                        let d = edit_distance(t, p);
                        let m = t.chars().count().max(p.chars().count()).max(1);
                        1.0 - d as f64 / m as f64
                    })
                    .sum::<f64>()
                    / pairs.len() as f64
            }
            Metric::RougeN(n) => {
                pairs
                    .iter()
                    .map(|(t, p)| rouge_n_recall(t, p, *n as usize))
                    .sum::<f64>()
                    / pairs.len() as f64
            }
            Metric::TokenF1 => {
                pairs.iter().map(|(t, p)| token_f1(t, p)).sum::<f64>()
                    / pairs.len() as f64
            }
        }
    }
}

/// ROUGE-N recall: fraction of target n-grams present in the prediction
/// (clipped multiset matching).
pub fn rouge_n_recall(target: &str, pred: &str, n: usize) -> f64 {
    let t: Vec<&str> = target.split_whitespace().collect();
    let p: Vec<&str> = pred.split_whitespace().collect();
    if t.len() < n {
        return 0.0;
    }
    let mut pred_ngrams: HashMap<Vec<&str>, usize> = HashMap::new();
    if p.len() >= n {
        for w in p.windows(n) {
            *pred_ngrams.entry(w.to_vec()).or_default() += 1;
        }
    }
    let mut hit = 0usize;
    let mut total = 0usize;
    for w in t.windows(n) {
        total += 1;
        if let Some(c) = pred_ngrams.get_mut(&w.to_vec()) {
            if *c > 0 {
                *c -= 1;
                hit += 1;
            }
        }
    }
    hit as f64 / total.max(1) as f64
}

/// Bag-of-tokens F1 between target and prediction.
pub fn token_f1(target: &str, pred: &str) -> f64 {
    let t: Vec<&str> = target.split_whitespace().collect();
    let p: Vec<&str> = pred.split_whitespace().collect();
    if t.is_empty() || p.is_empty() {
        return if t.is_empty() && p.is_empty() { 1.0 } else { 0.0 };
    }
    let mut counts: HashMap<&str, usize> = HashMap::new();
    for w in &t {
        *counts.entry(w).or_default() += 1;
    }
    let mut overlap = 0usize;
    for w in &p {
        if let Some(c) = counts.get_mut(w) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let precision = overlap as f64 / p.len() as f64;
    let recall = overlap as f64 / t.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

/// Corpus-level BLEU-4 with brevity penalty (uniform n-gram weights).
pub fn corpus_bleu(pairs: &[(String, String)]) -> f64 {
    let max_n = 4;
    let mut match_counts = vec![0usize; max_n];
    let mut total_counts = vec![0usize; max_n];
    let mut ref_len = 0usize;
    let mut hyp_len = 0usize;
    for (target, pred) in pairs {
        let r: Vec<&str> = target.split_whitespace().collect();
        let h: Vec<&str> = pred.split_whitespace().collect();
        ref_len += r.len();
        hyp_len += h.len();
        for n in 1..=max_n {
            if h.len() < n {
                continue;
            }
            let mut ref_ngrams: HashMap<Vec<&str>, usize> = HashMap::new();
            if r.len() >= n {
                for w in r.windows(n) {
                    *ref_ngrams.entry(w.to_vec()).or_default() += 1;
                }
            }
            for w in h.windows(n) {
                total_counts[n - 1] += 1;
                if let Some(c) = ref_ngrams.get_mut(&w.to_vec()) {
                    if *c > 0 {
                        *c -= 1;
                        match_counts[n - 1] += 1;
                    }
                }
            }
        }
    }
    if hyp_len == 0 || match_counts[0] == 0 {
        return 0.0;
    }
    // NIST-style exponential smoothing: the k-th zero-match precision is
    // replaced by (1/2^k)/total; exact precisions are used otherwise.
    let mut log_precision_sum = 0.0;
    let mut smooth = 1.0f64;
    for n in 0..max_n {
        let p = if total_counts[n] == 0 {
            1.0 // sentence shorter than n: skip via neutral value
        } else if match_counts[n] == 0 {
            smooth /= 2.0;
            smooth / total_counts[n] as f64
        } else {
            match_counts[n] as f64 / total_counts[n] as f64
        };
        log_precision_sum += p.ln();
    }
    let geo_mean = (log_precision_sum / max_n as f64).exp();
    let bp = if hyp_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };
    bp * geo_mean
}

/// Levenshtein distance.
pub fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let cost = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + cost);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Aggregated evaluation over one task.
pub struct EvalResult {
    pub task: String,
    pub num_examples: usize,
    pub metrics: Vec<(String, f64)>,
}

impl EvalResult {
    pub fn get(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
}

/// The seqio Evaluator: applies a task's metric set to decoded predictions.
pub struct Evaluator {
    pub metrics: Vec<Metric>,
}

impl Evaluator {
    pub fn new(metrics: Vec<Metric>) -> Self {
        Self { metrics }
    }

    pub fn evaluate(&self, task: &str, pairs: &[(String, String)]) -> EvalResult {
        EvalResult {
            task: task.to_string(),
            num_examples: pairs.len(),
            metrics: self
                .metrics
                .iter()
                .map(|m| (m.name().to_string(), m.compute(pairs)))
                .collect(),
        }
    }

    /// Iterator-accepting convenience over [`Evaluator::evaluate`] for
    /// streamed prediction sources (e.g. inference-engine completions);
    /// pairs are collected internally before the metric pass.
    pub fn evaluate_stream(
        &self,
        task: &str,
        pairs: impl IntoIterator<Item = (String, String)>,
    ) -> EvalResult {
        let pairs: Vec<(String, String)> = pairs.into_iter().collect();
        self.evaluate(task, &pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pairs(v: &[(&str, &str)]) -> Vec<(String, String)> {
        v.iter().map(|(a, b)| (a.to_string(), b.to_string())).collect()
    }

    #[test]
    fn exact_match() {
        let p = pairs(&[("a b", "a b"), ("c", "d")]);
        assert_eq!(Metric::ExactMatch.compute(&p), 0.5);
    }

    #[test]
    fn token_accuracy() {
        let p = pairs(&[("a b c d", "a x c d")]);
        assert!((Metric::TokenAccuracy.compute(&p) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn bleu_perfect_and_zero() {
        let perfect = pairs(&[("the quick brown fox jumps", "the quick brown fox jumps")]);
        assert!(corpus_bleu(&perfect) > 0.99);
        let bad = pairs(&[("aa bb cc dd ee", "xx yy zz ww vv")]);
        assert!(corpus_bleu(&bad) < 0.01);
        let partial = pairs(&[("the quick brown fox", "the quick red fox")]);
        let b = corpus_bleu(&partial);
        assert!(b > 0.05 && b < 0.9, "bleu={b}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("same", "same"), 0);
        let p = pairs(&[("abcd", "abed")]);
        assert!((Metric::EditSimilarity.compute(&p) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn evaluator_aggregates() {
        let ev = Evaluator::new(vec![Metric::ExactMatch, Metric::TokenAccuracy]);
        let res = ev.evaluate("task_x", &pairs(&[("a", "a"), ("b b", "b c")]));
        assert_eq!(res.num_examples, 2);
        assert_eq!(res.get("exact_match"), Some(0.5));
        assert!(res.get("token_accuracy").unwrap() > 0.5);
        assert!(res.get("bleu").is_none());
    }

    #[test]
    fn evaluate_stream_matches_slice_api() {
        let ev = Evaluator::new(vec![Metric::ExactMatch, Metric::TokenF1]);
        let data = pairs(&[("a b", "a b"), ("c d", "c x")]);
        let from_slice = ev.evaluate("t", &data);
        let from_stream = ev.evaluate_stream("t", data.clone());
        assert_eq!(from_slice.num_examples, from_stream.num_examples);
        assert_eq!(from_slice.metrics, from_stream.metrics);
    }

    #[test]
    fn empty_pairs_safe() {
        for m in [
            Metric::ExactMatch,
            Metric::TokenAccuracy,
            Metric::Bleu,
            Metric::EditSimilarity,
            Metric::RougeN(1),
            Metric::TokenF1,
        ] {
            assert_eq!(m.compute(&[]), 0.0);
        }
    }

    #[test]
    fn rouge_recall_values() {
        assert_eq!(rouge_n_recall("a b c", "a b c", 1), 1.0);
        assert_eq!(rouge_n_recall("a b c", "a b c", 2), 1.0);
        assert!((rouge_n_recall("a b c d", "a b x y", 1) - 0.5).abs() < 1e-12);
        // bigram: "a b" matches, "b c"/"c d" don't
        assert!((rouge_n_recall("a b c d", "a b x y", 2) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(rouge_n_recall("a", "a", 2), 0.0); // too short for bigrams
    }

    #[test]
    fn f1_values() {
        assert_eq!(token_f1("a b c", "a b c"), 1.0);
        assert_eq!(token_f1("a b", "x y"), 0.0);
        // pred "a" vs target "a b": p=1, r=0.5, f1=2/3
        assert!((token_f1("a b", "a") - 2.0 / 3.0).abs() < 1e-12);
        // order-insensitive
        assert_eq!(token_f1("a b c", "c b a"), 1.0);
    }
}
