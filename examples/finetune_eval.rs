//! Finetune + evaluate (E15): the paper's "downstream usage ... must be
//! applied consistently across competing models" workflow.
//!
//! Trains the nano encoder-decoder on a synthetic seq2seq task (reverse
//! the words of a sentence), then runs seqio's Evaluator over greedy
//! decodes: exact match / token accuracy / BLEU, before vs after.
//!
//! ```bash
//! cargo run --release --example finetune_eval -- --steps 150
//! ```

use std::sync::Arc;

use t5x::optim::{OptimizerKind, Schedule};
use t5x::runtime::{Artifacts, DeviceHandle};
use t5x::seqio::evaluation::{Evaluator, Metric};
use t5x::seqio::vocab::Vocabulary;
use t5x::trainer::eval::EvalRunner;
use t5x::trainer::recipes;
use t5x::trainer::{BatchSource, Trainer, TrainerConfig};
use t5x::util::cli::Args;

fn decode_pairs(
    runner: &EvalRunner,
    params: &t5x::model::Params,
    enc: &t5x::runtime::HostTensor,
    targets: &[String],
    vocab: &Arc<dyn Vocabulary>,
) -> anyhow::Result<Vec<(String, String)>> {
    let b = runner.manifest.batch();
    let prompts: Vec<Vec<i32>> = vec![Vec::new(); b];
    let decoded = runner.greedy_decode(params, Some(enc), &prompts, 30, 1)?;
    Ok(targets
        .iter()
        .zip(decoded)
        .map(|(t, ids)| (t.clone(), vocab.decode(&ids)))
        .collect())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 800)? as u64;
    let model = "t5-nano-encdec";

    let arts = Artifacts::load_default()?;
    let device = DeviceHandle::spawn()?;
    let m = arts.model(model)?;
    let vocab = recipes::default_vocab();

    // task + deterministic cache
    let task = recipes::reverse_words_task("reverse_words", 4000, 11);
    let cache_dir = std::env::temp_dir().join("t5x_finetune_reverse");
    let meta = recipes::ensure_cached(&task, &cache_dir, 8, 0)?;
    println!("task 'reverse_words': {} cached examples", meta.num_examples);

    // eval set (held-out seed) + evaluator with the task's metrics
    let eval_task = recipes::reverse_words_task("reverse_words_eval", 64, 999);
    let (enc_batch, targets, inputs) = recipes::decode_eval_set(m, &eval_task, 0);
    let evaluator = Evaluator::new(task.metrics.clone());
    let runner = EvalRunner::new(&arts, &device, model)?;

    let cfg = TrainerConfig {
        model: model.into(),
        mesh: t5x::partitioning::Mesh::new(1, 1),
        strategy: t5x::partitioning::ParamStrategy::OneD,
        optimizer: OptimizerKind::adam(),
        schedule: Schedule::RsqrtWithWarmup { peak: 3e-3, warmup: 20 },
        steps,
        seed: 3,
        log_every: 25,
        checkpoint_every: None,
        checkpoint_dir: None,
        grad_clip_norm: None,
        weight_decay: None,
        exec_mode: t5x::partitioning::ExecMode::Auto,
        trace_out: None,
        profile_steps: None,
    };
    let trainer = Trainer::new(&arts, &device, cfg)?
        .with_logger(t5x::metrics::MetricsLogger::new().with_terminal());

    // before-finetuning metrics
    let before_pairs =
        decode_pairs(&runner, &trainer.params(), &enc_batch[0], &targets, &vocab)?;
    let before = evaluator.evaluate("reverse_words", &before_pairs);
    println!("\nbefore finetuning:");
    for (name, v) in &before.metrics {
        println!("  {name}: {v:.4}");
    }

    // finetune
    let infeed = recipes::cached_infeed(m, &cache_dir, 1, 0, None)?;
    let summary = trainer.train(&BatchSource::Infeed(infeed))?;
    println!(
        "\nfinetuned {} steps: loss {:.3} -> {:.3}",
        summary.history.len(),
        summary.first_loss(),
        summary.final_loss()
    );

    // after-finetuning metrics
    let after_pairs =
        decode_pairs(&runner, &trainer.params(), &enc_batch[0], &targets, &vocab)?;
    let after = evaluator.evaluate("reverse_words", &after_pairs);
    println!("\nafter finetuning:");
    for (name, v) in &after.metrics {
        println!("  {name}: {v:.4}");
    }
    println!("\nsample decodes (input => prediction | target):");
    for i in 0..3.min(after_pairs.len()) {
        println!("  '{}' => '{}' | '{}'", inputs[i], after_pairs[i].1, after_pairs[i].0);
    }

    // Gate on edit similarity: byte-level word reversal needs many steps
    // before whole words match, but the decode gets monotonically closer.
    let sim_before = Metric::EditSimilarity.compute(&before_pairs);
    let sim_after = Metric::EditSimilarity.compute(&after_pairs);
    println!("\nedit similarity: {sim_before:.3} -> {sim_after:.3}");
    println!(
        "token accuracy: {:.3} -> {:.3}",
        before.get("token_accuracy").unwrap_or(0.0),
        after.get("token_accuracy").unwrap_or(0.0)
    );
    assert!(
        sim_after > sim_before + 0.05,
        "finetuning should substantially improve edit similarity"
    );
    println!("finetune_eval OK");
    device.shutdown();
    Ok(())
}
