//! Integration: the unified DatasetProvider surface (paper §3.1) — one
//! `seqio::get_dataset` entry point behind which live Tasks, Mixtures and
//! cached deterministic pipelines (§3.2) are interchangeable, resolved
//! from a single registry namespace.

use std::sync::Arc;

use t5x::seqio::cache::{cache_task, CacheConfig};
use t5x::seqio::feature_converters::{
    converter_for_arch, default_task_lengths, FeatureConverter,
};
use t5x::seqio::mixture::Mixture;
use t5x::seqio::provider::{
    get_dataset, CachedTask, DatasetProvider, GetDatasetOptions, ProviderRegistry,
    RegistryEntry, ShardInfo,
};
use t5x::seqio::source::TextLineSource;
use t5x::seqio::task::{Task, TaskRegistry};
use t5x::seqio::vocab::{ByteVocabulary, Vocabulary};
use t5x::seqio::{serialize_example, Example};
use t5x::trainer::recipes;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("provider_int_{}_{tag}", std::process::id()))
}

/// Converted (model-ready) options for the enc-dec arch at length 64.
fn encdec_opts() -> GetDatasetOptions {
    let conv = converter_for_arch("encdec");
    GetDatasetOptions {
        task_feature_lengths: default_task_lengths(conv.as_ref(), 64),
        converter: Some(conv.name().to_string()),
        seed: 3,
        ..Default::default()
    }
}

fn sorted_bytes(exs: &[Example]) -> Vec<Vec<u8>> {
    let mut v: Vec<Vec<u8>> = exs.iter().map(serialize_example).collect();
    v.sort();
    v
}

#[test]
fn cached_task_equals_live_task_through_get_dataset() {
    // §3.2 + §3.1 together: the SAME get_dataset call yields the same
    // model-ready examples whether the name resolves to the live task or
    // to its offline cache (which additionally fixes a global order).
    let task = recipes::span_corruption_task("prov_live_vs_cached", 48, 64, 7);
    let dir = tmpdir("live_vs_cached");
    cache_task(&task, &dir, &CacheConfig { num_shards: 8, seed: 3, workers: 2 }).unwrap();
    let cached = Arc::new(CachedTask::open(&dir, Some(&task)).unwrap());

    let opts = encdec_opts();
    let live = get_dataset(task.clone(), &opts).unwrap().collect_vec();
    let from_cache = get_dataset(cached.clone(), &opts).unwrap().collect_vec();
    assert!(!live.is_empty());
    assert_eq!(live.len(), from_cache.len());
    // identical multiset of converted examples (the cache globally
    // shuffles, so the order differs by design)
    assert_eq!(sorted_bytes(&live), sorted_bytes(&from_cache));

    // byte-identical across repeated identical calls, for both kinds
    let live2 = get_dataset(task.clone(), &opts).unwrap().collect_vec();
    let from_cache2 = get_dataset(cached.clone(), &opts).unwrap().collect_vec();
    assert_eq!(live, live2);
    assert_eq!(from_cache, from_cache2);

    // raw (unconverted) cached access preserves §3.2 index order
    let raw = get_dataset(cached, &GetDatasetOptions { seed: 3, ..Default::default() })
        .unwrap()
        .collect_vec();
    let indices: Vec<i32> = raw.iter().map(|e| e["_index"].as_ints().unwrap()[0]).collect();
    assert_eq!(indices, (0..raw.len() as i32).collect::<Vec<_>>());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn get_dataset_resume_matches_uninterrupted_stream() {
    // Exact resume-mid-split through get_dataset(.., resume): snapshot
    // the stream, rebuild via the same call, restore, continue — the
    // joined stream equals the uninterrupted one, for a live task, a
    // mixture, and a cached (repeating) provider.
    let task = recipes::span_corruption_task("prov_resume_live", 40, 64, 11);
    let opts = encdec_opts();

    let all = get_dataset(task.clone(), &opts).unwrap().collect_vec();
    for cut in [0usize, 1, 9, 25] {
        let mut first = get_dataset(task.clone(), &opts).unwrap();
        let head: Vec<Example> = (&mut first).take(cut).collect();
        let snap = first.state();
        let resumed_opts = GetDatasetOptions { resume: Some(snap), ..opts.clone() };
        let tail = get_dataset(task.clone(), &resumed_opts).unwrap().collect_vec();
        let mut joined = head;
        joined.extend(tail);
        assert_eq!(joined, all, "live cut={cut}");
    }

    // mixture provider resumes mid-draw
    let t1 = recipes::span_corruption_task("prov_resume_mix_a", 20, 64, 1);
    let t2 = recipes::span_corruption_task("prov_resume_mix_b", 30, 64, 2);
    let mix = Arc::new(Mixture::new("prov_resume_mix", vec![(t1, 0.5), (t2, 0.5)]).unwrap());
    let mix_all = get_dataset(mix.clone(), &opts).unwrap().collect_vec();
    let mut first = get_dataset(mix.clone(), &opts).unwrap();
    let head: Vec<Example> = (&mut first).take(13).collect();
    let snap = first.state();
    let tail = get_dataset(mix, &GetDatasetOptions { resume: Some(snap), ..opts.clone() })
        .unwrap()
        .collect_vec();
    let mut joined = head;
    joined.extend(tail);
    assert_eq!(joined, mix_all);

    // cached provider, repeating stream: resume across the epoch boundary
    let dir = tmpdir("resume_cached");
    cache_task(&task, &dir, &CacheConfig { num_shards: 4, seed: 3, workers: 2 }).unwrap();
    let cached = Arc::new(CachedTask::open(&dir, Some(&task)).unwrap());
    let rep_opts = GetDatasetOptions { repeat: true, ..opts.clone() };
    let n = cached.num_examples();
    let reference: Vec<Example> =
        (&mut get_dataset(cached.clone(), &rep_opts).unwrap()).take(n + 10).collect();
    let mut first = get_dataset(cached.clone(), &rep_opts).unwrap();
    let head: Vec<Example> = (&mut first).take(n + 3).collect();
    let snap = first.state();
    let mut resumed =
        get_dataset(cached, &GetDatasetOptions { resume: Some(snap), ..rep_opts.clone() })
            .unwrap();
    let tail: Vec<Example> = (&mut resumed).take(7).collect();
    let mut joined = head;
    joined.extend(tail);
    assert_eq!(joined, reference);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn duplicate_registration_is_an_error() {
    let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(4));
    let task = Task::builder("prov_dup_name")
        .source(Arc::new(t5x::seqio::source::SyntheticTextSource::new(1, 4)))
        .output_feature("text", vocab, false)
        .build();
    TaskRegistry::add(task.clone()).unwrap();
    // a second task under the same name
    let err = TaskRegistry::add(task.clone()).unwrap_err().to_string();
    assert!(err.contains("prov_dup_name"), "{err}");
    // ...and a mixture under the same name: one namespace, same error
    let mix = Mixture::new("prov_dup_name", vec![(task, 1.0)]).unwrap();
    assert!(mix.register().is_err());
    ProviderRegistry::remove("prov_dup_name");
    assert!(ProviderRegistry::get("prov_dup_name").is_none());
}

#[test]
fn splits_are_isolated_for_sharded_sources() {
    // train vs validation come from distinct file sets; shards within a
    // split partition it, and no example crosses splits.
    let dir = tmpdir("splits");
    std::fs::create_dir_all(&dir).unwrap();
    let train_path = dir.join("train.txt");
    let val_path = dir.join("val.txt");
    std::fs::write(&train_path, (0..12).map(|i| format!("t{i}\n")).collect::<String>()).unwrap();
    std::fs::write(&val_path, (0..5).map(|i| format!("v{i}\n")).collect::<String>()).unwrap();
    let vocab: Arc<dyn Vocabulary> = Arc::new(ByteVocabulary::new(4));
    let task = Task::builder("prov_split_isolation")
        .source(Arc::new(TextLineSource::new(vec![train_path])))
        .split_source("validation", Arc::new(TextLineSource::new(vec![val_path])))
        .output_feature("text", vocab, true)
        .build();
    let p: Arc<dyn DatasetProvider> = task;
    assert_eq!(p.splits(), vec!["train".to_string(), "validation".to_string()]);

    let text = |exs: &[Example]| -> Vec<String> {
        exs.iter().map(|e| e["text"].as_text().unwrap().to_string()).collect()
    };
    let mut train_all = Vec::new();
    for shard in 0..2 {
        let opts = GetDatasetOptions {
            shard: ShardInfo::new(shard, 2),
            ..Default::default()
        };
        train_all.extend(text(&get_dataset(p.clone(), &opts).unwrap().collect_vec()));
    }
    let val_opts = GetDatasetOptions { split: "validation".into(), ..Default::default() };
    let val = text(&get_dataset(p.clone(), &val_opts).unwrap().collect_vec());

    // shards partition the train split exactly
    let mut sorted = train_all.clone();
    sorted.sort();
    let mut expect: Vec<String> = (0..12).map(|i| format!("t{i}")).collect();
    expect.sort();
    assert_eq!(sorted, expect);
    // splits are disjoint
    assert_eq!(val, (0..5).map(|i| format!("v{i}")).collect::<Vec<_>>());
    assert!(train_all.iter().all(|t| !val.contains(t)));
    // unknown split fails loudly
    let bad = GetDatasetOptions { split: "test".into(), ..Default::default() };
    assert!(get_dataset(p, &bad).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn registry_entries_expose_kind_and_provider() {
    recipes::register_defaults();
    let entry = ProviderRegistry::get("c4_span_rev_mix").unwrap();
    assert_eq!(entry.kind(), "mixture");
    assert!(entry.as_task().is_none());
    let p = entry.provider();
    // the mixture serves the intersection of member splits
    assert!(p.splits().contains(&"train".to_string()));
    // and its stream can be built through get_dataset by name
    let opts = GetDatasetOptions { seed: 1, ..encdec_opts() };
    let head: Vec<Example> =
        (&mut get_dataset("c4_span_rev_mix", &opts).unwrap()).take(5).collect();
    assert_eq!(head.len(), 5);
    // cached entries can be registered under the unified namespace too
    let task = recipes::span_corruption_task("prov_reg_cached", 24, 64, 5);
    let dir = tmpdir("reg_cached");
    cache_task(&task, &dir, &CacheConfig { num_shards: 4, seed: 1, workers: 2 }).unwrap();
    let cached = Arc::new(CachedTask::open(&dir, Some(&task)).unwrap());
    ProviderRegistry::add(RegistryEntry::Cached(cached)).unwrap();
    let got = get_dataset(
        "prov_reg_cached",
        &GetDatasetOptions { seed: 1, ..encdec_opts() },
    )
    .unwrap()
    .collect_vec();
    assert!(!got.is_empty());
    ProviderRegistry::remove("prov_reg_cached");
    std::fs::remove_dir_all(&dir).ok();
}
