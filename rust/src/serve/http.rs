//! Stdlib-only HTTP/1.1 front end over the [`Gateway`].
//!
//! Routes:
//!
//! * `POST /v1/generate` — body is the same JSON schema as the JSONL
//!   transport (`prompt` required; `id`, `max_tokens`, `method`,
//!   `temperature`/`top_k`/`top_p`/`seed`, `priority`, `deadline_ms`
//!   optional). `200` carries `tokens`/`text`/`steps`/`replica`/
//!   `queue_ms`/`ttft_ms`/`latency_ms`. Admission rejections map to
//!   status codes: queue full / low-priority shed → `429` with
//!   `Retry-After`, draining → `503`, invalid request → `400`, deadline
//!   expired in queue → `504`.
//! * `GET /healthz` — liveness + replica count + queue depth.
//! * `GET /metrics` — gateway counters, histogram percentiles, queue
//!   state, per-replica utilization (JSON; see
//!   [`Gateway::metrics_json`]).
//! * `POST /admin/drain` — stop admission and begin graceful shutdown
//!   (same path as SIGINT).
//!
//! Mechanics: one nonblocking accept loop feeds a fixed pool of worker
//! threads over a channel; each worker speaks HTTP/1.1 with keep-alive
//! on its connection and blocks on the gateway outcome channel while its
//! request decodes. Concurrency is bounded by the pool size — a slow
//! client can hold one worker, never the engine.
//!
//! Hardening: a request body larger than
//! [`HttpConfig::max_body_bytes`] is rejected with `413` *before* the
//! buffer is allocated (the declared `Content-Length` is checked, so a
//! hostile header cannot trigger a huge allocation), and the whole
//! header+body read is bounded by [`HttpConfig::read_deadline_ms`] —
//! a slowloris client trickling one byte per second loses its worker
//! after the deadline, not never.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::AdmitError;
use super::router::Gateway;
use super::{ServeOutcome, ShedReason};
use crate::infer::server::{outcome_to_json, parse_request};
use crate::seqio::vocab::{ByteVocabulary, Vocabulary};
use crate::util::json::Json;

/// Auto-assigned ids for bodies without `"id"` (process-global so two
/// anonymous HTTP clients never collide).
static NEXT_HTTP_ID: AtomicU64 = AtomicU64::new(1_000_000);

/// Front-end knobs (`serve.http_port` etc. in gin, `--http-*` CLI flags).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    pub addr: String,
    /// 0 binds an ephemeral port (tests); read it back via
    /// [`HttpServer::port`].
    pub port: u16,
    /// Worker-thread pool size (max concurrently-served connections).
    pub threads: usize,
    /// `max_tokens` when the body doesn't set one.
    pub default_max_tokens: usize,
    /// Largest accepted request body; a bigger declared `Content-Length`
    /// gets `413 Payload Too Large` without allocating the buffer.
    pub max_body_bytes: usize,
    /// Wall-clock budget for reading one request (headers + body). A
    /// client that trickles bytes slower than this loses the connection
    /// (slowloris defense); the per-read socket timeout alone does not
    /// bound the total, only each gap.
    pub read_deadline_ms: u64,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1".to_string(),
            port: 0,
            threads: 8,
            default_max_tokens: 16,
            max_body_bytes: 1 << 20,
            read_deadline_ms: 10_000,
        }
    }
}

/// A running HTTP front end; dropping it does NOT stop it — set the
/// shared `stop` flag (or POST `/admin/drain`) and call
/// [`HttpServer::join`].
pub struct HttpServer {
    port: u16,
    accept: JoinHandle<()>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and start serving. The accept loop polls `stop` (~25 ms) and
    /// exits once it's set; workers drain queued connections, then exit.
    pub fn start(
        gateway: Arc<Gateway>,
        cfg: HttpConfig,
        stop: Arc<AtomicBool>,
    ) -> anyhow::Result<HttpServer> {
        let listener = TcpListener::bind((cfg.addr.as_str(), cfg.port))
            .map_err(|e| anyhow::anyhow!("binding {}:{}: {e}", cfg.addr, cfg.port))?;
        let port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut workers = Vec::new();
        for w in 0..cfg.threads.max(1) {
            let rx = conn_rx.clone();
            let gw = gateway.clone();
            let stopc = stop.clone();
            let max_tokens = cfg.default_max_tokens;
            let limits = ReadLimits {
                max_body_bytes: cfg.max_body_bytes,
                deadline: Duration::from_millis(cfg.read_deadline_ms.max(1)),
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("http-worker{w}"))
                    .spawn(move || loop {
                        // Holding the lock only for the recv keeps the
                        // other workers runnable.
                        let conn = rx.lock().unwrap().recv();
                        match conn {
                            Ok(stream) => handle_connection(
                                &gw, stream, max_tokens, limits, &stopc,
                            ),
                            Err(_) => break, // accept loop gone
                        }
                    })?,
            );
        }
        let accept = std::thread::Builder::new().name("http-accept".into()).spawn(
            move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if conn_tx.send(stream).is_err() {
                                break;
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(25));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(25)),
                    }
                }
                // Dropping conn_tx here unblocks every idle worker.
            },
        )?;
        Ok(HttpServer { port, accept, workers })
    }

    /// The bound port (differs from the config's when it asked for 0).
    pub fn port(&self) -> u16 {
        self.port
    }

    /// Join the accept loop and worker pool (call after setting the stop
    /// flag; in-flight connections finish first).
    pub fn join(self) {
        let _ = self.accept.join();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

struct Request {
    method: String,
    path: String,
    keep_alive: bool,
    body: Vec<u8>,
}

/// Per-request read budgets (see [`HttpConfig::max_body_bytes`] /
/// [`HttpConfig::read_deadline_ms`]).
#[derive(Debug, Clone, Copy)]
struct ReadLimits {
    max_body_bytes: usize,
    deadline: Duration,
}

/// What [`read_request`] produced: a complete request, or a request whose
/// declared body exceeds the cap (headers consumed, body deliberately
/// unread — the caller answers `413` and closes).
enum ReadRequest {
    Complete(Request),
    TooLarge { content_length: usize },
}

/// Read one HTTP/1.1 request; `Ok(None)` on clean EOF (client closed a
/// keep-alive connection between requests).
///
/// The whole read — request line, headers, body — must finish before
/// `limits.deadline` elapses; the body is pulled in socket-sized chunks
/// with the deadline rechecked between reads, so a slow-trickle client
/// cannot pin a worker past the budget. An oversized declared
/// `Content-Length` returns [`ReadRequest::TooLarge`] *before* any body
/// buffer is allocated.
fn read_request(
    reader: &mut BufReader<TcpStream>,
    limits: ReadLimits,
) -> std::io::Result<Option<ReadRequest>> {
    let deadline = Instant::now() + limits.deadline;
    let timed_out = || {
        std::io::Error::new(
            std::io::ErrorKind::TimedOut,
            "request read exceeded deadline",
        )
    };
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let path = parts.next().unwrap_or("/").to_string();
    let mut content_length = 0usize;
    let mut keep_alive = true; // HTTP/1.1 default
    loop {
        if Instant::now() >= deadline {
            return Err(timed_out());
        }
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            return Ok(None);
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let v = v.trim();
            match k.to_ascii_lowercase().as_str() {
                "content-length" => content_length = v.parse().unwrap_or(0),
                "connection" => keep_alive = !v.eq_ignore_ascii_case("close"),
                _ => {}
            }
        }
    }
    if content_length > limits.max_body_bytes {
        return Ok(Some(ReadRequest::TooLarge { content_length }));
    }
    let mut body = Vec::with_capacity(content_length);
    let mut chunk = [0u8; 8192];
    while body.len() < content_length {
        if Instant::now() >= deadline {
            return Err(timed_out());
        }
        let want = (content_length - body.len()).min(chunk.len());
        let n = reader.read(&mut chunk[..want])?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed mid-body",
            ));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    Ok(Some(ReadRequest::Complete(Request { method, path, keep_alive, body })))
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    extra_headers: &[(&str, String)],
    body: &Json,
    keep_alive: bool,
) -> std::io::Result<()> {
    let body = format!("{body}\n");
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {}\r\n",
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

fn err_body(msg: impl Into<String>) -> Json {
    Json::obj(vec![("error", Json::str(msg.into()))])
}

/// Serve requests on one connection until it closes (keep-alive loop).
fn handle_connection(
    gw: &Arc<Gateway>,
    stream: TcpStream,
    default_max_tokens: usize,
    limits: ReadLimits,
    stop: &Arc<AtomicBool>,
) {
    // Bound each individual read so an idle keep-alive connection frees
    // its worker; read_request additionally bounds the *total* per-request
    // read time. Blocking on a decode outcome is not affected.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut stream = stream;
    loop {
        let req = match read_request(&mut reader, limits) {
            Ok(Some(ReadRequest::Complete(r))) => r,
            Ok(Some(ReadRequest::TooLarge { content_length })) => {
                // The body was never read, so the connection cannot be
                // reused for a next request: answer and close.
                let _ = write_response(
                    &mut stream,
                    413,
                    "Payload Too Large",
                    &[],
                    &err_body(format!(
                        "body of {content_length} bytes exceeds limit of {} bytes",
                        limits.max_body_bytes
                    )),
                    false,
                );
                return;
            }
            Ok(None) | Err(_) => return, // EOF / timeout / bad peer
        };
        let mut keep = req.keep_alive && !stop.load(Ordering::Relaxed);
        let res = match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/v1/generate") => handle_generate(gw, &req.body, default_max_tokens),
            ("GET", "/healthz") => (200, "OK", Vec::new(), gw.healthz_json()),
            ("GET", "/metrics") => (200, "OK", Vec::new(), gw.metrics_json()),
            ("POST", "/admin/drain") => {
                stop.store(true, Ordering::Relaxed);
                gw.drain();
                keep = false;
                (200, "OK", Vec::new(), Json::obj(vec![("status", Json::str("draining"))]))
            }
            (_, path) => {
                (404, "Not Found", Vec::new(), err_body(format!("no route for {path}")))
            }
        };
        let (status, reason, headers, body) = res;
        if write_response(&mut stream, status, reason, &headers, &body, keep).is_err() {
            return;
        }
        if !keep {
            return;
        }
    }
}

type Response = (u16, &'static str, Vec<(&'static str, String)>, Json);

/// `POST /v1/generate`: parse, submit, block for the outcome, map it to
/// a status code + JSON body.
fn handle_generate(gw: &Arc<Gateway>, body: &[u8], default_max_tokens: usize) -> Response {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return (400, "Bad Request", Vec::new(), err_body("body is not UTF-8")),
    };
    let auto_id = NEXT_HTTP_ID.fetch_add(1, Ordering::Relaxed);
    let (req, opts) = match parse_request(text, auto_id, default_max_tokens) {
        Ok(p) => p,
        Err(e) => return (400, "Bad Request", Vec::new(), err_body(format!("{e:#}"))),
    };
    let (tx, rx) = mpsc::channel();
    if let Err(e) = gw.submit(req, opts, tx) {
        return match e {
            AdmitError::QueueFull { retry_after_secs, .. }
            | AdmitError::ShedLowPriority { retry_after_secs, .. } => (
                429,
                "Too Many Requests",
                vec![("Retry-After", retry_after_secs.to_string())],
                err_body(e.to_string()),
            ),
            AdmitError::Draining => {
                (503, "Service Unavailable", Vec::new(), err_body(e.to_string()))
            }
            AdmitError::Invalid(_) => {
                (400, "Bad Request", Vec::new(), err_body(e.to_string()))
            }
        };
    }
    // Exactly one outcome per admitted request (a dead gateway drops the
    // sender, surfacing as RecvError → 500 instead of a hang).
    let outcome = match rx.recv() {
        Ok(o) => o,
        Err(_) => {
            return (
                500,
                "Internal Server Error",
                Vec::new(),
                err_body("gateway dropped the request"),
            )
        }
    };
    match &outcome {
        ServeOutcome::Done { result, .. } => {
            let mut json = outcome_to_json(&outcome);
            if let Json::Obj(pairs) = &mut json {
                let vocab = ByteVocabulary::new(0);
                pairs.push(("text".to_string(), Json::str(vocab.decode(&result.tokens))));
            }
            (200, "OK", Vec::new(), json)
        }
        ServeOutcome::Shed { reason, .. } => {
            let body = outcome_to_json(&outcome);
            match reason {
                ShedReason::DeadlineExpired => (504, "Gateway Timeout", Vec::new(), body),
                ShedReason::Draining => (503, "Service Unavailable", Vec::new(), body),
            }
        }
        ServeOutcome::Failed { .. } => {
            (500, "Internal Server Error", Vec::new(), outcome_to_json(&outcome))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_limits() -> ReadLimits {
        ReadLimits { max_body_bytes: 1 << 20, deadline: Duration::from_secs(5) }
    }

    fn loopback() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn http_config_defaults() {
        let c = HttpConfig::default();
        assert_eq!(c.addr, "127.0.0.1");
        assert_eq!(c.port, 0);
        assert!(c.threads >= 1);
        assert!(c.max_body_bytes >= 1 << 16);
        assert!(c.read_deadline_ms >= 1000);
    }

    #[test]
    fn request_parsing_reads_headers_and_body() {
        // Loopback socket pair: write a raw request, read it back through
        // read_request.
        let (mut client, server) = loopback();
        client
            .write_all(
                b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 15\r\n\
                  Connection: close\r\n\r\n{\"prompt\": [5]}",
            )
            .unwrap();
        client.flush().unwrap();
        let mut reader = BufReader::new(server);
        let req = match read_request(&mut reader, test_limits()).unwrap().unwrap() {
            ReadRequest::Complete(r) => r,
            ReadRequest::TooLarge { .. } => panic!("unexpected TooLarge"),
        };
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert!(!req.keep_alive);
        assert_eq!(req.body, b"{\"prompt\": [5]}");
    }

    #[test]
    fn oversized_content_length_is_rejected_without_allocation() {
        let (mut client, server) = loopback();
        // Declares a 100 TB body; if read_request allocated it up front
        // this test would OOM instead of returning TooLarge.
        client
            .write_all(
                b"POST /v1/generate HTTP/1.1\r\nHost: x\r\n\
                  Content-Length: 109951162777600\r\n\r\n",
            )
            .unwrap();
        client.flush().unwrap();
        let mut reader = BufReader::new(server);
        match read_request(&mut reader, test_limits()).unwrap().unwrap() {
            ReadRequest::TooLarge { content_length } => {
                assert_eq!(content_length, 109_951_162_777_600);
            }
            ReadRequest::Complete(_) => panic!("expected TooLarge"),
        }
    }

    #[test]
    fn slow_trickle_body_trips_the_read_deadline() {
        let (mut client, server) = loopback();
        // Keep each gap under the 100 ms socket timeout so only the
        // overall deadline can end the read.
        server.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        client
            .write_all(
                b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 1000\r\n\r\n",
            )
            .unwrap();
        client.flush().unwrap();
        let writer = std::thread::spawn(move || {
            // Trickle one byte every 40 ms: at this rate the full body
            // would take 40 s — the 300 ms deadline must cut it off.
            for _ in 0..50 {
                if client.write_all(b"x").is_err() {
                    return;
                }
                let _ = client.flush();
                std::thread::sleep(Duration::from_millis(40));
            }
        });
        let mut reader = BufReader::new(server);
        let limits =
            ReadLimits { max_body_bytes: 1 << 20, deadline: Duration::from_millis(300) };
        let started = Instant::now();
        let err = read_request(&mut reader, limits).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "deadline did not bound the read: {:?}",
            started.elapsed()
        );
        drop(reader); // close server half so the writer unblocks
        writer.join().unwrap();
    }

    #[test]
    fn response_writing_is_parseable() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        write_response(
            &mut server,
            429,
            "Too Many Requests",
            &[("Retry-After", "1".to_string())],
            &err_body("full"),
            false,
        )
        .unwrap();
        drop(server);
        let mut text = String::new();
        BufReader::new(client).read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        let body = text.split("\r\n\r\n").nth(1).unwrap();
        let v = Json::parse(body.trim()).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("full"));
    }
}
