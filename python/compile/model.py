"""L2: pure-JAX T5-style transformer (encoder-decoder and decoder-only).

This is the "Minimal"-style model of the paper's §4 rewritten without Flax
(flax is unavailable in this image): parameters are a flat
``dict[name, jnp.ndarray]`` and every parameter carries *logical axis names*
(the t5x `param_with_axes` mechanism) in ``param_specs`` — the Rust L3
partitioner consumes those names through the artifact manifest to decide
model/data sharding, exactly as t5x maps logical axes to mesh axes.

Architecture (T5.1.1 flavour):
  * RMSNorm (T5 LayerNorm: no mean subtraction, no bias), pre-norm residuals
  * multi-head attention without biases, flash-attention Pallas kernel (L1)
  * bucketed relative position biases, shared across layers per stack
  * gated-GeLU MLP (wi_0/wi_1/wo), fused Pallas kernel (L1)
  * shared input/output embedding (logits = h @ embed^T / sqrt(d_model))
  * cross-entropy loss with z-loss regularizer (t5x default 1e-4)

Deviations from T5 (documented in DESIGN.md): attention logits are scaled by
1/sqrt(head_dim) (T5 folds this into Adafactor init); embeddings are always
shared.

``use_pallas=False`` swaps both kernels for the jnp oracles in
``kernels/ref.py`` — tests assert the two lowerings agree numerically.
"""

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.attention import flash_attention
from .kernels.fused_ffn import fused_ffn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + export-shape configuration."""

    name: str
    arch: str  # "decoder" | "encdec"
    num_layers: int
    d_model: int
    num_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    batch: int
    seq_len: int  # decoder length; encoder length is also seq_len
    relpos_buckets: int = 32
    relpos_max_distance: int = 128
    z_loss: float = 1e-4
    use_pallas: bool = True
    # L1 tile sizes (clamped to divisors inside the kernels).
    block_q: int = 64
    block_k: int = 64
    block_m: int = 128
    block_f: int = 128

    @property
    def joined_kv(self) -> int:
        return self.num_heads * self.head_dim


# ---------------------------------------------------------------------------
# Parameter inventory: (name, shape, logical_axes, init_spec)
# ---------------------------------------------------------------------------


def _layer_specs(prefix: str, cfg: ModelConfig, cross_attention: bool):
    d, jkv, ff = cfg.d_model, cfg.joined_kv, cfg.d_ff
    att = lambda p: [
        (f"{p}.wq", (d, jkv), ("embed", "joined_kv"), f"normal:{d ** -0.5:.8g}"),
        (f"{p}.wk", (d, jkv), ("embed", "joined_kv"), f"normal:{d ** -0.5:.8g}"),
        (f"{p}.wv", (d, jkv), ("embed", "joined_kv"), f"normal:{d ** -0.5:.8g}"),
        (f"{p}.wo", (jkv, d), ("joined_kv", "embed"), f"normal:{jkv ** -0.5:.8g}"),
    ]
    specs = [
        (f"{prefix}.pre_attn_norm.scale", (d,), ("embed",), "const:1"),
        *att(f"{prefix}.self_attn"),
    ]
    if cross_attention:
        specs += [
            (f"{prefix}.pre_cross_norm.scale", (d,), ("embed",), "const:1"),
            *att(f"{prefix}.cross_attn"),
        ]
    specs += [
        (f"{prefix}.pre_mlp_norm.scale", (d,), ("embed",), "const:1"),
        (f"{prefix}.mlp.wi_0", (d, ff), ("embed", "mlp"), f"normal:{d ** -0.5:.8g}"),
        (f"{prefix}.mlp.wi_1", (d, ff), ("embed", "mlp"), f"normal:{d ** -0.5:.8g}"),
        (f"{prefix}.mlp.wo", (ff, d), ("mlp", "embed"), f"normal:{ff ** -0.5:.8g}"),
    ]
    return specs


def param_specs(cfg: ModelConfig) -> List[Tuple[str, tuple, tuple, str]]:
    """Full parameter inventory in manifest (sorted) order."""
    specs = [
        ("token_embed", (cfg.vocab, cfg.d_model), ("vocab", "embed"), "normal:1"),
    ]
    if cfg.arch == "encdec":
        specs.append(
            (
                "encoder.relpos_bias",
                (cfg.relpos_buckets, cfg.num_heads),
                ("relpos_buckets", "heads"),
                f"normal:{cfg.d_model ** -0.5:.8g}",
            )
        )
        for i in range(cfg.num_layers):
            specs += _layer_specs(f"encoder.layers_{i}", cfg, cross_attention=False)
        specs.append(("encoder.final_norm.scale", (cfg.d_model,), ("embed",), "const:1"))
    specs.append(
        (
            "decoder.relpos_bias",
            (cfg.relpos_buckets, cfg.num_heads),
            ("relpos_buckets", "heads"),
            f"normal:{cfg.d_model ** -0.5:.8g}",
        )
    )
    for i in range(cfg.num_layers):
        specs += _layer_specs(
            f"decoder.layers_{i}", cfg, cross_attention=(cfg.arch == "encdec")
        )
    specs.append(("decoder.final_norm.scale", (cfg.d_model,), ("embed",), "const:1"))
    specs.sort(key=lambda s: s[0])
    return specs


# ---------------------------------------------------------------------------
# Deterministic "pattern" init shared bit-exactly with Rust (golden tests)
# ---------------------------------------------------------------------------

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def fnv1a64(name: str) -> int:
    h = _FNV_OFFSET
    for byte in name.encode("utf-8"):
        h = ((h ^ byte) * _FNV_PRIME) & _MASK64
    return h


def splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def pattern_init(name: str, shape: tuple, scale: float, seed: int = 0) -> np.ndarray:
    """Deterministic pseudo-random init computable identically in Rust.

    value[i] = (2*u - 1) * scale with u = splitmix64(fnv1a64(name)^seed ^ (i+1))
    mapped to [0, 1) via the top 53 bits.
    """
    base = fnv1a64(name) ^ seed
    n = int(np.prod(shape)) if shape else 1
    out = np.empty(n, np.float64)
    for i in range(n):
        u = splitmix64((base ^ (i + 1)) & _MASK64) >> 11
        out[i] = u * (2.0**-53)
    return ((2.0 * out - 1.0) * scale).astype(np.float32).reshape(shape)


def pattern_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    params = {}
    for name, shape, _, init in param_specs(cfg):
        kind, _, arg = init.partition(":")
        if kind == "const":
            params[name] = jnp.full(shape, float(arg), jnp.float32)
        else:
            params[name] = jnp.asarray(pattern_init(name, shape, 0.05, seed))
    return params


def random_params(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """jax.random init following the manifest init specs (python tests only)."""
    params = {}
    for name, shape, _, init in param_specs(cfg):
        kind, _, arg = init.partition(":")
        if kind == "const":
            params[name] = jnp.full(shape, float(arg), jnp.float32)
        else:
            key, sub = jax.random.split(key)
            params[name] = jax.random.normal(sub, shape, jnp.float32) * float(arg)
    return params


# ---------------------------------------------------------------------------
# Model forward
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def relative_position_bucket(relpos, bidirectional, num_buckets, max_distance):
    """T5 relative position bucketing (Raffel et al. 2020, Appendix)."""
    ret = 0
    n = -relpos
    if bidirectional:
        num_buckets //= 2
        ret += (n < 0).astype(jnp.int32) * num_buckets
        n = jnp.abs(n)
    else:
        n = jnp.maximum(n, 0)
    max_exact = num_buckets // 2
    is_small = n < max_exact
    val_if_large = max_exact + (
        jnp.log(n.astype(jnp.float32) / max_exact + 1e-6)
        / np.log(max_distance / max_exact)
        * (num_buckets - max_exact)
    ).astype(jnp.int32)
    val_if_large = jnp.minimum(val_if_large, num_buckets - 1)
    return ret + jnp.where(is_small, n, val_if_large)


def relpos_bias(rel_embedding, lq, lk, bidirectional, cfg: ModelConfig):
    """[H, Lq, Lk] additive attention bias from the bucket embedding table."""
    ctx = jnp.arange(lq)[:, None]
    mem = jnp.arange(lk)[None, :]
    buckets = relative_position_bucket(
        mem - ctx, bidirectional, cfg.relpos_buckets, cfg.relpos_max_distance
    )  # [Lq, Lk]
    values = rel_embedding[buckets]  # [Lq, Lk, H]
    return jnp.transpose(values, (2, 0, 1))


def _attention_kv(p, prefix, x_q, x_kv, bias, causal, cfg: ModelConfig):
    """Attention block that also returns the per-head K/V projections
    ([B, H, Lk, head_dim]) — the tensors `prefill` exports as the KV cache."""
    b, lq, d = x_q.shape
    lk = x_kv.shape[1]
    h, hd = cfg.num_heads, cfg.head_dim
    q = (x_q @ p[f"{prefix}.wq"]).reshape(b, lq, h, hd).transpose(0, 2, 1, 3)
    k = (x_kv @ p[f"{prefix}.wk"]).reshape(b, lk, h, hd).transpose(0, 2, 1, 3)
    v = (x_kv @ p[f"{prefix}.wv"]).reshape(b, lk, h, hd).transpose(0, 2, 1, 3)
    if bias is None:
        bias = jnp.zeros((h, lq, lk), x_q.dtype)
    if cfg.use_pallas:
        o = flash_attention(q, k, v, bias, causal, cfg.block_q, cfg.block_k)
    else:
        o = ref.attention_ref(q, k, v, bias, causal=causal)
    o = o.transpose(0, 2, 1, 3).reshape(b, lq, h * hd)
    return o @ p[f"{prefix}.wo"], k, v


def _attention(p, prefix, x_q, x_kv, bias, causal, cfg: ModelConfig):
    return _attention_kv(p, prefix, x_q, x_kv, bias, causal, cfg)[0]


def _mlp(p, prefix, x, cfg: ModelConfig):
    b, l, d = x.shape
    flat = x.reshape(b * l, d)
    if cfg.use_pallas:
        y = fused_ffn(
            flat,
            p[f"{prefix}.wi_0"],
            p[f"{prefix}.wi_1"],
            p[f"{prefix}.wo"],
            cfg.block_m,
            cfg.block_f,
        )
    else:
        y = ref.gated_ffn_ref(
            flat, p[f"{prefix}.wi_0"], p[f"{prefix}.wi_1"], p[f"{prefix}.wo"]
        )
    return y.reshape(b, l, d)


def _stack(p, stack, x, bias, causal, cfg, cross_x=None):
    """Run one transformer stack (encoder or decoder)."""
    for i in range(cfg.num_layers):
        lp = f"{stack}.layers_{i}"
        h = rms_norm(x, p[f"{lp}.pre_attn_norm.scale"])
        x = x + _attention(p, f"{lp}.self_attn", h, h, bias, causal, cfg)
        if cross_x is not None:
            h = rms_norm(x, p[f"{lp}.pre_cross_norm.scale"])
            x = x + _attention(p, f"{lp}.cross_attn", h, cross_x, None, False, cfg)
        h = rms_norm(x, p[f"{lp}.pre_mlp_norm.scale"])
        x = x + _mlp(p, f"{lp}.mlp", h, cfg)
    return rms_norm(x, p[f"{stack}.final_norm.scale"])


def logits_fn(p, cfg: ModelConfig, dec_tokens, enc_tokens=None):
    """Token logits [B, L, V] for the decoder positions."""
    embed = p["token_embed"]
    dec_x = embed[dec_tokens]
    dec_bias = relpos_bias(
        p["decoder.relpos_bias"], dec_tokens.shape[1], dec_tokens.shape[1], False, cfg
    )
    if cfg.arch == "encdec":
        enc_x = embed[enc_tokens]
        enc_bias = relpos_bias(
            p["encoder.relpos_bias"],
            enc_tokens.shape[1],
            enc_tokens.shape[1],
            True,
            cfg,
        )
        enc_out = _stack(p, "encoder", enc_x, enc_bias, False, cfg)
        dec_out = _stack(p, "decoder", dec_x, dec_bias, True, cfg, cross_x=enc_out)
    else:
        dec_out = _stack(p, "decoder", dec_x, dec_bias, True, cfg)
    # Shared-embedding output head, scaled per T5 (1/sqrt(d)).
    return (dec_out / np.sqrt(cfg.d_model)) @ embed.T


def loss_terms(p, cfg: ModelConfig, batch):
    """(loss_sum, weight_sum, correct_sum): unnormalized so the Rust trainer
    can all-reduce across hosts and divide once — exact global-batch math."""
    logits = logits_fn(
        p, cfg, batch["decoder_input_tokens"], batch.get("encoder_input_tokens")
    ).astype(jnp.float32)
    targets = batch["decoder_target_tokens"]
    weights = batch["decoder_loss_weights"].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    target_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - target_logit
    zl = cfg.z_loss * jnp.square(logz)
    loss_sum = jnp.sum((nll + zl) * weights)
    weight_sum = jnp.sum(weights)
    correct = (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32)
    correct_sum = jnp.sum(correct * weights)
    return loss_sum, weight_sum, correct_sum


def train_step_fn(cfg: ModelConfig):
    """(params..., batch...) -> (loss_sum, weight_sum, correct_sum, grads...).

    Parameters are passed positionally in sorted-name order so the HLO input
    layout matches the manifest exactly.
    """
    names = [s[0] for s in param_specs(cfg)]

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        batch = _batch_from_args(cfg, args[len(names):])

        def loss_of(p_):
            ls, ws, cs = loss_terms(p_, cfg, batch)
            return ls, (ws, cs)

        (loss_sum, (weight_sum, correct_sum)), grads = jax.value_and_grad(
            loss_of, has_aux=True
        )(p)
        return (loss_sum, weight_sum, correct_sum) + tuple(
            grads[n] for n in names
        )

    return fn, names


def eval_step_fn(cfg: ModelConfig):
    """(params..., batch...) -> (loss_sum, weight_sum, correct_sum)."""
    names = [s[0] for s in param_specs(cfg)]

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        batch = _batch_from_args(cfg, args[len(names):])
        return loss_terms(p, cfg, batch)

    return fn, names


def decode_logits_fn(cfg: ModelConfig):
    """(params..., tokens...) -> logits [B, L, V] (greedy decode in Rust)."""
    names = [s[0] for s in param_specs(cfg)]

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        rest = args[len(names):]
        if cfg.arch == "encdec":
            enc_tokens, dec_tokens = rest
            return (logits_fn(p, cfg, dec_tokens, enc_tokens),)
        (dec_tokens,) = rest
        return (logits_fn(p, cfg, dec_tokens),)

    return fn, names


# ---------------------------------------------------------------------------
# KV-cached incremental decoding (prefill + decode_step).
#
# `decode_logits` re-scores the full [B, L] prefix every step — O(L^2) work
# per sequence. The incremental pair below is the t5x `decoding` cache
# counterpart: `prefill` scores a prompt buffer once and materializes the
# per-layer K/V projections; `decode_step` extends the cache by ONE position
# per row ([B, 1] token input) and returns [B, V] next-token logits — O(L)
# total work per sequence. Decoder-only models only (the serving engine's
# scope); cache layout is [B, num_heads, L, head_dim], k then v per layer,
# recorded in the manifest as `kv_cache`.
# ---------------------------------------------------------------------------


def decoder_prefill(p, cfg: ModelConfig, dec_tokens):
    """Full-prefix decoder pass that also returns the per-layer K/V cache.

    The logits computation is the exact `logits_fn` decoder path (same
    kernels, same order of operations) — capturing K/V adds outputs, not
    different math — so `prefill` logits match `decode_logits` on the same
    buffer. Positions holding padding produce garbage cache rows; they are
    masked (`key_pos <= pos`) and later overwritten by `decode_step`.

    Returns (logits [B, L, V], [(k, v)] per layer, each [B, H, L, Hd]).
    """
    embed = p["token_embed"]
    x = embed[dec_tokens]
    l = dec_tokens.shape[1]
    bias = relpos_bias(p["decoder.relpos_bias"], l, l, False, cfg)
    caches = []
    for i in range(cfg.num_layers):
        lp = f"decoder.layers_{i}"
        h = rms_norm(x, p[f"{lp}.pre_attn_norm.scale"])
        att, k, v = _attention_kv(p, f"{lp}.self_attn", h, h, bias, True, cfg)
        x = x + att
        h = rms_norm(x, p[f"{lp}.pre_mlp_norm.scale"])
        x = x + _mlp(p, f"{lp}.mlp", h, cfg)
        caches.append((k, v))
    x = rms_norm(x, p["decoder.final_norm.scale"])
    return (x / np.sqrt(cfg.d_model)) @ embed.T, caches


def decoder_decode_step(p, cfg: ModelConfig, caches, token, pos):
    """One incremental decode step against a KV cache.

    Args:
      caches: flat [k0, v0, k1, v1, ...], each [B, H, L, head_dim].
      token: [B, 1] int32 — the most recently *written* decoder token.
      pos: [B] int32 — its position in the length-L decoder buffer
        (per-row: continuous batching packs rows at different lengths).

    Writes `token`'s K/V into the cache at `pos`, attends the single query
    over key positions `<= pos` (future cache rows are stale), and returns
    ([B, V] logits for the *next* position, updated caches). Attention is
    the `ref.attention_ref` formula specialized to Lq=1 with a per-row
    visibility mask instead of the triangular causal mask.
    """
    b = token.shape[0]
    l = cfg.seq_len
    nh, hd = cfg.num_heads, cfg.head_dim
    embed = p["token_embed"]
    x = embed[token]  # [B, 1, d]
    mem = jnp.arange(l)[None, :]  # [1, L] key positions
    buckets = relative_position_bucket(
        mem - pos[:, None], False, cfg.relpos_buckets, cfg.relpos_max_distance
    )  # [B, L]
    # [B, L, H] -> [B, H, 1, L]: per-row bias for the one query at `pos`.
    bias = jnp.transpose(p["decoder.relpos_bias"][buckets], (0, 2, 1))[:, :, None, :]
    visible = (mem <= pos[:, None])[:, None, None, :]  # [B, 1, 1, L]
    new_caches = []
    for i in range(cfg.num_layers):
        lp = f"decoder.layers_{i}"
        kc, vc = caches[2 * i], caches[2 * i + 1]
        h = rms_norm(x, p[f"{lp}.pre_attn_norm.scale"])
        q = (h @ p[f"{lp}.self_attn.wq"]).reshape(b, 1, nh, hd).transpose(0, 2, 1, 3)
        k1 = (h @ p[f"{lp}.self_attn.wk"]).reshape(b, 1, nh, hd).transpose(0, 2, 1, 3)
        v1 = (h @ p[f"{lp}.self_attn.wv"]).reshape(b, 1, nh, hd).transpose(0, 2, 1, 3)
        upd = lambda c, u, s: jax.lax.dynamic_update_slice(c, u, (0, s, 0))
        kc = jax.vmap(upd)(kc, k1, pos)
        vc = jax.vmap(upd)(vc, v1, pos)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, kc) / jnp.sqrt(
            jnp.asarray(hd, q.dtype)
        )
        logits = logits + bias.astype(logits.dtype)
        logits = jnp.where(visible, logits, ref.NEG_INF)
        weights = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
        o = jnp.einsum("bhqk,bhkd->bhqd", weights, vc)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, nh * hd)
        x = x + o @ p[f"{lp}.self_attn.wo"]
        h = rms_norm(x, p[f"{lp}.pre_mlp_norm.scale"])
        x = x + ref.gated_ffn_ref(
            h.reshape(b, cfg.d_model),
            p[f"{lp}.mlp.wi_0"],
            p[f"{lp}.mlp.wi_1"],
            p[f"{lp}.mlp.wo"],
        ).reshape(b, 1, cfg.d_model)
        new_caches += [kc, vc]
    x = rms_norm(x, p["decoder.final_norm.scale"])
    return ((x[:, 0, :] / np.sqrt(cfg.d_model)) @ embed.T,) + tuple(new_caches)


def prefill_fn(cfg: ModelConfig):
    """(params..., dec_tokens) -> (logits [B, L, V], k0, v0, k1, v1, ...)."""
    assert cfg.arch == "decoder", "KV-cached decoding exports are decoder-only"
    names = [s[0] for s in param_specs(cfg)]

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        (dec_tokens,) = args[len(names):]
        logits, caches = decoder_prefill(p, cfg, dec_tokens)
        return (logits,) + tuple(t for kv in caches for t in kv)

    return fn, names


def decode_step_fn(cfg: ModelConfig):
    """(params..., k0, v0, ..., token [B,1], pos [B]) -> (logits [B, V],
    k0', v0', ...)."""
    assert cfg.arch == "decoder", "KV-cached decoding exports are decoder-only"
    names = [s[0] for s in param_specs(cfg)]
    n_cache = 2 * cfg.num_layers

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        rest = args[len(names):]
        caches = list(rest[:n_cache])
        token, pos = rest[n_cache], rest[n_cache + 1]
        return decoder_decode_step(p, cfg, caches, token, pos)

    return fn, names


def kv_cache_shapes(cfg: ModelConfig):
    """ShapeDtypeStructs of the per-layer cache tensors, export order
    (k then v per layer) — the `kv_cache` manifest contract."""
    shape = (cfg.batch, cfg.num_heads, cfg.seq_len, cfg.head_dim)
    return [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _ in range(cfg.num_layers)
        for _ in ("k", "v")
    ]


def batch_feature_names(cfg: ModelConfig) -> List[str]:
    feats = []
    if cfg.arch == "encdec":
        feats.append("encoder_input_tokens")
    feats += ["decoder_input_tokens", "decoder_target_tokens", "decoder_loss_weights"]
    return feats


def _batch_from_args(cfg: ModelConfig, args):
    return dict(zip(batch_feature_names(cfg), args))


def batch_shapes(cfg: ModelConfig):
    """ShapeDtypeStructs for the batch features, manifest order."""
    b, l = cfg.batch, cfg.seq_len
    shapes = {}
    if cfg.arch == "encdec":
        shapes["encoder_input_tokens"] = jax.ShapeDtypeStruct((b, l), jnp.int32)
    shapes["decoder_input_tokens"] = jax.ShapeDtypeStruct((b, l), jnp.int32)
    shapes["decoder_target_tokens"] = jax.ShapeDtypeStruct((b, l), jnp.int32)
    shapes["decoder_loss_weights"] = jax.ShapeDtypeStruct((b, l), jnp.float32)
    return shapes


# ---------------------------------------------------------------------------
# Model-parallel block execution (§2.2): Megatron-style f/g decomposition.
#
# The train step is re-expressed as a sequence of HLO *segments* that take
# each weight as its `[.., dim/n, ..]` model-axis block plus the shard
# coordinate (a traced i32 scalar, so ONE HLO serves all shards of a degree).
# Column-parallel matmuls (wq/wk/wv on joined_kv, wi_0/wi_1 on mlp, the
# vocab-sharded embedding/output head) need no communication; row-parallel
# matmuls (attn wo, mlp wo) produce partial sums, so each segment ends right
# before a Megatron g-point and the host inserts the model-axis all-reduce
# between segments. The softmax/loss reduction is split the same way
# (block max -> AR-max, block sum-exp / target-logit -> AR-sum, argmax claim
# -> AR-min). Backward segments are the jax.vjp of the forward closures
# (rematerialized from the saved segment inputs — no residual-tensor
# contract); residual adds happen on the HOST so the replicated identity
# path is never double-counted by the per-shard vjps.
#
# Per layer the segments share one HLO (layer weights are inputs), so a
# degree exports exactly the 12 segments below regardless of depth. The
# ordered collective schedule (`block_collective_schedule`) is recorded in
# the manifest `block_exec` contract and replayed by the Rust trainer.
# ---------------------------------------------------------------------------

#: Logical axes the partitioner maps to the model mesh axis
#: (mirrors rust `LogicalAxisRules::standard()`).
MODEL_AXIS_NAMES = ("vocab", "heads", "mlp", "joined_kv")

#: Claim value meaning "my vocab block does not hold the global argmax";
#: larger than any token id, dropped by the AR-min.
BLOCK_CLAIM_NONE = 1.0e9

#: Segment export order (also the manifest order).
BLOCK_SEGMENT_NAMES = [
    "fwd_embed",
    "fwd_attn",
    "fwd_mlp",
    "fwd_loss_logits",
    "fwd_loss_finalize",
    "fwd_loss_final",
    "bwd_loss_final",
    "bwd_loss_finalize",
    "bwd_loss_logits",
    "bwd_attn",
    "bwd_mlp",
    "bwd_embed",
]


def supports_block_degree(cfg: ModelConfig, degree: int) -> bool:
    """A degree is exportable iff every model-sharded dimension divides:
    vocab (embedding/logits), heads (relpos table + joined_kv), d_ff."""
    return (
        cfg.arch == "decoder"
        and degree >= 2
        and cfg.vocab % degree == 0
        and cfg.num_heads % degree == 0
        and cfg.d_ff % degree == 0
    )


def model_block_specs(cfg: ModelConfig, degree: int):
    """Per-parameter model-axis block shapes at `degree` shards.

    Mirrors rust `Partitioner::spec_for`: the FIRST dimension whose logical
    axis maps to the model mesh axis and is divisible by `degree` is
    sharded; parameters with no such dimension are replicated
    (``model_dim`` None — the 2L+1 norm scales for a decoder stack).
    """
    out = []
    for name, shape, axes, _ in param_specs(cfg):
        bshape, mdim = list(shape), None
        if degree > 1:
            for i, ax in enumerate(axes):
                if ax in MODEL_AXIS_NAMES and shape[i] % degree == 0:
                    mdim = i
                    bshape[i] = shape[i] // degree
                    break
        out.append({"name": name, "block_shape": bshape, "model_dim": mdim})
    return out


def block_replicated_params(cfg: ModelConfig, degree: int):
    """Names of model-replicated params (manifest order) whose grads are
    summed over the model axis in ONE fused all-reduce at schedule end."""
    return [s["name"] for s in model_block_specs(cfg, degree) if s["model_dim"] is None]


def _embed_block_fwd(emb_b, tokens, shard):
    """Vocab-sharded embedding lookup: exact — each token id falls in exactly
    one shard's row range, the rest contribute zeros to the AR-sum."""
    vb = emb_b.shape[0]
    local = tokens - shard * vb
    ok = (local >= 0) & (local < vb)
    x = emb_b[jnp.clip(local, 0, vb - 1)]
    return jnp.where(ok[..., None], x, 0.0)


def _attn_block_fwd(cfg: ModelConfig, x, n1, wq, wk, wv, wo, rp):
    """Self-attention on a heads block: wq/wk/wv column-parallel, wo
    row-parallel -> returns the PARTIAL output (pre all-reduce). The heads
    block count is derived from the relpos table block ([buckets, H/n])."""
    b, l, d = x.shape
    hm, hd = rp.shape[1], cfg.head_dim
    h = rms_norm(x, n1)
    q = (h @ wq).reshape(b, l, hm, hd).transpose(0, 2, 1, 3)
    k = (h @ wk).reshape(b, l, hm, hd).transpose(0, 2, 1, 3)
    v = (h @ wv).reshape(b, l, hm, hd).transpose(0, 2, 1, 3)
    bias = relpos_bias(rp, l, l, False, cfg)
    if cfg.use_pallas:
        o = flash_attention(q, k, v, bias, True, cfg.block_q, cfg.block_k)
    else:
        o = ref.attention_ref(q, k, v, bias, causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(b, l, hm * hd)
    return o @ wo


def _mlp_block_fwd(cfg: ModelConfig, x, n2, wi0, wi1, wo2):
    """Gated MLP on a d_ff block: wi_0/wi_1 column-parallel, wo
    row-parallel -> PARTIAL output (pre all-reduce)."""
    b, l, d = x.shape
    flat = rms_norm(x, n2).reshape(b * l, d)
    if cfg.use_pallas:
        y = fused_ffn(flat, wi0, wi1, wo2, cfg.block_m, cfg.block_f)
    else:
        y = ref.gated_ffn_ref(flat, wi0, wi1, wo2)
    return y.reshape(b, l, d)


def _partial_loss_terms(z, gmax, targets, shard):
    """(sum_exp_part, target_logit_part) over one vocab block — the
    differentiable core of fwd_loss_finalize and its vjp. `gmax` is the
    global logit max; treating it as a constant is exact (logsumexp shift
    invariance)."""
    vb = z.shape[-1]
    se = jnp.sum(jnp.exp(z - gmax[..., None]), axis=-1)
    local_t = targets - shard * vb
    ok = (local_t >= 0) & (local_t < vb)
    zt = jnp.take_along_axis(z, jnp.clip(local_t, 0, vb - 1)[..., None], axis=-1)
    tl = jnp.where(ok, zt[..., 0], 0.0)
    return se, tl


def block_segment_fns(cfg: ModelConfig):
    """The 12 block-step segment functions, name -> fn (tuple outputs).

    All segments are pure functions of (activations, weight blocks, shard
    coordinate); backward segments rematerialize via jax.vjp of the matching
    forward closure, so the host only carries segment INPUTS between calls.
    """
    sqrt_d = np.sqrt(cfg.d_model)
    zl = cfg.z_loss

    def fwd_embed(emb_b, tokens, shard):
        return (_embed_block_fwd(emb_b, tokens, shard),)

    def fwd_attn(x, n1, wq, wk, wv, wo, rp):
        return (_attn_block_fwd(cfg, x, n1, wq, wk, wv, wo, rp),)

    def fwd_mlp(x, n2, wi0, wi1, wo2):
        return (_mlp_block_fwd(cfg, x, n2, wi0, wi1, wo2),)

    def fwd_loss_logits(x, fnorm, emb_b):
        z = ((rms_norm(x, fnorm) / sqrt_d) @ emb_b.T).astype(jnp.float32)
        return z, jnp.max(z, axis=-1)

    def fwd_loss_finalize(z, gmax, targets, weights, shard):
        se, tl = _partial_loss_terms(z, gmax, targets, shard)
        vb = z.shape[-1]
        claim = jnp.where(
            jnp.max(z, axis=-1) == gmax,
            (shard * vb + jnp.argmax(z, axis=-1)).astype(jnp.float32),
            jnp.float32(BLOCK_CLAIM_NONE),
        )
        return se, tl, claim

    def fwd_loss_final(se, tl, claim, gmax, targets, weights):
        logz = jnp.log(se) + gmax
        loss_sum = jnp.sum((logz - tl + zl * jnp.square(logz)) * weights)
        correct = (claim == targets.astype(jnp.float32)).astype(jnp.float32)
        return loss_sum, jnp.sum(weights), jnp.sum(correct * weights)

    def bwd_loss_final(se, tl, gmax, targets, weights):
        def f(se_, tl_):
            logz = jnp.log(se_) + gmax
            return jnp.sum((logz - tl_ + zl * jnp.square(logz)) * weights)

        _, vjp = jax.vjp(f, se, tl)
        return vjp(jnp.float32(1.0))

    def bwd_loss_finalize(z, gmax, targets, weights, shard, d_se, d_tl):
        _, vjp = jax.vjp(lambda z_: _partial_loss_terms(z_, gmax, targets, shard), z)
        return vjp((d_se, d_tl))

    def bwd_loss_logits(x, fnorm, emb_b, d_z):
        def f(x_, fn_, em_):
            return ((rms_norm(x_, fn_) / sqrt_d) @ em_.T).astype(jnp.float32)

        _, vjp = jax.vjp(f, x, fnorm, emb_b)
        return vjp(d_z)

    def bwd_attn(x, n1, wq, wk, wv, wo, rp, d_out):
        _, vjp = jax.vjp(
            lambda *ws: _attn_block_fwd(cfg, *ws), x, n1, wq, wk, wv, wo, rp
        )
        return vjp(d_out)

    def bwd_mlp(x, n2, wi0, wi1, wo2, d_out):
        _, vjp = jax.vjp(lambda *ws: _mlp_block_fwd(cfg, *ws), x, n2, wi0, wi1, wo2)
        return vjp(d_out)

    def bwd_embed(emb_b, tokens, shard, d_x):
        _, vjp = jax.vjp(lambda e: _embed_block_fwd(e, tokens, shard), emb_b)
        return vjp(d_x)

    fns = dict(
        fwd_embed=fwd_embed,
        fwd_attn=fwd_attn,
        fwd_mlp=fwd_mlp,
        fwd_loss_logits=fwd_loss_logits,
        fwd_loss_finalize=fwd_loss_finalize,
        fwd_loss_final=fwd_loss_final,
        bwd_loss_final=bwd_loss_final,
        bwd_loss_finalize=bwd_loss_finalize,
        bwd_loss_logits=bwd_loss_logits,
        bwd_attn=bwd_attn,
        bwd_mlp=bwd_mlp,
        bwd_embed=bwd_embed,
    )
    assert list(fns) == BLOCK_SEGMENT_NAMES
    return fns


def block_segment_shapes(cfg: ModelConfig, degree: int):
    """Input ShapeDtypeStructs per segment at `degree` (export lowering)."""
    b, l, d = cfg.batch, cfg.seq_len, cfg.d_model
    vb, jm, fm = cfg.vocab // degree, cfg.joined_kv // degree, cfg.d_ff // degree
    hm = cfg.num_heads // degree
    f32 = lambda *s: jax.ShapeDtypeStruct(tuple(s), jnp.float32)
    i32 = lambda *s: jax.ShapeDtypeStruct(tuple(s), jnp.int32)
    x, bl = f32(b, l, d), f32(b, l)
    emb, tok, shard = f32(vb, d), i32(b, l), i32()
    norm = f32(d)
    wq, wo, rp = f32(d, jm), f32(jm, d), f32(cfg.relpos_buckets, hm)
    wi, wo2, z = f32(d, fm), f32(fm, d), f32(b, l, vb)
    return {
        "fwd_embed": [emb, tok, shard],
        "fwd_attn": [x, norm, wq, wq, wq, wo, rp],
        "fwd_mlp": [x, norm, wi, wi, wo2],
        "fwd_loss_logits": [x, norm, emb],
        "fwd_loss_finalize": [z, bl, tok, bl, shard],
        "fwd_loss_final": [bl, bl, bl, bl, tok, bl],
        "bwd_loss_final": [bl, bl, bl, tok, bl],
        "bwd_loss_finalize": [z, bl, tok, bl, shard, bl, bl],
        "bwd_loss_logits": [x, norm, emb, z],
        "bwd_attn": [x, norm, wq, wq, wq, wo, rp, x],
        "bwd_mlp": [x, norm, wi, wi, wo2, x],
        "bwd_embed": [emb, tok, shard, x],
    }


def block_collective_schedule(cfg: ModelConfig, degree: int):
    """Ordered model-axis collective schedule: [(point, op, elems)].

    This IS the manifest contract the Rust trainer replays: one entry per
    host-inserted collective, in execution order. All payloads are f32.
    """
    b, l, d = cfg.batch, cfg.seq_len, cfg.d_model
    bld, bl_ = b * l * d, b * l
    sched = [("embed_out", "all_reduce_sum", bld)]
    for i in range(cfg.num_layers):
        sched.append((f"layer_{i}.attn_out", "all_reduce_sum", bld))
        sched.append((f"layer_{i}.mlp_out", "all_reduce_sum", bld))
    sched += [
        ("logits_max", "all_reduce_max", bl_),
        ("softmax_sum", "all_reduce_sum", bl_),
        ("target_logit", "all_reduce_sum", bl_),
        ("argmax_claim", "all_reduce_min", bl_),
        ("d_final", "all_reduce_sum", bld),
    ]
    for i in reversed(range(cfg.num_layers)):
        sched.append((f"layer_{i}.d_mlp", "all_reduce_sum", bld))
        sched.append((f"layer_{i}.d_attn", "all_reduce_sum", bld))
    sched.append(
        ("replicated_grads", "all_reduce_sum", (2 * cfg.num_layers + 1) * d)
    )
    return sched


def block_reference_step(cfg: ModelConfig, degree: int, params, batch):
    """Host-simulated block train step: the exact segment + collective
    schedule the Rust trainer runs, with collectives as float32 reductions
    over the per-shard partials. Returns (loss_sum, weight_sum, correct_sum,
    grads dict with FULL shapes) for comparison against `train_step_fn`.

    Used by the aot.py export-time assertion and python tests; it is the
    single source of truth for the host-side schedule (mirrored by
    `Trainer`'s block executor in rust)."""
    fns = block_segment_fns(cfg)
    specs = {s["name"]: (s["block_shape"], s["model_dim"]) for s in
             model_block_specs(cfg, degree)}

    def blk(name, m):
        w, (_, mdim) = np.asarray(params[name]), specs[name]
        if mdim is None:
            return jnp.asarray(w)
        size = w.shape[mdim] // degree
        idx = [slice(None)] * w.ndim
        idx[mdim] = slice(m * size, (m + 1) * size)
        return jnp.asarray(w[tuple(idx)])

    def ar(parts, op=np.add):
        acc = np.asarray(parts[0], np.float32)
        for p_ in parts[1:]:
            acc = op(acc, np.asarray(p_, np.float32))
        return jnp.asarray(acc)

    tokens = jnp.asarray(batch["decoder_input_tokens"])
    targets = jnp.asarray(batch["decoder_target_tokens"])
    weights = jnp.asarray(batch["decoder_loss_weights"], jnp.float32)
    shards = [jnp.int32(m) for m in range(degree)]
    nl = cfg.num_layers
    layer = lambda i, s: f"decoder.layers_{i}.{s}"

    # ---- forward ----
    x = ar([fns["fwd_embed"](blk("token_embed", m), tokens, shards[m])[0]
            for m in range(degree)])
    x_attn_in, x_mlp_in = [], []
    for i in range(nl):
        x_attn_in.append(x)
        x = x + ar([
            fns["fwd_attn"](
                x, blk(layer(i, "pre_attn_norm.scale"), m),
                blk(layer(i, "self_attn.wq"), m), blk(layer(i, "self_attn.wk"), m),
                blk(layer(i, "self_attn.wv"), m), blk(layer(i, "self_attn.wo"), m),
                blk("decoder.relpos_bias", m),
            )[0]
            for m in range(degree)
        ])
        x_mlp_in.append(x)
        x = x + ar([
            fns["fwd_mlp"](
                x, blk(layer(i, "pre_mlp_norm.scale"), m),
                blk(layer(i, "mlp.wi_0"), m), blk(layer(i, "mlp.wi_1"), m),
                blk(layer(i, "mlp.wo"), m),
            )[0]
            for m in range(degree)
        ])
    fnorm = blk("decoder.final_norm.scale", 0)
    heads = [fns["fwd_loss_logits"](x, fnorm, blk("token_embed", m))
             for m in range(degree)]
    gmax = ar([h[1] for h in heads], np.maximum)
    fin = [fns["fwd_loss_finalize"](heads[m][0], gmax, targets, weights, shards[m])
           for m in range(degree)]
    se, tl = ar([f[0] for f in fin]), ar([f[1] for f in fin])
    claim = ar([f[2] for f in fin], np.minimum)
    loss_sum, weight_sum, correct_sum = fns["fwd_loss_final"](
        se, tl, claim, gmax, targets, weights
    )

    # ---- backward ----
    d_se, d_tl = fns["bwd_loss_final"](se, tl, gmax, targets, weights)
    grads = {m: {} for m in range(degree)}
    d_x_parts = []
    for m in range(degree):
        (d_z,) = fns["bwd_loss_finalize"](
            heads[m][0], gmax, targets, weights, shards[m], d_se, d_tl
        )
        dx, dfn, demb = fns["bwd_loss_logits"](x, fnorm, blk("token_embed", m), d_z)
        grads[m]["decoder.final_norm.scale"] = dfn
        grads[m]["token_embed"] = demb
        d_x_parts.append(dx)
    d_x = ar(d_x_parts)
    for i in reversed(range(nl)):
        parts = []
        for m in range(degree):
            dx, dn2, dwi0, dwi1, dwo2 = fns["bwd_mlp"](
                x_mlp_in[i], blk(layer(i, "pre_mlp_norm.scale"), m),
                blk(layer(i, "mlp.wi_0"), m), blk(layer(i, "mlp.wi_1"), m),
                blk(layer(i, "mlp.wo"), m), d_x,
            )
            grads[m][layer(i, "pre_mlp_norm.scale")] = dn2
            grads[m][layer(i, "mlp.wi_0")] = dwi0
            grads[m][layer(i, "mlp.wi_1")] = dwi1
            grads[m][layer(i, "mlp.wo")] = dwo2
            parts.append(dx)
        d_x = d_x + ar(parts)
        parts = []
        for m in range(degree):
            dx, dn1, dwq, dwk, dwv, dwo, drp = fns["bwd_attn"](
                x_attn_in[i], blk(layer(i, "pre_attn_norm.scale"), m),
                blk(layer(i, "self_attn.wq"), m), blk(layer(i, "self_attn.wk"), m),
                blk(layer(i, "self_attn.wv"), m), blk(layer(i, "self_attn.wo"), m),
                blk("decoder.relpos_bias", m), d_x,
            )
            grads[m][layer(i, "pre_attn_norm.scale")] = dn1
            grads[m][layer(i, "self_attn.wq")] = dwq
            grads[m][layer(i, "self_attn.wk")] = dwk
            grads[m][layer(i, "self_attn.wv")] = dwv
            grads[m][layer(i, "self_attn.wo")] = dwo
            # relpos table is shared across layers: host-sum of per-layer blocks
            prev = grads[m].get("decoder.relpos_bias")
            grads[m]["decoder.relpos_bias"] = drp if prev is None else prev + drp
            parts.append(dx)
        d_x = d_x + ar(parts)
    for m in range(degree):
        (demb,) = fns["bwd_embed"](blk("token_embed", m), tokens, shards[m], d_x)
        grads[m]["token_embed"] = grads[m]["token_embed"] + demb

    # fused model-axis all-reduce of the replicated (norm-scale) grads
    for name in block_replicated_params(cfg, degree):
        g = ar([grads[m][name] for m in range(degree)])
        for m in range(degree):
            grads[m][name] = g

    # reassemble full-shape grads (concat model blocks) for comparison
    full = {}
    for name, (_, mdim) in specs.items():
        if mdim is None:
            full[name] = grads[0][name]
        else:
            full[name] = jnp.concatenate(
                [grads[m][name] for m in range(degree)], axis=mdim
            )
    return loss_sum, weight_sum, correct_sum, full


# ---------------------------------------------------------------------------
# Scan variant (Scalable T5, §4): layers stacked, lax.scan over depth.
# Used by the compile-time benchmark (E12); numerics match the unrolled model.
# ---------------------------------------------------------------------------


def scan_decoder_loss_fn(cfg: ModelConfig):
    """Decoder-only loss with stacked per-layer params + lax.scan over layers.

    Inputs: embed, relpos, stacked layer params (leading axis = num_layers),
    final norm scale, then the batch. Demonstrates the compile-time win of
    jax.scan that motivates Scalable T5.
    """

    def fn(
        embed,
        relpos,
        norm1,
        wq,
        wk,
        wv,
        wo,
        norm2,
        wi0,
        wi1,
        wo2,
        final_norm,
        dec_in,
        dec_tgt,
        weights,
    ):
        cfg_ref = dataclasses.replace(cfg, use_pallas=False)
        x = embed[dec_in]
        bias = relpos_bias(relpos, cfg.seq_len, cfg.seq_len, False, cfg)

        def layer(x, lp):
            (n1, q_, k_, v_, o_, n2, i0, i1, o2) = lp
            b, l, d = x.shape
            h = rms_norm(x, n1)
            hh, hd = cfg.num_heads, cfg.head_dim
            qh = (h @ q_).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            kh = (h @ k_).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            vh = (h @ v_).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            att = ref.attention_ref(qh, kh, vh, bias, causal=True)
            att = att.transpose(0, 2, 1, 3).reshape(b, l, hh * hd)
            x = x + att @ o_
            h = rms_norm(x, n2)
            x = x + ref.gated_ffn_ref(
                h.reshape(b * l, d), i0, i1, o2
            ).reshape(b, l, d)
            return x, ()

        x, _ = jax.lax.scan(layer, x, (norm1, wq, wk, wv, wo, norm2, wi0, wi1, wo2))
        x = rms_norm(x, final_norm)
        logits = (x / np.sqrt(cfg.d_model)) @ embed.T
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, dec_tgt[..., None], axis=-1)[..., 0]
        loss = jnp.sum((logz - tl) * weights)
        return loss

    return fn


def unrolled_decoder_loss_fn(cfg: ModelConfig):
    """Same computation as scan_decoder_loss_fn with a python-loop unroll."""

    def fn(
        embed,
        relpos,
        norm1,
        wq,
        wk,
        wv,
        wo,
        norm2,
        wi0,
        wi1,
        wo2,
        final_norm,
        dec_in,
        dec_tgt,
        weights,
    ):
        x = embed[dec_in]
        bias = relpos_bias(relpos, cfg.seq_len, cfg.seq_len, False, cfg)
        for i in range(cfg.num_layers):
            b, l, d = x.shape
            h = rms_norm(x, norm1[i])
            hh, hd = cfg.num_heads, cfg.head_dim
            qh = (h @ wq[i]).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            kh = (h @ wk[i]).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            vh = (h @ wv[i]).reshape(b, l, hh, hd).transpose(0, 2, 1, 3)
            att = ref.attention_ref(qh, kh, vh, bias, causal=True)
            att = att.transpose(0, 2, 1, 3).reshape(b, l, hh * hd)
            x = x + att @ wo[i]
            h = rms_norm(x, norm2[i])
            x = x + ref.gated_ffn_ref(
                h.reshape(b * l, d), wi0[i], wi1[i], wo2[i]
            ).reshape(b, l, d)
        x = rms_norm(x, final_norm)
        logits = (x / np.sqrt(cfg.d_model)) @ embed.T
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, dec_tgt[..., None], axis=-1)[..., 0]
        loss = jnp.sum((logz - tl) * weights)
        return loss

    return fn


# ---------------------------------------------------------------------------
# Registry of export configs (mirrored by the Rust model registry).
# ---------------------------------------------------------------------------

CONFIGS = {
    "t5-nano-dec": ModelConfig(
        name="t5-nano-dec", arch="decoder", num_layers=2, d_model=64, num_heads=4,
        head_dim=16, d_ff=128, vocab=512, batch=8, seq_len=32,
    ),
    "t5-nano-encdec": ModelConfig(
        name="t5-nano-encdec", arch="encdec", num_layers=2, d_model=64, num_heads=4,
        head_dim=16, d_ff=128, vocab=512, batch=8, seq_len=32,
    ),
    # Long-sequence nano variant: small weights, L=128 — the serving bench
    # case where O(L^2) rescoring visibly loses to O(L) KV-cached decode.
    "t5-nano-dec-l128": ModelConfig(
        name="t5-nano-dec-l128", arch="decoder", num_layers=2, d_model=64,
        num_heads=4, head_dim=16, d_ff=128, vocab=512, batch=4, seq_len=128,
    ),
    "t5-micro-dec": ModelConfig(
        name="t5-micro-dec", arch="decoder", num_layers=4, d_model=128, num_heads=8,
        head_dim=16, d_ff=512, vocab=4096, batch=8, seq_len=64,
    ),
    "t5-micro-encdec": ModelConfig(
        name="t5-micro-encdec", arch="encdec", num_layers=4, d_model=128, num_heads=8,
        head_dim=16, d_ff=512, vocab=4096, batch=8, seq_len=64,
    ),
    "t5-small-dec": ModelConfig(
        name="t5-small-dec", arch="decoder", num_layers=6, d_model=256, num_heads=8,
        head_dim=32, d_ff=1024, vocab=8192, batch=4, seq_len=64,
    ),
    "t5-100m-dec": ModelConfig(
        name="t5-100m-dec", arch="decoder", num_layers=12, d_model=768, num_heads=12,
        head_dim=64, d_ff=2048, vocab=16384, batch=2, seq_len=128,
    ),
}
