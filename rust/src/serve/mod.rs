//! Production serving gateway (S8→S9): HTTP front end, deadline-aware
//! admission, and a multi-engine replica router.
//!
//! The continuous-batching [`crate::infer::InferEngine`] packs requests
//! into one model's `B` batch slots; this module grows it into something
//! that can face real traffic:
//!
//! ```text
//!   clients ──HTTP──▶ ┌───────────┐    pop (≤ free slots)   ┌──────────┐
//!   (POST /v1/...)    │ admission │ ◀──────────────────────▶ │ replica 0│
//!   stdin  ──JSONL──▶ │   queue   │ ◀──────────────────────▶ │ replica 1│
//!                     └───────────┘        ...               └──────────┘
//!                      bounded depth,                   each an InferEngine
//!                      priority order,                  stepping on its own
//!                      deadline shedding                thread (shared Arcs)
//! ```
//!
//! * [`admission`] — one bounded, priority-ordered queue decoupled from
//!   engine slots. Over-capacity submits are rejected with explicit
//!   backpressure (HTTP 429 + `Retry-After`); a configurable watermark
//!   sheds low-priority work early; requests whose `deadline_ms` expires
//!   while queued are shed *before* they ever occupy a slot (counted as
//!   `serve/shed_deadline`).
//! * [`router`] — the [`router::Gateway`]: N engine replicas (built via
//!   [`crate::infer::InferEngine::replica`], sharing compiled executables
//!   and Arc-backed parameter tensors) each stepping on its own thread,
//!   fed from the single admission queue with least-loaded (capacity-
//!   driven) dispatch: a replica pulls at most as many requests as it has
//!   free slots, so work flows to whichever replica has room and a busy
//!   replica can never hoard the queue.
//! * [`http`] — a stdlib-only HTTP/1.1 front end (`POST /v1/generate`,
//!   `GET /healthz`, `GET /metrics`, `POST /admin/drain`) on a connection
//!   thread pool.
//! * [`signal`] — a raw `signal(2)` SIGINT hook (no external crates) so
//!   ctrl-C drains instead of dropping mid-flight requests.
//!
//! Both transports (HTTP and the JSONL stdin loop in
//! [`crate::infer::server`]) submit through the same [`router::Gateway`],
//! so scheduling, shedding and metrics live in exactly one place.
//!
//! ## Priority / deadline contract
//!
//! * `priority` (default 0, higher runs earlier): the queue pops the
//!   highest priority first, FIFO within a priority level. Once queue
//!   depth reaches the shed watermark, submits with `priority <= 0` are
//!   rejected (`serve/shed_lowpri`, HTTP 429) — under pressure only work
//!   marked urgent is admitted, until depth hits capacity and everyone
//!   gets 429.
//! * `deadline_ms` (optional): a request that has waited past its
//!   deadline when a replica would dispatch it is shed from the queue
//!   (`serve/shed_deadline`, HTTP 504) — a slot is never spent decoding
//!   an answer nobody is waiting for. Once dispatched, a request always
//!   runs to completion (the deadline bounds *queueing*, not decoding).
//!
//! ## Determinism
//!
//! Routing does not affect outputs: per-row engine decoding is
//! independent of batch neighbors and replicas share parameter tensors,
//! so a request's tokens are byte-identical whichever replica serves it
//! and whatever else is in flight (asserted by
//! `tests/integration_serve.rs` against solo-engine decode).

pub mod admission;
pub mod http;
pub mod router;
pub mod signal;

use std::time::Duration;

use crate::infer::InferResult;

pub use admission::{AdmissionQueue, AdmitError, Popped};
pub use http::{HttpConfig, HttpServer};
pub use router::{Gateway, GatewayConfig, GatewayReport};

/// Per-request scheduling options carried alongside the
/// [`crate::infer::InferRequest`] (JSON fields `priority` / `deadline_ms`
/// on both transports).
#[derive(Debug, Clone, Default)]
pub struct SubmitOpts {
    /// Higher runs earlier; `<= 0` (the default) is sheddable once queue
    /// depth crosses the watermark.
    pub priority: i64,
    /// Maximum time the request may wait in the admission queue before it
    /// is shed instead of dispatched.
    pub deadline: Option<Duration>,
}

/// Why a queued request was shed without occupying a slot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShedReason {
    /// `deadline_ms` elapsed while the request waited in the queue.
    DeadlineExpired,
    /// The gateway shut down with the request still queued (possible only
    /// when no replica drained it, e.g. a replica died or none exist).
    Draining,
}

impl ShedReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShedReason::DeadlineExpired => "deadline_expired",
            ShedReason::Draining => "draining",
        }
    }
}

/// Terminal outcome of an accepted request, delivered on the submitter's
/// channel. Submit-time rejections (queue full, watermark shed, draining,
/// validation) are returned synchronously as [`AdmitError`] instead.
#[derive(Debug, Clone)]
pub enum ServeOutcome {
    /// Completed on a replica. Latency fields are *client-true*: they
    /// include gateway queue time, unlike the engine-internal numbers in
    /// `result` (whose clock starts at engine admission).
    Done {
        /// The id the client supplied (echoed in responses).
        client_id: u64,
        result: InferResult,
        /// Which replica decoded it.
        replica: usize,
        /// Gateway queue wait + engine queue wait, ms.
        queue_ms: f64,
        /// Submit-to-first-token including gateway queue wait, ms.
        ttft_ms: Option<f64>,
        /// Submit-to-completion including gateway queue wait, ms.
        latency_ms: f64,
    },
    /// Shed from the queue without occupying a slot.
    Shed { client_id: u64, reason: ShedReason, waited_ms: f64 },
    /// Dispatch failed after admission (engine rejected the request or
    /// the replica died mid-flight); `error` is the rendered cause.
    Failed { client_id: u64, error: String },
}

impl ServeOutcome {
    pub fn client_id(&self) -> u64 {
        match self {
            ServeOutcome::Done { client_id, .. }
            | ServeOutcome::Shed { client_id, .. }
            | ServeOutcome::Failed { client_id, .. } => *client_id,
        }
    }
}

/// Channel end a submitter hands to [`Gateway::submit`]; the matching
/// receiver gets exactly one [`ServeOutcome`] per accepted request.
pub type OutcomeSender = std::sync::mpsc::Sender<ServeOutcome>;
