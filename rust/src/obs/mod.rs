//! Observability substrate: structured span tracing, log-bucket latency
//! histograms, and gauges.
//!
//! The [`Tracer`] records RAII spans into per-thread buffers (one
//! uncontended `Mutex<Vec<_>>` per thread, found through a thread-local
//! cache) and exports standard Chrome trace-event JSON, loadable in
//! Perfetto / `chrome://tracing`. The overhead contract:
//!
//! * tracing **off** ([`Tracer::off`] or outside the `--profile-steps`
//!   window): creating a span is a single relaxed atomic load — no
//!   allocation, no clock read, no lock;
//! * tracing **on**: one `Instant::now()` pair plus one `Vec` push under
//!   an uncontended per-thread mutex per span.
//!
//! [`Histogram`] is a fixed log-bucket (growth 1.5×, 64 buckets from
//! 1 µs) latency histogram with lock-free recording and p50/p95/p99
//! readout; percentiles report the *upper bound* of the bucket holding
//! the rank (so quoted percentiles never understate latency, and the top
//! occupied bucket reports the exact observed max). [`GaugeSet`] holds
//! last-write-wins scalar gauges (infeed queue depth, engine slot
//! occupancy). All three export through the existing
//! [`crate::metrics::MetricsLogger`] JSONL path.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::metrics::MetricsLogger;
use crate::util::json::Json;

pub mod summary;
pub use summary::{summarize_file, TraceSummary};

// ---------------------------------------------------------------------------
// Trace events

/// A span/gauge attribute value.
#[derive(Debug, Clone)]
pub enum ArgValue {
    Num(f64),
    Str(String),
}

impl From<f64> for ArgValue {
    fn from(v: f64) -> Self {
        ArgValue::Num(v)
    }
}
impl From<u64> for ArgValue {
    fn from(v: u64) -> Self {
        ArgValue::Num(v as f64)
    }
}
impl From<usize> for ArgValue {
    fn from(v: usize) -> Self {
        ArgValue::Num(v as f64)
    }
}
impl From<u32> for ArgValue {
    fn from(v: u32) -> Self {
        ArgValue::Num(v as f64)
    }
}
impl From<&str> for ArgValue {
    fn from(v: &str) -> Self {
        ArgValue::Str(v.to_string())
    }
}
impl From<String> for ArgValue {
    fn from(v: String) -> Self {
        ArgValue::Str(v)
    }
}

impl ArgValue {
    fn to_json(&self) -> Json {
        match self {
            ArgValue::Num(n) => Json::num(*n),
            ArgValue::Str(s) => Json::str(s.clone()),
        }
    }
}

/// One recorded trace event (Chrome trace-event model).
#[derive(Debug, Clone)]
enum EventKind {
    /// Complete span (`ph: "X"`), duration in microseconds.
    Complete { dur_us: f64 },
    /// Counter sample (`ph: "C"`).
    Counter { value: f64 },
}

#[derive(Debug, Clone)]
struct TraceEvent {
    name: String,
    ts_us: f64,
    kind: EventKind,
    args: Vec<(&'static str, ArgValue)>,
}

/// One timeline row in the exported trace (a thread or a virtual track
/// such as `serve/queue`).
struct Track {
    tid: u64,
    name: Mutex<String>,
    events: Mutex<Vec<TraceEvent>>,
}

impl Track {
    fn push(&self, ev: TraceEvent) {
        self.events.lock().unwrap().push(ev);
    }
}

// ---------------------------------------------------------------------------
// Tracer

static NEXT_TRACER_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Per-thread cache of (tracer id -> this thread's track), so the hot
    /// path skips the tracer-wide registry lock after the first span.
    static THREAD_TRACKS: RefCell<Vec<(u64, Arc<Track>)>> = const { RefCell::new(Vec::new()) };
}

/// Low-overhead span tracer exporting Chrome trace-event JSON.
///
/// Shared as `Arc<Tracer>`; span recording goes to per-thread tracks.
/// [`Tracer::off`] builds a permanently disarmed tracer whose every
/// operation is a no-op (this is the default everywhere, so untraced runs
/// pay one atomic load per would-be span).
pub struct Tracer {
    /// False for [`Tracer::off`]: permanently disabled, never allocates.
    armed: bool,
    /// Profile-window toggle (`--profile-steps N..M` flips this at step
    /// boundaries). Meaningless when `armed` is false.
    enabled: AtomicBool,
    /// ts=0 reference for every exported event.
    epoch: Instant,
    id: u64,
    tracks: Mutex<Vec<Arc<Track>>>,
    /// Virtual tracks addressed by name (request timelines, counters).
    named: Mutex<BTreeMap<String, Arc<Track>>>,
    export_warned: AtomicBool,
}

impl Tracer {
    /// An armed tracer, recording from the start.
    pub fn new() -> Arc<Tracer> {
        Arc::new(Tracer {
            armed: true,
            enabled: AtomicBool::new(true),
            epoch: Instant::now(),
            id: NEXT_TRACER_ID.fetch_add(1, Ordering::Relaxed),
            tracks: Mutex::new(Vec::new()),
            named: Mutex::new(BTreeMap::new()),
            export_warned: AtomicBool::new(false),
        })
    }

    /// The no-op tracer: every span/counter call returns immediately
    /// without allocating. This is the default wired into the trainer and
    /// serving engine.
    pub fn off() -> Arc<Tracer> {
        Arc::new(Tracer {
            armed: false,
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            id: NEXT_TRACER_ID.fetch_add(1, Ordering::Relaxed),
            tracks: Mutex::new(Vec::new()),
            named: Mutex::new(BTreeMap::new()),
            export_warned: AtomicBool::new(false),
        })
    }

    /// True when this tracer was built with [`Tracer::new`] (a trace was
    /// requested), regardless of the current profile window.
    pub fn is_armed(&self) -> bool {
        self.armed
    }

    /// True when spans are currently being recorded.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.armed && self.enabled.load(Ordering::Relaxed)
    }

    /// Toggle recording (the `--profile-steps` window). No-op on a
    /// disarmed tracer.
    pub fn set_enabled(&self, on: bool) {
        if self.armed {
            self.enabled.store(on, Ordering::Relaxed);
        }
    }

    fn ts_us(&self, t: Instant) -> f64 {
        t.saturating_duration_since(self.epoch).as_secs_f64() * 1e6
    }

    /// This thread's track, registering (and caching) it on first use.
    fn thread_track(&self) -> Arc<Track> {
        let hit = THREAD_TRACKS.with(|c| {
            c.borrow().iter().find(|(id, _)| *id == self.id).map(|(_, t)| t.clone())
        });
        if let Some(t) = hit {
            return t;
        }
        let name = std::thread::current()
            .name()
            .map(|s| s.to_string())
            .unwrap_or_else(|| "main".to_string());
        let track = self.register_track(name);
        THREAD_TRACKS.with(|c| c.borrow_mut().push((self.id, track.clone())));
        track
    }

    fn register_track(&self, name: String) -> Arc<Track> {
        let mut tracks = self.tracks.lock().unwrap();
        // tid 0 is reserved for the counters track.
        let track = Arc::new(Track {
            tid: tracks.len() as u64 + 1,
            name: Mutex::new(name),
            events: Mutex::new(Vec::new()),
        });
        tracks.push(track.clone());
        track
    }

    /// A virtual track addressed by name (request/counter timelines that
    /// don't correspond to a thread).
    fn named_track(&self, name: &str) -> Arc<Track> {
        if let Some(t) = self.named.lock().unwrap().get(name) {
            return t.clone();
        }
        let track = self.register_track(name.to_string());
        self.named.lock().unwrap().insert(name.to_string(), track.clone());
        track
    }

    /// Rename the calling thread's track (e.g. `host0 (d0,m1)`); threads
    /// otherwise inherit their OS thread name. No-op when disarmed.
    pub fn name_track(&self, name: impl Into<String>) {
        if !self.armed {
            return;
        }
        *self.thread_track().name.lock().unwrap() = name.into();
    }

    /// Open a RAII span; it records a complete (`X`) event on this
    /// thread's track when dropped. Prefer the [`crate::span!`] macro.
    #[inline]
    pub fn span(&self, name: &str) -> Span<'_> {
        if !self.is_enabled() {
            return Span { inner: None };
        }
        Span {
            inner: Some(SpanInner {
                tracer: self,
                name: name.to_string(),
                start: Instant::now(),
                args: Vec::new(),
            }),
        }
    }

    /// Record a complete span retroactively from a pair of instants onto
    /// a named virtual track (per-request timelines).
    pub fn complete(
        &self,
        track: &str,
        name: impl Into<String>,
        start: Instant,
        end: Instant,
        args: Vec<(&'static str, ArgValue)>,
    ) {
        if !self.is_enabled() {
            return;
        }
        let ts = self.ts_us(start);
        let dur = (self.ts_us(end) - ts).max(0.0);
        self.named_track(track).push(TraceEvent {
            name: name.into(),
            ts_us: ts,
            kind: EventKind::Complete { dur_us: dur },
            args,
        });
    }

    /// Record a counter (`C`) sample — gauges over time (queue depth,
    /// slot occupancy) render as area charts in Perfetto.
    pub fn counter(&self, name: &str, value: f64) {
        if !self.is_enabled() {
            return;
        }
        self.named_track("counters").push(TraceEvent {
            name: name.to_string(),
            ts_us: self.ts_us(Instant::now()),
            kind: EventKind::Counter { value },
            args: Vec::new(),
        });
    }

    /// Total recorded events across all tracks.
    pub fn event_count(&self) -> usize {
        self.tracks.lock().unwrap().iter().map(|t| t.events.lock().unwrap().len()).sum()
    }

    /// Render the trace as a Chrome trace-event JSON value.
    pub fn to_chrome_json(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        let tracks = self.tracks.lock().unwrap().clone();
        for track in &tracks {
            let tname = track.name.lock().unwrap().clone();
            events.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(track.tid as f64)),
                ("args", Json::obj(vec![("name", Json::str(tname))])),
            ]));
            for ev in track.events.lock().unwrap().iter() {
                let mut pairs = vec![
                    ("name", Json::str(ev.name.clone())),
                    ("pid", Json::num(0.0)),
                    ("tid", Json::num(track.tid as f64)),
                    ("ts", Json::num(ev.ts_us)),
                ];
                match &ev.kind {
                    EventKind::Complete { dur_us } => {
                        pairs.push(("ph", Json::str("X")));
                        pairs.push(("dur", Json::num(*dur_us)));
                        if !ev.args.is_empty() {
                            let apairs: Vec<(&str, Json)> =
                                ev.args.iter().map(|(k, v)| (*k, v.to_json())).collect();
                            pairs.push(("args", Json::obj(apairs)));
                        }
                    }
                    EventKind::Counter { value } => {
                        pairs.push(("ph", Json::str("C")));
                        pairs.push(("args", Json::obj(vec![("value", Json::num(*value))])));
                    }
                }
                events.push(Json::obj(pairs));
            }
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
        ])
    }

    /// Write the Chrome trace JSON to `path`.
    pub fn export_chrome(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_chrome_json().to_string().as_bytes())?;
        f.write_all(b"\n")
    }

    /// [`Self::export_chrome`], but on failure warn once to stderr
    /// instead of erroring (mirrors the `JsonlWriter` contract: a broken
    /// sink must never take down a training run).
    pub fn export_or_warn(&self, path: impl AsRef<Path>) {
        let path = path.as_ref();
        if let Err(e) = self.export_chrome(path) {
            if !self.export_warned.swap(true, Ordering::Relaxed) {
                eprintln!("warning: failed to write trace to {}: {e}", path.display());
            }
        }
    }
}

/// RAII span guard returned by [`Tracer::span`] / [`crate::span!`].
/// Records a complete event on drop; a disabled tracer returns an inert
/// guard that allocates nothing.
pub struct Span<'a> {
    inner: Option<SpanInner<'a>>,
}

struct SpanInner<'a> {
    tracer: &'a Tracer,
    name: String,
    start: Instant,
    args: Vec<(&'static str, ArgValue)>,
}

impl Span<'_> {
    /// Attach a key/value attribute. The value conversion only runs when
    /// the span is live, so `&str` args don't allocate while tracing is
    /// off.
    pub fn arg(mut self, key: &'static str, value: impl Into<ArgValue>) -> Self {
        if let Some(inner) = &mut self.inner {
            inner.args.push((key, value.into()));
        }
        self
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let Some(inner) = self.inner.take() else { return };
        let ts = inner.tracer.ts_us(inner.start);
        let dur = (inner.tracer.ts_us(Instant::now()) - ts).max(0.0);
        inner.tracer.thread_track().push(TraceEvent {
            name: inner.name,
            ts_us: ts,
            kind: EventKind::Complete { dur_us: dur },
            args: inner.args,
        });
    }
}

/// Open a RAII span on a [`Tracer`]:
/// `span!(tracer, "train/step")` or
/// `span!(tracer, "coll/all_reduce", { "elems" => n, "op" => "sum" })`.
#[macro_export]
macro_rules! span {
    ($tracer:expr, $name:expr) => {
        $tracer.span($name)
    };
    ($tracer:expr, $name:expr, { $($k:literal => $v:expr),* $(,)? }) => {
        $tracer.span($name)$(.arg($k, $v))*
    };
}

/// Parse a `--profile-steps` window: `N..M` traces steps `N <= s < M`;
/// a bare `N` traces just that step.
pub fn parse_profile_steps(s: &str) -> anyhow::Result<(u64, u64)> {
    let parse =
        |t: &str| t.trim().parse::<u64>().map_err(|_| anyhow::anyhow!("bad step '{t}'"));
    if let Some((a, b)) = s.split_once("..") {
        let (a, b) = (parse(a)?, parse(b)?);
        anyhow::ensure!(b > a, "--profile-steps expects N..M with M > N, got '{s}'");
        Ok((a, b))
    } else {
        let a = parse(s)?;
        Ok((a, a + 1))
    }
}

// ---------------------------------------------------------------------------
// Histogram

const HIST_BUCKETS: usize = 64;
const HIST_GROWTH: f64 = 1.5;
/// Lower edge of bucket 0, in milliseconds (1 µs).
const HIST_FLOOR_MS: f64 = 1e-3;

/// Fixed log-bucket latency histogram (growth 1.5×, 64 buckets from 1 µs
/// to ~5×10^7 s — far past anything a step or request can take).
///
/// Recording is lock-free (one atomic add per sample); clones share
/// storage. `percentile` returns the upper bound of the bucket containing
/// the requested rank, except in the histogram's top occupied bucket where
/// the exact observed max is returned (so p99 never exceeds max).
#[derive(Clone)]
pub struct Histogram {
    inner: Arc<HistInner>,
}

struct HistInner {
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            inner: Arc::new(HistInner {
                counts: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
                count: AtomicU64::new(0),
                sum_us: AtomicU64::new(0),
                max_us: AtomicU64::new(0),
            }),
        }
    }

    fn bucket_index(v_ms: f64) -> usize {
        if v_ms <= HIST_FLOOR_MS {
            return 0;
        }
        let idx = ((v_ms / HIST_FLOOR_MS).ln() / HIST_GROWTH.ln()).floor() as usize;
        idx.min(HIST_BUCKETS - 1)
    }

    /// Upper bound of bucket `i`, in milliseconds.
    fn bucket_upper_ms(i: usize) -> f64 {
        HIST_FLOOR_MS * HIST_GROWTH.powi(i as i32 + 1)
    }

    pub fn record_ms(&self, v_ms: f64) {
        if !v_ms.is_finite() || v_ms < 0.0 {
            return;
        }
        let i = Self::bucket_index(v_ms);
        self.inner.counts[i].fetch_add(1, Ordering::Relaxed);
        self.inner.count.fetch_add(1, Ordering::Relaxed);
        let us = (v_ms * 1e3) as u64;
        self.inner.sum_us.fetch_add(us, Ordering::Relaxed);
        self.inner.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn record_seconds(&self, v_s: f64) {
        self.record_ms(v_s * 1e3);
    }

    pub fn count(&self) -> u64 {
        self.inner.count.load(Ordering::Relaxed)
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.inner.sum_us.load(Ordering::Relaxed) as f64 / 1e3 / n as f64
    }

    pub fn max_ms(&self) -> f64 {
        self.inner.max_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Value (ms) at quantile `q` in [0, 1]: the upper bound of the
    /// bucket holding the rank, clamped to the observed max.
    pub fn percentile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for i in 0..HIST_BUCKETS {
            seen += self.inner.counts[i].load(Ordering::Relaxed);
            if seen >= rank {
                return Self::bucket_upper_ms(i).min(self.max_ms());
            }
        }
        self.max_ms()
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    /// Emit `{prefix}_p50/_p95/_p99/_mean_ms/_count` at `step`.
    pub fn log_to(&self, logger: &MetricsLogger, step: u64, prefix: &str) {
        if self.count() == 0 {
            return;
        }
        let names = [
            format!("{prefix}_p50"),
            format!("{prefix}_p95"),
            format!("{prefix}_p99"),
            format!("{prefix}_mean_ms"),
            format!("{prefix}_count"),
        ];
        let values =
            [self.p50(), self.p95(), self.p99(), self.mean_ms(), self.count() as f64];
        let pairs: Vec<(&str, f64)> =
            names.iter().map(|n| n.as_str()).zip(values).collect();
        logger.log(step, &pairs);
    }
}

// ---------------------------------------------------------------------------
// Gauges

/// Last-write-wins named scalar gauges (queue depth, slot occupancy).
/// Arc-backed: clones share storage, like [`crate::metrics::CounterSet`].
#[derive(Clone, Default)]
pub struct GaugeSet {
    inner: Arc<Mutex<BTreeMap<String, f64>>>,
}

impl GaugeSet {
    pub fn new() -> GaugeSet {
        GaugeSet::default()
    }

    pub fn set(&self, name: &str, v: f64) {
        self.inner.lock().unwrap().insert(name.to_string(), v);
    }

    pub fn get(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().get(name).copied()
    }

    pub fn snapshot(&self) -> Vec<(String, f64)> {
        self.inner.lock().unwrap().iter().map(|(k, &v)| (k.clone(), v)).collect()
    }

    /// Emit every gauge as a metric point at `step`.
    pub fn log_to(&self, logger: &MetricsLogger, step: u64) {
        let snap = self.snapshot();
        if snap.is_empty() {
            return;
        }
        let values: Vec<(&str, f64)> =
            snap.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        logger.log(step, &values);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threads::parallel_map;

    #[test]
    fn histogram_percentile_bounds() {
        let h = Histogram::new();
        for v in 1..=100 {
            h.record_ms(v as f64);
        }
        assert_eq!(h.count(), 100);
        // Upper-bound contract: true_pXX <= reported <= true_pXX * growth.
        let p50 = h.p50();
        assert!((50.0..=50.0 * HIST_GROWTH).contains(&p50), "p50={p50}");
        let p95 = h.p95();
        assert!((95.0..=95.0 * HIST_GROWTH).contains(&p95), "p95={p95}");
        let p99 = h.p99();
        assert!((99.0..=100.0).contains(&p99), "p99={p99} (clamped to max)");
        assert_eq!(h.max_ms(), 100.0);
        assert!((h.mean_ms() - 50.5).abs() < 0.01, "mean={}", h.mean_ms());
        // Percentiles never exceed the observed max.
        assert!(h.percentile(1.0) <= h.max_ms());
    }

    #[test]
    fn histogram_empty_and_tiny_values() {
        let h = Histogram::new();
        assert_eq!(h.p99(), 0.0);
        h.record_ms(0.0);
        h.record_ms(1e-9);
        assert_eq!(h.count(), 2);
        assert!(h.p50() <= Histogram::bucket_upper_ms(0));
    }

    #[test]
    fn histogram_concurrent_recording() {
        let h = Histogram::new();
        let hc = h.clone();
        parallel_map(8, 8, move |i| {
            for k in 0..250 {
                hc.record_ms((1 + (i * 250 + k) % 40) as f64);
            }
        });
        assert_eq!(h.count(), 2000);
        assert!(h.p99() >= 39.0);
    }

    #[test]
    fn tracer_off_records_nothing() {
        let t = Tracer::off();
        {
            let _s = span!(t, "work", { "k" => 1u64 });
        }
        t.counter("g", 1.0);
        assert!(!t.is_enabled());
        assert_eq!(t.event_count(), 0);
        t.set_enabled(true); // no-op on a disarmed tracer
        assert!(!t.is_enabled());
    }

    #[test]
    fn tracer_records_and_exports_chrome_json() {
        let t = Tracer::new();
        t.name_track("test-main");
        {
            let _outer = span!(t, "outer", { "step" => 3u64 });
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _inner = span!(t, "inner", { "op" => "sum", "elems" => 128usize });
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        t.counter("queue_depth", 4.0);
        let now = Instant::now();
        t.complete("virtual", "req 1", now, now, vec![("id", ArgValue::Num(1.0))]);
        assert_eq!(t.event_count(), 4);

        let path = std::env::temp_dir().join(format!("trace_{}.json", std::process::id()));
        t.export_chrome(&path).unwrap();
        let v = Json::parse_file(&path).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        // 4 events + >= 2 thread_name metadata records
        assert!(evs.len() >= 6, "got {} events", evs.len());
        let mut saw_inner = false;
        for e in evs {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            match ph {
                "X" => {
                    assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
                    assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
                    if e.get("name").unwrap().as_str() == Some("inner") {
                        saw_inner = true;
                        let args = e.get("args").unwrap();
                        assert_eq!(args.get("op").unwrap().as_str(), Some("sum"));
                        assert_eq!(args.get("elems").unwrap().as_f64(), Some(128.0));
                    }
                }
                "C" => {
                    assert_eq!(e.get("args").unwrap().get("value").unwrap().as_f64(), Some(4.0));
                }
                "M" => {}
                other => panic!("unexpected phase {other}"),
            }
        }
        assert!(saw_inner);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tracer_concurrent_span_recording() {
        let t = Tracer::new();
        let tc = t.clone();
        parallel_map(8, 8, move |i| {
            for k in 0..100 {
                let _s = span!(tc, "work", { "host" => i, "k" => k });
            }
        });
        assert_eq!(t.event_count(), 800);
        // 8 worker tracks, each with 100 spans; export stays parseable.
        let v = t.to_chrome_json();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("X")).count(), 800);
    }

    #[test]
    fn profile_window_gates_recording() {
        let t = Tracer::new();
        t.set_enabled(false);
        {
            let _s = span!(t, "hidden");
        }
        assert_eq!(t.event_count(), 0);
        t.set_enabled(true);
        {
            let _s = span!(t, "visible");
        }
        assert_eq!(t.event_count(), 1);
    }

    #[test]
    fn parse_profile_steps_forms() {
        assert_eq!(parse_profile_steps("2..5").unwrap(), (2, 5));
        assert_eq!(parse_profile_steps("7").unwrap(), (7, 8));
        assert!(parse_profile_steps("5..2").is_err());
        assert!(parse_profile_steps("x..y").is_err());
    }

    #[test]
    fn gauges_last_write_wins() {
        let g = GaugeSet::new();
        g.set("depth", 3.0);
        g.set("depth", 1.0);
        assert_eq!(g.get("depth"), Some(1.0));
        assert_eq!(g.snapshot(), vec![("depth".to_string(), 1.0)]);
    }
}
