//! Feature converters (paper §3.1, Figure 2): translate *task* features
//! ("inputs"/"targets") into the raw *model* features each architecture
//! consumes, so "the same task can be made compatible with various
//! architectures".
//!
//! * [`EncDecConverter`] — encoder-decoder (T5): encoder_input_tokens +
//!   teacher-forced decoder stream.
//! * [`LmConverter`] — decoder-only LM (LaMDA-style): targets only.
//! * [`PrefixLmConverter`] — decoder-only with inputs as unweighted prefix.
//!
//! Packing is provided by [`pack_lm`]/[`PackedLmConverter`]: multiple short
//! examples share one row with segment ids + positions. NOTE: the exported
//! HLO models do not take segment ids, so the trainer uses the unpacked
//! converters; packing is exercised by tests/benches (documented in
//! DESIGN.md).

use std::collections::BTreeMap;
use std::sync::Arc;

use super::dataset::Dataset;
use super::vocab::PAD_ID;
use super::{Example, Feature};

/// Requested sequence lengths per *task* feature, e.g.
/// {"inputs": 64, "targets": 64}.
pub type FeatureLengths = BTreeMap<String, usize>;

pub fn lengths(pairs: &[(&str, usize)]) -> FeatureLengths {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

/// Common converter interface.
pub trait FeatureConverter: Send + Sync {
    fn name(&self) -> &'static str;
    /// The *task* features this converter consumes ("inputs"/"targets").
    /// `get_dataset` validates them against the task's declared output
    /// features and requires a task_feature_length for each.
    fn task_features(&self) -> &'static [&'static str];
    /// Names (and lengths) of the model features this converter emits.
    fn model_feature_lengths(&self, task_lengths: &FeatureLengths) -> FeatureLengths;
    fn convert_example(&self, ex: &Example, task_lengths: &FeatureLengths) -> Example;

    fn convert(&self, ds: Dataset, task_lengths: &FeatureLengths) -> Dataset
    where
        Self: Sized + Clone + 'static,
    {
        let me = self.clone();
        let lens = task_lengths.clone();
        ds.map(move |ex| me.convert_example(&ex, &lens))
    }
}

// ---------------------------------------------------------------------------
// Converter registry: name / model-arch -> converter
// ---------------------------------------------------------------------------

static CONVERTERS: once_cell::sync::Lazy<
    std::sync::Mutex<BTreeMap<String, Arc<dyn FeatureConverter>>>,
> = once_cell::sync::Lazy::new(|| {
    let mut m: BTreeMap<String, Arc<dyn FeatureConverter>> = BTreeMap::new();
    m.insert("enc_dec".to_string(), Arc::new(EncDecConverter));
    m.insert("lm".to_string(), Arc::new(LmConverter));
    m.insert("prefix_lm".to_string(), Arc::new(PrefixLmConverter::default()));
    std::sync::Mutex::new(m)
});

/// Register a custom converter under a unique name (duplicates error,
/// matching the task registry contract).
pub fn register_converter(
    name: &str,
    conv: Arc<dyn FeatureConverter>,
) -> anyhow::Result<()> {
    let mut reg = CONVERTERS.lock().unwrap();
    anyhow::ensure!(
        !reg.contains_key(name),
        "a feature converter named '{name}' is already registered"
    );
    reg.insert(name.to_string(), conv);
    Ok(())
}

pub fn converter(name: &str) -> Option<Arc<dyn FeatureConverter>> {
    CONVERTERS.lock().unwrap().get(name).cloned()
}

pub fn converter_names() -> Vec<String> {
    CONVERTERS.lock().unwrap().keys().cloned().collect()
}

/// The converter a model architecture consumes by default — the single
/// home of the arch dispatch that used to be copy-pasted per call site.
pub fn converter_for_arch(arch: &str) -> Arc<dyn FeatureConverter> {
    let name = match arch {
        "encdec" | "enc_dec" | "encoder_decoder" => "enc_dec",
        _ => "lm",
    };
    converter(name).expect("built-in converter present")
}

/// Resolve a registry name or a model-arch alias to a converter.
pub fn resolve_converter(name_or_arch: &str) -> anyhow::Result<Arc<dyn FeatureConverter>> {
    if let Some(c) = converter(name_or_arch) {
        return Ok(c);
    }
    match name_or_arch {
        "encdec" | "encoder_decoder" | "decoder" | "dec" => Ok(converter_for_arch(name_or_arch)),
        other => anyhow::bail!(
            "unknown feature converter '{other}' (registered: [{}])",
            converter_names().join(", ")
        ),
    }
}

/// Uniform task-feature lengths for a converter (every consumed feature
/// at `len` — the trainer's default when only a model seq_len is known).
pub fn default_task_lengths(conv: &dyn FeatureConverter, len: usize) -> FeatureLengths {
    conv.task_features().iter().map(|f| (f.to_string(), len)).collect()
}

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

fn pad_or_trim(v: &[i32], len: usize) -> Vec<i32> {
    let mut out = v.to_vec();
    out.truncate(len);
    out.resize(len, PAD_ID);
    out
}

/// Teacher-forcing shift: BOS (= pad id 0, the T5 convention) + targets[:-1].
pub fn shift_right(targets: &[i32]) -> Vec<i32> {
    let mut out = Vec::with_capacity(targets.len());
    out.push(PAD_ID);
    out.extend_from_slice(&targets[..targets.len().saturating_sub(1)]);
    out
}

fn loss_weights(target_padded: &[i32]) -> Vec<f32> {
    target_padded
        .iter()
        .map(|&t| if t == PAD_ID { 0.0 } else { 1.0 })
        .collect()
}

fn ints<'a>(ex: &'a Example, key: &str) -> &'a [i32] {
    ex.get(key)
        .and_then(|f| f.as_ints())
        .unwrap_or_else(|| panic!("feature converter: missing int feature '{key}'"))
}

// ---------------------------------------------------------------------------
// Encoder-decoder
// ---------------------------------------------------------------------------

#[derive(Clone, Default)]
pub struct EncDecConverter;

impl FeatureConverter for EncDecConverter {
    fn name(&self) -> &'static str {
        "enc_dec"
    }

    fn task_features(&self) -> &'static [&'static str] {
        &["inputs", "targets"]
    }

    fn model_feature_lengths(&self, t: &FeatureLengths) -> FeatureLengths {
        lengths(&[
            ("encoder_input_tokens", t["inputs"]),
            ("decoder_input_tokens", t["targets"]),
            ("decoder_target_tokens", t["targets"]),
            ("decoder_loss_weights", t["targets"]),
        ])
    }

    fn convert_example(&self, ex: &Example, t: &FeatureLengths) -> Example {
        let enc = pad_or_trim(ints(ex, "inputs"), t["inputs"]);
        let tgt = pad_or_trim(ints(ex, "targets"), t["targets"]);
        let dec_in = shift_right(&tgt);
        let w = loss_weights(&tgt);
        let mut out = Example::new();
        out.insert("encoder_input_tokens".into(), Feature::Ints(enc));
        out.insert("decoder_input_tokens".into(), Feature::Ints(dec_in));
        out.insert("decoder_target_tokens".into(), Feature::Ints(tgt));
        out.insert("decoder_loss_weights".into(), Feature::Floats(w));
        out
    }
}

// ---------------------------------------------------------------------------
// Decoder-only LM
// ---------------------------------------------------------------------------

#[derive(Clone, Default)]
pub struct LmConverter;

impl FeatureConverter for LmConverter {
    fn name(&self) -> &'static str {
        "lm"
    }

    fn task_features(&self) -> &'static [&'static str] {
        &["targets"]
    }

    fn model_feature_lengths(&self, t: &FeatureLengths) -> FeatureLengths {
        lengths(&[
            ("decoder_input_tokens", t["targets"]),
            ("decoder_target_tokens", t["targets"]),
            ("decoder_loss_weights", t["targets"]),
        ])
    }

    fn convert_example(&self, ex: &Example, t: &FeatureLengths) -> Example {
        let tgt = pad_or_trim(ints(ex, "targets"), t["targets"]);
        let dec_in = shift_right(&tgt);
        let w = loss_weights(&tgt);
        let mut out = Example::new();
        out.insert("decoder_input_tokens".into(), Feature::Ints(dec_in));
        out.insert("decoder_target_tokens".into(), Feature::Ints(tgt));
        out.insert("decoder_loss_weights".into(), Feature::Floats(w));
        out
    }
}

// ---------------------------------------------------------------------------
// Prefix-LM (decoder-only with inputs as a loss-free prefix)
// ---------------------------------------------------------------------------

#[derive(Clone)]
pub struct PrefixLmConverter {
    pub loss_on_targets_only: bool,
}

impl Default for PrefixLmConverter {
    fn default() -> Self {
        Self { loss_on_targets_only: true }
    }
}

impl FeatureConverter for PrefixLmConverter {
    fn name(&self) -> &'static str {
        "prefix_lm"
    }

    fn task_features(&self) -> &'static [&'static str] {
        &["inputs", "targets"]
    }

    fn model_feature_lengths(&self, t: &FeatureLengths) -> FeatureLengths {
        let total = t["inputs"] + t["targets"];
        lengths(&[
            ("decoder_input_tokens", total),
            ("decoder_target_tokens", total),
            ("decoder_loss_weights", total),
        ])
    }

    fn convert_example(&self, ex: &Example, t: &FeatureLengths) -> Example {
        let total = t["inputs"] + t["targets"];
        let inp = ints(ex, "inputs");
        let tgt = ints(ex, "targets");
        let inp_trim: Vec<i32> =
            inp.iter().copied().take(t["inputs"]).collect();
        let mut full: Vec<i32> = inp_trim.clone();
        full.extend(tgt.iter().copied().take(t["targets"]));
        let full_padded = pad_or_trim(&full, total);
        let dec_in = shift_right(&full_padded);
        let mut w = loss_weights(&full_padded);
        if self.loss_on_targets_only {
            for slot in w.iter_mut().take(inp_trim.len()) {
                *slot = 0.0;
            }
        }
        let mut out = Example::new();
        out.insert("decoder_input_tokens".into(), Feature::Ints(dec_in));
        out.insert("decoder_target_tokens".into(), Feature::Ints(full_padded));
        out.insert("decoder_loss_weights".into(), Feature::Floats(w));
        out
    }
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// Greedy first-fit packing of LM examples into rows of length `row_len`.
/// Emits `decoder_*` features plus `decoder_segment_ids` (1-based per packed
/// example) and `decoder_positions` (position within each segment).
pub fn pack_lm(examples: &[Example], row_len: usize) -> Vec<Example> {
    let mut rows: Vec<(Vec<i32>, Vec<i32>, Vec<i32>)> = Vec::new(); // (tokens, seg, pos)
    for ex in examples {
        let tgt = ints(ex, "targets");
        let tgt: Vec<i32> = tgt.iter().copied().take(row_len).collect();
        // first-fit
        let slot = rows.iter_mut().find(|(toks, _, _)| toks.len() + tgt.len() <= row_len);
        match slot {
            Some((toks, seg, pos)) => {
                let seg_id = seg.last().copied().unwrap_or(0) + 1;
                for (i, &t) in tgt.iter().enumerate() {
                    toks.push(t);
                    seg.push(seg_id);
                    pos.push(i as i32);
                }
            }
            None => {
                let mut toks = Vec::with_capacity(row_len);
                let mut seg = Vec::with_capacity(row_len);
                let mut pos = Vec::with_capacity(row_len);
                for (i, &t) in tgt.iter().enumerate() {
                    toks.push(t);
                    seg.push(1);
                    pos.push(i as i32);
                }
                rows.push((toks, seg, pos));
            }
        }
    }
    rows.into_iter()
        .map(|(mut toks, mut seg, mut pos)| {
            let tgt_padded = {
                toks.resize(row_len, PAD_ID);
                toks
            };
            seg.resize(row_len, 0);
            pos.resize(row_len, 0);
            // shift within segments: BOS at each segment start
            let mut dec_in = vec![PAD_ID; row_len];
            for i in 0..row_len {
                if seg[i] != 0 && pos[i] > 0 {
                    dec_in[i] = tgt_padded[i - 1];
                }
            }
            let w = loss_weights(&tgt_padded);
            let mut out = Example::new();
            out.insert("decoder_input_tokens".into(), Feature::Ints(dec_in));
            out.insert("decoder_target_tokens".into(), Feature::Ints(tgt_padded));
            out.insert("decoder_loss_weights".into(), Feature::Floats(w));
            out.insert("decoder_segment_ids".into(), Feature::Ints(seg));
            out.insert("decoder_positions".into(), Feature::Ints(pos));
            out
        })
        .collect()
}

/// Dataset-level packed LM converter (buffers `buffer` examples per bin).
#[derive(Clone)]
pub struct PackedLmConverter {
    pub buffer: usize,
}

impl Default for PackedLmConverter {
    fn default() -> Self {
        Self { buffer: 128 }
    }
}

impl PackedLmConverter {
    pub fn convert(&self, ds: Dataset, row_len: usize) -> Dataset {
        Dataset::from_op(Packer {
            inner: ds.into_op(),
            out: Default::default(),
            buffer: self.buffer.max(1),
            row_len,
            done: false,
        })
    }
}

/// Stateful packing op: buffers `buffer` upstream examples per bin, emits
/// packed rows. Its state is the not-yet-emitted packed rows plus the
/// upstream state, so packed pipelines checkpoint/resume exactly.
struct Packer {
    inner: Box<dyn crate::seqio::dataset::PipelineOp>,
    out: std::collections::VecDeque<Example>,
    buffer: usize,
    row_len: usize,
    done: bool,
}

impl crate::seqio::dataset::PipelineOp for Packer {
    fn next(&mut self) -> Option<Example> {
        loop {
            if let Some(e) = self.out.pop_front() {
                return Some(e);
            }
            if self.done {
                return None;
            }
            let mut batch = Vec::with_capacity(self.buffer);
            for _ in 0..self.buffer {
                match self.inner.next() {
                    Some(e) => batch.push(e),
                    None => {
                        self.done = true;
                        break;
                    }
                }
            }
            if batch.is_empty() {
                return None;
            }
            self.out.extend(pack_lm(&batch, self.row_len));
        }
    }

    fn state(&mut self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("op", Json::str("packed_lm")),
            ("done", Json::Bool(self.done)),
            (
                "out",
                Json::Arr(
                    self.out
                        .iter()
                        .map(crate::seqio::dataset::example_to_json)
                        .collect(),
                ),
            ),
            ("inner", self.inner.state()),
        ])
    }

    fn restore(&mut self, s: &crate::util::json::Json) -> anyhow::Result<()> {
        use crate::seqio::dataset::{check_tag, example_from_json, field, field_arr, field_bool};
        check_tag(s, "packed_lm")?;
        self.done = field_bool(s, "done")?;
        self.out = field_arr(s, "out")?
            .iter()
            .map(example_from_json)
            .collect::<anyhow::Result<_>>()?;
        self.inner.restore(field(s, "inner")?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::seqio::ints_example;
    use crate::seqio::vocab::EOS_ID;

    fn lm_ex(toks: Vec<i32>) -> Example {
        ints_example(&[("targets", toks)])
    }

    #[test]
    fn lm_converter_shapes_and_shift() {
        let c = LmConverter;
        let t = lengths(&[("targets", 8)]);
        let out = c.convert_example(&lm_ex(vec![5, 6, 7, EOS_ID]), &t);
        assert_eq!(
            out["decoder_target_tokens"].as_ints().unwrap(),
            &[5, 6, 7, EOS_ID, 0, 0, 0, 0]
        );
        assert_eq!(
            out["decoder_input_tokens"].as_ints().unwrap(),
            &[0, 5, 6, 7, EOS_ID, 0, 0, 0]
        );
        assert_eq!(
            out["decoder_loss_weights"].as_floats().unwrap(),
            &[1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0]
        );
    }

    #[test]
    fn encdec_converter_emits_all_features() {
        let c = EncDecConverter;
        let t = lengths(&[("inputs", 6), ("targets", 4)]);
        let mut ex = lm_ex(vec![9, 8, EOS_ID]);
        ex.insert("inputs".into(), Feature::Ints(vec![1, 2, 3]));
        let out = c.convert_example(&ex, &t);
        assert_eq!(out["encoder_input_tokens"].as_ints().unwrap(), &[1, 2, 3, 0, 0, 0]);
        assert_eq!(out["decoder_target_tokens"].as_ints().unwrap(), &[9, 8, EOS_ID, 0]);
        assert_eq!(out["decoder_input_tokens"].as_ints().unwrap(), &[0, 9, 8, EOS_ID]);
        let ml = c.model_feature_lengths(&t);
        assert_eq!(ml["encoder_input_tokens"], 6);
        assert_eq!(ml["decoder_target_tokens"], 4);
    }

    #[test]
    fn truncation_applies() {
        let c = LmConverter;
        let t = lengths(&[("targets", 3)]);
        let out = c.convert_example(&lm_ex(vec![1, 2, 3, 4, 5]), &t);
        assert_eq!(out["decoder_target_tokens"].as_ints().unwrap(), &[1, 2, 3]);
    }

    #[test]
    fn prefix_lm_weights_mask_prefix() {
        let c = PrefixLmConverter::default();
        let t = lengths(&[("inputs", 3), ("targets", 3)]);
        let mut ex = lm_ex(vec![7, 8]);
        ex.insert("inputs".into(), Feature::Ints(vec![4, 5]));
        let out = c.convert_example(&ex, &t);
        assert_eq!(out["decoder_target_tokens"].as_ints().unwrap(), &[4, 5, 7, 8, 0, 0]);
        assert_eq!(
            out["decoder_loss_weights"].as_floats().unwrap(),
            &[0.0, 0.0, 1.0, 1.0, 0.0, 0.0]
        );
    }

    #[test]
    fn packing_invariants() {
        let exs: Vec<Example> = vec![
            lm_ex(vec![1, 2, 3]),
            lm_ex(vec![4, 5]),
            lm_ex(vec![6, 7, 8, 9]),
            lm_ex(vec![10]),
        ];
        let rows = pack_lm(&exs, 8);
        // fewer rows than examples
        assert!(rows.len() < exs.len());
        // every token appears exactly once across rows
        let mut all: Vec<i32> = rows
            .iter()
            .flat_map(|r| {
                r["decoder_target_tokens"]
                    .as_ints()
                    .unwrap()
                    .iter()
                    .copied()
                    .filter(|&t| t != PAD_ID)
            })
            .collect();
        all.sort();
        assert_eq!(all, (1..=10).collect::<Vec<_>>());
        for r in &rows {
            let seg = r["decoder_segment_ids"].as_ints().unwrap();
            let pos = r["decoder_positions"].as_ints().unwrap();
            let dec_in = r["decoder_input_tokens"].as_ints().unwrap();
            let tgt = r["decoder_target_tokens"].as_ints().unwrap();
            for i in 0..seg.len() {
                if seg[i] != 0 && pos[i] == 0 {
                    // each segment starts with BOS in the shifted stream
                    assert_eq!(dec_in[i], PAD_ID);
                }
                if seg[i] != 0 && pos[i] > 0 {
                    assert_eq!(dec_in[i], tgt[i - 1]);
                    assert_eq!(seg[i], seg[i - 1]);
                }
            }
        }
    }

    #[test]
    fn converter_registry_resolves_names_and_arch_aliases() {
        assert_eq!(resolve_converter("enc_dec").unwrap().name(), "enc_dec");
        assert_eq!(resolve_converter("encdec").unwrap().name(), "enc_dec");
        assert_eq!(resolve_converter("lm").unwrap().name(), "lm");
        assert_eq!(resolve_converter("decoder").unwrap().name(), "lm");
        assert_eq!(resolve_converter("prefix_lm").unwrap().name(), "prefix_lm");
        assert!(resolve_converter("no_such_converter").is_err());
        assert_eq!(converter_for_arch("encdec").name(), "enc_dec");
        assert_eq!(converter_for_arch("decoder").name(), "lm");
        // duplicate registration of a built-in name errors
        assert!(register_converter("lm", Arc::new(LmConverter)).is_err());
        // default lengths cover exactly the consumed task features
        let tl = default_task_lengths(&EncDecConverter, 32);
        assert_eq!(tl["inputs"], 32);
        assert_eq!(tl["targets"], 32);
        assert_eq!(default_task_lengths(&LmConverter, 16).len(), 1);
    }

    #[test]
    fn packed_dataset_converter_streams() {
        let exs: Vec<Example> = (0..50)
            .map(|i| lm_ex(vec![i + 1; (i as usize % 5) + 1]))
            .collect();
        let packed = PackedLmConverter { buffer: 16 }
            .convert(Dataset::from_vec(exs), 16)
            .collect_vec();
        assert!(!packed.is_empty());
        assert!(packed.len() < 50);
        for r in &packed {
            assert_eq!(r["decoder_target_tokens"].as_ints().unwrap().len(), 16);
        }
    }
}
