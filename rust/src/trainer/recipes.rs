//! Recipes: canonical task/pipeline constructions shared by the examples,
//! the CLI launcher, and the benches — the t5x "configs" directory as code.
//!
//! Since the [`crate::seqio::get_dataset`] redesign this module owns two
//! things:
//!
//! * the **default registry** ([`register_defaults`]): the named tasks and
//!   mixtures (`c4_lm`, `c4_span`, `reverse_words`, `c4_span_rev_mix`)
//!   that `t5x train --task <name>` / gin `train.task = '<name>'` resolve;
//! * the **provider → infeed bridge** ([`provider_infeed`]): any
//!   [`DatasetProvider`] (live task, mixture, or [`CachedTask`]) becomes a
//!   model-ready multi-host [`Infeed`] through one `get_dataset` call per
//!   host. The feature converter comes from the converter registry keyed
//!   by model arch — the per-arch `if arch == "encdec"` dispatch that used
//!   to be copy-pasted per call site lives only in
//!   [`crate::seqio::feature_converters::converter_for_arch`] now.

use std::path::Path;
use std::sync::Arc;

use crate::runtime::artifacts::ModelManifest;
use crate::seqio::cache::{cache_task_splits, CacheConfig, CacheMeta};
use crate::seqio::dataset::{Dataset, PipelineState};
use crate::seqio::feature_converters::{
    converter_for_arch, default_task_lengths, lengths, EncDecConverter, FeatureConverter,
};
use crate::seqio::preprocessors::{AppendEos, ChunkTokens, SpanCorruption, Tokenize};
use crate::seqio::provider::{
    get_dataset, CachedTask, DatasetProvider, GetDatasetOptions, ShardInfo,
};
use crate::seqio::mixture::Mixture;
use crate::seqio::source::SyntheticTextSource;
use crate::seqio::task::Task;
use crate::seqio::vocab::{ByteVocabulary, Vocabulary};
use crate::trainer::infeed::Infeed;

/// Byte vocabulary sized for every exported model (vocab >= 275).
pub fn default_vocab() -> Arc<dyn Vocabulary> {
    Arc::new(ByteVocabulary::new(16))
}

/// Sequence length the default registry tasks chunk to. Feature converters
/// pad/trim per model, so models with other seq_lens still consume them.
pub const DEFAULT_SEQ_LEN: usize = 64;

/// Held-out validation corpus derived from a task's train seed: same
/// document shape, distinct seed (`^ "VAL"`), a quarter of the train
/// docs (floor 16).
fn validation_source(
    seed: u64,
    train_docs: usize,
    sentences_per_doc: usize,
    words_per_sentence: usize,
) -> Arc<SyntheticTextSource> {
    Arc::new(SyntheticTextSource::with_shape(
        seed ^ 0x56414C, // "VAL"
        (train_docs / 4).max(16),
        sentences_per_doc,
        words_per_sentence,
    ))
}

/// Causal-LM pretraining task over the synthetic corpus: tokenize ->
/// chunk(seq_len-1) -> append EOS. (The C4-substitute pipeline.) Ships a
/// held-out "validation" split alongside "train".
pub fn lm_task(name: &str, docs: usize, seq_len: usize, seed: u64) -> Arc<Task> {
    let vocab = default_vocab();
    Task::builder(name)
        .source(Arc::new(SyntheticTextSource::new(seed, docs)))
        .split_source("validation", validation_source(seed, docs, 5, 12))
        .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
        .preprocessor(Arc::new(ChunkTokens::new("targets", seq_len - 1)))
        .preprocessor(Arc::new(AppendEos::new(&["targets"])))
        .output_feature("targets", vocab, true)
        .build()
}

/// T5 span-corruption pretraining task (the enc-dec objective).
pub fn span_corruption_task(name: &str, docs: usize, seq_len: usize, seed: u64) -> Arc<Task> {
    let vocab = default_vocab();
    Task::builder(name)
        .source(Arc::new(SyntheticTextSource::new(seed, docs)))
        .split_source("validation", validation_source(seed, docs, 5, 12))
        .preprocessor(Arc::new(Tokenize::new(vocab.clone(), &[("text", "targets")])))
        .preprocessor(Arc::new(ChunkTokens::new("targets", seq_len)))
        .preprocessor(Arc::new(SpanCorruption::new(vocab.clone())))
        .preprocessor(Arc::new(AppendEos::new(&["targets"])))
        .output_feature("inputs", vocab.clone(), false)
        .output_feature("targets", vocab, true)
        .build()
}

/// A synthetic *seq2seq* task with learnable structure: the target is the
/// input sentence with its words reversed. Used by the finetune/eval
/// example (E15) — exact-match/BLEU rise above chance quickly.
pub fn reverse_words_task(name: &str, examples: usize, seed: u64) -> Arc<Task> {
    let vocab = default_vocab();
    let src = SyntheticTextSource::with_shape(seed, examples, 1, 5);
    Task::builder(name)
        .source(Arc::new(src))
        .split_source("validation", validation_source(seed, examples, 1, 5))
        .preprocessor(Arc::new(MapReverse))
        .preprocessor(Arc::new(Tokenize::new(
            vocab.clone(),
            &[("inputs_text", "inputs"), ("targets_text", "targets")],
        )))
        .preprocessor(Arc::new(AppendEos::new(&["targets"])))
        .output_feature("inputs", vocab.clone(), false)
        .output_feature("targets", vocab, true)
        .metric(crate::seqio::evaluation::Metric::ExactMatch)
        .metric(crate::seqio::evaluation::Metric::TokenAccuracy)
        .metric(crate::seqio::evaluation::Metric::Bleu)
        .build()
}

/// Populate the unified provider registry with the canonical named tasks
/// and mixtures every CLI/gin scenario resolves (`t5x list-tasks` prints
/// them). Idempotent — names that already exist (user-registered or from
/// a previous call) are left untouched, and it re-registers after a
/// registry reset; call before any by-name lookup.
pub fn register_defaults() {
    use crate::seqio::provider::ProviderRegistry;
    use crate::seqio::task::TaskRegistry;
    if ProviderRegistry::get("c4_lm").is_none() {
        let _ = TaskRegistry::add(lm_task("c4_lm", 512, DEFAULT_SEQ_LEN, 42));
    }
    if ProviderRegistry::get("c4_span").is_none() {
        let _ = TaskRegistry::add(span_corruption_task("c4_span", 512, DEFAULT_SEQ_LEN, 42));
    }
    if ProviderRegistry::get("reverse_words").is_none() {
        let _ = TaskRegistry::add(reverse_words_task("reverse_words", 2048, 11));
    }
    if ProviderRegistry::get("c4_span_rev_mix").is_none() {
        // Can genuinely fail (e.g. a user-registered 'c4_span' with a
        // different schema) — surface it instead of a later misleading
        // "not in the registry".
        if let Err(e) =
            Mixture::from_names("c4_span_rev_mix", &[("c4_span", 0.7), ("reverse_words", 0.3)])
                .and_then(|m| m.register())
        {
            eprintln!("warning: default mixture 'c4_span_rev_mix' not registered: {e}");
        }
    }
}

/// Register a gin-defined mixture into the unified namespace:
///
/// ```text
/// mixture.name = 'my_mix'
/// mixture.tasks = ['c4_span', 'reverse_words']
/// mixture.rates = [0.7, 0.3]        # optional; uniform when omitted
/// ```
///
/// Members are bound *lazily by name* ([`Mixture::lazy`]) — the gin file
/// may name tasks that are registered later in process setup; resolution
/// happens at the mixture's first `dataset()` use. Returns the mixture
/// name, or `Ok(None)` when the config defines no mixture. Idempotent:
/// an already-registered name is left untouched.
pub fn register_gin_mixture(gin: &crate::gin::Config) -> anyhow::Result<Option<String>> {
    use crate::seqio::provider::ProviderRegistry;
    let Some(name) = gin.get("mixture", "name").and_then(|v| v.as_str()).map(String::from)
    else {
        return Ok(None);
    };
    if ProviderRegistry::get(&name).is_some() {
        return Ok(Some(name));
    }
    let tasks = gin.get("mixture", "tasks").and_then(|v| v.as_list()).ok_or_else(|| {
        anyhow::anyhow!("gin mixture '{name}' needs `mixture.tasks = ['a', 'b', ...]`")
    })?;
    let task_names: Vec<String> = tasks
        .iter()
        .map(|v| {
            v.as_str().map(String::from).ok_or_else(|| {
                anyhow::anyhow!("gin mixture '{name}': mixture.tasks entries must be strings")
            })
        })
        .collect::<anyhow::Result<_>>()?;
    let rates: Vec<f64> = match gin.get("mixture", "rates").and_then(|v| v.as_list()) {
        Some(rs) => {
            anyhow::ensure!(
                rs.len() == task_names.len(),
                "gin mixture '{name}': {} tasks but {} rates",
                task_names.len(),
                rs.len()
            );
            rs.iter()
                .map(|v| {
                    v.as_f64().ok_or_else(|| {
                        anyhow::anyhow!("gin mixture '{name}': rates must be numbers")
                    })
                })
                .collect::<anyhow::Result<_>>()?
        }
        None => vec![1.0; task_names.len()],
    };
    let members: Vec<(&str, f64)> =
        task_names.iter().map(String::as_str).zip(rates).collect();
    Mixture::lazy(&name, &members).register()?;
    Ok(Some(name))
}

/// Default registry task for a model architecture: an arch must get a
/// task whose output features its converter can consume (an encdec model
/// needs "inputs"; the old hardcoded `lm_task` fed it empty encoder rows).
pub fn default_task_for_arch(arch: &str) -> &'static str {
    match arch {
        "encdec" | "enc_dec" | "encoder_decoder" => "c4_span",
        _ => "c4_lm",
    }
}

/// The split evaluation should read: "validation" when the provider
/// declares one, else "train".
pub fn eval_split(provider: &dyn DatasetProvider) -> String {
    let splits = provider.splits();
    if splits.iter().any(|s| s == "validation") {
        "validation".to_string()
    } else {
        "train".to_string()
    }
}

/// text -> (inputs_text = text, targets_text = words reversed).
struct MapReverse;

impl crate::seqio::preprocessors::Preprocessor for MapReverse {
    fn name(&self) -> &'static str {
        "map_reverse"
    }

    fn apply(
        &self,
        ds: Dataset,
        _ctx: &crate::seqio::preprocessors::PipelineCtx,
    ) -> Dataset {
        ds.map(|mut ex| {
            let text = ex["text"].as_text().unwrap_or("").trim_end_matches('.').to_string();
            let reversed: Vec<&str> = text.split_whitespace().rev().collect();
            ex.insert(
                "inputs_text".into(),
                crate::seqio::Feature::Text(text.clone()),
            );
            ex.insert(
                "targets_text".into(),
                crate::seqio::Feature::Text(reversed.join(" ")),
            );
            ex
        })
    }
}

/// Cache every split of a task if not already cached (idempotent
/// `make`-style). A stale cache — different task, seed, shard count, or a
/// split set that no longer matches the task's declaration (including
/// legacy single-split roots) — is rebuilt in the per-split layout.
pub fn ensure_cached(
    task: &Task,
    dir: &Path,
    num_shards: usize,
    seed: u64,
) -> anyhow::Result<CacheMeta> {
    if dir.join("cache_meta.json").exists() {
        let meta = CacheMeta::load(dir)?;
        let want = DatasetProvider::splits(task);
        if meta.num_shards == num_shards
            && meta.seed == seed
            && meta.task == task.name
            && meta.splits.as_deref() == Some(want.as_slice())
        {
            return Ok(meta);
        }
    }
    cache_task_splits(task, dir, &CacheConfig { num_shards, seed, workers: 4 })
}

/// Model-ready multi-host infeed over any [`DatasetProvider`] — THE
/// trainer data path. Per host it issues one [`get_dataset`] call with
/// the feature converter the model arch consumes (converter registry),
/// validates task-vs-model feature lengths against the manifest, repeats
/// over epochs, and positions the stream: checkpointed per-host pipeline
/// states win (exact op-graph restore); otherwise the coarse
/// `start_step * batch` offset (the fallback for checkpoints that predate
/// pipeline state — caches seek it in O(1), live tasks replay).
pub fn provider_infeed(
    m: &ModelManifest,
    provider: Arc<dyn DatasetProvider>,
    split: &str,
    num_hosts: usize,
    prefetch: usize,
    start_step: u64,
    seed: u64,
    resume: Option<&[PipelineState]>,
) -> anyhow::Result<Infeed> {
    let conv = converter_for_arch(&m.arch);
    let task_lengths = default_task_lengths(conv.as_ref(), m.seq_len());

    // task-vs-model feature-length validation: the converter must emit
    // exactly the lengths the compiled entrypoints were built for.
    let model_lengths = conv.model_feature_lengths(&task_lengths);
    for spec in &m.batch_features {
        let got = model_lengths.get(&spec.name).ok_or_else(|| {
            anyhow::anyhow!(
                "converter '{}' does not produce model feature '{}' required by model '{}'",
                conv.name(),
                spec.name,
                m.name
            )
        })?;
        anyhow::ensure!(
            *got == spec.shape[1],
            "feature '{}': converter '{}' produces length {got}, model '{}' expects {}",
            spec.name,
            conv.name(),
            m.name,
            spec.shape[1]
        );
    }

    let start = if resume.is_some() { 0 } else { start_step as usize * m.batch() };
    let conv_name = conv.name().to_string();
    let split = split.to_string();
    Infeed::spawn_resumable(
        m,
        num_hosts,
        prefetch.max(1),
        move |host| {
            get_dataset(
                provider.clone(),
                &GetDatasetOptions {
                    split: split.clone(),
                    task_feature_lengths: task_lengths.clone(),
                    converter: Some(conv_name.clone()),
                    shard: ShardInfo { index: host, num_shards: num_hosts },
                    seed,
                    start,
                    repeat: true,
                    resume: None, // per-host restore is applied by spawn_resumable
                    // In-stream head validation is near-free, and running
                    // it on EVERY host keeps failure symmetric: a schema
                    // bug kills all rows' streams at the same step, so the
                    // mesh drains through the exhaustion path instead of
                    // stranding live rows in collectives.
                    validate: true,
                },
            )
        },
        resume,
    )
}

/// Infeed over a cached deterministic pipeline — [`provider_infeed`] with
/// the directory opened as a [`CachedTask`] provider.
pub fn cached_infeed(
    m: &ModelManifest,
    cache_dir: &Path,
    num_hosts: usize,
    prefetch: usize,
    start_step: u64,
    resume: Option<&[PipelineState]>,
) -> anyhow::Result<Infeed> {
    let cached: Arc<dyn DatasetProvider> = Arc::new(CachedTask::open(cache_dir, None)?);
    provider_infeed(m, cached, "train", num_hosts, prefetch, start_step, 0, resume)
}

/// Converted eval batches for `m` from any provider, through the same
/// [`get_dataset`] entry point. Pick `split` with [`eval_split`] (the
/// provider's "validation" split when declared). Errors if the provider
/// cannot feed the model's converter (e.g. a targets-only task under an
/// encdec model).
pub fn eval_batches(
    m: &ModelManifest,
    provider: Arc<dyn DatasetProvider>,
    split: &str,
    seed: u64,
    num_batches: usize,
) -> anyhow::Result<Vec<Vec<crate::runtime::HostTensor>>> {
    let conv = converter_for_arch(&m.arch);
    let ds = get_dataset(
        provider,
        &GetDatasetOptions {
            split: split.to_string(),
            task_feature_lengths: default_task_lengths(conv.as_ref(), m.seq_len()),
            converter: Some(conv.name().to_string()),
            seed,
            ..Default::default()
        },
    )?;
    let examples = ds.take(num_batches * m.batch()).collect_vec();
    Ok(examples
        .chunks(m.batch())
        .filter(|c| c.len() == m.batch())
        .take(num_batches)
        .map(|c| crate::trainer::infeed::assemble_batch(m, c))
        .collect())
}

/// Raw (target, source-pairs) for decode-based evaluation of the
/// reverse-words task: returns (enc_batch_tensors, target_strings).
pub fn decode_eval_set(
    m: &ModelManifest,
    task: &Task,
    seed: u64,
) -> (Vec<crate::runtime::HostTensor>, Vec<String>, Vec<String>) {
    assert_eq!(m.arch, "encdec");
    let seq = m.seq_len();
    let examples = task.dataset(seed, 0, 1).take(m.batch()).collect_vec();
    assert_eq!(examples.len(), m.batch(), "not enough eval examples");
    let tl = lengths(&[("inputs", seq), ("targets", seq)]);
    let converted: Vec<_> = examples
        .iter()
        .map(|e| EncDecConverter.convert_example(e, &tl))
        .collect();
    let batch = crate::trainer::infeed::assemble_batch(m, &converted);
    let enc = batch[0].clone();
    let targets: Vec<String> = examples
        .iter()
        .map(|e| e["targets_text"].as_text().unwrap_or("").to_string())
        .collect();
    let inputs: Vec<String> = examples
        .iter()
        .map(|e| e["inputs_text"].as_text().unwrap_or("").to_string())
        .collect();
    (vec![enc], targets, inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;
    use crate::seqio::provider::ProviderRegistry;

    #[test]
    fn reverse_task_produces_learnable_pairs() {
        let task = reverse_words_task("rev_test", 10, 1);
        let exs = task.dataset(0, 0, 1).collect_vec();
        assert_eq!(exs.len(), 10);
        for ex in &exs {
            let inp = ex["inputs_text"].as_text().unwrap();
            let tgt = ex["targets_text"].as_text().unwrap();
            let rev: Vec<&str> = inp.split_whitespace().rev().collect();
            assert_eq!(tgt, rev.join(" "));
            assert!(!ex["inputs"].as_ints().unwrap().is_empty());
        }
    }

    #[test]
    fn default_registry_resolves_by_name() {
        register_defaults();
        for name in ["c4_lm", "c4_span", "reverse_words", "c4_span_rev_mix"] {
            let p = ProviderRegistry::provider(name).unwrap();
            assert_eq!(p.name(), name);
            assert!(p.splits().contains(&"train".to_string()), "{name}");
        }
        // tasks carry held-out validation splits
        let span = ProviderRegistry::provider("c4_span").unwrap();
        assert!(span.splits().contains(&"validation".to_string()));
        assert_eq!(default_task_for_arch("encdec"), "c4_span");
        assert_eq!(default_task_for_arch("decoder"), "c4_lm");
    }

    #[test]
    fn gin_mixture_registers_and_binds_lazily() {
        // the gin file names member tasks that do not exist yet
        let gin = crate::gin::Config::parse(
            "mixture.name = 'gin_mix_test'\n\
             mixture.tasks = ['gin_mix_member_a', 'gin_mix_member_b']\n\
             mixture.rates = [0.7, 0.3]\n",
        )
        .unwrap();
        assert_eq!(register_gin_mixture(&gin).unwrap().as_deref(), Some("gin_mix_test"));
        let entry = ProviderRegistry::get("gin_mix_test").expect("mixture registered");
        assert_eq!(entry.kind(), "mixture");
        // members resolve at first dataset() use — register them now,
        // after the mixture
        use crate::seqio::task::TaskRegistry;
        TaskRegistry::add(lm_task("gin_mix_member_a", 40, 32, 1)).unwrap();
        TaskRegistry::add(lm_task("gin_mix_member_b", 40, 32, 2)).unwrap();
        let p = entry.provider();
        let ds = p
            .dataset("train", crate::seqio::provider::ShardInfo { index: 0, num_shards: 1 }, 0)
            .unwrap();
        assert!(!ds.take(5).collect_vec().is_empty());
        // second registration attempt is an idempotent no-op
        assert_eq!(register_gin_mixture(&gin).unwrap().as_deref(), Some("gin_mix_test"));
        // a config with no mixture section is a clean None
        assert_eq!(register_gin_mixture(&crate::gin::Config::new()).unwrap(), None);
        for n in ["gin_mix_test", "gin_mix_member_a", "gin_mix_member_b"] {
            ProviderRegistry::remove(n);
        }
    }

    #[test]
    fn eval_batches_shapes() {
        let arts = Artifacts::load_default().unwrap();
        let m = arts.model("t5-nano-dec").unwrap();
        let task = lm_task("recipes_eval_lm", 100, m.seq_len(), 3);
        let split = eval_split(task.as_ref());
        assert_eq!(split, "validation");
        let batches = eval_batches(m, task, &split, 0, 3).unwrap();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].len(), 3);
        assert_eq!(batches[0][0].shape, vec![m.batch(), m.seq_len()]);
    }

    #[test]
    fn eval_batches_rejects_featureless_task_for_encdec() {
        let arts = Artifacts::load_default().unwrap();
        // an encdec model cannot evaluate a targets-only LM task: the
        // converter's "inputs" feature is missing from the declaration
        if let Ok(m) = arts.model("t5-nano-encdec") {
            let task = lm_task("recipes_eval_mismatch", 50, m.seq_len(), 3);
            let err = eval_batches(m, task, "validation", 0, 2).unwrap_err().to_string();
            assert!(err.contains("inputs"), "{err}");
        }
    }

    #[test]
    fn ensure_cached_idempotent() {
        let dir = std::env::temp_dir().join(format!("recipes_cache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let task = lm_task("recipes_cache_lm", 50, 32, 1);
        let m1 = ensure_cached(&task, &dir, 4, 9).unwrap();
        let mtime1 = std::fs::metadata(dir.join("cache_meta.json")).unwrap().modified().unwrap();
        let m2 = ensure_cached(&task, &dir, 4, 9).unwrap();
        let mtime2 = std::fs::metadata(dir.join("cache_meta.json")).unwrap().modified().unwrap();
        assert_eq!(m1.num_examples, m2.num_examples);
        assert_eq!(mtime1, mtime2, "cache should not be rebuilt");
        std::fs::remove_dir_all(&dir).ok();
    }
}
