//! Self-healing training supervisor.
//!
//! [`Supervisor`] wraps [`Trainer::train`] in a restart loop: when an
//! attempt fails (a host panic, a tripped collective deadline, an infeed
//! source that exhausted its retries), the supervisor
//!
//! 1. waits out a bounded exponential backoff (`backoff_ms << (attempt-1)`,
//!    capped at [`MAX_BACKOFF_MS`]),
//! 2. builds a **fresh** [`Trainer`] — a failed attempt permanently poisons
//!    the shared collectives abort flag, so the old mesh is unusable,
//! 3. restores the latest *valid* checkpoint via [`Trainer::restore_latest`]
//!    (which sweeps stale `*.tmp` dirs and quarantines corrupt steps as
//!    `ckpt-<n>.corrupt` before falling back to an older one), and
//! 4. re-targets the attempt at the original end step, so a supervised run
//!    trains exactly as many steps as an unsupervised one.
//!
//! Because the training loop, the RNG streams, and the data pipeline are all
//! keyed on the absolute step / host / shard rather than wall-clock state, a
//! recovered run is **bit-identical** to a fault-free run — the integration
//! suite asserts final parameters and the consumed `_index` sequence match
//! exactly (`tests/integration_faults.rs`).
//!
//! The supervisor exports `train/restarts`, `train/recovery_ms`, and
//! `train/quarantined_ckpts` through the final attempt's [`CounterSet`], so
//! they land in the regular metrics stream.
//!
//! When [`SupervisorConfig::comm_deadline_ms`] is set, the supervisor arms
//! the global collective deadline (see
//! [`crate::collectives::set_comm_deadline_ms`]) for the duration of the run
//! and restores the previous value on exit; wedged ring neighbours then trip
//! the abort flag with a panic that names the stalled collective point, axis,
//! and rank — which the restart loop treats like any other failed attempt.

use std::time::Instant;

use crate::runtime::{Artifacts, DeviceHandle};

use super::{BatchSource, TrainSummary, Trainer, TrainerConfig};

/// Ceiling on a single backoff sleep, regardless of attempt count.
pub const MAX_BACKOFF_MS: u64 = 30_000;

/// Restart policy for a supervised training run.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// How many times a failed attempt may be relaunched before the
    /// supervisor gives up and propagates the last error. `0` disables
    /// recovery entirely (one attempt, no retry).
    pub max_restarts: u32,
    /// Base backoff between attempts; attempt `n` sleeps
    /// `backoff_ms << (n-1)` ms, capped at [`MAX_BACKOFF_MS`].
    pub backoff_ms: u64,
    /// When set, arm the global collective ring deadline for the duration
    /// of the supervised run so wedged peers fail loudly instead of
    /// hanging forever. The previous value is restored on exit.
    pub comm_deadline_ms: Option<u64>,
    /// Restore the latest checkpoint before the *first* attempt (the
    /// supervised equivalent of `--resume`). Restarted attempts always
    /// restore regardless of this flag.
    pub resume: bool,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            max_restarts: 3,
            backoff_ms: 100,
            comm_deadline_ms: None,
            resume: false,
        }
    }
}

/// The result of a supervised run: the usual [`TrainSummary`] plus recovery
/// bookkeeping, and the final attempt's [`Trainer`] so callers can inspect
/// parameters or reuse the mesh.
pub struct SupervisedRun {
    pub summary: TrainSummary,
    /// Restarts actually performed (0 for a fault-free run).
    pub restarts: u32,
    /// Checkpoints quarantined as `.corrupt` across all restore attempts.
    pub quarantined_ckpts: u64,
    /// Total wall-clock ms spent in backoff + rebuild + restore.
    pub recovery_ms: u64,
    pub trainer: Trainer,
}

/// Restores the previously configured collective deadline when dropped, so
/// a supervised run cannot leak its deadline into later (unsupervised)
/// work in the same process.
struct DeadlineGuard {
    prev: u64,
    armed: bool,
}

impl DeadlineGuard {
    fn arm(ms: Option<u64>) -> Self {
        let prev = crate::collectives::comm_deadline_ms();
        let armed = match ms {
            Some(ms) => {
                crate::collectives::set_comm_deadline_ms(ms);
                true
            }
            None => false,
        };
        DeadlineGuard { prev, armed }
    }
}

impl Drop for DeadlineGuard {
    fn drop(&mut self) {
        if self.armed {
            crate::collectives::set_comm_deadline_ms(self.prev);
        }
    }
}

/// Self-healing wrapper around [`Trainer::train`]. See the module docs for
/// the recovery contract.
pub struct Supervisor<'a> {
    arts: &'a Artifacts,
    device: &'a DeviceHandle,
    config: TrainerConfig,
    sup: SupervisorConfig,
}

impl<'a> Supervisor<'a> {
    pub fn new(
        arts: &'a Artifacts,
        device: &'a DeviceHandle,
        config: TrainerConfig,
        sup: SupervisorConfig,
    ) -> Self {
        Supervisor {
            arts,
            device,
            config,
            sup,
        }
    }

    /// Run to completion, restarting failed attempts.
    ///
    /// `make_source` builds the [`BatchSource`] for an attempt — it is
    /// called once per attempt because an [`super::infeed::Infeed`] is
    /// consumed by the attempt that used it (its producer threads die with
    /// the failed step loop), while the restored `pipeline_states` on the
    /// fresh trainer tell the new source where to resume.
    ///
    /// `configure` decorates each freshly built trainer (attach a logger or
    /// tracer, for example); it receives the attempt index starting at 0.
    /// Loggers are attached per attempt because [`Trainer::with_logger`]
    /// takes the logger by value.
    pub fn run(
        &self,
        make_source: impl Fn(&Trainer) -> anyhow::Result<BatchSource>,
        configure: impl Fn(Trainer, u32) -> Trainer,
    ) -> anyhow::Result<SupervisedRun> {
        let _deadline = DeadlineGuard::arm(self.sup.comm_deadline_ms);

        let mut restarts: u32 = 0;
        let mut recovery_ms: u64 = 0;
        let mut quarantined: u64 = 0;

        // Attempt 0: build, optionally resume, and fix the end step every
        // later attempt must re-target.
        let mut trainer = self.build_attempt(0, None, &configure, &mut quarantined)?;
        let target_end = trainer.start_step + self.config.steps;

        loop {
            trainer.counters.add("train/restarts", restarts as u64);
            trainer.counters.add("train/recovery_ms", recovery_ms);
            // A failed source build is retried like a failed attempt: a
            // transient data-path error on relaunch should not defeat the
            // restart budget that exists for exactly such failures.
            let outcome = match make_source(&trainer) {
                Ok(source) => trainer.train(&source),
                Err(e) => Err(e),
            };
            match outcome {
                Ok(summary) => {
                    return Ok(SupervisedRun {
                        summary,
                        restarts,
                        quarantined_ckpts: quarantined,
                        recovery_ms,
                        trainer,
                    });
                }
                Err(err) => {
                    let attempt = restarts + 1;
                    if attempt > self.sup.max_restarts {
                        return Err(err.context(format!(
                            "supervisor: giving up after {restarts} restart(s) \
                             (max_restarts = {})",
                            self.sup.max_restarts
                        )));
                    }
                    restarts = attempt;
                    eprintln!(
                        "warning: supervisor: training attempt failed ({err:#}); \
                         restart {attempt}/{} after backoff",
                        self.sup.max_restarts
                    );
                    let t0 = Instant::now();
                    // Clamp the doubling exponent so a large restart budget
                    // can neither overflow the shift nor exceed the cap.
                    let backoff = self
                        .sup
                        .backoff_ms
                        .saturating_mul(1u64 << (attempt - 1).min(20))
                        .min(MAX_BACKOFF_MS);
                    std::thread::sleep(std::time::Duration::from_millis(backoff));
                    trainer = self.build_attempt(
                        attempt,
                        Some(target_end),
                        &configure,
                        &mut quarantined,
                    )?;
                    recovery_ms += t0.elapsed().as_millis() as u64;
                }
            }
        }
    }

    /// Build + configure a fresh trainer for one attempt, restoring the
    /// latest valid checkpoint when appropriate and re-targeting the step
    /// budget at `target_end` on restarts.
    fn build_attempt(
        &self,
        attempt: u32,
        target_end: Option<u64>,
        configure: &impl Fn(Trainer, u32) -> Trainer,
        quarantined: &mut u64,
    ) -> anyhow::Result<Trainer> {
        let trainer = Trainer::new(self.arts, self.device, self.config.clone())?;
        let mut trainer = configure(trainer, attempt);

        let want_restore = attempt > 0 || self.sup.resume;
        if want_restore {
            if let Some(dir) = self.config.checkpoint_dir.clone() {
                match trainer.restore_latest(&dir) {
                    Ok(step) => {
                        eprintln!(
                            "supervisor: attempt {attempt} restored checkpoint at step {step}"
                        );
                    }
                    Err(e) if attempt > 0 => {
                        // Nothing valid survived (e.g. the failure hit
                        // before the first save, or every retained step was
                        // quarantined): restart from scratch.
                        eprintln!(
                            "warning: supervisor: no valid checkpoint to restore \
                             ({e:#}); restarting attempt {attempt} from scratch"
                        );
                    }
                    Err(e) => {
                        // Explicit resume on the first attempt with nothing
                        // to resume from is a caller error: surface it.
                        return Err(e.context("supervisor: resume requested"));
                    }
                }
            } else if attempt > 0 {
                eprintln!(
                    "warning: supervisor: no checkpoint dir configured; \
                     restarting attempt {attempt} from step 0"
                );
            }
        }

        // Fold this attempt's quarantine count into the running total and
        // make the trainer's counter reflect the cumulative value.
        let fresh_q = trainer.counters.get("train/quarantined_ckpts");
        let prior = *quarantined;
        *quarantined = prior + fresh_q;
        trainer.counters.add("train/quarantined_ckpts", prior);

        if let Some(end) = target_end {
            trainer.set_steps(end.saturating_sub(trainer.start_step));
        }
        Ok(trainer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Artifacts, DeviceHandle};

    fn quick_cfg(steps: u64) -> TrainerConfig {
        TrainerConfig::quick("t5-nano-dec", steps)
    }

    #[test]
    fn fault_free_supervised_run_matches_plain_run() {
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();

        let plain = Trainer::new(&arts, &dev, quick_cfg(3)).unwrap();
        let plain_summary = plain
            .train(&BatchSource::Synthetic { seed: 7 })
            .unwrap();

        let sup = Supervisor::new(&arts, &dev, quick_cfg(3), SupervisorConfig::default());
        let run = sup
            .run(
                |_| Ok(BatchSource::Synthetic { seed: 7 }),
                |t, _attempt| t,
            )
            .unwrap();

        assert_eq!(run.restarts, 0);
        assert_eq!(run.quarantined_ckpts, 0);
        assert_eq!(run.summary.history.len(), plain_summary.history.len());
        for (a, b) in run.summary.history.iter().zip(plain_summary.history.iter()) {
            assert!((a.loss - b.loss).abs() <= 1e-6, "{} vs {}", a.loss, b.loss);
        }
        drop(run);
        dev.shutdown();
    }

    #[test]
    fn supervisor_gives_up_after_max_restarts() {
        let arts = Artifacts::load_default().unwrap();
        let dev = DeviceHandle::spawn().unwrap();

        let sup = Supervisor::new(
            &arts,
            &dev,
            quick_cfg(2),
            SupervisorConfig {
                max_restarts: 1,
                backoff_ms: 1,
                comm_deadline_ms: None,
                resume: false,
            },
        );
        // A source factory that always fails stands in for an unrecoverable
        // attempt without needing a real fault plan in a unit test.
        let err = sup
            .run(
                |_| anyhow::bail!("synthetic source failure"),
                |t, _attempt| t,
            )
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("synthetic source failure"), "{msg}");
        assert!(msg.contains("giving up after 1 restart"), "{msg}");
        dev.shutdown();
    }

    #[test]
    fn deadline_guard_restores_previous_value() {
        crate::collectives::set_comm_deadline_ms(0);
        {
            let _g = DeadlineGuard::arm(Some(1234));
            assert_eq!(crate::collectives::comm_deadline_ms(), 1234);
        }
        assert_eq!(crate::collectives::comm_deadline_ms(), 0);
        {
            let _g = DeadlineGuard::arm(None);
            assert_eq!(crate::collectives::comm_deadline_ms(), 0);
        }
        assert_eq!(crate::collectives::comm_deadline_ms(), 0);
    }
}
