"""L2 block-execution tests: the model-parallel segment schedule (§2.2)
must reproduce the monolithic train step, and the manifest contract
(block shapes, collective schedule) must be internally consistent."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import golden_batch

NANO_REF = dataclasses.replace(M.CONFIGS["t5-nano-dec"], use_pallas=False)


def _params_and_batch(cfg, seed=0):
    params = M.random_params(cfg, jax.random.PRNGKey(seed))
    batch = {k: jnp.asarray(v) for k, v in golden_batch(cfg).items()}
    return params, batch


@pytest.mark.parametrize("degree", [2, 4])
def test_block_schedule_matches_train_step(degree):
    """The simulated segment + collective schedule (exactly what the Rust
    trainer replays) agrees with train_step_fn on loss and every grad."""
    cfg = NANO_REF
    params, batch = _params_and_batch(cfg)
    fn, names = M.train_step_fn(cfg)
    args = [params[n] for n in names] + [batch[f] for f in M.batch_feature_names(cfg)]
    outs = jax.jit(fn)(*args)
    ls, ws, cs, grads = M.block_reference_step(cfg, degree, params, batch)
    np.testing.assert_allclose(float(ls), float(outs[0]), rtol=1e-5)
    assert float(ws) == float(outs[1])
    # argmax ties across vocab blocks may flip correct_sum by a weight unit
    assert abs(float(cs) - float(outs[2])) <= 1.5
    for n, g in zip(names, outs[3:]):
        np.testing.assert_allclose(
            np.asarray(grads[n]), np.asarray(g), atol=1e-5, rtol=1e-3, err_msg=n
        )


def test_block_specs_mirror_partitioner():
    """block_shape divides exactly the first divisible model-axis dim;
    replicated params are exactly the norm scales (fused-AR contract)."""
    cfg = M.CONFIGS["t5-nano-dec"]
    for degree in (2, 4):
        specs = M.model_block_specs(cfg, degree)
        by_name = {s["name"]: s for s in specs}
        assert by_name["token_embed"]["model_dim"] == 0
        assert by_name["token_embed"]["block_shape"] == [
            cfg.vocab // degree,
            cfg.d_model,
        ]
        assert by_name["decoder.relpos_bias"]["model_dim"] == 1
        wq = by_name["decoder.layers_0.self_attn.wq"]
        assert wq["block_shape"] == [cfg.d_model, cfg.joined_kv // degree]
        wo = by_name["decoder.layers_0.self_attn.wo"]
        assert wo["model_dim"] == 0
        repl = M.block_replicated_params(cfg, degree)
        assert repl == sorted(repl)
        assert len(repl) == 2 * cfg.num_layers + 1
        assert all(n.endswith("norm.scale") for n in repl)


def test_block_collective_schedule_shape():
    """Schedule order and payload sizes: fwd ARs, 4 loss reductions, bwd
    ARs, one fused replicated-grad AR — sized by activations, NOT params."""
    cfg = M.CONFIGS["t5-nano-dec"]
    sched = M.block_collective_schedule(cfg, 2)
    points = [p for (p, _, _) in sched]
    assert points[0] == "embed_out"
    assert points[-1] == "replicated_grads"
    assert points.count("logits_max") == 1
    # order: forward layers ascending, backward descending
    assert points.index("layer_0.attn_out") < points.index("layer_1.attn_out")
    assert points.index("layer_1.d_mlp") < points.index("layer_0.d_attn")
    ops = {op for (_, op, _) in sched}
    assert ops == {"all_reduce_sum", "all_reduce_max", "all_reduce_min"}
    bld = cfg.batch * cfg.seq_len * cfg.d_model
    total = sum(e for (_, _, e) in sched)
    expected = (
        bld * (2 + 4 * cfg.num_layers)  # embed + d_final + 2/layer fwd + bwd
        + 4 * cfg.batch * cfg.seq_len  # max/sum-exp/target-logit/claim
        + (2 * cfg.num_layers + 1) * cfg.d_model  # fused norm-scale grads
    )
    assert total == expected
    # activation-sized, not param-sized: growing vocab/d_ff 8x (the dims a
    # gather pays for) leaves the schedule payload unchanged
    fat = dataclasses.replace(cfg, vocab=cfg.vocab * 8, d_ff=cfg.d_ff * 8)
    assert sum(e for (_, _, e) in M.block_collective_schedule(fat, 2)) == total


def test_block_segment_shapes_cover_all_segments():
    cfg = M.CONFIGS["t5-nano-dec"]
    shapes = M.block_segment_shapes(cfg, 2)
    fns = M.block_segment_fns(cfg)
    assert set(shapes) == set(fns) == set(M.BLOCK_SEGMENT_NAMES)


def test_supports_block_degree():
    nano = M.CONFIGS["t5-nano-dec"]
    assert M.supports_block_degree(nano, 2)
    assert M.supports_block_degree(nano, 4)
    assert not M.supports_block_degree(nano, 3)  # heads=4 not divisible
    assert not M.supports_block_degree(nano, 1)  # degenerate
    assert not M.supports_block_degree(M.CONFIGS["t5-nano-encdec"], 2)


def test_embed_block_exactness():
    """Vocab-sharded lookup: summing the per-shard partials is bitwise the
    full-table lookup (one shard contributes the row, the rest zeros)."""
    cfg = NANO_REF
    params, batch = _params_and_batch(cfg)
    tokens = batch["decoder_input_tokens"]
    full = np.asarray(params["token_embed"])[np.asarray(tokens)]
    degree = 4
    vb = cfg.vocab // degree
    acc = np.zeros_like(full)
    for m in range(degree):
        emb_b = params["token_embed"][m * vb : (m + 1) * vb]
        acc = acc + np.asarray(M._embed_block_fwd(emb_b, tokens, jnp.int32(m)))
    np.testing.assert_array_equal(acc, full)
